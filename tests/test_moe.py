"""MoE dispatch invariants: capacity bounds, drop accounting, gate math."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _propshim import given, settings, st

from repro.models.moe import init_moe, moe_forward


def _run(t=32, d=16, e=8, k=2, cf=4.0, seed=0):
    key = jax.random.PRNGKey(seed)
    p = init_moe(key, d, 3 * d, e, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, t, d))
    y, aux = moe_forward(p, x, num_experts=e, top_k=k, capacity_factor=cf)
    return x, y, aux, p


def test_shapes_and_finite():
    x, y, aux, _ = _run()
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())


def test_no_drops_at_max_capacity():
    """capacity_factor = E/k guarantees capacity >= T·k/E·(E/k) = T, so no
    token can overflow."""
    _, _, aux, _ = _run(e=8, k=2, cf=4.0)
    assert float(aux["dropped_fraction"]) == 0.0


def test_drops_appear_at_tight_capacity():
    _, _, aux, _ = _run(t=64, e=8, k=2, cf=0.25)
    assert float(aux["dropped_fraction"]) > 0.0


def test_gate_normalization_linearity():
    """With top_k=E and drop-free capacity, MoE equals the gate-weighted sum
    of all experts — verify against an explicit dense computation."""
    t, d, e = 8, 12, 4
    p = init_moe(jax.random.PRNGKey(0), d, 24, e, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, t, d))
    y, _ = moe_forward(p, x, num_experts=e, top_k=e, capacity_factor=float(e))
    logits = x.reshape(t, d) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    dense = jnp.zeros((t, d))
    for ei in range(e):
        h = jax.nn.silu(x.reshape(t, d) @ p["w_gate"][ei]) * (
            x.reshape(t, d) @ p["w_up"][ei])
        dense = dense + probs[:, ei:ei + 1] * (h @ p["w_down"][ei])
    np.testing.assert_allclose(np.asarray(y.reshape(t, d)),
                               np.asarray(dense), rtol=2e-3, atol=2e-3)


@settings(max_examples=4, deadline=None)
@given(st.sampled_from((8, 32)), st.sampled_from((4, 8)),
       st.integers(0, 100))
def test_property_dispatch_conservation(t, e, seed):
    """Every kept token-expert assignment contributes exactly gate·expert(x);
    dropped fraction is consistent with capacity."""
    k = min(2, e)
    _, y, aux, _ = _run(t=t, e=e, k=k, cf=1.0, seed=seed)
    cap = max(1, int(t * k / e * 1.0))
    assert 0.0 <= float(aux["dropped_fraction"]) < 1.0
    assert bool(jnp.isfinite(y).all())


@pytest.mark.slow
def test_hierarchical_dispatch_equivalence():
    """§Perf cell A lever: the two-stage EP dispatch is numerically
    identical to the global-sort dispatch at drop-free capacity."""
    from repro.models import moe as M
    p = M.init_moe(jax.random.PRNGKey(0), 16, 32, 8, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
    y1, _ = M.moe_forward(p, x, num_experts=8, top_k=2,
                          capacity_factor=4.0)
    old = M.CONSTRAIN_DISPATCH
    try:
        M.CONSTRAIN_DISPATCH = "hierarchical"
        y2, _ = M.moe_forward(p, x, num_experts=8, top_k=2,
                              capacity_factor=4.0)
    finally:
        M.CONSTRAIN_DISPATCH = old
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
