"""CSRC format invariants: construction, round-trip, transpose, rectangular
extension — unit + hypothesis property tests."""
import numpy as np
import jax.numpy as jnp
import pytest
from _propshim import given, settings, st

from repro.core import csrc
from repro.kernels import ref


def dense_roundtrip(A, **kw):
    M = csrc.from_dense(A, **kw)
    back = csrc.to_dense(M)
    np.testing.assert_allclose(back, A.astype(back.dtype), rtol=1e-6)
    return M


def test_paper_example_shape():
    """A 9×9 structurally-symmetric matrix like the paper's Figure 1:
    nnz = n + 2k must hold exactly."""
    M = csrc.fem_band(9, 3, seed=0)
    assert M.nnz == M.n + 2 * M.k
    A = csrc.to_dense(M)
    # structural symmetry: pattern(A) == pattern(A^T)
    assert ((A != 0) == (A != 0).T).all()


def test_roundtrip_poisson():
    M = csrc.poisson2d(8)
    A = csrc.to_dense(M)
    assert A.shape == (64, 64)
    np.testing.assert_allclose(A, A.T)       # numerically symmetric
    assert M.numerically_symmetric


def test_roundtrip_nonsymmetric_values():
    M = csrc.fem_band(40, 6, seed=3)
    A = csrc.to_dense(M)
    assert not np.allclose(A, A.T)
    assert not M.numerically_symmetric
    dense_roundtrip(A)


def test_pattern_padding():
    """General matrices get explicit zeros at missing transpose slots."""
    A = np.zeros((4, 4), np.float32)
    A[0, 0] = 1; A[2, 0] = 3.0; A[3, 3] = 2.0     # (0,2) missing
    M = csrc.from_dense(A)
    assert M.k == 1                                 # one lower slot
    np.testing.assert_allclose(csrc.to_dense(M), A)


def test_transpose_is_swap():
    M = csrc.fem_band(32, 5, seed=1)
    Mt = csrc.transpose(M)
    np.testing.assert_allclose(csrc.to_dense(Mt), csrc.to_dense(M).T,
                               rtol=1e-6)
    # O(1): same underlying arrays, swapped
    assert Mt.al is M.au and Mt.au is M.al


def test_rectangular_extension():
    M = csrc.rectangular_fem(24, 8, 4, seed=2)
    assert M.m == 32 and M.n == 24
    A = csrc.to_dense(M)
    x = np.random.default_rng(0).standard_normal(32).astype(np.float32)
    y = ref.csrc_spmv(M, jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y), A @ x, rtol=1e-4, atol=1e-4)


def test_bandwidth_and_nnz_per_row():
    M = csrc.fem_band(50, 7, seed=0)
    assert csrc.bandwidth(M) <= 7
    npr = csrc.nnz_per_row(M)
    A = csrc.to_dense(M)
    np.testing.assert_array_equal(npr, (A != 0).sum(axis=1))


@settings(max_examples=6, deadline=None)
@given(st.integers(4, 24), st.integers(1, 6), st.integers(0, 10_000))
def test_property_roundtrip_and_spmv(n, band, seed):
    """Property: for any random band matrix, CSRC round-trips exactly and
    its SpMV matches the dense product."""
    M = csrc.fem_band(n, min(band, n - 1), seed=seed)
    A = csrc.to_dense(M)
    assert ((A != 0) == (A != 0).T).all()
    x = np.random.default_rng(seed).standard_normal(n).astype(np.float32)
    y = np.asarray(ref.csrc_spmv(M, jnp.asarray(x)))
    np.testing.assert_allclose(y, A @ x, rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(3, 12), st.integers(0, 10_000))
def test_property_dense_general(n, seed):
    """Any dense nonsymmetric matrix is representable (pattern padding)."""
    rng = np.random.default_rng(seed)
    A = np.where(rng.random((n, n)) < 0.5,
                 rng.standard_normal((n, n)), 0.0).astype(np.float32)
    M = csrc.from_dense(A)
    np.testing.assert_allclose(csrc.to_dense(M), A, rtol=1e-6)
    assert M.nnz >= int((A != 0).sum())      # padding only adds slots
