"""Training substrate: optimizer math, microbatch equivalence, error
feedback, trainer fault tolerance, straggler detection, checkpoints."""
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.models.transformer import build_model
from repro.data.pipeline import pipeline_for_model, TokenPipeline, PipelineConfig
from repro.optim import adamw
from repro.optim.compress import ef_accumulate
from repro.train.step import make_train_step, init_train_state, TrainState
from repro.train.trainer import Trainer, TrainerConfig, StragglerMonitor
from repro.checkpoint import ckpt


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("qwen1.5-0.5b", reduced=True)
    model = build_model(cfg)
    opt_cfg = adamw.AdamWConfig(lr_peak=1e-3, warmup_steps=5,
                                total_steps=50)
    state = init_train_state(model, opt_cfg, jax.random.PRNGKey(0))
    pipe = pipeline_for_model(cfg, global_batch=4, seq_len=16)
    return cfg, model, opt_cfg, state, pipe


@pytest.mark.slow
def test_loss_decreases(tiny):
    cfg, model, opt_cfg, state, pipe = tiny
    step = jax.jit(make_train_step(model, opt_cfg, remat="none"))
    first = last = None
    for i in range(15):
        state, m = step(state, pipe.batch_at(i))
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first


@pytest.mark.slow
def test_microbatch_equivalence(tiny):
    """fp32 gradient accumulation over microbatches must equal the
    single-large-batch gradient (to bf16 backward noise).  Compared at the
    gradient level — Adam's sqrt(v) normalization amplifies bf16 noise on
    near-zero entries, which is not what this property is about."""
    cfg, model, opt_cfg, state, pipe = tiny
    batch = pipe.batch_at(0)

    def loss_fn(p, b):
        return model.loss(p, b)[0]

    g_full = jax.grad(loss_fn)(state.params, batch)
    mb = jax.tree.map(lambda a: a.reshape((4, 1) + a.shape[1:]), batch)
    gs = [jax.grad(loss_fn)(state.params,
                            jax.tree.map(lambda a, i=i: a[i], mb))
          for i in range(4)]
    g_acc = jax.tree.map(
        lambda *x: sum(xi.astype(jnp.float32) for xi in x) / 4, *gs)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
        d = float(jnp.abs(a.astype(jnp.float32) - b).max())
        s = float(jnp.abs(b).max()) + 1e-9
        assert d / s < 5e-2, (d, s)


@pytest.mark.slow
def test_remat_grad_equivalence(tiny):
    """Remat changes memory, never gradients."""
    cfg, model, opt_cfg, state, pipe = tiny
    batch = pipe.batch_at(3)
    outs = {}
    for pol in ("none", "full", "dots"):
        outs[pol] = jax.jit(make_train_step(model, opt_cfg, remat=pol)
                            )(state, batch)[0]
    for pol in ("full", "dots"):
        for a, b in zip(jax.tree.leaves(outs["none"].params),
                        jax.tree.leaves(outs[pol].params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-3, atol=1e-5)


def test_ef_accumulation_unbiased():
    """bf16 + error feedback tracks the fp32 sum far better than plain bf16."""
    rng = np.random.default_rng(0)
    gs = [rng.standard_normal(256).astype(np.float32) * 1e-2
          for _ in range(64)]
    acc = {"g": jnp.zeros(256, jnp.bfloat16)}
    res = {"g": jnp.zeros(256, jnp.float32)}
    plain = jnp.zeros(256, jnp.bfloat16)
    for g in gs:
        acc, res = ef_accumulate(acc, res, {"g": jnp.asarray(g)})
        plain = (plain.astype(jnp.float32) + g).astype(jnp.bfloat16)
    true = np.sum(gs, axis=0)
    ef_total = np.asarray(acc["g"], np.float32) + np.asarray(res["g"])
    ef_err = np.abs(ef_total - true).max()
    plain_err = np.abs(np.asarray(plain, np.float32) - true).max()
    assert ef_err < 1e-6
    assert ef_err < plain_err


def test_adamw_lr_schedule():
    cfg = adamw.AdamWConfig(lr_peak=1e-3, warmup_steps=10, total_steps=100,
                            lr_min_ratio=0.1)
    assert float(adamw.lr_at(cfg, 0)) < float(adamw.lr_at(cfg, 9))
    assert abs(float(adamw.lr_at(cfg, 10)) - 1e-3) < 1e-4
    assert float(adamw.lr_at(cfg, 99)) < 2.0e-4    # decayed near min


def test_straggler_monitor():
    m = StragglerMonitor(alpha=0.9, sigma=3.0)
    for i in range(50):
        m.observe(i, 0.1 + 0.001 * (i % 3))
    assert not m.flagged            # tight jitter never flags (rel floor)
    m.observe(50, 2.0)              # 20× outlier
    assert m.flagged and m.flagged[-1]["step"] == 50
    # warm-up: an early outlier is NOT flagged (variance not yet trusted)
    m2 = StragglerMonitor(alpha=0.9, sigma=3.0)
    m2.observe(0, 0.1)
    m2.observe(1, 2.0)
    assert not m2.flagged


def test_checkpoint_roundtrip_and_gc(tiny):
    cfg, model, opt_cfg, state, pipe = tiny
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            ckpt.save(d, s, state, keep=2)
        assert ckpt.all_steps(d) == [4, 5]          # keep-k GC
        back = ckpt.restore(d, 5, state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_checkpoint_atomicity(tiny):
    """Orphaned tmp dirs (crashed writers) are invisible to readers and
    garbage-collected by the next save."""
    cfg, model, opt_cfg, state, pipe = tiny
    with tempfile.TemporaryDirectory() as d:
        os.makedirs(os.path.join(d, "step_000000009.tmp-dead"))
        assert ckpt.all_steps(d) == []
        ckpt.save(d, 1, state)
        assert ckpt.all_steps(d) == [1]
        assert not any(".tmp-" in p for p in os.listdir(d))


def test_pipeline_determinism_and_sharding():
    pipe = TokenPipeline(PipelineConfig(vocab=97, global_batch=8,
                                        seq_len=12, seed=3))
    b1 = pipe.batch_at(7)
    b2 = pipe.batch_at(7)
    np.testing.assert_array_equal(np.asarray(b1["inputs"]),
                                  np.asarray(b2["inputs"]))
    # shards concatenate to the full batch regardless of shard count
    for num in (2, 4):
        parts = [pipe.shard_slice(7, s, num)["inputs"] for s in range(num)]
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(p) for p in parts]),
            np.asarray(b1["inputs"]))


@pytest.mark.slow
def test_trainer_resume_exactness(tiny):
    """Train 10 straight vs train 5 + crash + resume 5: identical params
    (checkpoint + counted data stream => sample-exact resume)."""
    cfg, model, opt_cfg, state0, pipe = tiny
    step = jax.jit(make_train_step(model, opt_cfg, remat="none"))
    with tempfile.TemporaryDirectory() as d:
        a = Trainer(TrainerConfig(total_steps=10, ckpt_dir=None),
                    step, pipe, state0)
        sa = a.run(start_step=0)
        b1 = Trainer(TrainerConfig(total_steps=5, ckpt_dir=d, ckpt_every=5),
                     step, pipe, state0)
        b1.run(start_step=0)
        b2 = Trainer(TrainerConfig(total_steps=10, ckpt_dir=d),
                     step, pipe, state0)
        sb = b2.run()                      # resumes at 5 from checkpoint
        for x, y in zip(jax.tree.leaves(sa.params),
                        jax.tree.leaves(sb.params)):
            np.testing.assert_array_equal(np.asarray(x, np.float32),
                                          np.asarray(y, np.float32))


@pytest.mark.slow
def test_elastic_restart_reshard():
    """Checkpoint written in a 1-device process restores into an 8-device
    process with sharded templates (elastic restart across fleet sizes)."""
    import subprocess, sys, textwrap, tempfile, os
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with tempfile.TemporaryDirectory() as d:
        save_code = f"""
            import jax, jax.numpy as jnp
            from repro.checkpoint import ckpt
            tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                     "step": jnp.asarray(7)}}
            ckpt.save({d!r}, 7, tree)
            print("saved")
        """
        load_code = f"""
            import numpy as np, jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.checkpoint import ckpt
            mesh = jax.make_mesh((8,), ("d",))
            template = {{"w": jax.device_put(
                            jnp.zeros((8, 8), jnp.float32),
                            NamedSharding(mesh, P("d"))),
                         "step": jnp.asarray(0)}}
            back = ckpt.restore({d!r}, 7, template)
            assert len(back["w"].sharding.device_set) == 8
            np.testing.assert_array_equal(
                np.asarray(back["w"]),
                np.arange(64, dtype=np.float32).reshape(8, 8))
            assert int(back["step"]) == 7
            print("restored sharded OK")
        """
        for code, devs in ((save_code, 1), (load_code, 8)):
            env = dict(os.environ)
            env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devs}"
            env["PYTHONPATH"] = os.path.join(root, "src")
            out = subprocess.run([sys.executable, "-c",
                                  textwrap.dedent(code)],
                                 capture_output=True, text=True, env=env,
                                 timeout=300)
            assert out.returncode == 0, out.stderr[-2000:]
