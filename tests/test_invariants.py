"""Standalone guards for invariants the rest of the stack relies on but
nothing previously tested in isolation: the O(1) CSRC transpose, the
transpose product, and coloring validity across every generator class."""
import numpy as np
import jax.numpy as jnp
import pytest

from _propshim import given, settings, st
from repro.core import csrc
from repro.core.coloring import color_rows, verify_coloring
from repro.kernels import ops


SQUARE_GENERATORS = [
    ("poisson2d", lambda: csrc.poisson2d(7)),
    ("fem_band_sym", lambda: csrc.fem_band(48, 4, seed=1,
                                           numeric_symmetric=True)),
    ("fem_band_asym", lambda: csrc.fem_band(48, 4, seed=2)),
    ("random_symmetric_pattern",
     lambda: csrc.random_symmetric_pattern(40, 3, seed=3)),
    ("dense_matrix", lambda: csrc.dense_matrix(24, seed=4)),
]


def _same_csrc(a: csrc.CSRC, b: csrc.CSRC):
    assert a.n == b.n and a.m == b.m
    for f in ("ad", "ia", "ja", "al", "au", "iar", "jar", "ar"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)


@pytest.mark.parametrize("name,make", SQUARE_GENERATORS,
                         ids=[n for n, _ in SQUARE_GENERATORS])
def test_transpose_involution(name, make):
    """transpose(transpose(M)) == M, field for field (paper §5: the CSRC
    transpose is an al/au swap, so applying it twice is the identity)."""
    M = make()
    _same_csrc(csrc.transpose(csrc.transpose(M)), M)
    # and the single transpose really is A^T
    np.testing.assert_allclose(csrc.to_dense(csrc.transpose(M)),
                               csrc.to_dense(M).T)


@pytest.mark.parametrize("name,make", SQUARE_GENERATORS,
                         ids=[n for n, _ in SQUARE_GENERATORS])
def test_spmv_transpose_matches_dense(name, make):
    M = make()
    A = csrc.to_dense(M).astype(np.float64)
    x = np.random.default_rng(5).standard_normal(M.n).astype(np.float32)
    y = np.asarray(ops.spmv_transpose(M, jnp.asarray(x)), dtype=np.float64)
    y_ref = A.T @ x.astype(np.float64)
    scale = max(1.0, np.abs(y_ref).max())
    np.testing.assert_allclose(y / scale, y_ref / scale,
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=4, deadline=None)
@given(st.integers(4, 48), st.integers(1, 6), st.integers(0, 10_000))
def test_property_transpose_product_duality(n, band, seed):
    """<A x, y> == <x, A^T y> for random band matrices."""
    M = csrc.fem_band(n, min(band, max(1, n - 1)), seed=seed)
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    ax = np.asarray(ops.spmv(M, jnp.asarray(x), path="segment"),
                    dtype=np.float64)
    aty = np.asarray(ops.spmv_transpose(M, jnp.asarray(y)),
                     dtype=np.float64)
    lhs, rhs = float(ax @ y), float(x @ aty)
    assert abs(lhs - rhs) <= 1e-3 * max(1.0, abs(lhs))


@pytest.mark.parametrize("name,make", SQUARE_GENERATORS,
                         ids=[n for n, _ in SQUARE_GENERATORS])
def test_coloring_valid_across_generators(name, make):
    """verify_coloring(M, color_rows(M)) for every matrix class — the §3.2
    conflict-free guarantee the colorful path depends on."""
    M = make()
    col = color_rows(M)
    assert verify_coloring(M, col)
    # every row colored exactly once
    rows = np.sort(np.concatenate(
        [col.rows(c) for c in range(col.num_colors)]))
    np.testing.assert_array_equal(rows, np.arange(M.n))


def test_transpose_rejects_rectangular():
    M = csrc.rectangular_fem(24, 8, 3, seed=0)
    with pytest.raises(AssertionError):
        csrc.transpose(M)
