"""Iterative solvers over the SpMV engine (the paper's application layer)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import csrc, solvers
from repro.kernels import ops


def test_cg_poisson_segment_path():
    M = csrc.poisson2d(20)
    A = csrc.to_dense(M)
    x_true = np.random.default_rng(0).standard_normal(M.n).astype(np.float32)
    b = jnp.asarray(A @ x_true)
    res = solvers.cg(ops.SpmvOperator(M, path="segment"), b,
                     tol=1e-6, maxiter=2000, diag=M.ad)
    assert bool(res.converged)
    assert np.abs(np.asarray(res.x) - x_true).max() < 1e-3


@pytest.mark.slow
def test_cg_through_pallas_kernel():
    """The full paper stack: CG iterations calling the Pallas CSRC kernel."""
    M = csrc.poisson2d(16)
    A = csrc.to_dense(M)
    x_true = np.random.default_rng(1).standard_normal(M.n).astype(np.float32)
    b = jnp.asarray(A @ x_true)
    op = ops.SpmvOperator(M, path="kernel", tm=8)
    res = solvers.cg(op, b, tol=1e-6, maxiter=2000, diag=M.ad)
    assert bool(res.converged)
    assert np.abs(np.asarray(res.x) - x_true).max() < 1e-3


def test_bicgstab_nonsymmetric():
    M = csrc.fem_band(256, 12, seed=7)
    A = csrc.to_dense(M)
    x_true = np.random.default_rng(2).standard_normal(256).astype(np.float32)
    b = jnp.asarray(A @ x_true)
    res = solvers.bicgstab(ops.SpmvOperator(M, path="segment"), b,
                           tol=1e-5, maxiter=2000)
    assert bool(res.converged)
    assert np.abs(np.asarray(res.x) - x_true).max() < 1e-2


def test_jacobi_preconditioner_helps():
    M = csrc.fem_band(400, 8, seed=3, numeric_symmetric=True)
    A = csrc.to_dense(M).astype(np.float64)
    A = (A + A.T) / 2 + np.eye(400) * 1.0     # ensure SPD
    Ms = csrc.from_dense(A.astype(np.float32))
    op = ops.SpmvOperator(Ms, path="segment")
    b = jnp.asarray(np.random.default_rng(4).standard_normal(400),
                    dtype=jnp.float32)
    plain = solvers.cg(op, b, tol=1e-6, maxiter=3000)
    prec = solvers.cg(op, b, tol=1e-6, maxiter=3000, diag=Ms.ad)
    assert bool(prec.converged)
    assert int(prec.iters) <= int(plain.iters)
