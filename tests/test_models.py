"""Per-arch smoke tests (reduced configs, CPU): forward/train-step shapes,
no NaNs, prefill+decode consistency — one parametrized case per assigned
architecture, as required."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import registry, get_config
from repro.models.transformer import build_model

ARCHS = sorted(registry())
# big reduced-configs dominate suite time: their smokes run with -m ""
# (tier-1 keeps only the cheapest arch, qwen1.5-0.5b, as the default
# smoke; every arch still gets the cheap param-count check below)
_SLOW_ARCHS = {"deepseek-v2-lite-16b", "gemma-2b", "granite-3-2b",
               "llava-next-34b", "musicgen-large", "qwen3-8b",
               "qwen3-moe-235b-a22b", "rwkv6-1.6b", "zamba2-7b"}
SMOKE_ARCHS = [pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS
               else a for a in ARCHS]


def _batch(cfg, b, s, rng):
    if cfg.input_mode == "tokens":
        inputs = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    else:
        inputs = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)),
                             jnp.float32)
    targets = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    return {"inputs": inputs, "targets": targets}


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, s = 2, 16
    batch = _batch(cfg, b, s, rng)
    logits = model.forward(params, batch["inputs"])
    assert logits.shape == (b, s, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    # one optimizer step must keep everything finite
    from repro.optim.adamw import AdamWConfig
    from repro.train.step import make_train_step, init_train_state
    opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=2, total_steps=10)
    state = init_train_state(model, opt_cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, opt_cfg, remat="full"))
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert all(bool(jnp.isfinite(p.astype(jnp.float32)).all())
               for p in jax.tree.leaves(state.params))


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_smoke_decode_consistency(arch):
    """prefill + one decode_step == forward on the extended sequence."""
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    b, s = 2, 12
    if cfg.input_mode == "tokens":
        seq = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + 1)), jnp.int32)
    else:
        seq = jnp.asarray(rng.standard_normal((b, s + 1, cfg.d_model)),
                          jnp.float32)
    ref = model.forward(params, seq)[:, s].astype(jnp.float32)
    state, _ = model.prefill(params, seq[:, :s], max_len=s + 8)
    state, logits = model.decode_step(params, state, seq[:, s:s + 1])
    got = logits[:, 0].astype(jnp.float32)
    err = float(jnp.max(jnp.abs(got - ref)))
    scale = float(jnp.max(jnp.abs(ref))) + 1e-6
    assert err / scale < 0.05, f"{arch}: rel err {err / scale}"


@pytest.mark.parametrize("arch", [
    pytest.param(a, marks=pytest.mark.slow)
    if a in ("deepseek-v2-lite-16b", "zamba2-7b", "rwkv6-1.6b",
             "qwen3-moe-235b-a22b", "gemma-2b") else a for a in ARCHS])
def test_param_count_formula(arch):
    """The analytic param_count driving §Roofline MODEL_FLOPS must track
    the real initialized count on the reduced config (within 20% — the
    formula ignores biases/norm vectors)."""
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    actual = sum(p.size for p in jax.tree.leaves(params))
    analytic = cfg.param_count()
    assert 0.6 < analytic / actual < 1.4, (analytic, actual)


def test_long_context_flags():
    from repro.configs.shapes import SHAPES, cell_supported
    long = SHAPES["long_500k"]
    supported = [a for a in ARCHS
                 if cell_supported(get_config(a), long)[0]]
    assert sorted(supported) == ["rwkv6-1.6b", "zamba2-7b"]


def test_hybrid_sliding_window_decode_bounded():
    """Zamba2 long-context: decode cache stays at the window size, and
    decode still matches full attention within the window."""
    cfg = get_config("zamba2-7b", reduced=True)
    model = build_model(cfg)
    state = jax.eval_shape(lambda: model.init_decode_state(1, 500_000))
    t = state["k"].shape[2]
    assert t == cfg.long_context_window        # bounded, not 500k


@pytest.mark.slow
def test_moe_aux_metrics():
    cfg = get_config("qwen3-moe-235b-a22b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    loss, metrics = model.loss(params, _batch(cfg, 2, 16, rng))
    assert "load_balance_loss" in metrics
    assert float(metrics["load_balance_loss"]) > 0.5   # ~1 when uniform
