"""Partitioning (paper §3.1) and coloring (§3.2) invariants."""
import numpy as np
import jax.numpy as jnp
import pytest
from _propshim import given, settings, st

from repro.core import csrc
from repro.core.partition import (partition_rows_by_nnz,
                                  partition_rows_by_count, load_imbalance,
                                  interval_boundaries, halo_widths)
from repro.core.coloring import (color_rows, verify_coloring, conflict_stats,
                                 balance_stats, color_graph,
                                 direct_adjacency, group_stats,
                                 race_color_graph, reuse_stats)
from repro.kernels import ref


def test_nnz_partition_covers_rows():
    M = csrc.fem_band(200, 11, seed=0)
    part = partition_rows_by_nnz(M, 4)
    assert part.starts[0] == 0 and part.starts[-1] == M.n
    assert (np.diff(part.starts) > 0).all()
    assert part.nnz_per_part.sum() == csrc.nnz_per_row(M).sum()


def test_nnz_beats_rowcount_on_skewed():
    """The paper's key partitioning claim: nnz-guided balances flops better
    than row-count on matrices with skewed row densities."""
    # skew: first rows dense, later rows sparse
    rows, cols, vals = [], [], []
    n = 120
    for i in range(n):
        rows.append(i); cols.append(i); vals.append(1.0)
        width = 20 if i < 20 else 2
        for j in range(max(0, i - width), i):
            rows += [i, j]; cols += [j, i]; vals += [0.5, 0.5]
    M = csrc.from_coo(np.array(rows), np.array(cols),
                      np.array(vals, np.float64), n=n, pad_pattern=False)
    by_nnz = load_imbalance(partition_rows_by_nnz(M, 4))
    by_cnt = load_imbalance(partition_rows_by_count(M, 4))
    assert by_nnz < by_cnt


def test_effective_ranges_cover_writes():
    """Effective range property: every y-write of part t (own rows and
    scatter targets) lies in [eff_lo[t], eff_hi[t])."""
    M = csrc.fem_band(150, 9, seed=1)
    part = partition_rows_by_nnz(M, 5)
    ia = np.asarray(M.ia); ja = np.asarray(M.ja)
    for t in range(part.p):
        r0, r1 = part.rows(t)
        targets = set(range(r0, r1))
        for p in range(int(ia[r0]), int(ia[r1])):
            targets.add(int(ja[p]))
        assert min(targets) >= part.eff_lo[t]
        assert max(targets) < part.eff_hi[t]


def test_interval_boundaries_and_halo():
    M = csrc.fem_band(100, 6, seed=2)
    part = partition_rows_by_nnz(M, 4)
    pts = interval_boundaries(part)
    assert pts[0] == 0 and pts[-1] == M.n
    assert (np.diff(pts) > 0).all()
    assert all(h <= 6 for h in halo_widths(part))   # halo bounded by band


@settings(max_examples=6, deadline=None)
@given(st.integers(8, 40), st.integers(1, 5), st.integers(0, 1000))
def test_property_coloring_conflict_free(n, band, seed):
    """Paper §3.2 invariant: rows in one color class share no write target
    (direct or indirect)."""
    M = csrc.fem_band(n, min(band, n - 1), seed=seed)
    col = color_rows(M)
    assert verify_coloring(M, col)
    assert col.num_colors >= 1
    # all rows colored exactly once
    assert sorted(np.concatenate(
        [col.rows(c) for c in range(col.num_colors)]).tolist()) == list(range(n))


def test_colorful_spmv_matches_dense():
    M = csrc.fem_band(60, 4, seed=3)
    col = color_rows(M)
    A = csrc.to_dense(M)
    x = np.random.default_rng(0).standard_normal(60).astype(np.float32)
    y = np.asarray(ref.colorful_spmv(M, jnp.asarray(x), col))
    np.testing.assert_allclose(y, A @ x, rtol=1e-4, atol=1e-4)


def test_narrow_band_needs_few_colors():
    """Paper: colorful suits narrow-band matrices (small conflict degree)."""
    narrow = color_rows(csrc.fem_band(80, 1, seed=0)).num_colors
    wide = color_rows(csrc.fem_band(80, 10, seed=0)).num_colors
    assert narrow < wide


def test_conflict_stats_counts():
    M = csrc.poisson2d(3)          # 9 nodes, 5-point stencil
    s = conflict_stats(M)
    assert s["direct"] == 12       # 2*3*2 grid edges
    assert s["indirect"] > 0


def test_paper_example_conflict_counts():
    """Regression pin for the §3.2 illustration: the 9×9 example has 12
    direct and 7 indirect conflicts (the hoisted-neighbor-set rewrite of
    conflict_stats must reproduce both exactly)."""
    s = conflict_stats(csrc.paper_example())
    assert s == {"direct": 12, "indirect": 7}


def test_balance_matches_full_scan_reference():
    """The incremental per-class member lists in _balance must reproduce
    the original full `color == d` scan move for move — same colors, so
    same balance_stats — on every suite matrix class."""
    from repro.core.coloring import (_balance, _forbidden_colors, _greedy,
                                     balance_stats, direct_adjacency)

    def balance_ref(adj, color, include_indirect, max_rounds=3):
        n = len(color)
        num_colors = int(color.max()) + 1 if n else 0
        if num_colors <= 1:
            return color
        target = -(-n // num_colors)
        for _ in range(max_rounds):
            sizes = np.bincount(color, minlength=num_colors)
            moved = False
            for v in range(n):
                c = int(color[v])
                if sizes[c] <= target:
                    continue
                forbidden = _forbidden_colors(v, adj, color,
                                              include_indirect)
                best, best_key = -1, None
                for d in range(num_colors):
                    if (d == c or d in forbidden
                            or sizes[d] + 1 > sizes[c] - 1):
                        continue
                    members = np.flatnonzero(color == d)
                    dist = (int(np.abs(members - v).min())
                            if members.size else 0)
                    key = (int(sizes[d]), dist)
                    if best_key is None or key < best_key:
                        best, best_key = d, key
                if best >= 0:
                    sizes[c] -= 1
                    sizes[best] += 1
                    color[v] = best
                    moved = True
            if not moved:
                break
        return color

    suite = [csrc.poisson2d(6), csrc.fem_band(80, 3, seed=0),
             csrc.skewed_band(64, 12, 2, seed=1),
             csrc.random_symmetric_pattern(48, 3, seed=3),
             csrc.paper_example()]
    for M in suite:
        adj = direct_adjacency(M)
        deg = np.asarray([len(a) for a in adj])
        order = np.argsort(-deg, kind="stable")
        c0 = _greedy(adj, np.arange(M.n), True)
        cd = _greedy(adj, order, True)
        base = cd if cd.max() <= c0.max() else c0
        got = _balance(adj, base.copy(), True)
        ref_c = balance_ref(adj, base.copy(), True)
        assert np.array_equal(got, ref_c), type(M)
        col = color_rows(M)
        assert verify_coloring(M, col)
        # stats derive from the colors, so they are unchanged too
        s = balance_stats(col)
        assert s["imbalance"] >= 1.0 and s["std"] >= 0.0


_SUITE = [lambda: csrc.poisson2d(6), lambda: csrc.fem_band(80, 3, seed=0),
          lambda: csrc.skewed_band(64, 12, 2, seed=1),
          lambda: csrc.random_symmetric_pattern(48, 3, seed=3),
          lambda: csrc.paper_example()]


def test_greedy_scratch_matches_set_reference():
    """The reusable boolean scratch in _greedy must reproduce the original
    per-vertex set scan move for move — identical color arrays, both
    natural and degree order, both conflict distances — on every suite
    matrix class."""
    from repro.core.coloring import _forbidden_colors, _greedy

    def greedy_ref(adj, order, include_indirect):
        n = len(adj)
        color = np.full(n, -1, dtype=np.int64)
        for v in order:
            forbidden = _forbidden_colors(int(v), adj, color,
                                          include_indirect)
            c = 0
            while c in forbidden:
                c += 1
            color[v] = c
        return color

    for make in _SUITE:
        M = make()
        adj = direct_adjacency(M)
        deg = np.asarray([len(a) for a in adj])
        for order in (np.arange(M.n), np.argsort(-deg, kind="stable")):
            for indirect in (False, True):
                got = _greedy(adj, order, indirect)
                want = greedy_ref(adj, order, indirect)
                assert np.array_equal(got, want), (type(M), indirect)


def _graph_coloring_valid(adj, col):
    """Chunk-aware validity on a raw conflict graph: no edge inside one
    color crosses two serial chunks (greedy: chunks are singletons)."""
    grp = col.group_of_row
    for c in range(col.num_colors):
        members = set(col.rows(c).tolist())
        for v in col.rows(c).tolist():
            gv = int(grp[v]) if grp is not None else v
            for u in adj[v]:
                u = int(u)
                if u in members:
                    gu = int(grp[u]) if grp is not None else u
                    if gu != gv:
                        return False
    return True


def test_paper_example_both_providers_valid():
    """§3.2 regression on the 9×9 illustration (12 direct / 7 indirect
    conflicts): both providers produce valid colorings at distance 1
    (direct conflicts only) and distance 2 (indirect included)."""
    M = csrc.paper_example()
    assert conflict_stats(M) == {"direct": 12, "indirect": 7}
    adj = direct_adjacency(M)
    for provider in ("greedy", "race"):
        d1 = color_graph(adj, include_indirect=False, provider=provider)
        assert _graph_coloring_valid(adj, d1), provider
        d2 = color_rows(M, include_indirect=True, provider=provider)
        assert verify_coloring(M, d2), provider
        assert sorted(np.concatenate(
            [d2.rows(c) for c in range(d2.num_colors)]).tolist()) == list(
                range(M.n))


def test_race_provider_valid_on_suite():
    """RACE colorings carry level/group metadata and satisfy the
    chunk-aware conflict invariant on every suite matrix class."""
    for make in _SUITE:
        M = make()
        col = color_rows(M, provider="race")
        assert col.provider == "race"
        assert col.level_of_row is not None and col.group_of_row is not None
        assert col.level_of_row.shape == (M.n,)
        assert verify_coloring(M, col)
        gs = group_stats(col)
        assert gs["chunks"] >= col.num_colors
        # every row colored exactly once
        assert sorted(np.concatenate(
            [col.rows(c) for c in range(col.num_colors)]).tolist()) == list(
                range(M.n))


def test_race_cuts_palette_and_stride_on_wide_band():
    """The provider's reason to exist: on a wide-band matrix RACE's level
    groups need a fraction of greedy's palette and keep consecutive rows
    of one class adjacent (small reuse strides), per the paper's §3.2
    locality criticism of scattered color classes."""
    M = csrc.fem_band(600, 24, seed=3)
    greedy = color_rows(M, provider="greedy")
    race = color_rows(M, provider="race")
    assert race.num_colors * 2 <= greedy.num_colors
    assert (reuse_stats(race)["mean_stride"]
            < reuse_stats(greedy)["mean_stride"])
    assert verify_coloring(M, race)


def test_race_groups_disjoint_targets():
    """The invariant the executors rely on: within a color, two rows of
    *different* serial chunks never share a write target (y[row] or
    y[ja[slot]]) — checked directly, not via verify_coloring."""
    M = csrc.skewed_band(96, 10, 2, seed=5)
    col = color_rows(M, provider="race")
    ia = np.asarray(M.ia)
    ja = np.asarray(M.ja)
    grp = col.group_of_row
    for c in range(col.num_colors):
        owner = {}
        for r in col.rows(c).tolist():
            targets = [r] + ja[ia[r]:ia[r + 1]].tolist()
            for t in targets:
                og = owner.get(int(t))
                assert og is None or og == int(grp[r]), (c, r, t)
                owner[int(t)] = int(grp[r])


@settings(max_examples=6, deadline=None)
@given(st.integers(8, 40), st.integers(1, 5), st.integers(0, 1000))
def test_property_race_coloring_conflict_free(n, band, seed):
    """Chunk-aware §3.2 invariant under the RACE provider on random band
    matrices (the greedy twin of this property runs above)."""
    M = csrc.fem_band(n, min(band, n - 1), seed=seed)
    col = color_rows(M, provider="race")
    assert verify_coloring(M, col)
    assert 1 <= col.num_colors <= n
    assert sorted(np.concatenate(
        [col.rows(c) for c in range(col.num_colors)]).tolist()) == list(
            range(n))


def test_race_balance_pass_keeps_validity():
    """The balance pass moves rows only under the classic (stronger)
    forbidden check, so the balanced RACE coloring stays chunk-valid and
    never widens the palette."""
    M = csrc.fem_band(200, 8, seed=7)
    adj = direct_adjacency(M)
    from repro.core.coloring import _conflict_closure
    cadj = _conflict_closure(adj)
    plain = race_color_graph(cadj, include_indirect=False, balance=False)
    balanced = race_color_graph(cadj, include_indirect=False, balance=True)
    assert balanced.num_colors <= plain.num_colors
    assert _graph_coloring_valid(cadj, balanced)
    assert (balance_stats(balanced)["imbalance"]
            <= balance_stats(plain)["imbalance"] + 1e-9)
