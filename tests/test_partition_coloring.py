"""Partitioning (paper §3.1) and coloring (§3.2) invariants."""
import numpy as np
import jax.numpy as jnp
import pytest
from _propshim import given, settings, st

from repro.core import csrc
from repro.core.partition import (partition_rows_by_nnz,
                                  partition_rows_by_count, load_imbalance,
                                  interval_boundaries, halo_widths)
from repro.core.coloring import color_rows, verify_coloring, conflict_stats
from repro.kernels import ref


def test_nnz_partition_covers_rows():
    M = csrc.fem_band(200, 11, seed=0)
    part = partition_rows_by_nnz(M, 4)
    assert part.starts[0] == 0 and part.starts[-1] == M.n
    assert (np.diff(part.starts) > 0).all()
    assert part.nnz_per_part.sum() == csrc.nnz_per_row(M).sum()


def test_nnz_beats_rowcount_on_skewed():
    """The paper's key partitioning claim: nnz-guided balances flops better
    than row-count on matrices with skewed row densities."""
    # skew: first rows dense, later rows sparse
    rows, cols, vals = [], [], []
    n = 120
    for i in range(n):
        rows.append(i); cols.append(i); vals.append(1.0)
        width = 20 if i < 20 else 2
        for j in range(max(0, i - width), i):
            rows += [i, j]; cols += [j, i]; vals += [0.5, 0.5]
    M = csrc.from_coo(np.array(rows), np.array(cols),
                      np.array(vals, np.float64), n=n, pad_pattern=False)
    by_nnz = load_imbalance(partition_rows_by_nnz(M, 4))
    by_cnt = load_imbalance(partition_rows_by_count(M, 4))
    assert by_nnz < by_cnt


def test_effective_ranges_cover_writes():
    """Effective range property: every y-write of part t (own rows and
    scatter targets) lies in [eff_lo[t], eff_hi[t])."""
    M = csrc.fem_band(150, 9, seed=1)
    part = partition_rows_by_nnz(M, 5)
    ia = np.asarray(M.ia); ja = np.asarray(M.ja)
    for t in range(part.p):
        r0, r1 = part.rows(t)
        targets = set(range(r0, r1))
        for p in range(int(ia[r0]), int(ia[r1])):
            targets.add(int(ja[p]))
        assert min(targets) >= part.eff_lo[t]
        assert max(targets) < part.eff_hi[t]


def test_interval_boundaries_and_halo():
    M = csrc.fem_band(100, 6, seed=2)
    part = partition_rows_by_nnz(M, 4)
    pts = interval_boundaries(part)
    assert pts[0] == 0 and pts[-1] == M.n
    assert (np.diff(pts) > 0).all()
    assert all(h <= 6 for h in halo_widths(part))   # halo bounded by band


@settings(max_examples=6, deadline=None)
@given(st.integers(8, 40), st.integers(1, 5), st.integers(0, 1000))
def test_property_coloring_conflict_free(n, band, seed):
    """Paper §3.2 invariant: rows in one color class share no write target
    (direct or indirect)."""
    M = csrc.fem_band(n, min(band, n - 1), seed=seed)
    col = color_rows(M)
    assert verify_coloring(M, col)
    assert col.num_colors >= 1
    # all rows colored exactly once
    assert sorted(np.concatenate(
        [col.rows(c) for c in range(col.num_colors)]).tolist()) == list(range(n))


def test_colorful_spmv_matches_dense():
    M = csrc.fem_band(60, 4, seed=3)
    col = color_rows(M)
    A = csrc.to_dense(M)
    x = np.random.default_rng(0).standard_normal(60).astype(np.float32)
    y = np.asarray(ref.colorful_spmv(M, jnp.asarray(x), col))
    np.testing.assert_allclose(y, A @ x, rtol=1e-4, atol=1e-4)


def test_narrow_band_needs_few_colors():
    """Paper: colorful suits narrow-band matrices (small conflict degree)."""
    narrow = color_rows(csrc.fem_band(80, 1, seed=0)).num_colors
    wide = color_rows(csrc.fem_band(80, 10, seed=0)).num_colors
    assert narrow < wide


def test_conflict_stats_counts():
    M = csrc.poisson2d(3)          # 9 nodes, 5-point stencil
    s = conflict_stats(M)
    assert s["direct"] == 12       # 2*3*2 grid edges
    assert s["indirect"] > 0


def test_paper_example_conflict_counts():
    """Regression pin for the §3.2 illustration: the 9×9 example has 12
    direct and 7 indirect conflicts (the hoisted-neighbor-set rewrite of
    conflict_stats must reproduce both exactly)."""
    s = conflict_stats(csrc.paper_example())
    assert s == {"direct": 12, "indirect": 7}


def test_balance_matches_full_scan_reference():
    """The incremental per-class member lists in _balance must reproduce
    the original full `color == d` scan move for move — same colors, so
    same balance_stats — on every suite matrix class."""
    from repro.core.coloring import (_balance, _forbidden_colors, _greedy,
                                     balance_stats, direct_adjacency)

    def balance_ref(adj, color, include_indirect, max_rounds=3):
        n = len(color)
        num_colors = int(color.max()) + 1 if n else 0
        if num_colors <= 1:
            return color
        target = -(-n // num_colors)
        for _ in range(max_rounds):
            sizes = np.bincount(color, minlength=num_colors)
            moved = False
            for v in range(n):
                c = int(color[v])
                if sizes[c] <= target:
                    continue
                forbidden = _forbidden_colors(v, adj, color,
                                              include_indirect)
                best, best_key = -1, None
                for d in range(num_colors):
                    if (d == c or d in forbidden
                            or sizes[d] + 1 > sizes[c] - 1):
                        continue
                    members = np.flatnonzero(color == d)
                    dist = (int(np.abs(members - v).min())
                            if members.size else 0)
                    key = (int(sizes[d]), dist)
                    if best_key is None or key < best_key:
                        best, best_key = d, key
                if best >= 0:
                    sizes[c] -= 1
                    sizes[best] += 1
                    color[v] = best
                    moved = True
            if not moved:
                break
        return color

    suite = [csrc.poisson2d(6), csrc.fem_band(80, 3, seed=0),
             csrc.skewed_band(64, 12, 2, seed=1),
             csrc.random_symmetric_pattern(48, 3, seed=3),
             csrc.paper_example()]
    for M in suite:
        adj = direct_adjacency(M)
        deg = np.asarray([len(a) for a in adj])
        order = np.argsort(-deg, kind="stable")
        c0 = _greedy(adj, np.arange(M.n), True)
        cd = _greedy(adj, order, True)
        base = cd if cd.max() <= c0.max() else c0
        got = _balance(adj, base.copy(), True)
        ref_c = balance_ref(adj, base.copy(), True)
        assert np.array_equal(got, ref_c), type(M)
        col = color_rows(M)
        assert verify_coloring(M, col)
        # stats derive from the colors, so they are unchanged too
        s = balance_stats(col)
        assert s["imbalance"] >= 1.0 and s["std"] >= 0.0
