"""Property-test shim: `given` / `settings` / `st` with or without hypothesis.

The tier-1 suite must collect and pass from a clean checkout where
`hypothesis` is not installed.  When the real library is available
(``pip install -r requirements-dev.txt``) it is used directly, with a
"tier1" profile capping example counts so the default run stays fast.
Otherwise this module provides a minimal drop-in: strategies draw from a
seeded ``random.Random`` (deterministic per test function) and ``given``
simply loops the test body over ``max_examples`` draws.

Usage in test modules (replaces ``from hypothesis import ...``):

    from _propshim import given, settings, st

Env knobs:
    PROPSHIM_MAX_EXAMPLES   hard cap on examples per property (default 10)
"""
from __future__ import annotations

import functools
import os
import random
import zlib

MAX_EXAMPLES_CAP = int(os.environ.get("PROPSHIM_MAX_EXAMPLES", "10"))

try:
    import hypothesis as _hyp
    from hypothesis import given, strategies as st

    HAVE_HYPOTHESIS = True

    _hyp.settings.register_profile(
        "tier1", max_examples=MAX_EXAMPLES_CAP, deadline=None)
    _hyp.settings.load_profile(
        os.environ.get("HYPOTHESIS_PROFILE", "tier1"))

    def settings(max_examples: int = MAX_EXAMPLES_CAP, **kw):
        """Pass through to hypothesis.settings, capping max_examples so the
        tier-1 suite stays fast even where tests ask for more."""
        return _hyp.settings(
            max_examples=min(max_examples, MAX_EXAMPLES_CAP), **kw)

except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A draw function wrapper mimicking a hypothesis SearchStrategy."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

        def map(self, f):
            return _Strategy(lambda rng: f(self._draw(rng)))

        def filter(self, pred, _tries: int = 100):
            def draw(rng):
                for _ in range(_tries):
                    v = self._draw(rng)
                    if pred(v):
                        return v
                raise ValueError("propshim: filter predicate never satisfied")
            return _Strategy(draw)

    class st:
        """Namespace mirroring the subset of hypothesis.strategies we use."""

        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

        @staticmethod
        def floats(min_value: float = 0.0, max_value: float = 1.0,
                   **_kw) -> _Strategy:
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(seq) -> _Strategy:
            seq = list(seq)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

        @staticmethod
        def lists(elements: _Strategy, min_size: int = 0,
                  max_size: int = 8) -> _Strategy:
            return _Strategy(lambda rng: [
                elements.draw(rng)
                for _ in range(rng.randint(min_size, max_size))])

    def settings(max_examples: int = MAX_EXAMPLES_CAP, **_kw):
        """Record the example budget on the (already given-wrapped) test."""
        def deco(fn):
            fn._shim_max_examples = min(max_examples, MAX_EXAMPLES_CAP)
            return fn
        return deco

    def given(*strategies: _Strategy):
        """Loop the test over deterministic seeded draws.

        The seed is derived from the test's qualified name (crc32, not
        ``hash`` — the latter is salted per process), so failures reproduce.
        """
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_shim_max_examples", MAX_EXAMPLES_CAP)
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    drawn = [s.draw(rng) for s in strategies]
                    fn(*args, *drawn, **kwargs)
            # wraps sets __wrapped__, which makes pytest introspect the
            # original signature and demand fixtures named like the drawn
            # params — hide it so the wrapper's (*args) signature is used
            del wrapper.__wrapped__
            return wrapper
        return deco
