"""Distributed SpMV strategies on fake multi-device meshes.

Device count is locked at first jax init, so these run in subprocesses with
their own XLA_FLAGS (the pattern all multi-device tests here use)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.slow
def test_all_strategies_match_dense():
    print(run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import csrc, distributed as D
        mesh = jax.make_mesh((8,), ('rows',))
        M = csrc.fem_band(512, 20, seed=1)
        A = csrc.to_dense(M)
        x = np.random.default_rng(0).standard_normal(512).astype(np.float32)
        for strat in D.STRATEGIES:
            fn = D.build_sharded_spmv(M, mesh, 'rows', strat)
            y = np.asarray(fn(jnp.asarray(x)))[:512]
            err = np.abs(y - A @ x).max() / max(1., np.abs(A @ x).max())
            assert err < 1e-5, (strat, err)
        print('OK')
    """))


@pytest.mark.slow
def test_halo_rejects_wide_band():
    print(run_with_devices("""
        import jax
        from repro.core import csrc, distributed as D
        mesh = jax.make_mesh((8,), ('rows',))
        M = csrc.fem_band(64, 32, seed=0)   # band 32 > 64/8 rows per shard
        try:
            D.build_spmv_halo(M, mesh, 'rows')
            raise SystemExit('expected ValueError')
        except ValueError:
            print('OK')
    """))


@pytest.mark.slow
def test_all_strategies_flat_kernel_match_dense():
    """Shard-local flat-grid kernel execution (plan.path='flat') inside
    every accumulation strategy, single- and multi-RHS."""
    print(run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import csrc, distributed as D
        from repro.core.plan import ExecutionPlan
        mesh = jax.make_mesh((8,), ('rows',))
        M = csrc.skewed_band(512, 24, 3, seed=2)
        A = csrc.to_dense(M)
        rng = np.random.default_rng(0)
        x = rng.standard_normal(M.n).astype(np.float32)
        X = rng.standard_normal((M.n, 4)).astype(np.float32)
        plan = ExecutionPlan(path='flat', tm=32)
        for strat in D.STRATEGIES:
            fn = D.build_sharded_spmv(M, mesh, 'rows', strat, plan=plan)
            y = np.asarray(fn(jnp.asarray(x)))[:M.n]
            ref = A @ x
            err = np.abs(y - ref).max() / max(1., np.abs(ref).max())
            assert err < 1e-5, (strat, err)
            Y = np.asarray(fn(jnp.asarray(X)))[:M.n]
            refm = A @ X
            errm = np.abs(Y - refm).max() / max(1., np.abs(refm).max())
            assert errm < 1e-5, (strat, errm)
        print('OK')
    """))


@pytest.mark.slow
def test_all_strategies_nnzsplit_match_dense():
    """Shard-local nnz-split execution (plan.path='nnzsplit') inside
    every accumulation strategy on 8 shards: the power-law class for the
    global strategies, a banded matrix for halo (whose gate needs
    bandwidth <= rows-per-shard), single- and multi-RHS."""
    print(run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import csrc, distributed as D
        from repro.core.plan import ExecutionPlan
        mesh = jax.make_mesh((8,), ('rows',))
        rng = np.random.default_rng(0)
        plan = ExecutionPlan(path='nnzsplit', k_step_sublanes=2)
        cases = [(csrc.powerlaw_laplacian(512, seed=1),
                  ('allreduce', 'reduce_scatter')),
                 (csrc.fem_band(512, 16, seed=2), ('halo',))]
        for M, strats in cases:
            A = np.asarray(csrc.to_dense(M), np.float64)
            x = (rng.integers(-64, 64, M.n) / 8.0).astype(np.float32)
            X = (rng.integers(-64, 64, (M.n, 4)) / 8.0).astype(np.float32)
            for strat in strats:
                fn = D.build_sharded_spmv(M, mesh, 'rows', strat,
                                          plan=plan)
                y = np.asarray(fn(jnp.asarray(x)))[:M.n]
                ref = A @ x
                err = np.abs(y - ref).max() / max(1., np.abs(ref).max())
                assert err < 1e-5, (strat, err)
                Y = np.asarray(fn(jnp.asarray(X)))[:M.n]
                refm = A @ X
                errm = (np.abs(Y - refm).max()
                        / max(1., np.abs(refm).max()))
                assert errm < 1e-5, (strat, errm)
        print('OK')
    """))


@pytest.mark.slow
def test_auto_strategy_selection():
    print(run_with_devices("""
        import jax
        from repro.core import csrc, distributed as D
        mesh = jax.make_mesh((4,), ('rows',))
        # banded -> halo; unbanded -> reduce_scatter
        banded = csrc.fem_band(256, 8, seed=0)
        unbanded = csrc.random_symmetric_pattern(256, 4, seed=0)
        import numpy as np
        for M, expect in ((banded, 'halo'), (unbanded, 'reduce_scatter')):
            fn = D.build_sharded_spmv(M, mesh, 'rows', 'auto')
            # behaviourally verify instead of introspecting
            x = np.random.default_rng(1).standard_normal(M.n).astype('float32')
            y = np.asarray(fn(x))[:M.n]
            ref = csrc.to_dense(M) @ x
            assert np.abs(y - ref).max() / max(1., np.abs(ref).max()) < 1e-5
        print('OK')
    """))


@pytest.mark.slow
def test_distributed_cg_solver():
    """The paper's end application: CG with a shard_map SpMV."""
    print(run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import csrc, distributed as D, solvers
        mesh = jax.make_mesh((4,), ('rows',))
        M = csrc.poisson2d(16)      # 256, SPD
        fn = D.build_sharded_spmv(M, mesh, 'rows', 'allreduce')
        A = csrc.to_dense(M)
        x_true = np.random.default_rng(0).standard_normal(M.n).astype('float32')
        b = jnp.asarray(A @ x_true)
        res = solvers.cg(fn, b, tol=1e-6, maxiter=1500, diag=M.ad)
        assert bool(res.converged), float(res.residual)
        assert np.abs(np.asarray(res.x) - x_true).max() < 1e-3
        print('OK iters', int(res.iters))
    """))


@pytest.mark.slow
def test_compressed_psum():
    print(run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp, functools
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.optim.compress import compressed_psum
        mesh = jax.make_mesh((8,), ('d',))
        g = np.random.default_rng(0).standard_normal((8, 64)).astype('float32')
        for mode, tol in (('float32', 1e-6), ('bfloat16', 2e-2), ('int8', 5e-2)):
            fn = shard_map(functools.partial(compressed_psum, axis_name='d', mode=mode),
                           mesh=mesh, in_specs=P('d'), out_specs=P('d'))
            out = np.asarray(jax.jit(fn)(g))
            expect = g.sum(0, keepdims=True).repeat(8, 0)
            err = np.abs(out - expect).max() / np.abs(expect).max()
            assert err < tol, (mode, err)
        print('OK')
    """))


def test_collective_bytes_model():
    """Halo moves O(band) bytes; allreduce moves O(n) — the paper's
    effective-vs-all-in-one gap."""
    from repro.core import csrc
    from repro.core.distributed import collective_bytes_estimate
    M = csrc.fem_band(4096, 16, seed=0)
    halo = collective_bytes_estimate(M, 8, "halo")
    ar = collective_bytes_estimate(M, 8, "allreduce")
    rs = collective_bytes_estimate(M, 8, "reduce_scatter")
    assert halo < rs < ar
    assert halo <= 2 * 4 * 16
