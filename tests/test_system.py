"""End-to-end behaviour tests for the paper's system: the full CSRC stack
(build → pack → kernel → accumulate → solver) and the dry-run cell driver
on a small mesh."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import csrc, solvers
from repro.kernels import ops

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_end_to_end_fem_solve():
    """The paper's target workload: assemble a FEM-like system, solve with
    PCG where every matrix-vector product runs the CSRC Pallas kernel."""
    M = csrc.poisson2d(24)                      # 576-dof Laplacian
    op = ops.SpmvOperator(M, path="kernel", tm=16)
    rng = np.random.default_rng(0)
    x_true = rng.standard_normal(M.n).astype(np.float32)
    b = op(jnp.asarray(x_true))                 # rhs via the same operator
    res = solvers.cg(op, b, tol=1e-6, maxiter=3000, diag=M.ad)
    assert bool(res.converged)
    assert np.abs(np.asarray(res.x) - x_true).max() < 1e-3
    # working-set bookkeeping matches the paper's accounting
    assert op.flops_per_call == 2 * M.nnz - M.n
    assert op.bytes_per_call > 0


def test_paper_bandwidth_claim():
    """Paper §4.1: CSRC loads ≈ (5/2)nnz - n/2 vs CSR 3nnz → ratio < 1.
    Check our streamed-bytes accounting reproduces the direction."""
    M = csrc.fem_band(2048, 64, seed=0)
    csr_loads = 3 * M.nnz
    csrc_loads = 5 * M.nnz // 2 - M.n // 2
    assert csrc_loads < csr_loads
    # numerically symmetric halves the value stream further
    Ms = csrc.fem_band(2048, 64, seed=0, numeric_symmetric=True)
    from repro.core import blockell
    p_ns = blockell.pack(M, tm=64)
    p_s = blockell.pack(Ms, tm=64)
    assert p_s.streamed_bytes() < p_ns.streamed_bytes()


@pytest.mark.slow
def test_dryrun_cell_on_test_mesh():
    """The launch driver lowers+compiles a real cell on a small placeholder
    mesh (subprocess: 8 fake devices) — the same path the 512-chip run
    uses."""
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from repro.launch.dryrun import lower_cell
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rec = lower_cell("qwen1.5-0.5b", "train_4k", mesh, "4x2",
                         verbose=False)
        assert rec["status"] == "ok", rec
        r = rec["roofline"]
        assert r["hlo_flops"] > 0 and r["collective_bytes"] > 0
        print("OK", r["bottleneck"])
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]


def test_all_cells_have_records_or_skips():
    """After the full dry-run sweep, every (arch × shape × mesh) cell must
    have a record: ok or a documented skip.  Runs only when results exist
    (the sweep is executed by `python -m repro.launch.dryrun`)."""
    outdir = os.path.join(ROOT, "results", "dryrun")
    if not os.path.isdir(outdir) or len(os.listdir(outdir)) < 80:
        pytest.skip("full dry-run sweep not yet executed")
    import json
    from repro.configs.base import registry
    from repro.configs.shapes import SHAPES
    bad = []
    for arch in registry():
        for shape in SHAPES:
            for mesh in ("16x16", "2x16x16"):
                p = os.path.join(outdir, f"{arch}__{shape}__{mesh}.json")
                if not os.path.exists(p):
                    bad.append((arch, shape, mesh, "missing"))
                    continue
                rec = json.load(open(p))
                if rec["status"] not in ("ok", "skipped"):
                    bad.append((arch, shape, mesh, rec["status"]))
    assert not bad, bad
