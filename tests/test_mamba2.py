"""Mamba2 SSD: chunked block-parallel form vs the recurrent scan."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import mamba2 as M


def _recurrent(xs, Bm, Cm, dt, a, h0):
    def step(h, inp):
        x_t, b_t, c_t, dt_t = inp
        decay = jnp.exp(a * dt_t)
        dbx = (dt_t[..., None] * x_t)[..., None] * b_t[:, None, None, :]
        h = decay[..., None, None] * h + dbx
        y = jnp.einsum("bhpn,bn->bhp", h, c_t)
        return h, y
    h_new, ys = jax.lax.scan(
        step, h0, (jnp.moveaxis(xs, 1, 0), jnp.moveaxis(Bm, 1, 0),
                   jnp.moveaxis(Cm, 1, 0), jnp.moveaxis(dt, 1, 0)))
    return jnp.moveaxis(ys, 0, 1), h_new


@pytest.mark.parametrize("t", [16, 64,
    pytest.param(128, marks=pytest.mark.slow)])
def test_chunked_ssd_matches_recurrent(t):
    rng = np.random.default_rng(t)
    bt, h, p, n = 2, 3, 8, 4
    xs = jnp.asarray(rng.standard_normal((bt, t, h, p)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((bt, t, n)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((bt, t, n)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((bt, t, h))) * 0.1,
                     jnp.float32)
    a = -jnp.exp(jnp.linspace(0.0, 1.0, h))
    h0 = jnp.asarray(rng.standard_normal((bt, h, p, n)) * 0.1, jnp.float32)
    y_ref, h_ref = _recurrent(xs, Bm, Cm, dt, a, h0)
    y_chk, h_chk = M._ssd_chunked(xs, Bm, Cm, dt, a, h0)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_chk), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_chunked_flag_end_to_end():
    """Full hybrid model forward agrees between recurrent and chunked."""
    from repro.configs.base import get_config
    from repro.models.transformer import build_model
    cfg = get_config("zamba2-7b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    inputs = jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32)
    y1 = model.forward(params, inputs)
    old = M.CHUNKED_SSD
    try:
        M.CHUNKED_SSD = True
        y2 = model.forward(params, inputs)
    finally:
        M.CHUNKED_SSD = old
    err = float(jnp.abs(y1.astype(jnp.float32)
                        - y2.astype(jnp.float32)).max())
    scale = float(jnp.abs(y1.astype(jnp.float32)).max()) + 1e-6
    assert err / scale < 2e-2, err / scale


class TestChunkedWKV:
    """RWKV6 chunked WKV (cell F) vs recurrent scan."""

    @staticmethod
    def _recurrent(r, k, v, w, u, S):
        def step(S, inp):
            r_t, k_t, v_t, w_t = inp
            kv = k_t[..., :, None] * v_t[..., None, :]
            y = jnp.einsum("bhi,bhij->bhj", r_t, S + u[..., None] * kv)
            S = w_t[..., None] * S + kv
            return S, y
        S, ys = jax.lax.scan(
            step, S, tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w)))
        return jnp.moveaxis(ys, 0, 1), S

    @pytest.mark.parametrize("t", [16, 48,
        pytest.param(96, marks=pytest.mark.slow)])
    def test_matches_recurrent(self, t):
        from repro.models import rwkv6 as R
        rng = np.random.default_rng(t)
        b, h, n = 2, 3, 8
        r = jnp.asarray(rng.standard_normal((b, t, h, n)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, t, h, n)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, t, h, n)), jnp.float32)
        w = jnp.asarray(rng.uniform(0.05, 0.99, (b, t, h, n)), jnp.float32)
        u = jnp.asarray(rng.standard_normal((h, n)), jnp.float32)
        S0 = jnp.asarray(rng.standard_normal((b, h, n, n)) * 0.1,
                         jnp.float32)
        y_ref, s_ref = self._recurrent(r, k, v, w, u, S0)
        y_chk, s_chk = R._wkv_chunked(r, k, v, w, u, S0)
        np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref),
                                   rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s_chk), np.asarray(s_ref),
                                   rtol=1e-3, atol=1e-4)

    @pytest.mark.slow
    def test_end_to_end_flag(self):
        from repro.configs.base import get_config
        from repro.models.transformer import build_model
        from repro.models import rwkv6 as R
        cfg = get_config("rwkv6-1.6b", reduced=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        inputs = jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32)
        y1 = model.forward(params, inputs)
        old = R.CHUNKED_WKV
        try:
            R.CHUNKED_WKV = True
            y2 = model.forward(params, inputs)
        finally:
            R.CHUNKED_WKV = old
        err = float(jnp.abs(y1.astype(jnp.float32)
                            - y2.astype(jnp.float32)).max())
        scale = float(jnp.abs(y1.astype(jnp.float32)).max()) + 1e-6
        assert err / scale < 2e-2, err / scale
