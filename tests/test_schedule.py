"""The unified schedule layer (core/schedule.py): cache round-trips with
zero re-pack/re-color, bit-identical execution from deserialized artifacts,
balanced largest-degree-first coloring invariants, and multi-RHS SpMM vs
the dense oracle across all three paths."""
import dataclasses
import os

import numpy as np
import jax.numpy as jnp
import pytest

from _propshim import given, settings, st
from repro.core import csrc, schedule as S, tuner
from repro.core.coloring import balance_stats, color_rows, verify_coloring
from repro.core.plan import ExecutionPlan
from repro.kernels import ops


def _build_delta(fn):
    """Run fn and return (result, builds-that-happened) from the probe."""
    before = dict(S.BUILD_COUNTS)
    out = fn()
    after = dict(S.BUILD_COUNTS)
    delta = {k: after.get(k, 0) - before.get(k, 0)
             for k in set(after) | set(before)}
    return out, {k: v for k, v in delta.items() if v}


# ---------------------------------------------------------------------------
# Schedule build + cache behavior
# ---------------------------------------------------------------------------

def test_schedule_bundles_everything_per_path():
    M = csrc.fem_band(72, 5, seed=1)
    kernel = S.build_schedule(M, ExecutionPlan(path="kernel", tm=8))
    assert kernel.pack is not None and kernel.coloring is None
    colorful = S.build_schedule(M, ExecutionPlan(path="colorful"))
    assert colorful.pack is None and colorful.coloring is not None
    assert colorful.color_slots.shape[0] == M.k
    segment = S.build_schedule(M, ExecutionPlan(path="segment"))
    assert segment.pack is None and segment.coloring is None
    for sched in (kernel, colorful, segment):
        assert sched.partition.starts[-1] == M.n
        assert sched.halo.shape == (sched.partition.p,)


def test_schedule_strictness_matches_plan_gates():
    Mr = csrc.rectangular_fem(32, 8, 3, seed=0)
    with pytest.raises(ValueError):
        S.build_schedule(Mr, ExecutionPlan(path="kernel"))
    with pytest.raises(ValueError):
        S.build_schedule(Mr, ExecutionPlan(path="colorful"))
    Mu = csrc.random_symmetric_pattern(300, 4, seed=0)   # bandwidth ~ n
    with pytest.raises(ValueError):
        S.build_schedule(Mu, ExecutionPlan(path="kernel", w_cap=256))


def test_cache_hit_skips_all_precompute():
    """The acceptance probe: a second operator construction for the same
    (matrix, plan) through the cache performs zero pack/partition/coloring
    work, and produces bit-identical results."""
    M = csrc.fem_band(48, 4, seed=3)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(M.m)
                    .astype(np.float32))
    cache = tuner.PlanCache()
    plan = ExecutionPlan(path="kernel", tm=8)
    op1, d1 = _build_delta(
        lambda: ops.SpmvOperator.from_plan(M, plan, cache=cache))
    assert d1.get("pack") == 1 and d1.get("schedule") == 1
    op2, d2 = _build_delta(
        lambda: ops.SpmvOperator.from_plan(M, plan, cache=cache))
    assert d2 == {}, f"cache hit rebuilt: {d2}"
    assert cache.schedule_hits == 1
    np.testing.assert_array_equal(np.asarray(op1(x)), np.asarray(op2(x)))


def test_same_class_different_values_does_not_share_schedule():
    """fingerprint() keys a matrix *class*; the schedule embeds values, so
    a same-class matrix with different values must never silently reuse
    another matrix's value streams.  With an identical *structure* the
    schedule layer satisfies that via the value-refresh fast path (new
    streams, zero structural rebuild) instead of a full re-pack."""
    M1 = csrc.fem_band(64, 3, seed=7)
    M2 = csrc.from_dense(2.0 * csrc.to_dense(M1))       # same structure
    assert tuner.fingerprint(M1) == tuner.fingerprint(M2)
    assert S.value_digest(M1) != S.value_digest(M2)
    cache = tuner.PlanCache()
    plan = ExecutionPlan(path="kernel", tm=8)
    op1 = ops.SpmvOperator.from_plan(M1, plan, cache=cache)
    op2, d = _build_delta(
        lambda: ops.SpmvOperator.from_plan(M2, plan, cache=cache))
    # M2's own value streams were installed (no silent reuse of M1's) ...
    assert d == {"value_refresh": 1}
    # ... and the results really are M2's, i.e. 2x M1's
    x = jnp.asarray(np.random.default_rng(1).standard_normal(M1.m)
                    .astype(np.float32))
    np.testing.assert_allclose(np.asarray(op2(x)),
                               2.0 * np.asarray(op1(x)),
                               rtol=1e-6, atol=1e-6)


def test_schedule_npz_roundtrip_through_disk_cache(tmp_path):
    """Round-trip the artifact through a disk-backed PlanCache: a fresh
    process (new cache object) loads the npz and re-packs nothing; SpMV and
    SpMM results are bit-identical to the originally-built operator."""
    path = os.path.join(tmp_path, "plans.json")
    M = csrc.fem_band(48, 3, seed=1)
    x = jnp.asarray(np.random.default_rng(2).standard_normal(M.m)
                    .astype(np.float32))
    X = jnp.asarray(np.random.default_rng(3).standard_normal((M.m, 2))
                    .astype(np.float32))
    for plan in (ExecutionPlan(path="kernel", tm=8),
                 ExecutionPlan(path="colorful"),
                 ExecutionPlan(path="segment")):
        cache = tuner.PlanCache(path=path)
        op1 = ops.SpmvOperator.from_plan(M, plan, cache=cache)
        cache2 = tuner.PlanCache(path=path)          # "new process"
        op2, d = _build_delta(
            lambda: ops.SpmvOperator.from_plan(M, plan, cache=cache2))
        assert d == {}, f"{plan.path}: disk hit rebuilt {d}"
        np.testing.assert_array_equal(np.asarray(op1(X)),
                                      np.asarray(op2(X)))
        if plan.path == "kernel":       # 1-D path bit-identical too
            np.testing.assert_array_equal(np.asarray(op1(x)),
                                          np.asarray(op2(x)))


def test_schedule_version_mismatch_invalidates(tmp_path, monkeypatch):
    """Bumping SCHEDULE_VERSION (a format change) silently invalidates
    stored schedules: the next request rebuilds instead of crashing."""
    path = os.path.join(tmp_path, "plans.json")
    M = csrc.fem_band(48, 3, seed=9)
    plan = ExecutionPlan(path="kernel", tm=8)
    cache = tuner.PlanCache(path=path)
    ops.SpmvOperator.from_plan(M, plan, cache=cache)
    monkeypatch.setattr(S, "SCHEDULE_VERSION", S.SCHEDULE_VERSION + 1)
    cache2 = tuner.PlanCache(path=path)
    _, d = _build_delta(
        lambda: ops.SpmvOperator.from_plan(M, plan, cache=cache2))
    assert d.get("pack") == 1        # rebuilt under the new version


def test_tune_stores_winning_schedule():
    M = csrc.poisson2d(8)
    cache = tuner.PlanCache()
    res = tuner.tune(M, cache=cache,
                     measure=lambda op, x: 1.0 if op.plan.path == "kernel"
                     else 2.0)
    assert len(cache.schedules) == 1
    _, d = _build_delta(
        lambda: ops.SpmvOperator.from_plan(M, res.plan, cache=cache))
    assert d == {} and cache.schedule_hits == 1


# ---------------------------------------------------------------------------
# Coloring quality: largest-degree-first + RACE-style balancing
# ---------------------------------------------------------------------------

# Small-scale analogs of every benchmark-suite matrix class
# (benchmarks/suite.py) — the invariant set for coloring quality.
COLORING_SET = [
    ("poisson", lambda: csrc.poisson2d(8)),
    ("narrow_band1", lambda: csrc.fem_band(120, 1, seed=1)),
    ("fem_band_w4", lambda: csrc.fem_band(120, 4, seed=2)),
    ("fem_band_w8", lambda: csrc.fem_band(80, 8, seed=3)),
    ("fem_band_w8_sym", lambda: csrc.fem_band(80, 8, seed=3,
                                              numeric_symmetric=True)),
    ("random_nnz4", lambda: csrc.random_symmetric_pattern(80, 4, seed=4)),
    ("dense", lambda: csrc.dense_matrix(24, seed=5)),
]


@pytest.mark.parametrize("name,make", COLORING_SET,
                         ids=[n for n, _ in COLORING_SET])
def test_degree_ordering_never_beaten_by_unordered(name, make):
    """Satellite invariant: the default (largest-degree-first) colorer never
    uses more colors than the legacy unordered greedy, on every benchmark
    matrix class."""
    M = make()
    legacy = color_rows(M, order="natural", balance=False)
    tuned = color_rows(M)
    assert tuned.num_colors <= legacy.num_colors
    assert verify_coloring(M, tuned)


@pytest.mark.parametrize("name,make", COLORING_SET[:5],
                         ids=[n for n, _ in COLORING_SET[:5]])
def test_balancing_reduces_dispersion_preserves_colors(name, make):
    M = make()
    raw = color_rows(M, balance=False)
    bal = color_rows(M, balance=True)
    assert bal.num_colors <= raw.num_colors
    assert verify_coloring(M, bal)
    assert balance_stats(bal)["std"] <= balance_stats(raw)["std"] + 1e-9


def test_balanced_color_classes_keep_row_locality():
    """Rows inside one color class are emitted in ascending row order (the
    §3.2 locality criticism: iteration inside a color should stride
    monotonically through y)."""
    M = csrc.fem_band(120, 4, seed=6)
    col = color_rows(M)
    for c in range(col.num_colors):
        rows = col.rows(c)
        assert (np.diff(rows) > 0).all()


@settings(max_examples=6, deadline=None)
@given(st.integers(8, 48), st.integers(1, 5), st.integers(0, 1000))
def test_property_balanced_coloring_conflict_free(n, band, seed):
    M = csrc.fem_band(n, min(band, n - 1), seed=seed)
    col = color_rows(M)
    assert verify_coloring(M, col)
    covered = sorted(np.concatenate(
        [col.rows(c) for c in range(col.num_colors)]).tolist())
    assert covered == list(range(n))


# ---------------------------------------------------------------------------
# Multi-RHS SpMM vs the dense oracle (all paths, edge-case matrices)
# ---------------------------------------------------------------------------

def _empty_rows(n):
    i = np.arange(0, n, 2)
    return csrc.from_coo(i, i, np.ones(i.size), n=n)


SPMM_CASES = [
    ("fem_band", lambda: csrc.fem_band(48, 4, seed=1)),
    ("poisson", lambda: csrc.poisson2d(7)),
    ("rect_tail", lambda: csrc.rectangular_fem(40, 12, 3, seed=5)),
    ("empty_rows", lambda: _empty_rows(20)),
]


@pytest.mark.parametrize("nrhs", [1, 3, 8])
@pytest.mark.parametrize("name,make", SPMM_CASES,
                         ids=[n for n, _ in SPMM_CASES])
def test_spmm_matches_dense_oracle_all_plans(name, make, nrhs):
    """Acceptance: batched SpMM results match the dense oracle for
    nrhs in {1, 3, 8} on every feasible path (kernel, segment, colorful),
    including the rectangular tail and empty-row matrices."""
    M = make()
    A = csrc.to_dense(M).astype(np.float64)
    X = np.random.default_rng(nrhs).standard_normal(
        (M.m, nrhs)).astype(np.float32)
    Y_ref = A @ X.astype(np.float64)
    scale = max(1.0, np.abs(Y_ref).max())
    plans = tuner.enumerate_plans(tuner.stats_of(M), tms=(8,),
                                  nrhs_options=(nrhs,))
    assert plans
    for plan in plans:
        op = ops.SpmvOperator.from_plan(M, plan)
        Y = np.asarray(op(jnp.asarray(X)), dtype=np.float64)
        np.testing.assert_allclose(Y / scale, Y_ref / scale,
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"plan {plan.key()}")
        if nrhs == 1 and name == "fem_band":
            y1 = np.asarray(op(jnp.asarray(X[:, 0])), dtype=np.float64)
            np.testing.assert_allclose(y1, Y[:, 0], rtol=1e-6, atol=1e-6)


@settings(max_examples=3, deadline=None)
@given(st.integers(10, 32), st.integers(1, 4), st.integers(0, 10_000),
       st.sampled_from([1, 3, 8]))
def test_property_spmm_random_band(n, band, seed, nrhs):
    M = csrc.fem_band(n, min(band, max(1, n - 1)), seed=seed)
    A = csrc.to_dense(M).astype(np.float64)
    X = np.random.default_rng(seed).standard_normal(
        (M.m, nrhs)).astype(np.float32)
    Y_ref = A @ X.astype(np.float64)
    scale = max(1.0, np.abs(Y_ref).max())
    for plan in tuner.enumerate_plans(tuner.stats_of(M), tms=(8,)):
        Y = np.asarray(ops.SpmvOperator.from_plan(M, plan)(jnp.asarray(X)),
                       dtype=np.float64)
        np.testing.assert_allclose(Y / scale, Y_ref / scale,
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"plan {plan.key()}")


def test_plan_nrhs_field_and_key():
    p = ExecutionPlan(path="segment", nrhs=8)
    assert p.key().endswith(":r8")
    assert ExecutionPlan.from_json(p.to_json()) == p
    with pytest.raises(ValueError):
        ExecutionPlan(nrhs=0)
    # old cache entries (no nrhs key) deserialize to nrhs=1
    d = p.to_dict()
    del d["nrhs"]
    assert ExecutionPlan.from_dict(d).nrhs == 1


def test_enumerate_plans_nrhs_options():
    stats = tuner.stats_of(csrc.poisson2d(6))
    plans = tuner.enumerate_plans(stats, nrhs_options=(1, 4))
    widths = {p.nrhs for p in plans}
    assert widths == {1, 4}
    base = tuner.enumerate_plans(stats)
    assert len(plans) == 2 * len(base)


# ---------------------------------------------------------------------------
# Serving engine: coalesced SpMM + zero-build registration
# ---------------------------------------------------------------------------

def test_serving_register_cache_hit_zero_builds():
    from repro.serve.engine import SpmvServingEngine
    M = csrc.fem_band(80, 4, seed=2)
    cache = tuner.PlanCache()
    tuner.tune(M, cache=cache,
               measure=lambda op, x: 1.0 if op.plan.path == "kernel" else 2.0)
    eng = SpmvServingEngine(cache=cache, autotune=True)
    _, d = _build_delta(lambda: eng.register("fem", M))
    assert d == {}, f"cache-hit register did precompute work: {d}"


def test_serving_step_coalesces_into_one_spmm():
    """All pending requests for one matrix are answered by a single batched
    operator call (probe: count operator invocations)."""
    from repro.serve.engine import SpmvServingEngine
    M = csrc.fem_band(64, 3, seed=4)
    A = csrc.to_dense(M)
    eng = SpmvServingEngine()
    eng.register("m", M)
    op = eng._ops["m"]
    calls = []
    orig = op.__call__

    class CountingOp:
        plan = op.plan
        path = op.path

        def __call__(self, x):
            calls.append(getattr(x, "ndim", 1))
            return orig(x)

    eng._ops["m"] = CountingOp()
    rng = np.random.default_rng(5)
    xs = [rng.standard_normal(M.m).astype(np.float32) for _ in range(5)]
    uids = [eng.submit("m", x) for x in xs]
    out = eng.step()
    assert set(out) == set(uids)
    assert calls == [2], f"expected one batched SpMM call, got {calls}"
    for uid, x in zip(uids, xs):
        np.testing.assert_allclose(out[uid], A @ x, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Value-refresh fast path (same structure, new values — FEM time stepping)
# ---------------------------------------------------------------------------

def _same_structure_scaled(M, factor=1.5, shift=0.25):
    """A matrix with identical structure but different values."""
    A = csrc.to_dense(M)
    return csrc.from_dense(np.where(A != 0, A * factor + shift, 0.0))


@pytest.mark.parametrize("path,tm", [("kernel", 8), ("flat", 8),
                                     ("colorful", 8), ("segment", 8)])
def test_schedule_value_refresh_skips_structural_rebuild(path, tm):
    """On a value-digest miss with a same-structure schedule cached, the
    schedule layer refreshes value streams only: exactly one value_refresh,
    no pack/partition/coloring/schedule build — on every path."""
    M1 = csrc.skewed_band(96, 12, 3, seed=2)
    M2 = _same_structure_scaled(M1)
    assert S.structure_digest(M1) == S.structure_digest(M2)
    assert S.value_digest(M1) != S.value_digest(M2)
    cache = tuner.PlanCache()
    plan = ExecutionPlan(path=path, tm=tm)
    ops.SpmvOperator.from_plan(M1, plan, cache=cache)
    op2, d = _build_delta(
        lambda: ops.SpmvOperator.from_plan(M2, plan, cache=cache))
    assert d == {"value_refresh": 1}, f"{path}: structural rebuild {d}"
    x = jnp.asarray(np.random.default_rng(3).standard_normal(M2.m)
                    .astype(np.float32))
    ref = csrc.to_dense(M2).astype(np.float64) @ np.asarray(x, np.float64)
    scale = max(1.0, np.abs(ref).max())
    np.testing.assert_allclose(
        np.asarray(op2(x), np.float64) / scale, ref / scale,
        rtol=2e-4, atol=2e-4, err_msg=f"path {path}")


def test_value_refresh_replaces_superseded_generation(tmp_path):
    """Time stepping through the cache keeps ONE schedule per structure in
    memory (each refresh evicts the generation it superseded) and does NOT
    re-compress an npz per step — the structural generation written at
    build time keeps serving fresh processes."""
    path = os.path.join(tmp_path, "plans.json")
    cache = tuner.PlanCache(path=path)
    plan = ExecutionPlan(path="kernel", tm=8)
    M = csrc.fem_band(64, 4, seed=0)
    ops.SpmvOperator.from_plan(M, plan, cache=cache)
    for t in range(4):
        M = _same_structure_scaled(M, factor=1.0, shift=0.5)
        ops.SpmvOperator.from_plan(M, plan, cache=cache)
    assert len(cache.schedules) == 1
    files = [f for f in os.listdir(cache._schedule_dir())
             if f.endswith(".npz")]
    assert len(files) == 1
    # and the surviving generation is the newest one
    sched = next(iter(cache.schedules.values()))
    assert sched.value_digest == S.value_digest(M)


def test_operator_update_values_in_place():
    """SpmvOperator.update_values: refresh the live operator; results match
    a freshly built operator bit-for-bit, with zero structural work."""
    M1 = csrc.fem_band(64, 4, seed=5)
    M2 = _same_structure_scaled(M1)
    op = ops.SpmvOperator.from_plan(M1, ExecutionPlan(path="kernel", tm=8))
    _, d = _build_delta(lambda: op.update_values(M2))
    assert d == {"value_refresh": 1}
    fresh = ops.SpmvOperator.from_plan(M2, ExecutionPlan(path="kernel",
                                                         tm=8))
    X = jnp.asarray(np.random.default_rng(4).standard_normal((M2.m, 3))
                    .astype(np.float32))
    np.testing.assert_array_equal(np.asarray(op(X)), np.asarray(fresh(X)))


def test_update_values_rejects_different_structure():
    M1 = csrc.fem_band(64, 4, seed=5)
    M3 = csrc.fem_band(64, 4, seed=6)          # different pattern
    op = ops.SpmvOperator.from_plan(M1, ExecutionPlan(path="kernel", tm=8))
    with pytest.raises(ValueError):
        op.update_values(M3)


def test_refresh_rejects_numeric_symmetry_flip():
    """A symmetric->nonsymmetric value change alters the pack's streamed
    layout (vals_u conditional) — must rebuild, not refresh."""
    from repro.core import blockell
    M_sym = csrc.fem_band(48, 3, seed=1, numeric_symmetric=True)
    A = csrc.to_dense(M_sym)
    A_ns = np.where(A != 0, A + np.tril(np.ones_like(A), -1) * 0.5, 0.0)
    M_ns = csrc.from_dense(A_ns)
    assert S.structure_digest(M_sym) == S.structure_digest(M_ns)
    pack = blockell.pack(M_sym, tm=8)
    with pytest.raises(ValueError):
        blockell.refresh_values(pack, M_ns)


def test_schedule_npz_records_structure_digest(tmp_path):
    path = os.path.join(tmp_path, "plans.json")
    M = csrc.fem_band(48, 3, seed=2)
    cache = tuner.PlanCache(path=path)
    op = ops.SpmvOperator.from_plan(M, ExecutionPlan(path="kernel", tm=8),
                                    cache=cache)
    assert op.schedule.structure_digest == S.structure_digest(M)
    cache2 = tuner.PlanCache(path=path)
    sched = cache2.get_schedule(tuner.fingerprint(M), S.value_digest(M),
                                ExecutionPlan(path="kernel", tm=8))
    assert sched is not None
    assert sched.structure_digest == S.structure_digest(M)


# ---------------------------------------------------------------------------
# index_dtype through plans, candidates, and schedules
# ---------------------------------------------------------------------------

def test_plan_index_dtype_field_key_and_roundtrip():
    p = ExecutionPlan(path="kernel", index_dtype="int16")
    assert ":i16:" in p.key()
    assert ExecutionPlan.from_json(p.to_json()) == p
    with pytest.raises(ValueError):
        ExecutionPlan(index_dtype="int8")
    # old cache entries (no index_dtype key) deserialize to int32
    d = p.to_dict()
    del d["index_dtype"]
    assert ExecutionPlan.from_dict(d).index_dtype == "int32"


def test_enumerate_proposes_int16_where_pack_supports_it():
    M = csrc.fem_band(96, 4, seed=1)
    plans = tuner.enumerate_plans(tuner.stats_of(M), tms=(8,))
    kernel = [p for p in plans if p.path == "kernel"]
    assert {p.index_dtype for p in kernel} == {"int32", "int16"}
    # and the sweep can be restricted to int32 (legacy behavior)
    only32 = tuner.enumerate_plans(tuner.stats_of(M), tms=(8,),
                                   index_dtypes=("int32",))
    assert all(p.index_dtype == "int32" for p in only32)


def test_int16_infeasible_when_window_overflows():
    from repro.core.plan import feasible
    wide = ExecutionPlan(path="kernel", tm=128, w_cap=1 << 20,
                         index_dtype="int16")
    assert feasible(dataclasses.replace(wide, index_dtype="int32"),
                    n=60000, m=60000, bandwidth=40000)
    assert not feasible(wide, n=60000, m=60000, bandwidth=40000)


@pytest.mark.parametrize("path", ["kernel", "flat"])
def test_int16_plan_bit_identical_and_smaller_stream(path):
    M = csrc.skewed_band(128, 16, 3, seed=4)
    p32 = ExecutionPlan(path=path, tm=16)
    p16 = ExecutionPlan(path=path, tm=16, index_dtype="int16")
    # distinct schedule artifacts (the pack differs)
    assert S.plan_artifact_fields(p32) != S.plan_artifact_fields(p16)
    op32 = ops.SpmvOperator.from_plan(M, p32)
    op16 = ops.SpmvOperator.from_plan(M, p16)
    assert op16.pack.col_local.dtype == jnp.int16
    assert op16.pack.streamed_bytes() < op32.pack.streamed_bytes()
    x = jnp.asarray(np.random.default_rng(5).standard_normal(M.m)
                    .astype(np.float32))
    np.testing.assert_array_equal(np.asarray(op32(x)), np.asarray(op16(x)))


def test_int16_plan_reaches_distributed_flat_packs():
    """The shard-local flat layouts stream indices in the plan's dtype
    (and memoize per dtype), so a tuned int16 plan keeps its bandwidth win
    under the distributed strategies too."""
    M = csrc.fem_band(64, 4, seed=2)
    p16 = ExecutionPlan(path="flat", tm=16, index_dtype="int16")
    p32 = ExecutionPlan(path="flat", tm=16)
    sched = S.build_schedule(M, p16)
    fs16 = S.build_flat_shards(M, sched.partition, p16)
    fs32 = S.build_flat_shards(M, sched.partition, p32)
    assert fs16.col_local.dtype == jnp.int16
    assert fs32.col_local.dtype == jnp.int32        # distinct memo entries
    fh16 = S.build_flat_halo_layout(M, 2, p16)
    assert fh16.col_local.dtype == jnp.int16
    np.testing.assert_array_equal(np.asarray(fs16.col_local, np.int32),
                                  np.asarray(fs32.col_local))


def test_int16_schedule_disk_roundtrip_preserves_dtype(tmp_path):
    path = os.path.join(tmp_path, "plans.json")
    M = csrc.fem_band(64, 4, seed=9)
    plan = ExecutionPlan(path="kernel", tm=8, index_dtype="int16")
    cache = tuner.PlanCache(path=path)
    op1 = ops.SpmvOperator.from_plan(M, plan, cache=cache)
    cache2 = tuner.PlanCache(path=path)
    op2, d = _build_delta(
        lambda: ops.SpmvOperator.from_plan(M, plan, cache=cache2))
    assert d == {}, f"disk hit rebuilt: {d}"
    assert op2.pack.col_local.dtype == jnp.int16
    x = jnp.asarray(np.random.default_rng(6).standard_normal(M.m)
                    .astype(np.float32))
    np.testing.assert_array_equal(np.asarray(op1(x)), np.asarray(op2(x)))


# ---------------------------------------------------------------------------
# coloring provider through plans, schedules, and the disk cache
# ---------------------------------------------------------------------------

def test_plan_coloring_field_key_and_backcompat():
    """The coloring provider is a plan field: ':race' marks the colorful
    key, greedy keys stay byte-identical to pre-provider caches, and old
    cache JSONs (no 'coloring' entry) deserialize to greedy."""
    greedy = ExecutionPlan(path="colorful")
    race = ExecutionPlan(path="colorful", coloring="race")
    assert greedy.key() == "colorful:nnz:allreduce"      # unchanged key
    assert race.key() == "colorful:race:nnz:allreduce"
    assert ExecutionPlan.from_json(race.to_json()) == race
    with pytest.raises(ValueError):
        ExecutionPlan(path="colorful", coloring="rainbow")
    # pre-provider cache entries (no coloring key) deserialize to greedy
    d = greedy.to_dict()
    del d["coloring"]
    restored = ExecutionPlan.from_dict(d)
    assert restored.coloring == "greedy"
    assert restored.key() == "colorful:nnz:allreduce"
    # the provider only marks the path that consumes it
    assert ":race" not in ExecutionPlan(path="segment",
                                        coloring="race").key()


def test_coloring_provider_separates_schedule_keys():
    """Both providers' artifacts coexist in one cache: the provider joins
    the colorful path's artifact fields, so the schedule keys differ."""
    M = csrc.fem_band(48, 4, seed=3)
    greedy = ExecutionPlan(path="colorful")
    race = ExecutionPlan(path="colorful", coloring="race")
    assert S.plan_artifact_fields(greedy) != S.plan_artifact_fields(race)
    fp, dig = tuner.fingerprint(M), S.value_digest(M)
    assert (S.schedule_key(fp, dig, greedy, p=1)
            != S.schedule_key(fp, dig, race, p=1))


def test_colorful_race_schedule_roundtrips_zero_rebuild(tmp_path):
    """A colorful:race schedule survives the npz round-trip — provider and
    level-group metadata included — and a fresh cache object rebuilds
    nothing (the BUILD_COUNTS probe) while producing bit-identical SpMV."""
    path = os.path.join(tmp_path, "plans.json")
    M = csrc.fem_band(96, 6, seed=5)
    plan = ExecutionPlan(path="colorful", coloring="race")
    x = jnp.asarray(np.random.default_rng(4).standard_normal(M.m)
                    .astype(np.float32))
    cache = tuner.PlanCache(path=path)
    op1, d1 = _build_delta(
        lambda: ops.SpmvOperator.from_plan(M, plan, cache=cache))
    assert d1.get("coloring") == 1
    cache2 = tuner.PlanCache(path=path)          # "new process"
    op2, d2 = _build_delta(
        lambda: ops.SpmvOperator.from_plan(M, plan, cache=cache2))
    assert d2 == {}, f"disk hit rebuilt: {d2}"
    col = op2.schedule.coloring
    assert col.provider == "race"
    assert col.level_of_row is not None and col.group_of_row is not None
    assert np.array_equal(col.color_of_row,
                          op1.schedule.coloring.color_of_row)
    assert verify_coloring(M, col)
    np.testing.assert_array_equal(np.asarray(op1(x)), np.asarray(op2(x)))


def test_race_colorful_spmv_matches_dense_oracle():
    """The chunk-aware RACE coloring executes exactly on the sum-combining
    scatter: colorful:race SpMV and SpMM match the dense oracle."""
    M = csrc.fem_band(80, 8, seed=6)
    A = csrc.to_dense(M)
    plan = ExecutionPlan(path="colorful", coloring="race")
    op = ops.SpmvOperator.from_plan(M, plan)
    X = np.random.default_rng(5).standard_normal((M.m, 3)).astype(
        np.float32)
    np.testing.assert_allclose(np.asarray(op(jnp.asarray(X))), A @ X,
                               rtol=2e-4, atol=2e-4)
    x = X[:, 0]
    np.testing.assert_allclose(np.asarray(op(jnp.asarray(x))), A @ x,
                               rtol=2e-4, atol=2e-4)


def test_enumerate_plans_emits_both_coloring_providers():
    M = csrc.fem_band(96, 4, seed=1)
    plans = tuner.enumerate_plans(tuner.stats_of(M), tms=(8,))
    colorful = [p for p in plans if p.path == "colorful"]
    assert {p.coloring for p in colorful} == {"greedy", "race"}
    # the sweep can be restricted to one provider (legacy behavior)
    only_greedy = tuner.enumerate_plans(tuner.stats_of(M), tms=(8,),
                                        colorings=("greedy",))
    assert all(p.coloring == "greedy" for p in only_greedy
               if p.path == "colorful")
