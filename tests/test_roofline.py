"""Roofline machinery: trip-count-aware HLO cost rollup + collective parse
(validated against hand-computable modules)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_cost import analyze_hlo, parse_hlo

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_scan_flops_scale_with_trip_count():
    def make(L):
        def f(w, x):
            def body(x, wi):
                return jnp.tanh(x @ wi), None
            x, _ = jax.lax.scan(body, x, w)
            return x.sum()
        return f

    for L in (2, 8, 24):
        w = jax.ShapeDtypeStruct((L, 128, 128), jnp.float32)
        x = jax.ShapeDtypeStruct((4, 128), jnp.float32)
        c = analyze_hlo(jax.jit(make(L)).lower(w, x).compile().as_text())
        expect = L * 2 * 4 * 128 * 128
        assert abs(c.flops / expect - 1.0) < 0.05, (L, c.flops)


def test_nested_scan_multiplies():
    def f(w, x):
        def outer(x, _):
            def inner(x, wi):
                return x @ wi, None
            x, _ = jax.lax.scan(inner, x, w)
            return x, None
        x, _ = jax.lax.scan(outer, x, None, length=3)
        return x.sum()

    w = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((2, 64), jnp.float32)
    c = analyze_hlo(jax.jit(f).lower(w, x).compile().as_text())
    expect = 3 * 5 * 2 * 2 * 64 * 64
    assert abs(c.flops / expect - 1.0) < 0.05


def test_plain_matmul_flops():
    a = jax.ShapeDtypeStruct((32, 48), jnp.float32)
    b = jax.ShapeDtypeStruct((48, 16), jnp.float32)
    c = analyze_hlo(jax.jit(jnp.dot).lower(a, b).compile().as_text())
    assert abs(c.flops - 2 * 32 * 48 * 16) / (2 * 32 * 48 * 16) < 0.01


@pytest.mark.slow
def test_collectives_counted_in_sharded_module():
    """psum inside a scan over a sharded mesh: collective bytes must be
    multiplied by the trip count (subprocess: needs 8 fake devices)."""
    code = """
        import jax, jax.numpy as jnp, functools
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.roofline.hlo_cost import analyze_hlo
        mesh = jax.make_mesh((8,), ('d',))
        def inner(x):
            def body(c, _):
                return jax.lax.psum(c, 'd'), None
            c, _ = jax.lax.scan(body, x, None, length=10)
            return c
        fn = shard_map(inner, mesh=mesh, in_specs=P(), out_specs=P())
        x = jax.ShapeDtypeStruct((1024,), jnp.float32)
        with mesh:
            txt = jax.jit(fn).lower(x).compile().as_text()
        c = analyze_hlo(txt)
        # 10 iterations x >= 4KB each (any all-reduce impl moves >= payload)
        assert c.collective_bytes >= 10 * 1024 * 4, c.collective_bytes
        assert c.collectives['all-reduce']['count'] >= 10
        print('OK', c.collective_bytes)
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=300)
    assert out.returncode == 0, out.stderr[-3000:]


def test_model_flops_formula():
    from repro.roofline.analysis import model_flops
    from repro.configs.base import get_config
    from repro.configs.shapes import SHAPES
    cfg = get_config("qwen3-8b")
    n = cfg.param_count()
    assert abs(model_flops(cfg, SHAPES["train_4k"])
               - 6 * n * 4096 * 256) / (6 * n * 4096 * 256) < 1e-6
    moe = get_config("qwen3-moe-235b-a22b")
    assert moe.active_param_count() < 0.15 * moe.param_count()
    # ~235B total / ~22B active (within modelling tolerance)
    assert 1.8e11 < moe.param_count() < 2.6e11
    assert 1.6e10 < moe.active_param_count() < 2.8e10
