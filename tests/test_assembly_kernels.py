"""The fused assembly-scatter kernel layer (repro.kernels.assembly_scatter
+ the scatter.py wiring): colored-batch stream/onehot bodies, the
sorted-slot strategy, int16 index gating, the value-refresh probe, and
predict-then-measure strategy selection.

Everything numerical is asserted bit-for-bit against the serial
``np.add.at`` oracle — the dyadic stiffness synthesis makes float32
accumulation order-independent, so any dropped sentinel, mis-gated
upcast, or pack corruption fails hard, not approximately."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _propshim import given, settings, st
from repro import obs
from repro.assembly import mesh as amesh
from repro.assembly import (assemble, build_assembly_schedule,
                            color_elements, scatter_colored,
                            scatter_colored_percolor, scatter_private,
                            scatter_serial, scatter_sorted, tune_assembly)
from repro.assembly.scatter import (ASSEMBLY_CANDIDATES, STRATEGIES,
                                    AssemblySchedule)
from repro.core import schedule as S, tuner
from repro.core.coloring import Coloring
from repro.kernels import assembly_scatter as akern
from repro.roofline import cost_model


MESHES = [
    ("tri", lambda: amesh.grid_tri(5)),
    ("quad", lambda: amesh.grid_quad(4)),
    ("tet", lambda: amesh.grid_tet(2)),
]
MESH_IDS = [n for n, _ in MESHES]

# every (strategy, variant) executor the PR ships, plus the in-grid
# Pallas bodies run through the emulated grid
COMBOS = [("colored", "stream"), ("colored", "onehot"),
          ("colored", "percolor"), ("sorted", "stream"),
          ("private", "vmap")]
COMBO_IDS = [f"{s}-{v}" for s, v in COMBOS]


def _build_delta(fn):
    before = dict(S.BUILD_COUNTS)
    out = fn()
    after = dict(S.BUILD_COUNTS)
    delta = {k: after.get(k, 0) - before.get(k, 0)
             for k in set(after) | set(before)}
    return out, {k: v for k, v in delta.items() if v}


def _scatter(sched, ke, strategy, variant):
    if strategy == "colored":
        return scatter_colored(sched, ke, variant=variant)
    if strategy == "sorted":
        return scatter_sorted(sched, ke)
    if strategy == "private":
        return scatter_private(sched, ke)
    return scatter_serial(sched, ke)


# ---------------------------------------------------------------------------
# Bit-identity: every strategy × variant × mesh class vs the oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy,variant", COMBOS, ids=COMBO_IDS)
@pytest.mark.parametrize("name,make", MESHES, ids=MESH_IDS)
def test_every_executor_bit_identical(name, make, strategy, variant):
    mesh = make()
    ke = amesh.synthetic_stiffness(mesh, seed=13)
    sched = build_assembly_schedule(mesh)
    ref = scatter_serial(sched, ke)
    got = np.asarray(_scatter(sched, ke, strategy, variant))
    np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("variant", ["stream", "onehot"])
@pytest.mark.parametrize("name,make", [MESHES[0], MESHES[2]],
                         ids=["tri", "tet"])
def test_pallas_grid_bodies_match_oracle(name, make, variant):
    """The in-grid colored-batch bodies (one program per color / per
    (color, tile)) through the emulated Pallas grid — the executors the
    compiled TPU target runs — match the oracle bit for bit."""
    mesh = make()
    ke = amesh.synthetic_stiffness(mesh, seed=5)
    sched = build_assembly_schedule(mesh)
    ref = scatter_serial(sched, ke)
    got = np.asarray(akern.colored_scatter_grid(
        sched.color_slots, sched.color_targets, jnp.asarray(ke),
        sched.size, variant=variant, interpret=True))
    np.testing.assert_array_equal(got, ref)


def test_colored_kernels_are_jit_compatible():
    mesh = amesh.grid_tet(2)
    ke = amesh.synthetic_stiffness(mesh, seed=3)
    sched = build_assembly_schedule(mesh)
    ref = scatter_serial(sched, ke)
    for fn in (jax.jit(lambda k: scatter_colored(sched, k)),
               jax.jit(lambda k: scatter_sorted(sched, k))):
        np.testing.assert_array_equal(np.asarray(fn(jnp.asarray(ke))),
                                      ref)


def test_race_coloring_through_the_fused_kernels():
    """RACE packs (fewer, larger colors) through both kernel variants."""
    mesh = amesh.grid_tet(2)
    ke = amesh.synthetic_stiffness(mesh, seed=17)
    sched = build_assembly_schedule(mesh.conn, coloring_provider="race")
    ref = scatter_serial(sched, ke)
    for variant in ("stream", "onehot"):
        np.testing.assert_array_equal(
            np.asarray(scatter_colored(sched, ke, variant=variant)), ref)
    np.testing.assert_array_equal(
        np.asarray(scatter_sorted(sched, ke)), ref)


# ---------------------------------------------------------------------------
# Property sweep + edge cases (satellite)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.sampled_from(["tri", "quad", "tet"]), st.integers(2, 5),
       st.integers(0, 10_000))
def test_property_random_meshes_all_strategies_exact(kind, nx, seed):
    """Random structured meshes × all three strategies × both kernel
    variants: bit-identity vs the serial oracle, every draw."""
    gen = {"tri": amesh.grid_tri, "quad": amesh.grid_quad,
           "tet": lambda s: amesh.grid_tet(max(2, s // 2))}[kind]
    mesh = gen(nx)
    ke = amesh.synthetic_stiffness(mesh, seed=seed)
    sched = build_assembly_schedule(mesh)
    ref = scatter_serial(sched, ke)
    for strategy, variant in COMBOS:
        got = np.asarray(_scatter(sched, ke, strategy, variant))
        np.testing.assert_array_equal(
            got, ref, err_msg=f"{kind} nx={nx} seed={seed} "
                              f"{strategy}/{variant}")


def test_empty_color_class_is_inert():
    """A palette entry with zero elements (legal after balancing) must
    contribute nothing: its pack row is all sentinels."""
    mesh = amesh.grid_tri(4)
    ke = amesh.synthetic_stiffness(mesh, seed=9)
    col = color_elements(mesh.conn)
    padded = Coloring(
        color_of_row=col.color_of_row,
        num_colors=col.num_colors + 1,
        rows_by_color=col.rows_by_color,
        color_ptr=np.append(col.color_ptr, col.color_ptr[-1]),
        provider=col.provider)
    sched = build_assembly_schedule(mesh, coloring=padded)
    assert sched.color_slots.shape[0] == col.num_colors + 1
    # the empty color's row is pure sentinel padding
    assert (sched.color_slots[-1] == sched.targets.size).all()
    assert (sched.color_targets[-1] == sched.size).all()
    ref = scatter_serial(sched, ke)
    for variant in ("stream", "onehot", "percolor"):
        np.testing.assert_array_equal(
            np.asarray(scatter_colored(sched, ke, variant=variant)), ref)


def test_single_element_mesh():
    """ne=1 degenerate schedule: one color, every strategy exact."""
    conn = np.asarray([[0, 1, 2]])
    ke = np.asarray([[[2.0, -1.0, -1.0], [-1.0, 2.0, -1.0],
                      [-1.0, -1.0, 2.0]]], np.float32) / 4
    sched = build_assembly_schedule(conn)
    assert sched.ne == 1 and sched.coloring.num_colors == 1
    ref = scatter_serial(sched, ke)
    for strategy, variant in COMBOS:
        np.testing.assert_array_equal(
            np.asarray(_scatter(sched, ke, strategy, variant)), ref,
            err_msg=f"{strategy}/{variant}")


# ---------------------------------------------------------------------------
# int16 index gating (satellite)
# ---------------------------------------------------------------------------

def test_int16_gate_small_mesh_narrows_all_streams():
    sched = build_assembly_schedule(amesh.grid_tri(5))
    assert sched.size <= np.iinfo(np.int16).max
    assert sched.color_slots.dtype == np.int16
    assert sched.color_targets.dtype == np.int16
    assert sched.sorted_perm.dtype == np.int16
    assert sched.sorted_targets.dtype == np.int16


def test_int16_gate_overflow_upcasts_targets_only():
    """A schedule whose unified vector exceeds the int16 range but whose
    contribution count does not: target streams widen to int32, slot
    streams stay int16 — the gates are per stream, like SpMV."""
    i16 = np.iinfo(np.int16).max
    conn = np.asarray([[0, 1, i16]])        # n = 32768 > int16 max
    sched = build_assembly_schedule(conn)
    assert sched.size > i16 and sched.targets.size <= i16
    assert sched.color_targets.dtype == np.int32
    assert sched.sorted_targets.dtype == np.int32
    assert sched.color_slots.dtype == np.int16
    assert sched.sorted_perm.dtype == np.int16
    # upcast correctness: the wide-target kernels still match the oracle
    ke = np.asarray([[[2.0, -0.5, -0.25], [-0.5, 1.0, -0.125],
                      [-0.25, -0.125, 3.0]]], np.float32)
    ref = scatter_serial(sched, ke)
    for strategy, variant in COMBOS:
        np.testing.assert_array_equal(
            np.asarray(_scatter(sched, ke, strategy, variant)), ref,
            err_msg=f"{strategy}/{variant}")


def test_int16_pack_dtypes_survive_npz(tmp_path):
    path = os.path.join(tmp_path, "asm.npz")
    sched = build_assembly_schedule(amesh.grid_quad(4))
    sched.save_npz(path)
    back = AssemblySchedule.load_npz(path)
    for f in ("color_slots", "color_targets", "sorted_perm",
              "sorted_targets"):
        assert getattr(back, f).dtype == getattr(sched, f).dtype, f
        np.testing.assert_array_equal(getattr(back, f),
                                      getattr(sched, f))


# ---------------------------------------------------------------------------
# Value-refresh instrumentation (satellite)
# ---------------------------------------------------------------------------

def test_assemble_counts_one_value_refresh_and_zero_rebuilds():
    mesh = amesh.grid_tri(5)
    ke = amesh.poisson_stiffness(mesh, mass=1.0)
    sched, d0 = _build_delta(lambda: build_assembly_schedule(mesh))
    assert d0.get("assembly_color_pack") == 1
    assert d0.get("assembly_sorted_pack") == 1
    for strategy in STRATEGIES:
        _, d = _build_delta(lambda: assemble(sched, ke,
                                             strategy=strategy))
        assert d == {"assembly_value_refresh": 1}, (strategy, d)


def test_assemble_observes_span_and_histogram():
    mesh = amesh.grid_tri(4)
    ke = amesh.poisson_stiffness(mesh, mass=1.0)
    sched = build_assembly_schedule(mesh)
    snap0 = obs.snapshot()
    assemble(sched, ke, strategy="sorted")
    assemble(sched, ke, strategy="colored", variant="onehot")
    d = obs.snapshot().diff(snap0)
    h_sorted = d.merged_hist("assembly_scatter_seconds",
                             strategy="sorted", variant="stream")
    h_onehot = d.merged_hist("assembly_scatter_seconds",
                             strategy="colored", variant="onehot")
    assert h_sorted.get("count") == 1, h_sorted
    assert h_onehot.get("count") == 1, h_onehot
    assert d.total("build_total", kind="assembly_value_refresh") == 2


# ---------------------------------------------------------------------------
# Predict-then-measure strategy selection + cost model
# ---------------------------------------------------------------------------

def test_assembly_cost_prices_every_candidate():
    sched = build_assembly_schedule(amesh.grid_tet(2))
    priced = cost_model.rank_assembly_candidates(sched,
                                                 ASSEMBLY_CANDIDATES)
    assert len(priced) == len(ASSEMBLY_CANDIDATES)
    for (s, v), est in priced:
        assert est.predicted_s > 0 and est.bytes > 0, (s, v)
    by_key = {f"{s}/{v}": est for (s, v), est in priced}
    # the one-hot mask build makes that variant compute-bound; the
    # per-color baseline pays the palette launch term above the fused
    # stream kernel
    assert by_key["colored/onehot"].bound == "compute"
    assert (by_key["colored/percolor"].predicted_s
            > by_key["colored/stream"].predicted_s)
    # sorted-slot streams the fewest bytes — no pack padding at all
    assert by_key["sorted/stream"].bytes <= by_key["colored/stream"].bytes


def test_tune_assembly_picks_injected_winner_and_caches(tmp_path):
    path = os.path.join(tmp_path, "plans.json")
    mesh = amesh.grid_tri(5)
    ke = amesh.poisson_stiffness(mesh, mass=1.0)
    sched = build_assembly_schedule(mesh)
    cache = tuner.PlanCache(path=path)

    def measure(fn, kej):                  # deterministic constant clock
        out = np.asarray(fn(kej))          # executor must actually run
        assert out.shape == (sched.size,)
        return 1.0

    res = tune_assembly(sched, ke, cache=cache, measure=measure)
    assert not res.cached
    assert (res.strategy, res.variant) in ASSEMBLY_CANDIDATES
    assert res.predictions_s.keys() >= res.timings_s.keys()
    assert set(res.roofline_fraction) == set(res.timings_s)
    # every strategy family was measured at least once (no family is
    # pruned unseen)
    measured_strategies = {k.split("/")[0] for k in res.timings_s}
    assert measured_strategies == {"colored", "sorted", "private"}
    # second call: pure cache hit, nothing measured
    res2 = tune_assembly(sched, ke, cache=cache,
                         measure=lambda fn, k: pytest.fail("measured"))
    assert res2.cached and res2.key() == res.key()
    # the record survives the disk round-trip ("new process")
    cache2 = tuner.PlanCache(path=path)
    res3 = tune_assembly(sched, ke, cache=cache2,
                         measure=lambda fn, k: pytest.fail("measured"))
    assert res3.cached and res3.key() == res.key()
    assert res3.roofline_fraction == res.roofline_fraction


def test_tune_assembly_winner_beats_percolor_on_tet():
    """The acceptance property, as a live measurement: on the tet mesh
    the tuned fused kernel is faster at steady state than the legacy
    per-color XLA scatter baseline."""
    mesh = amesh.grid_tet(3)
    ke = amesh.synthetic_stiffness(mesh, seed=1)
    sched = build_assembly_schedule(mesh)
    res = tune_assembly(sched, ke, repeats=3)
    assert res.key() != "colored/percolor"
    if "colored/percolor" in res.timings_s:
        assert (res.timings_s[res.key()]
                < res.timings_s["colored/percolor"])
