"""The flat-grid kernel as a first-class KernelPath: registry dispatch,
tuner enumeration (skew-gated, feasibility-filtered), schedule artifacts
with cache/disk round-trips and zero-rebuild probes, multi-RHS execution
vs the dense oracle, shard-local flat execution in every distributed
strategy, and the serving engine running a tuned flat plan."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _propshim import given, settings, st
from repro.core import csrc, distributed as D, paths, schedule as S, tuner
from repro.core.plan import PATHS, ExecutionPlan, feasible
from repro.kernels import ops
from repro.kernels.csrc_spmv_flat import flat_spmm, flat_spmv, pack_flat


def _skewed(n=256, wide=48, narrow=3, seed=1, **kw):
    return csrc.skewed_band(n, wide, narrow, seed=seed, **kw)


def _check_against_dense(M, plan, nrhs=1, rtol=2e-4, seed=11):
    A = csrc.to_dense(M).astype(np.float64)
    rng = np.random.default_rng(seed)
    shape = (M.m,) if nrhs == 1 else (M.m, nrhs)
    x = rng.standard_normal(shape).astype(np.float32)
    y_ref = A @ x.astype(np.float64)
    scale = max(1.0, np.abs(y_ref).max())
    op = ops.SpmvOperator.from_plan(M, plan)
    assert op.plan.path == plan.path          # strict: no silent fallback
    y = np.asarray(op(jnp.asarray(x)), dtype=np.float64)
    np.testing.assert_allclose(y / scale, y_ref / scale, rtol=rtol,
                               atol=rtol, err_msg=f"plan {plan.key()}")
    return op


# ---------------------------------------------------------------------------
# Registry + plan layer
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_flat_is_a_registered_path(self):
        assert "flat" in PATHS
        entry = paths.get_path("flat")
        assert entry.name == "flat"
        plan = ExecutionPlan(path="flat", tm=64)
        assert plan.key().startswith("flat:tm64:")

    def test_every_builtin_path_is_registered(self):
        names = {e.name for e in paths.registered_paths()}
        assert {"segment", "kernel", "colorful", "flat"} <= names
        # the registry is the source of truth for plan validation
        assert set(PATHS) == names

    def test_unknown_path_rejected(self):
        with pytest.raises(KeyError):
            paths.get_path("warp")
        with pytest.raises(ValueError):
            ExecutionPlan(path="warp")

    def test_flat_feasibility_mirrors_kernel_gate(self):
        M = _skewed(128, 16)
        band = csrc.bandwidth(M)
        ok = ExecutionPlan(path="flat", tm=32)
        assert feasible(ok, n=M.n, m=M.m, bandwidth=band)
        tight = ExecutionPlan(path="flat", tm=128, w_cap=64)
        assert not feasible(tight, n=M.n, m=M.m, bandwidth=band)
        # square-only
        assert not feasible(ok, n=64, m=96, bandwidth=band)


class TestEnumeration:
    def test_flat_emitted_on_skewed_matrices(self):
        M = _skewed()
        stats = tuner.stats_of(M)
        assert paths.flat_worth_measuring(stats), "not skewed?"
        plans = tuner.enumerate_plans(stats, tms=(32, 64))
        flat = [p for p in plans if p.path == "flat"]
        assert flat, [p.key() for p in plans]
        for p in flat:
            assert feasible(p, n=M.n, m=M.m, bandwidth=stats.bandwidth)

    def test_flat_skipped_on_uniform_rows(self):
        """Uniform nnz-per-row: the rectangular grid pads nothing, so a
        flat candidate is not worth measuring."""
        M = csrc.fem_band(128, 2, seed=0, fill=1.0)
        stats = tuner.stats_of(M)
        assert not paths.flat_worth_measuring(stats)
        plans = tuner.enumerate_plans(stats)
        assert not any(p.path == "flat" for p in plans)

    def test_unpackable_matrices_reject_flat_and_kernel(self):
        """The bugfix: a matrix the packer cannot tile (bandwidth ~ n,
        window over w_cap) must yield no 'flat'/'kernel' candidates
        instead of erroring mid-tune."""
        M = csrc.random_symmetric_pattern(300, 4, seed=0)
        stats = tuner.stats_of(M)
        plans = tuner.enumerate_plans(stats, w_cap=256)
        assert plans                       # segment survives
        assert not any(p.path in ("flat", "kernel") for p in plans)
        # ... and tuning such a matrix completes on the surviving paths
        res = tuner.tune(M, cache=tuner.PlanCache(),
                         measure=lambda op, x: 1.0)
        assert res.plan.path not in ("flat", "kernel")

    def test_candidate_source_plans_are_feasibility_filtered(self):
        """Plans injected through the legacy hook get the same feasibility
        gate as registry candidates — an unpackable flat plan never
        reaches measurement."""
        bad = ExecutionPlan(path="flat", tm=128, w_cap=128)
        ok = ExecutionPlan(path="segment", w_cap=777)

        def source(stats):
            return [bad, ok]

        tuner.register_candidate_source(source)
        try:
            M = csrc.random_symmetric_pattern(300, 4, seed=1)
            plans = tuner.enumerate_plans(tuner.stats_of(M))
            assert ok in plans
            assert bad not in plans
        finally:
            tuner._CANDIDATE_SOURCES.remove(source)

    def test_rectangular_matrix_yields_no_flat(self):
        M = csrc.rectangular_fem(48, 16, 4, seed=5)
        plans = tuner.enumerate_plans(tuner.stats_of(M))
        assert all(p.path == "segment" for p in plans)
        with pytest.raises(ValueError):
            ops.SpmvOperator.from_plan(M, ExecutionPlan(path="flat"))


# ---------------------------------------------------------------------------
# Execution vs the dense oracle (single- and multi-RHS, edge cases)
# ---------------------------------------------------------------------------

class TestFlatExecution:
    @pytest.mark.parametrize("nrhs", [1, 3, 8])
    def test_matches_dense_across_rhs_widths(self, nrhs):
        M = _skewed()
        _check_against_dense(M, ExecutionPlan(path="flat", tm=64),
                             nrhs=nrhs)

    @pytest.mark.parametrize("nrhs", [1, 3])
    def test_numerically_symmetric_stream(self, nrhs):
        M = _skewed(seed=7, numeric_symmetric=True)
        op = _check_against_dense(
            M, ExecutionPlan(path="flat", tm=32), nrhs=nrhs)
        assert op.schedule.flat_pack.num_symmetric

    def test_rectangular_tail_tile(self):
        """n not a multiple of tm: the last tile is partial."""
        M = csrc.fem_band(130, 5, seed=3)
        assert 130 % 64 != 0
        _check_against_dense(M, ExecutionPlan(path="flat", tm=64))

    def test_empty_rows(self):
        i = np.arange(0, 20, 2)
        M = csrc.from_coo(i, i, np.ones(i.size), n=20)
        _check_against_dense(M, ExecutionPlan(path="flat", tm=8))

    def test_n1(self):
        M = csrc.from_dense(np.array([[3.0]]))
        _check_against_dense(M, ExecutionPlan(path="flat"))

    def test_diag_only(self):
        n = 17
        i = np.arange(n)
        M = csrc.from_coo(i, i, np.arange(1.0, n + 1.0), n=n)
        _check_against_dense(M, ExecutionPlan(path="flat", tm=8))

    def test_flat_beats_rect_padding_and_bytes_on_skew(self):
        """The reason 'flat' exists: on a skewed matrix its pad_ratio and
        streamed_bytes are strictly below the rectangular grid's."""
        M = _skewed(1024, 48, 3, seed=1)
        rect = ops.SpmvOperator.from_plan(
            M, ExecutionPlan(path="kernel", tm=64))
        flat = ops.SpmvOperator.from_plan(
            M, ExecutionPlan(path="flat", tm=64))
        assert flat.pack.pad_ratio < rect.pack.pad_ratio
        assert flat.bytes_per_call < rect.bytes_per_call

    @settings(max_examples=4, deadline=None)
    @given(st.integers(16, 100), st.integers(1, 10), st.integers(0, 10_000),
           st.booleans())
    def test_property_flat_matches_dense(self, n, band, seed, sym):
        M = csrc.fem_band(n, min(band, n - 1), seed=seed,
                          numeric_symmetric=sym)
        _check_against_dense(M, ExecutionPlan(path="flat", tm=8))

    @settings(max_examples=3, deadline=None)
    @given(st.integers(16, 80), st.integers(1, 8), st.integers(0, 10_000),
           st.sampled_from([3, 8]))
    def test_property_flat_spmm_matches_dense(self, n, band, seed, nrhs):
        M = csrc.fem_band(n, min(band, n - 1), seed=seed)
        _check_against_dense(M, ExecutionPlan(path="flat", tm=8),
                             nrhs=nrhs)


# ---------------------------------------------------------------------------
# Schedule artifacts: cache, disk round-trip, zero-rebuild probes
# ---------------------------------------------------------------------------

def _build_delta(fn):
    before = dict(S.BUILD_COUNTS)
    out = fn()
    after = dict(S.BUILD_COUNTS)
    return out, {k: after.get(k, 0) - before.get(k, 0)
                 for k in set(after) | set(before)
                 if after.get(k, 0) != before.get(k, 0)}


class TestFlatSchedule:
    def test_schedule_bundles_flat_pack_only(self):
        M = _skewed(128, 16)
        sched = S.build_schedule(M, ExecutionPlan(path="flat", tm=32))
        assert sched.flat_pack is not None
        assert sched.pack is None and sched.coloring is None
        assert sched.partition.starts[-1] == M.n

    def test_cache_hit_rebuilds_zero_flat_packs(self):
        """The acceptance probe: a second operator construction through
        the cache performs zero flat packs and is bit-identical."""
        M = _skewed(96, 12)
        x = jnp.asarray(np.random.default_rng(0).standard_normal(M.m)
                        .astype(np.float32))
        cache = tuner.PlanCache()
        plan = ExecutionPlan(path="flat", tm=32)
        op1, d1 = _build_delta(
            lambda: ops.SpmvOperator.from_plan(M, plan, cache=cache))
        assert d1.get("flat_pack") == 1 and d1.get("schedule") == 1
        op2, d2 = _build_delta(
            lambda: ops.SpmvOperator.from_plan(M, plan, cache=cache))
        assert d2 == {}, f"cache hit rebuilt: {d2}"
        assert cache.schedule_hits == 1
        np.testing.assert_array_equal(np.asarray(op1(x)),
                                      np.asarray(op2(x)))

    def test_disk_roundtrip_bit_identical(self, tmp_path):
        M = _skewed(96, 12, seed=4)
        plan = ExecutionPlan(path="flat", tm=32)
        sched = S.build_schedule(M, plan)
        f = os.path.join(tmp_path, "flat.npz")
        sched.save_npz(f)
        loaded = S.SpmvSchedule.load_npz(f)
        assert loaded.plan == plan
        pk0, pk1 = sched.flat_pack, loaded.flat_pack
        assert (pk0.total_steps, pk0.w_pad, pk0.nt) == \
               (pk1.total_steps, pk1.w_pad, pk1.nt)
        x = jnp.asarray(np.random.default_rng(1).standard_normal(M.m)
                        .astype(np.float32))
        y0 = np.asarray(ops.SpmvOperator.from_plan(M, plan,
                                                   schedule=sched)(x))
        y1 = np.asarray(ops.SpmvOperator.from_plan(M, plan,
                                                   schedule=loaded)(x))
        np.testing.assert_array_equal(y0, y1)

    def test_disk_cache_hit_rebuilds_nothing(self, tmp_path):
        """Cold process simulation: a fresh PlanCache over the same file
        loads the flat schedule from npz — zero flat packs."""
        path = os.path.join(tmp_path, "plans.json")
        M = _skewed(96, 12, seed=6)
        plan = ExecutionPlan(path="flat", tm=32)
        cache1 = tuner.PlanCache(path=path)
        ops.SpmvOperator.from_plan(M, plan, cache=cache1)
        cache2 = tuner.PlanCache(path=path)       # fresh memory
        _, delta = _build_delta(
            lambda: ops.SpmvOperator.from_plan(M, plan, cache=cache2))
        assert delta == {}, f"disk hit rebuilt: {delta}"
        assert cache2.schedule_hits == 1

    def test_version_bump_invalidates(self, tmp_path, monkeypatch):
        M = _skewed(64, 8, seed=8)
        plan = ExecutionPlan(path="flat", tm=32)
        sched = S.build_schedule(M, plan)
        f = os.path.join(tmp_path, "flat.npz")
        sched.save_npz(f)
        monkeypatch.setattr(S, "SCHEDULE_VERSION", S.SCHEDULE_VERSION + 1)
        with pytest.raises(ValueError):
            S.SpmvSchedule.load_npz(f)

    def test_artifact_shared_across_accumulation_and_nrhs(self):
        a = ExecutionPlan(path="flat", tm=32, accumulation="halo")
        b = ExecutionPlan(path="flat", tm=32,
                          accumulation="reduce_scatter", nrhs=8)
        c = ExecutionPlan(path="flat", tm=64, accumulation="halo")
        assert S.plan_artifact_fields(a) == S.plan_artifact_fields(b)
        assert S.plan_artifact_fields(a) != S.plan_artifact_fields(c)


# ---------------------------------------------------------------------------
# Tuner end to end
# ---------------------------------------------------------------------------

def _prefer_flat(calls):
    def measure(op, x):
        calls.append(op.plan.key())
        return 1.0 if op.plan.path == "flat" else 2.0
    return measure


class TestFlatTuning:
    def test_tune_selects_and_caches_flat(self):
        M = _skewed()
        cache = tuner.PlanCache()
        calls = []
        res = tuner.tune(M, cache=cache, measure=_prefer_flat(calls))
        assert res.plan.path == "flat"
        assert any(k.startswith("flat:") for k in res.timings_s)

        def boom(op, x):
            raise AssertionError("re-measured on a cache hit")
        res2 = tuner.tune(M, cache=cache, measure=boom)
        assert res2.cached and res2.plan == res.plan

    def test_tuned_schedule_reused_with_zero_packs(self):
        """tune() stores the winner's schedule next to the plan: operator
        construction afterwards rebuilds nothing."""
        M = _skewed(seed=9)
        cache = tuner.PlanCache()
        res = tuner.tune(M, cache=cache, measure=_prefer_flat([]))
        _, delta = _build_delta(
            lambda: ops.SpmvOperator.from_plan(M, res.plan, cache=cache))
        assert delta == {}, f"tuned-plan construction rebuilt: {delta}"

    def test_serving_engine_runs_flat_plan(self):
        from repro.serve.engine import SpmvServingEngine
        M = _skewed(seed=10)
        A = csrc.to_dense(M)
        cache = tuner.PlanCache()
        tuner.tune(M, cache=cache, measure=_prefer_flat([]))
        eng = SpmvServingEngine(cache=cache, autotune=True)
        plan = eng.register("skew", M)
        assert plan.path == "flat"
        rng = np.random.default_rng(3)
        xs = [rng.standard_normal(M.m).astype(np.float32)
              for _ in range(4)]
        uids = [eng.submit("skew", x) for x in xs]
        out = eng.run_until_drained()
        assert set(out) == set(uids)
        for uid, x in zip(uids, xs):
            np.testing.assert_allclose(out[uid], A @ x, rtol=2e-4,
                                       atol=2e-4)


# ---------------------------------------------------------------------------
# Distributed: shard-local flat execution (fast 1-shard mesh here; the
# 8-shard subprocess sweep lives in test_distributed_spmv.py)
# ---------------------------------------------------------------------------

class TestFlatDistributedSingleShard:
    @pytest.mark.parametrize("strategy", D.STRATEGIES)
    def test_all_strategies_match_dense(self, strategy):
        mesh = jax.make_mesh((1,), ("rows",))
        M = _skewed(192, 24, seed=2)
        A = csrc.to_dense(M)
        plan = ExecutionPlan(path="flat", tm=32)
        fn = D.build_sharded_spmv(M, mesh, "rows", strategy, plan=plan)
        rng = np.random.default_rng(0)
        x = rng.standard_normal(M.n).astype(np.float32)
        y = np.asarray(fn(jnp.asarray(x)))[:M.n]
        ref = A @ x
        np.testing.assert_allclose(y, ref, rtol=2e-4,
                                   atol=2e-4 * max(1, np.abs(ref).max()))
        X = rng.standard_normal((M.n, 3)).astype(np.float32)
        Y = np.asarray(fn(jnp.asarray(X)))[:M.n]
        refm = A @ X
        np.testing.assert_allclose(Y, refm, rtol=2e-4,
                                   atol=2e-4 * max(1, np.abs(refm).max()))

    def test_shard_layouts_are_memoized(self):
        """Repeated builder calls (serving restarts) are zero-precompute:
        the schedule comes from the cache, the per-shard flat layouts
        from their memos."""
        mesh = jax.make_mesh((1,), ("rows",))
        M = _skewed(160, 16, seed=3)
        plan = ExecutionPlan(path="flat", tm=32)
        cache = tuner.PlanCache()
        D.build_sharded_spmv(M, mesh, "rows", "allreduce", plan=plan,
                             cache=cache)
        D.build_sharded_spmv(M, mesh, "rows", "halo", plan=plan,
                             cache=cache)
        _, delta = _build_delta(lambda: (
            D.build_sharded_spmv(M, mesh, "rows", "allreduce", plan=plan,
                                 cache=cache),
            D.build_sharded_spmv(M, mesh, "rows", "halo", plan=plan,
                                 cache=cache)))
        assert delta == {}, f"repeated build re-ran precompute: {delta}"
