"""Observability spine: metric semantics, spans, exporters, the
BUILD_COUNTS shim, plan-cache provenance, and serving integration."""
import json
import time
import warnings

import numpy as np
import pytest

from repro import obs
from repro.obs.metrics import MetricsRegistry, Snapshot


# ---------------------------------------------------------------------------
# metric semantics
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_semantics():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", kind="a")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    # same label values -> same child; different -> new child
    assert reg.counter("reqs_total", kind="a") is c
    assert reg.counter("reqs_total", kind="b") is not c

    g = reg.gauge("depth")
    g.set(7)
    g.add(-2)
    assert g.value == 5.0

    h = reg.histogram("lat_seconds")
    for v in (1e-4, 1e-3, 1e-2):
        h.observe(v)
    s = h.sample()
    assert s["count"] == 3
    assert abs(s["sum"] - 0.0111) < 1e-9


def test_label_name_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("x_total", a="1")
    with pytest.raises(ValueError):
        reg.counter("x_total", b="1")          # different labelnames
    with pytest.raises(ValueError):
        reg.gauge("x_total", a="1")            # different kind


def test_label_cardinality_collapses_to_overflow():
    reg = MetricsRegistry()
    fam = reg.family("big_total", "counter", ("i",))
    for i in range(obs.MAX_CARDINALITY + 10):
        fam.labels(i=i).inc()
    assert len(fam.children) <= obs.MAX_CARDINALITY + 1
    over = fam.children.get((obs.OVERFLOW_LABEL,))
    assert over is not None and over.value >= 10


def test_quantile_accuracy_on_known_distribution():
    reg = MetricsRegistry()
    h = reg.histogram("q_seconds")
    rng = np.random.default_rng(0)
    vals = rng.uniform(0.01, 0.1, size=2000)
    for v in vals:
        h.observe(float(v))
    # 4 buckets/decade -> adjacent bounds differ by 10^0.25 ~ 1.78; the
    # geometric interpolation should land within one bucket ratio
    ratio = 10 ** 0.25
    for q in (0.5, 0.95, 0.99):
        est = h.quantile(q)
        true = float(np.quantile(vals, q))
        assert true / ratio <= est <= true * ratio, (q, est, true)


def test_disabled_flag_gates_mutations():
    reg = MetricsRegistry()
    c = reg.counter("gated_total")
    h = reg.histogram("gated_seconds")
    with obs.disabled():
        c.inc()
        h.observe(1.0)
        c.inc_always(3)                        # probes bypass the gate
    assert c.value == 3.0
    assert h.sample()["count"] == 0
    c.inc()
    assert c.value == 4.0


# ---------------------------------------------------------------------------
# spans / tracing
# ---------------------------------------------------------------------------

def test_span_nesting_and_trace():
    obs.clear_trace()
    with obs.span("outer", job="t"):
        with obs.span("inner"):
            time.sleep(0.001)
    entries = {e["name"]: e for e in obs.trace()}
    assert set(entries) >= {"outer", "inner"}
    assert entries["inner"]["depth"] == entries["outer"]["depth"] + 1
    assert entries["inner"]["parent"] == "outer"
    assert entries["outer"]["duration_s"] >= entries["inner"]["duration_s"]
    assert entries["outer"]["labels"] == {"job": "t"}
    assert entries["outer"]["ok"] and entries["inner"]["ok"]


def test_span_exception_safety():
    obs.clear_trace()
    with pytest.raises(RuntimeError):
        with obs.span("boom"):
            raise RuntimeError("kaput")
    (e,) = [t for t in obs.trace() if t["name"] == "boom"]
    assert e["ok"] is False and "kaput" in e["error"]
    # the stack unwound: a new span sits at depth 0 again
    with obs.span("after"):
        pass
    (a,) = [t for t in obs.trace() if t["name"] == "after"]
    assert a["depth"] == 0 and a["parent"] is None


def test_spans_disabled_are_noops():
    obs.clear_trace()
    with obs.disabled():
        with obs.span("ghost"):
            pass
    assert not [t for t in obs.trace() if t["name"] == "ghost"]


# ---------------------------------------------------------------------------
# snapshot / diff / exporters
# ---------------------------------------------------------------------------

def _loaded_registry():
    reg = MetricsRegistry()
    reg.counter("c_total", kind="x").inc(3)
    reg.gauge("g", path="kernel").set(0.5)
    h = reg.histogram("h_seconds", op="spmv")
    for v in (2e-4, 3e-3, 5e-2):
        h.observe(v)
    return reg


def test_snapshot_diff_semantics():
    reg = _loaded_registry()
    s0 = reg.snapshot()
    reg.counter("c_total", kind="x").inc(2)
    reg.gauge("g", path="kernel").set(0.9)
    reg.histogram("h_seconds", op="spmv").observe(1e-3)
    d = reg.snapshot().diff(s0)
    assert d.value("c_total", kind="x") == 2.0           # counters subtract
    assert d.value("g", path="kernel") == 0.9            # gauges keep new
    hd = d.hist("h_seconds", op="spmv")
    assert hd["count"] == 1 and abs(hd["sum"] - 1e-3) < 1e-12
    assert d.total("c_total") == 2.0


def test_json_export_round_trip():
    reg = _loaded_registry()
    snap2 = Snapshot.from_json(reg.to_json())
    assert snap2.value("c_total", kind="x") == 3.0
    assert snap2.value("g", path="kernel") == 0.5
    assert snap2.hist("h_seconds", op="spmv")["count"] == 3
    # a restored snapshot still diffs against a live one
    reg.counter("c_total", kind="x").inc()
    assert reg.snapshot().diff(snap2).value("c_total", kind="x") == 1.0


def test_prometheus_text_format():
    reg = _loaded_registry()
    text = reg.to_prometheus()
    assert 'c_total{kind="x"} 3' in text
    assert '# TYPE c_total counter' in text
    assert '# TYPE h_seconds histogram' in text
    assert 'h_seconds_count{op="spmv"} 3' in text
    # cumulative buckets end at +Inf with the full count
    inf_lines = [ln for ln in text.splitlines()
                 if ln.startswith("h_seconds_bucket") and '+Inf' in ln]
    assert inf_lines and inf_lines[0].endswith(" 3")
    # every sample line is "name{labels} value" with a parseable value
    for ln in text.splitlines():
        if not ln or ln.startswith("#"):
            continue
        float(ln.rsplit(" ", 1)[1])


def test_merged_hist_across_label_sets():
    reg = MetricsRegistry()
    reg.histogram("m_seconds", path="a").observe(1e-3)
    reg.histogram("m_seconds", path="b").observe(1e-3)
    m = reg.snapshot().merged_hist("m_seconds")
    assert m["count"] == 2
    assert 0 < m["p50"] < 1e-2


# ---------------------------------------------------------------------------
# BUILD_COUNTS shim
# ---------------------------------------------------------------------------

def test_build_counts_dict_compat():
    from repro.core import schedule as S
    before = dict(S.BUILD_COUNTS)
    S.BUILD_COUNTS.inc("test_obs_probe")
    S.BUILD_COUNTS.inc("test_obs_probe", 2)
    after = dict(S.BUILD_COUNTS)
    assert after["test_obs_probe"] - before.get("test_obs_probe", 0) == 3
    assert S.BUILD_COUNTS["never_touched_kind"] == 0      # missing -> 0
    assert "test_obs_probe" in S.BUILD_COUNTS
    assert set(after) == set(S.BUILD_COUNTS.keys())
    # the shim is a real obs counter family underneath
    assert obs.snapshot().value(
        "build_total", kind="test_obs_probe") == after["test_obs_probe"]


def test_build_counts_setitem_deprecated_but_works():
    from repro.core import schedule as S
    base = S.BUILD_COUNTS["legacy_probe"]
    with pytest.warns(DeprecationWarning):
        S.BUILD_COUNTS["legacy_probe"] = base + 5
    assert S.BUILD_COUNTS["legacy_probe"] == base + 5
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        S.BUILD_COUNTS["legacy_probe"] += 1
    assert S.BUILD_COUNTS["legacy_probe"] == base + 6


def test_build_counts_count_while_disabled():
    from repro.core import schedule as S
    base = S.BUILD_COUNTS["disabled_probe"]
    with obs.disabled():
        S.BUILD_COUNTS.inc("disabled_probe")
    assert S.BUILD_COUNTS["disabled_probe"] == base + 1


# ---------------------------------------------------------------------------
# plan-cache provenance
# ---------------------------------------------------------------------------

def _small_matrix():
    from repro.core import csrc
    return csrc.fem_band(300, 4, seed=0)


def test_plan_cache_entry_records_environment(tmp_path):
    from repro.core import tuner
    M = _small_matrix()
    cache = tuner.PlanCache(path=str(tmp_path / "plans.json"))
    res = tuner.tune(M, cache=cache, repeats=1)
    entry = cache.entries[res.fingerprint]
    env = entry["env"]
    for field in obs.MISMATCH_FIELDS + ("git_sha", "python"):
        assert field in env, field
    assert env["jax"] is not None
    # the recorded env matches the live process -> no mismatch counted
    assert not obs.env_mismatches(env)


def test_plan_cache_env_mismatch_counter(tmp_path):
    from repro.core import tuner
    M = _small_matrix()
    cache = tuner.PlanCache(path=str(tmp_path / "plans.json"))
    res = tuner.tune(M, cache=cache, repeats=1)
    entry = cache.entries[res.fingerprint]
    entry["env"] = dict(entry["env"], device_count=9999,
                        device_kind="tpu-v9000")
    s0 = obs.snapshot()
    assert cache.get(res.fingerprint) is not None
    d = obs.snapshot().diff(s0)
    assert d.value("plan_cache_env_mismatch_total",
                   field="device_count") == 1.0
    assert d.value("plan_cache_env_mismatch_total",
                   field="device_kind") == 1.0
    # git_sha never counts as a mismatch
    entry["env"] = dict(entry["env"], device_count=entry["env"][
        "device_count"], git_sha="0000000")
    assert d.total("plan_cache_env_mismatch_total", field="git_sha") == 0


# ---------------------------------------------------------------------------
# serving integration
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serving_setup():
    from repro.core import tuner
    from repro.serve import SpmvServingEngine
    M = _small_matrix()
    eng = SpmvServingEngine(cache=tuner.PlanCache())
    eng.register("obs_m", M)
    return eng, M


def test_serving_emits_request_metrics(serving_setup):
    eng, M = serving_setup
    rng = np.random.default_rng(0)
    s0 = obs.snapshot()
    for _ in range(4):
        eng.submit("obs_m", rng.standard_normal(M.m).astype(np.float32))
    out = eng.step()
    d = obs.snapshot().diff(s0)
    assert d.total("serve_requests_total", matrix_id="obs_m") == 4.0
    ex = d.merged_hist("serve_execute_seconds", matrix_id="obs_m")
    assert ex["count"] == 1 and ex["sum"] > 0          # one coalesced SpMM
    # the coalesced group carries its size as a label
    (labels, _) = d.find("serve_execute_seconds", matrix_id="obs_m")[0]
    assert labels["nrhs"] == "4"
    qs = d.merged_hist("serve_queue_wait_seconds", matrix_id="obs_m")
    assert qs["count"] == 4
    assert d.merged_hist("serve_batch_size")["count"] == 1
    assert d.merged_hist("serve_tick_seconds")["count"] == 1
    # per-request timings ride on the result
    r = next(iter(out.values()))
    assert r.timings is not None
    assert r.timings["execute_s"] > 0
    assert r.timings["queue_wait_s"] >= 0
    assert "timings" in r.meta()


def test_serving_hot_path_overhead(serving_setup):
    """Metrics off vs on around the same serving ticks: the instrumented
    path must stay within a generous factor (the real budget is <2%; jax
    dispatch noise dominates, so the assertion is deliberately loose)."""
    eng, M = serving_setup
    rng = np.random.default_rng(1)
    xs = [rng.standard_normal(M.m).astype(np.float32) for _ in range(4)]

    def ticks(n):
        t0 = time.perf_counter()
        for _ in range(n):
            for x in xs:
                eng.submit("obs_m", x)
            eng.step()
        return time.perf_counter() - t0

    ticks(3)                                   # warm both code paths
    with obs.disabled():
        t_off = min(ticks(5) for _ in range(3))
    t_on = min(ticks(5) for _ in range(3))
    assert t_on <= t_off * 2.0 + 0.05, (t_on, t_off)


def test_repro_metrics_env_prints_prometheus(tmp_path):
    """REPRO_METRICS=1 makes any process dump Prometheus text at exit."""
    import subprocess
    import sys
    import os
    code = (
        "from repro import obs\n"
        "obs.counter('smoke_total', job='env').inc(2)\n"
    )
    env = dict(os.environ, REPRO_METRICS="1",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.getcwd(), "src"),
                    os.environ.get("PYTHONPATH", "")]))
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env,
                         timeout=120)
    assert out.returncode == 0, out.stderr
    assert 'smoke_total{job="env"} 2' in out.stdout
    assert "# TYPE smoke_total counter" in out.stdout
