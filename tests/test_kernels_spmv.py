"""Pallas kernel allclose sweeps against the pure-jnp oracle (interpret
mode), as required per kernel: shapes × dtypes × tile sizes + hypothesis."""
import numpy as np
import jax.numpy as jnp
import pytest
from _propshim import given, settings, st

from repro.core import csrc, blockell
from repro.kernels import ref, ops
from repro.kernels.csrc_spmv import blockell_spmv, blockell_spmv_windows


def _check(M, tm=16, k_step=1024, rtol=2e-4):
    A = csrc.to_dense(M)
    x = np.random.default_rng(7).standard_normal(M.n).astype(np.float32)
    pack = blockell.pack(M, tm=tm, k_step=k_step)
    y_k = np.asarray(blockell_spmv(pack, jnp.asarray(x), interpret=True))
    y_ref = np.asarray(ref.csrc_spmv(M, jnp.asarray(x),
                                     use_numeric_symmetry=False))
    y_dense = A @ x
    scale = max(1.0, np.abs(y_dense).max())
    np.testing.assert_allclose(y_k / scale, y_dense / scale,
                               rtol=rtol, atol=rtol)
    np.testing.assert_allclose(y_k / scale, np.asarray(y_ref) / scale,
                               rtol=rtol, atol=rtol)
    return pack


@pytest.mark.parametrize("n,band,tm", [
    (64, 3, 8), (100, 9, 8), (256, 17, 16),
    pytest.param(300, 40, 16, marks=pytest.mark.slow),
    pytest.param(512, 50, 64, marks=pytest.mark.slow),
    pytest.param(1000, 100, 128, marks=pytest.mark.slow),
    (130, 5, 128),   # n < tm*2 edge
])
def test_kernel_shape_sweep(n, band, tm):
    M = csrc.fem_band(n, band, seed=n + band)
    _check(M, tm=tm)


@pytest.mark.parametrize("sym", [False, True])
def test_kernel_symmetry_modes(sym):
    """Numerically symmetric packs stream al only (paper's one-fewer-load);
    both modes must agree with dense."""
    M = csrc.fem_band(200, 12, seed=5, numeric_symmetric=sym)
    pack = _check(M, tm=16)
    assert pack.num_symmetric == sym


def test_kernel_poisson():
    _check(csrc.poisson2d(20), tm=32)


def test_kernel_multi_ktile():
    """Force several k-steps per row tile (grid dim 2 > 1) to exercise the
    revisited-output accumulation."""
    M = csrc.fem_band(256, 60, seed=9, fill=0.95)
    pack = blockell.pack(M, tm=64, k_step=1024)
    assert pack.s // 1024 > 1
    _check(M, tm=64)


def test_pack_rejects_unbanded():
    M = csrc.random_symmetric_pattern(512, 6, seed=1)
    with pytest.raises(ValueError):
        blockell.pack(M, tm=16, w_cap=256)


def test_operator_auto_fallback():
    """SpmvOperator falls back to segment-sum for unbanded matrices (the
    paper's cage15/F1 case) and still matches dense."""
    M = csrc.random_symmetric_pattern(300, 5, seed=2)
    op = ops.SpmvOperator(M, path="auto", w_cap=256)
    assert op.path == "segment"
    A = csrc.to_dense(M)
    x = np.random.default_rng(1).standard_normal(M.n).astype(np.float32)
    np.testing.assert_allclose(np.asarray(op(jnp.asarray(x))), A @ x,
                               rtol=2e-4, atol=2e-4)


def test_windows_before_accumulation():
    """The kernel's per-tile windows must sum (overlap-add) to the product —
    the two-phase structure mirrors the paper's compute/accumulate split."""
    M = csrc.fem_band(128, 10, seed=3)
    pack = blockell.pack(M, tm=16)
    x = np.random.default_rng(2).standard_normal(M.n).astype(np.float32)
    wins = blockell_spmv_windows(pack, jnp.asarray(x), interpret=True)
    assert wins.shape == (pack.nt, pack.w_pad)
    y = blockell.overlap_add(pack, wins)
    np.testing.assert_allclose(np.asarray(y), csrc.to_dense(M) @ x,
                               rtol=2e-4, atol=2e-4)


def test_transpose_product():
    M = csrc.fem_band(80, 6, seed=4)
    A = csrc.to_dense(M)
    x = np.random.default_rng(3).standard_normal(80).astype(np.float32)
    y = np.asarray(ops.spmv_transpose(M, jnp.asarray(x)))
    np.testing.assert_allclose(y, A.T @ x, rtol=1e-4, atol=1e-4)


def test_spmm_multi_rhs():
    M = csrc.fem_band(64, 5, seed=6)
    A = csrc.to_dense(M)
    X = np.random.default_rng(4).standard_normal((64, 7)).astype(np.float32)
    Y = np.asarray(ops.spmm(M, jnp.asarray(X)))
    np.testing.assert_allclose(Y, A @ X, rtol=1e-4, atol=1e-4)


@settings(max_examples=5, deadline=None)
@given(st.integers(16, 120), st.integers(1, 12), st.integers(0, 10_000),
       st.booleans())
def test_property_kernel_matches_dense(n, band, seed, sym):
    M = csrc.fem_band(n, min(band, n - 1), seed=seed,
                      numeric_symmetric=sym)
    _check(M, tm=8)


@pytest.mark.parametrize("nrhs", [1, 4, 8])
def test_spmm_kernel_matches_dense(nrhs):
    """Multi-RHS Pallas kernel vs dense, across RHS widths."""
    from repro.kernels.csrc_spmm import blockell_spmm
    M = csrc.fem_band(200, 12, seed=11)
    pack = blockell.pack(M, tm=16)
    A = csrc.to_dense(M)
    X = np.random.default_rng(5).standard_normal((200, nrhs)).astype(
        np.float32)
    Y = np.asarray(blockell_spmm(pack, jnp.asarray(X), interpret=True))
    ref_y = A @ X
    scale = max(1.0, np.abs(ref_y).max())
    np.testing.assert_allclose(Y / scale, ref_y / scale, rtol=2e-4,
                               atol=2e-4)


def test_spmm_kernel_symmetric_stream():
    from repro.kernels.csrc_spmm import blockell_spmm
    M = csrc.fem_band(128, 8, seed=12, numeric_symmetric=True)
    pack = blockell.pack(M, tm=16)
    A = csrc.to_dense(M)
    X = np.random.default_rng(6).standard_normal((128, 3)).astype(np.float32)
    Y = np.asarray(blockell_spmm(pack, jnp.asarray(X), interpret=True))
    np.testing.assert_allclose(Y, A @ X, rtol=2e-4, atol=2e-4)


def test_int16_index_pack():
    """16-bit local indices (paper §1 index-compression lever): halves the
    index stream, bit-identical results."""
    M = csrc.fem_band(300, 20, seed=13)
    p32 = blockell.pack(M, tm=16)
    p16 = blockell.pack(M, tm=16, index_dtype=jnp.int16)
    assert p16.col_local.dtype == jnp.int16
    assert p16.streamed_bytes() < p32.streamed_bytes()
    x = np.random.default_rng(8).standard_normal(300).astype(np.float32)
    y32 = np.asarray(blockell_spmv(p32, jnp.asarray(x), interpret=True))
    y16 = np.asarray(blockell_spmv(p16, jnp.asarray(x), interpret=True))
    np.testing.assert_array_equal(y32, y16)


class TestFlatKernel:
    """Flattened 1-D grid kernel (scalar-prefetched tile ids): removes
    cross-tile ELL padding; allclose vs dense across shapes."""

    @pytest.mark.parametrize("n,band,tm", [
        (128, 5, 16), (300, 20, 16), (512, 40, 64),
    ])
    def test_matches_dense(self, n, band, tm):
        from repro.kernels.csrc_spmv_flat import pack_flat, flat_spmv
        M = csrc.fem_band(n, band, seed=n)
        A = csrc.to_dense(M)
        x = np.random.default_rng(0).standard_normal(n).astype(np.float32)
        pack = pack_flat(M, tm=tm)
        y = np.asarray(flat_spmv(pack, jnp.asarray(x), interpret=True))
        ref_y = A @ x
        scale = max(1.0, np.abs(ref_y).max())
        np.testing.assert_allclose(y / scale, ref_y / scale,
                                   rtol=2e-4, atol=2e-4)

    def test_beats_rect_padding_on_skew(self):
        """Skew strong enough that the densest tile needs several k-steps:
        the rectangular grid pads every tile to it, the flat grid
        doesn't."""
        from repro.kernels.csrc_spmv_flat import pack_flat, flat_spmv
        rows, cols, vals = [], [], []
        n = 1024
        rng = np.random.default_rng(1)
        for i in range(n):
            rows.append(i); cols.append(i); vals.append(50.0)
            width = 60 if i < 64 else 3
            for j in range(max(0, i - width), i):
                vl, vu = rng.standard_normal(2)
                rows += [i, j]; cols += [j, i]; vals += [vl, vu]
        M = csrc.from_coo(np.array(rows), np.array(cols),
                          np.array(vals, np.float64), n=n,
                          pad_pattern=False)
        rect = blockell.pack(M, tm=64, k_step=1024)
        flat = pack_flat(M, tm=64)
        assert flat.pad_ratio < rect.pad_ratio
        assert flat.streamed_bytes() < rect.streamed_bytes()
        # and it stays correct on the same matrix
        x = np.random.default_rng(2).standard_normal(n).astype(np.float32)
        y = np.asarray(flat_spmv(flat, jnp.asarray(x), interpret=True))
        ref_y = csrc.to_dense(M) @ x
        scale = max(1.0, np.abs(ref_y).max())
        np.testing.assert_allclose(y / scale, ref_y / scale,
                                   rtol=2e-4, atol=2e-4)


def test_bf16_value_stream():
    """Mixed-precision lever: bf16 values (fp32 accumulation) halve the
    value stream; accuracy within bf16 tolerance."""
    M = csrc.fem_band(256, 16, seed=21)
    A = csrc.to_dense(M)
    x = np.random.default_rng(9).standard_normal(256).astype(np.float32)
    pack = blockell.pack(M, tm=16, dtype=jnp.bfloat16,
                         index_dtype=jnp.int16)
    p32 = blockell.pack(M, tm=16)
    assert pack.streamed_bytes() < p32.streamed_bytes()
    y = np.asarray(blockell_spmv(pack, jnp.asarray(x), interpret=True))
    ref_y = A @ x
    scale = max(1.0, np.abs(ref_y).max())
    np.testing.assert_allclose(y / scale, ref_y / scale, atol=3e-2)
