import os
import sys

# allow plain `pytest tests/` without PYTHONPATH=src
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# make `from _propshim import ...` work regardless of pytest import mode
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

# NOTE: deliberately NOT setting XLA_FLAGS here — smoke tests and benches
# must see 1 device; only launch/dryrun.py forces 512 placeholder devices,
# and multi-device tests spawn subprocesses with their own XLA_FLAGS.
