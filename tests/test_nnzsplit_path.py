"""The nnz-split kernel as a first-class KernelPath: registry dispatch,
tuner enumeration (unstructured-gated, feasibility-filtered), schedule
artifacts with cache/disk round-trips and zero-rebuild probes, bit-exact
multi-RHS execution vs the dense oracle under dyadic values, shard-local
nnz-split execution in every distributed strategy, and the serving engine
running a tuned nnzsplit plan.

Bit-identity discipline: the unstructured suite matrices carry small-
integer values (powerlaw_laplacian, paper_example) or are quantized to
dyadic values, and x is drawn from multiples of 1/8 — float32
accumulation of the products is then order-independent, so the chunked
kernel must match the dense oracle **bit for bit**; a dropped or
double-counted stream entry is always visible.
"""
import dataclasses
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import csrc, distributed as D, paths, schedule as S, tuner
from repro.core.plan import PATHS, ExecutionPlan, feasible
from repro.kernels import ops
from repro.kernels.csrc_spmv_nnzsplit import NnzSplitPack, pack_nnzsplit


def _unstructured(n=300, seed=0, **kw):
    return csrc.powerlaw_laplacian(n, seed=seed, **kw)


def _dyadic(M):
    def q(a):
        return jnp.asarray(np.round(np.asarray(a) * 64.0) / 64.0,
                           jnp.float32)
    return dataclasses.replace(M, ad=q(M.ad), al=q(M.al), au=q(M.au))


def _dyadic_x(m, seed=0, nrhs=None):
    rng = np.random.default_rng(seed)
    shape = (m,) if nrhs is None else (m, nrhs)
    return (rng.integers(-64, 64, shape) / 8.0).astype(np.float32)


def _check_exact(M, plan, nrhs=None, seed=11):
    """Dyadic bit-identity against the dense oracle (no tolerances)."""
    A = np.asarray(csrc.to_dense(M), np.float64)
    x = _dyadic_x(M.m, seed=seed, nrhs=nrhs)
    op = ops.SpmvOperator.from_plan(M, plan)
    assert op.plan.path == plan.path          # strict: no silent fallback
    y = np.asarray(op(jnp.asarray(x)))
    ref = (A @ x.astype(np.float64)).astype(np.float32)
    np.testing.assert_array_equal(y, ref, err_msg=f"plan {plan.key()}")
    return op


def _build_delta(fn):
    before = dict(S.BUILD_COUNTS)
    out = fn()
    after = dict(S.BUILD_COUNTS)
    return out, {k: after.get(k, 0) - before.get(k, 0)
                 for k in set(after) | set(before)
                 if after.get(k, 0) != before.get(k, 0)}


STRUCTURAL_KEYS = ("pack", "flat_pack", "nnzsplit_pack", "partition",
                   "coloring", "schedule", "sharded_slots", "halo_layout",
                   "flat_shards", "flat_halo", "nnzsplit_shards",
                   "nnzsplit_halo")


# ---------------------------------------------------------------------------
# Registry + plan layer
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_nnzsplit_is_a_registered_path(self):
        assert "nnzsplit" in PATHS
        entry = paths.get_path("nnzsplit")
        assert entry.name == "nnzsplit"
        plan = ExecutionPlan(path="nnzsplit", k_step_sublanes=4)
        assert plan.key().startswith("nnzsplit:ks4")

    def test_plan_key_is_tm_independent(self):
        """Chunking is row-independent: tm is not a degree of freedom."""
        a = ExecutionPlan(path="nnzsplit", tm=32, k_step_sublanes=4)
        b = ExecutionPlan(path="nnzsplit", tm=128, k_step_sublanes=4)
        assert a.key() == b.key()
        assert S.plan_artifact_fields(a) == S.plan_artifact_fields(b)

    def test_square_only_and_int16_gate(self):
        plan = ExecutionPlan(path="nnzsplit")
        assert feasible(plan, n=64, m=64, bandwidth=10)
        assert not feasible(plan, n=64, m=96, bandwidth=10)
        i16 = ExecutionPlan(path="nnzsplit", index_dtype="int16")
        assert feasible(i16, n=32767, m=32767, bandwidth=10)
        assert not feasible(i16, n=32768, m=32768, bandwidth=10)

    def test_shard_support_registered(self):
        """The tentpole claim: mesh serving needs no per-path edits — the
        registry entry itself carries the shard-compute hooks."""
        sup = paths.get_path("nnzsplit").shard_support
        assert sup is not None
        assert sup.shards_kind == "nnzsplit_shards"
        assert sup.halo_kind == "nnzsplit_halo"


class TestEnumeration:
    def test_emitted_on_unstructured_matrices(self):
        M = _unstructured()
        stats = tuner.stats_of(M)
        assert paths.nnzsplit_worth_measuring(stats), "not unstructured?"
        plans = tuner.enumerate_plans(stats)
        cand = [p for p in plans if p.path == "nnzsplit"]
        assert cand, [p.key() for p in plans]
        assert len({p.k_step_sublanes for p in cand}) > 1  # ks sweep
        for p in cand:
            assert feasible(p, n=M.n, m=M.m, bandwidth=stats.bandwidth)

    def test_skipped_on_banded_low_skew_matrices(self):
        """poisson2d and the skewed band (CoV ~1.5, narrow band) stay with
        the windowed paths — nnzsplit's gate is deliberately above flat's
        skew floor."""
        for M in (csrc.poisson2d(16), csrc.skewed_band(256, 48, 3, seed=1)):
            stats = tuner.stats_of(M)
            assert not paths.nnzsplit_worth_measuring(stats)
            assert not any(p.path == "nnzsplit"
                           for p in tuner.enumerate_plans(stats))

    def test_rectangular_matrix_yields_no_nnzsplit(self):
        M = csrc.rectangular_fem(48, 16, 4, seed=5)
        plans = tuner.enumerate_plans(tuner.stats_of(M))
        assert all(p.path == "segment" for p in plans)
        with pytest.raises(ValueError):
            ops.SpmvOperator.from_plan(M, ExecutionPlan(path="nnzsplit"))

    def test_r_cap_gate_raises_in_packer(self):
        """A stream whose chunks span row windows beyond r_cap belongs to
        the banded paths; the packer refuses instead of padding."""
        M = _unstructured(600, seed=2)
        with pytest.raises(ValueError, match="row window"):
            pack_nnzsplit(M, ks=8, r_cap=128)


# ---------------------------------------------------------------------------
# Execution vs the dense oracle (bit-exact, single- and multi-RHS)
# ---------------------------------------------------------------------------

class TestNnzSplitExecution:
    @pytest.mark.parametrize("nrhs", [None, 3, 8])
    def test_powerlaw_bit_identical_across_rhs_widths(self, nrhs):
        M = _unstructured(seed=3)
        _check_exact(M, ExecutionPlan(path="nnzsplit", k_step_sublanes=2),
                     nrhs=nrhs)

    def test_paper_example(self):
        _check_exact(csrc.paper_example(),
                     ExecutionPlan(path="nnzsplit", k_step_sublanes=2))

    @pytest.mark.parametrize("ks", [2, 8])
    def test_chunk_size_sweep(self, ks):
        M = _dyadic(csrc.random_symmetric_pattern(220, 5, seed=4))
        _check_exact(M, ExecutionPlan(path="nnzsplit", k_step_sublanes=ks))

    def test_int16_indices(self):
        M = _unstructured(260, seed=5)
        op = _check_exact(
            M, ExecutionPlan(path="nnzsplit", k_step_sublanes=2,
                             index_dtype="int16"))
        assert op.pack.src.dtype == jnp.int16

    def test_diag_only(self):
        n = 17
        i = np.arange(n)
        M = csrc.from_coo(i, i, np.arange(1.0, n + 1.0), n=n)
        _check_exact(M, ExecutionPlan(path="nnzsplit", k_step_sublanes=2))

    def test_n1(self):
        M = csrc.from_dense(np.array([[3.0]]))
        _check_exact(M, ExecutionPlan(path="nnzsplit"))

    def test_empty_rows(self):
        i = np.arange(0, 20, 2)
        M = csrc.from_coo(i, i, np.ones(i.size), n=20)
        _check_exact(M, ExecutionPlan(path="nnzsplit", k_step_sublanes=2))

    def test_value_refresh_zero_structural_rebuild(self):
        M = _unstructured(seed=6)
        op = ops.SpmvOperator.from_plan(
            M, ExecutionPlan(path="nnzsplit", k_step_sublanes=2))
        M2 = dataclasses.replace(M, ad=M.ad * 2, al=M.al * 2, au=M.au * 2)
        _, d = _build_delta(lambda: op.update_values(M2))
        assert d == {"value_refresh": 1}, d
        x = _dyadic_x(M.m, seed=1)
        ref = (np.asarray(csrc.to_dense(M2), np.float64)
               @ x.astype(np.float64)).astype(np.float32)
        np.testing.assert_array_equal(np.asarray(op(jnp.asarray(x))), ref)

    def test_streamed_bytes_reported(self):
        M = _unstructured(seed=7)
        op = ops.SpmvOperator.from_plan(
            M, ExecutionPlan(path="nnzsplit", k_step_sublanes=2))
        assert isinstance(op.pack, NnzSplitPack)
        assert op.bytes_per_call == op.pack.streamed_bytes() > 0


# ---------------------------------------------------------------------------
# Schedule artifacts: cache, disk round-trip, zero-rebuild probes
# ---------------------------------------------------------------------------

class TestNnzSplitSchedule:
    def test_schedule_bundles_nnzsplit_pack_only(self):
        M = _unstructured(seed=8)
        sched = S.build_schedule(
            M, ExecutionPlan(path="nnzsplit", k_step_sublanes=2))
        assert sched.nnzsplit_pack is not None
        assert sched.pack is None and sched.flat_pack is None
        assert sched.coloring is None
        assert sched.partition.starts[-1] == M.n

    def test_cache_hit_rebuilds_zero_packs(self):
        """The acceptance probe: a second operator construction through
        the cache performs zero nnzsplit packs and is bit-identical."""
        M = _unstructured(seed=9)
        x = jnp.asarray(_dyadic_x(M.m, seed=2))
        cache = tuner.PlanCache()
        plan = ExecutionPlan(path="nnzsplit", k_step_sublanes=2)
        op1, d1 = _build_delta(
            lambda: ops.SpmvOperator.from_plan(M, plan, cache=cache))
        assert d1.get("nnzsplit_pack") == 1 and d1.get("schedule") == 1
        op2, d2 = _build_delta(
            lambda: ops.SpmvOperator.from_plan(M, plan, cache=cache))
        assert d2 == {}, f"cache hit rebuilt: {d2}"
        assert cache.schedule_hits == 1
        np.testing.assert_array_equal(np.asarray(op1(x)),
                                      np.asarray(op2(x)))

    def test_disk_roundtrip_bit_identical(self, tmp_path):
        M = _unstructured(seed=10)
        plan = ExecutionPlan(path="nnzsplit", k_step_sublanes=2)
        sched = S.build_schedule(M, plan)
        f = os.path.join(tmp_path, "nnzsplit.npz")
        sched.save_npz(f)
        loaded = S.SpmvSchedule.load_npz(f)
        assert loaded.plan == plan
        pk0, pk1 = sched.nnzsplit_pack, loaded.nnzsplit_pack
        assert (pk0.num_chunks, pk0.ks, pk0.r_pad) == \
               (pk1.num_chunks, pk1.ks, pk1.r_pad)
        x = jnp.asarray(_dyadic_x(M.m, seed=3))
        y0 = np.asarray(ops.SpmvOperator.from_plan(M, plan,
                                                   schedule=sched)(x))
        y1 = np.asarray(ops.SpmvOperator.from_plan(M, plan,
                                                   schedule=loaded)(x))
        np.testing.assert_array_equal(y0, y1)

    def test_disk_cache_hit_rebuilds_nothing(self, tmp_path):
        """Cold process simulation: a fresh PlanCache over the same file
        loads the nnzsplit schedule from npz — zero packs."""
        path = os.path.join(tmp_path, "plans.json")
        M = _unstructured(seed=11)
        plan = ExecutionPlan(path="nnzsplit", k_step_sublanes=2)
        cache1 = tuner.PlanCache(path=path)
        ops.SpmvOperator.from_plan(M, plan, cache=cache1)
        cache2 = tuner.PlanCache(path=path)       # fresh memory
        _, delta = _build_delta(
            lambda: ops.SpmvOperator.from_plan(M, plan, cache=cache2))
        assert delta == {}, f"disk hit rebuilt: {delta}"
        assert cache2.schedule_hits == 1


# ---------------------------------------------------------------------------
# Tuner end to end
# ---------------------------------------------------------------------------

def _prefer_nnzsplit(calls):
    def measure(op, x):
        calls.append(op.plan.key())
        return 1.0 if op.plan.path == "nnzsplit" else 2.0
    return measure


class TestNnzSplitTuning:
    def test_tune_selects_and_caches_nnzsplit(self):
        M = _unstructured(seed=12)
        cache = tuner.PlanCache()
        calls = []
        res = tuner.tune(M, cache=cache, measure=_prefer_nnzsplit(calls))
        assert res.plan.path == "nnzsplit"
        assert any(k.startswith("nnzsplit:") for k in res.timings_s)

        def boom(op, x):
            raise AssertionError("re-measured on a cache hit")
        res2 = tuner.tune(M, cache=cache, measure=boom)
        assert res2.cached and res2.plan == res.plan

    def test_tuned_schedule_reused_with_zero_packs(self):
        M = _unstructured(seed=13)
        cache = tuner.PlanCache()
        res = tuner.tune(M, cache=cache, measure=_prefer_nnzsplit([]))
        _, delta = _build_delta(
            lambda: ops.SpmvOperator.from_plan(M, res.plan, cache=cache))
        assert delta == {}, f"tuned-plan construction rebuilt: {delta}"

    def test_serving_engine_runs_nnzsplit_plan_bit_identical(self):
        from repro.serve.engine import SpmvServingEngine
        M = _unstructured(seed=14)
        A = np.asarray(csrc.to_dense(M), np.float64)
        cache = tuner.PlanCache()
        tuner.tune(M, cache=cache, measure=_prefer_nnzsplit([]))
        eng = SpmvServingEngine(cache=cache, autotune=True)
        plan = eng.register("unstructured", M)
        assert plan.path == "nnzsplit"
        xs = [_dyadic_x(M.m, seed=i) for i in range(4)]
        uids = [eng.submit("unstructured", x) for x in xs]
        out = eng.run_until_drained()
        assert set(out) == set(uids)
        for uid, x in zip(uids, xs):
            assert out[uid].path == "nnzsplit"
            np.testing.assert_array_equal(
                np.asarray(out[uid]),
                (A @ x.astype(np.float64)).astype(np.float32))


# ---------------------------------------------------------------------------
# Distributed: shard-local nnz-split execution (fast 1-shard mesh here;
# the 8-shard subprocess sweep lives in test_distributed_spmv.py)
# ---------------------------------------------------------------------------

class TestNnzSplitDistributedSingleShard:
    @pytest.mark.parametrize("strategy", D.STRATEGIES)
    def test_all_strategies_bit_identical_to_dense(self, strategy):
        mesh = jax.make_mesh((1,), ("rows",))
        M = _unstructured(seed=15)
        A = np.asarray(csrc.to_dense(M), np.float64)
        plan = ExecutionPlan(path="nnzsplit", k_step_sublanes=2)
        fn = D.build_sharded_spmv(M, mesh, "rows", strategy, plan=plan)
        x = _dyadic_x(M.n, seed=4)
        y = np.asarray(fn(jnp.asarray(x)))[:M.n]
        np.testing.assert_array_equal(
            y, (A @ x.astype(np.float64)).astype(np.float32))
        X = _dyadic_x(M.n, seed=5, nrhs=3)
        Y = np.asarray(fn(jnp.asarray(X)))[:M.n]
        np.testing.assert_array_equal(
            Y, (A @ X.astype(np.float64)).astype(np.float32))

    def test_shard_layouts_are_memoized(self):
        mesh = jax.make_mesh((1,), ("rows",))
        M = _unstructured(seed=16)
        plan = ExecutionPlan(path="nnzsplit", k_step_sublanes=2)
        cache = tuner.PlanCache()
        D.build_sharded_spmv(M, mesh, "rows", "allreduce", plan=plan,
                             cache=cache)
        D.build_sharded_spmv(M, mesh, "rows", "halo", plan=plan,
                             cache=cache)
        _, delta = _build_delta(lambda: (
            D.build_sharded_spmv(M, mesh, "rows", "allreduce", plan=plan,
                                 cache=cache),
            D.build_sharded_spmv(M, mesh, "rows", "halo", plan=plan,
                                 cache=cache)))
        assert delta == {}, f"repeated build re-ran precompute: {delta}"

    @pytest.mark.parametrize("acc", ["allreduce", "reduce_scatter", "halo"])
    def test_mesh_executor_bit_identical_to_local_p1(self, acc):
        from repro.serve import LocalExecutor, MeshExecutor
        M = _unstructured(seed=17)
        lplan = ExecutionPlan(path="nnzsplit", k_step_sublanes=2)
        local = LocalExecutor(M, lplan)
        mesh = MeshExecutor(M, dataclasses.replace(
            lplan, strategy="mesh", mesh_p=1, accumulation=acc))
        for nrhs in (None, 3, 8):
            x = jnp.asarray(_dyadic_x(M.m, seed=nrhs or 1, nrhs=nrhs))
            np.testing.assert_array_equal(np.asarray(local(x)),
                                          np.asarray(mesh(x)))

    @pytest.mark.parametrize("acc", ["allreduce", "halo"])
    def test_mesh_value_refresh_p1(self, acc):
        from repro.serve import MeshExecutor
        M = _unstructured(seed=18)
        ex = MeshExecutor(M, ExecutionPlan(
            path="nnzsplit", k_step_sublanes=2, strategy="mesh", mesh_p=1,
            accumulation=acc))
        M2 = dataclasses.replace(M, ad=M.ad * 2, al=M.al * 2, au=M.au * 2)
        _, d = _build_delta(lambda: ex.update_values(M2))
        assert d.get("shard_value_refresh") == 1, d
        assert not any(d.get(k) for k in STRUCTURAL_KEYS), d
        x = _dyadic_x(M.m, seed=6)
        np.testing.assert_array_equal(
            np.asarray(ex(jnp.asarray(x))),
            (np.asarray(csrc.to_dense(M2), np.float64)
             @ x.astype(np.float64)).astype(np.float32))


# ---------------------------------------------------------------------------
# from_scipy quickstart path
# ---------------------------------------------------------------------------

class TestFromScipy:
    def test_from_scipy_roundtrip(self):
        sp = pytest.importorskip("scipy.sparse")
        rng = np.random.default_rng(0)
        A = sp.random(60, 60, density=0.08, random_state=0,
                      data_rvs=lambda k: rng.integers(-8, 8, k) / 4.0)
        A = (A + A.T).tocsr()                    # structurally symmetric
        A.setdiag(np.arange(1.0, 61.0))
        M = csrc.CSRC.from_scipy(A)
        np.testing.assert_array_equal(np.asarray(csrc.to_dense(M)),
                                      A.toarray().astype(np.float32))
        x = _dyadic_x(60, seed=7)
        y = np.asarray(ops.SpmvOperator.from_plan(
            M, ExecutionPlan(path="nnzsplit", k_step_sublanes=2))(
                jnp.asarray(x)))
        ref = (A.toarray().astype(np.float64)
               @ x.astype(np.float64)).astype(np.float32)
        np.testing.assert_array_equal(y, ref)
