"""The FEM assembly subsystem (repro.assembly): conflict-free CSRC
construction feeding the SpMV stack.

Covers: mesh generator invariants, element-coloring conflict-freeness,
bit-for-bit agreement of the colored / private-buffer strategies with the
serial oracle (the dyadic stiffness synthesis makes float32 accumulation
order-independent, so equality is exact — any race or dropped
contribution fails hard), AssemblySchedule cache/disk round-trips with
zero-rebuild probes, assembled matrices through the SpMV dense oracle for
nrhs in {1, 3, 8}, and the end-to-end assemble → tune → solve pipeline
including the value-refresh fast path for time stepping."""
import os

import numpy as np
import jax.numpy as jnp
import pytest

from _propshim import given, settings, st
from repro.assembly import mesh as amesh
from repro.assembly import (assemble, assemble_mesh, assembly_schedule_for,
                            build_assembly_schedule, color_elements,
                            element_dofs, scatter_colored, scatter_private,
                            scatter_serial, scatter_sorted,
                            verify_element_coloring)
from repro.assembly import scatter as scatter_mod
from repro.core import csrc, schedule as S, tuner
from repro.core.plan import ExecutionPlan
from repro.core.solvers import cg_solve
from repro.kernels import ops


def _build_delta(fn):
    """Run fn and return (result, builds-that-happened) from the probe."""
    before = dict(S.BUILD_COUNTS)
    out = fn()
    after = dict(S.BUILD_COUNTS)
    delta = {k: after.get(k, 0) - before.get(k, 0)
             for k in set(after) | set(before)}
    return out, {k: v for k, v in delta.items() if v}


MESHES = [
    ("tri", lambda: amesh.grid_tri(5)),
    ("quad", lambda: amesh.grid_quad(4)),
    ("tet", lambda: amesh.grid_tet(2)),
]
MESH_IDS = [n for n, _ in MESHES]


def _dense_oracle(mesh, ke, ndof_per_node=1):
    """Independent dense assembly: float64 loop over elements — shares no
    code with the scatter strategies."""
    ed = element_dofs(mesh.conn, ndof_per_node)
    n = mesh.num_nodes * ndof_per_node
    A = np.zeros((n, n), np.float64)
    for e in range(mesh.ne):
        dofs = ed[e]
        A[np.ix_(dofs, dofs)] += np.asarray(ke[e], np.float64)
    return A


# ---------------------------------------------------------------------------
# Mesh generators and stiffness synthesis
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,make", MESHES, ids=MESH_IDS)
def test_mesh_generators_wellformed(name, make):
    mesh = make()
    assert mesh.conn.min() >= 0
    assert mesh.conn.max() < mesh.num_nodes
    assert mesh.coords.shape == (mesh.num_nodes, mesh.dim)
    # every element's nodes are distinct
    for e in range(mesh.ne):
        assert len(set(mesh.conn[e].tolist())) == mesh.nen
    vols = amesh.element_volumes(mesh)
    assert (vols > 0).all(), f"{name}: non-positive element volume"


def test_tet_mesh_covers_the_cube():
    """Kuhn triangulation: 6 tets per cube, volumes sum to the domain."""
    mesh = amesh.grid_tet(2)
    assert mesh.ne == 6 * 2 * 2 * 2
    assert amesh.element_volumes(mesh).sum() == pytest.approx(8.0)


def test_stiffness_is_dyadic_and_symmetric():
    """The synthesis contract: entries are multiples of 1/64 (exact in
    float32, order-independent accumulation) and element-symmetric."""
    for name, make in MESHES:
        mesh = make()
        for ke in (amesh.poisson_stiffness(mesh, mass=0.5),
                   amesh.synthetic_stiffness(mesh, seed=3)):
            assert ke.dtype == np.float32
            scaled = np.asarray(ke, np.float64) * amesh.QUANTUM
            np.testing.assert_array_equal(scaled, np.round(scaled))
            np.testing.assert_array_equal(ke, np.swapaxes(ke, 1, 2))


def test_element_dofs_interleaved():
    conn = np.asarray([[0, 2, 3]])
    ed = element_dofs(conn, ndof_per_node=2)
    np.testing.assert_array_equal(ed, [[0, 1, 4, 5, 6, 7]])
    np.testing.assert_array_equal(element_dofs(conn, 1), conn)


# ---------------------------------------------------------------------------
# Element coloring (conflict graph)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,make", MESHES, ids=MESH_IDS)
def test_element_coloring_conflict_free(name, make):
    """Satellite invariant: no two same-color elements share a DOF, and
    every element is covered exactly once."""
    mesh = make()
    col = color_elements(mesh.conn)
    assert verify_element_coloring(mesh.conn, col)
    covered = sorted(np.concatenate(
        [col.rows(c) for c in range(col.num_colors)]).tolist())
    assert covered == list(range(mesh.ne))


def test_element_coloring_balancing_preserves_invariant():
    mesh = amesh.grid_tri(6)
    raw = color_elements(mesh.conn, balance=False)
    bal = color_elements(mesh.conn, balance=True)
    assert bal.num_colors <= raw.num_colors
    assert verify_element_coloring(mesh.conn, bal)


# ---------------------------------------------------------------------------
# Assembly strategies vs the serial oracle (exact)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,make", MESHES, ids=MESH_IDS)
def test_assembled_poisson_matches_dense_oracle(name, make):
    """Acceptance: the assembled Poisson matrix equals the independent
    dense float64 oracle bit-for-bit on every mesh generator."""
    mesh = make()
    ke = amesh.poisson_stiffness(mesh, mass=0.5)
    sched = build_assembly_schedule(mesh)
    M = assemble(sched, ke, strategy="colored")
    A = _dense_oracle(mesh, ke)
    np.testing.assert_array_equal(csrc.to_dense(M).astype(np.float64), A)
    assert M.numerically_symmetric


@pytest.mark.parametrize("name,make", [MESHES[0], MESHES[2]],
                         ids=["tri", "tet"])
def test_assembled_elasticity_matches_dense_oracle(name, make):
    """Vector-valued DOFs (ndof_per_node=2, the elasticity shape)."""
    mesh = make()
    ke = amesh.synthetic_stiffness(mesh, ndof_per_node=2, seed=7)
    sched = build_assembly_schedule(mesh, ndof_per_node=2)
    M = assemble(sched, ke, strategy="colored")
    A = _dense_oracle(mesh, ke, ndof_per_node=2)
    np.testing.assert_array_equal(csrc.to_dense(M).astype(np.float64), A)


@pytest.mark.parametrize("name,make", MESHES, ids=MESH_IDS)
def test_all_strategies_bit_identical(name, make):
    """Colored and private-buffer scatters must equal the serial oracle
    exactly — the race detector the dyadic synthesis enables."""
    mesh = make()
    ke = amesh.synthetic_stiffness(mesh, seed=11)
    sched = build_assembly_schedule(mesh)
    ref = scatter_serial(sched, ke)
    np.testing.assert_array_equal(np.asarray(scatter_colored(sched, ke)),
                                  ref)
    np.testing.assert_array_equal(np.asarray(scatter_sorted(sched, ke)),
                                  ref)
    np.testing.assert_array_equal(np.asarray(scatter_private(sched, ke)),
                                  ref)


def test_private_buffer_width_does_not_change_result():
    mesh = amesh.grid_tri(5)
    ke = amesh.synthetic_stiffness(mesh, seed=2)
    ref = scatter_serial(build_assembly_schedule(mesh), ke)
    for nb in (1, 3, 16, 1000):
        sched = build_assembly_schedule(mesh, num_buffers=nb)
        assert sched.num_buffers <= mesh.ne
        np.testing.assert_array_equal(
            np.asarray(scatter_private(sched, ke)), ref)


@settings(max_examples=4, deadline=None)
@given(st.integers(2, 7), st.integers(0, 1000))
def test_property_random_tri_assembly_exact(nx, seed):
    mesh = amesh.grid_tri(nx)
    ke = amesh.synthetic_stiffness(mesh, seed=seed)
    sched = build_assembly_schedule(mesh)
    ref = scatter_serial(sched, ke)
    np.testing.assert_array_equal(np.asarray(scatter_colored(sched, ke)),
                                  ref)
    A = _dense_oracle(mesh, ke)
    M = assemble(sched, ke)
    np.testing.assert_array_equal(csrc.to_dense(M).astype(np.float64), A)


# ---------------------------------------------------------------------------
# AssemblySchedule caching (PlanCache, disk, zero-rebuild probes)
# ---------------------------------------------------------------------------

def test_assembly_schedule_cache_hit_zero_builds():
    mesh = amesh.grid_tri(5)
    cache = tuner.PlanCache()
    _, d1 = _build_delta(lambda: assembly_schedule_for(mesh, cache=cache))
    assert d1.get("assembly_schedule") == 1
    assert d1.get("element_coloring") == 1
    _, d2 = _build_delta(lambda: assembly_schedule_for(mesh, cache=cache))
    assert d2 == {}, f"cache hit rebuilt: {d2}"
    assert cache.assembly_hits == 1


def test_assembly_cache_hits_when_fewer_elements_than_buffers():
    """Regression: the builder clamps num_buffers to ne; the cache lookup
    must use the same clamp or the key never matches (tiny meshes would
    silently rebuild the schedule every step)."""
    mesh = amesh.grid_tri(1)                   # ne=2 < default 8 buffers
    cache = tuner.PlanCache()
    s1, d1 = _build_delta(lambda: assembly_schedule_for(mesh, cache=cache))
    assert s1.num_buffers == mesh.ne
    assert d1.get("assembly_schedule") == 1
    s2, d2 = _build_delta(lambda: assembly_schedule_for(mesh, cache=cache))
    assert d2 == {} and s2 is s1, f"tiny-mesh cache miss: {d2}"


def test_assembly_schedule_npz_roundtrip_through_disk_cache(tmp_path):
    """A fresh process (new cache object on the same file) loads the npz
    and rebuilds nothing; assembled matrices are bit-identical."""
    path = os.path.join(tmp_path, "plans.json")
    mesh = amesh.grid_tet(2)
    ke = amesh.synthetic_stiffness(mesh, seed=5)
    cache = tuner.PlanCache(path=path)
    s1 = assembly_schedule_for(mesh, cache=cache)
    cache2 = tuner.PlanCache(path=path)            # "new process"
    s2, d = _build_delta(lambda: assembly_schedule_for(mesh, cache=cache2))
    assert d == {}, f"disk hit rebuilt: {d}"
    for f in ("ia", "ja", "targets", "buffer_elements", "color_slots",
              "color_targets", "sorted_perm", "sorted_targets"):
        np.testing.assert_array_equal(getattr(s1, f), getattr(s2, f))
        assert getattr(s1, f).dtype == getattr(s2, f).dtype, f
    np.testing.assert_array_equal(csrc.to_dense(assemble(s1, ke)),
                                  csrc.to_dense(assemble(s2, ke)))


def test_assembly_version_mismatch_invalidates(tmp_path, monkeypatch):
    path = os.path.join(tmp_path, "plans.json")
    mesh = amesh.grid_tri(4)
    cache = tuner.PlanCache(path=path)
    assembly_schedule_for(mesh, cache=cache)
    monkeypatch.setattr(scatter_mod, "ASSEMBLY_VERSION",
                        scatter_mod.ASSEMBLY_VERSION + 1)
    cache2 = tuner.PlanCache(path=path)
    _, d = _build_delta(lambda: assembly_schedule_for(mesh, cache=cache2))
    assert d.get("assembly_schedule") == 1     # rebuilt, not crashed


def test_structure_digest_discriminates():
    m1, m2 = amesh.grid_tri(4), amesh.grid_tri(5)
    from repro.assembly import structure_digest
    assert structure_digest(m1.conn) != structure_digest(m2.conn)
    assert (structure_digest(m1.conn, ndof_per_node=2)
            != structure_digest(m1.conn))
    assert structure_digest(m1.conn) == structure_digest(m1.conn.copy())


# ---------------------------------------------------------------------------
# Assembled matrices through the SpMV stack
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nrhs", [1, 3, 8])
def test_assembled_matrix_all_spmv_plans_dense_oracle(nrhs):
    """Acceptance: the assembled matrix executes through every feasible
    registry path (kernel/flat/segment/colorful, int32 and int16 index
    streams) and matches the dense oracle for nrhs in {1, 3, 8}."""
    mesh = amesh.grid_tri(5)
    ke = amesh.poisson_stiffness(mesh, mass=0.5)
    M = assemble(build_assembly_schedule(mesh), ke)
    A = csrc.to_dense(M).astype(np.float64)
    X = np.random.default_rng(nrhs).standard_normal(
        (M.m, nrhs)).astype(np.float32)
    Y_ref = A @ X.astype(np.float64)
    scale = max(1.0, np.abs(Y_ref).max())
    plans = tuner.enumerate_plans(tuner.stats_of(M), tms=(8,))
    assert any(p.path == "kernel" for p in plans)
    for plan in plans:
        op = ops.SpmvOperator.from_plan(M, plan)
        Y = np.asarray(op(jnp.asarray(X)), dtype=np.float64)
        np.testing.assert_allclose(Y / scale, Y_ref / scale,
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"plan {plan.key()}")


def test_time_stepping_reuses_everything():
    """FEM time stepping: re-assembly with unchanged connectivity reuses
    the assembly schedule AND the SpMV schedule — the second step performs
    exactly one value refresh, zero structural rebuilds."""
    mesh = amesh.grid_tri(6)
    cache = tuner.PlanCache()
    plan = ExecutionPlan(path="kernel", tm=8)
    sched = assembly_schedule_for(mesh, cache=cache)

    def step(t):
        ke = amesh.poisson_stiffness(mesh, mass=0.5 + 0.25 * t)
        return assemble(sched, ke, strategy="colored")

    # the value-refresh fast path: one refresh probe per assemble, zero
    # structural rebuilds (the kernel packs are reused as-is)
    _, da = _build_delta(lambda: step(0))
    assert da == {"assembly_value_refresh": 1}, f"refresh rebuilt: {da}"

    M0 = step(0)
    op, d0 = _build_delta(
        lambda: ops.SpmvOperator.from_plan(M0, plan, cache=cache))
    assert d0.get("pack") == 1
    M1 = step(1)
    op1, d1 = _build_delta(
        lambda: ops.SpmvOperator.from_plan(M1, plan, cache=cache))
    assert d1 == {"value_refresh": 1}, f"structural rebuild: {d1}"
    x = jnp.asarray(np.random.default_rng(0).standard_normal(M1.m)
                    .astype(np.float32))
    ref = csrc.to_dense(M1).astype(np.float64) @ np.asarray(x, np.float64)
    scale = max(1.0, np.abs(ref).max())
    np.testing.assert_allclose(
        np.asarray(op1(x), np.float64) / scale, ref / scale,
        rtol=2e-4, atol=2e-4)
    # in-place refresh of the existing operator: same probe, same result
    _, d2 = _build_delta(lambda: op.update_values(M1))
    assert d2 == {"value_refresh": 1}
    np.testing.assert_array_equal(np.asarray(op(x)), np.asarray(op1(x)))


def test_end_to_end_assemble_tune_solve():
    """The acceptance demo: assemble a Poisson system from a mesh, tune
    it, solve with cg_solve; the solution matches the dense solve."""
    mesh = amesh.grid_tri(6)
    ke = amesh.poisson_stiffness(mesh, mass=1.0)
    cache = tuner.PlanCache()
    M, sched = assemble_mesh(mesh, ke, cache=cache)
    # colored assembly matches the serial oracle exactly
    np.testing.assert_array_equal(
        csrc.to_dense(M), csrc.to_dense(assemble(sched, ke, "serial")))
    # tune (deterministic injected measure), then solve through the cache
    res = tuner.tune(M, cache=cache,
                     measure=lambda op, x: 1.0 if op.plan.path == "kernel"
                     else 2.0)
    assert res.plan.path == "kernel"
    A = csrc.to_dense(M).astype(np.float64)
    x_true = np.random.default_rng(3).standard_normal(M.n)
    b = jnp.asarray(A @ x_true, dtype=jnp.float32)
    sol, op = cg_solve(M, b, cache=cache, tol=1e-7, maxiter=2000)
    assert bool(sol.converged)
    assert op.plan == res.plan                 # solved with the tuned plan
    np.testing.assert_allclose(np.asarray(sol.x, np.float64), x_true,
                               rtol=5e-3, atol=5e-3)


def test_serving_time_stepping_value_refresh():
    """Re-registering a re-assembled (same-structure) matrix in the
    serving engine refreshes value streams only — the satellite's
    zero-structural-rebuild probe."""
    from repro.serve.engine import SpmvServingEngine
    mesh = amesh.grid_tri(6)
    sched = build_assembly_schedule(mesh)
    M0 = assemble(sched, amesh.poisson_stiffness(mesh, mass=0.5))
    M1 = assemble(sched, amesh.poisson_stiffness(mesh, mass=1.5))
    eng = SpmvServingEngine(cache=tuner.PlanCache())
    eng.register("fem", M0)
    _, d = _build_delta(lambda: eng.register("fem", M1))
    assert d == {"value_refresh": 1}, f"structural rebuild: {d}"
    # the delta wraps an assemble() call too: exactly one assembly value
    # refresh fires (the satellite probe) and no pack/schedule rebuilds
    _, d2 = _build_delta(lambda: eng.update_values(
        "fem", assemble(sched, amesh.poisson_stiffness(mesh, mass=2.5))))
    assert d2 == {"value_refresh": 1, "assembly_value_refresh": 1}
    x = np.random.default_rng(1).standard_normal(M1.m).astype(np.float32)
    uid = eng.submit("fem", x)
    out = eng.run_until_drained()
    M2 = assemble(sched, amesh.poisson_stiffness(mesh, mass=2.5))
    np.testing.assert_allclose(out[uid], csrc.to_dense(M2) @ x,
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# RACE element coloring through assembly schedules and the cache
# ---------------------------------------------------------------------------

def test_race_halves_tet_element_palette():
    """The acceptance property: on the tet mesh ~24 elements share one
    node (a 24-clique), so any classic coloring needs a palette past 24 —
    RACE's level groups need at most half of greedy's, and the coloring
    stays valid under the chunk-aware invariant."""
    mesh = amesh.grid_tet(3)
    greedy = color_elements(mesh.conn, provider="greedy")
    race = color_elements(mesh.conn, provider="race")
    assert race.num_colors * 2 <= greedy.num_colors
    assert verify_element_coloring(mesh.conn, greedy)
    assert verify_element_coloring(mesh.conn, race)
    assert race.provider == "race"
    assert race.group_of_row is not None


@pytest.mark.parametrize("name,make", MESHES, ids=MESH_IDS)
def test_race_colored_assembly_bit_identical(name, make):
    """RACE's weaker intra-chunk guarantee is exact on the sum-combining
    scatter: colored assembly under the race provider matches the serial
    oracle bit for bit on every mesh class."""
    mesh = make()
    ke = amesh.synthetic_stiffness(mesh, seed=11)
    sched = build_assembly_schedule(mesh.conn, coloring_provider="race")
    assert sched.coloring.provider == "race"
    got = scatter_colored(sched, ke)
    want = scatter_serial(sched, ke)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_assembly_key_separates_providers():
    """Both providers' schedules coexist: the provider suffixes the
    assembly cache key (greedy keys stay byte-identical to pre-provider
    caches)."""
    from repro.assembly.scatter import assembly_key
    dig = "abc123"
    assert assembly_key(dig, 8, "greedy") == assembly_key(dig, 8)
    assert assembly_key(dig, 8, "race") != assembly_key(dig, 8, "greedy")
    assert assembly_key(dig, 8, "race").endswith(".race")


def test_race_assembly_schedule_roundtrips_zero_rebuild(tmp_path):
    """A race AssemblySchedule survives the npz round-trip with provider
    and level-group metadata, a fresh cache rebuilds nothing, and both
    providers' artifacts live side by side in one cache file."""
    path = os.path.join(tmp_path, "plans.json")
    mesh = amesh.grid_tet(2)
    ke = amesh.synthetic_stiffness(mesh, seed=7)
    cache = tuner.PlanCache(path=path)
    s_greedy = assembly_schedule_for(mesh, cache=cache)
    s1, d1 = _build_delta(lambda: assembly_schedule_for(
        mesh, cache=cache, coloring_provider="race"))
    assert d1.get("element_coloring") == 1     # distinct artifact built
    cache2 = tuner.PlanCache(path=path)            # "new process"
    s2, d2 = _build_delta(lambda: assembly_schedule_for(
        mesh, cache=cache2, coloring_provider="race"))
    assert d2 == {}, f"disk hit rebuilt: {d2}"
    col = s2.coloring
    assert col.provider == "race"
    assert col.level_of_row is not None and col.group_of_row is not None
    np.testing.assert_array_equal(col.color_of_row,
                                  s1.coloring.color_of_row)
    assert s_greedy.coloring.provider == "greedy"
    np.testing.assert_array_equal(csrc.to_dense(assemble(s1, ke)),
                                  csrc.to_dense(assemble(s2, ke)))
