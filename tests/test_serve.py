"""Serving: engine batching/draining, greedy determinism, ring-buffer
sliding-window decode correctness."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.models.transformer import build_model
from repro.models import attention as A
from repro.serve.engine import ServingEngine, Request


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("granite-3-2b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.slow
def test_engine_drains_and_batches(small_model):
    cfg, model, params = small_model
    eng = ServingEngine(model, params, max_slots=3, max_len=64, eos_id=0)
    rng = np.random.default_rng(0)
    for i in range(7):
        eng.submit(Request(uid=i, prompt=rng.integers(2, 64, 5 + i % 3),
                           max_new_tokens=5))
    done = eng.run_until_drained()
    assert len(done) == 7
    assert all(1 <= len(r.out_tokens) <= 5 for r in done)


@pytest.mark.slow
def test_greedy_determinism(small_model):
    cfg, model, params = small_model
    outs = []
    for _ in range(2):
        eng = ServingEngine(model, params, max_slots=1, max_len=64,
                            eos_id=0)
        eng.submit(Request(uid=0, prompt=np.arange(2, 10),
                           max_new_tokens=8))
        outs.append(eng.run_until_drained()[0].out_tokens)
    assert outs[0] == outs[1]


def test_ring_positions():
    from repro.models.attention import ring_positions
    t = 8
    # after writing token 11 at slot 3, slot i holds max p<=11, p≡i (mod 8)
    pos = np.asarray(ring_positions(jnp.asarray(11), t))
    assert pos[3] == 11 and pos[4] == 4 and pos[0] == 8
    # short fill: unwritten slots masked with INT32_MAX
    pos = np.asarray(ring_positions(jnp.asarray(2), t))
    assert pos[2] == 2 and pos[7] == np.iinfo(np.int32).max


@pytest.mark.slow
def test_ring_decode_matches_window_attention():
    """Sliding-window ring decode == full attention restricted to the
    window, for positions beyond the buffer size."""
    d, h, kv, hd = 32, 4, 4, 8
    params = A.init_attn(jax.random.PRNGKey(0), d, h, kv, hd,
                         dtype=jnp.float32)
    rng = np.random.default_rng(0)
    seq = jnp.asarray(rng.standard_normal((1, 20, d)), jnp.float32)
    window = 6
    # reference: full-sequence attention with sliding window
    pos = jnp.arange(20)
    y_ref, _ = A.attn_forward(params, seq, pos, n_heads=h, n_kv=kv,
                              head_dim=hd, window=window)
    # ring decode token by token with a buffer of exactly `window`
    k = jnp.zeros((1, window, kv, hd), jnp.float32)
    v = jnp.zeros((1, window, kv, hd), jnp.float32)
    for t in range(20):
        y_t, k, v = A.attn_decode_ring(
            params, seq[:, t:t + 1], k, v, jnp.asarray(t), n_heads=h,
            n_kv=kv, head_dim=hd, window=window)
        np.testing.assert_allclose(
            np.asarray(y_t[0, 0]), np.asarray(y_ref[0, t]),
            rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_int8_kv_decode_consistency():
    """§Perf cell C lever: int8 KV cache decode matches bf16 within
    quantization noise."""
    import dataclasses
    from repro.configs.base import get_config
    from repro.models.transformer import build_model
    cfg = get_config("qwen3-8b", reduced=True)
    rng = np.random.default_rng(1)
    b, s = 2, 12
    seq = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + 1)), jnp.int32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    ref = model.forward(params, seq)[:, s].astype(jnp.float32)
    mq = build_model(dataclasses.replace(cfg, kv_cache_dtype="int8"))
    state, _ = mq.prefill(params, seq[:, :s], max_len=s + 8)
    state, logits = mq.decode_step(params, state, seq[:, s:s + 1])
    got = logits[:, 0].astype(jnp.float32)
    err = float(jnp.abs(got - ref).max()) / (float(jnp.abs(ref).max()) + 1e-6)
    assert err < 0.1, err


def test_quantize_kv_roundtrip():
    from repro.models.attention import quantize_kv, dequantize_kv
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.standard_normal((2, 5, 4, 16)) * 3.0, jnp.float32)
    q, s = quantize_kv(k)
    back = dequantize_kv(q, s, jnp.float32)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(back), np.asarray(k),
                               atol=float(jnp.abs(k).max()) / 100)


# ---------------------------------------------------------------------------
# Token accounting (the off-by-one/prefill-EOS regression pins)
# ---------------------------------------------------------------------------

class _CountingModel:
    """Deterministic toy LM: the next token is always (prev + 1) mod vocab,
    so tests can steer exactly when EOS appears without a real model."""
    vocab = 16

    def prefill(self, params, prompt, max_len):
        nxt = (prompt[:, -1:] + 1) % self.vocab
        return jnp.zeros(()), jax.nn.one_hot(nxt, self.vocab)

    def decode_step(self, params, state, tok):
        nxt = (tok[:, -1:] + 1) % self.vocab
        return state, jax.nn.one_hot(nxt, self.vocab)


def _counting_engine(eos_id, max_slots=2):
    return ServingEngine(_CountingModel(), params=None,
                         max_slots=max_slots, max_len=32, eos_id=eos_id)


def test_max_new_tokens_one_emits_exactly_one_token():
    """Regression: the prefill token counts toward the budget — a budget
    of one must not burn a decode tick and emit a second token."""
    eng = _counting_engine(eos_id=15)
    eng.submit(Request(uid=0, prompt=np.array([2, 3]), max_new_tokens=1))
    done = eng.step()
    assert [r.uid for r in done] == [0]
    assert done[0].out_tokens == [4]          # exactly one, no decode
    assert not eng.active and not eng.queue


def test_prefill_eos_retires_without_decode_tick():
    """Regression: a prompt whose very first sampled token is EOS must
    retire at admission, not occupy a slot for one more decode."""
    eng = _counting_engine(eos_id=9)
    eng.submit(Request(uid=0, prompt=np.array([3, 8]),   # prefill -> 9
                       max_new_tokens=10))
    done = eng.step()
    assert [r.uid for r in done] == [0]
    assert done[0].out_tokens == [9]
    assert not eng.active and not eng.queue


def test_exact_token_budget_without_eos():
    """max_new_tokens is exact when EOS never fires: k tokens, not k+1."""
    eng = _counting_engine(eos_id=15)
    for k in (1, 2, 5):
        eng.submit(Request(uid=k, prompt=np.array([0]), max_new_tokens=k))
    done = eng.run_until_drained()
    assert {r.uid: len(r.out_tokens) for r in done} == {1: 1, 2: 2, 5: 5}
    assert all(r.out_tokens == list(range(1, r.uid + 1)) for r in done)


def test_prefill_eos_frees_slot_for_queue():
    """A prefill-finished request never occupies a slot, so a queued
    request behind it is admitted the same tick."""
    eng = _counting_engine(eos_id=9, max_slots=1)
    eng.submit(Request(uid=0, prompt=np.array([8]), max_new_tokens=10))
    eng.submit(Request(uid=1, prompt=np.array([0]), max_new_tokens=3))
    done = eng.run_until_drained()
    assert sorted(r.uid for r in done) == [0, 1]
    by_uid = {r.uid: r.out_tokens for r in done}
    assert by_uid[0] == [9]
    assert by_uid[1] == [1, 2, 3]


# ---------------------------------------------------------------------------
# SpMV serving: overflow ordering and coalesced-batch metadata
# ---------------------------------------------------------------------------

def _spmv_engine(max_batch):
    from repro.core import csrc, tuner
    from repro.serve.engine import SpmvServingEngine
    eng = SpmvServingEngine(cache=tuner.PlanCache(), max_batch=max_batch)
    M = csrc.poisson2d(5)
    eng.register("m", M)
    return eng, M


def test_spmv_step_overflow_drains_fifo_across_ticks():
    """Requests beyond max_batch stay queued in submission order and are
    answered on the following ticks, oldest first."""
    eng, M = _spmv_engine(max_batch=3)
    rng = np.random.default_rng(0)
    xs = [rng.standard_normal(M.m).astype(np.float32) for _ in range(8)]
    uids = [eng.submit("m", x) for x in xs]
    out1 = eng.step()
    assert sorted(out1) == uids[:3]           # first tick: oldest three
    assert [r.uid for r in eng.queue] == uids[3:]
    out2 = eng.step()
    assert sorted(out2) == uids[3:6]
    out3 = eng.step()
    assert sorted(out3) == uids[6:]
    assert not eng.queue
    from repro.core import csrc as C
    A = np.asarray(C.to_dense(M), np.float64)
    for out in (out1, out2, out3):
        for uid, y in out.items():
            np.testing.assert_allclose(
                np.asarray(y), A @ xs[uids.index(uid)],
                rtol=1e-5, atol=1e-5)


def test_spmv_result_batched_metadata_matches_group_size():
    """SpmvResult.batched reports the coalesced SpMM width: the full
    group on a saturated tick, the remainder afterwards, 1 for a lone
    request."""
    eng, M = _spmv_engine(max_batch=4)
    rng = np.random.default_rng(1)
    uids = [eng.submit("m", rng.standard_normal(M.m).astype(np.float32))
            for _ in range(6)]
    out1 = eng.step()
    assert all(out1[u].batched == 4 for u in uids[:4])
    out2 = eng.step()
    assert all(out2[u].batched == 2 for u in uids[4:])
    lone = eng.submit("m", rng.standard_normal(M.m).astype(np.float32))
    out3 = eng.step()
    assert out3[lone].batched == 1


def test_spmv_run_until_drained_covers_overflow():
    eng, M = _spmv_engine(max_batch=2)
    rng = np.random.default_rng(2)
    uids = [eng.submit("m", rng.standard_normal(M.m).astype(np.float32))
            for _ in range(7)]
    out = eng.run_until_drained()
    assert sorted(out) == sorted(uids)
