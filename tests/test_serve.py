"""Serving: engine batching/draining, greedy determinism, ring-buffer
sliding-window decode correctness."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.models.transformer import build_model
from repro.models import attention as A
from repro.serve.engine import ServingEngine, Request


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("granite-3-2b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.slow
def test_engine_drains_and_batches(small_model):
    cfg, model, params = small_model
    eng = ServingEngine(model, params, max_slots=3, max_len=64, eos_id=0)
    rng = np.random.default_rng(0)
    for i in range(7):
        eng.submit(Request(uid=i, prompt=rng.integers(2, 64, 5 + i % 3),
                           max_new_tokens=5))
    done = eng.run_until_drained()
    assert len(done) == 7
    assert all(1 <= len(r.out_tokens) <= 5 for r in done)


@pytest.mark.slow
def test_greedy_determinism(small_model):
    cfg, model, params = small_model
    outs = []
    for _ in range(2):
        eng = ServingEngine(model, params, max_slots=1, max_len=64,
                            eos_id=0)
        eng.submit(Request(uid=0, prompt=np.arange(2, 10),
                           max_new_tokens=8))
        outs.append(eng.run_until_drained()[0].out_tokens)
    assert outs[0] == outs[1]


def test_ring_positions():
    from repro.models.attention import ring_positions
    t = 8
    # after writing token 11 at slot 3, slot i holds max p<=11, p≡i (mod 8)
    pos = np.asarray(ring_positions(jnp.asarray(11), t))
    assert pos[3] == 11 and pos[4] == 4 and pos[0] == 8
    # short fill: unwritten slots masked with INT32_MAX
    pos = np.asarray(ring_positions(jnp.asarray(2), t))
    assert pos[2] == 2 and pos[7] == np.iinfo(np.int32).max


@pytest.mark.slow
def test_ring_decode_matches_window_attention():
    """Sliding-window ring decode == full attention restricted to the
    window, for positions beyond the buffer size."""
    d, h, kv, hd = 32, 4, 4, 8
    params = A.init_attn(jax.random.PRNGKey(0), d, h, kv, hd,
                         dtype=jnp.float32)
    rng = np.random.default_rng(0)
    seq = jnp.asarray(rng.standard_normal((1, 20, d)), jnp.float32)
    window = 6
    # reference: full-sequence attention with sliding window
    pos = jnp.arange(20)
    y_ref, _ = A.attn_forward(params, seq, pos, n_heads=h, n_kv=kv,
                              head_dim=hd, window=window)
    # ring decode token by token with a buffer of exactly `window`
    k = jnp.zeros((1, window, kv, hd), jnp.float32)
    v = jnp.zeros((1, window, kv, hd), jnp.float32)
    for t in range(20):
        y_t, k, v = A.attn_decode_ring(
            params, seq[:, t:t + 1], k, v, jnp.asarray(t), n_heads=h,
            n_kv=kv, head_dim=hd, window=window)
        np.testing.assert_allclose(
            np.asarray(y_t[0, 0]), np.asarray(y_ref[0, t]),
            rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_int8_kv_decode_consistency():
    """§Perf cell C lever: int8 KV cache decode matches bf16 within
    quantization noise."""
    import dataclasses
    from repro.configs.base import get_config
    from repro.models.transformer import build_model
    cfg = get_config("qwen3-8b", reduced=True)
    rng = np.random.default_rng(1)
    b, s = 2, 12
    seq = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + 1)), jnp.int32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    ref = model.forward(params, seq)[:, s].astype(jnp.float32)
    mq = build_model(dataclasses.replace(cfg, kv_cache_dtype="int8"))
    state, _ = mq.prefill(params, seq[:, :s], max_len=s + 8)
    state, logits = mq.decode_step(params, state, seq[:, s:s + 1])
    got = logits[:, 0].astype(jnp.float32)
    err = float(jnp.abs(got - ref).max()) / (float(jnp.abs(ref).max()) + 1e-6)
    assert err < 0.1, err


def test_quantize_kv_roundtrip():
    from repro.models.attention import quantize_kv, dequantize_kv
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.standard_normal((2, 5, 4, 16)) * 3.0, jnp.float32)
    q, s = quantize_kv(k)
    back = dequantize_kv(q, s, jnp.float32)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(back), np.asarray(k),
                               atol=float(jnp.abs(k).max()) / 100)
