"""Streaming kernel variants: the 'stream' bodies (per-lane gather +
segment-sum) and the fused interpret-mode executors must be bit-identical
to the one-hot oracle on dyadic values — both routes sum the same slots
into the same window positions, so with exactly-representable values the
only freedom (float addition order) cannot show.  Plus the tuner's
predict-then-measure mode: the analytic roofline ranking must keep the
full-measurement winner inside the measured top-K while cutting the
measurement count at least in half."""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import csrc, tuner
from repro.core.plan import ExecutionPlan
from repro.kernels import ops


def _dyadic(M):
    """Quantize values to multiples of 1/64: float sums become exact, so
    variant comparisons can assert bitwise equality."""
    q = lambda a: np.round(np.asarray(a) * 64.0) / 64.0
    return dataclasses.replace(M, ad=q(M.ad), al=q(M.al), au=q(M.au))


def _dyadic_x(m, nrhs, seed=0):
    r = np.random.default_rng(seed)
    shape = (m,) if nrhs == 1 else (m, nrhs)
    return (np.round(r.uniform(-1.0, 1.0, shape) * 8.0) / 8.0
            ).astype(np.float32)


def _empty_rows(n):
    i = np.arange(0, n, 2)
    return csrc.from_coo(i, i, np.ones(i.size), n=n)


MATRICES = [
    ("fem_band", lambda: csrc.fem_band(200, 12, seed=5)),
    ("fem_band_sym", lambda: csrc.fem_band(200, 12, seed=5,
                                           numeric_symmetric=True)),
    ("rect_tail", lambda: csrc.fem_band(130, 5, seed=3)),   # n % tm != 0
    ("empty_rows", lambda: _empty_rows(64)),
    ("powerlaw", lambda: csrc.powerlaw_laplacian(192, seed=7)),
]
_BY_NAME = dict(MATRICES)


def _plan(path, variant, **kw):
    base = (dict(path="nnzsplit", k_step_sublanes=2)
            if path == "nnzsplit" else dict(path=path, tm=128))
    base.update(kw, variant=variant)
    return ExecutionPlan(**base)


def _assert_variants_identical(M, path, nrhs, **plan_kw):
    """The registry-dispatched stream executor (fused in interpret mode)
    must match the one-hot oracle bit for bit on dyadic values."""
    M = _dyadic(M)
    x = jnp.asarray(_dyadic_x(M.m, nrhs, seed=nrhs))
    try:
        op_oh = ops.SpmvOperator.from_plan(M, _plan(path, "onehot",
                                                    **plan_kw))
    except ValueError:
        pytest.skip(f"{path} infeasible for this matrix")
    op_st = ops.SpmvOperator.from_plan(M, _plan(path, "stream", **plan_kw))
    y_oh = np.asarray(op_oh(x))
    y_st = np.asarray(op_st(x))
    np.testing.assert_array_equal(y_st, y_oh)
    # and both must be the true product (dyadic values: exact in f64)
    if plan_kw.get("value_dtype", "float32") == "float32":
        A = csrc.to_dense(M).astype(np.float64)
        y_ref = (A @ np.asarray(x, dtype=np.float64)).astype(np.float32)
        np.testing.assert_array_equal(y_oh, y_ref)


@pytest.mark.parametrize("nrhs", [1, 3, 8])
@pytest.mark.parametrize("path", ["kernel", "flat", "nnzsplit"])
@pytest.mark.parametrize("name", [n for n, _ in MATRICES])
def test_stream_bitwise_equals_onehot(name, path, nrhs):
    M = _BY_NAME[name]()
    if path == "nnzsplit" and name != "powerlaw":
        pytest.skip("nnzsplit exercised on the unstructured class")
    _assert_variants_identical(M, path, nrhs)


@pytest.mark.parametrize("path", ["kernel", "flat"])
def test_stream_int16_indices(path):
    _assert_variants_identical(_BY_NAME["fem_band"](), path, 3,
                               index_dtype="int16")


@pytest.mark.parametrize("path", ["kernel", "flat"])
def test_stream_bf16_values(path):
    # bf16 value streams: both variants read the same rounded values and
    # form exact f32 products, so they still agree bitwise
    _assert_variants_identical(_BY_NAME["fem_band_sym"](), path, 3,
                               value_dtype="bfloat16")


@pytest.mark.parametrize("nrhs", [1, 3])
def test_pallas_stream_bodies_match_onehot(nrhs):
    """The in-grid Pallas stream bodies (the compiled-TPU route, here run
    through interpret-mode grid emulation) — not just the fused
    executors — are bit-identical to the one-hot bodies."""
    from repro.core import blockell
    from repro.kernels.csrc_spmv import blockell_spmv
    from repro.kernels.csrc_spmm import blockell_spmm
    from repro.kernels.csrc_spmv_flat import pack_flat, flat_spmv, flat_spmm
    from repro.kernels.csrc_spmv_nnzsplit import (pack_nnzsplit,
                                                  nnzsplit_spmv,
                                                  nnzsplit_spmm)
    M = _dyadic(csrc.fem_band(96, 7, seed=2))
    x = jnp.asarray(_dyadic_x(M.m, nrhs, seed=9))
    pack = blockell.pack(M, tm=16, k_step=256)
    fpack = pack_flat(M, tm=16, ks=2)
    if nrhs == 1:
        pairs = [
            (blockell_spmv, (pack, x), dict(k_step_sublanes=2)),
            (flat_spmv, (fpack, x), {}),
        ]
    else:
        pairs = [
            (blockell_spmm, (pack, x), dict(k_step_sublanes=2)),
            (flat_spmm, (fpack, x), {}),
        ]
    Mu = _dyadic(csrc.powerlaw_laplacian(128, seed=3))
    xu = jnp.asarray(_dyadic_x(Mu.m, nrhs, seed=4))
    npack = pack_nnzsplit(Mu, ks=2)
    pairs.append(((nnzsplit_spmv if nrhs == 1 else nnzsplit_spmm),
                  (npack, xu), {}))
    for fn, args, kw in pairs:
        y_oh = np.asarray(fn(*args, interpret=True, variant="onehot", **kw))
        y_st = np.asarray(fn(*args, interpret=True, variant="stream", **kw))
        np.testing.assert_array_equal(y_st, y_oh, err_msg=fn.__name__)


# ---------------------------------------------------------------------------
# Predict-then-measure
# ---------------------------------------------------------------------------

def _bandwidth_measure(calls):
    """Deterministic stand-in for the clock, independent of the analytic
    cost model: time = actually-streamed pack bytes / bandwidth, with the
    one-hot variants charged the compute-bound factor their (S, W) mask
    contractions cost in practice."""
    def measure(op, x):
        calls.append(op.plan.key())
        t = op.bytes_per_call / 100e9
        if (op.plan.variant == "onehot"
                and op.plan.path in ("kernel", "flat", "nnzsplit")):
            t *= 50.0
        return t
    return measure


@pytest.mark.parametrize("name", ["fem_band_w16", "powerlaw"])
def test_predict_then_measure_keeps_winner(name):
    M = (csrc.fem_band(512, 16, seed=2) if name == "fem_band_w16"
         else csrc.powerlaw_laplacian(512, seed=7))
    full_calls, pruned_calls = [], []
    res_full = tuner.tune(M, predict=False,
                          measure=_bandwidth_measure(full_calls))
    res_pruned = tuner.tune(M, predict=True,
                            measure=_bandwidth_measure(pruned_calls))
    # >= 2x fewer measurements...
    assert 2 * len(pruned_calls) <= len(full_calls), (
        len(pruned_calls), len(full_calls))
    # ...and the full-measurement winner survived the pruning
    assert res_pruned.plan == res_full.plan, (
        res_pruned.plan.key(), res_full.plan.key())
    # provenance: every ranked candidate was priced, the winner got a
    # roofline fraction
    assert set(res_pruned.timings_s) <= set(res_pruned.predictions_s)
    assert len(res_pruned.predictions_s) == len(full_calls)
    assert res_pruned.roofline_fraction is not None
    assert res_pruned.roofline_fraction > 0


def test_predicted_and_measured_land_in_cache():
    M = csrc.fem_band(256, 8, seed=1)
    cache = tuner.PlanCache()
    res = tuner.tune(M, cache=cache, measure=_bandwidth_measure([]))
    e = cache.entries[res.fingerprint]
    assert "predicted_us" in e and "predicted_ms" in e
    assert "measured_ms" in e and "roofline_fraction" in e
    # predicted_ms / measured_ms are rounded for the JSON; the stored
    # fraction is the exact ratio
    assert e["roofline_fraction"] == pytest.approx(
        e["predicted_ms"] / e["measured_ms"], rel=0.05)
    # the winner's measured time is the recorded one
    assert e["measured_ms"] == pytest.approx(
        res.timings_s[res.plan.key()] * 1e3, rel=0.05)
