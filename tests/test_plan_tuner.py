"""The plan/autotune subsystem: every feasible ExecutionPlan must compute
the same product as the dense numpy oracle, and the tuner cache must
round-trip without re-measurement."""
import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

from _propshim import given, settings, st
from repro.core import csrc, solvers, tuner
from repro.core.plan import (ExecutionPlan, DEFAULT_PLAN, feasible,
                             kernel_window)
from repro.kernels import ops


# ---------------------------------------------------------------------------
# Matrix classes + edge cases (small sizes: the kernel path runs the Pallas
# kernel in interpret mode)
# ---------------------------------------------------------------------------

def _diag_only(n):
    i = np.arange(n)
    return csrc.from_coo(i, i, np.arange(1.0, n + 1.0), n=n)


def _empty_rows(n):
    """Rows with no entries at all (zero diagonal, no off-diagonals)."""
    i = np.arange(0, n, 2)
    return csrc.from_coo(i, i, np.ones(i.size), n=n)


MATRIX_CASES = [
    ("poisson2d", lambda: csrc.poisson2d(8)),
    ("fem_band_sym", lambda: csrc.fem_band(72, 5, seed=1,
                                           numeric_symmetric=True)),
    ("fem_band_asym", lambda: csrc.fem_band(72, 5, seed=2)),
    ("random_symmetric_pattern",
     lambda: csrc.random_symmetric_pattern(48, 3, seed=3)),
    ("dense_matrix", lambda: csrc.dense_matrix(32, seed=4)),
    ("rectangular_fem", lambda: csrc.rectangular_fem(48, 16, 4, seed=5)),
    ("n1", lambda: csrc.from_dense(np.array([[3.0]]))),
    ("diag_only_k0", lambda: _diag_only(17)),
    ("empty_rows", lambda: _empty_rows(20)),
]


def _check_all_plans(M, rtol=2e-4, tms=(8,)):
    A = csrc.to_dense(M).astype(np.float64)
    x = np.random.default_rng(11).standard_normal(M.m).astype(np.float32)
    y_ref = A @ x.astype(np.float64)
    scale = max(1.0, np.abs(y_ref).max())
    stats = tuner.stats_of(M)
    plans = tuner.enumerate_plans(stats, tms=tms)
    assert plans, "at least the segment plan must be feasible"
    for plan in plans:
        assert feasible(plan, n=M.n, m=M.m, bandwidth=stats.bandwidth)
        op = ops.SpmvOperator.from_plan(M, plan)
        assert op.plan.path == plan.path      # strict: no silent fallback
        y = np.asarray(op(jnp.asarray(x)), dtype=np.float64)
        # reduced-precision value streams carry bf16 rounding; the tuner
        # accuracy-gates them at VALUE_DTYPE_TOL, test at the same level
        tol = (tuner.VALUE_DTYPE_TOL if plan.value_dtype != "float32"
               else rtol)
        np.testing.assert_allclose(y / scale, y_ref / scale,
                                   rtol=tol, atol=tol,
                                   err_msg=f"plan {plan.key()}")
    return plans


@pytest.mark.parametrize("name,make", MATRIX_CASES,
                         ids=[n for n, _ in MATRIX_CASES])
def test_every_feasible_plan_matches_dense_oracle(name, make):
    M = make()
    plans = _check_all_plans(M)
    if not M.is_square:
        # rectangular: only the segment path may be enumerated
        assert all(p.path == "segment" for p in plans)


@settings(max_examples=3, deadline=None)
@given(st.integers(6, 40), st.integers(1, 6), st.integers(0, 10_000),
       st.booleans())
def test_property_plans_agree_random_band(n, band, seed, sym):
    M = csrc.fem_band(n, min(band, max(1, n - 1)), seed=seed,
                      numeric_symmetric=sym)
    _check_all_plans(M, tms=(8,))


# ---------------------------------------------------------------------------
# Plan dataclass mechanics
# ---------------------------------------------------------------------------

def test_plan_serialization_roundtrip():
    p = ExecutionPlan(path="kernel", tm=64, w_cap=2048, k_step_sublanes=4,
                      partition="count", accumulation="halo")
    assert ExecutionPlan.from_json(p.to_json()) == p
    assert p.k_step == 512
    assert "tm64" in p.key()


def test_plan_validation():
    with pytest.raises(ValueError):
        ExecutionPlan(path="warp")
    with pytest.raises(ValueError):
        ExecutionPlan(partition="hash")
    with pytest.raises(ValueError):
        ExecutionPlan(accumulation="gossip")
    assert DEFAULT_PLAN.path == "segment"


def test_kernel_plan_infeasible_raises():
    """from_plan is strict: a kernel plan whose window exceeds w_cap raises
    instead of silently falling back (the old static behavior)."""
    M = csrc.random_symmetric_pattern(300, 4, seed=0)   # bandwidth ~ n
    band = csrc.bandwidth(M)
    plan = ExecutionPlan(path="kernel", tm=128, w_cap=256)
    assert kernel_window(plan.tm, band) > plan.w_cap
    assert not feasible(plan, n=M.n, m=M.m, bandwidth=band)
    with pytest.raises(ValueError):
        ops.SpmvOperator.from_plan(M, plan)


def test_square_only_plans_reject_rectangular():
    M = csrc.rectangular_fem(32, 8, 3, seed=0)
    with pytest.raises(ValueError):
        ops.SpmvOperator.from_plan(M, ExecutionPlan(path="colorful"))
    with pytest.raises(ValueError):
        ops.SpmvOperator.from_plan(M, ExecutionPlan(path="kernel"))


# ---------------------------------------------------------------------------
# Tuner + cache
# ---------------------------------------------------------------------------

def _counting_measure(calls):
    def measure(op, x):
        calls.append(op.plan.key())
        # deterministic fake timing: prefer the kernel path
        return 1.0 if op.plan.path == "kernel" else 2.0
    return measure


def test_tune_picks_argmin_and_caches():
    M = csrc.poisson2d(8)
    cache = tuner.PlanCache()
    calls = []
    res = tuner.tune(M, cache=cache, measure=_counting_measure(calls))
    assert not res.cached
    assert len(calls) == len(res.timings_s) >= 2
    assert res.plan.path == "kernel"          # fake argmin
    # second tune: cache hit, zero measurements
    def boom(op, x):
        raise AssertionError("re-measured on a cache hit")
    res2 = tuner.tune(M, cache=cache, measure=boom)
    assert res2.cached and res2.plan == res.plan and res2.timings_s == {}
    assert cache.hits == 1


def test_cache_file_roundtrip(tmp_path):
    """tune -> save -> load -> same plan, no re-measurement."""
    path = os.path.join(tmp_path, "plans.json")
    M = csrc.fem_band(64, 3, seed=7)
    cache = tuner.PlanCache(path=path)
    calls = []
    res = tuner.tune(M, cache=cache, measure=_counting_measure(calls))
    assert os.path.exists(path)
    data = json.load(open(path))
    assert data["version"] == tuner.PlanCache.VERSION
    assert res.fingerprint in data["entries"]

    cache2 = tuner.PlanCache(path=path)
    def boom(op, x):
        raise AssertionError("re-measured after reload")
    res2 = tuner.tune(M, cache=cache2, measure=boom)
    assert res2.cached and res2.plan == res.plan


def test_fingerprint_stability_and_sensitivity():
    a = tuner.fingerprint(csrc.poisson2d(8))
    b = tuner.fingerprint(csrc.poisson2d(8))
    c = tuner.fingerprint(csrc.poisson2d(9))
    d = tuner.fingerprint(csrc.fem_band(64, 3, seed=0))
    assert a == b
    assert len({a, c, d}) == 3


def test_plan_for_heuristic_is_cached_and_stable():
    M = csrc.fem_band(96, 4, seed=0)
    cache = tuner.PlanCache()
    p1 = tuner.plan_for(M, cache=cache, autotune=False)
    p2 = tuner.plan_for(M, cache=cache, autotune=False)
    assert p1 == p2 and cache.hits == 1
    # heuristic mirrors the static auto decision for a banded matrix
    assert p1.path == "kernel"


def test_plan_for_autotune_counts_one_miss():
    """plan_for must not double-probe the cache around tune()."""
    cache = tuner.PlanCache()
    M = csrc.poisson2d(6)
    tuner.plan_for(M, cache=cache, autotune=True,
                   measure=lambda op, x: 1.0)
    assert cache.misses == 1 and cache.hits == 0


def test_heuristic_cache_entry_does_not_satisfy_tune():
    """A heuristic (unmeasured) plan cached by plan_for(autotune=False)
    must not be returned by tune() as if it were the measured argmin."""
    cache = tuner.PlanCache()
    M = csrc.poisson2d(6)
    tuner.plan_for(M, cache=cache, autotune=False)   # caches heuristic
    calls = []
    res = tuner.tune(M, cache=cache, measure=_counting_measure(calls))
    assert not res.cached and len(calls) >= 2        # really measured
    # the measured result replaced the heuristic entry: now a tune hit
    res2 = tuner.tune(M, cache=cache,
                      measure=lambda op, x: (_ for _ in ()).throw(
                          AssertionError("re-measured")))
    assert res2.cached and res2.plan == res.plan
    # and heuristic lookups still see it
    assert tuner.plan_for(M, cache=cache, autotune=False) == res.plan


def test_candidate_source_registration():
    marker = ExecutionPlan(path="segment", w_cap=1234)
    def source(stats):
        return [marker]
    tuner.register_candidate_source(source)
    try:
        plans = tuner.enumerate_plans(tuner.stats_of(csrc.poisson2d(6)))
        assert any(p == marker for p in plans)
    finally:
        tuner._CANDIDATE_SOURCES.remove(source)


# ---------------------------------------------------------------------------
# Solver + serving integration (the tuner path end to end)
# ---------------------------------------------------------------------------

def test_cg_solve_uses_plan_subsystem():
    M = csrc.poisson2d(12)
    A = csrc.to_dense(M)
    x_true = np.random.default_rng(0).standard_normal(M.n).astype(np.float32)
    b = jnp.asarray(A @ x_true)
    cache = tuner.PlanCache()
    res, op = solvers.cg_solve(M, b, cache=cache, maxiter=2000)
    assert bool(res.converged)
    assert np.abs(np.asarray(res.x) - x_true).max() < 1e-3
    assert isinstance(op.plan, ExecutionPlan)
    # the decision landed in the cache: a second solve is a pure hit
    res2, op2 = solvers.cg_solve(M, b, cache=cache, maxiter=2000)
    assert cache.hits >= 1 and op2.plan == op.plan


def test_spmv_serving_engine_tuned_batching():
    from repro.serve.engine import SpmvServingEngine
    M = csrc.fem_band(80, 4, seed=2)
    A = csrc.to_dense(M)
    cache = tuner.PlanCache()
    calls = []
    # pre-tune through the same cache the engine uses
    tuner.tune(M, cache=cache, measure=_counting_measure(calls))
    eng = SpmvServingEngine(cache=cache, autotune=True)
    plan = eng.register("fem", M)
    assert calls and cache.hits >= 1          # registration hit the cache
    rng = np.random.default_rng(3)
    xs = [rng.standard_normal(M.m).astype(np.float32) for _ in range(4)]
    uids = [eng.submit("fem", x) for x in xs]
    out = eng.run_until_drained()
    assert set(out) == set(uids)
    for uid, x in zip(uids, xs):
        np.testing.assert_allclose(out[uid], A @ x, rtol=2e-4, atol=2e-4)
    assert eng.plan("fem") == plan


# ---------------------------------------------------------------------------
# coloring providers through the tuner and the cost model
# ---------------------------------------------------------------------------

def test_cost_model_prefers_race_on_wide_band():
    """The locality terms the provider choice rides on: per-color launch
    overhead plus the reuse-distance waste price greedy's ~2·band palette
    far above RACE's constant handful on a wide-band matrix — so
    predict-then-measure always measures the race colorful candidate."""
    from repro.roofline import cost_model
    stats = tuner.stats_of(csrc.fem_band(512, 24, seed=3))
    greedy = ExecutionPlan(path="colorful")
    race = ExecutionPlan(path="colorful", coloring="race")
    cg = cost_model.plan_cost(stats, greedy)
    cr = cost_model.plan_cost(stats, race)
    assert cr.predicted_s < cg.predicted_s
    ranked = cost_model.rank_plans(stats, [greedy, race])
    assert ranked[0][0].coloring == "race"


def test_tune_measures_best_colorful_provider_and_persists(tmp_path):
    """tune() measures the colorful path through its best-predicted
    provider, and the winning plan's coloring field round-trips through
    the cache JSON."""
    path = os.path.join(tmp_path, "plans.json")
    M = csrc.fem_band(96, 8, seed=2)
    cache = tuner.PlanCache(path=path)

    def prefer_colorful(op, x):
        return 1.0 if op.plan.path == "colorful" else 2.0

    res = tuner.tune(M, cache=cache, measure=prefer_colorful)
    assert res.plan.path == "colorful"
    # the measured colorful candidate is the cost model's provider pick
    from repro.roofline import cost_model
    stats = tuner.stats_of(M)
    colorful_keys = [k for k in res.timings_s if k.startswith("colorful")]
    want = cost_model.rank_plans(
        stats, [ExecutionPlan(path="colorful"),
                ExecutionPlan(path="colorful", coloring="race")])[0][0]
    prefix = "colorful:race" if want.coloring == "race" else "colorful:nnz"
    assert len(colorful_keys) == 1 and colorful_keys[0].startswith(prefix)
    # the provider survives the disk round-trip
    cache2 = tuner.PlanCache(path=path)

    def boom(op, x):
        raise AssertionError("re-measured after reload")

    res2 = tuner.tune(M, cache=cache2, measure=boom)
    assert res2.cached and res2.plan == res.plan
    assert res2.plan.coloring == res.plan.coloring
