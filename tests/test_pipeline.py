"""Pipeline parallelism: GPipe schedule correctness on placeholder devices."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_bubble_fraction():
    from repro.train.pipeline import bubble_fraction
    assert bubble_fraction(1, 8) == 0.0
    assert abs(bubble_fraction(4, 8) - 3 / 11) < 1e-9
    assert bubble_fraction(4, 32) < bubble_fraction(4, 8)


@pytest.mark.slow
def test_pipeline_matches_sequential():
    code = """
        import numpy as np, jax, jax.numpy as jnp
        from repro.train.pipeline import pipeline_apply
        mesh = jax.make_mesh((4,), ('stage',))
        L, D, M, B = 8, 16, 6, 3
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (L, D, D)) * (D ** -0.5)
        params = {'w': w}
        def layer_fn(p, x):
            return jnp.tanh(x @ p['w'])
        xs = jax.random.normal(jax.random.PRNGKey(1), (M, B, D))
        out = pipeline_apply(layer_fn, params, xs, mesh, 'stage')
        # sequential reference
        ref = xs
        for i in range(L):
            ref = jnp.tanh(ref @ w[i])
        err = float(jnp.abs(out - ref).max())
        assert err < 1e-5, err
        print('OK', err)
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]


@pytest.mark.slow
def test_pipeline_collectives_are_permutes():
    """The handoff must lower to collective-permute (point-to-point), not
    all-gather — that is the PP communication advantage."""
    code = """
        import jax, jax.numpy as jnp
        from repro.train.pipeline import pipeline_apply
        from repro.roofline.hlo_cost import analyze_hlo
        mesh = jax.make_mesh((4,), ('stage',))
        L, D, M, B = 8, 16, 6, 3
        w = jax.random.normal(jax.random.PRNGKey(0), (L, D, D))
        def layer_fn(p, x): return jnp.tanh(x @ p['w'])
        xs = jax.ShapeDtypeStruct((M, B, D), jnp.float32)
        ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
        with mesh:
            txt = jax.jit(lambda w_, x_: pipeline_apply(
                layer_fn, {'w': w_}, x_, mesh, 'stage')).lower(
                ws, xs).compile().as_text()
        c = analyze_hlo(txt)
        assert c.collectives['collective-permute']['count'] > 0
        print('OK', {k: v['count'] for k, v in c.collectives.items()
                     if isinstance(v, dict)})
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
