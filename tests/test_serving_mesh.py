"""Mesh-backed serving: executors, placement, mesh-aware tuning, and the
shipped shard-layout artifacts.

Single-device tests run in-process with 1-wide meshes (a mesh plan with
``mesh_p=1`` exercises the full MeshExecutor machinery on any host);
8-device tests run in subprocesses with their own XLA_FLAGS, like every
multi-device test here (device count is locked at first jax init).

Bit-identity discipline: matrices and inputs are quantized to dyadic
values (multiples of 1/64, the assembly subsystem's trick), so float32
accumulation is exact in any order and the mesh path must reproduce the
local oracle **bit for bit** — a dropped or double-counted shard
contribution is always visible.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import csrc, schedule as S, tuner
from repro.core.plan import ExecutionPlan
from repro.serve import (LocalExecutor, MeshExecutor, SpmvResult,
                         SpmvServingEngine)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def _build_delta(fn):
    """Run fn and return (result, builds-that-happened) from the probe."""
    before = dict(S.BUILD_COUNTS)
    out = fn()
    after = dict(S.BUILD_COUNTS)
    delta = {k: after.get(k, 0) - before.get(k, 0)
             for k in set(after) | set(before)}
    return out, {k: v for k, v in delta.items() if v}


def _dyadic(M):
    """Quantize a CSRC matrix's values to multiples of 1/64: float32
    accumulation of the products becomes order-independent, so every
    strategy must agree bit for bit."""
    def q(a):
        return jnp.asarray(np.round(np.asarray(a) * 64.0) / 64.0,
                           jnp.float32)
    return dataclasses.replace(M, ad=q(M.ad), al=q(M.al), au=q(M.au))


def _dyadic_x(m, seed=0, nrhs=None):
    rng = np.random.default_rng(seed)
    shape = (m,) if nrhs is None else (m, nrhs)
    return (rng.integers(-128, 128, shape) / 64.0).astype(np.float32)


STRUCTURAL_KEYS = ("pack", "flat_pack", "nnzsplit_pack", "partition",
                   "coloring", "schedule", "sharded_slots", "halo_layout",
                   "flat_shards", "flat_halo", "nnzsplit_shards",
                   "nnzsplit_halo")


# ---------------------------------------------------------------------------
# Plan fields: strategy / mesh_p / value_dtype
# ---------------------------------------------------------------------------

def test_plan_mesh_fields_roundtrip_and_keys():
    p = ExecutionPlan(path="segment", strategy="mesh", mesh_p=8,
                      accumulation="halo")
    assert ExecutionPlan.from_json(p.to_json()) == p
    assert ":mesh8" in p.key()
    local = ExecutionPlan()
    assert "mesh" not in local.key()
    bf = ExecutionPlan(path="kernel", value_dtype="bfloat16")
    assert ":bf16" in bf.key()
    # old cache entries (no new fields) load with defaults
    d = local.to_dict()
    for k in ("strategy", "mesh_p", "value_dtype"):
        d.pop(k)
    assert ExecutionPlan.from_dict(d) == local


def test_plan_mesh_fields_validation():
    with pytest.raises(ValueError):
        ExecutionPlan(strategy="cluster")
    with pytest.raises(ValueError):
        ExecutionPlan(strategy="mesh", mesh_p=0)
    with pytest.raises(ValueError):
        ExecutionPlan(strategy="local", mesh_p=4)   # mesh_p needs 'mesh'
    with pytest.raises(ValueError):
        ExecutionPlan(value_dtype="float8")


# ---------------------------------------------------------------------------
# Mesh-aware candidate enumeration (collective-bytes + halo gates)
# ---------------------------------------------------------------------------

def test_enumerate_mesh_plans_basic():
    M = csrc.fem_band(512, 8, seed=1)
    plans = tuner.enumerate_mesh_plans(tuner.stats_of(M), 8)
    assert plans and all(p.strategy == "mesh" and p.mesh_p == 8
                         for p in plans)
    accs = {p.accumulation for p in plans}
    # band 8 fits inside 64-row shards: all three strategies compete
    assert accs == {"halo", "reduce_scatter", "allreduce"}
    assert {p.path for p in plans} == {"segment"}   # no skew: no flat


def test_enumerate_mesh_plans_halo_gate():
    M = csrc.fem_band(64, 32, seed=0)       # band 32 > 64/8 rows per shard
    plans = tuner.enumerate_mesh_plans(tuner.stats_of(M), 8)
    assert plans
    assert all(p.accumulation != "halo" for p in plans)


def test_enumerate_mesh_plans_collective_bytes_gate():
    # p=64 on a narrow band: Θ(n) collectives exceed the per-shard
    # working set by construction; only the Θ(band) halo survives
    M = csrc.fem_band(4096, 1, seed=1)
    plans = tuner.enumerate_mesh_plans(tuner.stats_of(M), 64)
    assert plans
    assert {p.accumulation for p in plans} == {"halo"}


def test_enumerate_mesh_plans_proposes_flat_on_skew():
    M = csrc.skewed_band(512, 24, 3, seed=2)
    plans = tuner.enumerate_mesh_plans(tuner.stats_of(M), 4)
    assert {"segment", "flat"} <= {p.path for p in plans}


def test_enumerate_mesh_plans_rectangular_empty():
    M = csrc.rectangular_fem(64, 16, 4, seed=5)
    assert tuner.enumerate_mesh_plans(tuner.stats_of(M), 4) == []


# ---------------------------------------------------------------------------
# MeshExecutor on a 1-wide mesh: bit-identical to the local oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("acc", ["allreduce", "reduce_scatter", "halo"])
def test_mesh_executor_bit_identical_to_local_p1(acc):
    M = _dyadic(csrc.fem_band(96, 4, seed=2))
    local = LocalExecutor(M, ExecutionPlan(path="segment"))
    mesh = MeshExecutor(M, ExecutionPlan(path="segment", strategy="mesh",
                                         mesh_p=1, accumulation=acc))
    for nrhs in (None, 3, 8):
        x = jnp.asarray(_dyadic_x(M.m, seed=nrhs or 1, nrhs=nrhs))
        y_local = np.asarray(local(x))
        y_mesh = np.asarray(mesh(x))
        assert np.array_equal(y_local, y_mesh), (acc, nrhs)


def test_mesh_engine_register_step_update_values_p1():
    """The full serving loop through MeshExecutor on one device:
    coalesced step bit-identical to the local-oracle engine, zero
    structural rebuild on re-register, value-refresh probe on
    update_values."""
    M = _dyadic(csrc.fem_band(96, 4, seed=3))
    A = np.asarray(csrc.to_dense(M), np.float64)
    mesh_plan = ExecutionPlan(path="segment", strategy="mesh", mesh_p=1,
                              accumulation="reduce_scatter")
    cache = tuner.PlanCache()
    eng = SpmvServingEngine(cache=cache)
    eng_oracle = SpmvServingEngine(cache=tuner.PlanCache())
    eng.register("m", M, plan=mesh_plan)
    eng_oracle.register("m", M, plan=ExecutionPlan(path="segment"))
    assert eng.executor("m").kind == "mesh"

    xs = [_dyadic_x(M.m, seed=i) for i in range(3)]
    uids = [eng.submit("m", x) for x in xs]
    uids_o = [eng_oracle.submit("m", x) for x in xs]
    out = eng.run_until_drained()
    out_o = eng_oracle.run_until_drained()
    for u, uo in zip(uids, uids_o):
        assert np.array_equal(np.asarray(out[u]), np.asarray(out_o[uo]))
        np.testing.assert_allclose(out[u], A @ xs[uids.index(u)],
                                   rtol=1e-6, atol=1e-6)

    # re-register: every artifact (plan, schedule, shard layout) hits
    _, d = _build_delta(lambda: eng.register("m2", M, plan=mesh_plan))
    assert d == {}, f"cache-hit mesh register did precompute work: {d}"

    # same-structure value refresh: value streams only, on the mesh
    M2 = _dyadic(dataclasses.replace(M, al=M.al * 2, au=M.au * 2,
                                     ad=M.ad * 2))
    _, d = _build_delta(lambda: eng.update_values("m", M2))
    assert d.get("shard_value_refresh") == 1, d
    assert not any(d.get(k) for k in STRUCTURAL_KEYS), d
    u = eng.submit("m", xs[0])
    y = eng.step()[u]
    np.testing.assert_allclose(
        y, np.asarray(csrc.to_dense(M2), np.float64) @ xs[0],
        rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("acc", ["reduce_scatter", "halo"])
def test_mesh_update_values_rejects_structure_change(acc):
    """The mesh path enforces the same contract as the local one: a
    different-structure matrix must raise, never silently refill the
    stale layout's value streams."""
    M = csrc.fem_band(96, 4, seed=2)
    M_other = csrc.fem_band(96, 4, seed=9)      # same class, new sparsity
    ex = MeshExecutor(M, ExecutionPlan(path="segment", strategy="mesh",
                                       mesh_p=1, accumulation=acc))
    with pytest.raises(ValueError, match="structure differs"):
        ex.update_values(M_other)
    # the executor still serves the registered matrix correctly
    x = _dyadic_x(M.m, seed=1)
    np.testing.assert_allclose(
        np.asarray(ex(jnp.asarray(x))),
        np.asarray(csrc.to_dense(M), np.float64) @ x,
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("acc", ["allreduce", "halo"])
def test_mesh_flat_value_refresh_p1(acc):
    """Flat shard-compute value refresh through the executor: value
    streams only, correct product afterwards."""
    M = csrc.skewed_band(256, 24, 3, seed=2)
    ex = MeshExecutor(M, ExecutionPlan(path="flat", tm=32,
                                       strategy="mesh", mesh_p=1,
                                       accumulation=acc))
    M2 = dataclasses.replace(M, al=M.al * 2, au=M.au * 2, ad=M.ad * 2)
    _, d = _build_delta(lambda: ex.update_values(M2))
    assert d.get("shard_value_refresh") == 1, d
    assert not any(d.get(k) for k in STRUCTURAL_KEYS), d
    x = np.random.default_rng(1).standard_normal(M.m).astype(np.float32)
    y = np.asarray(ex(jnp.asarray(x)), np.float64)
    ref = np.asarray(csrc.to_dense(M2), np.float64) @ x
    assert np.abs(y - ref).max() / np.abs(ref).max() < 1e-5, acc


def test_flat_shard_refresh_matches_fresh_pack_multishard():
    """refresh_flat_shards / refresh_flat_halo at p=4 reproduce a fresh
    pack of the new-value matrix bit for bit (host-side: no devices
    needed — this pins the fill-order identity the refreshers rely on)."""
    from repro.kernels import csrc_spmv_flat as F
    M = csrc.skewed_band(300, 24, 3, seed=3)
    M2 = dataclasses.replace(M, al=M.al * 3, au=M.au * 3, ad=M.ad * 3)
    part = S.partition_rows_by_nnz(M, 4)
    fs = F.pack_flat_shards(M, part.starts, tm=32)
    fresh = F.pack_flat_shards(M2, part.starts, tm=32)
    refreshed = F.refresh_flat_shards(fs, M2, np.asarray(part.starts))
    for name in ("vals_l", "vals_u", "ad"):
        assert np.array_equal(np.asarray(getattr(refreshed, name)),
                              np.asarray(getattr(fresh, name))), name
    lay = F.pack_flat_halo(M, 4, tm=32)
    fresh_h = F.pack_flat_halo(M2, 4, tm=32)
    refreshed_h = F.refresh_flat_halo(lay, M2)
    for name in ("vals_l", "vals_u", "ad"):
        assert np.array_equal(np.asarray(getattr(refreshed_h, name)),
                              np.asarray(getattr(fresh_h, name))), name


def test_mesh_halo_value_refresh_p1():
    M = _dyadic(csrc.fem_band(96, 4, seed=5))
    ex = MeshExecutor(M, ExecutionPlan(path="segment", strategy="mesh",
                                       mesh_p=1, accumulation="halo"))
    M2 = _dyadic(dataclasses.replace(M, al=M.al * 3, au=M.au * 3,
                                     ad=M.ad * 3))
    _, d = _build_delta(lambda: ex.update_values(M2))
    assert d.get("shard_value_refresh") == 1, d
    assert not any(d.get(k) for k in STRUCTURAL_KEYS), d
    x = _dyadic_x(M.m, seed=2)
    np.testing.assert_allclose(
        np.asarray(ex(jnp.asarray(x))),
        np.asarray(csrc.to_dense(M2), np.float64) @ x,
        rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Placement: plan resolution and graceful degradation
# ---------------------------------------------------------------------------

def _skip_unless_single_device(p: int = 8):
    from repro.serve import placement
    if placement.device_count() >= p:
        pytest.skip(f"process sees >= {p} devices; the degradation "
                    "path under test needs a device-starved process")


def test_placement_falls_back_to_local_without_devices():
    """A mesh_p the process cannot satisfy degrades to the local plan
    (needs a device-starved process — skipped under forced devices,
    e.g. the CI serving-smoke job)."""
    _skip_unless_single_device(8)
    M = csrc.fem_band(80, 4, seed=2)
    eng = SpmvServingEngine(cache=tuner.PlanCache(), mesh_p=8)
    plan = eng.register("m", M)
    assert plan.strategy == "local"
    assert eng.executor("m").kind == "local"


def test_mesh_executor_requires_devices():
    _skip_unless_single_device(8)
    M = csrc.fem_band(80, 4, seed=2)
    plan = ExecutionPlan(path="segment", strategy="mesh", mesh_p=8,
                         accumulation="halo")
    with pytest.raises(ValueError, match="devices"):
        MeshExecutor(M, plan)


def test_placement_falls_back_to_local_for_rectangular():
    """The distributed strategies shard square rows only: a rectangular
    matrix on a mesh-width engine must serve through the (working)
    local path, never a crashing mesh plan."""
    M = csrc.rectangular_fem(64, 16, 4, seed=5)
    eng = SpmvServingEngine(cache=tuner.PlanCache(), mesh_p=1)
    plan = eng.register("r", M)
    assert plan.strategy == "local"
    assert eng.executor("r").kind == "local"
    x = np.random.default_rng(0).standard_normal(M.m).astype(np.float32)
    u = eng.submit("r", x)
    np.testing.assert_allclose(
        eng.step()[u], np.asarray(csrc.to_dense(M), np.float64) @ x,
        rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError):
        tuner.heuristic_mesh_plan(tuner.stats_of(M), 4)


def test_mesh_plan_for_heuristic_is_cached():
    M = csrc.fem_band(256, 4, seed=1)
    cache = tuner.PlanCache()
    plan = tuner.mesh_plan_for(M, 8, cache=cache)
    assert plan.strategy == "mesh" and plan.mesh_p == 8
    assert plan.accumulation == "halo"          # band fits inside a shard
    hits0 = cache.hits
    assert tuner.mesh_plan_for(M, 8, cache=cache) == plan
    assert cache.hits == hits0 + 1
    # the mesh entry does not shadow the local entry
    local = tuner.plan_for(M, cache=cache)
    assert local.strategy == "local"


# ---------------------------------------------------------------------------
# Mesh-aware tuning (1-wide mesh in-process; 8-wide in the slow test)
# ---------------------------------------------------------------------------

def test_tune_mesh_records_per_p_winner():
    M = csrc.fem_band(128, 4, seed=2)
    cache = tuner.PlanCache()
    calls = []

    def measure(fn, x):
        calls.append(1)
        return 1.0 + len(calls) * 1e-3      # first candidate wins

    res = tuner.tune_mesh(M, 1, cache=cache, measure=measure)
    assert calls and not res.cached
    assert res.plan.strategy == "mesh" and res.plan.mesh_p == 1
    fp = tuner.mesh_fingerprint(tuner.fingerprint(M), 1)
    assert res.fingerprint == fp
    entry = cache.entries[fp]
    assert entry["measured"] and entry["timings_us"]
    # all three accumulation strategies were actually measured
    # (key layout: ...:<partition>:<accumulation>:mesh<p>)
    accs = {k.split(":")[-2] for k in res.timings_s}
    assert accs == {"halo", "reduce_scatter", "allreduce"}
    # second call: pure cache hit, zero measurements
    calls.clear()
    res2 = tuner.tune_mesh(M, 1, cache=cache, measure=measure)
    assert res2.cached and not calls and res2.plan == res.plan


def test_tune_mesh_ps_through_tune():
    M = csrc.fem_band(128, 4, seed=2)
    cache = tuner.PlanCache()
    res = tuner.tune(M, cache=cache, measure=lambda op, x: 1.0,
                     mesh_ps=(1,))
    assert res.plan.strategy == "local"
    assert 1 in res.mesh_plans and res.mesh_plans[1].mesh_p == 1
    fp = tuner.mesh_fingerprint(tuner.fingerprint(M), 1)
    assert cache.get(fp, require_measured=True) is not None


def test_registered_mesh_winner_drives_serving():
    """The serving flow of the tuned mesh decision: tune_mesh fills the
    per-(matrix, p) entry, an engine with that mesh width picks it up
    and serves through a MeshExecutor."""
    M = _dyadic(csrc.fem_band(96, 4, seed=7))
    cache = tuner.PlanCache()
    tuner.tune_mesh(M, 1, cache=cache, measure=lambda fn, x: 1.0)
    eng = SpmvServingEngine(cache=cache, mesh_p=1)
    plan = eng.register("m", M)
    assert plan.strategy == "mesh" and plan.mesh_p == 1
    assert eng.executor("m").kind == "mesh"
    x = _dyadic_x(M.m, seed=1)
    u = eng.submit("m", x)
    np.testing.assert_allclose(
        eng.step()[u], np.asarray(csrc.to_dense(M), np.float64) @ x,
        rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Result metadata (per-request plan/strategy attribution)
# ---------------------------------------------------------------------------

def test_results_surface_plan_metadata():
    M = csrc.fem_band(64, 3, seed=4)
    eng = SpmvServingEngine(cache=tuner.PlanCache())
    plan = eng.register("m", M)
    rng = np.random.default_rng(0)
    xs = [rng.standard_normal(M.m).astype(np.float32) for _ in range(3)]
    uids = [eng.submit("m", x) for x in xs]
    out = eng.run_until_drained()
    for u in uids:
        r = out[u]
        assert isinstance(r, SpmvResult) and isinstance(r, np.ndarray)
        assert r.matrix_id == "m"
        assert r.plan_key == plan.key()
        assert r.path == plan.path
        assert r.strategy == "local" and r.mesh_p == 1
        assert r.executor == "local"
        assert r.batched == 3
        assert set(r.meta()) == set(SpmvResult._META)
    # single-request ticks report batched == 1
    u = eng.submit("m", xs[0])
    assert eng.step()[u].batched == 1


def test_result_metadata_survives_slicing_mesh():
    M = _dyadic(csrc.fem_band(64, 3, seed=4))
    eng = SpmvServingEngine(cache=tuner.PlanCache())
    eng.register("m", M, plan=ExecutionPlan(
        path="segment", strategy="mesh", mesh_p=1,
        accumulation="allreduce"))
    uids = [eng.submit("m", _dyadic_x(M.m, seed=i)) for i in range(2)]
    out = eng.run_until_drained()
    for u in uids:
        assert out[u].executor == "mesh"
        assert out[u].strategy == "mesh"
        assert out[u].batched == 2


# ---------------------------------------------------------------------------
# Shipped shard-layout artifacts (the PlanCache npz layer)
# ---------------------------------------------------------------------------

def _clear_layout_memos():
    S._SHARDED_SLOTS_MEMO.clear()
    S._HALO_LAYOUT_MEMO.clear()
    S._FLAT_SHARDS_MEMO.clear()
    S._FLAT_HALO_MEMO.clear()


@pytest.mark.parametrize("acc,path", [
    ("reduce_scatter", "segment"),
    ("halo", "segment"),
    ("allreduce", "flat"),
    ("halo", "flat"),
])
def test_shard_layout_ships_through_npz(tmp_path, acc, path):
    """A fresh process (simulated: new PlanCache instance + cleared
    memos) constructs the mesh executor for a known matrix with ZERO
    structural work — every per-shard sub-artifact loads from the npz
    beside the plans."""
    M = _dyadic(csrc.skewed_band(256, 24, 3, seed=2) if path == "flat"
                else csrc.fem_band(128, 4, seed=2))
    plan = ExecutionPlan(path=path, tm=32, strategy="mesh", mesh_p=1,
                         accumulation=acc)
    cache_file = str(tmp_path / "plans.json")
    cache = tuner.PlanCache(path=cache_file)
    ex = MeshExecutor(M, plan, cache=cache)
    x = _dyadic_x(M.m, seed=1)
    y_ref = np.asarray(ex(jnp.asarray(x)))

    _clear_layout_memos()
    cache2 = tuner.PlanCache(path=cache_file)
    _, d = _build_delta(lambda: MeshExecutor(M, plan, cache=cache2))
    assert d == {}, f"shipped artifacts were rebuilt: {d}"
    ex2 = MeshExecutor(M, plan, cache=cache2)
    assert np.array_equal(np.asarray(ex2(jnp.asarray(x))), y_ref)


def test_shard_layout_npz_roundtrip(tmp_path):
    M = csrc.fem_band(96, 4, seed=1)
    part = S.partition_rows_by_nnz(M, 4)
    ss = S.build_sharded_slots(M, part)
    f = str(tmp_path / "lay.npz")
    S.save_shard_layout_npz(f, ss)
    back = S.load_shard_layout_npz(f)
    assert type(back).__name__ == "ShardedSlots"
    for name in ("row_idx", "ja", "al", "au", "ad_shard"):
        assert np.array_equal(np.asarray(getattr(back, name)),
                              np.asarray(getattr(ss, name))), name
    assert np.array_equal(np.asarray(back.part.starts),
                          np.asarray(part.starts))
    # version gate: a bumped version is a miss, not a crash
    ver = S.SHARD_LAYOUT_VERSION
    try:
        S.SHARD_LAYOUT_VERSION = ver + 1
        with pytest.raises(ValueError):
            S.load_shard_layout_npz(f)
    finally:
        S.SHARD_LAYOUT_VERSION = ver


# ---------------------------------------------------------------------------
# bf16 value-stream plans (satellite)
# ---------------------------------------------------------------------------

def test_bf16_enumerated_only_for_numerically_symmetric():
    sym = tuner.stats_of(csrc.fem_band(128, 8, seed=1,
                                       numeric_symmetric=True))
    nonsym = tuner.stats_of(csrc.fem_band(128, 8, seed=1))
    assert any(p.value_dtype == "bfloat16"
               for p in tuner.enumerate_plans(sym))
    assert all(p.value_dtype == "float32"
               for p in tuner.enumerate_plans(nonsym))


def test_bf16_winner_passes_accuracy_gate_and_executes():
    M = csrc.fem_band(128, 8, seed=1, numeric_symmetric=True)
    cache = tuner.PlanCache()
    res = tuner.tune(M, cache=cache,
                     measure=lambda op, x: (
                         0.5 if op.plan.value_dtype == "bfloat16" else 1.0))
    assert res.plan.value_dtype == "bfloat16"
    from repro.kernels import ops
    op = ops.SpmvOperator.from_plan(M, res.plan, cache=cache)
    assert str(op.pack.vals_l.dtype) == "bfloat16"
    x = np.random.default_rng(0).standard_normal(M.m).astype(np.float32)
    y = np.asarray(op(jnp.asarray(x)), np.float64)
    ref = np.asarray(csrc.to_dense(M), np.float64) @ x
    assert np.abs(y - ref).max() / np.abs(ref).max() < tuner.VALUE_DTYPE_TOL


def test_bf16_rejected_when_accuracy_gate_fails():
    """tol=0 makes every reduced-precision candidate fail the gate: the
    tuner must fall back to an exact plan even when bf16 measures
    faster."""
    M = csrc.fem_band(128, 8, seed=1, numeric_symmetric=True)
    res = tuner.tune(M, cache=tuner.PlanCache(), value_dtype_tol=0.0,
                     measure=lambda op, x: (
                         0.5 if op.plan.value_dtype == "bfloat16" else 1.0))
    assert res.plan.value_dtype == "float32"
    assert all(":bf16" not in k for k in res.timings_s)


def test_bf16_schedule_npz_roundtrip(tmp_path):
    """bf16 packs persist widened to f32 and re-narrow on load."""
    M = csrc.fem_band(96, 4, seed=2, numeric_symmetric=True)
    plan = ExecutionPlan(path="kernel", tm=32, value_dtype="bfloat16")
    sched = S.build_schedule(M, plan)
    assert str(sched.pack.vals_l.dtype) == "bfloat16"
    f = str(tmp_path / "sched.npz")
    sched.save_npz(f)
    back = S.SpmvSchedule.load_npz(f)
    assert str(back.pack.vals_l.dtype) == "bfloat16"
    assert np.array_equal(np.asarray(back.pack.vals_l, np.float32),
                          np.asarray(sched.pack.vals_l, np.float32))
    # artifact key separates value dtypes: no silent cross-dtype reuse
    f32 = ExecutionPlan(path="kernel", tm=32)
    assert (S.plan_artifact_fields(plan) != S.plan_artifact_fields(f32))


def test_bf16_mesh_flat_plan_streams_bf16(tmp_path):
    """An explicit bf16 mesh flat plan actually narrows the shard value
    streams (plan.key() attribution is honest) and round-trips through
    the shipped npz layer."""
    M = csrc.skewed_band(256, 24, 3, seed=2)
    plan = ExecutionPlan(path="flat", tm=32, value_dtype="bfloat16",
                         strategy="mesh", mesh_p=1,
                         accumulation="allreduce")
    cache = tuner.PlanCache(path=str(tmp_path / "plans.json"))
    ex = MeshExecutor(M, plan, cache=cache)
    assert str(ex.layout.vals_l.dtype) == "bfloat16"
    x = np.random.default_rng(0).standard_normal(M.m).astype(np.float32)
    y = np.asarray(ex(jnp.asarray(x)), np.float64)
    ref = np.asarray(csrc.to_dense(M), np.float64) @ x
    assert np.abs(y - ref).max() / np.abs(ref).max() < tuner.VALUE_DTYPE_TOL
    # shipped artifact reloads with the narrow dtype intact
    _clear_layout_memos()
    cache2 = tuner.PlanCache(path=str(tmp_path / "plans.json"))
    _, d = _build_delta(lambda: MeshExecutor(M, plan, cache=cache2))
    assert d == {}, d
    ex2 = MeshExecutor(M, plan, cache=cache2)
    assert str(ex2.layout.vals_l.dtype) == "bfloat16"
    assert np.array_equal(np.asarray(ex2(jnp.asarray(x))),
                          np.asarray(ex(jnp.asarray(x))))


def test_tune_mesh_ships_only_winner_artifacts(tmp_path):
    """Measurement must not persist one npz per losing candidate: after
    tune_mesh, the schedules dir holds the winner's artifacts only."""
    M = csrc.fem_band(128, 4, seed=2)
    cache = tuner.PlanCache(path=str(tmp_path / "plans.json"))
    res = tuner.tune_mesh(M, 1, cache=cache, measure=lambda fn, x: 1.0)
    assert len(res.timings_s) >= 3
    sdir = str(tmp_path / "plans_schedules")
    layouts = [f for f in os.listdir(sdir) if f.startswith("shard-")]
    assert len(layouts) == 1, layouts       # the winner's, nothing else


def test_bf16_value_refresh_preserves_dtype():
    M = csrc.fem_band(96, 4, seed=2, numeric_symmetric=True)
    plan = ExecutionPlan(path="kernel", tm=32, value_dtype="bfloat16")
    from repro.kernels import ops
    op = ops.SpmvOperator.from_plan(M, plan)
    M2 = dataclasses.replace(M, al=M.al * 2, au=M.al * 2, ad=M.ad * 2)
    _, d = _build_delta(lambda: op.update_values(M2))
    assert d == {"value_refresh": 1}, d
    assert str(op.pack.vals_l.dtype) == "bfloat16"


# ---------------------------------------------------------------------------
# 8-device end-to-end (subprocess; the CI serving-smoke job runs these)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_mesh_serving_8dev_bit_identical_all_strategies():
    """The acceptance probe: register/step/update_values through a
    MeshExecutor on 8 forced host devices, bit-identical to the
    LocalExecutor oracle for nrhs in {1, 3, 8}, zero rebuild on
    re-register, value refresh on the mesh path."""
    print(run_with_devices("""
        import dataclasses, numpy as np, jax.numpy as jnp
        from repro.core import csrc, schedule as S, tuner
        from repro.core.plan import ExecutionPlan
        from repro.serve import SpmvServingEngine

        def dyadic(M):
            q = lambda a: jnp.asarray(
                np.round(np.asarray(a) * 64.0) / 64.0, jnp.float32)
            return dataclasses.replace(M, ad=q(M.ad), al=q(M.al),
                                       au=q(M.au))

        def dx(m, seed, nrhs=None):
            rng = np.random.default_rng(seed)
            shape = (m,) if nrhs is None else (m, nrhs)
            return (rng.integers(-128, 128, shape) / 64.0
                    ).astype(np.float32)

        def delta(fn):
            before = dict(S.BUILD_COUNTS)
            out = fn()
            d = {k: S.BUILD_COUNTS[k] - before.get(k, 0)
                 for k in S.BUILD_COUNTS}
            return out, {k: v for k, v in d.items() if v}

        M = dyadic(csrc.fem_band(512, 8, seed=1))
        oracle = SpmvServingEngine(cache=tuner.PlanCache())
        oracle.register('m', M, plan=ExecutionPlan(path='segment'))
        for acc in ('allreduce', 'reduce_scatter', 'halo'):
            plan = ExecutionPlan(path='segment', strategy='mesh',
                                 mesh_p=8, accumulation=acc)
            cache = tuner.PlanCache()
            eng = SpmvServingEngine(cache=cache)
            eng.register('m', M, plan=plan)
            assert eng.executor('m').kind == 'mesh'
            for nrhs in (1, 3, 8):
                xs = [dx(M.m, 10 * nrhs + i) for i in range(nrhs)]
                uids = [eng.submit('m', x) for x in xs]
                uo = [oracle.submit('m', x) for x in xs]
                out = eng.run_until_drained()
                ref = oracle.run_until_drained()
                for u, r in zip(uids, uo):
                    assert np.array_equal(np.asarray(out[u]),
                                          np.asarray(ref[r])), (acc, nrhs)
                assert out[uids[0]].executor == 'mesh'
                assert out[uids[0]].mesh_p == 8
                assert out[uids[0]].batched == nrhs
            # zero-rebuild probe on re-register
            _, d = delta(lambda: eng.register('m2', M, plan=plan))
            assert d == {}, (acc, d)
            # value refresh on the mesh path
            M2 = dyadic(dataclasses.replace(M, al=M.al * 2, au=M.au * 2,
                                            ad=M.ad * 2))
            _, d = delta(lambda: eng.update_values('m', M2))
            assert d.get('shard_value_refresh') == 1, (acc, d)
            structural = ('pack', 'flat_pack', 'partition', 'coloring',
                          'schedule', 'sharded_slots', 'halo_layout',
                          'flat_shards', 'flat_halo')
            assert not any(d.get(k) for k in structural), (acc, d)
            x = dx(M.m, 99)
            u = eng.submit('m', x)
            y = np.asarray(eng.step()[u], np.float64)
            ref2 = np.asarray(csrc.to_dense(M2), np.float64) @ x
            assert np.abs(y - ref2).max() < 1e-6, acc
        print('OK')
    """))


@pytest.mark.slow
def test_mesh_serving_8dev_flat_path():
    """Flat shard-compute through the serving engine on 8 devices."""
    print(run_with_devices("""
        import numpy as np, jax.numpy as jnp
        from repro.core import csrc, tuner
        from repro.core.plan import ExecutionPlan
        from repro.serve import SpmvServingEngine
        M = csrc.skewed_band(512, 24, 3, seed=2)
        A = np.asarray(csrc.to_dense(M), np.float64)
        rng = np.random.default_rng(0)
        for acc in ('allreduce', 'halo'):
            plan = ExecutionPlan(path='flat', tm=32, strategy='mesh',
                                 mesh_p=8, accumulation=acc)
            eng = SpmvServingEngine(cache=tuner.PlanCache())
            eng.register('skew', M, plan=plan)
            xs = [rng.standard_normal(M.m).astype(np.float32)
                  for _ in range(4)]
            uids = [eng.submit('skew', x) for x in xs]
            out = eng.run_until_drained()
            for u, x in zip(uids, xs):
                err = np.abs(np.asarray(out[u], np.float64) - A @ x).max()
                assert err / max(1.0, np.abs(A @ x).max()) < 1e-5, acc
            assert out[uids[0]].path == 'flat'
            assert out[uids[0]].executor == 'mesh'
        print('OK')
    """))


@pytest.mark.slow
def test_tune_mesh_8dev_records_skewed_band_winner():
    """The mesh-aware mode on a real 8-device mesh: the skewed-band suite
    entry gets a measured per-(matrix, p) winner in the cache, and an
    engine with mesh_p=8 serves through it."""
    print(run_with_devices("""
        import numpy as np
        from repro.core import csrc, tuner
        from repro.serve import SpmvServingEngine
        M = csrc.skewed_band(2000, 48, 3, seed=6)   # skew_band_w48 class
        cache = tuner.PlanCache()
        res = tuner.tune_mesh(M, 8, cache=cache, repeats=1)
        assert not res.cached and res.plan.strategy == 'mesh'
        assert res.plan.mesh_p == 8
        fp = tuner.mesh_fingerprint(tuner.fingerprint(M), 8)
        entry = cache.entries[fp]
        assert entry['measured'] and entry['timings_us']
        paths_seen = {k.split(':')[0] for k in res.timings_s}
        assert 'segment' in paths_seen and 'flat' in paths_seen
        eng = SpmvServingEngine(cache=cache, mesh_p=8)
        plan = eng.register('skew', M)
        assert plan == res.plan
        assert eng.executor('skew').kind == 'mesh'
        x = np.random.default_rng(0).standard_normal(M.m).astype('float32')
        u = eng.submit('skew', x)
        y = np.asarray(eng.step()[u], np.float64)
        ref = np.asarray(csrc.to_dense(M), np.float64) @ x
        assert np.abs(y - ref).max() / np.abs(ref).max() < 1e-5
        print('OK', res.plan.key())
    """))
