"""train_step factory: remat policy, microbatch gradient accumulation
(with optional bf16 error-feedback), AdamW update.

Microbatch accumulation uses lax.scan so XLA overlaps the DP gradient
reduce-scatter of microbatch i with the compute of i+1 (compute/comm
overlap without manual scheduling).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import adamw
from repro.optim.compress import ef_accumulate


class TrainState(NamedTuple):
    params: object
    opt: adamw.AdamWState
    step: jnp.ndarray


def init_train_state(model, opt_cfg: adamw.AdamWConfig, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=adamw.init(opt_cfg, params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(model, opt_cfg: adamw.AdamWConfig,
                    microbatches: int = 1, remat: str = "full",
                    accum_dtype: str = "float32") -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``accum_dtype='bfloat16'`` enables error-feedback bf16 accumulation of
    microbatch gradients (optim/compress.py).
    """
    model.remat = remat

    def loss_fn(params, batch):
        return model.loss(params, batch)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch):
        params = state.params
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(a):
                b = a.shape[0]
                assert b % microbatches == 0, (
                    f"batch {b} must divide microbatches {microbatches}")
                return a.reshape((microbatches, b // microbatches)
                                 + a.shape[1:])
            mb = jax.tree.map(split, batch)

            if accum_dtype == "bfloat16":
                acc0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
                res0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)

                def body(carry, mb_i):
                    acc, res, loss_sum = carry
                    (loss, _), g = grad_fn(params, mb_i)
                    acc, res = ef_accumulate(acc, res, g)
                    return (acc, res, loss_sum + loss), None

                (acc, res, loss_sum), _ = jax.lax.scan(
                    body, (acc0, res0, jnp.zeros((), jnp.float32)), mb)
                grads = jax.tree.map(
                    lambda a, r: (a.astype(jnp.float32) + r)
                    / microbatches, acc, res)
            else:
                acc0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)

                def body(carry, mb_i):
                    acc, loss_sum = carry
                    (loss, _), g = grad_fn(params, mb_i)
                    acc = jax.tree.map(
                        lambda a, gg: a + gg.astype(jnp.float32), acc, g)
                    return (acc, loss_sum + loss), None

                (acc, loss_sum), _ = jax.lax.scan(
                    body, (acc0, jnp.zeros((), jnp.float32)), mb)
                grads = jax.tree.map(lambda a: a / microbatches, acc)
            loss = loss_sum / microbatches
            metrics = {"ce": loss}

        new_params, new_opt, opt_metrics = adamw.update(
            opt_cfg, state.opt, params, grads)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return TrainState(params=new_params, opt=new_opt,
                          step=state.step + 1), metrics

    return train_step
