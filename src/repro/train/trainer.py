"""Training loop with checkpoint/restart, failure recovery, and straggler
monitoring — the fleet-facing control plane.

Fault model (what actually happens at 1000+ nodes, and how each is
handled here):

  * process crash / preemption   -> restart resumes from the latest atomic
    checkpoint; the data stream is a pure function of step, so resume is
    sample-exact (tests/test_trainer.py kills and resumes mid-run);
  * transient step failure (bad host, flaky ICI) -> the step is retried
    from the last checkpoint up to ``max_retries`` times (fault injection
    hook in tests);
  * stragglers -> per-step wall time EWMA + deviation; steps slower than
    ``straggler_sigma`` deviations are logged with their step index.  On a
    real fleet this signal feeds the controller that cordons the slow host
    — the detection logic is what we can build and test here;
  * elastic restart -> checkpoints reshard on load (checkpoint/ckpt.py),
    and the pipeline's shard_slice is device-count independent.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.train.step import TrainState


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    ckpt_async: bool = False
    log_every: int = 10
    max_retries: int = 3
    straggler_ewma: float = 0.9
    straggler_sigma: float = 3.0


class StragglerMonitor:
    """EWMA mean/var of step wall-time; flags outlier steps.

    Guards against false positives: a warm-up period before any flagging
    (the EWMA variance starts near zero), and a relative floor — a step
    must exceed both mean + sigma·std AND rel_floor × mean to count (5%
    jitter on a tight distribution is not a straggler)."""

    WARMUP = 10

    def __init__(self, alpha: float, sigma: float,
                 rel_floor: float = 1.25):
        self.alpha = alpha
        self.sigma = sigma
        self.rel_floor = rel_floor
        self.mean = None
        self.var = 0.0
        self.count = 0
        self.flagged: List[Dict] = []

    def observe(self, step: int, dt: float) -> bool:
        self.count += 1
        if self.mean is None:
            self.mean = dt
            return False
        dev = dt - self.mean
        threshold = self.sigma * max(self.var, 1e-12) ** 0.5
        is_straggler = (self.count > self.WARMUP
                        and dev > threshold
                        and dt > self.rel_floor * self.mean)
        self.mean = self.alpha * self.mean + (1 - self.alpha) * dt
        self.var = self.alpha * self.var + (1 - self.alpha) * dev * dev
        if is_straggler:
            self.flagged.append({"step": step, "dt": dt,
                                 "mean": self.mean})
        return is_straggler


class Trainer:
    def __init__(self, cfg: TrainerConfig, train_step: Callable,
                 pipeline, state: TrainState,
                 fault_hook: Optional[Callable[[int], None]] = None):
        """fault_hook(step) may raise to simulate a step failure (tests)."""
        self.cfg = cfg
        self.train_step = train_step
        self.pipeline = pipeline
        self.state = state
        self.fault_hook = fault_hook
        self.monitor = StragglerMonitor(cfg.straggler_ewma,
                                        cfg.straggler_sigma)
        self.history: List[Dict] = []

    # ---- checkpointing ----
    def _save(self, step: int, blocking: bool = True):
        if self.cfg.ckpt_dir:
            ckpt.save(self.cfg.ckpt_dir, step, self.state,
                      keep=self.cfg.ckpt_keep,
                      blocking=blocking or not self.cfg.ckpt_async)

    def try_restore(self) -> int:
        """Resume from the latest checkpoint if one exists; returns the
        step to start from."""
        if not self.cfg.ckpt_dir:
            return 0
        latest = ckpt.latest_step(self.cfg.ckpt_dir)
        if latest is None:
            return 0
        self.state = ckpt.restore(self.cfg.ckpt_dir, latest, self.state)
        return latest

    # ---- main loop ----
    def run(self, start_step: Optional[int] = None) -> TrainState:
        step = self.try_restore() if start_step is None else start_step
        retries = 0
        while step < self.cfg.total_steps:
            batch = self.pipeline.batch_at(step)
            t0 = time.perf_counter()
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                new_state, metrics = self.train_step(self.state, batch)
                # materialize before trusting the step (surfacing async
                # errors here, inside the retry scope)
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at {step}")
            except Exception as e:  # noqa: BLE001 — fleet-style recovery
                retries += 1
                if retries > self.cfg.max_retries:
                    raise
                restored = self.try_restore()
                self.history.append({"step": step, "event": "retry",
                                     "error": repr(e),
                                     "restored_to": restored})
                step = restored
                continue
            retries = 0
            self.state = new_state
            dt = time.perf_counter() - t0
            self.monitor.observe(step, dt)
            if step % self.cfg.log_every == 0 or step == self.cfg.total_steps - 1:
                self.history.append({"step": step, "loss": loss, "dt": dt})
            step += 1
            if self.cfg.ckpt_dir and step % self.cfg.ckpt_every == 0:
                self._save(step, blocking=not self.cfg.ckpt_async)
        self._save(step, blocking=True)
        return self.state
