"""GPipe-style pipeline parallelism over a mesh axis.

Layers are split into S contiguous stages along a `stage` mesh axis; M
microbatches stream through with ppermute activation handoff.  Each tick
every stage runs its layer block on its current microbatch — the schedule
fills in S-1 ticks, runs M+S-1 ticks total (bubble fraction
(S-1)/(M+S-1)), exactly GPipe.

SPMD formulation: all stages execute one program under shard_map; stage
identity comes from jax.lax.axis_index.  Stage 0 injects microbatch t at
tick t; the last stage emits microbatch t at tick t+S-1; a psum over the
stage axis (outputs are zero-masked elsewhere) collects results.

This composes with the data/model axes (pipeline over `pod`, FSDP/TP
inside a stage) — at 512+ chips PP over pods avoids cross-DCI all-reduce
of weights.  Correctness is subprocess-tested on 8 placeholder devices
(tests/test_pipeline.py); the same code lowers on the production mesh.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
try:                                    # jax >= 0.6 top-level export
    from jax import shard_map
except ImportError:                     # jax 0.4.x
    from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _pcast_varying(tree, axis: str):
    """jax.lax.pcast(..., to="varying") where available; identity on jax
    versions whose shard_map has no varying-axis types (<= 0.4.x)."""
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is None:
        return tree
    return pcast(tree, (axis,), to="varying")


def pipeline_apply(layer_fn: Callable, stacked_params, x_microbatches,
                   mesh: Mesh, axis: str = "stage"):
    """Run a stack of layers as a pipeline over ``axis``.

    layer_fn(params_i, x) -> x       one layer
    stacked_params: pytree with leading axis L (total layers); L must be
      divisible by the stage count S = mesh.shape[axis].
    x_microbatches: (M, ...) microbatch-major activations.
    Returns (M, ...) outputs, identical to applying all L layers serially
    to each microbatch.
    """
    s = dict(mesh.shape)[axis]
    m = x_microbatches.shape[0]
    leaves = jax.tree.leaves(stacked_params)
    l_total = leaves[0].shape[0]
    assert l_total % s == 0, f"{l_total} layers not divisible by {s} stages"

    # reshape params to (S, L/S, ...) and shard the stage axis
    staged = jax.tree.map(
        lambda a: a.reshape((s, l_total // s) + a.shape[1:]),
        stacked_params)

    def stage_program(params_local, xs):
        # params_local: (1, L/S, ...) this stage's block; xs: (M, ...) full
        stage_id = jax.lax.axis_index(axis)
        params_local = jax.tree.map(lambda a: a[0], params_local)

        def run_block(x):
            def body(x, p):
                return layer_fn(p, x), None
            x, _ = jax.lax.scan(body, x, params_local)
            return x

        ticks = m + s - 1
        zero = jnp.zeros_like(xs[0])

        def tick(carry, t):
            held, outputs = carry
            # stage 0 injects microbatch t (if in range); others use held
            inject = jnp.where(t < m, t, m - 1)
            x_in = jnp.where(stage_id == 0, xs[inject], held)
            active = (t - stage_id >= 0) & (t - stage_id < m)
            y = run_block(x_in)
            y = jnp.where(active, y, zero)
            # pass to the right neighbor (stage i -> i+1)
            passed = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % s) for i in range(s)])
            # last stage emits microbatch t-(s-1) at tick t; masked add
            # (each microbatch is emitted exactly once) keeps the body
            # branch-free for shard_map's varying-axis typing
            emit_idx = t - (s - 1)
            do_emit = (stage_id == s - 1) & (emit_idx >= 0) & (emit_idx < m)
            outputs = outputs.at[jnp.clip(emit_idx, 0, m - 1)].add(
                jnp.where(do_emit, y, 0.0))
            return (passed, outputs), None

        outputs0 = jnp.zeros((m,) + xs.shape[1:], xs.dtype)
        # carries become stage-varying inside the body; mark the initials
        # (jax >= 0.6 varying-axis typing; older shard_map needs no mark)
        init = _pcast_varying((zero, outputs0), axis)
        (_, outputs), _ = jax.lax.scan(tick, init, jnp.arange(ticks))
        # collect: outputs live on the last stage only
        return jax.lax.psum(jnp.where(stage_id == s - 1, outputs, 0.0),
                            axis)

    in_specs = (jax.tree.map(lambda _: P(axis), staged), P())
    fn = shard_map(stage_program, mesh=mesh, in_specs=in_specs,
                   out_specs=P())
    return fn(staged, x_microbatches)


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    """GPipe bubble overhead: (S-1)/(M+S-1)."""
    return (num_stages - 1) / (num_microbatches + num_stages - 1)
