"""Execution plans: every degree of freedom of a CSRC SpMV, in one record.

The paper's central empirical result is that *which* parallelization
strategy wins — local buffers with one of four accumulation methods, or
colorful partitioning — depends on the matrix: working-set size, band
structure, and numeric symmetry decide it per input (§4, Figs. 5–9).
``ExecutionPlan`` reifies that decision so it can be enumerated, measured,
cached, and shipped between processes instead of being hard-coded in
``SpmvOperator``:

  path               single-device compute strategy, one of the names in
                     the KernelPath registry (core/paths.py):
                       'kernel'   rectangular-grid block-ELL Pallas kernel
                                  (banded matrices)
                       'flat'     flat-grid block-ELL Pallas kernel (banded
                                  matrices with skewed row lengths — no
                                  cross-tile ELL padding)
                       'segment'  segment-sum jnp path (any matrix)
                       'colorful' color-by-color permutation writes (§3.2)
                     New kernels add a name by registering a KernelPath —
                     not by editing this module.
  tm                 block-ELL row-tile height (kernel path)
  w_cap              max window width the kernel will accept before the
                     pack is declared infeasible (bandwidth gate)
  k_step_sublanes    slot padding granularity in 128-lane sublanes; the
                     pack's k_step is 128 * k_step_sublanes
  partition          row partitioning for sharding: 'nnz' (paper's
                     nnz-guided split) or 'count' (naive row count)
  accumulation       distributed accumulation strategy (core/distributed):
                     'allreduce' (all-in-one), 'reduce_scatter'
                     (per-buffer/interval), or 'halo' (effective)
  nrhs               right-hand-side block width the plan was tuned for
                     (1 = classic SpMV; >1 = multi-RHS SpMM, the batched
                     serving / block-Krylov shape).  Execution accepts any
                     width — nrhs records the tuned operating point.
  index_dtype        index-stream dtype of the windowed packs ('kernel'/
                     'flat'): 'int32' (default) or 'int16', which halves
                     the index stream whenever the padded window fits in
                     16 bits (local window offsets are small on banded
                     matrices) — the tuner proposes both and measures.
  value_dtype        value-stream dtype of the windowed packs: 'float32'
                     (default) or 'bfloat16', which halves the value
                     stream.  Enumerated only for numerically-symmetric
                     (well-conditioned suite) classes and accuracy-gated
                     in the tuner before it can win.
  strategy           which executor serves the plan (serve/executor.py):
                     'local' — single-device SpmvOperator; 'mesh' — the
                     distributed strategies of core/distributed.py across
                     ``mesh_p`` shards, with ``accumulation`` naming the
                     collective.  Chosen per (matrix, p) by the tuner's
                     mesh-aware mode.
  mesh_p             mesh width the plan was tuned for (1 for local
                     plans; the shard count of a 'mesh' plan).

Plans are plain data: JSON-serializable, hashable, comparable.  The tuner
(core/tuner.py) enumerates feasible plans from matrix statistics, measures
them, and caches the argmin per matrix fingerprint.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict

# Valid ExecutionPlan.path values.  Seeded with the names every install
# ships; paths.register_path() appends new ones at registration time, so a
# new kernel path never edits this module.
PATHS = ["kernel", "segment", "colorful"]


def register_path_name(name: str) -> None:
    """Called by paths.register_path: makes ``name`` a valid plan path."""
    if name not in PATHS:
        PATHS.append(name)


PARTITIONS = ("nnz", "count")
ACCUMULATIONS = ("allreduce", "reduce_scatter", "halo")
# Index-stream dtypes the windowed packs support (blockell.pack /
# csrc_spmv_flat.pack_flat): 'int16' halves the index stream whenever the
# padded window fits (w_pad + 1 <= 32767) — the paper's §1 index
# compression (Williams et al.) as a tunable plan field.
INDEX_DTYPES = ("int32", "int16")
# Value-stream dtypes the windowed packs support: 'bfloat16' halves the
# value stream (SpMV is bandwidth-bound); the tuner only proposes it for
# numerically-symmetric classes and rejects it when the accuracy check
# fails (core/tuner.py VALUE_DTYPE_TOL).
VALUE_DTYPES = ("float32", "bfloat16")
# Executor strategies (serve/executor.py): 'local' = single-device
# SpmvOperator, 'mesh' = distributed product over mesh_p shards with the
# plan's accumulation as the collective pattern.
STRATEGIES = ("local", "mesh")
# Coloring providers of the colorful path (core/coloring.py): 'greedy' is
# the sequential largest-degree-first coloring, 'race' the recursive
# level-group scheme (arXiv:1907.06487) — fewer, locality-preserving
# classes on banded and mesh-born matrices.  The tuner proposes both and
# measures; the field is inert on every other path.
COLORINGS = ("greedy", "race")
# Kernel body variants of the Pallas paths ('kernel'/'flat'/'nnzsplit'):
# 'onehot' realizes gather/scatter as one-hot MXU contractions — O(W) work
# per slot, compute-bound but Mosaic-safe on compiled TPU; 'stream' gathers
# via per-lane indexing + segment-sum over the precomputed lane offsets —
# O(1) work per slot, the bandwidth-bound shape the paper requires.  Both
# share the same pack artifacts (variant is not an artifact field); the
# tuner measures both and picks per matrix.
VARIANTS = ("onehot", "stream")

LANES = 128                     # TPU lane count; sublane unit for k_step


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """A fully-resolved SpMV execution strategy (no 'auto' anywhere)."""

    path: str = "segment"
    tm: int = 128
    w_cap: int = 4096
    k_step_sublanes: int = 8
    partition: str = "nnz"
    accumulation: str = "allreduce"
    nrhs: int = 1
    index_dtype: str = "int32"
    value_dtype: str = "float32"
    strategy: str = "local"
    mesh_p: int = 1
    variant: str = "onehot"
    # colorful-path coloring provider; plans serialized before this field
    # existed load with the greedy default (from_dict fills missing fields)
    coloring: str = "greedy"

    def __post_init__(self):
        if self.path not in PATHS:
            # a registered-but-not-yet-imported path (e.g. 'flat' before
            # anything touched the registry): loading core.paths runs the
            # built-in registrations, which extend PATHS
            from . import paths as _paths  # noqa: F401
            if self.path not in PATHS:
                raise ValueError(
                    f"path {self.path!r} not in {tuple(PATHS)}")
        if self.partition not in PARTITIONS:
            raise ValueError(
                f"partition {self.partition!r} not in {PARTITIONS}")
        if self.accumulation not in ACCUMULATIONS:
            raise ValueError(
                f"accumulation {self.accumulation!r} not in {ACCUMULATIONS}")
        if self.tm < 1:
            raise ValueError(f"tm must be >= 1, got {self.tm}")
        if self.k_step_sublanes < 1:
            raise ValueError(
                f"k_step_sublanes must be >= 1, got {self.k_step_sublanes}")
        if self.nrhs < 1:
            raise ValueError(f"nrhs must be >= 1, got {self.nrhs}")
        if self.index_dtype not in INDEX_DTYPES:
            raise ValueError(
                f"index_dtype {self.index_dtype!r} not in {INDEX_DTYPES}")
        if self.value_dtype not in VALUE_DTYPES:
            raise ValueError(
                f"value_dtype {self.value_dtype!r} not in {VALUE_DTYPES}")
        if self.strategy not in STRATEGIES:
            raise ValueError(
                f"strategy {self.strategy!r} not in {STRATEGIES}")
        if self.mesh_p < 1:
            raise ValueError(f"mesh_p must be >= 1, got {self.mesh_p}")
        if self.strategy == "local" and self.mesh_p != 1:
            raise ValueError(
                f"local plans run on one device; mesh_p {self.mesh_p} "
                "requires strategy='mesh'")
        if self.variant not in VARIANTS:
            raise ValueError(
                f"variant {self.variant!r} not in {VARIANTS}")
        if self.coloring not in COLORINGS:
            raise ValueError(
                f"coloring {self.coloring!r} not in {COLORINGS}")

    @property
    def k_step(self) -> int:
        return LANES * self.k_step_sublanes

    def key(self) -> str:
        """Stable short identifier (used in cache timing tables and CSV)."""
        rhs = f":r{self.nrhs}" if self.nrhs != 1 else ""
        mesh = f":mesh{self.mesh_p}" if self.strategy == "mesh" else ""
        st = ":st" if self.variant == "stream" else ""
        if self.path in ("kernel", "flat"):
            i16 = ":i16" if self.index_dtype == "int16" else ""
            bf16 = ":bf16" if self.value_dtype == "bfloat16" else ""
            return (f"{self.path}:tm{self.tm}:ks{self.k_step_sublanes}"
                    f"{i16}{bf16}{st}"
                    f":{self.partition}:{self.accumulation}{rhs}{mesh}")
        if self.path == "nnzsplit":
            # no tm: chunking is row-independent; ks sets the chunk size
            i16 = ":i16" if self.index_dtype == "int16" else ""
            bf16 = ":bf16" if self.value_dtype == "bfloat16" else ""
            return (f"{self.path}:ks{self.k_step_sublanes}{i16}{bf16}{st}"
                    f":{self.partition}:{self.accumulation}{rhs}{mesh}")
        # colorful keys carry the non-default provider (':race'); greedy
        # keys are byte-identical to pre-provider caches
        col = (":race" if self.path == "colorful"
               and self.coloring == "race" else "")
        return (f"{self.path}{col}:{self.partition}:{self.accumulation}"
                f"{rhs}{mesh}")

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "ExecutionPlan":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ExecutionPlan":
        return cls.from_dict(json.loads(s))


def kernel_window(tm: int, bandwidth: int) -> int:
    """The padded window width the block-ELL pack would use (blockell.pack):
    round_up(tm + bandwidth, max(128, tm))."""
    return _round_up(tm + bandwidth, max(LANES, tm))


def feasible(plan: ExecutionPlan, *, n: int, m: int, bandwidth: int) -> bool:
    """Can this plan execute the matrix at all?

    Delegates to the plan path's registry entry (core/paths.py):

    * 'segment' handles everything, including the rectangular tail;
    * 'kernel' / 'flat' need a square matrix whose window fits under w_cap
      (the bandwidth gate — the packer cannot tile anything wider);
    * 'colorful' needs a square matrix (the color loop covers only the
      structurally-symmetric part).
    """
    from . import paths as paths_mod
    return paths_mod.get_path(plan.path).feasible(
        plan, n=n, m=m, bandwidth=bandwidth)


DEFAULT_PLAN = ExecutionPlan()
