"""The KernelPath registry: one registration per execution path.

Before this layer, adding a kernel path meant editing five places in
lock-step: the ``if path == ...`` chain in ``kernels/ops.py``, the
validation tuple and feasibility function in ``core/plan.py``, the
candidate enumeration in ``core/tuner.py``, and the artifact
build/serialize branches in ``core/schedule.py``.  The registry collapses
those into one record per path (docs/DESIGN.md §3):

  name              the ``ExecutionPlan.path`` value
  feasible          can this path execute a matrix with these shape stats
                    at all (the tuner filters candidates through this —
                    an infeasible plan is rejected up front, never
                    mid-tune)
  candidates        tuner candidate enumerator: the plans worth measuring
                    for a matrix with the given statistics
  artifact_fields   the plan fields the schedule artifact depends on
                    (plans differing only elsewhere share one artifact)
  build_artifact    packer / coloring builder -> SpmvSchedule field dict
  save_artifact     npz serialization of those fields (meta, arrays)
  load_artifact     the inverse; versioned via schedule.SCHEDULE_VERSION
  make_spmv         executor factory, x of shape (m,)
  make_spmm         executor factory, X of shape (m, r)
  refresh_values    same-structure value-stream refresh (FEM time
                    stepping; schedule.refresh_schedule) — optional

``register_path`` wires the name into ``plan.PATHS`` (so ``ExecutionPlan``
validation accepts it) and makes the path visible to the operator, the
schedule layer, the tuner, and — through schedule's shard-layout builders —
the distributed strategies.  Adding a path is one registration, not five
edits; the built-in registrations below double as the template.

Executors live in ``repro.kernels`` — imported lazily inside the factory
functions so the core package keeps its import order (kernels imports
core, never the reverse at module load).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

from .plan import ExecutionPlan, kernel_window, register_path_name

# Build probe: how many times each expensive structure precomputation ran.
# Tests (and ops dashboards) diff these counters around a cache-hit path to
# assert that no re-pack / re-partition / re-coloring happened.  (Re-exported
# as ``schedule.BUILD_COUNTS`` — same object.)
#
# Since the obs spine landed this is a thin dict-like compat shim over the
# real ``build_total{kind=...}`` counter family in ``repro.obs.REGISTRY``:
# reads (``BUILD_COUNTS['pack']``, ``dict(BUILD_COUNTS)``, ``.items()``)
# behave exactly like the old collections.Counter, and the build sites call
# ``BUILD_COUNTS.inc(kind)``.  Direct item assignment (the old
# ``BUILD_COUNTS[k] += 1`` pattern) still works but is deprecated — it warns and will be removed once
# external probes migrate to ``obs.counter('build_total', kind=...)``.
class BuildCounts:
    """Counter-compatible view over the ``build_total`` metric family."""

    FAMILY = "build_total"
    _HELP = ("expensive structure precomputations (pack / partition / "
             "coloring / shard layouts) that actually ran")

    def _family(self):
        from repro import obs
        return obs.REGISTRY.family(self.FAMILY, "counter", ("kind",),
                                   help=self._HELP)

    def inc(self, kind: str, v: int = 1):
        """Record ``v`` builds of this kind.  Counts even when metrics
        are disabled: the probe is a correctness assertion, not
        telemetry."""
        self._family().labels(kind=kind).inc_always(v)

    def __getitem__(self, kind: str) -> int:
        child = self._family().children.get((str(kind),))
        return 0 if child is None else int(child.value)

    def __setitem__(self, kind: str, v):
        import warnings
        warnings.warn(
            "direct BUILD_COUNTS mutation is deprecated; use "
            "BUILD_COUNTS.inc(kind) or obs.counter('build_total', ...)",
            DeprecationWarning, stacklevel=2)
        self._family().labels(kind=kind).set_always(v)

    def get(self, kind: str, default: int = 0) -> int:
        v = self[kind]
        return v if (str(kind),) in self._family().children else default

    def keys(self):
        return [k for (k,) in self._family().children]

    def values(self):
        return [int(c.value) for c in self._family().children.values()]

    def items(self):
        return [(k, int(c.value))
                for (k,), c in self._family().children.items()]

    def __iter__(self):
        return iter(self.keys())

    def __len__(self) -> int:
        return len(self._family().children)

    def __contains__(self, kind) -> bool:
        return (str(kind),) in self._family().children

    def __repr__(self) -> str:
        return f"BuildCounts({dict(self.items())!r})"


BUILD_COUNTS = BuildCounts()


@dataclasses.dataclass(frozen=True)
class CandidateSpace:
    """The degrees of freedom ``tuner.enumerate_plans`` sweeps, plus the
    analytically-chosen distributed fields every candidate inherits."""
    tms: Tuple[int, ...] = (32, 128)
    k_steps_sublanes: Tuple[int, ...] = (8,)
    w_cap: int = 4096
    colorful_max_n: int = 2048
    partition: str = "nnz"
    accumulation: str = "allreduce"
    # index-stream dtypes the windowed enumerators propose; 'int16' is
    # emitted only where the pack supports it (window fits in 16 bits),
    # letting the tuner trade index bandwidth per matrix
    index_dtypes: Tuple[str, ...] = ("int32", "int16")
    # value-stream dtypes the windowed enumerators propose; 'bfloat16' is
    # emitted only for numerically-symmetric matrices (the well-conditioned
    # suite classes) and must additionally pass the tuner's accuracy check
    # before it can win
    value_dtypes: Tuple[str, ...] = ("float32", "bfloat16")
    # chunk sizes (sublanes; S = ks*128 stream entries per chunk) the
    # nnz-split enumerator sweeps: small chunks bound the per-chunk row
    # window, large chunks amortize the per-program overhead
    nnzsplit_ks: Tuple[int, ...] = (2, 8)
    # kernel body variants the Pallas-path enumerators propose: 'stream'
    # (per-lane gather + segment-sum, bandwidth-bound) and 'onehot' (MXU
    # one-hot contraction fallback).  Both share one pack artifact —
    # variant is not an artifact field — so proposing both costs no extra
    # schedule builds.
    variants: Tuple[str, ...] = ("stream", "onehot")
    # coloring providers the colorful enumerator proposes (core/coloring):
    # 'greedy' sequential first-fit and 'race' recursive level-groups.
    # The provider is an artifact field — greedy and race schedules cache
    # under distinct keys — and the cost model prices the locality gap
    # (launch count x reuse distance) so predict-then-measure separates
    # them before the first coloring is ever built.
    colorings: Tuple[str, ...] = ("greedy", "race")


@dataclasses.dataclass(frozen=True)
class ShardSupport:
    """How a path executes *shard-locally* inside the distributed
    strategies (core/distributed.py) and the serving ``MeshExecutor``.

    A path without one (``KernelPath.shard_support is None``) still works
    on a mesh — the strategies fall back to the segment-sum shard-local
    product — but a path that registers one is served end-to-end by all
    three accumulation strategies over its own per-shard sub-packs, with
    the schedule layer memoizing/shipping the layouts and
    ``refresh_shard_layout`` refreshing their value streams.  This is the
    registry's answer to the former ``if plan.path == 'flat'`` special
    cases in distributed.py / executor.py / tuner.py / schedule.py.

      shards_kind     npz-kind + BUILD_COUNTS key of the row-partition
                      layout (allreduce / reduce_scatter)
      halo_kind       likewise for the local-coordinate halo layout
      layout_classes  () -> {kind: dataclass} (lazy kernel import)
      geometry        plan -> the plan-derived geometry tuple layouts are
                      keyed by (memoization + npz cache keys)
      pack_shards     (M, starts, plan) -> shards layout
      pack_halo       (M, p, plan) -> halo layout (ValueError: band gate)
      refresh_shards  (layout, M, starts) -> value-refreshed layout
      refresh_halo    (layout, M) -> value-refreshed layout
      shard_arrays    layout -> tuple of leading-axis-p device arrays
      shard_specs     axis name -> matching shard_map PartitionSpecs
      local_fn        (layout, n_local, interpret) -> local product
                      fn(*shard_arrays, x) -> y  (n_local rows)
      halo_dims       halo layout -> (ns, h, n_local)
    """
    shards_kind: str
    halo_kind: str
    layout_classes: Callable[[], dict]
    geometry: Callable[[ExecutionPlan], tuple]
    pack_shards: Callable[..., object]
    pack_halo: Callable[..., object]
    refresh_shards: Callable[..., object]
    refresh_halo: Callable[..., object]
    shard_arrays: Callable[[object], tuple]
    shard_specs: Callable[[str], tuple]
    local_fn: Callable[..., Callable]
    halo_dims: Callable[[object], tuple]


@dataclasses.dataclass(frozen=True)
class KernelPath:
    """Everything the plan/schedule/tuner/operator stack needs to know
    about one execution path."""
    name: str
    feasible: Callable[..., bool]
    candidates: Callable[..., list]
    artifact_fields: Callable[[ExecutionPlan], tuple]
    build_artifact: Callable[..., dict]
    save_artifact: Callable[..., Tuple[dict, dict]]
    load_artifact: Callable[..., dict]
    make_spmv: Callable[..., Callable]
    make_spmm: Callable[..., Callable]
    # Same-structure value refresh (M, schedule) -> updated artifact field
    # dict (schedule.refresh_schedule).  None means the path's artifact is
    # purely structural (or absent) and is reused as-is — the executors
    # read values from the matrix directly ('segment', 'colorful').
    refresh_values: Optional[Callable[..., dict]] = None
    # Shard-local execution hooks for the distributed strategies and the
    # serving MeshExecutor.  None means the path runs shard-locally as
    # segment-sum (distributed.py's fallback).
    shard_support: Optional[ShardSupport] = None


_REGISTRY: Dict[str, KernelPath] = {}


def register_path(entry: KernelPath) -> KernelPath:
    """Register a path.  The name becomes a valid ``ExecutionPlan.path``,
    the candidates join every tuner enumeration, the artifact builder is
    called by ``schedule.build_schedule``, and the executors by
    ``SpmvOperator``."""
    _REGISTRY[entry.name] = entry
    register_path_name(entry.name)
    return entry


def get_path(name: str) -> KernelPath:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"no kernel path {name!r} registered "
                       f"(known: {sorted(_REGISTRY)})") from None


def registered_paths() -> Tuple[KernelPath, ...]:
    return tuple(_REGISTRY.values())


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------

def _always_feasible(plan, *, n, m, bandwidth) -> bool:
    return True


def _square_feasible(plan, *, n, m, bandwidth) -> bool:
    return n == m


def _windowed_feasible(plan, *, n, m, bandwidth) -> bool:
    """Square matrix whose padded window fits under the plan's cap — the
    bandwidth gate shared by the rectangular-grid and flat-grid kernels.
    An int16 index stream additionally needs the window (and its padding
    sentinel, index == W) to fit in 16 bits."""
    if n != m:
        return False
    w = kernel_window(plan.tm, bandwidth)
    if w > plan.w_cap:
        return False
    return plan.index_dtype != "int16" or w + 1 <= 32767


def _no_artifact(M, plan, coloring=None) -> dict:
    return {}


def _save_nothing(sched):
    return {}, {}


def _load_nothing(meta, z) -> dict:
    return {}


def _empty_fields(plan) -> tuple:
    return ()


def _windowed_fields(plan) -> tuple:
    return (plan.tm, plan.w_cap, plan.k_step_sublanes, plan.index_dtype,
            plan.value_dtype)


def _windowed_candidates(path, stats, space):
    out = []
    if stats.n != stats.m:
        return out
    for tm in space.tms:
        w = kernel_window(tm, stats.bandwidth)
        if w > space.w_cap:
            continue
        for ks in space.k_steps_sublanes:
            for idt in space.index_dtypes:
                if idt == "int16" and w + 1 > 32767:
                    continue        # window overflows 16-bit offsets
                for vdt in space.value_dtypes:
                    if (vdt == "bfloat16"
                            and not stats.numerically_symmetric):
                        # bf16 value streams are proposed only for the
                        # numerically-symmetric (well-conditioned) classes
                        continue
                    for var in space.variants:
                        out.append(ExecutionPlan(
                            path=path, tm=tm, w_cap=space.w_cap,
                            k_step_sublanes=ks, index_dtype=idt,
                            value_dtype=vdt, variant=var,
                            partition=space.partition,
                            accumulation=space.accumulation))
    return out


# ---------------------------------------------------------------------------
# 'segment' — segment-sum jnp path (any matrix, incl. the rectangular tail)
# ---------------------------------------------------------------------------

def _segment_candidates(stats, space):
    return [ExecutionPlan(path="segment", w_cap=space.w_cap,
                          partition=space.partition,
                          accumulation=space.accumulation)]


def _segment_make_spmv(M, schedule, plan, *, interpret=True, coloring=None):
    from repro.kernels import ref
    return lambda x: ref.csrc_spmv(M, x)


def _segment_make_spmm(M, schedule, plan, *, interpret=True, coloring=None):
    from repro.kernels import ref
    return lambda X: ref.csrc_spmm(M, X)


register_path(KernelPath(
    name="segment",
    feasible=_always_feasible,
    candidates=_segment_candidates,
    artifact_fields=_empty_fields,
    build_artifact=_no_artifact,
    save_artifact=_save_nothing,
    load_artifact=_load_nothing,
    make_spmv=_segment_make_spmv,
    make_spmm=_segment_make_spmm,
))


# ---------------------------------------------------------------------------
# 'kernel' — rectangular-grid block-ELL Pallas kernel (banded matrices)
# ---------------------------------------------------------------------------

def _index_dtype_of(plan):
    import jax.numpy as jnp
    return jnp.int16 if plan.index_dtype == "int16" else jnp.int32


def _value_dtype_of(plan):
    import jax.numpy as jnp
    return (jnp.bfloat16 if plan.value_dtype == "bfloat16"
            else jnp.float32)


def _kernel_build(M, plan, coloring=None) -> dict:
    from . import blockell
    if not M.is_square:
        raise ValueError(
            "kernel path packs the square CSRC part only; "
            "use 'segment' for rectangular matrices")
    BUILD_COUNTS.inc("pack")
    return {"pack": blockell.pack(M, tm=plan.tm, k_step=plan.k_step,
                                  w_cap=plan.w_cap,
                                  dtype=_value_dtype_of(plan),
                                  index_dtype=_index_dtype_of(plan))}


def _kernel_save(sched):
    import numpy as np
    pk = sched.pack
    # value streams are persisted as float32 (bf16 -> f32 widening is
    # lossless; numpy npz has no native bfloat16) and re-narrowed on load
    # to the dtype recorded in the meta
    meta = {"pack": {"n": pk.n, "tm": pk.tm, "nt": pk.nt,
                     "w_pad": pk.w_pad, "s": pk.s,
                     "num_symmetric": bool(pk.num_symmetric),
                     "value_dtype": str(pk.vals_l.dtype),
                     "pad_ratio": pk.pad_ratio}}
    arrays = dict(
        pack_vals_l=np.asarray(pk.vals_l, dtype=np.float32),
        pack_vals_u=np.asarray(pk.vals_u, dtype=np.float32),
        pack_col_local=np.asarray(pk.col_local),
        pack_row_in_win=np.asarray(pk.row_in_win),
        pack_ad=np.asarray(pk.ad, dtype=np.float32),
    )
    return meta, arrays


def _kernel_load(meta, z) -> dict:
    import jax.numpy as jnp
    from .blockell import BlockEll
    pm = meta["pack"]
    vdt = jnp.dtype(pm.get("value_dtype", "float32"))
    return {"pack": BlockEll(
        n=pm["n"], tm=pm["tm"], nt=pm["nt"], w_pad=pm["w_pad"], s=pm["s"],
        vals_l=jnp.asarray(z["pack_vals_l"], dtype=vdt),
        vals_u=jnp.asarray(z["pack_vals_u"], dtype=vdt),
        col_local=jnp.asarray(z["pack_col_local"]),
        row_in_win=jnp.asarray(z["pack_row_in_win"]),
        ad=jnp.asarray(z["pack_ad"], dtype=vdt),
        num_symmetric=bool(pm["num_symmetric"]),
        pad_ratio=float(pm["pad_ratio"]),
    )}


def _kernel_refresh(M, sched) -> dict:
    from . import blockell
    return {"pack": blockell.refresh_values(sched.pack, M)}


def _kernel_make_spmv(M, schedule, plan, *, interpret=True, coloring=None):
    if plan.variant == "stream":
        from repro.kernels import csrc_spmv_stream as stream_mod
        return functools.partial(stream_mod.blockell_spmv_stream,
                                 schedule.pack, interpret=interpret,
                                 k_step_sublanes=plan.k_step_sublanes)
    from repro.kernels import csrc_spmv as kernel_mod
    return functools.partial(kernel_mod.blockell_spmv, schedule.pack,
                             interpret=interpret,
                             k_step_sublanes=plan.k_step_sublanes)


def _kernel_make_spmm(M, schedule, plan, *, interpret=True, coloring=None):
    if plan.variant == "stream":
        from repro.kernels import csrc_spmv_stream as stream_mod
        return functools.partial(stream_mod.blockell_spmm_stream,
                                 schedule.pack, interpret=interpret,
                                 k_step_sublanes=plan.k_step_sublanes)
    from repro.kernels import csrc_spmm as kernel_mm_mod
    return functools.partial(kernel_mm_mod.blockell_spmm, schedule.pack,
                             interpret=interpret,
                             k_step_sublanes=plan.k_step_sublanes)


register_path(KernelPath(
    name="kernel",
    feasible=_windowed_feasible,
    candidates=functools.partial(_windowed_candidates, "kernel"),
    artifact_fields=_windowed_fields,
    build_artifact=_kernel_build,
    save_artifact=_kernel_save,
    load_artifact=_kernel_load,
    make_spmv=_kernel_make_spmv,
    make_spmm=_kernel_make_spmm,
    refresh_values=_kernel_refresh,
))


# ---------------------------------------------------------------------------
# 'colorful' — the paper's §3.2 color-by-color permutation writes
# ---------------------------------------------------------------------------

def _colorful_candidates(stats, space):
    if (stats.n != stats.m or stats.n > space.colorful_max_n
            or stats.k == 0):
        return []
    return [ExecutionPlan(path="colorful", w_cap=space.w_cap,
                          partition=space.partition,
                          accumulation=space.accumulation,
                          coloring=provider)
            for provider in space.colorings]


def _colorful_fields(plan) -> tuple:
    # greedy and race colorings are different artifacts: the provider joins
    # the schedule cache key so the two never collide
    return (plan.coloring,)


def _colorful_build(M, plan, coloring=None) -> dict:
    from .coloring import color_rows
    from . import schedule as schedule_mod
    if not M.is_square:
        raise ValueError(
            "colorful path covers the square CSRC part only; "
            "use 'segment' for rectangular matrices")
    if coloring is None:
        BUILD_COUNTS.inc("coloring")
        col = color_rows(M, provider=plan.coloring)
    else:
        col = coloring
    slots, ptr = schedule_mod.color_slot_batches(M, col)
    return {"coloring": col, "color_slots": slots, "color_slot_ptr": ptr}


def _colorful_save(sched):
    import numpy as np
    col = sched.coloring
    meta = {"num_colors": int(col.num_colors),
            "coloring_provider": col.provider}
    arrays = dict(
        color_of_row=np.asarray(col.color_of_row),
        rows_by_color=np.asarray(col.rows_by_color),
        color_ptr=np.asarray(col.color_ptr),
        color_slots=np.asarray(sched.color_slots),
        color_slot_ptr=np.asarray(sched.color_slot_ptr),
    )
    # RACE level-group metadata rides along so a loaded schedule keeps the
    # chunk-aware conflict invariant verifiable without re-coloring
    if col.level_of_row is not None:
        arrays["color_level_of_row"] = np.asarray(col.level_of_row)
    if col.group_of_row is not None:
        arrays["color_group_of_row"] = np.asarray(col.group_of_row)
    return meta, arrays


def _colorful_load(meta, z) -> dict:
    from .coloring import Coloring
    files = getattr(z, "files", z)
    return {
        "coloring": Coloring(
            color_of_row=z["color_of_row"],
            num_colors=int(meta["num_colors"]),
            rows_by_color=z["rows_by_color"],
            color_ptr=z["color_ptr"],
            provider=meta.get("coloring_provider", "greedy"),
            level_of_row=(z["color_level_of_row"]
                          if "color_level_of_row" in files else None),
            group_of_row=(z["color_group_of_row"]
                          if "color_group_of_row" in files else None)),
        "color_slots": z["color_slots"],
        "color_slot_ptr": z["color_slot_ptr"],
    }


def _colorful_make(M, schedule, plan, *, interpret=True, coloring=None):
    from . import schedule as schedule_mod
    slots, ptr = schedule.color_slots, schedule.color_slot_ptr
    if coloring is not None and coloring is not schedule.coloring:
        slots, ptr = schedule_mod.color_slot_batches(M, coloring)
    elif slots is None:
        slots, ptr = schedule_mod.color_slot_batches(M, schedule.coloring)
    return functools.partial(schedule_mod.colorful_apply, M,
                             color_slots=slots, color_slot_ptr=ptr)


register_path(KernelPath(
    name="colorful",
    feasible=_square_feasible,
    candidates=_colorful_candidates,
    artifact_fields=_colorful_fields,
    build_artifact=_colorful_build,
    save_artifact=_colorful_save,
    load_artifact=_colorful_load,
    make_spmv=_colorful_make,
    make_spmm=_colorful_make,       # colorful_apply handles (m,) and (m, r)
))


# ---------------------------------------------------------------------------
# 'flat' — flat-grid block-ELL Pallas kernel (skewed row-length matrices)
# ---------------------------------------------------------------------------

# Candidate gate: coefficient of variation of nnz-per-row above which the
# rectangular grid's per-tile padding is expected to waste bandwidth and
# the flat grid becomes worth measuring.  (Feasibility — can the matrix be
# tiled at all — is _windowed_feasible, identical to the rectangular
# kernel; the skew statistic only gates *enumeration*.)
FLAT_SKEW_MIN = 0.25


def flat_worth_measuring(stats) -> bool:
    """The flat enumerator's skew gate, shared with benchmarks: is the
    nnz-per-row spread large enough that per-tile-exact packing could
    beat the rectangular grid?"""
    return stats.nnz_row_dev > FLAT_SKEW_MIN * max(stats.nnz_row_mean, 1.0)


def _flat_candidates(stats, space):
    if not flat_worth_measuring(stats):
        return []
    return _windowed_candidates("flat", stats, space)


def _flat_build(M, plan, coloring=None) -> dict:
    from repro.kernels import csrc_spmv_flat as flat_mod
    if not M.is_square:
        raise ValueError(
            "flat path packs the square CSRC part only; "
            "use 'segment' for rectangular matrices")
    BUILD_COUNTS.inc("flat_pack")
    return {"flat_pack": flat_mod.pack_flat(
        M, tm=plan.tm, ks=plan.k_step_sublanes, w_cap=plan.w_cap,
        dtype=_value_dtype_of(plan),
        index_dtype=_index_dtype_of(plan))}


def _flat_save(sched):
    import numpy as np
    pk = sched.flat_pack
    meta = {"flat_pack": {"n": pk.n, "tm": pk.tm, "nt": pk.nt,
                          "w_pad": pk.w_pad,
                          "total_steps": pk.total_steps, "ks": pk.ks,
                          "num_symmetric": bool(pk.num_symmetric),
                          "value_dtype": str(pk.vals_l.dtype),
                          "pad_ratio": pk.pad_ratio}}
    arrays = dict(
        flat_vals_l=np.asarray(pk.vals_l, dtype=np.float32),
        flat_vals_u=np.asarray(pk.vals_u, dtype=np.float32),
        flat_col_local=np.asarray(pk.col_local),
        flat_row_in_win=np.asarray(pk.row_in_win),
        flat_ad=np.asarray(pk.ad, dtype=np.float32),
        flat_tile_of_step=np.asarray(pk.tile_of_step),
        flat_first_of_tile=np.asarray(pk.first_of_tile),
    )
    return meta, arrays


def _flat_load(meta, z) -> dict:
    import jax.numpy as jnp
    from repro.kernels.csrc_spmv_flat import FlatBlockEll
    pm = meta["flat_pack"]
    vdt = jnp.dtype(pm.get("value_dtype", "float32"))
    return {"flat_pack": FlatBlockEll(
        n=pm["n"], tm=pm["tm"], nt=pm["nt"], w_pad=pm["w_pad"],
        total_steps=pm["total_steps"], ks=pm["ks"],
        vals_l=jnp.asarray(z["flat_vals_l"], dtype=vdt),
        vals_u=jnp.asarray(z["flat_vals_u"], dtype=vdt),
        col_local=jnp.asarray(z["flat_col_local"]),
        row_in_win=jnp.asarray(z["flat_row_in_win"]),
        ad=jnp.asarray(z["flat_ad"], dtype=vdt),
        tile_of_step=jnp.asarray(z["flat_tile_of_step"]),
        first_of_tile=jnp.asarray(z["flat_first_of_tile"]),
        num_symmetric=bool(pm["num_symmetric"]),
        pad_ratio=float(pm["pad_ratio"]),
    )}


def _flat_refresh(M, sched) -> dict:
    from repro.kernels import csrc_spmv_flat as flat_mod
    return {"flat_pack": flat_mod.refresh_flat_values(sched.flat_pack, M)}


def _flat_make_spmv(M, schedule, plan, *, interpret=True, coloring=None):
    if plan.variant == "stream":
        from repro.kernels import csrc_spmv_stream as stream_mod
        return functools.partial(stream_mod.flat_spmv_stream,
                                 schedule.flat_pack, interpret=interpret)
    from repro.kernels import csrc_spmv_flat as flat_mod
    return functools.partial(flat_mod.flat_spmv, schedule.flat_pack,
                             interpret=interpret)


def _flat_make_spmm(M, schedule, plan, *, interpret=True, coloring=None):
    if plan.variant == "stream":
        from repro.kernels import csrc_spmv_stream as stream_mod
        return functools.partial(stream_mod.flat_spmm_stream,
                                 schedule.flat_pack, interpret=interpret)
    from repro.kernels import csrc_spmv_flat as flat_mod
    return functools.partial(flat_mod.flat_spmm, schedule.flat_pack,
                             interpret=interpret)


def _flat_layout_classes():
    from repro.kernels.csrc_spmv_flat import FlatHalo, FlatShards
    return {"flat_shards": FlatShards, "flat_halo": FlatHalo}


def _flat_geometry(plan):
    return (plan.tm, plan.k_step_sublanes, plan.w_cap, plan.index_dtype,
            plan.value_dtype)


def _flat_pack_shards(M, starts, plan):
    from repro.kernels import csrc_spmv_flat as flat_mod
    return flat_mod.pack_flat_shards(
        M, starts, tm=plan.tm, ks=plan.k_step_sublanes, w_cap=plan.w_cap,
        dtype=_value_dtype_of(plan), index_dtype=_index_dtype_of(plan))


def _flat_pack_halo(M, p, plan):
    from repro.kernels import csrc_spmv_flat as flat_mod
    return flat_mod.pack_flat_halo(
        M, p, tm=plan.tm, ks=plan.k_step_sublanes, w_cap=plan.w_cap,
        dtype=_value_dtype_of(plan), index_dtype=_index_dtype_of(plan))


def _flat_refresh_shards(lay, M, starts):
    from repro.kernels import csrc_spmv_flat as flat_mod
    return flat_mod.refresh_flat_shards(lay, M, starts)


def _flat_refresh_halo(lay, M):
    from repro.kernels import csrc_spmv_flat as flat_mod
    return flat_mod.refresh_flat_halo(lay, M)


def _flat_shard_arrays(lay):
    from repro.kernels import csrc_spmv_flat as flat_mod
    return flat_mod.flat_shard_arrays(lay)


def _flat_shard_specs(axis):
    from repro.kernels import csrc_spmv_flat as flat_mod
    return flat_mod.flat_shard_specs(axis)


def _flat_local_fn(lay, n_local, interpret):
    from repro.kernels import csrc_spmv_flat as flat_mod
    return flat_mod.flat_local_fn(lay, n_local, interpret)


def _flat_halo_dims(lay):
    from repro.kernels import csrc_spmv_flat as flat_mod
    return flat_mod.flat_halo_dims(lay)


FLAT_SHARD_SUPPORT = ShardSupport(
    shards_kind="flat_shards",
    halo_kind="flat_halo",
    layout_classes=_flat_layout_classes,
    geometry=_flat_geometry,
    pack_shards=_flat_pack_shards,
    pack_halo=_flat_pack_halo,
    refresh_shards=_flat_refresh_shards,
    refresh_halo=_flat_refresh_halo,
    shard_arrays=_flat_shard_arrays,
    shard_specs=_flat_shard_specs,
    local_fn=_flat_local_fn,
    halo_dims=_flat_halo_dims,
)


register_path(KernelPath(
    name="flat",
    feasible=_windowed_feasible,
    candidates=_flat_candidates,
    artifact_fields=_windowed_fields,
    build_artifact=_flat_build,
    save_artifact=_flat_save,
    load_artifact=_flat_load,
    make_spmv=_flat_make_spmv,
    make_spmm=_flat_make_spmm,
    refresh_values=_flat_refresh,
    shard_support=FLAT_SHARD_SUPPORT,
))


# ---------------------------------------------------------------------------
# 'nnzsplit' — merge-style equal-nnz chunking Pallas kernel (unstructured
# matrices: the CSRC analogue of merge-based CSR SpMV)
# ---------------------------------------------------------------------------

# Candidate gates.  The windowed paths lose in two distinct ways on
# unstructured matrices, and each gets a gate:
#  * skew: nnz-per-row CoV above this means even the flat grid's per-tile
#    packing pays for hub rows (power-law degree tails) — row-independent
#    chunking is worth measuring.  Deliberately above FLAT_SKEW_MIN: in
#    the moderate-skew band the flat path already wins and nnzsplit only
#    adds tuner work.
#  * spread: `ja` bandwidth above this fraction of n means the windowed
#    packs pad a window comparable to the whole matrix (random graphs,
#    circuits) — there is no band to exploit.
NNZSPLIT_SKEW_MIN = 2.0
NNZSPLIT_SPREAD_MIN = 0.25


def nnzsplit_worth_measuring(stats) -> bool:
    """The nnzsplit enumerator's gate, shared with benchmarks: is the
    matrix unstructured enough (heavy row-length tail OR non-banded column
    spread) that nnz-balanced chunking could beat the windowed paths?"""
    if stats.n != stats.m:
        return False
    cov = stats.nnz_row_dev / max(stats.nnz_row_mean, 1.0)
    return (cov > NNZSPLIT_SKEW_MIN
            or stats.bandwidth > NNZSPLIT_SPREAD_MIN * max(stats.n, 1))


def _nnzsplit_feasible(plan, *, n, m, bandwidth) -> bool:
    """Square matrices only; int16 gather indices additionally need every
    global index (src into x) to fit.  The per-chunk row window is checked
    at pack time against plan.w_cap (reused as the chunk-window cap) — it
    depends on row-gap statistics, not on the bandwidth stat."""
    if n != m:
        return False
    return plan.index_dtype != "int16" or n <= 32767


def _nnzsplit_candidates(stats, space):
    if not nnzsplit_worth_measuring(stats):
        return []
    out = []
    for ks in space.nnzsplit_ks:
        for idt in space.index_dtypes:
            if idt == "int16" and stats.n > 32767:
                continue        # gather index overflows 16 bits
            for vdt in space.value_dtypes:
                if (vdt == "bfloat16"
                        and not stats.numerically_symmetric):
                    continue
                for var in space.variants:
                    out.append(ExecutionPlan(
                        path="nnzsplit", w_cap=space.w_cap,
                        k_step_sublanes=ks, index_dtype=idt,
                        value_dtype=vdt, variant=var,
                        partition=space.partition,
                        accumulation=space.accumulation))
    return out


def _nnzsplit_fields(plan) -> tuple:
    # no tm: the chunking is row-independent; w_cap doubles as the
    # per-chunk row-window cap
    return (plan.k_step_sublanes, plan.w_cap, plan.index_dtype,
            plan.value_dtype)


def _nnzsplit_build(M, plan, coloring=None) -> dict:
    from repro.kernels import csrc_spmv_nnzsplit as nz_mod
    if not M.is_square:
        raise ValueError(
            "nnzsplit path chunks the square CSRC part only; "
            "use 'segment' for rectangular matrices")
    BUILD_COUNTS.inc("nnzsplit_pack")
    return {"nnzsplit_pack": nz_mod.pack_nnzsplit(
        M, ks=plan.k_step_sublanes, r_cap=plan.w_cap,
        dtype=_value_dtype_of(plan),
        index_dtype=_index_dtype_of(plan))}


def _nnzsplit_save(sched):
    import numpy as np
    pk = sched.nnzsplit_pack
    meta = {"nnzsplit_pack": {
        "n": pk.n, "num_chunks": pk.num_chunks, "ks": pk.ks,
        "r_pad": pk.r_pad, "num_symmetric": bool(pk.num_symmetric),
        "value_dtype": str(pk.vals.dtype),
        "pad_ratio": pk.pad_ratio}}
    arrays = dict(
        nnzsplit_vals=np.asarray(pk.vals, dtype=np.float32),
        nnzsplit_lrow=np.asarray(pk.lrow),
        nnzsplit_src=np.asarray(pk.src),
        nnzsplit_chunk_row0=np.asarray(pk.chunk_row0),
        nnzsplit_fixup_idx=np.asarray(pk.fixup_idx),
        nnzsplit_ad=np.asarray(pk.ad, dtype=np.float32),
    )
    return meta, arrays


def _nnzsplit_load(meta, z) -> dict:
    import jax.numpy as jnp
    from repro.kernels.csrc_spmv_nnzsplit import NnzSplitPack
    pm = meta["nnzsplit_pack"]
    vdt = jnp.dtype(pm.get("value_dtype", "float32"))
    return {"nnzsplit_pack": NnzSplitPack(
        n=pm["n"], num_chunks=pm["num_chunks"], ks=pm["ks"],
        r_pad=pm["r_pad"],
        vals=jnp.asarray(z["nnzsplit_vals"], dtype=vdt),
        lrow=jnp.asarray(z["nnzsplit_lrow"]),
        src=jnp.asarray(z["nnzsplit_src"]),
        chunk_row0=jnp.asarray(z["nnzsplit_chunk_row0"]),
        fixup_idx=jnp.asarray(z["nnzsplit_fixup_idx"]),
        ad=jnp.asarray(z["nnzsplit_ad"], dtype=vdt),
        num_symmetric=bool(pm["num_symmetric"]),
        pad_ratio=float(pm["pad_ratio"]),
    )}


def _nnzsplit_refresh(M, sched) -> dict:
    from repro.kernels import csrc_spmv_nnzsplit as nz_mod
    return {"nnzsplit_pack": nz_mod.refresh_nnzsplit_values(
        sched.nnzsplit_pack, M)}


def _nnzsplit_make_spmv(M, schedule, plan, *, interpret=True, coloring=None):
    if plan.variant == "stream":
        from repro.kernels import csrc_spmv_stream as stream_mod
        return functools.partial(stream_mod.nnzsplit_spmv_stream,
                                 schedule.nnzsplit_pack,
                                 interpret=interpret)
    from repro.kernels import csrc_spmv_nnzsplit as nz_mod
    return functools.partial(nz_mod.nnzsplit_spmv, schedule.nnzsplit_pack,
                             interpret=interpret)


def _nnzsplit_make_spmm(M, schedule, plan, *, interpret=True, coloring=None):
    if plan.variant == "stream":
        from repro.kernels import csrc_spmv_stream as stream_mod
        return functools.partial(stream_mod.nnzsplit_spmm_stream,
                                 schedule.nnzsplit_pack,
                                 interpret=interpret)
    from repro.kernels import csrc_spmv_nnzsplit as nz_mod
    return functools.partial(nz_mod.nnzsplit_spmm, schedule.nnzsplit_pack,
                             interpret=interpret)


def _nnzsplit_layout_classes():
    from repro.kernels.csrc_spmv_nnzsplit import NnzSplitHalo, NnzSplitShards
    return {"nnzsplit_shards": NnzSplitShards, "nnzsplit_halo": NnzSplitHalo}


def _nnzsplit_geometry(plan):
    return (plan.k_step_sublanes, plan.w_cap, plan.index_dtype,
            plan.value_dtype)


def _nnzsplit_pack_shards(M, starts, plan):
    from repro.kernels import csrc_spmv_nnzsplit as nz_mod
    return nz_mod.pack_nnzsplit_shards(
        M, starts, ks=plan.k_step_sublanes, r_cap=plan.w_cap,
        dtype=_value_dtype_of(plan), index_dtype=_index_dtype_of(plan))


def _nnzsplit_pack_halo(M, p, plan):
    from repro.kernels import csrc_spmv_nnzsplit as nz_mod
    return nz_mod.pack_nnzsplit_halo(
        M, p, ks=plan.k_step_sublanes, r_cap=plan.w_cap,
        dtype=_value_dtype_of(plan), index_dtype=_index_dtype_of(plan))


def _nnzsplit_refresh_shards(lay, M, starts):
    from repro.kernels import csrc_spmv_nnzsplit as nz_mod
    return nz_mod.refresh_nnzsplit_shards(lay, M, starts)


def _nnzsplit_refresh_halo(lay, M):
    from repro.kernels import csrc_spmv_nnzsplit as nz_mod
    return nz_mod.refresh_nnzsplit_halo(lay, M)


def _nnzsplit_shard_arrays(lay):
    from repro.kernels import csrc_spmv_nnzsplit as nz_mod
    return nz_mod.nnzsplit_shard_arrays(lay)


def _nnzsplit_shard_specs(axis):
    from repro.kernels import csrc_spmv_nnzsplit as nz_mod
    return nz_mod.nnzsplit_shard_specs(axis)


def _nnzsplit_local_fn(lay, n_local, interpret):
    from repro.kernels import csrc_spmv_nnzsplit as nz_mod
    return nz_mod.nnzsplit_local_fn(lay, n_local, interpret)


def _nnzsplit_halo_dims(lay):
    from repro.kernels import csrc_spmv_nnzsplit as nz_mod
    return nz_mod.nnzsplit_halo_dims(lay)


NNZSPLIT_SHARD_SUPPORT = ShardSupport(
    shards_kind="nnzsplit_shards",
    halo_kind="nnzsplit_halo",
    layout_classes=_nnzsplit_layout_classes,
    geometry=_nnzsplit_geometry,
    pack_shards=_nnzsplit_pack_shards,
    pack_halo=_nnzsplit_pack_halo,
    refresh_shards=_nnzsplit_refresh_shards,
    refresh_halo=_nnzsplit_refresh_halo,
    shard_arrays=_nnzsplit_shard_arrays,
    shard_specs=_nnzsplit_shard_specs,
    local_fn=_nnzsplit_local_fn,
    halo_dims=_nnzsplit_halo_dims,
)


register_path(KernelPath(
    name="nnzsplit",
    feasible=_nnzsplit_feasible,
    candidates=_nnzsplit_candidates,
    artifact_fields=_nnzsplit_fields,
    build_artifact=_nnzsplit_build,
    save_artifact=_nnzsplit_save,
    load_artifact=_nnzsplit_load,
    make_spmv=_nnzsplit_make_spmv,
    make_spmm=_nnzsplit_make_spmm,
    refresh_values=_nnzsplit_refresh,
    shard_support=NNZSPLIT_SHARD_SUPPORT,
))
