"""Iterative solvers on top of the CSRC SpMV engine.

The paper motivates SpMV as the dominant kernel of FEM iterative solvers
("a thousand products ... a reasonable value for iterative solvers like the
preconditioned conjugate gradient method and the generalized minimum
residual method").  We provide the two solver families its benchmark models:

  * cg        — preconditioned conjugate gradient (numerically symmetric
                positive-definite matrices; Jacobi preconditioner);
  * bicgstab  — for structurally-symmetric but numerically non-symmetric
                matrices (uses the O(1) CSRC transpose when needed).

Both are jax.lax.while_loop-based (jit-able end to end, dry-run lowerable)
and accept any ``spmv`` callable — single-chip kernel or the distributed
shard_map product — so the whole paper stack composes.

Multi-RHS: ``b`` may be (n,) or (n, r).  With a block of right-hand sides
the iterations run per column (independent alpha/beta per RHS) but share
one batched SpMM per step — the memory-bound matrix pass is amortized
across the block exactly as in block-Krylov methods, and the SpMV operator
(kernels/ops.py) executes it through its tuned plan.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class SolveResult(NamedTuple):
    x: jnp.ndarray
    iters: jnp.ndarray
    residual: jnp.ndarray         # max over RHS columns for block solves
    converged: jnp.ndarray


def _dot(a, b):
    """Per-column vdot: () for (n,) operands, (r,) for (n, r)."""
    return jnp.sum(a * b, axis=0)


def _norm(v):
    return jnp.sqrt(_dot(v, v))


def cg(spmv: Callable, b: jnp.ndarray, x0: Optional[jnp.ndarray] = None,
       tol: float = 1e-6, maxiter: int = 1000,
       diag: Optional[jnp.ndarray] = None) -> SolveResult:
    """Jacobi-preconditioned CG.  ``diag`` enables the preconditioner.
    ``b`` of shape (n, r) solves all r systems with one SpMM per step."""
    x0 = jnp.zeros_like(b) if x0 is None else x0
    inv_d = None if diag is None else jnp.where(diag != 0, 1.0 / diag, 1.0)
    if inv_d is not None and b.ndim == 2:
        inv_d = inv_d[:, None]

    def prec(r):
        return r if inv_d is None else inv_d * r

    r0 = b - spmv(x0)
    z0 = prec(r0)
    p0 = z0
    rz0 = _dot(r0, z0)
    bnorm = jnp.maximum(_norm(b), 1e-30)

    def res_of(r):
        return jnp.max(_norm(r) / bnorm)

    def cond(state):
        _, r, _, _, k, _ = state
        return (res_of(r) > tol) & (k < maxiter)

    def body(state):
        x, r, p, rz, k, _ = state
        ap = spmv(p)
        alpha = rz / jnp.maximum(_dot(p, ap), 1e-30)
        x = x + alpha * p
        r = r - alpha * ap
        z = prec(r)
        rz_new = _dot(r, z)
        beta = rz_new / jnp.maximum(rz, 1e-30)
        p = z + beta * p
        return (x, r, p, rz_new, k + 1, res_of(r))

    x, r, _, _, k, res = jax.lax.while_loop(
        cond, body, (x0, r0, p0, rz0, jnp.zeros((), jnp.int32),
                     res_of(r0)))
    return SolveResult(x=x, iters=k, residual=res, converged=res <= tol)


def bicgstab(spmv: Callable, b: jnp.ndarray,
             x0: Optional[jnp.ndarray] = None, tol: float = 1e-6,
             maxiter: int = 1000) -> SolveResult:
    """BiCGSTAB for non-symmetric systems; per-column scalars on (n, r)."""
    x0 = jnp.zeros_like(b) if x0 is None else x0
    r0 = b - spmv(x0)
    bnorm = jnp.maximum(_norm(b), 1e-30)
    ones = jnp.ones(b.shape[1:][:1] or ())
    init = (x0, r0, r0, ones, ones, ones,
            jnp.zeros_like(b), jnp.zeros_like(b),
            jnp.zeros((), jnp.int32), jnp.max(_norm(r0) / bnorm))

    def cond(s):
        return (s[-1] > tol) & (s[-2] < maxiter)

    def safe_div(a, d):
        # sign-preserving guard: BiCGSTAB denominators may be negative
        return a / jnp.where(jnp.abs(d) < 1e-30,
                             jnp.where(d < 0, -1e-30, 1e-30), d)

    def body(s):
        x, r, rh, rho, alpha, omega, v, p, k, _ = s
        rho_new = _dot(rh, r)
        beta = safe_div(rho_new, rho) * safe_div(alpha, omega)
        p = r + beta * (p - omega * v)
        v = spmv(p)
        alpha = safe_div(rho_new, _dot(rh, v))
        s_vec = r - alpha * v
        t = spmv(s_vec)
        omega = safe_div(_dot(t, s_vec), _dot(t, t))
        x = x + alpha * p + omega * s_vec
        r = s_vec - omega * t
        return (x, r, rh, rho_new, alpha, omega, v, p, k + 1,
                jnp.max(_norm(r) / bnorm))

    out = jax.lax.while_loop(cond, body, init)
    x, k, res = out[0], out[-2], out[-1]
    return SolveResult(x=x, iters=k, residual=res, converged=res <= tol)


def cg_solve(M, b: jnp.ndarray, *, plan=None, cache=None,
             autotune: bool = False, interpret: bool = True,
             x0: Optional[jnp.ndarray] = None, tol: float = 1e-6,
             maxiter: int = 1000, precondition: bool = True,
             **tune_kw) -> Tuple[SolveResult, object]:
    """Matrix-level CG: builds the SpMV operator through the plan/tuner
    subsystem instead of a hard-coded path.

    Resolution order: an explicit ``plan`` wins; else the plan-cache /
    tuner (``autotune=True`` measures candidates, ``False`` uses the
    measurement-free heuristic; either way a cache hit skips everything,
    including the schedule artifact — no re-pack).  ``b`` of shape (n, r)
    runs block CG through one batched SpMM per iteration.  Returns
    ``(SolveResult, operator)`` — the operator exposes the concrete plan
    it ran as ``op.plan`` and the artifact as ``op.schedule``.
    """
    from repro.core import tuner as _tuner
    from repro.kernels.ops import SpmvOperator

    if plan is None:
        plan = _tuner.plan_for(M, cache=cache, autotune=autotune,
                               interpret=interpret, **tune_kw)
    op = SpmvOperator.from_plan(M, plan, interpret=interpret, cache=cache)
    res = cg(op, b, x0=x0, tol=tol, maxiter=maxiter,
             diag=M.ad if precondition else None)
    return res, op
