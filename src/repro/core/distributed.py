"""Distributed CSRC SpMV: the paper's partitioning strategies on a JAX mesh.

The paper parallelizes over OpenMP threads on 2–4 cores; we parallelize over
mesh shards (chips).  The race on the destination vector is identical — the
scatter term writes rows owned by other shards — and each of the paper's
accumulation strategies maps onto one collective pattern (DESIGN.md §2):

  strategy='allreduce'       paper: local buffers + *all-in-one* accumulation.
      Every shard owns an nnz-balanced contiguous slot range, computes a
      full-length partial y, and the partials are summed with psum
      (all-reduce).  Output replicated.  Collective bytes: Θ(n) per shard.

  strategy='reduce_scatter'  paper: *per buffer / interval* accumulation.
      Same partials; psum_scatter sums them AND splits y into p equal
      intervals, one per shard — the paper's interval boundaries realized by
      the collective's shard boundaries.  Output row-sharded.  Θ(n/p) bytes.

  strategy='halo'            paper: *effective* accumulation.
      Row-block shards; because CSRC stores the lower triangle of a band
      matrix, a shard's effective write range is its own rows plus a window
      of at most `band` rows below — exchanged with the left neighbor via
      collective_permute.  Θ(band) bytes per shard, independent of n.
      This is the strategy the paper found best (80–93% of matrices), and
      on TPU the gap widens: ICI halo exchange is point-to-point.

The colorful method (paper §3.2) is a shared-memory construct (conflict-free
concurrent writes to one y); across distributed memories every write is a
message regardless of conflicts, so it degenerates to one of the above.  It
is provided on-device in kernels/ (see ref.colorful_spmv) and benchmarked
single-chip, as in the paper.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
try:                                    # jax >= 0.6 top-level export
    from jax import shard_map
except ImportError:                     # jax 0.4.x
    from jax.experimental.shard_map import shard_map

from .csrc import CSRC, bandwidth, row_of_slot
from .partition import partition_rows_by_nnz, RowPartition


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ShardedSlots:
    """Slot arrays split into p nnz-balanced groups, padded to equal length
    and stacked on a leading shard axis."""
    row_idx: jnp.ndarray     # (p, S) global row of each slot (pad: 0)
    ja: jnp.ndarray          # (p, S) global col             (pad: 0)
    al: jnp.ndarray          # (p, S)                        (pad: 0.0)
    au: jnp.ndarray          # (p, S)
    ad_shard: jnp.ndarray    # (p, n) diagonal owned by shard (zero elsewhere)
    part: RowPartition


def shard_slots(M: CSRC, p: int) -> ShardedSlots:
    part = partition_rows_by_nnz(M, p)
    ros = row_of_slot(M)
    ja = np.asarray(M.ja)
    al = np.asarray(M.al)
    au = np.asarray(M.au)
    ia = np.asarray(M.ia)
    spans = [(int(ia[part.starts[t]]), int(ia[part.starts[t + 1]]))
             for t in range(p)]
    smax = max(1, max(e - s for s, e in spans))
    smax = _round_up(smax, 128)

    def padded(arr, fill, dtype):
        out = np.full((p, smax), fill, dtype=dtype)
        for t, (s, e) in enumerate(spans):
            out[t, :e - s] = arr[s:e]
        return jnp.asarray(out)

    ad_shard = np.zeros((p, M.n), dtype=np.float32)
    for t in range(p):
        r0, r1 = part.rows(t)
        ad_shard[t, r0:r1] = np.asarray(M.ad)[r0:r1]

    return ShardedSlots(
        row_idx=padded(ros, 0, np.int32),
        ja=padded(ja, 0, np.int32),
        al=padded(al, 0.0, np.float32),
        au=padded(au, 0.0, np.float32),
        ad_shard=jnp.asarray(ad_shard),
        part=part,
    )


def build_spmv_allreduce(M: CSRC, mesh: Mesh, axis: str = "rows",
                         scatter_output: bool = False) -> Callable:
    """'allreduce' (all-in-one) and 'reduce_scatter' (per-buffer/interval)
    strategies.  x replicated; output replicated or row-sharded."""
    p = mesh.shape[axis]
    ss = shard_slots(M, p)
    n = M.n
    n_pad = _round_up(n, p)

    def local(row_idx, ja, al, au, ad_shard, x):
        # shard-local partial: the paper's private y buffer
        y = ad_shard[0] * x
        y = y + jax.ops.segment_sum(al[0] * x[ja[0]], row_idx[0],
                                    num_segments=n)
        y = y + jax.ops.segment_sum(au[0] * x[row_idx[0]], ja[0],
                                    num_segments=n)
        if scatter_output:
            y = jnp.pad(y, (0, n_pad - n))
            return jax.lax.psum_scatter(y, axis, scatter_dimension=0,
                                        tiled=True)
        return jax.lax.psum(y, axis)

    out_spec = P(axis) if scatter_output else P()
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None),) * 4 + (P(axis, None), P()),
        out_specs=out_spec)

    sharded = jax.device_put(
        (ss.row_idx, ss.ja, ss.al, ss.au, ss.ad_shard),
        jax.sharding.NamedSharding(mesh, P(axis, None)))

    @jax.jit
    def apply(x):
        return fn(*sharded, x)

    return apply


def build_spmv_halo(M: CSRC, mesh: Mesh, axis: str = "rows") -> Callable:
    """'halo' (effective) strategy: x and y row-sharded; only band-width
    windows cross shard boundaries (two collective_permutes)."""
    p = mesh.shape[axis]
    n = M.n
    ns = _round_up(-(-n // p), 8)          # rows per shard
    n_pad = ns * p
    band = bandwidth(M)
    h = max(8, _round_up(band, 8))
    if h > ns:
        raise ValueError(
            f"band {band} exceeds shard rows {ns}; halo strategy needs "
            "band <= n/p (fall back to allreduce/reduce_scatter)")

    # equal-row shard slot arrays with *local* coordinates
    ros = row_of_slot(M)
    ja = np.asarray(M.ja)
    al_np = np.asarray(M.al)
    au_np = np.asarray(M.au)
    shard_of_slot = ros // ns
    counts = np.bincount(shard_of_slot, minlength=p)
    smax = _round_up(max(1, int(counts.max())), 128)
    row_loc = np.zeros((p, smax), np.int32)
    col_rel = np.full((p, smax), ns + h - 1, np.int32)   # inert target
    al_s = np.zeros((p, smax), np.float32)
    au_s = np.zeros((p, smax), np.float32)
    fill = np.zeros(p, np.int64)
    for idx in np.argsort(shard_of_slot, kind="stable"):
        t = int(shard_of_slot[idx])
        q = int(fill[t]); fill[t] += 1
        row_loc[t, q] = int(ros[idx]) - t * ns
        col_rel[t, q] = int(ja[idx]) - (t * ns - h)      # in [0, ns+h)
        al_s[t, q] = al_np[idx]
        au_s[t, q] = au_np[idx]
    ad_pad = np.zeros(n_pad, np.float32)
    ad_pad[:n] = np.asarray(M.ad)
    ad_sh = ad_pad.reshape(p, ns)

    def local(row_loc, col_rel, al, au, ad, x_own):
        # x halo from the LEFT neighbor: its tail h rows
        left_tail = jax.lax.ppermute(
            x_own[-h:], axis, [(i, (i + 1) % p) for i in range(p)])
        x_ext = jnp.concatenate([left_tail, x_own])      # rows [r0-h, r1)
        row_loc, col_rel = row_loc[0], col_rel[0]
        al, au, ad = al[0], au[0], ad[0]
        y_ext = jnp.zeros((ns + h,), jnp.float32)
        y_ext = y_ext.at[h + row_loc].add(al * x_ext[col_rel])
        y_ext = y_ext.at[col_rel].add(au * x_ext[h + row_loc])
        y_ext = y_ext.at[h:].add(ad * x_own)
        # y halo to the LEFT neighbor (it owns rows [r0-h, r0))
        from_right = jax.lax.ppermute(
            y_ext[:h], axis, [(i, (i - 1) % p) for i in range(p)])
        y_own = y_ext[h:].at[-h:].add(from_right)
        return y_own

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None),) * 5 + (P(axis),),
        out_specs=P(axis))

    sharded = jax.device_put(
        (jnp.asarray(row_loc), jnp.asarray(col_rel), jnp.asarray(al_s),
         jnp.asarray(au_s), jnp.asarray(ad_sh)),
        jax.sharding.NamedSharding(mesh, P(axis, None)))
    x_sharding = jax.sharding.NamedSharding(mesh, P(axis))

    @jax.jit
    def apply(x):
        x_pad = jnp.pad(x, (0, n_pad - n))
        x_pad = jax.lax.with_sharding_constraint(x_pad, x_sharding)
        y = fn(*sharded, x_pad)
        return y[:n]

    return apply


STRATEGIES = ("allreduce", "reduce_scatter", "halo")


def build_sharded_spmv(M: CSRC, mesh: Mesh, axis: str = "rows",
                       strategy: str = "auto") -> Callable:
    """Factory: y_fn(x) computing A·x across the mesh axis."""
    if strategy == "auto":
        p = mesh.shape[axis]
        ns = -(-M.n // p)
        strategy = "halo" if bandwidth(M) <= max(8, ns) else "reduce_scatter"
    if strategy == "allreduce":
        return build_spmv_allreduce(M, mesh, axis, scatter_output=False)
    if strategy == "reduce_scatter":
        return build_spmv_allreduce(M, mesh, axis, scatter_output=True)
    if strategy == "halo":
        return build_spmv_halo(M, mesh, axis)
    raise ValueError(f"unknown strategy {strategy!r}")


def collective_bytes_estimate(M: CSRC, p: int, strategy: str) -> int:
    """Napkin model used by §Roofline and the benchmarks: bytes crossing
    links per shard per product."""
    n, band = M.n, bandwidth(M)
    if strategy == "allreduce":
        return 2 * 4 * n * (p - 1) // p          # ring all-reduce
    if strategy == "reduce_scatter":
        return 4 * n * (p - 1) // p
    if strategy == "halo":
        return 2 * 4 * max(8, band)              # x halo + y halo
    raise ValueError(strategy)
