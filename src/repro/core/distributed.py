"""Distributed CSRC SpMV/SpMM: the paper's partitioning strategies on a JAX
mesh.

The paper parallelizes over OpenMP threads on 2–4 cores; we parallelize over
mesh shards (chips).  The race on the destination vector is identical — the
scatter term writes rows owned by other shards — and each of the paper's
accumulation strategies maps onto one collective pattern (docs/DESIGN.md §2):

  strategy='allreduce'       paper: local buffers + *all-in-one* accumulation.
      Every shard owns an nnz-balanced contiguous slot range, computes a
      full-length partial y, and the partials are summed with psum
      (all-reduce).  Output replicated.  Collective bytes: Θ(n) per shard.

  strategy='reduce_scatter'  paper: *per buffer / interval* accumulation.
      Same partials; psum_scatter sums them AND splits y into p equal
      intervals, one per shard — the paper's interval boundaries realized by
      the collective's shard boundaries.  Output row-sharded.  Θ(n/p) bytes.

  strategy='halo'            paper: *effective* accumulation.
      Row-block shards; because CSRC stores the lower triangle of a band
      matrix, a shard's effective write range is its own rows plus a window
      of at most `band` rows below — exchanged with the left neighbor via
      collective_permute.  Θ(band) bytes per shard, independent of n.
      This is the strategy the paper found best (80–93% of matrices), and
      on TPU the gap widens: ICI halo exchange is point-to-point.

All structure precomputations (row partition, shard slot layouts, halo
geometry) come from the schedule layer (core/schedule.py) — the builders
here contain no inline partition/pack construction and accept a cached
:class:`~repro.core.schedule.SpmvSchedule` so repeated builds (serving,
solver restarts) are zero-precompute.  Every strategy accepts x of shape
(n,) or (n, B): the multi-RHS product shares one collective per block.

Shard-local compute is itself plan-driven: with a plan (or schedule) whose
path registers a :class:`~repro.core.paths.ShardSupport` ('flat',
'nnzsplit'), every strategy runs that path's Pallas kernel per shard —
allreduce/reduce_scatter over per-shard global-coordinate sub-packs
(``schedule.build_path_shards``), halo over local-coordinate per-shard
packs (``schedule.build_path_halo``) — instead of the default
segment-sum.  The branches below only consume the ShardSupport hooks;
a newly registered path is served here with zero edits.

The colorful method (paper §3.2) is a shared-memory construct (conflict-free
concurrent writes to one y); across distributed memories every write is a
message regardless of conflicts, so it degenerates to one of the above.  It
is provided on-device in kernels/ (see ref.colorful_spmv) and benchmarked
single-chip, as in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
try:                                    # jax >= 0.6 top-level export
    from jax import shard_map
except ImportError:                     # jax 0.4.x
    from jax.experimental.shard_map import shard_map

from .csrc import CSRC, bandwidth
from .plan import ExecutionPlan
from . import paths as paths_mod
from . import schedule as schedule_mod
from .schedule import SpmvSchedule


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _bc(v: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Broadcast per-slot/per-row values over RHS columns when x is (n, B)."""
    return v[:, None] if x.ndim == 2 else v


def _schedule(M: CSRC, p: int, accumulation: str,
              schedule: Optional[SpmvSchedule], cache,
              plan: Optional[ExecutionPlan] = None) -> SpmvSchedule:
    if schedule is not None:
        return schedule
    if plan is None:
        plan = ExecutionPlan(path="segment", partition="nnz",
                             accumulation=accumulation)
    return schedule_mod.schedule_for(M, plan, cache=cache, p=p)


def _shard_support(plan: Optional[ExecutionPlan]):
    """The requested plan's ShardSupport, or None when the path runs
    shard-locally as segment-sum ('segment', 'colorful', 'kernel', or no
    plan at all)."""
    if plan is None:
        return None
    return paths_mod.get_path(plan.path).shard_support


def build_spmv_allreduce(M: CSRC, mesh: Mesh, axis: str = "rows",
                         scatter_output: bool = False,
                         schedule: Optional[SpmvSchedule] = None,
                         cache=None,
                         plan: Optional[ExecutionPlan] = None,
                         interpret: bool = True,
                         layout=None) -> Callable:
    """'allreduce' (all-in-one) and 'reduce_scatter' (per-buffer/interval)
    strategies.  x replicated, shape (n,) or (n, B); output replicated or
    row-sharded.  With a plan/schedule whose path registers ShardSupport
    ('flat', 'nnzsplit') the shard-local partial runs that path's kernel
    over the shard's sub-pack instead of segment-sum.

    ``layout`` injects a prebuilt (or value-refreshed) ShardedSlots /
    path shards layout; otherwise the schedule layer builds it — and,
    given ``cache``, serves it from / ships it to the PlanCache npz
    layer."""
    p = mesh.shape[axis]
    acc = "reduce_scatter" if scatter_output else "allreduce"
    # the requested plan decides shard-local compute; the *schedule* only
    # supplies the row partition here, so a shard-supported plan builds
    # its path-specific artifact per shard (build_path_shards), never the
    # unused full-matrix pack — schedule_for gets the path-free variant
    req_plan = plan if plan is not None else (
        schedule.plan if schedule is not None else None)
    if plan is not None and schedule is None and plan.path != "segment":
        plan = dataclasses.replace(plan, path="segment")
    sched = _schedule(M, p, acc, schedule, cache, plan=plan)
    part = sched.partition
    if part.p != p:
        raise ValueError(
            f"schedule partition is {part.p}-way, mesh axis {axis} has {p}")
    n = M.n
    n_pad = _round_up(n, p)
    sup = _shard_support(req_plan)

    def reduce_y(y, x_ndim):
        if scatter_output:
            pad = ((0, n_pad - n),) + ((0, 0),) * (x_ndim - 1)
            y = jnp.pad(y, pad)
            return jax.lax.psum_scatter(y, axis, scatter_dimension=0,
                                        tiled=True)
        return jax.lax.psum(y, axis)

    if sup is not None:
        fs = (layout if layout is not None
              else schedule_mod.build_path_shards(M, part, req_plan,
                                                  cache=cache))
        local_y = sup.local_fn(fs, M.n, interpret)

        def local(*args):
            x = args[-1]
            return reduce_y(local_y(*args), x.ndim)

        sharded = jax.device_put(
            sup.shard_arrays(fs),
            jax.sharding.NamedSharding(mesh, P(axis)))
        in_specs = tuple(sup.shard_specs(axis)) + (P(),)
    else:
        ss = (layout if layout is not None
              else schedule_mod.build_sharded_slots(M, part, cache=cache))

        def local(row_idx, ja, al, au, ad_shard, x):
            # shard-local partial: the paper's private y buffer
            y = _bc(ad_shard[0], x) * x
            y = y + jax.ops.segment_sum(_bc(al[0], x) * x[ja[0]],
                                        row_idx[0], num_segments=n)
            y = y + jax.ops.segment_sum(_bc(au[0], x) * x[row_idx[0]],
                                        ja[0], num_segments=n)
            return reduce_y(y, x.ndim)

        sharded = jax.device_put(
            (ss.row_idx, ss.ja, ss.al, ss.au, ss.ad_shard),
            jax.sharding.NamedSharding(mesh, P(axis, None)))
        in_specs = (P(axis, None),) * 5 + (P(),)

    # x is replicated (P() leaves trailing dims unsharded), so one
    # shard_map serves both the (n,) and (n, B) forms.  check_rep is off
    # on kernel-backed paths: shard_map has no replication rule for
    # pallas_call.
    fn = shard_map(
        local, mesh=mesh, in_specs=in_specs,
        out_specs=(P(axis) if scatter_output else P()),
        check_rep=sup is None)

    @jax.jit
    def apply(x):
        return fn(*sharded, x)

    return apply


def build_spmv_halo(M: CSRC, mesh: Mesh, axis: str = "rows",
                    schedule: Optional[SpmvSchedule] = None,
                    cache=None,
                    plan: Optional[ExecutionPlan] = None,
                    interpret: bool = True,
                    layout=None) -> Callable:
    """'halo' (effective) strategy: x and y row-sharded; only band-width
    windows cross shard boundaries (two collective_permutes).

    The halo geometry depends on the mesh width, not on the plan's
    partition, so it is not part of the ``schedule`` artifact —
    ``build_halo_layout`` / ``build_path_halo`` memoize it per
    (matrix, p[, pack geometry]) and repeated builds are zero-precompute.
    With a plan/schedule whose path registers ShardSupport each shard
    runs that path's kernel over its local-coordinate pack instead of
    the scatter-add form."""
    p = mesh.shape[axis]
    plan = plan if plan is not None else (
        schedule.plan if schedule is not None else None)
    sup = _shard_support(plan)

    if sup is not None:
        lay = (layout if layout is not None
               else schedule_mod.build_path_halo(M, p, plan, cache=cache))
        ns, h, n_local = sup.halo_dims(lay)
        n = M.n
        n_pad = ns * p
        local_y = sup.local_fn(lay, n_local, interpret)

        def local(*args):
            x_own = args[-1]
            # x halo from the LEFT neighbor: its tail h rows
            left_tail = jax.lax.ppermute(
                x_own[-h:], axis, [(i, (i + 1) % p) for i in range(p)])
            x_ext = jnp.concatenate([left_tail, x_own])  # rows [r0-h, r1)
            y_ext = local_y(*args[:-1], x_ext)
            # y halo to the LEFT neighbor (it owns rows [r0-h, r0))
            from_right = jax.lax.ppermute(
                y_ext[:h], axis, [(i, (i - 1) % p) for i in range(p)])
            return y_ext[h:].at[-h:].add(from_right)

        sharded = jax.device_put(
            sup.shard_arrays(lay),
            jax.sharding.NamedSharding(mesh, P(axis)))
        slot_specs = tuple(sup.shard_specs(axis))
    else:
        lay = (layout if layout is not None
               else schedule_mod.build_halo_layout(M, p, cache=cache))
        n, ns, h, n_pad = M.n, lay.ns, lay.h, lay.n_pad

        def local(row_loc, col_rel, al, au, ad, x_own):
            # x halo from the LEFT neighbor: its tail h rows
            left_tail = jax.lax.ppermute(
                x_own[-h:], axis, [(i, (i + 1) % p) for i in range(p)])
            x_ext = jnp.concatenate([left_tail, x_own])  # rows [r0-h, r1)
            row_loc, col_rel = row_loc[0], col_rel[0]
            al, au, ad = al[0], au[0], ad[0]
            y_ext = jnp.zeros((ns + h,) + x_own.shape[1:], jnp.float32)
            y_ext = y_ext.at[h + row_loc].add(
                _bc(al, x_own) * x_ext[col_rel])
            y_ext = y_ext.at[col_rel].add(
                _bc(au, x_own) * x_ext[h + row_loc])
            y_ext = y_ext.at[h:].add(_bc(ad, x_own) * x_own)
            # y halo to the LEFT neighbor (it owns rows [r0-h, r0))
            from_right = jax.lax.ppermute(
                y_ext[:h], axis, [(i, (i - 1) % p) for i in range(p)])
            return y_ext[h:].at[-h:].add(from_right)

        sharded = jax.device_put(
            (lay.row_loc, lay.col_rel, lay.al, lay.au, lay.ad),
            jax.sharding.NamedSharding(mesh, P(axis, None)))
        slot_specs = (P(axis, None),) * 5

    def make_fn(two_d: bool):
        x_spec = P(axis, None) if two_d else P(axis)
        # check_rep off on kernel-backed paths: shard_map has no
        # replication rule for pallas_call
        return shard_map(
            local, mesh=mesh,
            in_specs=slot_specs + (x_spec,),
            out_specs=x_spec, check_rep=sup is None)

    fns = {False: make_fn(False), True: make_fn(True)}

    @jax.jit
    def apply(x):
        two_d = x.ndim == 2
        pad = ((0, n_pad - n),) + ((0, 0),) * (x.ndim - 1)
        x_pad = jnp.pad(x, pad)
        spec = P(axis, None) if two_d else P(axis)
        x_pad = jax.lax.with_sharding_constraint(
            x_pad, jax.sharding.NamedSharding(mesh, spec))
        y = fns[two_d](*sharded, x_pad)
        return y[:n]

    return apply


STRATEGIES = ("allreduce", "reduce_scatter", "halo")


def build_sharded_spmv(M: CSRC, mesh: Mesh, axis: str = "rows",
                       strategy: str = "auto",
                       schedule: Optional[SpmvSchedule] = None,
                       cache=None,
                       plan: Optional[ExecutionPlan] = None,
                       interpret: bool = True,
                       layout=None) -> Callable:
    """Factory: y_fn(x) computing A·x (or A·X for (n, B) blocks) across the
    mesh axis.  ``schedule``/``cache`` reuse the precomputed artifact; with
    ``strategy='auto'`` a supplied schedule's (or ``plan``'s) accumulation
    decides.  A plan/schedule whose path registers ShardSupport ('flat',
    'nnzsplit') makes every strategy run that path's kernel shard-locally.
    ``layout`` injects a prebuilt shard layout (the serving MeshExecutor's
    value-refresh path)."""
    p = mesh.shape[axis]
    if strategy == "auto":
        if schedule is not None:
            strategy = schedule.plan.accumulation
        elif plan is not None:
            strategy = plan.accumulation
        else:
            ns = -(-M.n // p)
            strategy = ("halo" if bandwidth(M) <= max(8, ns)
                        else "reduce_scatter")
    if strategy == "allreduce":
        return build_spmv_allreduce(M, mesh, axis, scatter_output=False,
                                    schedule=schedule, cache=cache,
                                    plan=plan, interpret=interpret,
                                    layout=layout)
    if strategy == "reduce_scatter":
        return build_spmv_allreduce(M, mesh, axis, scatter_output=True,
                                    schedule=schedule, cache=cache,
                                    plan=plan, interpret=interpret,
                                    layout=layout)
    if strategy == "halo":
        return build_spmv_halo(M, mesh, axis, schedule=schedule,
                               cache=cache, plan=plan, interpret=interpret,
                               layout=layout)
    raise ValueError(f"unknown strategy {strategy!r}")


def collective_bytes_from_stats(n: int, band: int, p: int, strategy: str,
                                nrhs: int = 1) -> int:
    """The collective-bytes model over bare matrix statistics — the form
    the tuner's mesh-aware candidate gate consumes (no matrix needed)."""
    if strategy == "allreduce":
        return 2 * 4 * n * nrhs * (p - 1) // p       # ring all-reduce
    if strategy == "reduce_scatter":
        return 4 * n * nrhs * (p - 1) // p
    if strategy == "halo":
        return 2 * 4 * max(8, band) * nrhs           # x halo + y halo
    raise ValueError(strategy)


def collective_bytes_estimate(M: CSRC, p: int, strategy: str,
                              nrhs: int = 1) -> int:
    """Napkin model used by §Roofline and the benchmarks: bytes crossing
    links per shard per product (scales linearly with the RHS block)."""
    return collective_bytes_from_stats(M.n, bandwidth(M), p, strategy,
                                       nrhs=nrhs)
