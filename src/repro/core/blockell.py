"""Block-ELL packing of a CSRC matrix for the Pallas TPU kernel.

This is the hardware-adaptation layer (docs/DESIGN.md §4).  The paper's per-thread
row ranges become per-*tile* row ranges; the paper's "effective range" of a
thread becomes the tile's **window** — a contiguous slice of x/y that covers
every column the tile touches.  Windows are uniform-width and end-aligned to
the tile's last row, so the window start is an affine function of the tile id
(no scalar prefetch needed in the kernel):

    window(b) = [ (b+1)·TM - W,  (b+1)·TM )       (original coordinates)

W = round_up(TM + bandwidth, 128).  This holds because CSRC stores only the
lower triangle: every stored column j of row i satisfies i - band <= j <= i.

Slots are padded per row-tile to a common count S (multiple of the k-step),
ELL-style.  Padded slots carry value 0 and the sentinel column W (one-hot of
an out-of-range index is the zero vector — padding is numerically inert).

Layout (NT = ceil(n / TM) row tiles, S slots per tile):

    vals_l     (NT, S)  f32   lower values (diag excluded)
    vals_u     (NT, S)  f32   aligned upper values (absent if numerically sym.)
    col_local  (NT, S)  i32   j - win_lo(b)   in [0, W)   (W = padding sentinel)
    row_in_win (NT, S)  i32   i - win_lo(b)   in [W-TM, W)
    ad         (NT, TM) f32   diagonal, row-tiled

x is padded with W zeros on the left and to NT·TM on the right, so window b
in padded coordinates starts at (b+1)·TM.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from .csrc import CSRC, bandwidth, row_of_slot


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class BlockEll:
    n: int
    tm: int
    nt: int
    w_pad: int
    s: int                      # padded slots per tile
    vals_l: jnp.ndarray         # (NT, S)
    vals_u: jnp.ndarray         # (NT, S)  (== vals_l when num_symmetric)
    col_local: jnp.ndarray      # (NT, S)
    row_in_win: jnp.ndarray     # (NT, S)
    ad: jnp.ndarray             # (NT, TM)
    num_symmetric: bool
    pad_ratio: float            # NT*S / k  (ELL padding overhead; 1.0 = none)

    @property
    def n_pad(self) -> int:
        return self.nt * self.tm

    def streamed_bytes(self) -> int:
        """Bytes the kernel streams from HBM per product (the §Roofline
        memory term for the kernel): values + indices + x + y windows."""
        b = self.vals_l.size * self.vals_l.dtype.itemsize
        if not self.num_symmetric:
            b += self.vals_u.size * self.vals_u.dtype.itemsize
        b += self.col_local.size * self.col_local.dtype.itemsize
        b += self.row_in_win.size * self.row_in_win.dtype.itemsize
        b += self.ad.size * self.ad.dtype.itemsize
        b += (self.n_pad + self.w_pad) * 4          # x (windows overlap-read)
        b += self.nt * self.w_pad * 4               # window partials out
        return b


def pack(M: CSRC, tm: int = 128, k_step: int = 1024,
         w_cap: int = 4096, dtype=jnp.float32,
         index_dtype=jnp.int32) -> BlockEll:
    """Pack a square CSRC matrix into block-ELL tiles.

    Raises ValueError when the matrix band is too wide for the windowed
    kernel (w_pad would exceed ``w_cap``) — callers fall back to the
    segment-sum path (ref.csrc_spmv), mirroring the paper's finding that
    unbanded matrices (cage15, F1) defeat locality-based strategies.

    ``index_dtype=jnp.int16`` halves the index stream (local window
    offsets always fit: w_pad <= w_cap << 32767) — the paper's 16-bit
    index compression (§1, Williams et al.) applied at tile scope.
    """
    assert M.is_square, "block-ELL packs the square CSRC part only"
    n = M.n
    band = bandwidth(M)
    # multiple of 128 (lane alignment) AND of tm (overlap-add group size)
    w_pad = _round_up(tm + band, max(128, tm))
    if index_dtype == jnp.int16 and w_pad + 1 > 32767:
        raise ValueError(f"window {w_pad} overflows int16 indices")
    if w_pad > w_cap:
        raise ValueError(
            f"bandwidth {band} needs window {w_pad} > cap {w_cap}; "
            "use the segment-sum path")
    nt = max(1, -(-n // tm))
    ros = row_of_slot(M)
    ja = np.asarray(M.ja)
    al = np.asarray(M.al)
    au = np.asarray(M.au)
    tile_of_slot = ros // tm
    counts = np.bincount(tile_of_slot, minlength=nt)
    s = max(k_step, _round_up(int(counts.max()) if counts.size else k_step,
                              k_step))

    vals_l = np.zeros((nt, s), dtype=np.float32)
    vals_u = np.zeros((nt, s), dtype=np.float32)
    col_local = np.full((nt, s), w_pad, dtype=np.int32)       # sentinel
    row_in_win = np.full((nt, s), w_pad - 1, dtype=np.int32)  # inert
    # stable fill: slots are already row-major within each tile
    order = np.argsort(tile_of_slot, kind="stable")
    pos_in_tile = np.zeros_like(order)
    fill = np.zeros(nt, dtype=np.int64)
    for idx in order:
        t = tile_of_slot[idx]
        pos_in_tile[idx] = fill[t]
        fill[t] += 1
    win_lo = (np.arange(nt) + 1) * tm - w_pad                 # original coords
    t_idx = tile_of_slot
    p_idx = pos_in_tile
    vals_l[t_idx, p_idx] = al
    vals_u[t_idx, p_idx] = au
    col_local[t_idx, p_idx] = ja - win_lo[t_idx]
    row_in_win[t_idx, p_idx] = ros - win_lo[t_idx]

    ad = np.zeros((nt, tm), dtype=np.float32)
    ad.reshape(-1)[:n] = np.asarray(M.ad)

    k = max(1, int(ja.shape[0]))
    return BlockEll(
        n=n, tm=tm, nt=nt, w_pad=w_pad, s=s,
        vals_l=jnp.asarray(vals_l, dtype=dtype),
        vals_u=jnp.asarray(vals_l if M.numerically_symmetric else vals_u,
                           dtype=dtype),
        col_local=jnp.asarray(col_local, dtype=index_dtype),
        row_in_win=jnp.asarray(row_in_win, dtype=index_dtype),
        ad=jnp.asarray(ad, dtype=dtype),
        num_symmetric=bool(M.numerically_symmetric),
        pad_ratio=float(nt * s) / k,
    )


def refresh_values(pack_: BlockEll, M: CSRC) -> BlockEll:
    """Refill a pack's value streams (vals_l/vals_u/ad) from a matrix with
    **identical structure** — the FEM time-stepping fast path: no window
    recomputation, no index-stream rebuild, no per-slot Python loop.

    The slot→(tile, position) map is re-derived vectorized from ``ia``
    alone: slots are row-major, so within a tile they are consecutive and
    the position is ``slot_index − first_slot_of_tile``.  This reproduces
    the original pack's fill order exactly (the pack's stable-sort loop
    over a non-decreasing tile array is the identity order).
    """
    assert M.is_square and M.n == pack_.n, "structure mismatch"
    if bool(M.numerically_symmetric) != pack_.num_symmetric:
        raise ValueError(
            "numeric symmetry changed; the pack layout streams vals_u "
            "conditionally — rebuild instead of refreshing")
    ros = row_of_slot(M)
    k = ros.shape[0]
    tile = ros // pack_.tm
    first = np.searchsorted(tile, np.arange(pack_.nt))
    pos = np.arange(k) - first[tile]
    vals_l = np.zeros((pack_.nt, pack_.s), dtype=np.float32)
    vals_l[tile, pos] = np.asarray(M.al)
    if pack_.num_symmetric:          # vals_u aliases vals_l; skip the fill
        vals_u = vals_l
    else:
        vals_u = np.zeros((pack_.nt, pack_.s), dtype=np.float32)
        vals_u[tile, pos] = np.asarray(M.au)
    ad = np.zeros((pack_.nt, pack_.tm), dtype=np.float32)
    ad.reshape(-1)[:pack_.n] = np.asarray(M.ad)
    vdtype = pack_.vals_l.dtype
    return dataclasses.replace(
        pack_,
        vals_l=jnp.asarray(vals_l, dtype=vdtype),
        vals_u=jnp.asarray(vals_u, dtype=vdtype),
        ad=jnp.asarray(ad, dtype=pack_.ad.dtype))


def pad_x(pack_: BlockEll, x: jnp.ndarray) -> jnp.ndarray:
    """Left-pad by W and right-pad to NT*TM (window coordinates)."""
    return jnp.pad(x, (pack_.w_pad, pack_.n_pad - pack_.n))


def overlap_add(pack_: BlockEll, wins: jnp.ndarray) -> jnp.ndarray:
    """Accumulate per-tile windows into y — the paper's *effective*
    accumulation step, vectorized as overlap-add (hop TM, frame W).

    Windows are decomposed into r = W/TM groups of stride-r tiles; windows
    inside one group are disjoint, so each group reduces to a reshape +
    static-offset add (no scatter in the HLO).
    """
    nt, w = wins.shape
    tm = pack_.tm
    r = w // tm                      # W is a multiple of 128; ensure tm | w
    assert w % tm == 0, "w_pad must be a multiple of tm for overlap-add"
    y = jnp.zeros((pack_.w_pad + pack_.n_pad + w,), wins.dtype)
    for g in range(r):
        group = wins[g::r]                       # (ceil((nt-g)/r), W)
        ng = group.shape[0]
        if ng == 0:
            continue
        flat = group.reshape(ng * w)
        # window b starts (padded coords) at (b+1)*tm; group g holds tiles
        # b = g, g+r, g+2r, ... whose windows are back-to-back (stride r*tm = w)
        start = (g + 1) * tm
        y = jax.lax.dynamic_update_slice(
            y, jax.lax.dynamic_slice(y, (start,), (ng * w,)) + flat, (start,))
    return y[pack_.w_pad:pack_.w_pad + pack_.n]


def overlap_add_mm(pack_, wins: jnp.ndarray) -> jnp.ndarray:
    """Multi-RHS overlap-add: windows (NT, W, B) -> y (n, B).  Same group
    decomposition as :func:`overlap_add`, per RHS column.  Works for any
    pack exposing ``tm``/``w_pad``/``n_pad``/``n`` (rectangular BlockEll
    and the flat-grid FlatBlockEll share it)."""
    nt, w, nrhs = wins.shape
    tm = pack_.tm
    r = w // tm
    assert w % tm == 0, "w_pad must be a multiple of tm for overlap-add"
    y = jnp.zeros((pack_.w_pad + pack_.n_pad + w, nrhs), wins.dtype)
    for g in range(r):
        group = wins[g::r]
        ng = group.shape[0]
        if ng == 0:
            continue
        flat = group.reshape(ng * w, nrhs)
        start = (g + 1) * tm
        y = jax.lax.dynamic_update_slice(
            y, jax.lax.dynamic_slice(y, (start, 0), (ng * w, nrhs)) + flat,
            (start, 0))
    return y[pack_.w_pad:pack_.w_pad + pack_.n]
