"""The unified SpMV schedule layer: every structure-dependent precomputation
an :class:`~repro.core.plan.ExecutionPlan` needs to execute, bundled in one
cached, serializable artifact.

The paper's two race-avoidance families — per-thread buffers with four
accumulation variants (§3.1) and conflict-graph coloring (§3.2) — are all
*precomputations over the matrix structure*.  Before this layer each consumer
rebuilt its own piece ad-hoc (the operator packed block-ELL inline, the
distributed builders re-derived partitions and halo windows, the colorful
path re-ran the greedy colorer).  ``SpmvSchedule`` gives them one home:

  partition        nnz-guided (or row-count) :class:`RowPartition` with the
                   paper's *effective* write ranges per part
  halo             per-part halo widths (§3.1 effective accumulation;
                   the distributed 'halo' strategy's exchange windows)
  pack             the block-ELL pack for the Pallas kernel path
  coloring         balanced largest-degree-first :class:`Coloring` plus
                   device-ready per-color slot batches (colorful path)

A schedule is built **once** per (matrix fingerprint, value digest, plan,
partition width) and stored next to the plan in the tuner's
:class:`~repro.core.tuner.PlanCache` — a serving process that re-registers a
known matrix performs zero pack/partition/coloring work
(``BUILD_COUNTS`` is the probe tests assert that with).

Path-specific artifact contents (the block-ELL pack, the flat-grid pack,
the coloring batches) are built and serialized by the path's
:class:`~repro.core.paths.KernelPath` registry entry — this module owns
the common pieces (partition, halo, fingerprinting, cache plumbing) and
delegates the rest, so a newly registered path is schedule-cached with
zero edits here.

Serialization is npz + a JSON meta record (``save_npz`` / ``load_npz``);
``SCHEDULE_VERSION`` gates the on-disk layout — bumping it (e.g. on a pack
format change) invalidates every stored schedule, which is then silently
rebuilt on the next request.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Optional

import numpy as np
import jax.numpy as jnp

from . import paths as paths_mod
from .blockell import BlockEll
from .coloring import Coloring
from .csrc import CSRC, row_of_slot
from .partition import (RowPartition, halo_widths, partition_rows_by_count,
                        partition_rows_by_nnz)
# the build probe lives with the registry (path builders count into it);
# re-exported here because consumers/tests address it as
# ``schedule.BUILD_COUNTS`` — same Counter object.
from .paths import BUILD_COUNTS
from .plan import ExecutionPlan

# version 5: the 'nnzsplit' path's NnzSplitPack artifact joins the npz
# layout (nnzsplit_* arrays + "nnzsplit_pack" meta).  Version-4 files
# load as misses and are rebuilt transparently.
# version 6: the colorful artifact records its coloring provider plus the
# RACE level-group metadata (color_level_of_row / color_group_of_row), and
# the provider joins the colorful path's artifact fields (schedule keys).
# Version-5 files load as misses and are rebuilt transparently.
SCHEDULE_VERSION = 6


@dataclasses.dataclass(frozen=True)
class SpmvSchedule:
    """Everything structure-dependent one plan needs to execute one matrix."""

    fingerprint: str            # matrix-class key (tuner.fingerprint)
    value_digest: str           # exact structure+values digest (this matrix)
    plan: ExecutionPlan
    n: int
    m: int
    p: int                      # partition width the row partition was built for
    partition: RowPartition
    halo: np.ndarray            # (p,) halo width per part (effective ranges)
    # --- path-specific artifact fields (built/serialized by the path's
    # KernelPath registry entry; exactly the fields its build_artifact
    # returns are non-None) ---
    pack: Optional[BlockEll] = None          # 'kernel' path
    coloring: Optional[Coloring] = None      # 'colorful' path
    # device-ready color batches: slot ids grouped by color, concatenated;
    # color c owns color_slots[color_slot_ptr[c]:color_slot_ptr[c+1]].
    color_slots: Optional[np.ndarray] = None
    color_slot_ptr: Optional[np.ndarray] = None
    flat_pack: Optional[object] = None       # 'flat' path (FlatBlockEll)
    nnzsplit_pack: Optional[object] = None   # 'nnzsplit' path (NnzSplitPack)
    # exact-structure digest (ia/ja/iar/jar only — values excluded): the
    # key of the value-refresh fast path (refresh_schedule)
    structure_digest: str = ""

    def key(self) -> str:
        return schedule_key(self.fingerprint, self.value_digest, self.plan,
                            self.p)

    # ------------------------------------------------------------------
    # Serialization (npz arrays + JSON meta); the path-specific section is
    # delegated to the registry entry's save_artifact/load_artifact
    # ------------------------------------------------------------------

    def save_npz(self, path: str):
        meta = {
            "version": SCHEDULE_VERSION,
            "fingerprint": self.fingerprint,
            "value_digest": self.value_digest,
            "plan": self.plan.to_dict(),
            "n": self.n, "m": self.m, "p": self.p,
            "structure_digest": self.structure_digest,
        }
        arrays = {
            "part_starts": np.asarray(self.partition.starts),
            "part_eff_lo": np.asarray(self.partition.eff_lo),
            "part_eff_hi": np.asarray(self.partition.eff_hi),
            "part_nnz": np.asarray(self.partition.nnz_per_part),
            "halo": np.asarray(self.halo),
        }
        entry = paths_mod.get_path(self.plan.path)
        path_meta, path_arrays = entry.save_artifact(self)
        meta.update(path_meta)
        arrays.update(path_arrays)
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        tmp = path + ".tmp.npz"
        with open(tmp, "wb") as f:
            np.savez_compressed(f, __meta__=np.frombuffer(
                json.dumps(meta, sort_keys=True).encode(), dtype=np.uint8),
                **arrays)
        os.replace(tmp, path)

    @classmethod
    def load_npz(cls, path: str) -> "SpmvSchedule":
        with np.load(path) as z:
            meta = json.loads(bytes(z["__meta__"]).decode())
            if meta.get("version") != SCHEDULE_VERSION:
                raise ValueError(
                    f"schedule {path}: version {meta.get('version')!r} "
                    f"!= {SCHEDULE_VERSION}")
            plan = ExecutionPlan.from_dict(meta["plan"])
            part = RowPartition(starts=z["part_starts"],
                                eff_lo=z["part_eff_lo"],
                                eff_hi=z["part_eff_hi"],
                                nnz_per_part=z["part_nnz"])
            entry = paths_mod.get_path(plan.path)
            fields = entry.load_artifact(meta, z)
            return cls(fingerprint=meta["fingerprint"],
                       value_digest=meta["value_digest"], plan=plan,
                       n=meta["n"], m=meta["m"], p=meta["p"],
                       structure_digest=meta["structure_digest"],
                       partition=part, halo=z["halo"], **fields)


def value_digest(M: CSRC) -> str:
    """Digest of the exact matrix content (structure AND values).

    The tuner's ``fingerprint`` identifies a matrix *class* (two matrices of
    the same generator share it, so plans transfer).  A schedule embeds the
    matrix values (pack value streams, per-slot al/au), so its cache key
    additionally pins the exact matrix — a same-class matrix with different
    values rebuilds instead of silently reusing another matrix's values.
    """
    h = hashlib.sha1()
    for a in (M.ia, M.ja, M.ad, M.al, M.au, M.iar, M.jar, M.ar):
        arr = np.ascontiguousarray(np.asarray(a))
        h.update(arr.tobytes())
    return h.hexdigest()[:16]


def structure_digest(M: CSRC) -> str:
    """Digest of the matrix *structure* only (ia/ja/iar/jar + shape).

    Two matrices sharing it differ at most in values — the FEM
    time-stepping shape (re-assembled stiffness on a fixed mesh).  For
    such a pair every structural schedule artifact (partition, halo,
    coloring, pack index streams) is identical; only the value streams
    need refreshing (:func:`refresh_schedule`).
    """
    h = hashlib.sha1()
    h.update(np.asarray([M.n, M.m], np.int64).tobytes())
    for a in (M.ia, M.ja, M.iar, M.jar):
        h.update(np.ascontiguousarray(np.asarray(a)).tobytes())
    return h.hexdigest()[:16]


def plan_artifact_fields(plan: ExecutionPlan) -> tuple:
    """The plan fields the schedule artifact actually depends on.  Two plans
    differing only in accumulation strategy or tuned RHS width (nrhs) share
    one artifact — the pack/partition/coloring are identical.  The
    path-specific tail comes from the registry entry ('kernel'/'flat' pin
    their tile/window geometry; 'segment'/'colorful' add nothing)."""
    entry = paths_mod.get_path(plan.path)
    return (plan.path, plan.partition) + tuple(entry.artifact_fields(plan))


def schedule_key(fingerprint: str, digest: str, plan: ExecutionPlan,
                 p: int) -> str:
    ph = hashlib.sha1(json.dumps(plan_artifact_fields(plan)).encode()
                      ).hexdigest()[:10]
    return f"{fingerprint}.{digest}.p{p}.{ph}"


def color_slot_batches(M: CSRC, coloring: Coloring):
    """Device-ready colorful batches: lower-triangle slot ids grouped by the
    color of their owning row (the per-color gather/scatter index sets the
    colorful path replays serially).  Returns (slots, ptr)."""
    ia = np.asarray(M.ia)
    slots = []
    ptr = np.zeros(coloring.num_colors + 1, dtype=np.int64)
    for c in range(coloring.num_colors):
        rows = coloring.rows(c)
        sl = (np.concatenate([np.arange(ia[r], ia[r + 1]) for r in rows])
              if len(rows) else np.zeros(0, np.int64))
        slots.append(sl.astype(np.int32))
        ptr[c + 1] = ptr[c] + sl.shape[0]
    slots = (np.concatenate(slots).astype(np.int32) if slots
             else np.zeros(0, np.int32))
    return slots, ptr


def build_schedule(M: CSRC, plan: ExecutionPlan, p: int = 8,
                   coloring: Optional[Coloring] = None) -> SpmvSchedule:
    """Build the full schedule artifact for (matrix, plan).

    The path-specific artifact (pack / flat pack / coloring batches) comes
    from the plan path's registry entry; it raises ValueError exactly where
    strict plan execution must fail: a windowed ('kernel'/'flat') plan
    whose window exceeds ``plan.w_cap`` (bandwidth gate) and square-only
    plans on rectangular matrices.
    """
    from .tuner import fingerprint as _fingerprint   # local: avoid cycle
    from repro import obs

    entry = paths_mod.get_path(plan.path)
    # build the path artifact first: infeasible plans raise before any
    # build counter moves
    with obs.span("schedule.build_artifact", path=plan.path):
        fields = entry.build_artifact(M, plan, coloring=coloring)

    BUILD_COUNTS.inc("schedule")
    BUILD_COUNTS.inc("partition")
    with obs.span("schedule.partition", partition=plan.partition):
        p = max(1, min(p, M.n))
        if plan.partition == "count":
            part = partition_rows_by_count(M, p)
        else:
            part = partition_rows_by_nnz(M, p)
        halo = np.asarray(halo_widths(part), dtype=np.int64)

    return SpmvSchedule(
        fingerprint=_fingerprint(M), value_digest=value_digest(M),
        plan=plan, n=M.n, m=M.m, p=p, partition=part, halo=halo,
        structure_digest=structure_digest(M), **fields)


def refresh_schedule(sched: SpmvSchedule, M: CSRC) -> SpmvSchedule:
    """Same-structure value refresh: a new schedule for ``M`` reusing every
    structural artifact of ``sched`` (partition, halo, coloring, pack index
    streams) and rebuilding only the value streams.

    This is the FEM time-stepping fast path — the matrix is re-assembled
    every step with unchanged connectivity, so re-packing or re-coloring
    would redo O(nnz) structural work per step for nothing.  The path's
    registry entry supplies the stream refresh ('kernel'/'flat' refill the
    pack values vectorized); paths whose artifacts are purely structural
    ('segment', 'colorful' — executors read values from ``M`` directly)
    reuse the artifact as-is.  Raises ValueError when the structures do
    not actually match.
    """
    if structure_digest(M) != sched.structure_digest:
        raise ValueError(
            "refresh_schedule: matrix structure differs from the "
            "schedule's; a full rebuild (build_schedule) is required")
    entry = paths_mod.get_path(sched.plan.path)
    BUILD_COUNTS.inc("value_refresh")
    fields = ({} if entry.refresh_values is None
              else entry.refresh_values(M, sched))
    return dataclasses.replace(sched, value_digest=value_digest(M),
                               **fields)


def schedule_for(M: CSRC, plan: ExecutionPlan, cache=None, p: int = 8,
                 coloring: Optional[Coloring] = None) -> SpmvSchedule:
    """The schedule to execute (M, plan) with — cache hit wins.

    ``cache`` is a :class:`~repro.core.tuner.PlanCache`; a hit performs zero
    pack/partition/coloring work.  On a value-digest miss a same-structure
    schedule (matching fingerprint + structure digest — FEM time stepping)
    is value-refreshed instead of rebuilt (:func:`refresh_schedule`): only
    the value streams are touched, no re-pack/re-partition/re-color.  An
    explicit ``coloring`` override bypasses the cache (custom colorings are
    caller-owned, not shared artifacts).
    """
    from .tuner import fingerprint as _fingerprint

    if coloring is not None or cache is None:
        return build_schedule(M, plan, p=p, coloring=coloring)
    fp = _fingerprint(M)
    vd = value_digest(M)
    hit = cache.get_schedule(fp, vd, plan, p)
    if hit is not None:
        return hit
    base = cache.find_schedule_by_structure(fp, structure_digest(M), plan, p)
    if base is not None:
        from repro import obs
        obs.counter("plan_cache_lookups_total", kind="schedule",
                    outcome="refresh").inc()
        sched = refresh_schedule(base, M)
        # the refreshed generation supersedes the base in memory (one
        # schedule per structure, not one per step); the npz already on
        # disk keeps serving fresh processes, so skip re-compressing a
        # full artifact per time step
        cache.drop_schedule(base, remove_file=False)
        cache.put_schedule(sched, persist=False)
    else:
        sched = build_schedule(M, plan, p=p)
        cache.put_schedule(sched)
    return sched


# ---------------------------------------------------------------------------
# Distributed slot layouts (the shard-level structure precomputations the
# core/distributed.py strategies execute with)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedSlots:
    """Slot arrays split into p nnz-balanced groups, padded to equal length
    and stacked on a leading shard axis (allreduce/reduce_scatter)."""
    row_idx: jnp.ndarray     # (p, S) global row of each slot (pad: 0)
    ja: jnp.ndarray          # (p, S) global col             (pad: 0)
    al: jnp.ndarray          # (p, S)                        (pad: 0.0)
    au: jnp.ndarray          # (p, S)
    ad_shard: jnp.ndarray    # (p, n) diagonal owned by shard (zero elsewhere)
    part: RowPartition


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


# Memo for the device-ready distributed layouts: repeated builder calls for
# the same matrix (serving restarts, solver re-instantiation) are
# zero-precompute, matching the schedule-cache contract.  Keys pin the exact
# matrix (value digest) and the layout geometry; entries are small (device
# array handles) and matrices served per process are few, so no eviction.
_SHARDED_SLOTS_MEMO: dict = {}
_HALO_LAYOUT_MEMO: dict = {}


# ---------------------------------------------------------------------------
# Shard-layout (de)serialization: the npz layer that ships per-shard
# sub-artifacts (ShardedSlots / HaloLayout / FlatShards / FlatHalo) to
# serving workers through the PlanCache, keyed by (fingerprint, value
# digest, p, strategy kind, pack geometry).
# ---------------------------------------------------------------------------

SHARD_LAYOUT_VERSION = 1


def _layout_kinds() -> dict:
    """npz-kind -> dataclass for every serializable shard layout: the two
    segment-path layouts owned here, plus every registered path's
    ShardSupport layouts (the registry keeps this map current — a new
    path's layouts serialize with zero edits here)."""
    kinds = {"sharded_slots": ShardedSlots, "halo": HaloLayout}
    for entry in paths_mod.registered_paths():
        if entry.shard_support is not None:
            kinds.update(entry.shard_support.layout_classes())
    return kinds


def shard_layout_key(kind: str, fp: str, digest: str, p: int,
                     geo: tuple = ()) -> str:
    """Cache key of one distributed layout: matrix class + exact values +
    shard count + strategy family, plus a hash of the pack geometry (tile
    height, k-step, index dtype, partition boundaries...)."""
    gh = hashlib.sha1(json.dumps([str(g) for g in geo]).encode()
                      ).hexdigest()[:10]
    return f"shard-{kind}-{fp}.{digest}.p{p}.{gh}"


def save_shard_layout_npz(path: str, lay):
    """Serialize any of the four shard-layout dataclasses: scalar fields
    go to the JSON meta, arrays (and the embedded RowPartition) to npz.
    bf16 value streams persist widened to f32 (lossless) and re-narrow on
    load (npz has no native bfloat16)."""
    kinds = _layout_kinds()
    kind = next(k for k, cls in kinds.items() if isinstance(lay, cls))
    meta = {"version": SHARD_LAYOUT_VERSION, "kind": kind}
    arrays = {}
    for f in dataclasses.fields(lay):
        v = getattr(lay, f.name)
        if isinstance(v, RowPartition):
            for pf in dataclasses.fields(v):
                arrays[f"part__{pf.name}"] = np.asarray(getattr(v, pf.name))
        elif isinstance(v, (bool, int, float)):
            meta[f.name] = v
        elif str(v.dtype) == "bfloat16":
            meta.setdefault("__bf16__", []).append(f.name)
            arrays[f.name] = np.asarray(v, dtype=np.float32)
        else:
            arrays[f.name] = np.asarray(v)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp.npz"
    with open(tmp, "wb") as f:
        np.savez_compressed(f, __meta__=np.frombuffer(
            json.dumps(meta, sort_keys=True).encode(), dtype=np.uint8),
            **arrays)
    os.replace(tmp, path)


def load_shard_layout_npz(path: str):
    with np.load(path) as z:
        meta = json.loads(bytes(z["__meta__"]).decode())
        if meta.get("version") != SHARD_LAYOUT_VERSION:
            raise ValueError(
                f"shard layout {path}: version {meta.get('version')!r} "
                f"!= {SHARD_LAYOUT_VERSION}")
        cls = _layout_kinds()[meta["kind"]]
        bf16 = set(meta.get("__bf16__", ()))
        kwargs = {}
        for f in dataclasses.fields(cls):
            if f.name in meta:
                kwargs[f.name] = meta[f.name]
            elif f.name == "part":
                kwargs["part"] = RowPartition(
                    starts=z["part__starts"], eff_lo=z["part__eff_lo"],
                    eff_hi=z["part__eff_hi"],
                    nnz_per_part=z["part__nnz_per_part"])
            else:
                kwargs[f.name] = jnp.asarray(
                    z[f.name],
                    dtype=jnp.bfloat16 if f.name in bf16 else None)
        return cls(**kwargs)


def _cached_layout(M: CSRC, cache, kind: str, p: int, geo: tuple):
    """Probe the cache's shipped-artifact store for a layout; returns
    (layout_or_None, key_or_None)."""
    if cache is None:
        return None, None
    from .tuner import fingerprint as _fingerprint
    key = shard_layout_key(kind, _fingerprint(M), value_digest(M), p, geo)
    return cache.get_shard_layout(key), key


def _ensure_shipped(M: CSRC, cache, kind: str, p: int, geo: tuple, lay):
    """Persist a memoized layout on the first cache-bearing request: a
    layout built without a cache (e.g. during tune_mesh measurement)
    ships as soon as a cache-aware consumer asks for it."""
    if cache is None:
        return
    shipped, key = _cached_layout(M, cache, kind, p, geo)
    if shipped is None and key is not None:
        cache.put_shard_layout(key, lay)


def build_sharded_slots(M: CSRC, part: RowPartition,
                        cache=None) -> ShardedSlots:
    """Shard-stacked slot arrays over the schedule's row partition
    (memoized per exact matrix + partition boundaries; with ``cache``,
    also served from / shipped to the PlanCache npz layer)."""
    starts_geo = tuple(int(s) for s in np.asarray(part.starts))
    memo_key = (value_digest(M), np.asarray(part.starts).tobytes())
    hit = _SHARDED_SLOTS_MEMO.get(memo_key)
    if hit is not None:
        _ensure_shipped(M, cache, "sharded_slots", part.p, starts_geo, hit)
        return hit
    shipped, key = _cached_layout(M, cache, "sharded_slots", part.p,
                                  starts_geo)
    if shipped is not None:
        _SHARDED_SLOTS_MEMO[memo_key] = shipped
        return shipped
    BUILD_COUNTS.inc("sharded_slots")
    p = part.p
    ros = row_of_slot(M)
    ja = np.asarray(M.ja)
    al = np.asarray(M.al)
    au = np.asarray(M.au)
    ia = np.asarray(M.ia)
    spans = [(int(ia[part.starts[t]]), int(ia[part.starts[t + 1]]))
             for t in range(p)]
    smax = max(1, max(e - s for s, e in spans))
    smax = _round_up(smax, 128)

    def padded(arr, fill, dtype):
        out = np.full((p, smax), fill, dtype=dtype)
        for t, (s, e) in enumerate(spans):
            out[t, :e - s] = arr[s:e]
        return jnp.asarray(out)

    ad_shard = np.zeros((p, M.n), dtype=np.float32)
    for t in range(p):
        r0, r1 = part.rows(t)
        ad_shard[t, r0:r1] = np.asarray(M.ad)[r0:r1]

    out = ShardedSlots(
        row_idx=padded(ros, 0, np.int32),
        ja=padded(ja, 0, np.int32),
        al=padded(al, 0.0, np.float32),
        au=padded(au, 0.0, np.float32),
        ad_shard=jnp.asarray(ad_shard),
        part=part,
    )
    _SHARDED_SLOTS_MEMO[memo_key] = out
    if key is not None:
        cache.put_shard_layout(key, out)
    return out


@dataclasses.dataclass(frozen=True)
class HaloLayout:
    """Equal-row shard slot arrays in *local* coordinates for the paper's
    effective-accumulation ('halo') strategy: each shard owns ns rows and
    writes at most h rows below its range (the halo exchanged with the left
    neighbor)."""
    p: int
    ns: int                  # rows per shard (8-aligned)
    h: int                   # halo width (8-aligned bandwidth)
    n_pad: int
    row_loc: jnp.ndarray     # (p, S) local row of each slot
    col_rel: jnp.ndarray     # (p, S) column relative to [r0-h, r1)
    al: jnp.ndarray          # (p, S)
    au: jnp.ndarray          # (p, S)
    ad: jnp.ndarray          # (p, ns)


def build_halo_layout(M: CSRC, p: int, cache=None) -> HaloLayout:
    """Memoized per exact matrix + shard count (with ``cache``, also
    served from / shipped to the PlanCache npz layer).  Raises ValueError
    when the band does not fit inside one shard (the strategy's
    feasibility gate — callers fall back to allreduce/reduce_scatter)."""
    from .csrc import bandwidth as csrc_bandwidth

    memo_key = (value_digest(M), p)
    hit = _HALO_LAYOUT_MEMO.get(memo_key)
    if hit is not None:
        _ensure_shipped(M, cache, "halo", p, (), hit)
        return hit
    shipped, key = _cached_layout(M, cache, "halo", p, ())
    if shipped is not None:
        _HALO_LAYOUT_MEMO[memo_key] = shipped
        return shipped
    BUILD_COUNTS.inc("halo_layout")
    n = M.n
    ns = _round_up(-(-n // p), 8)          # rows per shard
    n_pad = ns * p
    band = csrc_bandwidth(M)
    h = max(8, _round_up(band, 8))
    if h > ns:
        raise ValueError(
            f"band {band} exceeds shard rows {ns}; halo strategy needs "
            "band <= n/p (fall back to allreduce/reduce_scatter)")

    ros = row_of_slot(M)
    ja = np.asarray(M.ja)
    al_np = np.asarray(M.al)
    au_np = np.asarray(M.au)
    shard_of_slot = ros // ns
    counts = np.bincount(shard_of_slot, minlength=p)
    smax = _round_up(max(1, int(counts.max())), 128)
    row_loc = np.zeros((p, smax), np.int32)
    col_rel = np.full((p, smax), ns + h - 1, np.int32)   # inert target
    al_s = np.zeros((p, smax), np.float32)
    au_s = np.zeros((p, smax), np.float32)
    fill = np.zeros(p, np.int64)
    for idx in np.argsort(shard_of_slot, kind="stable"):
        t = int(shard_of_slot[idx])
        q = int(fill[t]); fill[t] += 1
        row_loc[t, q] = int(ros[idx]) - t * ns
        col_rel[t, q] = int(ja[idx]) - (t * ns - h)      # in [0, ns+h)
        al_s[t, q] = al_np[idx]
        au_s[t, q] = au_np[idx]
    ad_pad = np.zeros(n_pad, np.float32)
    ad_pad[:n] = np.asarray(M.ad)
    out = HaloLayout(p=p, ns=ns, h=h, n_pad=n_pad,
                     row_loc=jnp.asarray(row_loc),
                     col_rel=jnp.asarray(col_rel),
                     al=jnp.asarray(al_s), au=jnp.asarray(au_s),
                     ad=jnp.asarray(ad_pad.reshape(p, ns)))
    _HALO_LAYOUT_MEMO[memo_key] = out
    if key is not None:
        cache.put_shard_layout(key, out)
    return out


# Shard-local path layouts (a plan whose path has ShardSupport, under a
# distributed strategy): per-shard sub-packs, memoized like the other
# layouts so repeated builder calls are zero-precompute.  One memo dict
# per layout kind; the flat names are module-level for compatibility
# (tests clear them to force rebuild counting).
_FLAT_SHARDS_MEMO: dict = {}
_FLAT_HALO_MEMO: dict = {}
_PATH_LAYOUT_MEMOS: dict = {"flat_shards": _FLAT_SHARDS_MEMO,
                            "flat_halo": _FLAT_HALO_MEMO}


def _layout_memo(kind: str) -> dict:
    return _PATH_LAYOUT_MEMOS.setdefault(kind, {})


# one mapping from plan dtype strings to jnp dtypes for the whole stack
# (paths.py owns it; the local pack builders use the same helpers)
_plan_index_dtype = paths_mod._index_dtype_of
_plan_value_dtype = paths_mod._value_dtype_of


def _shard_support_of(path_name: str):
    sup = paths_mod.get_path(path_name).shard_support
    if sup is None:
        raise ValueError(f"path {path_name!r} registers no shard support; "
                         "distributed strategies run it as segment-sum")
    return sup


def build_path_shards(M: CSRC, part: RowPartition, plan: ExecutionPlan,
                      cache=None):
    """Per-shard sub-packs of ``plan.path`` over the schedule's row
    partition (global coordinates; allreduce / reduce_scatter
    strategies).  Generic over the registry's ShardSupport: memoized per
    exact matrix + partition boundaries + path pack geometry; with
    ``cache``, also served from / shipped to the PlanCache npz layer."""
    sup = _shard_support_of(plan.path)
    kind = sup.shards_kind
    pgeo = sup.geometry(plan)
    geo = pgeo + tuple(int(s) for s in np.asarray(part.starts))
    memo = _layout_memo(kind)
    memo_key = (value_digest(M), np.asarray(part.starts).tobytes()) + pgeo
    hit = memo.get(memo_key)
    if hit is not None:
        _ensure_shipped(M, cache, kind, part.p, geo, hit)
        return hit
    shipped, key = _cached_layout(M, cache, kind, part.p, geo)
    if shipped is not None:
        memo[memo_key] = shipped
        return shipped
    BUILD_COUNTS.inc(kind)
    out = sup.pack_shards(M, np.asarray(part.starts), plan)
    memo[memo_key] = out
    if key is not None:
        cache.put_shard_layout(key, out)
    return out


def build_path_halo(M: CSRC, p: int, plan: ExecutionPlan, cache=None):
    """Per-shard local-coordinate packs of ``plan.path`` for the halo
    strategy.  Raises ValueError when the band does not fit inside one
    shard (same gate as :func:`build_halo_layout`).  Memoized per exact
    matrix + shard count + path pack geometry; with ``cache``, also
    served from / shipped to the PlanCache npz layer."""
    sup = _shard_support_of(plan.path)
    kind = sup.halo_kind
    geo = sup.geometry(plan)
    memo = _layout_memo(kind)
    memo_key = (value_digest(M), p) + geo
    hit = memo.get(memo_key)
    if hit is not None:
        _ensure_shipped(M, cache, kind, p, geo, hit)
        return hit
    shipped, key = _cached_layout(M, cache, kind, p, geo)
    if shipped is not None:
        memo[memo_key] = shipped
        return shipped
    BUILD_COUNTS.inc(kind)
    out = sup.pack_halo(M, p, plan)
    memo[memo_key] = out
    if key is not None:
        cache.put_shard_layout(key, out)
    return out


def build_flat_shards(M: CSRC, part: RowPartition, plan: ExecutionPlan,
                      cache=None):
    """Back-compat name: :func:`build_path_shards` for a 'flat' plan."""
    return build_path_shards(M, part, plan, cache=cache)


def build_flat_halo_layout(M: CSRC, p: int, plan: ExecutionPlan,
                           cache=None):
    """Back-compat name: :func:`build_path_halo` for a 'flat' plan."""
    return build_path_halo(M, p, plan, cache=cache)


# ---------------------------------------------------------------------------
# Same-structure value refresh of the shard layouts (the mesh-path analog
# of refresh_schedule: serving update_values / FEM time stepping must not
# re-pack, re-partition, or re-color on the mesh)
# ---------------------------------------------------------------------------

def refresh_shard_layout(lay, M: CSRC, part: Optional[RowPartition] = None):
    """Refill a distributed layout's value streams from a same-structure
    matrix.  Structural arrays (slot indices, tile maps, halo geometry)
    are reused untouched; only al/au/ad streams are rewritten — the probe
    counter is ``shard_value_refresh``, and no structural counter moves.
    ``part`` is required for the partition-keyed shards layouts
    (FlatShards, NnzSplitShards, ... — they do not embed their partition
    boundaries)."""
    BUILD_COUNTS.inc("shard_value_refresh")
    if isinstance(lay, ShardedSlots):
        return _refresh_sharded_slots(lay, M)
    if isinstance(lay, HaloLayout):
        return _refresh_halo_layout(lay, M)
    # path-specific layouts: dispatch through the registry's ShardSupport
    for entry in paths_mod.registered_paths():
        sup = entry.shard_support
        if sup is None:
            continue
        classes = sup.layout_classes()
        if isinstance(lay, classes[sup.shards_kind]):
            if part is None:
                raise ValueError(
                    f"refresh_shard_layout: {type(lay).__name__} needs "
                    "the row partition it was built over")
            return sup.refresh_shards(lay, M, np.asarray(part.starts))
        if isinstance(lay, classes[sup.halo_kind]):
            return sup.refresh_halo(lay, M)
    raise TypeError(f"unknown shard layout {type(lay).__name__}")


def _refresh_sharded_slots(ss: ShardedSlots, M: CSRC) -> ShardedSlots:
    """Value-only refill of the stacked slot arrays: the spans are
    re-derived from the (unchanged) row pointers, so the padded layout is
    bit-compatible with the original build."""
    part = ss.part
    p = part.p
    ia = np.asarray(M.ia)
    al = np.asarray(M.al)
    au = np.asarray(M.au)
    smax = int(ss.al.shape[1])
    spans = [(int(ia[part.starts[t]]), int(ia[part.starts[t + 1]]))
             for t in range(p)]

    def padded(arr):
        out = np.zeros((p, smax), dtype=np.float32)
        for t, (s, e) in enumerate(spans):
            out[t, :e - s] = arr[s:e]
        return jnp.asarray(out)

    ad_shard = np.zeros((p, M.n), dtype=np.float32)
    for t in range(p):
        r0, r1 = part.rows(t)
        ad_shard[t, r0:r1] = np.asarray(M.ad)[r0:r1]
    return dataclasses.replace(ss, al=padded(al), au=padded(au),
                               ad_shard=jnp.asarray(ad_shard))


def _refresh_halo_layout(lay: HaloLayout, M: CSRC) -> HaloLayout:
    """Value-only refill of the local-coordinate halo arrays, vectorized:
    slots are row-major, so a shard's slots are consecutive and the
    original fill order (stable sort over a non-decreasing shard array)
    is the identity."""
    ros = row_of_slot(M)
    k = ros.shape[0]
    p, ns = lay.p, lay.ns
    smax = int(lay.al.shape[1])
    al_s = np.zeros((p, smax), np.float32)
    au_s = np.zeros((p, smax), np.float32)
    if k:
        shard = ros // ns
        first = np.searchsorted(shard, np.arange(p))
        q = np.arange(k) - first[shard]
        al_s[shard, q] = np.asarray(M.al)
        au_s[shard, q] = np.asarray(M.au)
    ad_pad = np.zeros(lay.n_pad, np.float32)
    ad_pad[:M.n] = np.asarray(M.ad)
    return dataclasses.replace(lay, al=jnp.asarray(al_s),
                               au=jnp.asarray(au_s),
                               ad=jnp.asarray(ad_pad.reshape(p, ns)))


# ---------------------------------------------------------------------------
# Colorful execution over the precomputed batches (single- and multi-RHS)
# ---------------------------------------------------------------------------

def colorful_apply(M: CSRC, x, color_slots: np.ndarray,
                   color_slot_ptr: np.ndarray):
    """y = A·x color by color, using the schedule's precomputed slot batches.

    ``x`` may be (n,) or (n, r): inside one color every write target is
    unique, so ``.at[].add`` is a permutation write for any RHS width.
    """
    two_d = x.ndim == 2
    row_idx = jnp.asarray(row_of_slot(M))

    def bc(v):                  # broadcast slot values over RHS columns
        return v[:, None] if two_d else v

    y = (M.ad[:, None] if two_d else M.ad) * x[:M.n]
    ptr = np.asarray(color_slot_ptr)
    for c in range(len(ptr) - 1):
        sl = jnp.asarray(color_slots[ptr[c]:ptr[c + 1]])
        if sl.shape[0] == 0:
            continue
        r = row_idx[sl]
        j = M.ja[sl]
        y = y.at[r].add(bc(M.al[sl]) * x[j])
        y = y.at[j].add(bc(M.au[sl]) * x[r])
    return y
