"""CSRC (compressed sparse row-column) storage format.

The paper's core data structure (§2): a structurally-symmetric n×n sparse
matrix A is decomposed as A = A_D + A_L + A_U.  Only the *lower* triangle's
combinatorial structure is stored:

  ad (n,)     diagonal values
  ia (n+1,)   row pointers into the lower triangle (CSR-style)
  ja (k,)     column indices of the strictly-lower non-zeros, k = (nnz - n) / 2
  al (k,)     values of the strictly-lower non-zeros  (A_L, row-major)
  au (k,)     values at the *transposed* positions    (A_U, column-major)

i.e. al[p] = A[i, ja[p]] and au[p] = A[ja[p], i] for p in [ia[i], ia[i+1]).
A_L is CSR; A_U is CSC sharing the same (ia, ja).  This halves index memory
vs CSR and lets one pass over the lower half produce both the row (gather)
and column (scatter) contributions of the product.

The rectangular extension (§2.1) represents an n×m matrix (m > n) as
A = [A_S | A_R] where A_S is n×n structurally symmetric (CSRC) and A_R is
n×(m-n) general (auxiliary CSR: iar, jar, ar).

Host-side construction is numpy; the resulting container holds jnp arrays
ready for jit'd products.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax.numpy as jnp

Array = np.ndarray


@dataclasses.dataclass(frozen=True)
class CSRC:
    """Device-ready CSRC matrix (square structurally-symmetric part + optional
    rectangular CSR tail)."""

    n: int                      # number of rows (= cols of the square part)
    m: int                      # total number of columns (m == n if square)
    ad: jnp.ndarray             # (n,) diagonal
    ia: jnp.ndarray             # (n+1,) lower-triangle row pointers
    ja: jnp.ndarray             # (k,) lower-triangle column indices
    al: jnp.ndarray             # (k,) lower values
    au: jnp.ndarray             # (k,) upper (transpose-position) values
    # Rectangular tail A_R (n × (m-n)) stored as CSR; empty arrays if square.
    iar: jnp.ndarray            # (n+1,)
    jar: jnp.ndarray            # (kr,) column indices in [0, m-n)
    ar: jnp.ndarray             # (kr,)
    numerically_symmetric: bool = False

    @property
    def k(self) -> int:
        return int(self.ja.shape[0])

    @property
    def nnz(self) -> int:
        """Non-zeros of the square part counting both halves + diagonal,
        plus the rectangular tail."""
        return self.n + 2 * self.k + int(self.jar.shape[0])

    @property
    def is_square(self) -> bool:
        return self.m == self.n

    def working_set_bytes(self) -> int:
        """Paper Table 1's ``ws`` column: bytes touched by one product."""
        total = 0
        for a in (self.ad, self.ia, self.ja, self.al, self.au,
                  self.iar, self.jar, self.ar):
            total += a.size * a.dtype.itemsize
        # source + destination vectors
        total += self.m * self.ad.dtype.itemsize
        total += self.n * self.ad.dtype.itemsize
        return total


def _dedup_coo(rows: Array, cols: Array, vals: Array, n: int, m: int):
    """Sum duplicate (row, col) entries; return sorted COO."""
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    key = rows.astype(np.int64) * m + cols.astype(np.int64)
    uniq, inv = np.unique(key, return_inverse=True)
    out_vals = np.zeros(uniq.shape[0], dtype=vals.dtype)
    np.add.at(out_vals, inv, vals)
    out_rows = (uniq // m).astype(np.int32)
    out_cols = (uniq % m).astype(np.int32)
    return out_rows, out_cols, out_vals


def symmetrize_pattern(rows: Array, cols: Array, vals: Array, n: int):
    """Make the pattern of the square part structurally symmetric by adding
    explicit zeros at missing transpose positions (standard FEM preprocessing:
    global FEM matrices are pattern-symmetric by construction; general inputs
    are padded)."""
    in_sq = (rows < n) & (cols < n)
    r, c, v = rows[in_sq], cols[in_sq], vals[in_sq]
    key = set(zip(r.tolist(), c.tolist()))
    add_r, add_c = [], []
    for (i, j) in key:
        if i != j and (j, i) not in key:
            add_r.append(j)
            add_c.append(i)
    if add_r:
        rows = np.concatenate([rows, np.asarray(add_r, dtype=rows.dtype)])
        cols = np.concatenate([cols, np.asarray(add_c, dtype=cols.dtype)])
        vals = np.concatenate([vals, np.zeros(len(add_r), dtype=vals.dtype)])
    return rows, cols, vals


def from_coo(rows: Array, cols: Array, vals: Array, n: int,
             m: Optional[int] = None, dtype=np.float32,
             pad_pattern: bool = True) -> CSRC:
    """Build a CSRC matrix from COO triplets.

    The square n×n leading block must be (or is padded to be) structurally
    symmetric.  Columns >= n go to the rectangular CSR tail.
    """
    m = n if m is None else m
    rows = np.asarray(rows, dtype=np.int32)
    cols = np.asarray(cols, dtype=np.int32)
    vals = np.asarray(vals, dtype=dtype)
    if rows.size:
        assert rows.max() < n and cols.max() < m, "index out of range"
    if pad_pattern:
        rows, cols, vals = symmetrize_pattern(rows, cols, vals, n)
    rows, cols, vals = _dedup_coo(rows, cols, vals, n, m)

    sq = cols < n
    r_sq, c_sq, v_sq = rows[sq], cols[sq], vals[sq]

    # --- diagonal ---
    ad = np.zeros(n, dtype=dtype)
    diag = r_sq == c_sq
    ad[r_sq[diag]] = v_sq[diag]

    # --- strictly lower triangle, row-major (already lexsorted) ---
    low = c_sq < r_sq
    r_lo, c_lo, v_lo = r_sq[low], c_sq[low], v_sq[low]
    k = r_lo.shape[0]
    ia = np.zeros(n + 1, dtype=np.int32)
    np.add.at(ia, r_lo + 1, 1)
    ia = np.cumsum(ia, dtype=np.int32)
    ja = c_lo.astype(np.int32)
    al = v_lo.astype(dtype)

    # --- upper values aligned to the lower slots: au[p] = A[ja[p], i(p)] ---
    up = c_sq > r_sq
    r_up, c_up, v_up = r_sq[up], c_sq[up], v_sq[up]
    # Lower slot p sits at (i, j) = (row_of_slot[p], ja[p]); its transpose
    # partner is the upper entry at (j, i).  Keys of lower slots are sorted
    # ascending (COO was lexsorted by (row, col)), so align via searchsorted.
    au = np.zeros(k, dtype=dtype)
    row_of_slot = np.repeat(np.arange(n, dtype=np.int32), np.diff(ia))
    if k:
        key_lower = row_of_slot.astype(np.int64) * n + ja.astype(np.int64)
        key_upper = c_up.astype(np.int64) * n + r_up.astype(np.int64)
        pos = np.searchsorted(key_lower, key_upper)
        ok = (pos < k) & (key_lower[np.minimum(pos, k - 1)] == key_upper)
        au[pos[ok]] = v_up[ok].astype(dtype)

    num_sym = bool(k == 0 or np.allclose(al, au))

    # --- rectangular tail ---
    rect = ~sq
    r_rc, c_rc, v_rc = rows[rect], cols[rect] - n, vals[rect]
    kr = r_rc.shape[0]
    iar = np.zeros(n + 1, dtype=np.int32)
    np.add.at(iar, r_rc + 1, 1)
    iar = np.cumsum(iar, dtype=np.int32)
    jar = c_rc.astype(np.int32)
    ar = v_rc.astype(dtype)

    return CSRC(
        n=n, m=m,
        ad=jnp.asarray(ad), ia=jnp.asarray(ia), ja=jnp.asarray(ja),
        al=jnp.asarray(al), au=jnp.asarray(au),
        iar=jnp.asarray(iar), jar=jnp.asarray(jar), ar=jnp.asarray(ar),
        numerically_symmetric=num_sym,
    )


def from_coo_symmetric(rows: Array, cols: Array, vals: Array, n: int,
                       dtype=np.float32) -> CSRC:
    """Build a square CSRC matrix from COO triplets whose pattern is
    *already* structurally symmetric — the shape FEM assembly produces by
    construction (every element contributes a dense symmetric block of
    positions).  Skips the O(k) transpose-completion set walk of
    :func:`symmetrize_pattern`; duplicate entries are summed as usual."""
    return from_coo(rows, cols, vals, n=n, m=n, dtype=dtype,
                    pad_pattern=False)


def from_assembly(n: int, ia: Array, ja: Array, ad: Array, al: Array,
                  au: Array, dtype=np.float32) -> CSRC:
    """Assemble-side constructor: build a square CSRC container directly
    from precomputed structure (``ia``/``ja`` — e.g. an
    :class:`repro.assembly.scatter.AssemblySchedule`'s slot layout) and
    freshly scattered value streams.  No dedup, no pattern work — this is
    the value-refresh path FEM time stepping takes every step, so it must
    stay O(k) array conversions only."""
    ia = np.asarray(ia, dtype=np.int32)
    ja = np.asarray(ja, dtype=np.int32)
    ad = np.asarray(ad, dtype=dtype)
    al = np.asarray(al, dtype=dtype)
    au = np.asarray(au, dtype=dtype)
    assert ia.shape == (n + 1,) and ad.shape == (n,)
    assert al.shape == ja.shape == au.shape
    num_sym = bool(ja.shape[0] == 0 or np.array_equal(al, au))
    empty_i = np.zeros(n + 1, dtype=np.int32)
    empty = np.zeros(0, dtype=np.int32)
    return CSRC(
        n=n, m=n,
        ad=jnp.asarray(ad), ia=jnp.asarray(ia), ja=jnp.asarray(ja),
        al=jnp.asarray(al), au=jnp.asarray(au),
        iar=jnp.asarray(empty_i), jar=jnp.asarray(empty),
        ar=jnp.asarray(empty.astype(dtype)),
        numerically_symmetric=num_sym,
    )


def from_scipy(A, dtype=np.float32) -> CSRC:
    """Ingest any ``scipy.sparse`` matrix.

    The square leading block is pattern-symmetrized with explicit zeros at
    missing transpose positions (the standard CSRC preprocessing), values
    split into ad / al / au; columns ``>= n`` land in the rectangular CSR
    tail.  scipy is imported lazily — it is an ingestion convenience, not a
    package dependency.
    """
    try:
        import scipy.sparse as sp
    except ImportError as e:   # pragma: no cover - scipy present in CI
        raise ImportError(
            "CSRC.from_scipy requires scipy; install it or build via "
            "from_coo directly") from e
    if not sp.issparse(A):
        raise TypeError(
            f"from_scipy expects a scipy.sparse matrix, got "
            f"{type(A).__name__}")
    n, m = A.shape
    if m < n:
        raise ValueError(
            "CSRC requires m >= n (the rectangular extension stores wide "
            "matrices only); transpose the input first")
    C = A.tocoo()
    return from_coo(C.row, C.col, C.data, n=n, m=m, dtype=dtype,
                    pad_pattern=True)


def from_dense(A: Array, dtype=np.float32) -> CSRC:
    """Build from a dense matrix, keeping exact non-zero pattern (plus the
    symmetrizing explicit zeros)."""
    A = np.asarray(A)
    n, m = A.shape
    assert m >= n, "CSRC requires m >= n (rectangular extension is n x m, m>n)"
    rows, cols = np.nonzero(A)
    vals = A[rows, cols]
    return from_coo(rows, cols, vals, n=n, m=m, dtype=dtype)


def to_dense(M: CSRC) -> Array:
    """Oracle-side expansion back to dense (numpy)."""
    n, m = M.n, M.m
    A = np.zeros((n, m), dtype=np.asarray(M.ad).dtype)
    A[np.arange(n), np.arange(n)] = np.asarray(M.ad)
    ia = np.asarray(M.ia)
    ja = np.asarray(M.ja)
    al = np.asarray(M.al)
    au = np.asarray(M.au)
    row_of_slot = np.repeat(np.arange(n), np.diff(ia))
    A[row_of_slot, ja] = al
    A[ja, row_of_slot] = au
    iar = np.asarray(M.iar)
    if M.jar.shape[0]:
        row_r = np.repeat(np.arange(n), np.diff(iar))
        A[row_r, np.asarray(M.jar) + n] = np.asarray(M.ar)
    return A


def row_of_slot(M: CSRC) -> Array:
    """Expand ia to a per-slot row index (host-side helper)."""
    ia = np.asarray(M.ia)
    return np.repeat(np.arange(M.n, dtype=np.int32), np.diff(ia))


def bandwidth(M: CSRC) -> int:
    """Maximum |i - j| over stored off-diagonal entries (paper §4.2 discusses
    band structure as the locality driver)."""
    if M.k == 0:
        return 0
    ros = row_of_slot(M)
    return int(np.max(ros - np.asarray(M.ja)))


def nnz_per_row(M: CSRC) -> Array:
    """Full (both halves + diag + rect tail) non-zeros per row — the load
    balance metric used for nnz-guided partitioning."""
    n = M.n
    ia = np.asarray(M.ia)
    lower = np.diff(ia)
    upper = np.zeros(n, dtype=np.int64)
    np.add.at(upper, np.asarray(M.ja), 1)
    rect = np.diff(np.asarray(M.iar))
    return lower + upper + rect + 1


# ---------------------------------------------------------------------------
# Transpose product support (paper §5: transpose = swap al/au)
# ---------------------------------------------------------------------------

def transpose(M: CSRC) -> CSRC:
    """O(1): swapping al and au yields A_S^T.  Only valid for square CSRC."""
    assert M.is_square, "transpose of the rectangular extension not supported"
    return dataclasses.replace(M, al=M.au, au=M.al)


# ---------------------------------------------------------------------------
# Synthetic matrix generators (benchmark + test suite substrate; the UF
# collection is not available offline, so we generate the same *classes*:
# FEM band matrices, quasi-diagonal, random sparse, dense)
# ---------------------------------------------------------------------------

def poisson2d(nx: int, ny: Optional[int] = None, dtype=np.float32) -> CSRC:
    """5-point Laplacian on an nx×ny grid — the canonical FEM-like band matrix
    (numerically symmetric, bandwidth nx)."""
    ny = nx if ny is None else ny
    n = nx * ny
    rows, cols, vals = [], [], []
    for y in range(ny):
        for x in range(nx):
            i = y * nx + x
            rows.append(i); cols.append(i); vals.append(4.0)
            for dx, dy in ((-1, 0), (1, 0), (0, -1), (0, 1)):
                xx, yy = x + dx, y + dy
                if 0 <= xx < nx and 0 <= yy < ny:
                    j = yy * nx + xx
                    rows.append(i); cols.append(j); vals.append(-1.0)
    return from_coo(np.asarray(rows), np.asarray(cols),
                    np.asarray(vals, dtype=np.float64), n=n, dtype=dtype)


def fem_band(n: int, half_band: int, seed: int = 0, fill: float = 0.6,
             numeric_symmetric: bool = False, dtype=np.float32) -> CSRC:
    """Random band matrix with structurally-symmetric pattern: each row gets
    ~fill·half_band entries inside the band, mirrored. Diagonally dominant."""
    rng = np.random.default_rng(seed)
    rows, cols, vals = [], [], []
    for i in range(n):
        lo = max(0, i - half_band)
        cand = np.arange(lo, i)
        if cand.size:
            take = rng.random(cand.size) < fill
            for j in cand[take]:
                vl = rng.standard_normal()
                vu = vl if numeric_symmetric else rng.standard_normal()
                rows += [i, int(j)]
                cols += [int(j), i]
                vals += [vl, vu]
    rows += list(range(n))
    cols += list(range(n))
    vals += list(2.0 * half_band * np.ones(n))
    return from_coo(np.asarray(rows), np.asarray(cols),
                    np.asarray(vals, dtype=np.float64), n=n, dtype=dtype,
                    pad_pattern=False)


def skewed_band(n: int, wide_band: int, narrow_band: int = 3,
                wide_frac: float = 0.06, seed: int = 0,
                numeric_symmetric: bool = False, dtype=np.float32) -> CSRC:
    """Band matrix with a *skewed* row-length distribution: the first
    ``wide_frac·n`` rows carry a dense band of half-width ``wide_band``,
    the rest a narrow band of ``narrow_band`` — the skewed-FEM shape where
    a rectangular block-ELL grid pads every tile to the densest one and
    the flat-grid kernel does not (docs/DESIGN.md §4)."""
    rng = np.random.default_rng(seed)
    n_wide = max(1, int(round(wide_frac * n)))
    rows, cols, vals = [], [], []
    for i in range(n):
        width = wide_band if i < n_wide else narrow_band
        for j in range(max(0, i - width), i):
            vl = rng.standard_normal()
            vu = vl if numeric_symmetric else rng.standard_normal()
            rows += [i, j]
            cols += [j, i]
            vals += [vl, vu]
    rows += list(range(n))
    cols += list(range(n))
    vals += list(2.0 * wide_band * np.ones(n))
    return from_coo(np.asarray(rows), np.asarray(cols),
                    np.asarray(vals, dtype=np.float64), n=n, dtype=dtype,
                    pad_pattern=False)


def random_symmetric_pattern(n: int, avg_nnz_per_row: int, seed: int = 0,
                             dtype=np.float32) -> CSRC:
    """Unstructured pattern (cage15/F1-like: no band structure)."""
    rng = np.random.default_rng(seed)
    k = n * avg_nnz_per_row // 2
    r = rng.integers(1, n, size=k, dtype=np.int64)
    c = (rng.random(k) * r).astype(np.int64)  # strictly lower
    v = rng.standard_normal(k)
    vu = rng.standard_normal(k)
    rows = np.concatenate([r, c, np.arange(n)])
    cols = np.concatenate([c, r, np.arange(n)])
    vals = np.concatenate([v, vu, np.full(n, float(avg_nnz_per_row) + 1.0)])
    return from_coo(rows, cols, vals, n=n, dtype=dtype, pad_pattern=False)


def powerlaw_laplacian(n: int, attach: int = 4, seed: int = 0,
                       dtype=np.float32) -> CSRC:
    """Graph Laplacian of a Barabási–Albert preferential-attachment graph
    with randomly shuffled vertex labels — the unstructured scenario class
    (social/power/circuit graphs) none of the band-ish generators cover.

    Two properties matter downstream: the power-law degree distribution
    gives a high nnz-per-row CoV (hub rows), and the label shuffle spreads
    ``ja`` across the full index range (bandwidth ~ n), so windowed paths
    either pad explosively or fall infeasible.  All entries are small
    integers (degree diagonal, -1 off-diagonals), exactly representable in
    float32: products against dyadic vectors are accumulation-order
    independent, which is what lets tests compare kernels bit-for-bit
    against the dense oracle."""
    assert n > attach >= 1
    rng = np.random.default_rng(seed)
    edges = []
    repeated: list = []             # endpoint pool; sampling it uniformly
    targets = list(range(attach))   # is preferential attachment by degree
    for source in range(attach, n):
        for t in targets:
            edges.append((source, t))
        repeated.extend(targets)
        repeated.extend([source] * attach)
        seen: set = set()
        targets = []
        while len(targets) < attach:
            x = int(repeated[rng.integers(0, len(repeated))])
            if x not in seen:
                seen.add(x)
                targets.append(x)
    perm = rng.permutation(n)
    e = perm[np.asarray(edges, dtype=np.int64)]         # (ne, 2) relabeled
    u, v = e[:, 0], e[:, 1]
    deg = np.zeros(n, dtype=np.int64)
    np.add.at(deg, u, 1)
    np.add.at(deg, v, 1)
    rows = np.concatenate([u, v, np.arange(n)])
    cols = np.concatenate([v, u, np.arange(n)])
    vals = np.concatenate([-np.ones(2 * e.shape[0]), deg.astype(np.float64)])
    return from_coo(rows, cols, vals, n=n, dtype=dtype, pad_pattern=False)


def paper_example(dtype=np.float32) -> CSRC:
    """The paper's 9×9 conflict-graph example: a structurally-symmetric
    pattern with exactly 12 direct conflicts (stored lower entries) and 7
    indirect conflicts (non-adjacent row pairs sharing a direct neighbor)
    — the counts §3.2 reports for its illustration.  Values are small
    integers so products against dyadic vectors are exact in float32."""
    lower = np.asarray([(1, 0), (2, 0), (4, 0), (6, 0), (2, 1), (4, 1),
                        (4, 2), (6, 2), (7, 3), (8, 3), (7, 5), (8, 6)],
                       dtype=np.int64)
    r, c = lower[:, 0], lower[:, 1]
    deg = np.zeros(9, dtype=np.int64)
    np.add.at(deg, r, 1)
    np.add.at(deg, c, 1)
    rows = np.concatenate([r, c, np.arange(9)])
    cols = np.concatenate([c, r, np.arange(9)])
    vals = np.concatenate([-np.ones(2 * len(lower)),
                           (deg + 1).astype(np.float64)])
    return from_coo(rows, cols, vals, n=9, dtype=dtype, pad_pattern=False)


def dense_matrix(n: int, seed: int = 0, dtype=np.float32) -> CSRC:
    """The paper's dense_1000 control case."""
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((n, n)).astype(dtype)
    return from_dense(A, dtype=dtype)


def rectangular_fem(n: int, extra_cols: int, half_band: int, seed: int = 0,
                    dtype=np.float32) -> CSRC:
    """Paper §2.1: overlapping-subdomain matrices A = [A_S | A_R]."""
    rng = np.random.default_rng(seed)
    base = fem_band(n, half_band, seed=seed, numeric_symmetric=True,
                    dtype=dtype)
    kr = max(1, n // 4)
    r = rng.integers(0, n, size=kr, dtype=np.int64)
    c = rng.integers(0, extra_cols, size=kr, dtype=np.int64) + n
    v = rng.standard_normal(kr)
    # rebuild with the tail via COO to keep construction single-path
    A = to_dense(base)
    full = np.zeros((n, n + extra_cols), dtype=A.dtype)
    full[:, :n] = A
    full[r, c] = v.astype(A.dtype)
    return from_dense(full, dtype=dtype)


# quickstart-facing alias: CSRC.from_scipy(sp_matrix) reads naturally at
# ingestion call sites
CSRC.from_scipy = staticmethod(from_scipy)
