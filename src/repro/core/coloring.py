"""The colorful partitioning method (paper §3.2).

Rows are vertices of the conflict graph G[A]; two rows conflict when
processing them concurrently could write the same y position:

  * direct conflict:  row j > i has a stored lower entry in column i
    (thread owning j scatters into y[i] while thread owning i writes y[i]);
  * indirect conflict: rows u, v share a neighbor in the direct graph
    (both scatter into the same third row's y slot).

Two coloring providers build the conflict-free color classes:

``greedy`` — sequential coloring of G[A], **largest-degree-first**
(Welsh–Powell): high-degree vertices are colored while many colors are
still unused, which empirically never needs more colors than the
unordered first-fit on our matrix classes — ``color_rows`` additionally
guards the invariant by falling back to the natural-order result if
degree ordering ever came out worse.  The product is computed
color-by-color (serial across colors, parallel inside); within a color
every write target is unique.

``race`` — the recursive level-group scheme of RACE (Alappat et al.,
arXiv:1907.06487): BFS levels of the conflict graph from a
locality-preserving seed (the lowest-index minimum-degree vertex of each
component — a band end / mesh corner, so levels sweep the rows in index
order), recursively bipartitioned into even/odd level groups.  Same-parity
groups are ≥ 2 levels apart, hence conflict-free against each other; a
group whose induced subgraph is still too large is recursively split the
same way (its sub-parity refines the parent color).  The classes that come
out are unions of *contiguous level ranges* — the locality the paper's
§3.2 criticism asks for — at the price of a weaker intra-class guarantee:
rows of one color are partitioned into **serial chunks** (``group_of_row``,
one chunk per leaf level group) and write targets are only disjoint
*across* chunks.  Inside a chunk the modeled machine runs rows serially,
and the jnp executors scatter with order-free ``.at[].add`` (sum
combining), so intra-chunk sharing is numerically exact either way.
``verify_coloring`` checks exactly this chunk-aware invariant (which
degenerates to the classic per-row one for greedy colorings).

On top of either provider sits a RACE-style balancing pass: rows are moved
from over-full color classes into under-full ones (staying conflict-free
at the *classic* distance — strictly stronger than the chunk invariant —
and never adding a color), preferring the class whose members are nearest
in row index.

On TPU this maps to: rows of one color form a batch whose scatter is a
single ``at[].add`` launch — fewer colors mean fewer serial launches, and
contiguous classes keep the x/y working set in cache between them.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import List, Optional

import numpy as np

from .csrc import CSRC, row_of_slot

# Coloring providers ``color_graph`` (and everything above it) accepts.
PROVIDERS = ("greedy", "race")

# RACE recursion bounds: a leaf level group bigger than n / (2·p_target)
# rows is recursively re-split (so every color offers ≥ ~2·p chunks of
# modeled parallelism) until the bipartition stops making progress or the
# depth cap is hit.
RACE_P_TARGET = 8
RACE_MAX_DEPTH = 4


@dataclasses.dataclass(frozen=True)
class Coloring:
    color_of_row: np.ndarray     # (n,) color id per row
    num_colors: int
    # rows grouped by color, concatenated; color c owns
    # rows_by_color[color_ptr[c]:color_ptr[c+1]]
    rows_by_color: np.ndarray
    color_ptr: np.ndarray
    # which provider built the classes ('greedy' | 'race')
    provider: str = "greedy"
    # RACE level-group metadata (None for greedy): the top-level BFS level
    # per row, and the serial-chunk id per row — rows sharing (color, group)
    # may share write targets (executed as one serial chunk); rows sharing a
    # color across different groups never do.
    level_of_row: Optional[np.ndarray] = None
    group_of_row: Optional[np.ndarray] = None

    def rows(self, c: int) -> np.ndarray:
        return self.rows_by_color[self.color_ptr[c]:self.color_ptr[c + 1]]

    def class_sizes(self) -> np.ndarray:
        return np.diff(self.color_ptr)


def direct_adjacency(M: CSRC) -> List[np.ndarray]:
    """Adjacency lists of the *direct* conflict graph: i ~ ja[p] for every
    stored lower slot p of row i (symmetric)."""
    n = M.n
    ros = row_of_slot(M)
    ja = np.asarray(M.ja)
    adj: List[List[int]] = [[] for _ in range(n)]
    for i, j in zip(ros.tolist(), ja.tolist()):
        adj[i].append(j)
        adj[j].append(i)
    return [np.unique(np.asarray(a, dtype=np.int64)) for a in adj]


def _mark_forbidden(v: int, adj, color, include_indirect: bool,
                    mask: np.ndarray) -> list:
    """Mark ``mask[c] = True`` for every color already used within conflict
    distance of ``v`` (distance 2 when indirect conflicts are included).

    ``mask`` is the reusable boolean scratch of the greedy/balance hot
    loops — no per-vertex ``set`` is built.  Returns the list of marked
    color arrays so the caller can reset only the touched entries.  (The
    2-hop walk may mark v's own color via the u→v back-edge; both callers
    skip the vertex's current color before consulting the mask, so the
    class assignment is identical to the historical set-based scan.)
    """
    touched = []
    cu = color[adj[v]]
    cu = cu[cu >= 0]
    mask[cu] = True
    touched.append(cu)
    if include_indirect:
        for u in adj[v]:
            cw = color[adj[u]]
            cw = cw[cw >= 0]
            mask[cw] = True
            touched.append(cw)
    return touched


def _forbidden_colors(v: int, adj, color, include_indirect: bool) -> set:
    """Reference (set-returning) view of the forbidden-color scan — kept
    for tests and debugging; the hot loops use :func:`_mark_forbidden`'s
    boolean scratch instead."""
    forbidden = set()
    for u in adj[v]:
        cu = color[u]
        if cu >= 0:
            forbidden.add(int(cu))
        if include_indirect:
            for w in adj[u]:
                cw = color[w]
                if cw >= 0 and w != v:
                    forbidden.add(int(cw))
    return forbidden


def _greedy(adj, order, include_indirect: bool) -> np.ndarray:
    n = len(adj)
    color = np.full(n, -1, dtype=np.int64)
    mask = np.zeros(n + 2, dtype=bool)      # reusable forbidden scratch
    for v in order:
        touched = _mark_forbidden(int(v), adj, color, include_indirect,
                                  mask)
        # first-fit: smallest unmarked color.  With t marked entries (dupes
        # included) some color in [0, t] is free, so the argmax scan stays
        # O(conflict degree) instead of O(n).
        t = sum(a.shape[0] for a in touched)
        color[v] = int(np.argmax(~mask[:t + 1]))
        for a in touched:
            mask[a] = False
    return color


def _balance(adj, color, include_indirect: bool, max_rounds: int = 3):
    """RACE-style balancing: shrink over-full color classes by recoloring
    rows into the feasible under-full class whose members are nearest in row
    index.  Never introduces a new color, never breaks conflict-freeness."""
    n = len(color)
    num_colors = int(color.max()) + 1 if n else 0
    if num_colors <= 1:
        return color
    target = -(-n // num_colors)            # ceil: perfectly balanced size
    # sorted member list per class, maintained incrementally across moves
    # (a full color == d scan per (vertex, class) pair is O(n) per query)
    members: List[List[int]] = [[] for _ in range(num_colors)]
    for v in range(n):                      # ascending v keeps lists sorted
        members[int(color[v])].append(v)
    mask = np.zeros(n + 2, dtype=bool)      # reusable forbidden scratch
    for _ in range(max_rounds):
        sizes = np.bincount(color, minlength=num_colors)
        moved = False
        for v in range(n):                  # ascending row order (locality)
            c = int(color[v])
            if sizes[c] <= target:
                continue
            touched = _mark_forbidden(v, adj, color, include_indirect,
                                      mask)
            best, best_key = -1, None
            for d in range(num_colors):
                if d == c or mask[d] or sizes[d] + 1 > sizes[c] - 1:
                    continue
                # locality: distance from v to the nearest row of class d
                dist = _nearest_distance(members[d], v)
                key = (int(sizes[d]), dist)
                if best_key is None or key < best_key:
                    best, best_key = d, key
            for a in touched:
                mask[a] = False
            if best >= 0:
                sizes[c] -= 1
                sizes[best] += 1
                del members[c][bisect.bisect_left(members[c], v)]
                bisect.insort(members[best], v)
                color[v] = best
                moved = True
        if not moved:
            break
    return color


def _nearest_distance(sorted_members: List[int], v: int) -> int:
    """min |m - v| over a sorted member list; 0 when the class is empty."""
    if not sorted_members:
        return 0
    i = bisect.bisect_left(sorted_members, v)
    best = sorted_members[i] - v if i < len(sorted_members) else None
    if i > 0 and (best is None or v - sorted_members[i - 1] < best):
        best = v - sorted_members[i - 1]
    return int(best)


def _finalize(color: np.ndarray, provider: str = "greedy",
              level_of_row: Optional[np.ndarray] = None,
              group_of_row: Optional[np.ndarray] = None) -> Coloring:
    n = color.shape[0]
    max_color = int(color.max()) + 1 if n else 0
    # stable sort: rows ascend within each color (row-index locality)
    order = np.argsort(color, kind="stable")
    counts = np.bincount(color, minlength=max_color) if n else np.zeros(
        0, np.int64)
    ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return Coloring(color_of_row=color, num_colors=max_color,
                    rows_by_color=order.astype(np.int64), color_ptr=ptr,
                    provider=provider, level_of_row=level_of_row,
                    group_of_row=group_of_row)


# ---------------------------------------------------------------------------
# RACE provider: recursive level-group bipartition (arXiv:1907.06487)
# ---------------------------------------------------------------------------

def _conflict_closure(adj) -> List[np.ndarray]:
    """Distance-2 closure of the direct graph as explicit adjacency lists:
    u ~ w when they are direct neighbors *or* share one (the paper's direct
    + indirect conflicts as a single edge set).  Folding the distance into
    the edges lets the recursion reason purely about distance 1 — induced
    subgraphs preserve every conflict edge between their members, which a
    distance-2 walk over an induced subgraph would not."""
    out: List[np.ndarray] = []
    for v in range(len(adj)):
        nb = [adj[v]] + [adj[int(u)] for u in adj[v]]
        m = np.unique(np.concatenate(nb)) if nb else np.zeros(0, np.int64)
        out.append(m[m != v].astype(np.int64))
    return out


def _bfs_levels(cadj, verts: np.ndarray) -> np.ndarray:
    """BFS levels of the conflict graph induced on ``verts``.

    Seeded per connected component at its lowest-index vertex of minimum
    induced degree (a band end / mesh corner — the locality-preserving
    seed: levels then sweep the rows in index order).  Components number
    their levels independently from 0; no conflict edge crosses
    components, so sharing level ids across them is safe.  Returns the
    level id per position of ``verts``.

    The BFS property carries the whole scheme: a conflict edge inside the
    induced subgraph spans at most one level, so vertices ≥ 2 levels apart
    never conflict.
    """
    local = {int(v): i for i, v in enumerate(verts)}
    nloc = len(verts)
    level = np.full(nloc, -1, dtype=np.int64)
    deg = np.asarray([sum(1 for u in cadj[int(v)] if int(u) in local)
                      for v in verts], dtype=np.int64)
    for s in sorted(range(nloc), key=lambda i: (int(deg[i]), i)):
        if level[s] >= 0:
            continue
        level[s] = 0
        frontier = [s]
        d = 0
        while frontier:
            d += 1
            nxt = []
            for i in frontier:
                for u in cadj[int(verts[i])]:
                    j = local.get(int(u))
                    if j is not None and level[j] < 0:
                        level[j] = d
                        nxt.append(j)
            frontier = nxt
    return level


def _race_partition(cadj, verts: np.ndarray, group_of: np.ndarray,
                    next_group: List[int], depth: int, chunk_target: int,
                    level_out: Optional[np.ndarray] = None):
    """One recursion node of the RACE scheme on the induced subgraph.

    BFS levels become the level groups (conflict distance is already folded
    into ``cadj``); even/odd groups take disjoint sub-palettes, and a group
    larger than ``chunk_target`` is recursively re-split, its sub-parity
    refining the parent color.  Leaf groups get a fresh serial-chunk id in
    ``group_of``.  Returns (colors aligned with ``verts``, palette size).
    """
    nv = len(verts)
    if nv == 0:
        return np.zeros(0, np.int64), 1
    lev = _bfs_levels(cadj, verts)
    if level_out is not None:
        level_out[verts] = lev
    nlev = int(lev.max()) + 1
    color = np.zeros(nv, np.int64)
    if nlev <= 1:
        # indivisible: the induced conflict graph spans one BFS level (a
        # near-clique, or an independent set) — one serial chunk, one color
        group_of[verts] = next_group[0]
        next_group[0] += 1
        return color, 1
    parts = []
    for g in range(nlev):
        idx = np.flatnonzero(lev == g)
        if idx.shape[0] > chunk_target and depth < RACE_MAX_DEPTH:
            sub, npal = _race_partition(cadj, verts[idx], group_of,
                                        next_group, depth + 1, chunk_target)
        else:
            sub, npal = np.zeros(idx.shape[0], np.int64), 1
            group_of[verts[idx]] = next_group[0]
            next_group[0] += 1
        parts.append((g, idx, sub, npal))
    pal = [0, 0]
    for g, _, _, npal in parts:
        pal[g % 2] = max(pal[g % 2], npal)
    for g, idx, sub, _ in parts:
        color[idx] = sub if g % 2 == 0 else pal[0] + sub
    return color, pal[0] + pal[1]


def race_color_graph(adj: list, include_indirect: bool = False,
                     balance: bool = True,
                     p_target: int = RACE_P_TARGET) -> Coloring:
    """RACE-style recursive level-group coloring of a conflict graph.

    Returns the same :class:`Coloring` artifact the greedy provider does,
    with ``provider='race'`` and the level-group metadata filled in; the
    colorful executors and the assembly scatter consume it unchanged.
    """
    n = len(adj)
    adj = [np.asarray(a, dtype=np.int64) for a in adj]
    cadj = _conflict_closure(adj) if include_indirect else adj
    level = np.zeros(n, dtype=np.int64)
    group = np.zeros(n, dtype=np.int64)
    next_group = [0]
    chunk_target = max(1, -(-n // (2 * p_target)))
    color, _ = _race_partition(cadj, np.arange(n), group, next_group, 0,
                               chunk_target, level_out=level)
    if balance and n:
        before = color.copy()
        color = _balance(adj, color, include_indirect)
        moved = np.flatnonzero(color != before)
        if moved.size:
            # a moved row passed the classic forbidden check against its
            # whole destination class, so it forms its own serial chunk
            group[moved] = next_group[0] + np.arange(moved.size)
            next_group[0] += int(moved.size)
    return _finalize(color, provider="race", level_of_row=level,
                     group_of_row=group)


def color_graph(adj: list, include_indirect: bool = False,
                order: str = "degree", balance: bool = True,
                provider: str = "greedy") -> Coloring:
    """Coloring of an arbitrary conflict graph given as adjacency lists.

    This is the machinery behind :func:`color_rows` factored over the
    graph instead of the matrix, so other conflict graphs — notably the
    FEM *element* conflict graph of ``repro.assembly.conflict`` — reuse
    the identical pipeline.

    ``provider``: 'greedy' (sequential first-fit, the default) or 'race'
    (the recursive level-group scheme, :func:`race_color_graph`).

    ``order`` (greedy only): 'degree' (largest-degree-first, the default),
    'natural' (the legacy unordered first-fit).  Degree ordering guards the
    invariant that it never uses more colors than the natural order by
    computing both and keeping the smaller palette (coloring is a one-time
    precomputation; see core/schedule.py).
    """
    if provider not in PROVIDERS:
        raise ValueError(f"unknown coloring provider {provider!r}; "
                         f"expected one of {PROVIDERS}")
    if provider == "race":
        return race_color_graph(adj, include_indirect=include_indirect,
                                balance=balance)
    n = len(adj)
    if order not in ("degree", "natural"):
        raise ValueError(f"unknown coloring order {order!r}")
    natural = np.arange(n)
    color = _greedy(adj, natural, include_indirect)
    if order == "degree" and n:
        deg = np.asarray([len(a) for a in adj], dtype=np.int64)
        by_degree = np.argsort(-deg, kind="stable")
        cd = _greedy(adj, by_degree, include_indirect)
        if cd.max() <= color.max():
            color = cd
    if balance:
        color = _balance(adj, color, include_indirect)
    return _finalize(color)


def color_rows(M: CSRC, include_indirect: bool = True,
               order: str = "degree", balance: bool = True,
               adj: Optional[list] = None,
               provider: str = "greedy") -> Coloring:
    """Row coloring of the paper's conflict graph (§3.2) via
    :func:`color_graph`.

    With ``include_indirect`` the conflict graph is G'^2 restricted to direct
    edges' 2-hop closure (paper: u,v indirectly conflict when their direct
    neighborhoods intersect) — i.e. distance-2 coloring of the direct graph.
    """
    adj = direct_adjacency(M) if adj is None else adj
    return color_graph(adj, include_indirect=include_indirect,
                       order=order, balance=balance, provider=provider)


def verify_coloring(M: CSRC, col: Coloring) -> bool:
    """Property check of the chunk-aware conflict invariant: inside one
    color, no two rows of *different* serial chunks may share a write
    target (each row writes y[row] and y[ja[slots of row]]).

    Greedy colorings carry no chunk structure (``group_of_row is None``) —
    every row is its own chunk and this degenerates to the classic check
    that all targets inside a color are pairwise distinct.  RACE colorings
    may share targets inside one level-group chunk: the modeled machine
    runs a chunk serially, and the jnp executors scatter with order-free
    ``.at[].add``."""
    ia = np.asarray(M.ia)
    ja = np.asarray(M.ja)
    grp = col.group_of_row
    for c in range(col.num_colors):
        owner: dict = {}
        for r in col.rows(c).tolist():
            g = int(grp[r]) if grp is not None else r
            targets = [r] + ja[ia[r]:ia[r + 1]].tolist()
            for t in targets:
                og = owner.get(t)
                if og is not None and og != g:
                    return False
                owner[t] = g
    return True


def balance_stats(col: Coloring) -> dict:
    """Rows-per-color dispersion: max/mean (1.0 = perfectly balanced) and
    std — the quantity the RACE-style pass minimizes."""
    sizes = col.class_sizes().astype(np.float64)
    if sizes.size == 0:
        return {"imbalance": 1.0, "std": 0.0}
    return {"imbalance": float(sizes.max() / max(1.0, sizes.mean())),
            "std": float(sizes.std())}


def reuse_stats(col: Coloring) -> dict:
    """Reuse-distance proxy (the paper's §3.2 locality criticism): the
    row-index strides between consecutive rows of one color in execution
    order.  Big strides inside a color evict x/y cache lines between
    uses; RACE classes are unions of contiguous level ranges, so their
    mean stride stays near 1 while greedy classes stride by ~num_colors."""
    gaps = []
    for c in range(col.num_colors):
        r = col.rows(c)
        if r.shape[0] > 1:
            gaps.append(np.abs(np.diff(r)).astype(np.float64))
    if not gaps:
        return {"mean_stride": 0.0, "p90_stride": 0.0}
    g = np.concatenate(gaps)
    return {"mean_stride": float(g.mean()),
            "p90_stride": float(np.percentile(g, 90))}


def group_stats(col: Coloring) -> dict:
    """Serial-chunk structure of a coloring: chunk count and the largest
    chunk (the modeled machine's per-color span).  A greedy coloring is
    all singleton chunks."""
    if col.group_of_row is None:
        n = int(col.color_of_row.shape[0])
        return {"chunks": n, "max_chunk": 1 if n else 0}
    _, counts = np.unique(col.group_of_row, return_counts=True)
    return {"chunks": int(counts.shape[0]),
            "max_chunk": int(counts.max()) if counts.size else 0}


def conflict_stats(M: CSRC) -> dict:
    """Direct/indirect conflict counts (paper Fig. 3c reports 12 direct and
    7 indirect for its 9×9 example)."""
    adj = direct_adjacency(M)
    n = M.n
    direct = sum(len(a) for a in adj) // 2
    indirect = 0
    for v in range(n):
        direct_v = set(adj[v].tolist())
        two_hop = set()
        for u in adj[v]:
            for w in adj[u]:
                if w > v and w not in direct_v:
                    two_hop.add(int(w))
        indirect += len(two_hop)
    return {"direct": int(direct), "indirect": int(indirect)}
