"""The colorful partitioning method (paper §3.2).

Rows are vertices of the conflict graph G[A]; two rows conflict when
processing them concurrently could write the same y position:

  * direct conflict:  row j > i has a stored lower entry in column i
    (thread owning j scatters into y[i] while thread owning i writes y[i]);
  * indirect conflict: rows u, v share a neighbor in the direct graph
    (both scatter into the same third row's y slot).

A greedy sequential coloring of G[A] yields conflict-free color classes; the
product is computed color-by-color (serial across colors, parallel inside).

The greedy is ordered **largest-degree-first** (Welsh–Powell): high-degree
vertices are colored while many colors are still unused, which empirically
never needs more colors than the unordered first-fit on our matrix classes —
``color_rows`` additionally guards the invariant by falling back to the
natural-order result if degree ordering ever came out worse.  On top of the
greedy sits a RACE-style balancing pass (Alappat et al., arXiv:1907.06487):
rows are moved from over-full color classes into under-full ones (staying
conflict-free, never adding a color), preferring the class whose members are
nearest in row index — this addresses the paper's §3.2 locality criticism
(variable-size strides inside a color) instead of merely reproducing it.

On TPU this maps to: rows of one color form a batch whose scatter indices are
pairwise disjoint, so the scatter is a permutation-write (safe segment_sum /
at[].add with unique indices — no read-modify-write ordering needed).
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import List, Optional

import numpy as np

from .csrc import CSRC, row_of_slot


@dataclasses.dataclass(frozen=True)
class Coloring:
    color_of_row: np.ndarray     # (n,) color id per row
    num_colors: int
    # rows grouped by color, concatenated; color c owns
    # rows_by_color[color_ptr[c]:color_ptr[c+1]]
    rows_by_color: np.ndarray
    color_ptr: np.ndarray

    def rows(self, c: int) -> np.ndarray:
        return self.rows_by_color[self.color_ptr[c]:self.color_ptr[c + 1]]

    def class_sizes(self) -> np.ndarray:
        return np.diff(self.color_ptr)


def direct_adjacency(M: CSRC) -> List[np.ndarray]:
    """Adjacency lists of the *direct* conflict graph: i ~ ja[p] for every
    stored lower slot p of row i (symmetric)."""
    n = M.n
    ros = row_of_slot(M)
    ja = np.asarray(M.ja)
    adj: List[List[int]] = [[] for _ in range(n)]
    for i, j in zip(ros.tolist(), ja.tolist()):
        adj[i].append(j)
        adj[j].append(i)
    return [np.unique(np.asarray(a, dtype=np.int64)) for a in adj]


def _forbidden_colors(v: int, adj, color, include_indirect: bool) -> set:
    """Colors already used within conflict distance of v (distance 2 when
    indirect conflicts are included)."""
    forbidden = set()
    for u in adj[v]:
        cu = color[u]
        if cu >= 0:
            forbidden.add(int(cu))
        if include_indirect:
            for w in adj[u]:
                cw = color[w]
                if cw >= 0 and w != v:
                    forbidden.add(int(cw))
    return forbidden


def _greedy(adj, order, include_indirect: bool) -> np.ndarray:
    n = len(adj)
    color = np.full(n, -1, dtype=np.int64)
    for v in order:
        forbidden = _forbidden_colors(int(v), adj, color, include_indirect)
        c = 0
        while c in forbidden:
            c += 1
        color[v] = c
    return color


def _balance(adj, color, include_indirect: bool, max_rounds: int = 3):
    """RACE-style balancing: shrink over-full color classes by recoloring
    rows into the feasible under-full class whose members are nearest in row
    index.  Never introduces a new color, never breaks conflict-freeness."""
    n = len(color)
    num_colors = int(color.max()) + 1 if n else 0
    if num_colors <= 1:
        return color
    target = -(-n // num_colors)            # ceil: perfectly balanced size
    # sorted member list per class, maintained incrementally across moves
    # (a full color == d scan per (vertex, class) pair is O(n) per query)
    members: List[List[int]] = [[] for _ in range(num_colors)]
    for v in range(n):                      # ascending v keeps lists sorted
        members[int(color[v])].append(v)
    for _ in range(max_rounds):
        sizes = np.bincount(color, minlength=num_colors)
        moved = False
        for v in range(n):                  # ascending row order (locality)
            c = int(color[v])
            if sizes[c] <= target:
                continue
            forbidden = _forbidden_colors(v, adj, color, include_indirect)
            best, best_key = -1, None
            for d in range(num_colors):
                if d == c or d in forbidden or sizes[d] + 1 > sizes[c] - 1:
                    continue
                # locality: distance from v to the nearest row of class d
                dist = _nearest_distance(members[d], v)
                key = (int(sizes[d]), dist)
                if best_key is None or key < best_key:
                    best, best_key = d, key
            if best >= 0:
                sizes[c] -= 1
                sizes[best] += 1
                del members[c][bisect.bisect_left(members[c], v)]
                bisect.insort(members[best], v)
                color[v] = best
                moved = True
        if not moved:
            break
    return color


def _nearest_distance(sorted_members: List[int], v: int) -> int:
    """min |m - v| over a sorted member list; 0 when the class is empty."""
    if not sorted_members:
        return 0
    i = bisect.bisect_left(sorted_members, v)
    best = sorted_members[i] - v if i < len(sorted_members) else None
    if i > 0 and (best is None or v - sorted_members[i - 1] < best):
        best = v - sorted_members[i - 1]
    return int(best)


def _finalize(color: np.ndarray) -> Coloring:
    n = color.shape[0]
    max_color = int(color.max()) + 1 if n else 0
    # stable sort: rows ascend within each color (row-index locality)
    order = np.argsort(color, kind="stable")
    counts = np.bincount(color, minlength=max_color) if n else np.zeros(
        0, np.int64)
    ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return Coloring(color_of_row=color, num_colors=max_color,
                    rows_by_color=order.astype(np.int64), color_ptr=ptr)


def color_graph(adj: list, include_indirect: bool = False,
                order: str = "degree", balance: bool = True) -> Coloring:
    """Sequential greedy coloring [Coleman–Moré] of an arbitrary conflict
    graph given as adjacency lists, with vertex ordering and balancing.

    This is the machinery behind :func:`color_rows` factored over the
    graph instead of the matrix, so other conflict graphs — notably the
    FEM *element* conflict graph of ``repro.assembly.conflict`` — reuse
    the identical ordering + RACE-style balancing pipeline.

    ``order``: 'degree' (largest-degree-first, the default), 'natural'
    (the legacy unordered first-fit).  Degree ordering guards the invariant
    that it never uses more colors than the natural order by computing both
    and keeping the smaller palette (coloring is a one-time precomputation;
    see core/schedule.py).
    """
    n = len(adj)
    if order not in ("degree", "natural"):
        raise ValueError(f"unknown coloring order {order!r}")
    natural = np.arange(n)
    color = _greedy(adj, natural, include_indirect)
    if order == "degree" and n:
        deg = np.asarray([len(a) for a in adj], dtype=np.int64)
        by_degree = np.argsort(-deg, kind="stable")
        cd = _greedy(adj, by_degree, include_indirect)
        if cd.max() <= color.max():
            color = cd
    if balance:
        color = _balance(adj, color, include_indirect)
    return _finalize(color)


def color_rows(M: CSRC, include_indirect: bool = True,
               order: str = "degree", balance: bool = True,
               adj: Optional[list] = None) -> Coloring:
    """Row coloring of the paper's conflict graph (§3.2) via
    :func:`color_graph`.

    With ``include_indirect`` the conflict graph is G'^2 restricted to direct
    edges' 2-hop closure (paper: u,v indirectly conflict when their direct
    neighborhoods intersect) — i.e. distance-2 coloring of the direct graph.
    """
    adj = direct_adjacency(M) if adj is None else adj
    return color_graph(adj, include_indirect=include_indirect,
                       order=order, balance=balance)


def verify_coloring(M: CSRC, col: Coloring) -> bool:
    """Property check: inside one color no two rows may share a write target
    (each row writes y[row] and y[ja[slots of row]])."""
    ia = np.asarray(M.ia)
    ja = np.asarray(M.ja)
    for c in range(col.num_colors):
        seen = set()
        for r in col.rows(c).tolist():
            targets = [r] + ja[ia[r]:ia[r + 1]].tolist()
            for t in targets:
                if t in seen:
                    return False
                seen.add(t)
    return True


def balance_stats(col: Coloring) -> dict:
    """Rows-per-color dispersion: max/mean (1.0 = perfectly balanced) and
    std — the quantity the RACE-style pass minimizes."""
    sizes = col.class_sizes().astype(np.float64)
    if sizes.size == 0:
        return {"imbalance": 1.0, "std": 0.0}
    return {"imbalance": float(sizes.max() / max(1.0, sizes.mean())),
            "std": float(sizes.std())}


def conflict_stats(M: CSRC) -> dict:
    """Direct/indirect conflict counts (paper Fig. 3c reports 12 direct and
    7 indirect for its 9×9 example)."""
    adj = direct_adjacency(M)
    n = M.n
    direct = sum(len(a) for a in adj) // 2
    indirect = 0
    for v in range(n):
        direct_v = set(adj[v].tolist())
        two_hop = set()
        for u in adj[v]:
            for w in adj[u]:
                if w > v and w not in direct_v:
                    two_hop.add(int(w))
        indirect += len(two_hop)
    return {"direct": int(direct), "indirect": int(indirect)}
