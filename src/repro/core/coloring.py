"""The colorful partitioning method (paper §3.2).

Rows are vertices of the conflict graph G[A]; two rows conflict when
processing them concurrently could write the same y position:

  * direct conflict:  row j > i has a stored lower entry in column i
    (thread owning j scatters into y[i] while thread owning i writes y[i]);
  * indirect conflict: rows u, v share a neighbor in the direct graph
    (both scatter into the same third row's y slot).

A greedy sequential coloring of G[A] yields conflict-free color classes; the
product is computed color-by-color (serial across colors, parallel inside).

On TPU this maps to: rows of one color form a batch whose scatter indices are
pairwise disjoint, so the scatter is a permutation-write (safe segment_sum /
at[].add with unique indices — no read-modify-write ordering needed).  The
paper's locality criticism (variable-size strides inside a color) applies
directly to VMEM tiling and is reproduced in our benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .csrc import CSRC, row_of_slot


@dataclasses.dataclass(frozen=True)
class Coloring:
    color_of_row: np.ndarray     # (n,) color id per row
    num_colors: int
    # rows grouped by color, concatenated; color c owns
    # rows_by_color[color_ptr[c]:color_ptr[c+1]]
    rows_by_color: np.ndarray
    color_ptr: np.ndarray

    def rows(self, c: int) -> np.ndarray:
        return self.rows_by_color[self.color_ptr[c]:self.color_ptr[c + 1]]


def direct_adjacency(M: CSRC) -> List[np.ndarray]:
    """Adjacency lists of the *direct* conflict graph: i ~ ja[p] for every
    stored lower slot p of row i (symmetric)."""
    n = M.n
    ros = row_of_slot(M)
    ja = np.asarray(M.ja)
    adj: List[List[int]] = [[] for _ in range(n)]
    for i, j in zip(ros.tolist(), ja.tolist()):
        adj[i].append(j)
        adj[j].append(i)
    return [np.unique(np.asarray(a, dtype=np.int64)) for a in adj]


def color_rows(M: CSRC, include_indirect: bool = True) -> Coloring:
    """Greedy (first-fit) sequential coloring [Coleman–Moré].

    With ``include_indirect`` the conflict graph is G'^2 restricted to direct
    edges' 2-hop closure (paper: u,v indirectly conflict when their direct
    neighborhoods intersect) — i.e. distance-2 coloring of the direct graph.
    """
    n = M.n
    adj = direct_adjacency(M)
    color = np.full(n, -1, dtype=np.int64)
    max_color = 0
    scratch = np.zeros(1, dtype=np.int64)
    for v in range(n):
        # collect colors of direct (and optionally 2-hop) neighbors
        forbidden = set()
        for u in adj[v]:
            cu = color[u]
            if cu >= 0:
                forbidden.add(int(cu))
            if include_indirect:
                for w in adj[u]:
                    cw = color[w]
                    if cw >= 0 and w != v:
                        forbidden.add(int(cw))
        c = 0
        while c in forbidden:
            c += 1
        color[v] = c
        max_color = max(max_color, c + 1)
    del scratch
    order = np.argsort(color, kind="stable")
    counts = np.bincount(color, minlength=max_color)
    ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return Coloring(color_of_row=color, num_colors=max_color,
                    rows_by_color=order.astype(np.int64), color_ptr=ptr)


def verify_coloring(M: CSRC, col: Coloring) -> bool:
    """Property check: inside one color no two rows may share a write target
    (each row writes y[row] and y[ja[slots of row]])."""
    n = M.n
    ia = np.asarray(M.ia)
    ja = np.asarray(M.ja)
    for c in range(col.num_colors):
        seen = set()
        for r in col.rows(c).tolist():
            targets = [r] + ja[ia[r]:ia[r + 1]].tolist()
            for t in targets:
                if t in seen:
                    return False
                seen.add(t)
    return True


def conflict_stats(M: CSRC) -> dict:
    """Direct/indirect conflict counts (paper Fig. 3c reports 12 direct and
    7 indirect for its 9×9 example)."""
    adj = direct_adjacency(M)
    n = M.n
    direct = sum(len(a) for a in adj) // 2
    indirect = 0
    for v in range(n):
        two_hop = set()
        for u in adj[v]:
            for w in adj[u]:
                if w > v and w not in adj[v].tolist():
                    two_hop.add(int(w))
        indirect += len(two_hop)
    return {"direct": int(direct), "indirect": int(indirect)}
