"""Autotuner: measure feasible ExecutionPlans per matrix, cache the argmin.

This is the paper's per-matrix strategy-selection problem (§4: which of
local-buffers/accumulation-variants vs. colorful wins depends on the
matrix) solved the way RACE (arXiv:1907.06487) and Bergmans et al.
(arXiv:2502.19284) do it: enumerate feasible candidates from matrix
statistics, *measure* them, and remember the winner.

Pieces:

  MatrixStats / stats_of     the statistics that gate candidates
                             (bandwidth, nnz/row deviation, working set,
                             numeric symmetry)
  fingerprint                stable string key of a matrix *class*
                             (n, m, k, bandwidth, nnz-histogram digest)
  enumerate_plans            feasible candidates from stats, one
                             enumerator per registered KernelPath
                             (core/paths.py) — a new kernel path joins
                             every tuning run by registering; the legacy
                             @register_candidate_source hook also works
  heuristic_plan             measurement-free default (mirrors the old
                             static auto path, plus distributed strategy
                             selection from the collective-bytes model)
  PlanCache                  JSON plan cache keyed by fingerprint; a hit
                             skips re-measurement entirely
  tune / plan_for            the tuning entry points used by solvers,
                             the serve engine, and benchmarks

The timing harness is benchmarks/util.time_fn when importable (running
from the repo root); a same-contract fallback is inlined so the tuner
works from any installed location.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from . import paths as paths_mod
from .csrc import CSRC, bandwidth as csrc_bandwidth, nnz_per_row
from .plan import ExecutionPlan, feasible, kernel_window

try:                                          # repo-root layout
    from benchmarks.util import time_fn as _time_fn
except ImportError:                           # installed / src-only path
    def _time_fn(fn, *args, warmup: int = 3, repeats: int = 10) -> float:
        """Median wall-clock seconds per call (benchmarks/util.py contract)."""
        import jax
        for _ in range(warmup):
            out = fn(*args)
        jax.block_until_ready(out)
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))


# ---------------------------------------------------------------------------
# Matrix statistics and fingerprinting
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MatrixStats:
    n: int
    m: int
    k: int
    nnz: int
    bandwidth: int
    working_set_bytes: int
    nnz_row_mean: float
    nnz_row_dev: float            # std of nnz per row (load-balance driver)
    numerically_symmetric: bool


def stats_of(M: CSRC) -> MatrixStats:
    w = nnz_per_row(M)
    return MatrixStats(
        n=M.n, m=M.m, k=M.k, nnz=M.nnz,
        bandwidth=csrc_bandwidth(M),
        working_set_bytes=M.working_set_bytes(),
        nnz_row_mean=float(w.mean()),
        nnz_row_dev=float(w.std()),
        numerically_symmetric=bool(M.numerically_symmetric),
    )


def fingerprint(M: CSRC) -> str:
    """Stable key of the matrix *class*: (n, m, k, bandwidth) in the clear
    plus a digest of the nnz-per-row histogram and symmetry flag.  Two
    matrices of the same class (same generator, same size) share a key, so
    solvers and the serve engine never re-tune a known class."""
    w = nnz_per_row(M)
    hist = np.bincount(np.minimum(w, 255).astype(np.int64), minlength=256)
    h = hashlib.sha1()
    h.update(hist.astype(np.int64).tobytes())
    h.update(bytes([int(M.numerically_symmetric)]))
    band = csrc_bandwidth(M)
    return f"n{M.n}m{M.m}k{M.k}b{band}-{h.hexdigest()[:12]}"


# ---------------------------------------------------------------------------
# Candidate enumeration
# ---------------------------------------------------------------------------

_CANDIDATE_SOURCES: List[Callable[[MatrixStats], List[ExecutionPlan]]] = []


def register_candidate_source(fn):
    """Extension hook: future kernels register a function
    ``stats -> [ExecutionPlan, ...]``; its (feasible) plans join every
    enumeration and therefore every tuning run."""
    _CANDIDATE_SOURCES.append(fn)
    return fn


def _distributed_fields(stats: MatrixStats, p_hint: int = 8):
    """Analytic choice of the sharding degrees of freedom (not measured on
    a single chip): nnz-guided partition unless rows are uniform; halo when
    the band fits inside a shard (the collective-bytes model's winner),
    reduce_scatter otherwise."""
    partition = "nnz" if stats.nnz_row_dev > 0 else "count"
    rows_per_shard = max(1, -(-stats.n // p_hint))
    acc = ("halo" if stats.bandwidth <= max(8, rows_per_shard)
           else "reduce_scatter")
    return partition, acc


def enumerate_plans(stats: MatrixStats,
                    tms=(32, 128),
                    k_steps_sublanes=(8,),
                    w_cap: int = 4096,
                    colorful_max_n: int = 2048,
                    p_hint: int = 8,
                    nrhs_options=(1,),
                    index_dtypes=("int32", "int16")) -> List[ExecutionPlan]:
    """All feasible candidate plans for a matrix with these statistics.

    Candidates come from the KernelPath registry (core/paths.py): every
    registered path contributes its own enumerator over the sweep space —
    segment is always a candidate; windowed kernel plans ('kernel', and
    'flat' when the nnz-per-row skew makes per-tile-exact packing worth
    measuring) are emitted per (tm, k_step) whose window fits under
    ``w_cap``; colorful for square matrices small enough that the
    O(n·deg²) greedy coloring is worth attempting.  Legacy
    ``@register_candidate_source`` hooks still join the pool.

    Every candidate — registry or hook — is filtered through the path's
    feasibility predicate, so a plan the packer cannot tile (window over
    ``w_cap``, square-only path on a rectangular matrix) is rejected here
    instead of erroring mid-tune.

    ``nrhs_options`` replicates every candidate per RHS block width, so a
    serving deployment can tune the batched SpMM operating point directly
    (the winning path may differ between nrhs=1 and nrhs=8: arithmetic
    intensity rises with the block).

    ``index_dtypes`` controls the windowed paths' index-stream proposals:
    with the default both int32 and (where the window fits in 16 bits)
    int16 variants are measured, so the tuner trades index bandwidth per
    matrix — SpMV is bandwidth-bound, and int16 halves 8 of ~16 streamed
    bytes per slot.
    """
    partition, acc = _distributed_fields(stats, p_hint)
    space = paths_mod.CandidateSpace(
        tms=tuple(tms), k_steps_sublanes=tuple(k_steps_sublanes),
        w_cap=w_cap, colorful_max_n=colorful_max_n,
        partition=partition, accumulation=acc,
        index_dtypes=tuple(index_dtypes))
    raw: List[ExecutionPlan] = []
    for entry in paths_mod.registered_paths():
        raw.extend(entry.candidates(stats, space))
    for source in _CANDIDATE_SOURCES:
        raw.extend(source(stats))
    plans = [p for p in raw
             if feasible(p, n=stats.n, m=stats.m, bandwidth=stats.bandwidth)]
    if tuple(nrhs_options) != (1,):
        plans = [dataclasses.replace(p, nrhs=r)
                 for p in plans for r in nrhs_options]
    # dedup on the full plan (frozen dataclass), preserving order — key()
    # elides execution-irrelevant fields and must not drop distinct plans
    seen, out = set(), []
    for p in plans:
        if p not in seen:
            seen.add(p)
            out.append(p)
    return out


def heuristic_plan(stats: MatrixStats, tm: int = 128,
                   w_cap: int = 4096) -> ExecutionPlan:
    """Measurement-free plan: the old SpmvOperator 'auto' logic (kernel if
    the window fits, else segment) with the analytic distributed fields."""
    partition, acc = _distributed_fields(stats)
    square = stats.n == stats.m
    if square and kernel_window(tm, stats.bandwidth) <= w_cap:
        return ExecutionPlan(path="kernel", tm=tm, w_cap=w_cap,
                             partition=partition, accumulation=acc)
    return ExecutionPlan(path="segment", w_cap=w_cap,
                         partition=partition, accumulation=acc)


# ---------------------------------------------------------------------------
# Plan cache
# ---------------------------------------------------------------------------

class PlanCache:
    """JSON plan cache keyed by matrix fingerprint.

    File format (version 1):

        {"version": 1,
         "entries": {"<fingerprint>": {"plan": {...ExecutionPlan fields...},
                                       "best_us": 12.3,
                                       "timings_us": {"<plan key>": 12.3}}}}

    A ``get`` hit returns the stored plan without any re-measurement; the
    hit/miss counters let tests (and ops dashboards) assert that.  Entries
    carry a ``measured`` flag: heuristic (unmeasured) plans cached by
    ``plan_for(autotune=False)`` are visible to heuristic lookups but do
    NOT satisfy ``tune()``, which would otherwise report a never-measured
    plan as the argmin.

    Next to each plan the cache stores the **schedule artifact**
    (core/schedule.py): the block-ELL pack, row partition/halo ranges, and
    coloring the plan executes with.  Schedules live in memory plus — when
    the cache has a file path — as npz files under ``<stem>_schedules/``
    beside the JSON, keyed by (fingerprint, value digest, plan, partition
    width).  ``get_schedule`` hits mean zero pack/partition/coloring work;
    a schedule whose ``SCHEDULE_VERSION`` no longer matches is ignored and
    rebuilt (format-change invalidation).
    """

    VERSION = 1

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.entries: Dict[str, Dict] = {}
        self.hits = 0
        self.misses = 0
        self.schedules: Dict[str, object] = {}
        self.schedule_hits = 0
        self.schedule_misses = 0
        self.assembly_schedules: Dict[str, object] = {}
        self.assembly_hits = 0
        self.assembly_misses = 0
        if path is not None and os.path.exists(path):
            self._read(path)

    def _read(self, path: str):
        with open(path) as f:
            data = json.load(f)
        if data.get("version") != self.VERSION:
            raise ValueError(
                f"plan cache {path}: version {data.get('version')!r} "
                f"!= {self.VERSION}")
        self.entries = dict(data.get("entries", {}))

    def save(self, path: Optional[str] = None):
        path = path or self.path
        if path is None:
            raise ValueError("PlanCache.save: no path given or stored")
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"version": self.VERSION, "entries": self.entries},
                      f, indent=1, sort_keys=True)
        os.replace(tmp, path)
        self.path = path

    def get(self, fp: str,
            require_measured: bool = False) -> Optional[ExecutionPlan]:
        e = self.entries.get(fp)
        if e is None or (require_measured and not e.get("measured")):
            self.misses += 1
            return None
        self.hits += 1
        return ExecutionPlan.from_dict(e["plan"])

    def put(self, fp: str, plan: ExecutionPlan,
            timings_s: Optional[Dict[str, float]] = None):
        entry: Dict = {"plan": plan.to_dict(),
                       "measured": bool(timings_s)}
        if timings_s:
            entry["timings_us"] = {k: round(v * 1e6, 3)
                                   for k, v in timings_s.items()}
            entry["best_us"] = round(min(timings_s.values()) * 1e6, 3)
        self.entries[fp] = entry

    # ---- schedule artifacts (stored next to the plans) ----

    def _schedule_dir(self) -> Optional[str]:
        if self.path is None:
            return None
        stem, _ = os.path.splitext(os.path.abspath(self.path))
        return stem + "_schedules"

    def get_schedule(self, fp: str, digest: str, plan: ExecutionPlan,
                     p: int = 8):
        """The cached schedule for (matrix, plan), or None.  Memory first,
        then the npz file beside the cache; version/plan mismatches count
        as misses (the caller rebuilds)."""
        from .schedule import (SpmvSchedule, plan_artifact_fields,
                               schedule_key)
        key = schedule_key(fp, digest, plan, p)
        sched = self.schedules.get(key)
        if sched is None:
            d = self._schedule_dir()
            f = None if d is None else os.path.join(d, key + ".npz")
            if f is not None and os.path.exists(f):
                try:
                    sched = SpmvSchedule.load_npz(f)
                except Exception:         # stale version, truncated or
                    sched = None          # foreign file: rebuild, not crash
                if sched is not None and (
                        plan_artifact_fields(sched.plan)
                        != plan_artifact_fields(plan)
                        or sched.value_digest != digest):
                    sched = None
                if sched is not None:
                    self.schedules[key] = sched
        if sched is None:
            self.schedule_misses += 1
            return None
        self.schedule_hits += 1
        return sched

    def put_schedule(self, sched, persist: bool = True):
        """Store a schedule (memory, and — for path-backed caches — as an
        npz beside the plans).  ``persist=False`` keeps it memory-only:
        the value-refresh path uses it so per-step time stepping does not
        re-compress a full npz (values + unchanged index streams) every
        step; the structural generation already on disk keeps serving
        fresh processes, which value-refresh from it on load."""
        key = sched.key()
        self.schedules[key] = sched
        d = self._schedule_dir()
        if persist and d is not None:
            sched.save_npz(os.path.join(d, key + ".npz"))

    def drop_schedule(self, sched, remove_file: bool = True):
        """Evict a schedule from memory (and, by default, its npz).  Used
        by the value-refresh path to replace a superseded value
        generation: time stepping keeps exactly one schedule per
        (structure, plan, p) in memory — the newest — so a 10k-step run
        does not accumulate 10k dead value streams."""
        key = sched.key()
        self.schedules.pop(key, None)
        d = self._schedule_dir()
        if remove_file and d is not None:
            try:
                os.remove(os.path.join(d, key + ".npz"))
            except OSError:
                pass

    def find_schedule_by_structure(self, fp: str, sdigest: str, plan,
                                   p: int = 8):
        """A cached schedule for the same matrix *structure* (fingerprint +
        structure digest + plan artifact geometry + partition width) whose
        values may differ — the FEM time-stepping fast path: the caller
        refreshes value streams (``schedule.refresh_schedule``) instead of
        re-packing/re-coloring.  In-memory schedules only: the scenario is
        repeated refreshes within one serving/solver process."""
        from .schedule import plan_artifact_fields
        fields = plan_artifact_fields(plan)
        for sched in self.schedules.values():
            if (sched.fingerprint == fp and sched.p == p
                    and sched.structure_digest == sdigest
                    and plan_artifact_fields(sched.plan) == fields):
                return sched
        return None

    # ---- assembly schedules (repro.assembly.scatter), stored beside the
    # SpMV schedules and keyed by connectivity digest ----

    def get_assembly_schedule(self, digest: str, num_buffers: int = 8):
        """The cached AssemblySchedule for this connectivity digest, or
        None.  Memory first, then the npz beside the cache — a hit means
        zero structural assembly work (slot maps, coloring, buffers)."""
        from repro.assembly.scatter import AssemblySchedule
        key = f"asm-{digest}.b{num_buffers}"
        sched = self.assembly_schedules.get(key)
        if sched is None:
            d = self._schedule_dir()
            f = None if d is None else os.path.join(d, key + ".npz")
            if f is not None and os.path.exists(f):
                try:
                    sched = AssemblySchedule.load_npz(f)
                except Exception:      # stale version / truncated: rebuild
                    sched = None
                if sched is not None and sched.structure_digest != digest:
                    sched = None
                if sched is not None:
                    self.assembly_schedules[key] = sched
        if sched is None:
            self.assembly_misses += 1
            return None
        self.assembly_hits += 1
        return sched

    def put_assembly_schedule(self, sched):
        key = sched.key()
        self.assembly_schedules[key] = sched
        d = self._schedule_dir()
        if d is not None:
            sched.save_npz(os.path.join(d, key + ".npz"))

    def __len__(self) -> int:
        return len(self.entries)


# ---------------------------------------------------------------------------
# Tuning
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TuneResult:
    plan: ExecutionPlan
    fingerprint: str
    timings_s: Dict[str, float]   # plan.key() -> seconds; empty on cache hit
    cached: bool


def tune(M: CSRC,
         cache: Optional[PlanCache] = None,
         x: Optional[np.ndarray] = None,
         candidates: Optional[List[ExecutionPlan]] = None,
         measure: Optional[Callable] = None,
         warmup: int = 1,
         repeats: int = 3,
         interpret: bool = True,
         save: bool = True) -> TuneResult:
    """Measure every feasible candidate and return the argmin plan.

    ``cache`` short-circuits: a fingerprint hit returns the stored plan
    with zero measurements.  ``measure(op, x) -> seconds`` is injectable
    for tests; the default is the benchmarks/util timing harness with a
    small budget (the tuner runs at operator-construction time).
    """
    from repro.kernels.ops import SpmvOperator   # local: avoid import cycle

    fp = fingerprint(M)
    if cache is not None:
        # a heuristic (unmeasured) entry must not satisfy a tune request
        hit = cache.get(fp, require_measured=True)
        if hit is not None:
            return TuneResult(plan=hit, fingerprint=fp, timings_s={},
                              cached=True)

    stats = stats_of(M)
    cands = candidates if candidates is not None else enumerate_plans(stats)
    if measure is None:
        def measure(op, xv):
            return _time_fn(op, xv, warmup=warmup, repeats=repeats)
    if x is None:
        x = np.random.default_rng(0).standard_normal(M.m).astype(np.float32)
    import jax.numpy as jnp
    xj = jnp.asarray(x)
    # multi-RHS candidates are measured at their tuned block width
    _x_by_width = {1: xj} if xj.ndim == 1 else {xj.shape[1]: xj,
                                               1: xj[:, 0]}

    def _x_for(nrhs: int):
        if nrhs not in _x_by_width:
            _x_by_width[nrhs] = jnp.asarray(
                np.random.default_rng(nrhs).standard_normal(
                    (M.m, nrhs)).astype(np.float32))
        return _x_by_width[nrhs]

    timings: Dict[str, float] = {}
    best_plan, best_t, best_op = None, float("inf"), None
    for p in cands:
        if not feasible(p, n=M.n, m=M.m, bandwidth=stats.bandwidth):
            continue
        try:
            op = SpmvOperator.from_plan(M, p, interpret=interpret)
        except ValueError:
            continue              # pack-time infeasibility (bandwidth gate)
        t = float(measure(op, _x_for(p.nrhs)))
        timings[p.key()] = t
        # argmin on per-RHS-column time: an nrhs=8 candidate does 8x the
        # work of a single product, so raw runtimes are not comparable
        # across block widths
        t_norm = t / p.nrhs
        if t_norm < best_t:
            best_plan, best_t, best_op = p, t_norm, op
    if best_plan is None:
        raise ValueError("no feasible execution plan for this matrix")

    if cache is not None:
        cache.put(fp, best_plan, timings)
        # store the winner's schedule next to the plan: serving processes
        # constructing this (matrix, plan) never re-pack or re-color
        if best_op is not None and getattr(best_op, "schedule", None) is not None:
            cache.put_schedule(best_op.schedule)
        if save and cache.path is not None:
            cache.save()
    return TuneResult(plan=best_plan, fingerprint=fp, timings_s=timings,
                      cached=False)


def plan_for(M: CSRC,
             cache: Optional[PlanCache] = None,
             autotune: bool = False,
             **tune_kw) -> ExecutionPlan:
    """The plan to run this matrix with.

    Cache hit wins; otherwise ``autotune=True`` measures (and fills the
    cache), ``autotune=False`` falls back to the measurement-free
    heuristic (still cached, so the decision is stable across calls).
    """
    if autotune:
        # tune() performs the cache probe itself — probing here too would
        # double-count misses and fingerprint twice
        return tune(M, cache=cache, **tune_kw).plan
    fp = fingerprint(M)
    if cache is not None:
        hit = cache.get(fp)
        if hit is not None:
            return hit
    plan = heuristic_plan(stats_of(M))
    if cache is not None:
        cache.put(fp, plan)
        if cache.path is not None:
            cache.save()
    return plan
