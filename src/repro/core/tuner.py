"""Autotuner: measure feasible ExecutionPlans per matrix, cache the argmin.

This is the paper's per-matrix strategy-selection problem (§4: which of
local-buffers/accumulation-variants vs. colorful wins depends on the
matrix) solved the way RACE (arXiv:1907.06487) and Bergmans et al.
(arXiv:2502.19284) do it: enumerate feasible candidates from matrix
statistics, *measure* them, and remember the winner.

Pieces:

  MatrixStats / stats_of     the statistics that gate candidates
                             (bandwidth, nnz/row deviation, working set,
                             numeric symmetry)
  fingerprint                stable string key of a matrix *class*
                             (n, m, k, bandwidth, nnz-histogram digest)
  enumerate_plans            feasible candidates from stats, one
                             enumerator per registered KernelPath
                             (core/paths.py) — a new kernel path joins
                             every tuning run by registering; the legacy
                             @register_candidate_source hook also works
  heuristic_plan             measurement-free default (mirrors the old
                             static auto path, plus distributed strategy
                             selection from the collective-bytes model)
  PlanCache                  JSON plan cache keyed by fingerprint; a hit
                             skips re-measurement entirely
  tune / plan_for            the tuning entry points used by solvers,
                             the serve engine, and benchmarks
  enumerate_mesh_plans /     the mesh-aware mode: distributed candidates
  tune_mesh / mesh_plan_for  (strategy='mesh', every accumulation x
                             shard-compute path, gated by the
                             collective-bytes model) measured on an
                             actual mesh of ``p`` forced host (or real)
                             devices; winners land in the cache under the
                             per-(matrix, p) key ``<fingerprint>@p<p>``

Mesh-aware tuning needs the process to see ``p`` devices — launch with
``XLA_FLAGS=--xla_force_host_platform_device_count=<p>`` on CPU (device
count is locked at first jax init, so benchmarks run it in a subprocess).

Windowed candidates with ``value_dtype='bfloat16'`` (enumerated only for
numerically-symmetric matrices) additionally pass an accuracy check
against the exact segment-sum product before they may win
(``VALUE_DTYPE_TOL``): the tuner trades precision for value-stream
bandwidth only where the matrix class tolerates it.

The timing harness is benchmarks/util.time_fn when importable (running
from the repo root); a same-contract fallback is inlined so the tuner
works from any installed location.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro import obs
from . import paths as paths_mod
from .csrc import CSRC, bandwidth as csrc_bandwidth, nnz_per_row
from .plan import ExecutionPlan, feasible, kernel_window

try:                                          # repo-root layout
    from benchmarks.util import time_fn as _time_fn
except ImportError:                           # installed / src-only path
    def _time_fn(fn, *args, warmup: int = 3, repeats: int = 10) -> float:
        """Median wall-clock seconds per call (benchmarks/util.py contract)."""
        import jax
        for _ in range(warmup):
            out = fn(*args)
        jax.block_until_ready(out)
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))


# ---------------------------------------------------------------------------
# Matrix statistics and fingerprinting
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MatrixStats:
    n: int
    m: int
    k: int
    nnz: int
    bandwidth: int
    working_set_bytes: int
    nnz_row_mean: float
    nnz_row_dev: float            # std of nnz per row (load-balance driver)
    numerically_symmetric: bool


def stats_of(M: CSRC) -> MatrixStats:
    w = nnz_per_row(M)
    return MatrixStats(
        n=M.n, m=M.m, k=M.k, nnz=M.nnz,
        bandwidth=csrc_bandwidth(M),
        working_set_bytes=M.working_set_bytes(),
        nnz_row_mean=float(w.mean()),
        nnz_row_dev=float(w.std()),
        numerically_symmetric=bool(M.numerically_symmetric),
    )


def fingerprint(M: CSRC) -> str:
    """Stable key of the matrix *class*: (n, m, k, bandwidth) in the clear
    plus a digest of the nnz-per-row histogram and symmetry flag.  Two
    matrices of the same class (same generator, same size) share a key, so
    solvers and the serve engine never re-tune a known class."""
    w = nnz_per_row(M)
    hist = np.bincount(np.minimum(w, 255).astype(np.int64), minlength=256)
    h = hashlib.sha1()
    h.update(hist.astype(np.int64).tobytes())
    h.update(bytes([int(M.numerically_symmetric)]))
    band = csrc_bandwidth(M)
    return f"n{M.n}m{M.m}k{M.k}b{band}-{h.hexdigest()[:12]}"


def mesh_fingerprint(fp: str, p: int) -> str:
    """Cache key of the per-(matrix class, mesh width) distributed tuning
    decision — the mesh-aware mode records one winner per (matrix, p)."""
    return f"{fp}@p{p}"


# ---------------------------------------------------------------------------
# Candidate enumeration
# ---------------------------------------------------------------------------

_CANDIDATE_SOURCES: List[Callable[[MatrixStats], List[ExecutionPlan]]] = []


def register_candidate_source(fn):
    """Extension hook: future kernels register a function
    ``stats -> [ExecutionPlan, ...]``; its (feasible) plans join every
    enumeration and therefore every tuning run."""
    _CANDIDATE_SOURCES.append(fn)
    return fn


def _distributed_fields(stats: MatrixStats, p_hint: int = 8):
    """Analytic choice of the sharding degrees of freedom (not measured on
    a single chip): nnz-guided partition unless rows are uniform; halo when
    the band fits inside a shard (the collective-bytes model's winner),
    reduce_scatter otherwise."""
    partition = "nnz" if stats.nnz_row_dev > 0 else "count"
    rows_per_shard = max(1, -(-stats.n // p_hint))
    acc = ("halo" if stats.bandwidth <= max(8, rows_per_shard)
           else "reduce_scatter")
    return partition, acc


def enumerate_plans(stats: MatrixStats,
                    tms=(32, 128),
                    k_steps_sublanes=(8,),
                    w_cap: int = 4096,
                    colorful_max_n: int = 2048,
                    p_hint: int = 8,
                    nrhs_options=(1,),
                    index_dtypes=("int32", "int16"),
                    colorings=("greedy", "race")) -> List[ExecutionPlan]:
    """All feasible candidate plans for a matrix with these statistics.

    Candidates come from the KernelPath registry (core/paths.py): every
    registered path contributes its own enumerator over the sweep space —
    segment is always a candidate; windowed kernel plans ('kernel', and
    'flat' when the nnz-per-row skew makes per-tile-exact packing worth
    measuring) are emitted per (tm, k_step) whose window fits under
    ``w_cap``; colorful for square matrices small enough that the
    O(n·deg²) greedy coloring is worth attempting.  Legacy
    ``@register_candidate_source`` hooks still join the pool.

    Every candidate — registry or hook — is filtered through the path's
    feasibility predicate, so a plan the packer cannot tile (window over
    ``w_cap``, square-only path on a rectangular matrix) is rejected here
    instead of erroring mid-tune.

    ``nrhs_options`` replicates every candidate per RHS block width, so a
    serving deployment can tune the batched SpMM operating point directly
    (the winning path may differ between nrhs=1 and nrhs=8: arithmetic
    intensity rises with the block).

    ``index_dtypes`` controls the windowed paths' index-stream proposals:
    with the default both int32 and (where the window fits in 16 bits)
    int16 variants are measured, so the tuner trades index bandwidth per
    matrix — SpMV is bandwidth-bound, and int16 halves 8 of ~16 streamed
    bytes per slot.

    ``colorings`` controls the colorful enumerator's provider proposals:
    with the default both the greedy first-fit and the RACE recursive
    level-group coloring (arXiv:1907.06487) are candidates wherever the
    colored path is feasible, priced apart by the cost model's locality
    terms (per-color launch overhead x palette size + reuse-distance
    penalty) and measured per matrix.
    """
    partition, acc = _distributed_fields(stats, p_hint)
    space = paths_mod.CandidateSpace(
        tms=tuple(tms), k_steps_sublanes=tuple(k_steps_sublanes),
        w_cap=w_cap, colorful_max_n=colorful_max_n,
        partition=partition, accumulation=acc,
        index_dtypes=tuple(index_dtypes),
        colorings=tuple(colorings))
    raw: List[ExecutionPlan] = []
    for entry in paths_mod.registered_paths():
        raw.extend(entry.candidates(stats, space))
    for source in _CANDIDATE_SOURCES:
        raw.extend(source(stats))
    plans = [p for p in raw
             if feasible(p, n=stats.n, m=stats.m, bandwidth=stats.bandwidth)]
    if tuple(nrhs_options) != (1,):
        plans = [dataclasses.replace(p, nrhs=r)
                 for p in plans for r in nrhs_options]
    # dedup on the full plan (frozen dataclass), preserving order — key()
    # elides execution-irrelevant fields and must not drop distinct plans
    seen, out = set(), []
    for p in plans:
        if p not in seen:
            seen.add(p)
            out.append(p)
    return out


def heuristic_plan(stats: MatrixStats, tm: int = 128,
                   w_cap: int = 4096) -> ExecutionPlan:
    """Measurement-free plan: the old SpmvOperator 'auto' logic (kernel if
    the window fits, else segment) with the analytic distributed fields."""
    partition, acc = _distributed_fields(stats)
    square = stats.n == stats.m
    if square and kernel_window(tm, stats.bandwidth) <= w_cap:
        return ExecutionPlan(path="kernel", tm=tm, w_cap=w_cap,
                             partition=partition, accumulation=acc)
    return ExecutionPlan(path="segment", w_cap=w_cap,
                         partition=partition, accumulation=acc)


# ---------------------------------------------------------------------------
# Mesh-aware candidate enumeration (strategy='mesh' plans per shard count)
# ---------------------------------------------------------------------------

# A distributed candidate is dropped when its estimated collective traffic
# exceeds this multiple of the shard's compute-stream bytes (working set /
# p): past that point the product is collective-bound by construction and
# measuring it wastes tuning budget (Schubert et al., arXiv:0910.4836 —
# the strategy decision is a bandwidth/topology question).
MESH_COLLECTIVE_RATIO = 4.0


def _halo_fits(stats: MatrixStats, p: int) -> bool:
    ns = -(-stats.n // p)
    ns = (ns + 7) // 8 * 8
    h = max(8, (stats.bandwidth + 7) // 8 * 8)
    return h <= ns


def enumerate_mesh_plans(stats: MatrixStats, p: int,
                         tms=(32, 128),
                         k_steps_sublanes=(8,),
                         w_cap: int = 4096,
                         nrhs_options=(1,),
                         index_dtypes=("int32", "int16"),
                         max_collective_ratio: float = MESH_COLLECTIVE_RATIO
                         ) -> List[ExecutionPlan]:
    """Distributed candidate plans for a p-way mesh.

    Shard-local compute comes from the paths the distributed strategies
    execute — 'segment' always, 'flat' when the skew gate makes it worth
    measuring (same enumerator the local tuner uses) — crossed with every
    accumulation strategy whose collective footprint passes the
    bandwidth gate: 'halo' only when the band fits inside one shard, and
    any strategy only when ``collective_bytes_estimate`` stays within
    ``max_collective_ratio`` x the shard's working-set bytes.
    """
    from .distributed import collective_bytes_from_stats

    if stats.n != stats.m or p < 1:
        return []                 # distributed strategies shard square rows
    partition = "nnz" if stats.nnz_row_dev > 0 else "count"
    space = paths_mod.CandidateSpace(
        tms=tuple(tms), k_steps_sublanes=tuple(k_steps_sublanes),
        w_cap=w_cap, partition=partition,
        index_dtypes=tuple(index_dtypes),
        # the precision trade is not enumerated on the mesh yet (explicit
        # bf16 mesh plans execute; measuring them needs the accuracy gate
        # wired into the distributed measurement loop first)
        value_dtypes=("float32",))
    bases: List[ExecutionPlan] = []
    # shard-compute candidates: segment (the universal shard-local
    # fallback) plus every registered path with ShardSupport — the
    # distributed strategies can run those per shard
    for entry in paths_mod.registered_paths():
        if entry.name != "segment" and entry.shard_support is None:
            continue
        for cand in entry.candidates(stats, space):
            if feasible(cand, n=stats.n, m=stats.m,
                        bandwidth=stats.bandwidth):
                bases.append(cand)
    shard_ws = max(1, stats.working_set_bytes // p)
    out: List[ExecutionPlan] = []
    for acc in ("halo", "reduce_scatter", "allreduce"):
        if acc == "halo" and not _halo_fits(stats, p):
            continue
        for r in nrhs_options:
            est = collective_bytes_from_stats(
                stats.n, stats.bandwidth, p, acc, nrhs=r)
            if est > max_collective_ratio * shard_ws:
                continue          # collective-bound by construction
            for base in bases:
                out.append(dataclasses.replace(
                    base, strategy="mesh", mesh_p=p, accumulation=acc,
                    nrhs=r))
    return out


def heuristic_mesh_plan(stats: MatrixStats, p: int,
                        w_cap: int = 4096) -> ExecutionPlan:
    """Measurement-free distributed plan: segment shard compute with the
    collective-bytes model's strategy pick (the analytic fallback when the
    process cannot see p devices to measure on).  Raises ValueError for
    rectangular matrices — same gate as ``enumerate_mesh_plans`` (the
    distributed strategies shard square rows only)."""
    if stats.n != stats.m:
        raise ValueError(
            "distributed strategies shard square matrices only; serve "
            "rectangular matrices through a local plan")
    partition = "nnz" if stats.nnz_row_dev > 0 else "count"
    acc = "halo" if _halo_fits(stats, p) else "reduce_scatter"
    return ExecutionPlan(path="segment", w_cap=w_cap, partition=partition,
                         accumulation=acc, strategy="mesh", mesh_p=p)


# ---------------------------------------------------------------------------
# Plan cache
# ---------------------------------------------------------------------------

class PlanCache:
    """JSON plan cache keyed by matrix fingerprint.

    File format (version 1):

        {"version": 1,
         "entries": {"<fingerprint>": {"plan": {...ExecutionPlan fields...},
                                       "best_us": 12.3,
                                       "timings_us": {"<plan key>": 12.3}}}}

    A ``get`` hit returns the stored plan without any re-measurement; the
    hit/miss counters let tests (and ops dashboards) assert that.  Entries
    carry a ``measured`` flag: heuristic (unmeasured) plans cached by
    ``plan_for(autotune=False)`` are visible to heuristic lookups but do
    NOT satisfy ``tune()``, which would otherwise report a never-measured
    plan as the argmin.

    Next to each plan the cache stores the **schedule artifact**
    (core/schedule.py): the block-ELL pack, row partition/halo ranges, and
    coloring the plan executes with.  Schedules live in memory plus — when
    the cache has a file path — as npz files under ``<stem>_schedules/``
    beside the JSON, keyed by (fingerprint, value digest, plan, partition
    width).  ``get_schedule`` hits mean zero pack/partition/coloring work;
    a schedule whose ``SCHEDULE_VERSION`` no longer matches is ignored and
    rebuilt (format-change invalidation).
    """

    VERSION = 1

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.entries: Dict[str, Dict] = {}
        self.hits = 0
        self.misses = 0
        self.schedules: Dict[str, object] = {}
        self.schedule_hits = 0
        self.schedule_misses = 0
        self.assembly_schedules: Dict[str, object] = {}
        self.assembly_hits = 0
        self.assembly_misses = 0
        self.shard_layouts: Dict[str, object] = {}
        self.shard_layout_hits = 0
        self.shard_layout_misses = 0
        if path is not None and os.path.exists(path):
            self._read(path)

    def _read(self, path: str):
        with open(path) as f:
            data = json.load(f)
        if data.get("version") != self.VERSION:
            raise ValueError(
                f"plan cache {path}: version {data.get('version')!r} "
                f"!= {self.VERSION}")
        self.entries = dict(data.get("entries", {}))

    def save(self, path: Optional[str] = None):
        path = path or self.path
        if path is None:
            raise ValueError("PlanCache.save: no path given or stored")
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"version": self.VERSION, "entries": self.entries},
                      f, indent=1, sort_keys=True)
        os.replace(tmp, path)
        self.path = path

    def get(self, fp: str,
            require_measured: bool = False) -> Optional[ExecutionPlan]:
        e = self.entries.get(fp)
        if e is None or (require_measured and not e.get("measured")):
            self.misses += 1
            obs.counter("plan_cache_lookups_total", kind="plan",
                        outcome="miss").inc()
            return None
        self.hits += 1
        obs.counter("plan_cache_lookups_total", kind="plan",
                    outcome="hit").inc()
        env = e.get("env")
        if env:
            # a winner measured under a different toolchain/device is
            # identifiable; loading one bumps the warning counter per
            # disagreeing field (git SHA excluded — see obs.provenance)
            for field in obs.env_mismatches(env):
                obs.counter("plan_cache_env_mismatch_total",
                            field=field).inc()
        return ExecutionPlan.from_dict(e["plan"])

    def put(self, fp: str, plan: ExecutionPlan,
            timings_s: Optional[Dict[str, float]] = None,
            predictions_s: Optional[Dict[str, float]] = None,
            roofline: Optional[Dict[str, float]] = None):
        """``predictions_s`` (plan key -> analytic seconds) and
        ``roofline`` ({'predicted_ms', 'measured_ms', 'roofline_fraction'}
        of the winner) are the predict-then-measure provenance: the cache
        records what the cost model claimed next to what the clock said —
        and ``env`` records which jax/device/git environment measured it
        (obs.environment_provenance)."""
        entry: Dict = {"plan": plan.to_dict(),
                       "measured": bool(timings_s),
                       "env": dict(obs.environment_provenance())}
        if timings_s:
            entry["timings_us"] = {k: round(v * 1e6, 3)
                                   for k, v in timings_s.items()}
            entry["best_us"] = round(min(timings_s.values()) * 1e6, 3)
        if predictions_s:
            entry["predicted_us"] = {k: round(v * 1e6, 3)
                                     for k, v in predictions_s.items()}
        if roofline:
            entry.update({k: roofline[k] for k in
                          ("predicted_ms", "measured_ms",
                           "roofline_fraction") if k in roofline})
        self.entries[fp] = entry

    # ---- schedule artifacts (stored next to the plans) ----

    def _schedule_dir(self) -> Optional[str]:
        if self.path is None:
            return None
        stem, _ = os.path.splitext(os.path.abspath(self.path))
        return stem + "_schedules"

    def get_schedule(self, fp: str, digest: str, plan: ExecutionPlan,
                     p: int = 8):
        """The cached schedule for (matrix, plan), or None.  Memory first,
        then the npz file beside the cache; version/plan mismatches count
        as misses (the caller rebuilds)."""
        from .schedule import (SpmvSchedule, plan_artifact_fields,
                               schedule_key)
        key = schedule_key(fp, digest, plan, p)
        sched = self.schedules.get(key)
        if sched is None:
            d = self._schedule_dir()
            f = None if d is None else os.path.join(d, key + ".npz")
            if f is not None and os.path.exists(f):
                try:
                    sched = SpmvSchedule.load_npz(f)
                except Exception:         # stale version, truncated or
                    sched = None          # foreign file: rebuild, not crash
                if sched is not None and (
                        plan_artifact_fields(sched.plan)
                        != plan_artifact_fields(plan)
                        or sched.value_digest != digest):
                    sched = None
                if sched is not None:
                    self.schedules[key] = sched
        if sched is None:
            self.schedule_misses += 1
            obs.counter("plan_cache_lookups_total", kind="schedule",
                        outcome="miss").inc()
            return None
        self.schedule_hits += 1
        obs.counter("plan_cache_lookups_total", kind="schedule",
                    outcome="hit").inc()
        return sched

    def put_schedule(self, sched, persist: bool = True):
        """Store a schedule (memory, and — for path-backed caches — as an
        npz beside the plans).  ``persist=False`` keeps it memory-only:
        the value-refresh path uses it so per-step time stepping does not
        re-compress a full npz (values + unchanged index streams) every
        step; the structural generation already on disk keeps serving
        fresh processes, which value-refresh from it on load."""
        key = sched.key()
        self.schedules[key] = sched
        d = self._schedule_dir()
        if persist and d is not None:
            sched.save_npz(os.path.join(d, key + ".npz"))

    def drop_schedule(self, sched, remove_file: bool = True):
        """Evict a schedule from memory (and, by default, its npz).  Used
        by the value-refresh path to replace a superseded value
        generation: time stepping keeps exactly one schedule per
        (structure, plan, p) in memory — the newest — so a 10k-step run
        does not accumulate 10k dead value streams."""
        key = sched.key()
        self.schedules.pop(key, None)
        d = self._schedule_dir()
        if remove_file and d is not None:
            try:
                os.remove(os.path.join(d, key + ".npz"))
            except OSError:
                pass

    def find_schedule_by_structure(self, fp: str, sdigest: str, plan,
                                   p: int = 8):
        """A cached schedule for the same matrix *structure* (fingerprint +
        structure digest + plan artifact geometry + partition width) whose
        values may differ — the FEM time-stepping fast path: the caller
        refreshes value streams (``schedule.refresh_schedule``) instead of
        re-packing/re-coloring.  In-memory schedules only: the scenario is
        repeated refreshes within one serving/solver process."""
        from .schedule import plan_artifact_fields
        fields = plan_artifact_fields(plan)
        for sched in self.schedules.values():
            if (sched.fingerprint == fp and sched.p == p
                    and sched.structure_digest == sdigest
                    and plan_artifact_fields(sched.plan) == fields):
                return sched
        return None

    # ---- distributed shard layouts (ShardedSlots / HaloLayout /
    # FlatShards / FlatHalo), stored beside the schedules and keyed by
    # (fingerprint, value digest, p, strategy kind, pack geometry) — the
    # npz layer that ships per-shard sub-artifacts to serving workers ----

    def get_shard_layout(self, key: str):
        """The cached distributed layout for this key, or None.  Memory
        first, then the npz beside the plans — a hit means zero per-shard
        pack/layout construction (the mesh executor's artifact-shipping
        path)."""
        from .schedule import load_shard_layout_npz
        lay = self.shard_layouts.get(key)
        if lay is None:
            d = self._schedule_dir()
            f = None if d is None else os.path.join(d, key + ".npz")
            if f is not None and os.path.exists(f):
                try:
                    lay = load_shard_layout_npz(f)
                except Exception:     # stale version / truncated: rebuild
                    lay = None
                if lay is not None:
                    self.shard_layouts[key] = lay
        if lay is None:
            self.shard_layout_misses += 1
            obs.counter("plan_cache_lookups_total", kind="shard_layout",
                        outcome="miss").inc()
            return None
        self.shard_layout_hits += 1
        obs.counter("plan_cache_lookups_total", kind="shard_layout",
                    outcome="hit").inc()
        return lay

    def put_shard_layout(self, key: str, lay, persist: bool = True):
        from .schedule import save_shard_layout_npz
        self.shard_layouts[key] = lay
        d = self._schedule_dir()
        if persist and d is not None:
            save_shard_layout_npz(os.path.join(d, key + ".npz"), lay)

    # ---- assembly schedules (repro.assembly.scatter), stored beside the
    # SpMV schedules and keyed by connectivity digest ----

    def get_assembly_schedule(self, digest: str, num_buffers: int = 8,
                              coloring: str = "greedy"):
        """The cached AssemblySchedule for this connectivity digest, or
        None.  Memory first, then the npz beside the cache — a hit means
        zero structural assembly work (slot maps, coloring, buffers).
        ``coloring`` picks the element-coloring provider slice of the
        cache (greedy keys are unchanged from pre-provider caches)."""
        from repro.assembly.scatter import AssemblySchedule, assembly_key
        key = assembly_key(digest, num_buffers, coloring)
        sched = self.assembly_schedules.get(key)
        if sched is None:
            d = self._schedule_dir()
            f = None if d is None else os.path.join(d, key + ".npz")
            if f is not None and os.path.exists(f):
                try:
                    sched = AssemblySchedule.load_npz(f)
                except Exception:      # stale version / truncated: rebuild
                    sched = None
                if sched is not None and (
                        sched.structure_digest != digest
                        or sched.coloring.provider != coloring):
                    sched = None
                if sched is not None:
                    self.assembly_schedules[key] = sched
        if sched is None:
            self.assembly_misses += 1
            obs.counter("plan_cache_lookups_total", kind="assembly",
                        outcome="miss").inc()
            return None
        self.assembly_hits += 1
        obs.counter("plan_cache_lookups_total", kind="assembly",
                    outcome="hit").inc()
        return sched

    def put_assembly_schedule(self, sched):
        key = sched.key()
        self.assembly_schedules[key] = sched
        d = self._schedule_dir()
        if d is not None:
            sched.save_npz(os.path.join(d, key + ".npz"))

    # ---- assembly strategy plans (assembly.scatter.tune_assembly):
    # the tuned (strategy, variant) winner + predict/measure provenance,
    # stored as a JSON record under "asmplan-<schedule key>" ----

    def get_assembly_plan(self, key: str):
        """The tuned assembly record for this schedule key, or None."""
        e = self.entries.get(key)
        rec = None if e is None else e.get("assembly")
        if rec is None:
            obs.counter("plan_cache_lookups_total", kind="assembly_plan",
                        outcome="miss").inc()
            return None
        obs.counter("plan_cache_lookups_total", kind="assembly_plan",
                    outcome="hit").inc()
        return dict(rec)

    def put_assembly_plan(self, key: str, record: Dict):
        self.entries[key] = {"assembly": dict(record), "measured": True}
        if self.path:
            self.save()

    def __len__(self) -> int:
        return len(self.entries)


# ---------------------------------------------------------------------------
# Tuning
# ---------------------------------------------------------------------------

# Max relative error a reduced-precision (value_dtype != 'float32')
# candidate may show against the exact segment-sum product before the
# tuner rejects it — the accuracy gate of the bf16 value-stream trade.
VALUE_DTYPE_TOL = 2e-2


def _rhs_pool(M: CSRC, x: Optional[np.ndarray]):
    """Measurement inputs per RHS block width, shared by the local and
    mesh tuners: multi-RHS candidates are measured at their tuned width
    (seeded per width, memoized)."""
    import jax.numpy as jnp
    if x is None:
        x = np.random.default_rng(0).standard_normal(M.m).astype(np.float32)
    xj = jnp.asarray(x)
    by_width = {1: xj} if xj.ndim == 1 else {xj.shape[1]: xj, 1: xj[:, 0]}

    def x_for(nrhs: int):
        if nrhs not in by_width:
            by_width[nrhs] = jnp.asarray(
                np.random.default_rng(nrhs).standard_normal(
                    (M.m, nrhs)).astype(np.float32))
        return by_width[nrhs]

    return x_for


@dataclasses.dataclass(frozen=True)
class TuneResult:
    plan: ExecutionPlan
    fingerprint: str
    timings_s: Dict[str, float]   # plan.key() -> seconds; empty on cache hit
    cached: bool
    # per-p distributed winners when tune() ran with mesh_ps (empty
    # otherwise); also recorded in the cache under mesh_fingerprint keys
    mesh_plans: Dict[int, ExecutionPlan] = dataclasses.field(
        default_factory=dict)
    # predict-then-measure provenance: plan.key() -> analytic roofline
    # seconds for every ranked candidate (superset of timings_s keys when
    # pruning ran), and the winner's achieved-roofline fraction
    predictions_s: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    roofline_fraction: Optional[float] = None


def tune(M: CSRC,
         cache: Optional[PlanCache] = None,
         x: Optional[np.ndarray] = None,
         candidates: Optional[List[ExecutionPlan]] = None,
         measure: Optional[Callable] = None,
         warmup: int = 1,
         repeats: int = 3,
         interpret: bool = True,
         save: bool = True,
         value_dtype_tol: float = VALUE_DTYPE_TOL,
         predict: bool = True,
         measure_top_k: Optional[int] = None,
         nrhs_options=(1,),
         mesh_ps=()) -> TuneResult:
    """Rank candidates by the analytic roofline, measure the top few, and
    return the argmin plan.

    ``cache`` short-circuits: a fingerprint hit returns the stored plan
    with zero measurements.  ``measure(op, x) -> seconds`` is injectable
    for tests; the default is the benchmarks/util timing harness with a
    small budget (the tuner runs at operator-construction time).

    ``predict=True`` (default) is the predict-then-measure mode: every
    feasible candidate is priced by roofline/cost_model.py (bytes/flops
    from matrix statistics — no packing, no timing) and only the
    ``measure_top_k`` cheapest-predicted plans are clocked (default
    max(3, quarter of the pool) — a >= 2x measurement cut on every suite
    matrix), plus each distinct path's best-predicted candidate so a
    cross-path mispricing can never exclude a path from measurement.  The cache entry records ``predicted_us`` per ranked
    candidate plus the winner's ``predicted_ms`` / ``measured_ms`` /
    ``roofline_fraction`` (fraction of the analytic roofline the
    measured time achieved).  ``predict=False`` measures the full pool
    (the oracle mode the pruned tuner is validated against in tests).

    Candidates with a reduced ``value_dtype`` must additionally match the
    exact segment-sum product within ``value_dtype_tol`` relative error or
    they are rejected before measurement (the bf16 accuracy gate).

    ``nrhs_options`` is the serving-time batched operating point: every
    candidate is replicated per RHS block width and measured at that
    width (argmin on per-column time), so a serving deployment that
    coalesces requests into multi-RHS blocks tunes the block product it
    will actually run — the winner's ``plan.nrhs`` records the width.

    ``mesh_ps`` is the mesh-aware mode: for every shard count listed the
    distributed candidates are measured on an actual ``p``-device mesh
    (``tune_mesh``) and the per-(matrix, p) winner is recorded in the
    cache under ``mesh_fingerprint(fp, p)`` — the process must see that
    many devices (forced host platform on CPU).
    """
    from repro.kernels.ops import SpmvOperator   # local: avoid import cycle

    fp = fingerprint(M)
    if cache is not None and not mesh_ps:
        # a heuristic (unmeasured) entry must not satisfy a tune request
        hit = cache.get(fp, require_measured=True)
        if hit is not None:
            return TuneResult(plan=hit, fingerprint=fp, timings_s={},
                              cached=True)

    stats = stats_of(M)
    cands = (candidates if candidates is not None
             else enumerate_plans(stats, nrhs_options=tuple(nrhs_options)))
    if measure is None:
        def measure(op, xv):
            return _time_fn(op, xv, warmup=warmup, repeats=repeats)
    _x_for = _rhs_pool(M, x)

    _y_ref_by_width: Dict[int, np.ndarray] = {}

    def _accuracy_ok(op, nrhs: int) -> bool:
        """Reduced-precision gate: compare against the exact product."""
        from repro.kernels import ref as ref_mod
        xv = _x_for(nrhs)
        if nrhs not in _y_ref_by_width:
            y_ref = (ref_mod.csrc_spmm(M, xv) if xv.ndim == 2
                     else ref_mod.csrc_spmv(M, xv))
            _y_ref_by_width[nrhs] = np.asarray(y_ref, dtype=np.float64)
        y_ref = _y_ref_by_width[nrhs]
        y = np.asarray(op(xv), dtype=np.float64)
        scale = max(1.0, float(np.abs(y_ref).max()))
        return float(np.abs(y - y_ref).max()) / scale <= value_dtype_tol

    cached_local = False
    if cache is not None and mesh_ps:
        hit = cache.get(fp, require_measured=True)
    else:
        hit = None

    timings: Dict[str, float] = {}
    predictions: Dict[str, float] = {}
    winner_frac: Optional[float] = None
    if hit is not None:
        best_plan, cached_local = hit, True
    else:
        pool = [p for p in cands
                if feasible(p, n=M.n, m=M.m, bandwidth=stats.bandwidth)]
        obs.counter("tuner_candidates_enumerated_total").inc(len(pool))
        est_by_key: Dict[str, object] = {}
        if predict and pool:
            from repro.roofline import cost_model
            ranked = cost_model.rank_plans(stats, pool)
            est_by_key = {p.key(): e for p, e in ranked}
            predictions = {p.key(): e.predicted_s for p, e in ranked}
            k_top = (measure_top_k if measure_top_k
                     else max(3, len(ranked) // 4))
            pool = [p for p, _ in ranked[:max(2, k_top)]]
            # path-diversity guarantee: the analytic model ranks *within*
            # a path reliably but can misprice one path against another
            # (padding on skewed row distributions is the known case), so
            # every distinct path keeps its best-predicted candidate in
            # the measured set — at most one extra measurement per path,
            # which preserves the >= 2x cut on pools of 10+ plans
            seen_paths = {p.path for p in pool}
            for p, _ in ranked:
                if p.path not in seen_paths:
                    seen_paths.add(p.path)
                    pool.append(p)
            pruned = len(ranked) - len(pool)
            obs.counter("tuner_candidates_pruned_total").inc(pruned)
            if ranked:
                # predict-then-measure savings: fraction of the feasible
                # pool the roofline ranking removed from the clock
                obs.gauge("tuner_predict_measure_savings").set(
                    pruned / len(ranked))
        best_plan, best_t, best_raw, best_op = None, float("inf"), None, None
        for p in pool:
            with obs.span("tune.measure", plan=p.key()):
                try:
                    op = SpmvOperator.from_plan(M, p, interpret=interpret)
                except ValueError:
                    continue      # pack-time infeasibility (bandwidth gate)
                if (p.value_dtype != "float32"
                        and not _accuracy_ok(op, p.nrhs)):
                    continue      # precision trade failed the gate
                t = float(measure(op, _x_for(p.nrhs)))
            obs.counter("tuner_candidates_measured_total").inc()
            timings[p.key()] = t
            # argmin on per-RHS-column time: an nrhs=8 candidate does 8x
            # the work of a single product, so raw runtimes are not
            # comparable across block widths
            t_norm = t / p.nrhs
            if t_norm < best_t:
                best_plan, best_t, best_raw, best_op = p, t_norm, t, op
        if best_plan is None:
            raise ValueError("no feasible execution plan for this matrix")

        roofline_entry: Optional[Dict[str, float]] = None
        est = est_by_key.get(best_plan.key())
        if est is not None and best_raw:
            winner_frac = est.predicted_s / best_raw
            obs.gauge("tuner_winner_roofline_fraction",
                      path=best_plan.path).set(winner_frac)
            roofline_entry = {
                "predicted_ms": round(est.predicted_s * 1e3, 6),
                "measured_ms": round(best_raw * 1e3, 6),
                "roofline_fraction": winner_frac,
            }
        if cache is not None:
            cache.put(fp, best_plan, timings, predictions_s=predictions,
                      roofline=roofline_entry)
            # store the winner's schedule next to the plan: serving
            # processes constructing this (matrix, plan) never re-pack or
            # re-color
            if (best_op is not None
                    and getattr(best_op, "schedule", None) is not None):
                cache.put_schedule(best_op.schedule)
            if save and cache.path is not None:
                cache.save()

    mesh_plans: Dict[int, ExecutionPlan] = {}
    for p_mesh in mesh_ps:
        res = tune_mesh(M, p_mesh, cache=cache, x=x, measure=measure,
                        warmup=warmup, repeats=repeats,
                        interpret=interpret, save=save,
                        nrhs_options=nrhs_options)
        mesh_plans[p_mesh] = res.plan
    return TuneResult(plan=best_plan, fingerprint=fp, timings_s=timings,
                      cached=cached_local, mesh_plans=mesh_plans,
                      predictions_s=predictions,
                      roofline_fraction=winner_frac)


def tune_mesh(M: CSRC, p: int,
              cache: Optional[PlanCache] = None,
              mesh=None,
              axis: str = "rows",
              x: Optional[np.ndarray] = None,
              candidates: Optional[List[ExecutionPlan]] = None,
              measure: Optional[Callable] = None,
              warmup: int = 1,
              repeats: int = 3,
              interpret: bool = True,
              save: bool = True,
              nrhs_options=(1,)) -> TuneResult:
    """The mesh-aware tuning mode: measure distributed candidates on an
    actual p-device mesh and cache the per-(matrix, p) winner.

    ``nrhs_options`` replicates the distributed candidates per RHS block
    width exactly as in :func:`tune` — the serving engine passes its
    batched operating point so the per-(matrix, p) winner is tuned for
    the block product it serves, not for nrhs=1.

    The winner is recorded under ``mesh_fingerprint(fingerprint(M), p)``,
    so local and distributed decisions for one matrix class coexist in
    the same cache: the serving engine asks for the mesh entry when it
    has a mesh to serve from, and the local entry otherwise.  The process
    must see ``p`` devices (``XLA_FLAGS=--xla_force_host_platform_
    device_count=<p>`` on CPU); a ``measure(fn, x) -> seconds`` injection
    makes the mode testable on one device with a 1-wide mesh.
    """
    import jax
    from .distributed import build_sharded_spmv

    fp = mesh_fingerprint(fingerprint(M), p)
    if cache is not None:
        hit = cache.get(fp, require_measured=True)
        if hit is not None:
            return TuneResult(plan=hit, fingerprint=fp, timings_s={},
                              cached=True)

    if mesh is None:
        ndev = len(jax.devices())
        if ndev < p:
            raise ValueError(
                f"mesh-aware tuning for p={p} needs {p} devices, this "
                f"process sees {ndev}; relaunch with XLA_FLAGS="
                f"--xla_force_host_platform_device_count={p}")
        mesh = jax.make_mesh((p,), (axis,))

    stats = stats_of(M)
    cands = (candidates if candidates is not None
             else enumerate_mesh_plans(stats, p,
                                       nrhs_options=tuple(nrhs_options)))
    if not cands:
        raise ValueError(
            f"no feasible distributed plan for this matrix at p={p}")
    if measure is None:
        def measure(fn, xv):
            return _time_fn(fn, xv, warmup=warmup, repeats=repeats)
    _x_for = _rhs_pool(M, x)

    timings: Dict[str, float] = {}
    best_plan, best_t = None, float("inf")
    for cand in cands:
        try:
            # measured WITHOUT the cache: only the argmin's artifacts
            # are shipped (below) — losers would otherwise persist one
            # matrix-sized npz per candidate geometry
            fn = build_sharded_spmv(M, mesh, axis, strategy="auto",
                                    cache=None, plan=cand,
                                    interpret=interpret)
        except ValueError:
            continue              # halo band gate / window over cap
        with obs.span("tune.measure_mesh", plan=cand.key(), p=p):
            t = float(measure(fn, _x_for(cand.nrhs)))
        obs.counter("tuner_candidates_measured_total").inc()
        timings[cand.key()] = t
        t_norm = t / cand.nrhs
        if t_norm < best_t:
            best_plan, best_t = cand, t_norm
    if best_plan is None:
        raise ValueError(
            f"no distributed candidate survived measurement at p={p}")

    if cache is not None:
        cache.put(fp, best_plan, timings)
        # ship the winner's schedule + shard-layout artifacts (layout
        # builders re-serve the memoized build and persist it)
        build_sharded_spmv(M, mesh, axis, strategy="auto", cache=cache,
                           plan=best_plan, interpret=interpret)
        if save and cache.path is not None:
            cache.save()
    return TuneResult(plan=best_plan, fingerprint=fp, timings_s=timings,
                      cached=False)


def plan_for(M: CSRC,
             cache: Optional[PlanCache] = None,
             autotune: bool = False,
             **tune_kw) -> ExecutionPlan:
    """The plan to run this matrix with.

    Cache hit wins; otherwise ``autotune=True`` measures (and fills the
    cache), ``autotune=False`` falls back to the measurement-free
    heuristic (still cached, so the decision is stable across calls).
    """
    if autotune:
        # tune() performs the cache probe itself — probing here too would
        # double-count misses and fingerprint twice
        return tune(M, cache=cache, **tune_kw).plan
    fp = fingerprint(M)
    if cache is not None:
        hit = cache.get(fp)
        if hit is not None:
            return hit
    plan = heuristic_plan(stats_of(M))
    if cache is not None:
        cache.put(fp, plan)
        if cache.path is not None:
            cache.save()
    return plan


def mesh_plan_for(M: CSRC, p: int,
                  cache: Optional[PlanCache] = None,
                  autotune: bool = False,
                  interpret: bool = True,
                  **tune_kw) -> ExecutionPlan:
    """The distributed plan to serve this matrix with on a p-way mesh.

    Mirrors :func:`plan_for` for the per-(matrix, p) cache keys: hit wins;
    ``autotune=True`` measures on an actual mesh (``tune_mesh``);
    ``autotune=False`` falls back to the collective-bytes heuristic
    (cached, so the decision is stable across calls)."""
    if autotune:
        return tune_mesh(M, p, cache=cache, interpret=interpret,
                         **tune_kw).plan
    fp = mesh_fingerprint(fingerprint(M), p)
    if cache is not None:
        hit = cache.get(fp)
        if hit is not None:
            return hit
    plan = heuristic_mesh_plan(stats_of(M), p)
    if cache is not None:
        cache.put(fp, plan)
        if cache.path is not None:
            cache.save()
    return plan
