"""Row partitioning for the parallel CSRC product (paper §3).

The paper found that nnz-guided partitioning ("the deviation from the average
number of non-zeros per row is minimized") beats row-count partitioning
because flops per row are proportional to nnz.  We reuse the same algorithm
at every granularity of the TPU mapping:

  * shard level  — rows → mesh shards (the paper's "threads");
  * tile level   — rows inside a shard → Pallas grid tiles.

Effective ranges (paper §3.1, the *effective* accumulation method) are the
set of destination rows a partition actually writes: its own rows (gather
term) plus the scatter targets ja[p].  For band matrices these are contiguous
windows, which on TPU become halo windows exchanged between neighbor shards.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from .csrc import CSRC, nnz_per_row, row_of_slot


@dataclasses.dataclass(frozen=True)
class RowPartition:
    """A p-way contiguous row partition."""
    starts: np.ndarray          # (p+1,) row boundaries; part t owns [starts[t], starts[t+1])
    # effective write range per part: [eff_lo[t], eff_hi[t]) covers every row
    # part t writes (own rows + scatter targets).
    eff_lo: np.ndarray          # (p,)
    eff_hi: np.ndarray          # (p,)
    nnz_per_part: np.ndarray    # (p,)

    @property
    def p(self) -> int:
        return len(self.starts) - 1

    def rows(self, t: int) -> Tuple[int, int]:
        return int(self.starts[t]), int(self.starts[t + 1])


def partition_rows_by_nnz(M: CSRC, p: int) -> RowPartition:
    """Contiguous p-way split minimizing per-part nnz deviation (greedy
    prefix walk against the ideal quantile, as in the paper's non-zero guided
    implementation)."""
    n = M.n
    w = nnz_per_row(M).astype(np.int64)
    csum = np.concatenate([[0], np.cumsum(w)])
    total = csum[-1]
    starts = np.zeros(p + 1, dtype=np.int64)
    for t in range(1, p):
        target = total * t / p
        # row index whose prefix is closest to the target quantile
        idx = int(np.searchsorted(csum, target))
        idx = min(max(idx, int(starts[t - 1]) + 1), n - (p - t))
        # snap to whichever neighbor is closer
        if idx > 0 and abs(csum[idx - 1] - target) < abs(csum[idx] - target):
            idx = max(idx - 1, int(starts[t - 1]) + 1)
        starts[t] = idx
    starts[p] = n

    eff_lo = np.zeros(p, dtype=np.int64)
    eff_hi = np.zeros(p, dtype=np.int64)
    ros = row_of_slot(M)
    ja = np.asarray(M.ja)
    ia = np.asarray(M.ia)
    for t in range(p):
        r0, r1 = int(starts[t]), int(starts[t + 1])
        lo, hi = r0, r1
        s0, s1 = int(ia[r0]), int(ia[r1])
        if s1 > s0:
            lo = min(lo, int(ja[s0:s1].min()))
        eff_lo[t], eff_hi[t] = lo, hi
    nnz_part = np.array([
        int(np.sum(nnz_per_row(M)[int(starts[t]):int(starts[t + 1])]))
        for t in range(p)
    ], dtype=np.int64)
    del ros
    return RowPartition(starts=starts, eff_lo=eff_lo, eff_hi=eff_hi,
                        nnz_per_part=nnz_part)


def partition_rows_by_count(M: CSRC, p: int) -> RowPartition:
    """Row-count split (the paper's inferior baseline — kept for benchmarks)."""
    n = M.n
    starts = np.linspace(0, n, p + 1).astype(np.int64)
    ja = np.asarray(M.ja)
    ia = np.asarray(M.ia)
    eff_lo = np.zeros(p, dtype=np.int64)
    eff_hi = np.zeros(p, dtype=np.int64)
    for t in range(p):
        r0, r1 = int(starts[t]), int(starts[t + 1])
        lo = r0
        s0, s1 = int(ia[r0]), int(ia[r1])
        if s1 > s0:
            lo = min(lo, int(ja[s0:s1].min()))
        eff_lo[t], eff_hi[t] = lo, r1
    w = nnz_per_row(M)
    nnz_part = np.array([int(np.sum(w[int(starts[t]):int(starts[t + 1])]))
                         for t in range(p)], dtype=np.int64)
    return RowPartition(starts=starts, eff_lo=eff_lo, eff_hi=eff_hi,
                        nnz_per_part=nnz_part)


def load_imbalance(part: RowPartition) -> float:
    """max/mean nnz per part — 1.0 is perfect balance."""
    m = part.nnz_per_part
    return float(m.max() / max(1.0, m.mean()))


def interval_boundaries(part: RowPartition) -> np.ndarray:
    """Paper §3.1 method 4 (*interval*): the union of all effective-range
    endpoints splits y into intervals, each accumulated by one thread.
    Returns the sorted unique boundary list."""
    pts = np.unique(np.concatenate([part.eff_lo, part.eff_hi,
                                    part.starts[:1], part.starts[-1:]]))
    return pts


def halo_widths(part: RowPartition) -> List[int]:
    """For the TPU *effective/halo* strategy: how far below its own range each
    shard writes (band matrices ⇒ this is the halo a shard must send to its
    left neighbors)."""
    return [int(part.starts[t] - part.eff_lo[t]) for t in range(part.p)]
