"""Analytic per-candidate roofline costs for ExecutionPlans.

The tuner's predict-then-measure mode (core/tuner.py) needs a ranking of
candidate plans *before* any of them is packed or timed.  This module
prices a candidate from matrix statistics alone — the same geometry
formulas the packers use (window width, tile count, slot padding), but
evaluated on MatrixStats instead of a built pack:

  bytes   the streamed working set per product: value streams (halved for
          numerically-symmetric matrices and again for bfloat16), index
          streams (halved for int16), x/y traffic, and the per-tile window
          writes + overlap-add re-reads;
  flops   O(1)-per-slot multiply-adds for the streaming/segment variants;
          the one-hot variants additionally pay the (S, W) mask build
          (iota + compare + convert, one op per mask element — the same
          ops roofline/hlo_cost.py now counts) and the dot_general
          contractions, 2·S·W·nrhs flops each — which is exactly why
          one-hot is compute-bound and stream is not;
  predicted_s = max(bytes / HBM_BW, flops / PEAK_FLOPS_BF16), the chip
          roofline of repro.launch.mesh (the same constants
          roofline/analysis.py prices whole serving configs with).

Absolute times are TPU-scale and the tests run in interpret mode on CPU,
so predictions are used for *ranking* (measure only the top-K) and for
the achieved-roofline observability ratio, never as a substitute for
measurement.  ``roofline_fraction = predicted_s / measured_s`` — the
fraction of the analytic roofline a measured plan actually achieved
(1.0 = at the roofline; interpret-mode CPU numbers are far below).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.core.plan import ExecutionPlan, kernel_window, LANES
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


_VALUE_BYTES = {"float32": 4, "bfloat16": 2}
_INDEX_BYTES = {"int32": 4, "int16": 2}

# --- colorful-path locality terms -----------------------------------------
# Per-color serial launch overhead: each color class is its own scatter
# dispatch, serialized against the previous one, so the colored path pays
# this once per palette entry — the term that makes a 49-color greedy
# schedule price above a 4-color RACE schedule on the same bytes.
COLOR_LAUNCH_S = 2e-6
# Scatter transaction granularity: an isolated y/x touch moves a whole
# line, using only the 4 bytes it wanted.  Classes whose rows stride the
# matrix (greedy destroys row locality — the paper's §3.2 criticism) pay
# the waste on most touches; RACE classes are unions of contiguous level
# ranges, so neighbouring rows share lines and most of the waste vanishes.
SCATTER_LINE_BYTES = 64.0
_REUSE_WASTE_FRACTION = {"greedy": 1.0, "race": 0.25}


def _coloring_palette_estimate(stats, provider: str) -> float:
    """Analytic palette-size estimate for the distance-2 row coloring.

    greedy first-fit needs about the conflict degree + 1 colors: on banded
    matrices the distance-2 conflict degree is ~2·bandwidth, on
    unstructured ones ~deg² (capped at n-1).  RACE's bipartition needs two
    sweeps per recursion depth, and the depth the chunk-size target forces
    is shallow (one or two) on every class we generate — so it is modeled
    as a small constant palette, which is exactly its empirical behaviour
    (2–10 colors where greedy needs 30–70).
    """
    n = max(stats.n, 1)
    deg = 2.0 * stats.k / n
    conflict_deg = min(float(n - 1), 2.0 * stats.bandwidth,
                       deg * deg + deg)
    if provider == "race":
        return 4.0                       # two sweeps x ~one recursion level
    return 1.0 + conflict_deg


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """Roofline price of one candidate plan on one matrix class."""
    bytes: float                  # streamed bytes per product
    flops: float                  # arithmetic ops per product
    memory_s: float               # bytes / HBM_BW
    compute_s: float              # flops / PEAK_FLOPS_BF16
    predicted_s: float            # max(memory_s, compute_s)

    @property
    def bound(self) -> str:
        return "compute" if self.compute_s > self.memory_s else "memory"

    def to_dict(self) -> Dict:
        return {"bytes": self.bytes, "flops": self.flops,
                "predicted_ms": self.predicted_s * 1e3, "bound": self.bound}


def _windowed_geometry(stats, plan: ExecutionPlan) -> Tuple[int, int, int]:
    """(nt, w_pad, padded slot count) of a 'kernel'/'flat' pack, estimated
    from stats — mirrors blockell.pack / pack_flat without building them."""
    tm = plan.tm
    nt = max(1, -(-stats.n // tm))
    w_pad = kernel_window(tm, stats.bandwidth)
    k_step = plan.k_step_sublanes * LANES
    if plan.path == "flat":
        # per-tile-exact packing: ceil(k / k_step) full steps plus at most
        # one remainder step per tile (rows never share a step across
        # tiles), so padding stays O(nt·k_step) regardless of skew
        steps = -(-max(stats.k, 1) // k_step) + nt
        return nt, w_pad, steps * k_step
    # rectangular grid: every tile is padded to the fullest tile's slot
    # count, so skew inflates the pack — model it with the nnz-per-row
    # dispersion (a tile of tm rows concentrates ~tm·dev of excess)
    mean_tile = max(stats.k, 1) / nt
    imbalance = 1.0 + stats.nnz_row_dev / max(stats.nnz_row_mean, 1.0)
    s_tile = _round_up(max(int(mean_tile * imbalance), 1), k_step)
    return nt, w_pad, nt * s_tile


def _nnzsplit_geometry(stats, plan: ExecutionPlan) -> Tuple[int, int, int]:
    """(num_chunks, r_pad, padded entry count): the dest-sorted stream has
    one entry per triangle half (2k total), cut into S = ks·128 chunks."""
    s = plan.k_step_sublanes * LANES
    entries = max(2 * stats.k, 1)
    num_chunks = -(-entries // s)
    # a chunk of S entries spans ~S / (nnz per row) rows
    span = s / max(stats.nnz_row_mean, 1.0)
    r_pad = _round_up(max(int(span), 1), 128)
    return num_chunks, r_pad, num_chunks * s


def plan_cost(stats, plan: ExecutionPlan) -> CostEstimate:
    """Roofline price of one candidate.  Any registered path prices at
    least as the generic streaming product (the segment formula), so a
    future path joins predict-then-measure without editing this module."""
    nrhs = max(plan.nrhs, 1)
    vb = _VALUE_BYTES.get(plan.value_dtype, 4)
    ib = _INDEX_BYTES.get(plan.index_dtype, 4)
    n, k = stats.n, max(stats.k, 1)
    vstreams = 1 if stats.numerically_symmetric else 2
    xy = 2.0 * 4 * max(n, stats.m) * nrhs      # x read + y write
    diag = 4.0 * n
    launch_s = 0.0                             # serialized dispatch overhead

    if plan.path in ("kernel", "flat"):
        nt, w_pad, slots = _windowed_geometry(stats, plan)
        byts = (slots * (vb * vstreams + ib * 2)   # vals + col/row streams
                + diag + xy
                + 2.0 * nt * w_pad * 4 * nrhs)     # windows + overlap-add
        flops = 4.0 * slots * nrhs + 2.0 * n * nrhs
        if plan.variant == "onehot":
            # two (S, W) masks: iota + compare + convert per element, then
            # four dot_generals at 2·S·W·nrhs each
            flops += slots * w_pad * (6.0 + 8.0 * nrhs)
    elif plan.path == "nnzsplit":
        nc, r_pad, slots = _nnzsplit_geometry(stats, plan)
        byts = (slots * (vb + 4 + ib)     # vals + src gather idx + lrow
                + diag + xy
                + 2.0 * nc * r_pad * 4 * nrhs)     # partials + fixup
        flops = 2.0 * slots * nrhs + 2.0 * n * nrhs
        if plan.variant == "onehot":
            flops += slots * r_pad * (3.0 + 2.0 * nrhs)
    elif plan.path == "colorful":
        # colored execution streams the triangle once in total (the color
        # classes tile the slots), but adds the two locality terms: one
        # serialized scatter launch per color, and the reuse-distance
        # penalty — scattered classes touch x/y one isolated line per
        # element (2k + n targets per product), contiguous RACE level
        # groups touch dense lines
        colors = _coloring_palette_estimate(stats, plan.coloring)
        waste = _REUSE_WASTE_FRACTION.get(plan.coloring, 1.0)
        byts = k * (4 * vstreams + 4 * 2) + diag + xy
        byts += waste * (2.0 * k + n) * (SCATTER_LINE_BYTES - 4.0)
        flops = 4.0 * k * nrhs + 2.0 * n * nrhs
        launch_s = colors * COLOR_LAUNCH_S
    else:
        # segment / future paths: the unpadded streaming product
        byts = k * (4 * vstreams + 4 * 2) + diag + xy
        flops = 4.0 * k * nrhs + 2.0 * n * nrhs

    mem_s = byts / HBM_BW
    cmp_s = flops / PEAK_FLOPS_BF16
    return CostEstimate(bytes=float(byts), flops=float(flops),
                        memory_s=mem_s, compute_s=cmp_s,
                        predicted_s=max(mem_s, cmp_s) + launch_s)


def rank_plans(stats, plans: Sequence[ExecutionPlan]
               ) -> List[Tuple[ExecutionPlan, CostEstimate]]:
    """Candidates cheapest-first by predicted per-RHS-column time (the
    tuner's argmin metric — an nrhs=8 plan prices 8 columns of work)."""
    priced = [(p, plan_cost(stats, p)) for p in plans]
    priced.sort(key=lambda pc: pc[1].predicted_s / max(pc[0].nrhs, 1))
    return priced


def roofline_fraction(est: CostEstimate, measured_s: float) -> float:
    """Fraction of the analytic roofline the measured time achieved."""
    if measured_s <= 0:
        return 0.0
    return est.predicted_s / measured_s


# ---------------------------------------------------------------------------
# FEM assembly scatter pricing (repro.assembly.scatter.tune_assembly)
# ---------------------------------------------------------------------------

def assembly_cost(sched, strategy: str,
                  variant: str = "stream") -> CostEstimate:
    """Roofline price of one assembly value refresh for a (strategy,
    variant) candidate on an AssemblySchedule (duck-typed: ne, edof,
    size, num_buffers, coloring, and the kernel packs).

    All strategies stream the G = ne·edof² contribution values plus
    their index streams (halved under the int16 gate) and write the
    size-length unified vector.  What separates them are the overhead
    terms: the colored-batch kernels pay the (C, Lmax) pack padding;
    the one-hot body additionally builds an (L, TILE) mask per output
    tile (iota + compare + convert + 2-op contraction per element —
    compute-bound by construction); the legacy per-color baseline pays
    one serialized scatter launch per palette entry plus the isolated
    scatter-line waste; private pays 2·B·size partial traffic for the
    buffer reduce; sorted-slot streams exactly G with none of the above
    — which is precisely when it beats colored (docs/DESIGN.md §10)."""
    from repro.kernels.assembly_scatter import ONEHOT_TILE

    contribs = float(sched.ne * sched.edof * sched.edof)   # G
    size = float(sched.size)
    out_bytes = size * 4.0
    ib_slot = _INDEX_BYTES.get(str(sched.color_slots.dtype), 4)
    ib_tgt = _INDEX_BYTES.get(str(sched.color_targets.dtype), 4)
    launch_s = 0.0

    if strategy == "colored" and variant == "percolor":
        colors = int(sched.coloring.num_colors)
        byts = contribs * (4.0 + 4.0) + out_bytes
        # each color's targets stride the unified vector: isolated
        # line-granularity touches, like the colorful SpMV path
        byts += contribs * (SCATTER_LINE_BYTES - 4.0)
        flops = contribs
        launch_s = colors * COLOR_LAUNCH_S
    elif strategy == "colored":
        padded = float(sched.color_slots.shape[0]
                       * sched.color_slots.shape[1])       # C·Lmax
        byts = padded * (4.0 + ib_slot + ib_tgt) + out_bytes
        flops = padded
        if variant == "onehot":
            # per (color, tile) program: an (L, TILE) mask — iota +
            # compare + convert (3 ops) + the 2-op dot contraction —
            # over ceil((size+1)/TILE) tiles
            size_pad = float(_round_up(int(size) + 1, ONEHOT_TILE))
            flops += padded * size_pad * 5.0
    elif strategy == "sorted":
        byts = contribs * (4.0 + ib_slot + ib_tgt) + out_bytes
        flops = contribs
    elif strategy == "private":
        buffers = float(sched.num_buffers)
        # partials written then re-read for the reduce
        byts = (contribs * (4.0 + 4.0) + out_bytes
                + 2.0 * buffers * (size + 1.0) * 4.0)
        flops = contribs + buffers * size
    else:                              # serial oracle — not a candidate
        byts = contribs * (4.0 + 4.0) + out_bytes
        byts += contribs * (SCATTER_LINE_BYTES - 4.0)
        flops = contribs

    mem_s = byts / HBM_BW
    cmp_s = flops / PEAK_FLOPS_BF16
    return CostEstimate(bytes=float(byts), flops=float(flops),
                        memory_s=mem_s, compute_s=cmp_s,
                        predicted_s=max(mem_s, cmp_s) + launch_s)


def rank_assembly_candidates(
        sched, candidates: Sequence[Tuple[str, str]]
        ) -> List[Tuple[Tuple[str, str], CostEstimate]]:
    """(strategy, variant) candidates cheapest-first by predicted time —
    the assembly tuner's measure-ordering (tune_assembly)."""
    priced = [(sv, assembly_cost(sched, sv[0], sv[1]))
              for sv in candidates]
    priced.sort(key=lambda pc: pc[1].predicted_s)
    return priced
