"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds:

  compute    = HLO_FLOPs            / (chips × 197 TFLOP/s)
  memory     = HLO_bytes_accessed   / (chips × 819 GB/s)
  collective = collective_bytes     / (chips × 50 GB/s per link)

HLO_FLOPs / bytes come from compiled.cost_analysis() (already per-module,
post-SPMD: they are *per-device* totals on the CPU backend's partitioned
module).  collective_bytes is parsed from the post-optimization HLO text:
we sum operand bytes of all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute ops.

MODEL_FLOPS (6·N·D train, 2·N·D inference; N = active params for MoE) gives
the useful-work ratio — remat recompute and ELL/capacity padding show up as
HLO_FLOPs > MODEL_FLOPS.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  f32[16,512]{1,0}  or  bf16[2,4096]
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, int]]:
    """Per-collective-kind {count, bytes} from post-SPMD HLO text.

    Bytes = output shape bytes of each collective instruction (per-device).
    Tuple-shaped outputs sum their components.
    """
    stats = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # "<name> = <shape> <op>(...)" — find "= shape op(" patterns
        m = re.match(r"[%\w.\-]+ = ((?:\([^)]*\))|(?:\w+\[[\d,]*\][^ ]*)) "
                     r"([\w\-]+)\(", s)
        if not m:
            continue
        shape_str, op = m.groups()
        base = op.rstrip("-start").rstrip("-done") if op.endswith(
            ("-start", "-done")) else op
        if base.endswith("-start"):
            base = base[:-6]
        if base not in _COLLECTIVES:
            continue
        if op.endswith("-done"):
            continue                      # counted at -start
        if shape_str.startswith("("):
            total = sum(_shape_bytes(p.strip())
                        for p in shape_str[1:-1].split(","))
        else:
            total = _shape_bytes(shape_str)
        stats[base]["count"] += 1
        stats[base]["bytes"] += total
    return stats


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0
    bytes_per_device: float = 0.0
    collectives: Optional[dict] = None

    def finalize(self):
        self.compute_s = self.hlo_flops / PEAK_FLOPS_BF16
        self.memory_s = self.hlo_bytes / HBM_BW
        self.collective_s = self.collective_bytes / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        self.useful_ratio = (self.model_flops / max(1.0, self.hlo_flops
                                                    * self.chips))
        return self

    def to_dict(self):
        return dataclasses.asdict(self)


def model_flops(cfg, shape) -> float:
    """6·N·D (train) / 2·N·D (inference) with N = active params."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.batch


def analyze(arch: str, shape, mesh_name: str, chips: int,
            cost: Dict, hlo_text: str, cfg) -> Roofline:
    """All three terms from the trip-count-aware HLO rollup (hlo_cost.py).

    XLA's raw cost_analysis counts while bodies once (layer scans would be
    undercounted ~n_layers×) — its values are kept as ``xla_raw_*``
    diagnostics only.
    """
    from .hlo_cost import analyze_hlo
    costs = analyze_hlo(hlo_text)
    r = Roofline(arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
                 hlo_flops=costs.flops, hlo_bytes=costs.bytes,
                 collective_bytes=costs.collective_bytes,
                 model_flops=model_flops(cfg, shape),
                 collectives=costs.collectives)
    r = r.finalize()
    r.collectives = dict(r.collectives)
    r.collectives["xla_raw_flops"] = float(cost.get("flops", 0.0))
    r.collectives["xla_raw_bytes"] = float(cost.get("bytes accessed", 0.0))
    return r
