"""Trip-count-aware cost analysis over post-optimization HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE regardless
of trip count (verified in this container: a scan of length 2 and length 8
report identical flops).  Since every layer stack here is a lax.scan, raw
cost_analysis undercounts by ~n_layers×.  This module re-derives costs by
walking the HLO computation tree:

  * parse computations and instructions (shapes, ops, operands, attrs);
  * extract while-loop trip counts from the loop condition's comparison
    constant (our loops are canonical 0..N counters);
  * roll up from ENTRY:  cost(comp) = Σ local
        + Σ_while trips × (cost(body) + cost(cond))
        + Σ_call cost(callee);
  * FLOPs: dot ops (2·prod(out)·prod(contracting)) — matmuls dominate all
    our models; fusion computations are traversed for dots; iota/compare/
    convert count one op per output element (the one-hot SpMV kernels
    synthesize (S, W) masks from exactly these three ops, so eliding them
    misclassifies that path as bandwidth-bound);
  * bytes: instruction boundary traffic (out + operands) at non-fused
    level — the same semantics as XLA's "bytes accessed";
  * collective bytes: output bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute, trip-multiplied.

Validated against hand-computable scans in tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


@dataclasses.dataclass
class Instr:
    name: str
    shape_str: str
    op: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]          # symbol -> shape string
    is_entry: bool = False


# header: "[ENTRY ]%name (params...) -> type {"  — params may nest parens,
# so match only the name prefix and require the line to end with '{'
_COMP_HEAD = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w]+\[[\d,]*\]"
    r"(?:{[^}]*})?))\s+([\w\-]+)\((.*)$")


def _shape_elems_bytes(shape: str) -> Tuple[int, int]:
    """(elements, bytes) of one non-tuple shape string."""
    m = re.match(r"(\w+)\[([\d,]*)\]", shape)
    if not m:
        return 0, 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n, n * _DTYPE_BYTES.get(dt, 0)


def shape_bytes(shape: str) -> int:
    if shape.startswith("("):
        return sum(shape_bytes(p.strip())
                   for p in _split_tuple(shape[1:-1]))
    return _shape_elems_bytes(shape)[1]


def _split_tuple(s: str) -> List[str]:
    out, depth, cur = [], 0, ""
    for ch in s:
        # '{' guards layout annotations: "f32[32,48]{1,0} %x" must not be
        # split at the comma inside the layout
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append(cur)
            cur = ""
        else:
            cur += ch
    if cur.strip():
        out.append(cur)
    return out


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = _COMP_HEAD.match(s)
            if m and s.endswith("{") and "->" in s:
                cur = Computation(name=m.group(2), instrs=[], shapes={},
                                  is_entry=bool(m.group(1)))
            continue
        if s == "}" or s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, shape_str, op, rest = m.groups()
        # split operand list from attrs: operands end at the matching ')'
        depth = 1
        i = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operand_str, attrs = rest[:i], rest[i + 1:]
        operands = [o.strip().lstrip("%")
                    for o in _split_tuple(operand_str) if o.strip()]
        # operands may carry inline types: "f32[2,3] %x" -> take last token
        operands = [o.split()[-1].lstrip("%") if " " in o else o
                    for o in operands]
        cur.instrs.append(Instr(name=name, shape_str=shape_str, op=op,
                                operands=operands, attrs=attrs))
        cur.shapes[name] = shape_str
    return comps


def _dot_flops(instr: Instr, shapes: Dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(instr.shape_str)
    lhs = shapes.get(instr.operands[0] if instr.operands else "", "")
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs)
    if not m or not lhs:
        return 2.0 * out_elems          # fallback: assume K=1
    dims_m = re.match(r"\w+\[([\d,]*)\]", lhs)
    if not dims_m:
        return 2.0 * out_elems
    lhs_dims = [int(d) for d in dims_m.group(1).split(",") if d]
    k = 1
    for ci in m.group(1).split(","):
        if ci != "" and int(ci) < len(lhs_dims):
            k *= lhs_dims[int(ci)]
    return 2.0 * out_elems * k


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the loop condition — our scans compare a
    0-based counter against the trip count (constants parse as the sole
    'operand' of a constant instruction: ``%c = s32[] constant(4096)``)."""
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant" and ins.operands:
            tok = ins.operands[0]
            if re.fullmatch(r"\d+", tok):
                best = max(best, int(tok))
        for m in re.finditer(r"constant\((\d+)\)", ins.attrs):
            best = max(best, int(m.group(1)))
    return best


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: Dict[str, Dict[str, float]] = None

    def __post_init__(self):
        if self.collectives is None:
            self.collectives = {k: {"count": 0.0, "bytes": 0.0}
                                for k in _COLLECTIVES}

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.collective_bytes += mult * other.collective_bytes
        for k in _COLLECTIVES:
            self.collectives[k]["count"] += mult * other.collectives[k]["count"]
            self.collectives[k]["bytes"] += mult * other.collectives[k]["bytes"]


def _comp_cost(comp: Computation, comps: Dict[str, Computation],
               memo: Dict[str, Costs], in_fusion: bool) -> Costs:
    key = comp.name + ("#f" if in_fusion else "")
    if key in memo:
        return memo[key]
    c = Costs()
    for ins in comp.instrs:
        out_bytes = shape_bytes(ins.shape_str)
        if ins.op == "dot":
            c.flops += _dot_flops(ins, comp.shapes)
        elif ins.op in ("convolution",):
            c.flops += 2.0 * _shape_elems_bytes(ins.shape_str)[0]
        elif ins.op in ("iota", "compare", "convert"):
            # one op per output element: the one-hot SpMV kernels build
            # (S, W) masks from broadcasted_iota + compare + convert, which
            # dominates their op count — leaving these at zero made the
            # one-hot path look bandwidth-bound when it is compute-bound
            c.flops += float(_shape_elems_bytes(ins.shape_str)[0])
        if not in_fusion and ins.op not in ("parameter", "constant",
                                            "get-tuple-element", "tuple",
                                            "bitcast"):
            opb = sum(shape_bytes(comp.shapes.get(o, "")) for o in
                      ins.operands)
            c.bytes += out_bytes + opb
        base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
        if base in _COLLECTIVES and not ins.op.endswith("-done"):
            c.collective_bytes += out_bytes
            c.collectives[base]["count"] += 1
            c.collectives[base]["bytes"] += out_bytes
        # recurse
        if ins.op == "while":
            bm = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
            cm = re.search(r"condition=%?([\w\.\-]+)", ins.attrs)
            if bm and bm.group(1) in comps:
                trips = 1
                if cm and cm.group(1) in comps:
                    trips = _trip_count(comps[cm.group(1)])
                c.add(_comp_cost(comps[bm.group(1)], comps, memo,
                                 in_fusion), trips)
        elif ins.op == "fusion":
            fm = re.search(r"calls=%?([\w\.\-]+)", ins.attrs)
            if fm and fm.group(1) in comps:
                c.add(_comp_cost(comps[fm.group(1)], comps, memo, True))
        elif ins.op in ("call", "conditional", "custom-call"):
            for mm in re.finditer(
                    r"(?:to_apply|branch_computations=\{|calls=)%?"
                    r"([\w\.\-]+)", ins.attrs):
                if mm.group(1) in comps:
                    c.add(_comp_cost(comps[mm.group(1)], comps, memo,
                                     in_fusion))
    memo[key] = c
    return c


def analyze_hlo(text: str) -> Costs:
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:           # fall back: biggest computation
        entry = max(comps.values(), key=lambda c: len(c.instrs))
    return _comp_cost(entry, comps, {}, in_fusion=False)
