"""The serving subsystem (docs/DESIGN.md §6).

  engine.py     continuous-batching engines: token generation
                (``ServingEngine``) and the paper's SpMV-as-a-service
                (``SpmvServingEngine``), which coalesces same-matrix
                requests into one multi-RHS SpMM per tick
  executor.py   pluggable execution behind a registered matrix:
                ``LocalExecutor`` (single-device SpmvOperator) and
                ``MeshExecutor`` (distributed strategies over mesh_p
                shards, artifacts shipped via the PlanCache npz layer)
  placement.py  plan resolution (local vs per-(matrix, p) mesh cache
                entries) and executor construction
"""
from .engine import (Request, ServingEngine, SpmvRequest, SpmvResult,
                     SpmvServingEngine)
from .executor import LocalExecutor, MeshExecutor, SpmvExecutor

__all__ = [
    "Request", "ServingEngine", "SpmvRequest", "SpmvResult",
    "SpmvServingEngine", "LocalExecutor", "MeshExecutor", "SpmvExecutor",
]
