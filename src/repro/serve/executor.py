"""Pluggable SpMV executors: how a registered matrix actually computes.

The serving engine (serve/engine.py) resolves an
:class:`~repro.core.plan.ExecutionPlan` per matrix and hands execution to
whichever executor the plan's ``strategy`` field names:

* :class:`LocalExecutor` — ``strategy='local'``: today's single-device
  :class:`~repro.kernels.ops.SpmvOperator`, schedule-cached through the
  PlanCache (zero pack/partition/coloring on a hit).

* :class:`MeshExecutor` — ``strategy='mesh'``: the paper's accumulation
  strategies across ``plan.mesh_p`` shards via
  :func:`~repro.core.distributed.build_sharded_spmv`.  Every structural
  artifact the mesh needs — the :class:`~repro.core.schedule.SpmvSchedule`
  (row partition) and the per-shard layout (``ShardedSlots`` /
  ``HaloLayout`` for segment shard-compute, the path's ShardSupport
  layouts — ``FlatShards``/``FlatHalo``, ``NnzSplitShards``/
  ``NnzSplitHalo`` — for kernel-backed paths) — is built through the
  schedule layer and, given a cache,
  served from / shipped to the PlanCache npz layer keyed by
  (fingerprint, value digest, p, strategy kind): a worker process
  re-registering a known matrix performs zero per-shard pack work.

Both executors expose the same three-method surface (``__call__``,
``update_values``, ``plan``), so the engine's coalesced multi-RHS step
path is executor-agnostic: a request batch is answered by one SpMM
through whichever executor the plan chose.

``update_values`` is the FEM time-stepping / model-refresh fast path on
either side: the local executor refreshes the schedule's value streams
(``BUILD_COUNTS['value_refresh']``), the mesh executor additionally
refreshes the shard layout's value streams
(``BUILD_COUNTS['shard_value_refresh']``) — no re-pack, no re-partition,
no re-coloring on either path.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.csrc import CSRC
from repro.core.plan import ExecutionPlan


class SpmvExecutor:
    """Executor surface the serving engine programs against."""

    kind: str = "abstract"
    plan: ExecutionPlan

    @property
    def path(self) -> str:
        """Shard-compute path of the plan (SpmvOperator API parity)."""
        return self.plan.path

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    def update_values(self, M: CSRC) -> "SpmvExecutor":
        raise NotImplementedError


class LocalExecutor(SpmvExecutor):
    """Single-device execution through a tuned SpmvOperator."""

    kind = "local"

    def __init__(self, M: CSRC, plan: ExecutionPlan, cache=None,
                 interpret: bool = True):
        from repro.kernels.ops import SpmvOperator
        self.M = M
        self.op = SpmvOperator.from_plan(M, plan, interpret=interpret,
                                         cache=cache)
        self.plan = self.op.plan

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        return self.op(x)

    def update_values(self, M: CSRC) -> "LocalExecutor":
        self.M = M
        self.op.update_values(M)
        return self

    @property
    def schedule(self):
        return self.op.schedule


class MeshExecutor(SpmvExecutor):
    """Distributed execution across ``plan.mesh_p`` shards.

    Construction materializes (or fetches from the cache's npz layer) the
    schedule and the per-shard layout, then compiles one shard_map'd
    apply through :func:`~repro.core.distributed.build_sharded_spmv` with
    the layout injected.  ``update_values`` refreshes value streams in
    place — schedule and layout — and recompiles the apply; the matrix
    structure, partition, halo geometry, and index streams never move.
    """

    kind = "mesh"

    def __init__(self, M: CSRC, plan: ExecutionPlan, mesh=None,
                 cache=None, interpret: bool = True, axis: str = "rows"):
        if plan.strategy != "mesh":
            raise ValueError(
                f"MeshExecutor needs a strategy='mesh' plan, got "
                f"{plan.key()}")
        p = plan.mesh_p
        if mesh is None:
            ndev = len(jax.devices())
            if ndev < p:
                raise ValueError(
                    f"plan {plan.key()} needs {p} devices, this process "
                    f"sees {ndev}; relaunch with XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={p} or "
                    "register a local plan")
            mesh = jax.make_mesh((p,), (axis,))
        self.plan = plan
        self.mesh = mesh
        self.axis = axis
        self.p = p
        self.cache = cache
        self.interpret = interpret
        from repro.core import paths as paths_mod
        self._sup = paths_mod.get_path(plan.path).shard_support
        self._sched = None
        self.layout = None
        self._structure_digest = None
        self._build(M)

    # the schedule artifact only supplies the row partition here; a
    # shard-supported plan ('flat', 'nnzsplit') builds its per-shard
    # sub-packs instead of the (unused) full-matrix pack, so the schedule
    # request is path-free
    def _sched_plan(self) -> ExecutionPlan:
        return (dataclasses.replace(self.plan, path="segment")
                if self._sup is not None else self.plan)

    def _build(self, M: CSRC):
        from repro.core import distributed as dist
        from repro.core import schedule as schedule_mod
        self.M = M
        self._structure_digest = schedule_mod.structure_digest(M)
        strat = self.plan.accumulation
        if strat == "halo":
            # halo geometry depends only on (matrix, p): no schedule needed
            self._sched = None
            if self._sup is not None:
                self.layout = schedule_mod.build_path_halo(
                    M, self.p, self.plan, cache=self.cache)
            else:
                self.layout = schedule_mod.build_halo_layout(
                    M, self.p, cache=self.cache)
        else:
            self._sched = schedule_mod.schedule_for(
                M, self._sched_plan(), cache=self.cache, p=self.p)
            part = self._sched.partition
            if self._sup is not None:
                self.layout = schedule_mod.build_path_shards(
                    M, part, self.plan, cache=self.cache)
            else:
                self.layout = schedule_mod.build_sharded_slots(
                    M, part, cache=self.cache)
        self._fn = dist.build_sharded_spmv(
            M, self.mesh, self.axis, strategy=strat, schedule=self._sched,
            cache=self.cache, plan=self.plan, interpret=self.interpret,
            layout=self.layout)

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        # reduce_scatter pads y to p equal intervals; serve the true rows
        return self._fn(x)[:self.M.n]

    def update_values(self, M: CSRC) -> "MeshExecutor":
        """Same-structure value refresh on the mesh: schedule value
        streams (via the cache's structure-digest fast path) and shard
        layout value streams are rewritten; partition, halo geometry, and
        index streams are reused untouched.  Raises ValueError when the
        structure actually differs (same contract as the local path's
        ``refresh_schedule``) — the shard layouts can only be value-
        refilled against the slot order they were built for."""
        from repro.core import distributed as dist
        from repro.core import schedule as schedule_mod
        if schedule_mod.structure_digest(M) != self._structure_digest:
            raise ValueError(
                "MeshExecutor.update_values: matrix structure differs "
                "from the registered one; re-register for a full rebuild")
        part = None
        if self._sched is not None:
            if self.cache is not None:
                self._sched = schedule_mod.schedule_for(
                    M, self._sched_plan(), cache=self.cache, p=self.p)
            else:
                self._sched = schedule_mod.refresh_schedule(self._sched, M)
            part = self._sched.partition
        self.layout = schedule_mod.refresh_shard_layout(
            self.layout, M, part=part)
        self.M = M
        self._fn = dist.build_sharded_spmv(
            M, self.mesh, self.axis, strategy=self.plan.accumulation,
            schedule=self._sched, cache=self.cache, plan=self.plan,
            interpret=self.interpret, layout=self.layout)
        return self

    @property
    def schedule(self):
        return self._sched
