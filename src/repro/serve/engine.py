"""Batched continuous serving engines.

Two engines share the continuous-batching discipline:

* ``ServingEngine`` — token generation.  Fixed-slot batching (the standard
  TPU serving shape discipline): the decode step always runs at
  (max_slots, 1); finished or empty slots hold padding.  Requests are
  admitted into free slots between steps, prefill fills the slot's cache
  region, greedy/temperature sampling produces tokens until EOS or
  max_new_tokens.

* ``SpmvServingEngine`` — the paper's workload as a service: clients
  submit (matrix_id, x) products; matrices are registered once and get an
  :class:`ExecutionPlan` from the plan-cache/tuner (a cache hit means a
  known matrix class is never re-tuned), and each tick answers all pending
  requests per matrix with one batched multi-RHS product through a
  pluggable :class:`~repro.serve.executor.SpmvExecutor` — single-device
  (``LocalExecutor``) or distributed across a mesh (``MeshExecutor``),
  chosen by the plan's ``strategy``/``mesh_p`` fields
  (serve/placement.py).

The decode step is the same function the launch layer lowers for the
256-chip serve dry-run.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0      # 0 => greedy
    out_tokens: Optional[List[int]] = None


class ServingEngine:
    def __init__(self, model, params, max_slots: int, max_len: int,
                 eos_id: int = 1, seed: int = 0):
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.key = jax.random.PRNGKey(seed)
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}       # slot -> request
        self.remaining: Dict[int, int] = {}
        # one decode state per slot (batch=1 states merged by stacking would
        # complicate ring caches; slots are independent for clarity)
        self._states: Dict[int, object] = {}
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill,
                                static_argnames=("max_len",))

    def submit(self, req: Request):
        req.out_tokens = []
        self.queue.append(req)

    def _admit(self):
        """Admit queued requests into free slots; returns the requests
        that finished at prefill (EOS straight from the prompt, or a
        one-token budget) — those never occupy a decode slot."""
        finished = []
        for slot in range(self.max_slots):
            if slot in self.active or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]
            state, logits = self._prefill(self.params, prompt,
                                          max_len=self.max_len)
            tok = self._sample(logits[:, -1], req.temperature)
            req.out_tokens.append(int(tok[0]))
            # the prefill token counts toward max_new_tokens; retire here
            # when it is EOS or exhausts the budget, instead of burning a
            # decode tick on an already-finished request
            if int(tok[0]) == self.eos_id or req.max_new_tokens <= 1:
                finished.append(req)
                continue
            self.active[slot] = req
            self.remaining[slot] = req.max_new_tokens - 1
            self._states[slot] = (state, tok)
        return finished

    def _sample(self, logits, temperature: float):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, k = jax.random.split(self.key)
        return jax.random.categorical(
            k, logits.astype(jnp.float32) / temperature, axis=-1
        ).astype(jnp.int32)

    def step(self):
        """One engine tick: admit, decode every active slot, retire."""
        finished = self._admit()
        done = []
        for slot, req in self.active.items():
            state, last_tok = self._states[slot]
            state, logits = self._decode(self.params, state,
                                         last_tok[:, None])
            tok = self._sample(logits[:, 0], req.temperature)
            req.out_tokens.append(int(tok[0]))
            self._states[slot] = (state, tok)
            self.remaining[slot] -= 1
            if int(tok[0]) == self.eos_id or self.remaining[slot] <= 0:
                done.append(slot)
        for slot in done:
            finished.append(self.active.pop(slot))
            self._states.pop(slot)
            self.remaining.pop(slot)
        return finished

    def run_until_drained(self, max_ticks: int = 1000):
        out = []
        for _ in range(max_ticks):
            out.extend(self.step())
            if not self.queue and not self.active:
                break
        return out


# ---------------------------------------------------------------------------
# SpMV serving (the paper's kernel as a traffic-serving endpoint)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SpmvRequest:
    uid: int
    matrix_id: str
    x: np.ndarray
    t_submit: float = 0.0         # perf_counter at submit (0 = unknown)


class SpmvResult(np.ndarray):
    """A served y = A·x with the metadata benchmarks need to attribute
    latency to the chosen path: behaves exactly like the float32 result
    array (ndarray subclass), plus

      matrix_id   the registered matrix the request hit
      plan_key    ExecutionPlan.key() of the plan that served it
      path        shard-compute path ('kernel'/'flat'/'segment'/...)
      strategy    'local' or 'mesh'
      mesh_p      shard count (1 for local)
      executor    executor kind that ran it
      batched     how many requests shared the coalesced SpMM
      timings     {'queue_wait_s', 'execute_s'} for this request (None
                  when the engine was constructed before timing landed)
    """

    _META = ("matrix_id", "plan_key", "path", "strategy", "mesh_p",
             "executor", "batched", "timings")

    def __array_finalize__(self, obj):
        for k in self._META:
            setattr(self, k, getattr(obj, k, None))

    def meta(self) -> Dict[str, object]:
        return {k: getattr(self, k, None) for k in self._META}


class SpmvServingEngine:
    """Continuous-batching SpMV service over tuned execution plans.

    ``register`` resolves the matrix's plan through the shared plan cache
    (``autotune=True`` measures candidates on a miss; a hit — e.g. a second
    matrix of an already-served class — constructs the executor with zero
    measurements) and backs it with a pluggable executor
    (serve/executor.py): ``strategy='local'`` plans run today's
    single-device SpmvOperator, ``strategy='mesh'`` plans run the
    distributed strategies across ``plan.mesh_p`` shards, with every
    schedule / shard-layout artifact served from (and shipped through)
    the PlanCache npz layer — re-registering a known matrix performs zero
    pack/partition/coloring work on either path.  Construct with
    ``mesh_p=N`` to prefer the per-(matrix, p) distributed cache entries
    when the process has N devices (placement degrades to local
    otherwise).  ``step`` groups the queue by matrix and answers each
    group with **one batched multi-RHS SpMM** through the chosen
    executor — never a loop of single products; results are
    :class:`SpmvResult` arrays carrying the plan/strategy metadata.
    """

    def __init__(self, cache=None, autotune: bool = False,
                 interpret: bool = True, max_batch: int = 64,
                 mesh_p: Optional[int] = None,
                 serve_nrhs: Optional[int] = None):
        from repro.core.tuner import PlanCache
        self.cache = cache if cache is not None else PlanCache()
        self.autotune = autotune
        self.interpret = interpret
        self.max_batch = max_batch
        self.mesh_p = mesh_p
        # the batched operating point registration tunes at: coalesced
        # groups run as (n, B) SpMM blocks, so the plan must be measured
        # at a representative B, not at nrhs=1 (capped at 8: per-column
        # time flattens once the RHS block amortizes the value streams)
        self.serve_nrhs = (serve_nrhs if serve_nrhs is not None
                           else min(max_batch, 8))
        self._matrices: Dict[str, object] = {}
        self._ops: Dict[str, object] = {}
        self.queue: List[SpmvRequest] = []
        self._uid = 0

    def register(self, matrix_id: str, M, plan=None):
        """Install a matrix; returns the ExecutionPlan it will run with.

        The plan resolves through placement (mesh entry when the engine
        has a mesh width and the process the devices; local otherwise) —
        or is pinned by the explicit ``plan`` argument.  Registering a
        matrix whose *structure* is already known to the cache (FEM time
        stepping: same connectivity, re-assembled values) takes the
        value-refresh fast path through ``schedule_for`` — the plan is a
        fingerprint hit and the schedule only refreshes value streams,
        zero re-pack/re-partition/re-coloring (the ``BUILD_COUNTS`` probe
        asserts it).
        """
        from . import placement
        if plan is None:
            plan = placement.resolve_plan(
                M, cache=self.cache, autotune=self.autotune,
                interpret=self.interpret, mesh_p=self.mesh_p,
                nrhs=self.serve_nrhs)
        self._matrices[matrix_id] = M
        self._ops[matrix_id] = placement.build_executor(
            M, plan, cache=self.cache, interpret=self.interpret)
        return plan

    def update_values(self, matrix_id: str, M):
        """In-place value refresh of a registered matrix (structure must
        be unchanged): the executor swaps the value streams without any
        structural rebuild — on the mesh path this refreshes the shipped
        shard layouts too (``BUILD_COUNTS['shard_value_refresh']``)."""
        if matrix_id not in self._ops:
            raise KeyError(f"matrix {matrix_id!r} not registered")
        self._matrices[matrix_id] = M
        self._ops[matrix_id].update_values(M)
        return self._ops[matrix_id].plan

    def plan(self, matrix_id: str):
        return self._ops[matrix_id].plan

    def executor(self, matrix_id: str):
        return self._ops[matrix_id]

    def submit(self, matrix_id: str, x: np.ndarray) -> int:
        if matrix_id not in self._ops:
            raise KeyError(f"matrix {matrix_id!r} not registered")
        x = np.asarray(x, dtype=np.float32)
        m = self._matrices[matrix_id].m
        if x.shape != (m,):
            # out-of-range gathers clamp silently in jax; reject early
            raise ValueError(
                f"x has shape {x.shape}, matrix {matrix_id!r} needs ({m},)")
        uid = self._uid
        self._uid += 1
        obs.counter("serve_requests_total", matrix_id=matrix_id).inc()
        self.queue.append(SpmvRequest(uid=uid, matrix_id=matrix_id, x=x,
                                      t_submit=time.perf_counter()))
        return uid

    def _wrap(self, y, matrix_id: str, batched: int,
              timings=None) -> SpmvResult:
        """Attach per-request plan/strategy metadata to a result array."""
        ex = self._ops[matrix_id]
        plan = getattr(ex, "plan", None)
        r = np.ascontiguousarray(np.asarray(y)).view(SpmvResult)
        r.matrix_id = matrix_id
        r.plan_key = plan.key() if plan is not None else None
        r.path = getattr(plan, "path", None)
        r.strategy = getattr(plan, "strategy", "local")
        r.mesh_p = getattr(plan, "mesh_p", 1)
        r.executor = getattr(ex, "kind", "local")
        r.batched = batched
        r.timings = timings
        return r

    def step(self) -> Dict[int, SpmvResult]:
        """One tick: answer up to max_batch requests per matrix, each group
        coalesced into a single batched SpMM through the chosen executor
        (every registered path executes blocks natively, locally or on
        the mesh)."""
        t_tick = time.perf_counter()
        by_matrix: Dict[str, List[SpmvRequest]] = {}
        rest: List[SpmvRequest] = []
        for r in self.queue:
            grp = by_matrix.setdefault(r.matrix_id, [])
            if len(grp) < self.max_batch:
                grp.append(r)
            else:
                rest.append(r)
        self.queue = rest
        out: Dict[int, SpmvResult] = {}
        with obs.span("serve.tick", groups=len(by_matrix)):
            for mid, group in by_matrix.items():
                op = self._ops[mid]
                plan = getattr(op, "plan", None)
                t0 = time.perf_counter()
                if len(group) == 1:
                    Y = np.asarray(op(jnp.asarray(group[0].x)))
                else:
                    X = jnp.asarray(np.stack([r.x for r in group], axis=1))
                    Y = np.asarray(op(X))
                dt = time.perf_counter() - t0
                if obs.STATE.enabled:
                    lbl = dict(matrix_id=mid,
                               path=getattr(plan, "path", None),
                               variant=getattr(plan, "variant", None),
                               strategy=getattr(plan, "strategy", "local"),
                               nrhs=len(group))
                    obs.histogram("serve_execute_seconds",
                                  **lbl).observe(dt)
                    obs.histogram("serve_batch_size",
                                  _buckets=obs.log_buckets(1.0, 1024.0, 2),
                                  matrix_id=mid).observe(len(group))
                    for r in group:
                        if r.t_submit:
                            obs.histogram(
                                "serve_queue_wait_seconds", matrix_id=mid,
                            ).observe(max(0.0, t0 - r.t_submit))
                if len(group) == 1:
                    timings = {"queue_wait_s":
                               (max(0.0, t0 - group[0].t_submit)
                                if group[0].t_submit else None),
                               "execute_s": dt}
                    out[group[0].uid] = self._wrap(Y, mid, batched=1,
                                                   timings=timings)
                else:
                    for i, r in enumerate(group):
                        timings = {"queue_wait_s":
                                   (max(0.0, t0 - r.t_submit)
                                    if r.t_submit else None),
                                   "execute_s": dt}
                        out[r.uid] = self._wrap(Y[:, i], mid,
                                                batched=len(group),
                                                timings=timings)
        if obs.STATE.enabled:
            obs.histogram("serve_tick_seconds").observe(
                time.perf_counter() - t_tick)
        return out

    def run_until_drained(self, max_ticks: int = 1000) -> Dict[int, SpmvResult]:
        out: Dict[int, SpmvResult] = {}
        for _ in range(max_ticks):
            if not self.queue:
                break
            out.update(self.step())
        return out
