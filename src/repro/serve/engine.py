"""Batched continuous serving engines.

Two engines share the continuous-batching discipline:

* ``ServingEngine`` — token generation.  Fixed-slot batching (the standard
  TPU serving shape discipline): the decode step always runs at
  (max_slots, 1); finished or empty slots hold padding.  Requests are
  admitted into free slots between steps, prefill fills the slot's cache
  region, greedy/temperature sampling produces tokens until EOS or
  max_new_tokens.

* ``SpmvServingEngine`` — the paper's workload as a service: clients
  submit (matrix_id, x) products; matrices are registered once and get an
  :class:`ExecutionPlan` from the plan-cache/tuner (a cache hit means a
  known matrix class is never re-tuned), and each tick answers all pending
  requests per matrix with one batched multi-RHS product.

Single-chip CPU execution here; the decode step is the same function the
launch layer lowers for the 256-chip serve dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0      # 0 => greedy
    out_tokens: Optional[List[int]] = None


class ServingEngine:
    def __init__(self, model, params, max_slots: int, max_len: int,
                 eos_id: int = 1, seed: int = 0):
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.key = jax.random.PRNGKey(seed)
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}       # slot -> request
        self.remaining: Dict[int, int] = {}
        # one decode state per slot (batch=1 states merged by stacking would
        # complicate ring caches; slots are independent for clarity)
        self._states: Dict[int, object] = {}
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill,
                                static_argnames=("max_len",))

    def submit(self, req: Request):
        req.out_tokens = []
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.max_slots):
            if slot in self.active or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]
            state, logits = self._prefill(self.params, prompt,
                                          max_len=self.max_len)
            tok = self._sample(logits[:, -1], req.temperature)
            req.out_tokens.append(int(tok[0]))
            self.active[slot] = req
            self.remaining[slot] = req.max_new_tokens - 1
            self._states[slot] = (state, tok)

    def _sample(self, logits, temperature: float):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, k = jax.random.split(self.key)
        return jax.random.categorical(
            k, logits.astype(jnp.float32) / temperature, axis=-1
        ).astype(jnp.int32)

    def step(self):
        """One engine tick: admit, decode every active slot, retire."""
        self._admit()
        done = []
        for slot, req in self.active.items():
            state, last_tok = self._states[slot]
            state, logits = self._decode(self.params, state,
                                         last_tok[:, None])
            tok = self._sample(logits[:, 0], req.temperature)
            req.out_tokens.append(int(tok[0]))
            self._states[slot] = (state, tok)
            self.remaining[slot] -= 1
            if int(tok[0]) == self.eos_id or self.remaining[slot] <= 0:
                done.append(slot)
        finished = []
        for slot in done:
            finished.append(self.active.pop(slot))
            self._states.pop(slot)
            self.remaining.pop(slot)
        return finished

    def run_until_drained(self, max_ticks: int = 1000):
        out = []
        for _ in range(max_ticks):
            out.extend(self.step())
            if not self.queue and not self.active:
                break
        return out


# ---------------------------------------------------------------------------
# SpMV serving (the paper's kernel as a traffic-serving endpoint)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SpmvRequest:
    uid: int
    matrix_id: str
    x: np.ndarray


class SpmvServingEngine:
    """Continuous-batching SpMV service over tuned execution plans.

    ``register`` resolves the matrix's plan through the shared plan cache
    (``autotune=True`` measures candidates on a miss; a hit — e.g. a second
    matrix of an already-served class — constructs the operator with zero
    measurements) and reuses the schedule artifact stored next to the plan
    (core/schedule.py): re-registering a known matrix performs zero
    pack/partition/coloring work.  Plans resolve through the KernelPath
    registry, so every registered path — including 'flat' for skewed
    matrices — is servable with no engine changes.  ``step`` groups the
    queue by matrix and answers each group with **one batched multi-RHS
    SpMM** through the operator's tuned path — never a loop of single
    products.
    """

    def __init__(self, cache=None, autotune: bool = False,
                 interpret: bool = True, max_batch: int = 64):
        from repro.core.tuner import PlanCache
        self.cache = cache if cache is not None else PlanCache()
        self.autotune = autotune
        self.interpret = interpret
        self.max_batch = max_batch
        self._matrices: Dict[str, object] = {}
        self._ops: Dict[str, object] = {}
        self.queue: List[SpmvRequest] = []
        self._uid = 0

    def register(self, matrix_id: str, M):
        """Install a matrix; returns the ExecutionPlan it will run with.

        Registering a matrix whose *structure* is already known to the
        cache (FEM time stepping: same connectivity, re-assembled values)
        takes the value-refresh fast path through ``schedule_for`` — the
        plan is a fingerprint hit and the schedule only refreshes value
        streams, zero re-pack/re-partition/re-coloring (the
        ``BUILD_COUNTS`` probe asserts it).
        """
        from repro.core import tuner as _tuner
        from repro.kernels.ops import SpmvOperator
        plan = _tuner.plan_for(M, cache=self.cache, autotune=self.autotune,
                               interpret=self.interpret)
        self._matrices[matrix_id] = M
        self._ops[matrix_id] = SpmvOperator.from_plan(
            M, plan, interpret=self.interpret, cache=self.cache)
        return plan

    def update_values(self, matrix_id: str, M):
        """In-place value refresh of a registered matrix (structure must
        be unchanged): ``SpmvOperator.update_values`` swaps the value
        streams without any structural rebuild."""
        if matrix_id not in self._ops:
            raise KeyError(f"matrix {matrix_id!r} not registered")
        self._matrices[matrix_id] = M
        self._ops[matrix_id].update_values(M)
        return self._ops[matrix_id].plan

    def plan(self, matrix_id: str):
        return self._ops[matrix_id].plan

    def submit(self, matrix_id: str, x: np.ndarray) -> int:
        if matrix_id not in self._ops:
            raise KeyError(f"matrix {matrix_id!r} not registered")
        x = np.asarray(x, dtype=np.float32)
        m = self._matrices[matrix_id].m
        if x.shape != (m,):
            # out-of-range gathers clamp silently in jax; reject early
            raise ValueError(
                f"x has shape {x.shape}, matrix {matrix_id!r} needs ({m},)")
        uid = self._uid
        self._uid += 1
        self.queue.append(SpmvRequest(uid=uid, matrix_id=matrix_id, x=x))
        return uid

    def step(self) -> Dict[int, np.ndarray]:
        """One tick: answer up to max_batch requests per matrix, each group
        coalesced into a single batched SpMM through the tuned operator
        (kernel, segment, and colorful paths all execute blocks natively)."""
        by_matrix: Dict[str, List[SpmvRequest]] = {}
        rest: List[SpmvRequest] = []
        for r in self.queue:
            grp = by_matrix.setdefault(r.matrix_id, [])
            if len(grp) < self.max_batch:
                grp.append(r)
            else:
                rest.append(r)
        self.queue = rest
        out: Dict[int, np.ndarray] = {}
        for mid, group in by_matrix.items():
            op = self._ops[mid]
            if len(group) == 1:
                out[group[0].uid] = np.asarray(op(jnp.asarray(group[0].x)))
            else:
                X = jnp.asarray(np.stack([r.x for r in group], axis=1))
                Y = np.asarray(op(X))
                for i, r in enumerate(group):
                    out[r.uid] = Y[:, i]
        return out

    def run_until_drained(self, max_ticks: int = 1000) -> Dict[int, np.ndarray]:
        out: Dict[int, np.ndarray] = {}
        for _ in range(max_ticks):
            if not self.queue:
                break
            out.update(self.step())
        return out
