"""Batched continuous serving engine.

Fixed-slot batching (the standard TPU serving shape discipline): the decode
step always runs at (max_slots, 1); finished or empty slots hold padding.
Requests are admitted into free slots between steps (continuous batching),
prefill fills the slot's cache region, greedy/temperature sampling produces
tokens until EOS or max_new_tokens.

Single-chip CPU execution here; the decode step is the same function the
launch layer lowers for the 256-chip serve dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0      # 0 => greedy
    out_tokens: Optional[List[int]] = None


class ServingEngine:
    def __init__(self, model, params, max_slots: int, max_len: int,
                 eos_id: int = 1, seed: int = 0):
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.key = jax.random.PRNGKey(seed)
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}       # slot -> request
        self.remaining: Dict[int, int] = {}
        # one decode state per slot (batch=1 states merged by stacking would
        # complicate ring caches; slots are independent for clarity)
        self._states: Dict[int, object] = {}
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill,
                                static_argnames=("max_len",))

    def submit(self, req: Request):
        req.out_tokens = []
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.max_slots):
            if slot in self.active or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]
            state, logits = self._prefill(self.params, prompt,
                                          max_len=self.max_len)
            tok = self._sample(logits[:, -1], req.temperature)
            req.out_tokens.append(int(tok[0]))
            self.active[slot] = req
            self.remaining[slot] = req.max_new_tokens - 1
            self._states[slot] = (state, tok)

    def _sample(self, logits, temperature: float):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, k = jax.random.split(self.key)
        return jax.random.categorical(
            k, logits.astype(jnp.float32) / temperature, axis=-1
        ).astype(jnp.int32)

    def step(self):
        """One engine tick: admit, decode every active slot, retire."""
        self._admit()
        done = []
        for slot, req in self.active.items():
            state, last_tok = self._states[slot]
            state, logits = self._decode(self.params, state,
                                         last_tok[:, None])
            tok = self._sample(logits[:, 0], req.temperature)
            req.out_tokens.append(int(tok[0]))
            self._states[slot] = (state, tok)
            self.remaining[slot] -= 1
            if int(tok[0]) == self.eos_id or self.remaining[slot] <= 0:
                done.append(slot)
        finished = []
        for slot in done:
            finished.append(self.active.pop(slot))
            self._states.pop(slot)
            self.remaining.pop(slot)
        return finished

    def run_until_drained(self, max_ticks: int = 1000):
        out = []
        for _ in range(max_ticks):
            out.extend(self.step())
            if not self.queue and not self.active:
                break
        return out
