"""Placement: which executor (and which plan) serves a registered matrix.

One decision point for the serving engine:

* ``resolve_plan`` — the plan a matrix will run with.  With a requested
  mesh width it consults the per-(matrix, p) mesh entries of the plan
  cache (``tuner.mesh_plan_for``: cache hit > measured ``tune_mesh`` when
  autotuning > collective-bytes heuristic); without one — or when the
  process cannot see enough devices — it degrades to the local entries
  (``tuner.plan_for``).  Either way the decision is cached, so it is
  stable across engines and processes.

* ``build_executor`` — the executor for a resolved plan:
  ``strategy='mesh'`` plans get a :class:`~repro.serve.executor.
  MeshExecutor` over a ``plan.mesh_p``-wide mesh, everything else a
  :class:`~repro.serve.executor.LocalExecutor`.

Device counts are locked at first jax init: a CPU host serves meshes only
when launched with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
(the 8-device CI smoke job and examples/serve_mesh.py do exactly that).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.csrc import CSRC
from repro.core.plan import ExecutionPlan

from .executor import LocalExecutor, MeshExecutor, SpmvExecutor


def device_count() -> int:
    return len(jax.devices())


def mesh_available(p: Optional[int]) -> bool:
    return p is not None and p >= 1 and device_count() >= p


def resolve_plan(M: CSRC, cache=None, autotune: bool = False,
                 interpret: bool = True,
                 mesh_p: Optional[int] = None,
                 nrhs: int = 1) -> ExecutionPlan:
    """The plan to serve this matrix with, honoring a mesh request when
    the process can satisfy it and falling back to local otherwise.
    Rectangular matrices always resolve locally — the distributed
    strategies shard square rows only.

    ``nrhs`` > 1 is the engine's batched operating point: autotuning then
    measures every candidate at nrhs=1 *and* at that block width (argmin
    on per-column time), so the cached winner is tuned for the coalesced
    SpMM the engine actually issues — the winning ``plan.nrhs`` records
    the width it was tuned at."""
    from repro.core import tuner
    tune_kw = {}
    if autotune and nrhs > 1:
        tune_kw["nrhs_options"] = (1, nrhs)
    if mesh_p is not None and mesh_available(mesh_p) and M.is_square:
        return tuner.mesh_plan_for(M, mesh_p, cache=cache,
                                   autotune=autotune, interpret=interpret,
                                   **tune_kw)
    return tuner.plan_for(M, cache=cache, autotune=autotune,
                          interpret=interpret, **tune_kw)


def build_executor(M: CSRC, plan: ExecutionPlan, cache=None,
                   interpret: bool = True, mesh=None,
                   axis: str = "rows") -> SpmvExecutor:
    """Executor for a resolved plan (strategy field dispatch)."""
    if plan.strategy == "mesh":
        return MeshExecutor(M, plan, mesh=mesh, cache=cache,
                            interpret=interpret, axis=axis)
    return LocalExecutor(M, plan, cache=cache, interpret=interpret)
