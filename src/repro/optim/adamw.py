"""AdamW with global-norm clipping and cosine/linear-warmup schedule.

Self-contained (no optax in the container).  Moments are fp32 regardless of
parameter dtype; ``moment_dtype='bfloat16'`` halves optimizer HBM (a
memory-roofline lever recorded in §Perf).  Optimizer state shards like the
parameters (ZeRO-1 comes from the FSDP param specs propagating to the
moment pytrees).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    lr_min_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"     # float32 | bfloat16


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: object
    v: object


def lr_at(cfg: AdamWConfig, step):
    warm = cfg.lr_peak * (step + 1) / max(1, cfg.warmup_steps)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.lr_min_ratio + (1 - cfg.lr_min_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr_peak * cos)


def init(cfg: AdamWConfig, params) -> AdamWState:
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(cfg: AdamWConfig, state: AdamWState, params, grads):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, state.step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step=step, m=new_m, v=new_v), metrics
