"""Gradient compression utilities (distributed-optimization tricks).

Two mechanisms, each the paper's "reduce the bytes of the accumulation
step" idea applied to training state instead of SpMV buffers:

  * ``ef_accumulate`` — bf16 gradient-accumulation across microbatches with
    an fp32 error-feedback residual: halves accumulation-buffer HBM traffic
    while keeping the summed gradient unbiased to fp32 over time;
  * ``compressed_psum`` — explicit shard_map all-reduce in bf16 (or int8
    with per-tensor scale) for DP gradient reduction when the training step
    is expressed with explicit collectives.  With pjit/GSPMD the backward
    reduce-scatter is XLA-inserted and keeps the grad dtype — so the lever
    there is casting grads to bf16 *before* the optimizer (see train/step).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def ef_accumulate(acc_bf16, residual_f32, grad):
    """One error-feedback accumulation step.

    acc_bf16: running sum (bf16); residual_f32: fp32 error carry;
    grad: new fp32/bf16 microbatch gradient.
    Returns (new_acc, new_residual).
    """
    def one(a, r, g):
        want = r + g.astype(jnp.float32)
        new_a = (a.astype(jnp.float32) + want).astype(jnp.bfloat16)
        new_r = want - (new_a.astype(jnp.float32) - a.astype(jnp.float32))
        return new_a, new_r
    flat_a, td = jax.tree.flatten(acc_bf16)
    flat_r = jax.tree.leaves(residual_f32)
    flat_g = jax.tree.leaves(grad)
    out = [one(a, r, g) for a, r, g in zip(flat_a, flat_r, flat_g)]
    return (jax.tree.unflatten(td, [o[0] for o in out]),
            jax.tree.unflatten(td, [o[1] for o in out]))


def compressed_psum(tree, axis_name: str, mode: str = "bfloat16"):
    """All-reduce a pytree across a shard_map axis with on-the-wire
    compression.  bf16 halves collective bytes; int8 quarters them with
    per-tensor max-abs scaling (scale itself psum_max'ed first)."""
    if mode == "float32":
        return jax.tree.map(lambda g: jax.lax.psum(g, axis_name), tree)
    if mode == "bfloat16":
        def one(g):
            s = jax.lax.psum(g.astype(jnp.bfloat16), axis_name)
            return s.astype(g.dtype)
        return jax.tree.map(one, tree)
    if mode == "int8":
        def one(g):
            amax = jnp.max(jnp.abs(g)).astype(jnp.float32)
            amax = jax.lax.pmax(amax, axis_name)
            scale = jnp.where(amax > 0, amax / 127.0, 1.0)
            q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale),
                         -127, 127).astype(jnp.int8)
            s = jax.lax.psum(q.astype(jnp.int32), axis_name)
            return (s.astype(jnp.float32) * scale).astype(g.dtype)
        return jax.tree.map(one, tree)
    raise ValueError(mode)
