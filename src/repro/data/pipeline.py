"""Deterministic, resumable, shard-aware synthetic data pipeline.

The batch at step ``t`` is a pure function of (seed, t) — a counted PRNG
stream.  This is the property that makes checkpoint/restart exact (restoring
``step`` restores the stream; no iterator state to save) and elastic
restarts trivial (a host computes exactly its shard of any step's batch).

No external corpora exist in this container; the synthetic stream generates
Zipf-ish token ids so losses are non-degenerate.  The interface (batch_at,
shard_slice) is what a real corpus-backed pipeline would implement.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 0
    input_mode: str = "tokens"     # tokens | embeds
    d_model: int = 0               # for embeds mode
    dtype: str = "bfloat16"


class TokenPipeline:
    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        """Global batch for a step (pure function of step)."""
        c = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(c.seed), step)
        # Zipf-ish marginal: exponentiate a uniform to concentrate mass
        u = jax.random.uniform(key, (c.global_batch, c.seq_len + 1))
        tokens = jnp.minimum(
            (u ** 4.0 * c.vocab).astype(jnp.int32), c.vocab - 1)
        batch = {"targets": tokens[:, 1:]}
        if c.input_mode == "tokens":
            batch["inputs"] = tokens[:, :-1]
        else:
            ekey = jax.random.fold_in(key, 1)
            batch["inputs"] = jax.random.normal(
                ekey, (c.global_batch, c.seq_len, c.d_model),
                jnp.bfloat16 if c.dtype == "bfloat16" else jnp.float32)
        return batch

    def shard_slice(self, step: int, shard: int, num_shards: int
                    ) -> Dict[str, jnp.ndarray]:
        """The rows of step ``step`` owned by data shard ``shard`` — what a
        multi-host deployment feeds each host (identical content regardless
        of num_shards, so elastic restarts keep the stream)."""
        full = self.batch_at(step)
        b = self.cfg.global_batch
        assert b % num_shards == 0
        lo = b // num_shards * shard
        hi = lo + b // num_shards
        return jax.tree.map(lambda a: a[lo:hi], full)


def pipeline_for_model(cfg, global_batch: int, seq_len: int,
                       seed: int = 0) -> TokenPipeline:
    """Build a pipeline matching a ModelConfig (handles embeds-mode stubs)."""
    return TokenPipeline(PipelineConfig(
        vocab=cfg.vocab, global_batch=global_batch, seq_len=seq_len,
        seed=seed, input_mode=cfg.input_mode, d_model=cfg.d_model,
        dtype=cfg.dtype))
