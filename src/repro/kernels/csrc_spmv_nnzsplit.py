"""Nnz-split (merge-style) CSRC SpMV/SpMM kernels for unstructured matrices.

Every other registered path assumes band-ish structure: the windowed paths
(kernel, flat) pad a per-tile column window that explodes when ``ja``
spreads across the full index range, and row-based balancing loses when
the nnz-per-row distribution is heavy-tailed (power-law graphs: one hub
row can outweigh a thousand others).  This module is the CSRC analogue of
merge/nonzero-split CSR SpMV: work is balanced over *non-zeros*, not rows.

Layout.  The symmetric storage is first expanded into one combined
scatter stream of K = 2k entries — lower slot p at (i, j) contributes
(dest=i, src=j, val=al[p]) and its transpose partner (dest=j, src=i,
val=au[p]) — stably sorted by ``dest``.  The stream is cut into
equal-size chunks of S = ks·128 entries regardless of row boundaries
(rows may span chunks).  Each chunk c covers a contiguous row interval
starting at ``chunk_row0[c]``; per entry we store the chunk-local row
``lrow = dest - chunk_row0[c]`` (bounded by the chunk's row span, padded
to ``r_pad``) and the global gather index ``src``.

Execution.  ``x[src]`` is gathered outside the kernel (a single
contiguous stream read; unstructured matrices have no window to exploit,
so an in-kernel one-hot gather would be O(S·n)).  The Pallas grid is 1-D
over chunks; each program reduces its S products into an ``r_pad``-wide
partial row vector with one one-hot matmul (MXU-friendly, no in-kernel
scatter) and writes its own output row — no cross-program accumulation,
so no first-of-tile bookkeeping.  A host-side fix-up pass scatter-adds
the per-chunk partials at ``chunk_row0[c] + r`` — rows split across a
chunk boundary are merged here — and the diagonal term closes the
product.  All float32 sums are plain adds, so for dyadic values the
result is bit-identical to any other summation order (the tests compare
against the dense oracle with assert_array_equal).

Shard layouts for the distributed strategies mirror the flat path's:
``NnzSplitShards`` keeps global coordinates and partitions the combined
stream by dest ownership (allreduce / reduce_scatter — each shard emits a
full-length partial y), ``NnzSplitHalo`` assigns both halves of a slot to
the shard owning its *row* and rebases coordinates into the local
[r0-h, r1) frame of the halo exchange.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.sharding import PartitionSpec as P

from repro.core.csrc import CSRC, bandwidth, row_of_slot
from repro.core.blockell import _round_up


def _combined_stream(M: CSRC):
    """The dest-sorted scatter stream of the square symmetric part."""
    ros = row_of_slot(M).astype(np.int64)
    ja = np.asarray(M.ja, dtype=np.int64)
    dest = np.concatenate([ros, ja])
    src = np.concatenate([ja, ros])
    val = np.concatenate([np.asarray(M.al, dtype=np.float32),
                          np.asarray(M.au, dtype=np.float32)])
    order = np.argsort(dest, kind="stable")   # deterministic: value refresh
    return dest[order], src[order], val[order]   # re-derives the same order


def _chunk_arrays(dest, src, val, *, ks: int, num_chunks=None, r_pad=None):
    """Cut one dest-sorted stream into equal-S chunks.

    ``num_chunks`` / ``r_pad`` force the geometry (used to equalize shapes
    across shards); padding entries carry val=0 on the stream's last real
    row, so they add exact zeros.  Returns the per-chunk numpy arrays.
    """
    s = ks * 128
    kk = int(dest.shape[0])
    need = max(1, -(-kk // s))
    nc = need if num_chunks is None else int(num_chunks)
    if nc < need:
        raise ValueError(f"num_chunks {nc} < required {need}")
    pad = nc * s - kk
    fill_dest = int(dest[-1]) if kk else 0
    dest = np.concatenate([dest, np.full(pad, fill_dest, np.int64)])
    src = np.concatenate([src, np.zeros(pad, np.int64)])
    val = np.concatenate([val, np.zeros(pad, np.float32)])
    dest = dest.reshape(nc, s)
    chunk_row0 = dest[:, 0].copy()
    span = int((dest[:, -1] - chunk_row0).max()) + 1
    rp = _round_up(max(span, 1), 128) if r_pad is None else int(r_pad)
    if span > rp:
        raise ValueError(f"chunk row span {span} > r_pad {rp}")
    lrow = (dest - chunk_row0[:, None]).astype(np.int32)
    fixup = (chunk_row0[:, None]
             + np.arange(rp, dtype=np.int64)[None, :]).reshape(-1)
    return dict(num_chunks=nc, r_pad=rp,
                vals=val.reshape(nc, ks, 128),
                lrow=lrow.reshape(nc, ks, 128),
                src=src.reshape(-1).astype(np.int64),
                chunk_row0=chunk_row0.astype(np.int32),
                fixup_idx=fixup.astype(np.int32))


@dataclasses.dataclass(frozen=True)
class NnzSplitPack:
    n: int
    num_chunks: int
    ks: int                     # sublanes per chunk: S = ks*128 entries
    r_pad: int                  # per-chunk local row window (128-aligned)
    vals: jnp.ndarray           # (C, KS, 128) dest-sorted combined values
    lrow: jnp.ndarray           # (C, KS, 128) dest - chunk_row0[chunk]
    src: jnp.ndarray            # (C*S,) global gather index into x
    chunk_row0: jnp.ndarray     # (C,) first dest row of each chunk
    fixup_idx: jnp.ndarray      # (C*r_pad,) scatter rows into y_pad
    ad: jnp.ndarray             # (n,) diagonal
    num_symmetric: bool
    pad_ratio: float            # allocated slots / real stream entries

    @property
    def s(self) -> int:
        return self.ks * 128

    def streamed_bytes(self) -> int:
        b = self.vals.size * self.vals.dtype.itemsize
        b += self.lrow.size * self.lrow.dtype.itemsize
        b += self.src.size * self.src.dtype.itemsize
        b += self.src.size * 4                      # gathered x stream
        b += self.fixup_idx.size * self.fixup_idx.dtype.itemsize
        b += self.num_chunks * self.r_pad * 4       # partials written+read
        b += self.ad.size * self.ad.dtype.itemsize
        b += 2 * self.n * 4                         # x and y
        return b


def pack_nnzsplit(M: CSRC, ks: int = 8, r_cap: int = 4096,
                  dtype=jnp.float32, index_dtype=jnp.int32) -> NnzSplitPack:
    """Equal-nnz chunking of a square CSRC matrix.

    ``r_cap`` bounds the per-chunk row window: a stream whose chunks skip
    huge row gaps (near-diagonal matrices with a handful of scattered
    entries) would pad every chunk to the worst gap — those matrices
    belong to the banded paths, so the packer raises (same contract as the
    windowed packers' w_cap gate).
    """
    assert M.is_square
    n = M.n
    if index_dtype == jnp.int16 and n > 32767:
        raise ValueError(f"n {n} overflows int16 gather indices")
    dest, src, val = _combined_stream(M)
    ch = _chunk_arrays(dest, src, val, ks=ks)
    if ch["r_pad"] > r_cap:
        raise ValueError(f"chunk row window {ch['r_pad']} > cap {r_cap}")
    kk = max(1, int(dest.shape[0]))
    return NnzSplitPack(
        n=n, num_chunks=ch["num_chunks"], ks=ks, r_pad=ch["r_pad"],
        vals=jnp.asarray(ch["vals"], dtype=dtype),
        lrow=jnp.asarray(ch["lrow"], dtype=index_dtype),
        src=jnp.asarray(ch["src"], dtype=index_dtype),
        chunk_row0=jnp.asarray(ch["chunk_row0"]),
        fixup_idx=jnp.asarray(ch["fixup_idx"]),
        ad=jnp.asarray(np.asarray(M.ad), dtype=dtype),
        num_symmetric=bool(M.numerically_symmetric),
        pad_ratio=float(ch["num_chunks"] * ks * 128) / kk,
    )


def refresh_nnzsplit_values(pack: NnzSplitPack, M: CSRC) -> NnzSplitPack:
    """Refill the value stream from a same-structure matrix: the stable
    dest argsort is re-derived (structure unchanged means the same
    permutation), values refilled, no index stream touched."""
    assert M.is_square and M.n == pack.n, "structure mismatch"
    if bool(M.numerically_symmetric) != pack.num_symmetric:
        raise ValueError(
            "numeric symmetry changed; rebuild instead of refreshing")
    _dest, _src, val = _combined_stream(M)
    s = pack.ks * 128
    pad = pack.num_chunks * s - val.shape[0]
    if pad < 0:
        raise ValueError("structure mismatch: stream longer than pack")
    val = np.concatenate([val, np.zeros(pad, np.float32)])
    return dataclasses.replace(
        pack,
        vals=jnp.asarray(val.reshape(pack.num_chunks, pack.ks, 128),
                         dtype=pack.vals.dtype),
        ad=jnp.asarray(np.asarray(M.ad), dtype=pack.ad.dtype))


# ---------------------------------------------------------------------------
# Kernels: one program per chunk, one one-hot matmul per product
# ---------------------------------------------------------------------------

def _kernel(vals_ref, lrow_ref, xg_ref, out_ref, *, r_pad: int):
    lr = lrow_ref[0].astype(jnp.int32)        # (KS, 128)
    ks = lr.shape[0]
    s = ks * 128
    c = vals_ref[0].reshape(-1).astype(jnp.float32) * xg_ref[0].reshape(-1)
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (ks, 128, r_pad), 2)
    oh = (lr[..., None] == iota_r).astype(jnp.float32).reshape(s, r_pad)
    out_ref[0] = jax.lax.dot_general(oh, c[:, None],
                                     (((0,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)[:, 0]


def _kernel_stream(vals_ref, lrow_ref, xg_ref, out_ref, *, r_pad: int):
    """Streaming variant: segment-sum over the chunk-local rows instead of
    the (S, r_pad) one-hot contraction — O(1) work per stream entry.
    Padding entries carry val=0 and an in-range lrow, so they add exact
    zeros (same invariant the one-hot body relies on)."""
    lr = lrow_ref[0].astype(jnp.int32).reshape(-1)     # (S,)
    c = vals_ref[0].reshape(-1).astype(jnp.float32) * xg_ref[0].reshape(-1)
    out_ref[0] = jax.ops.segment_sum(c, lr, num_segments=r_pad)


_BODIES = {"onehot": _kernel, "stream": _kernel_stream}


def nnzsplit_spmv(pack: NnzSplitPack, x: jnp.ndarray,
                  interpret: bool = True,
                  variant: str = "onehot") -> jnp.ndarray:
    x = x.astype(jnp.float32)
    xg = x[pack.src.astype(jnp.int32)].reshape(pack.num_chunks, pack.ks, 128)
    partial = pl.pallas_call(
        functools.partial(_BODIES[variant], r_pad=pack.r_pad),
        grid=(pack.num_chunks,),
        in_specs=[
            pl.BlockSpec((1, pack.ks, 128), lambda j: (j, 0, 0)),
            pl.BlockSpec((1, pack.ks, 128), lambda j: (j, 0, 0)),
            pl.BlockSpec((1, pack.ks, 128), lambda j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, pack.r_pad), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((pack.num_chunks, pack.r_pad),
                                       jnp.float32),
        interpret=interpret,
    )(pack.vals, pack.lrow, xg)
    y_pad = jnp.zeros(pack.n + pack.r_pad, jnp.float32
                      ).at[pack.fixup_idx].add(partial.reshape(-1))
    return y_pad[:pack.n] + pack.ad.astype(jnp.float32) * x


def _kernel_mm(vals_ref, lrow_ref, xg_ref, out_ref, *, r_pad: int,
               nrhs: int):
    lr = lrow_ref[0].astype(jnp.int32)
    ks = lr.shape[0]
    s = ks * 128
    c = vals_ref[0].reshape(s, 1).astype(jnp.float32) * xg_ref[0]  # (S, B)
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (ks, 128, r_pad), 2)
    oh = (lr[..., None] == iota_r).astype(jnp.float32).reshape(s, r_pad)
    out_ref[0] = jax.lax.dot_general(oh, c, (((0,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)


def _kernel_mm_stream(vals_ref, lrow_ref, xg_ref, out_ref, *, r_pad: int,
                      nrhs: int):
    """Streaming multi-RHS variant: B-wide segment-sum scatter."""
    lr = lrow_ref[0].astype(jnp.int32).reshape(-1)
    s = lr.shape[0]
    c = vals_ref[0].reshape(s, 1).astype(jnp.float32) * xg_ref[0]  # (S, B)
    out_ref[0] = jax.ops.segment_sum(c, lr, num_segments=r_pad)


_BODIES_MM = {"onehot": _kernel_mm, "stream": _kernel_mm_stream}


def nnzsplit_spmm(pack: NnzSplitPack, X: jnp.ndarray,
                  interpret: bool = True,
                  variant: str = "onehot") -> jnp.ndarray:
    """Y = A @ X for X (n, B): same chunk layout, B-wide partials."""
    n, nrhs = X.shape
    assert n == pack.n
    X = X.astype(jnp.float32)
    s = pack.s
    xg = X[pack.src.astype(jnp.int32), :].reshape(pack.num_chunks, s, nrhs)
    partial = pl.pallas_call(
        functools.partial(_BODIES_MM[variant], r_pad=pack.r_pad, nrhs=nrhs),
        grid=(pack.num_chunks,),
        in_specs=[
            pl.BlockSpec((1, pack.ks, 128), lambda j: (j, 0, 0)),
            pl.BlockSpec((1, pack.ks, 128), lambda j: (j, 0, 0)),
            pl.BlockSpec((1, s, nrhs), lambda j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, pack.r_pad, nrhs), lambda j: (j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((pack.num_chunks, pack.r_pad, nrhs),
                                       jnp.float32),
        interpret=interpret,
    )(pack.vals, pack.lrow, xg)
    y_pad = jnp.zeros((pack.n + pack.r_pad, nrhs), jnp.float32
                      ).at[pack.fixup_idx].add(partial.reshape(-1, nrhs))
    return y_pad[:pack.n] + pack.ad.astype(jnp.float32)[:, None] * X


# ---------------------------------------------------------------------------
# Shard-local layouts for the distributed strategies
# (consumed through core/schedule.py's memoized builders and the
# ShardSupport entry registered in core/paths.py)
# ---------------------------------------------------------------------------

def _stack_chunked(streams, *, ks: int, r_cap: int):
    """Chunk one stream per shard with equalized (num_chunks, r_pad)."""
    probed = [_chunk_arrays(d, s, v, ks=ks) for d, s, v in streams]
    nc = max(c["num_chunks"] for c in probed)
    rp = max(c["r_pad"] for c in probed)
    if rp > r_cap:
        raise ValueError(f"chunk row window {rp} > cap {r_cap}")
    parts = [_chunk_arrays(d, s, v, ks=ks, num_chunks=nc, r_pad=rp)
             for d, s, v in streams]
    stacked = {key: np.stack([c[key] for c in parts])
               for key in ("vals", "lrow", "src", "chunk_row0", "fixup_idx")}
    return nc, rp, stacked


def _as_shard_arrays(stacked, *, dtype, index_dtype):
    return dict(
        vals=jnp.asarray(stacked["vals"], dtype=dtype),
        lrow=jnp.asarray(stacked["lrow"], dtype=index_dtype),
        src=jnp.asarray(stacked["src"], dtype=index_dtype),
        chunk_row0=jnp.asarray(stacked["chunk_row0"]),
        fixup_idx=jnp.asarray(stacked["fixup_idx"]))


@dataclasses.dataclass(frozen=True)
class NnzSplitShards:
    """Per-shard nnz-split sub-packs in *global* coordinates (allreduce /
    reduce_scatter): shard t chunks only the combined entries whose dest
    row it owns, plus its slice of the diagonal, and emits a full-length
    partial y."""
    p: int
    n: int
    num_chunks: int             # uniform chunks per shard (padded)
    ks: int
    r_pad: int
    vals: jnp.ndarray           # (p, C, KS, 128)
    lrow: jnp.ndarray           # (p, C, KS, 128)
    src: jnp.ndarray            # (p, C*S)
    chunk_row0: jnp.ndarray     # (p, C)
    fixup_idx: jnp.ndarray      # (p, C*r_pad)
    ad: jnp.ndarray             # (p, n) — shard-owned diagonal, zero rest
    num_symmetric: bool

    def shard_pack(self, t: int) -> NnzSplitPack:
        return NnzSplitPack(
            n=self.n, num_chunks=self.num_chunks, ks=self.ks,
            r_pad=self.r_pad, vals=self.vals[t], lrow=self.lrow[t],
            src=self.src[t], chunk_row0=self.chunk_row0[t],
            fixup_idx=self.fixup_idx[t], ad=self.ad[t],
            num_symmetric=self.num_symmetric, pad_ratio=1.0)


def pack_nnzsplit_shards(M: CSRC, starts, ks: int = 8, r_cap: int = 4096,
                         dtype=jnp.float32,
                         index_dtype=jnp.int32) -> NnzSplitShards:
    """Split the combined stream along the row partition ``starts``: shard
    t takes the entries with dest in [starts[t], starts[t+1])."""
    assert M.is_square
    n = M.n
    if index_dtype == jnp.int16 and n > 32767:
        raise ValueError(f"n {n} overflows int16 gather indices")
    starts = np.asarray(starts, dtype=np.int64)
    p = starts.shape[0] - 1
    dest, src, val = _combined_stream(M)

    def streams():
        for t in range(p):
            sel = (dest >= starts[t]) & (dest < starts[t + 1])
            yield dest[sel], src[sel], val[sel]

    nc, rp, stacked = _stack_chunked(list(streams()), ks=ks, r_cap=r_cap)
    ad = np.zeros((p, n), np.float32)
    ad_full = np.asarray(M.ad)
    for t in range(p):
        r0, r1 = int(starts[t]), int(starts[t + 1])
        ad[t, r0:r1] = ad_full[r0:r1]
    return NnzSplitShards(
        p=p, n=n, num_chunks=nc, ks=ks, r_pad=rp,
        ad=jnp.asarray(ad, dtype=dtype),
        num_symmetric=bool(M.numerically_symmetric),
        **_as_shard_arrays(stacked, dtype=dtype, index_dtype=index_dtype))


@dataclasses.dataclass(frozen=True)
class NnzSplitHalo:
    """Per-shard nnz-split packs in *local* halo coordinates: both halves
    of a slot go to the shard owning the slot's row (columns then lie in
    [r0-h, r1), the frame the halo exchange provides), and the local
    product is an n_local = ns + h row vector with the halo rows first —
    the same y_ext/x_ext contract as the other halo layouts."""
    p: int
    ns: int
    h: int
    n_local: int
    num_chunks: int
    ks: int
    r_pad: int
    vals: jnp.ndarray
    lrow: jnp.ndarray
    src: jnp.ndarray
    chunk_row0: jnp.ndarray
    fixup_idx: jnp.ndarray
    ad: jnp.ndarray             # (p, n_local) local-coordinate diagonal
    num_symmetric: bool

    def shard_pack(self, t: int) -> NnzSplitPack:
        return NnzSplitPack(
            n=self.n_local, num_chunks=self.num_chunks, ks=self.ks,
            r_pad=self.r_pad, vals=self.vals[t], lrow=self.lrow[t],
            src=self.src[t], chunk_row0=self.chunk_row0[t],
            fixup_idx=self.fixup_idx[t], ad=self.ad[t],
            num_symmetric=self.num_symmetric, pad_ratio=1.0)


def pack_nnzsplit_halo(M: CSRC, p: int, ks: int = 8, r_cap: int = 4096,
                       dtype=jnp.float32,
                       index_dtype=jnp.int32) -> NnzSplitHalo:
    """Per-shard local packs for the halo strategy.  Same band-fits-shard
    gate as the other halo builders — unstructured matrices with band ~ n
    correctly fail it and fall back to allreduce/reduce_scatter."""
    assert M.is_square
    n = M.n
    ns = _round_up(-(-n // p), 8)
    band = bandwidth(M)
    h = max(8, _round_up(band, 8))
    if h > ns:
        raise ValueError(
            f"band {band} exceeds shard rows {ns}; halo strategy needs "
            "band <= n/p (fall back to allreduce/reduce_scatter)")
    n_local = ns + h
    if index_dtype == jnp.int16 and n_local > 32767:
        raise ValueError(f"n_local {n_local} overflows int16 indices")
    ros = row_of_slot(M).astype(np.int64)
    ja = np.asarray(M.ja, dtype=np.int64)
    al = np.asarray(M.al, dtype=np.float32)
    au = np.asarray(M.au, dtype=np.float32)
    shard_of_slot = ros // ns

    def streams():
        for t in range(p):
            sel = shard_of_slot == t
            off = t * ns - h              # global row g -> local g - off
            d = np.concatenate([ros[sel], ja[sel]]) - off
            s = np.concatenate([ja[sel], ros[sel]]) - off
            v = np.concatenate([al[sel], au[sel]])
            order = np.argsort(d, kind="stable")
            yield d[order], s[order], v[order]

    nc, rp, stacked = _stack_chunked(list(streams()), ks=ks, r_cap=r_cap)
    ad = np.zeros((p, n_local), np.float32)
    ad_full = np.asarray(M.ad)
    for t in range(p):
        r0 = t * ns
        r1 = min(n, r0 + ns)
        if r1 > r0:
            ad[t, h:h + (r1 - r0)] = ad_full[r0:r1]
    return NnzSplitHalo(
        p=p, ns=ns, h=h, n_local=n_local, num_chunks=nc, ks=ks, r_pad=rp,
        ad=jnp.asarray(ad, dtype=dtype),
        num_symmetric=bool(M.numerically_symmetric),
        **_as_shard_arrays(stacked, dtype=dtype, index_dtype=index_dtype))


# --- same-structure value refresh of the stacked layouts -------------------

def _refresh_stacked(lay, value_streams, ad_rows):
    """Refill ``vals`` (and ad) of a stacked layout from per-shard value
    streams re-derived in the layout's build order."""
    s = lay.ks * 128
    vals = np.zeros((lay.p, lay.num_chunks, lay.ks, 128), np.float32)
    for t, v in enumerate(value_streams):
        flat = vals[t].reshape(-1)
        flat[:v.shape[0]] = v
    return dataclasses.replace(
        lay,
        vals=jnp.asarray(vals, dtype=lay.vals.dtype),
        ad=jnp.asarray(ad_rows, dtype=lay.ad.dtype))


def refresh_nnzsplit_shards(lay: NnzSplitShards, M: CSRC,
                            starts) -> NnzSplitShards:
    assert M.is_square and M.n == lay.n, "structure mismatch"
    starts = np.asarray(starts, dtype=np.int64)
    dest, _src, val = _combined_stream(M)
    streams = []
    for t in range(lay.p):
        sel = (dest >= starts[t]) & (dest < starts[t + 1])
        streams.append(val[sel])
    ad = np.zeros((lay.p, lay.n), np.float32)
    ad_full = np.asarray(M.ad)
    for t in range(lay.p):
        r0, r1 = int(starts[t]), int(starts[t + 1])
        ad[t, r0:r1] = ad_full[r0:r1]
    return _refresh_stacked(lay, streams, ad)


def refresh_nnzsplit_halo(lay: NnzSplitHalo, M: CSRC) -> NnzSplitHalo:
    assert M.is_square, "structure mismatch"
    ros = row_of_slot(M).astype(np.int64)
    ja = np.asarray(M.ja, dtype=np.int64)
    al = np.asarray(M.al, dtype=np.float32)
    au = np.asarray(M.au, dtype=np.float32)
    shard_of_slot = ros // lay.ns
    streams = []
    for t in range(lay.p):
        sel = shard_of_slot == t
        d = np.concatenate([ros[sel], ja[sel]]) - (t * lay.ns - lay.h)
        v = np.concatenate([al[sel], au[sel]])
        streams.append(v[np.argsort(d, kind="stable")])
    n = M.n
    ad = np.zeros((lay.p, lay.n_local), np.float32)
    ad_full = np.asarray(M.ad)
    for t in range(lay.p):
        r0 = t * lay.ns
        r1 = min(n, r0 + lay.ns)
        if r1 > r0:
            ad[t, lay.h:lay.h + (r1 - r0)] = ad_full[r0:r1]
    return _refresh_stacked(lay, streams, ad)


# --- shard_map plumbing (ShardSupport hooks) -------------------------------

def nnzsplit_shard_arrays(lay):
    """Leading-axis-p arrays a shard_map local function consumes."""
    return (lay.vals, lay.lrow, lay.src, lay.chunk_row0, lay.fixup_idx,
            lay.ad)


def nnzsplit_shard_specs(axis: str):
    return (P(axis, None, None, None), P(axis, None, None, None),
            P(axis, None), P(axis, None), P(axis, None), P(axis, None))


def nnzsplit_local_fn(lay, n_local: int, interpret: bool,
                      variant: str = "onehot"):
    """Shard-local product: rebuild the shard's pack from the shard_map
    slices (leading axis 1) and dispatch SpMV/SpMM on x's rank."""
    def fn(vals, lrow, src, chunk_row0, fixup_idx, ad, x):
        pk = NnzSplitPack(
            n=n_local, num_chunks=lay.num_chunks, ks=lay.ks,
            r_pad=lay.r_pad, vals=vals[0], lrow=lrow[0], src=src[0],
            chunk_row0=chunk_row0[0], fixup_idx=fixup_idx[0], ad=ad[0],
            num_symmetric=lay.num_symmetric, pad_ratio=1.0)
        if x.ndim == 2:
            return nnzsplit_spmm(pk, x, interpret=interpret, variant=variant)
        return nnzsplit_spmv(pk, x, interpret=interpret, variant=variant)
    return fn


def nnzsplit_halo_dims(lay: NnzSplitHalo):
    return lay.ns, lay.h, lay.n_local
