"""Pallas colored-batch + sorted-slot kernels for FEM assembly scatter.

The assembly hot path (repro.assembly.scatter) historically executed one
XLA ``.at[].add`` scatter per color class — C serialized dispatches per
value refresh, the exact launch-bound regime the colored SpMV path left
behind in PR 7.  This module is the streaming formulation of the
scatter-add ``vals[targets[g]] += ke.flat[g]``, consuming the
per-color slot packs the AssemblySchedule precomputes:

  colored-batch   one grid program per color class.  Within a color no
                  two contributions share a target (the conflict-free
                  coloring invariant), so a program's segment-sum is a
                  permutation write; programs accumulate into the same
                  revisited output block.  Two bodies, dispatched like
                  the SpMV variants:
                    stream   per-lane ``jnp.take`` gather of the
                             contribution values + one ``segment_sum``
                             over the target stream — O(1) work/slot,
                             bandwidth-bound;
                    onehot   targets realized as an (S, TS) one-hot
                             mask contracted on the MXU per output tile
                             — the Mosaic-safe compiled-TPU fallback,
                             compute-bound by construction.
  sorted-slot     the arXiv:2012.00585 analogue: contributions are
                  pre-sorted by destination at schedule-build time, so
                  the whole assembly is ONE color-free gather +
                  ``segment_sum(..., indices_are_sorted=True)`` — a
                  single fused launch, no palette term at all.

Sentinel discipline (shared with csrc_spmv_stream): padded pack entries
carry slot sentinel G (one past the last contribution — the gather reads
an appended zero) and target sentinel ``size`` (one past the last real
segment — the segment-sum drops it).  Index streams arrive int16 when
the schedule's overflow gate allowed it and are upcast in-register.

In interpret mode (the CPU backend of this repo's tests and benches) the
emulated Pallas grid costs ~1 ms/step, so the stream variant evaluates
the identical per-color computation as one fused XLA expression over all
(color, slot) pairs — same slots summed into the same segments, so for
dyadic element values the result is bit-identical to the in-grid bodies
and to the serial ``np.add.at`` oracle (tests assert equality, not
closeness).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# output-tile width of the one-hot body: each (color, tile) program
# contracts an (S, TILE) mask on the MXU
ONEHOT_TILE = 512
COLORED_VARIANTS = ("stream", "onehot")


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _padded_contribs(kflat) -> jnp.ndarray:
    """Flat contribution values with one appended zero — the slot
    sentinel G gathers it, so padded pack entries are numerically inert."""
    flat = jnp.asarray(kflat, jnp.float32).reshape(-1)
    return jnp.concatenate([flat, jnp.zeros((1,), jnp.float32)])


# ---------------------------------------------------------------------------
# Fused XLA executors (the interpret-mode / CPU route)
# ---------------------------------------------------------------------------

def colored_scatter_fused(color_slots, color_targets, kflat,
                          size: int) -> jnp.ndarray:
    """All color batches as one gather + one segment-sum: the same
    (slot, target) pairs the in-grid bodies process per color, evaluated
    grid-free.  Target sentinel ``size`` routes padding to the drop
    segment one past the vector end."""
    kpad = _padded_contribs(kflat)
    slots = jnp.asarray(color_slots).astype(jnp.int32).reshape(-1)
    tgts = jnp.asarray(color_targets).astype(jnp.int32).reshape(-1)
    contribs = jnp.take(kpad, slots)
    out = jax.ops.segment_sum(contribs, tgts, num_segments=size + 1)
    return out[:size]


def sorted_scatter(sorted_perm, sorted_targets, kflat,
                   size: int) -> jnp.ndarray:
    """Sorted-slot assembly: gather contributions in destination order,
    then one monotone segment-sum — no colors, no sentinels, one launch."""
    kvals = jnp.asarray(kflat, jnp.float32).reshape(-1)
    contribs = jnp.take(kvals, jnp.asarray(sorted_perm).astype(jnp.int32))
    return jax.ops.segment_sum(
        contribs, jnp.asarray(sorted_targets).astype(jnp.int32),
        num_segments=size, indices_are_sorted=True)


# ---------------------------------------------------------------------------
# In-grid Pallas bodies (one program per color / per (color, tile))
# ---------------------------------------------------------------------------

def _colored_kernel_stream(slots_ref, tgts_ref, kvals_ref, out_ref, *,
                           size_pad: int):
    """grid = (C,): gather this color's contributions, segment-sum them
    into the full output block (revisited across colors)."""
    c = pl.program_id(0)
    slots = slots_ref[0].astype(jnp.int32)        # (L,), sentinel == G
    tgts = tgts_ref[0].astype(jnp.int32)          # (L,), sentinel == size
    contribs = jnp.take(kvals_ref[...], slots)
    win = jax.ops.segment_sum(contribs, tgts, num_segments=size_pad)

    @pl.when(c == 0)
    def _init():
        out_ref[...] = win

    @pl.when(c != 0)
    def _acc():
        out_ref[...] = out_ref[...] + win


def _colored_kernel_onehot(slots_ref, tgts_ref, kvals_ref, out_ref, *,
                           tile: int):
    """grid = (C, NT): the scatter as an MXU contraction.  The (TILE, L)
    one-hot of this tile's local targets is contracted with the color's
    contribution vector; out-of-tile targets (including the sentinel)
    match no iota row and contribute zero."""
    c = pl.program_id(0)
    t = pl.program_id(1)
    slots = slots_ref[0].astype(jnp.int32)               # (L,)
    local = tgts_ref[0].astype(jnp.int32) - t * tile     # (L,)
    contribs = jnp.take(kvals_ref[...], slots)
    length = local.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (tile, length), 0)
    onehot = (iota == local[None, :]).astype(jnp.float32)   # (TILE, L)
    win = jax.lax.dot_general(
        onehot, contribs[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]           # (TILE,)

    @pl.when(c == 0)
    def _init():
        out_ref[...] = win

    @pl.when(c != 0)
    def _acc():
        out_ref[...] = out_ref[...] + win


def colored_scatter_grid(color_slots, color_targets, kflat, size: int,
                         variant: str = "stream",
                         interpret: bool = True) -> jnp.ndarray:
    """The colored-batch kernel through the Pallas grid (both variants).

    Inputs are the schedule's (C, L) packs; the contribution table is
    padded with the sentinel zero and lane-aligned.  The output block is
    revisited across the color axis (standard revisited-output
    accumulation), then sliced back to ``size`` — the drop segment and
    the alignment pad fall off."""
    if variant not in COLORED_VARIANTS:
        raise ValueError(
            f"variant {variant!r} not in {COLORED_VARIANTS}")
    slots = jnp.asarray(color_slots)
    tgts = jnp.asarray(color_targets)
    num_colors, length = slots.shape
    kpad = _padded_contribs(kflat)
    g_pad = _round_up(kpad.shape[0], 128)
    kpad = jnp.pad(kpad, (0, g_pad - kpad.shape[0]))

    if variant == "stream":
        size_pad = _round_up(size + 1, 128)
        out = pl.pallas_call(
            functools.partial(_colored_kernel_stream, size_pad=size_pad),
            grid=(num_colors,),
            in_specs=[
                pl.BlockSpec((1, length), lambda c: (c, 0)),   # slots
                pl.BlockSpec((1, length), lambda c: (c, 0)),   # targets
                pl.BlockSpec((g_pad,), lambda c: (0,)),        # contribs
            ],
            out_specs=pl.BlockSpec((size_pad,), lambda c: (0,)),
            out_shape=jax.ShapeDtypeStruct((size_pad,), jnp.float32),
            interpret=interpret,
        )(slots, tgts, kpad)
        return out[:size]

    size_pad = _round_up(size + 1, ONEHOT_TILE)
    nt = size_pad // ONEHOT_TILE
    out = pl.pallas_call(
        functools.partial(_colored_kernel_onehot, tile=ONEHOT_TILE),
        grid=(num_colors, nt),
        in_specs=[
            pl.BlockSpec((1, length), lambda c, t: (c, 0)),    # slots
            pl.BlockSpec((1, length), lambda c, t: (c, 0)),    # targets
            pl.BlockSpec((g_pad,), lambda c, t: (0,)),         # contribs
        ],
        out_specs=pl.BlockSpec((ONEHOT_TILE,), lambda c, t: (t,)),
        out_shape=jax.ShapeDtypeStruct((size_pad,), jnp.float32),
        interpret=interpret,
    )(slots, tgts, kpad)
    return out[:size]


def colored_scatter(color_slots, color_targets, kflat, size: int,
                    variant: str = "stream",
                    interpret: bool = True) -> jnp.ndarray:
    """Variant dispatch, mirroring the SpMV stream modules: the stream
    variant in interpret mode takes the grid-free fused route (the
    emulated grid's per-step cost dwarfs the kernel math); everything
    else runs the in-grid bodies."""
    if variant == "stream" and interpret:
        return colored_scatter_fused(color_slots, color_targets, kflat,
                                     size)
    return colored_scatter_grid(color_slots, color_targets, kflat, size,
                                variant=variant, interpret=interpret)
