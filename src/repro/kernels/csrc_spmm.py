"""Pallas TPU kernel: block-ELL CSRC sparse matrix × multi-vector (SpMM).

Generalizes csrc_spmv.py to B right-hand sides (batched serving / block
Krylov solvers).  The one-hot contractions become genuine MXU matmuls —
(S, W) one-hot @ (W, B) window — so arithmetic intensity rises with B and
the kernel leaves the bandwidth-bound regime the paper analyzes for B=1
(bytes/slot amortize across the RHS block: the CSRC index-halving matters
*less* as B grows, quantified in benchmarks).

Same layout/window/accumulation scheme as csrc_spmv (see that module);
x: (n, B), output (n, B) via per-tile (W, B) windows + overlap-add.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.blockell import BlockEll, overlap_add_mm


def _kernel(vals_l_ref, vals_u_ref, col_ref, row_ref, ad_ref, x_ref,
            out_ref, *, tm: int, w_pad: int, nrhs: int,
            num_symmetric: bool):
    b = pl.program_id(0)
    kt = pl.program_id(1)
    start = (b + 1) * tm
    xw = jax.lax.dynamic_slice(x_ref[...], (start, 0), (w_pad, nrhs))

    cols = col_ref[0].astype(jnp.int32)   # int32/int16 stream, upcast
    rows = row_ref[0].astype(jnp.int32)
    vl = vals_l_ref[0]
    vu = vl if num_symmetric else vals_u_ref[0]
    ks = cols.shape[0]
    s = ks * 128

    iota_w = jax.lax.broadcasted_iota(jnp.int32, (ks, 128, w_pad), 2)
    oh_cols = (cols[..., None] == iota_w).astype(vl.dtype).reshape(s, w_pad)
    oh_rows = (rows[..., None] == iota_w).astype(vl.dtype).reshape(s, w_pad)

    xg = jax.lax.dot_general(oh_cols, xw, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (S,B)
    xi = jax.lax.dot_general(oh_rows, xw, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    c_rows = vl.reshape(s, 1) * xg      # al[p]·x[ja[p],:] -> rows
    c_cols = vu.reshape(s, 1) * xi      # au[p]·x[i,:]     -> cols

    win = jax.lax.dot_general(oh_rows, c_rows, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    win = win + jax.lax.dot_general(oh_cols, c_cols,
                                    (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)

    @pl.when(kt == 0)
    def _init():
        diag = ad_ref[0][:, None] * jax.lax.dynamic_slice(
            xw, (w_pad - tm, 0), (tm, nrhs))
        base = jnp.zeros((w_pad, nrhs), jnp.float32)
        base = jax.lax.dynamic_update_slice(base, diag, (w_pad - tm, 0))
        out_ref[0] = base + win

    @pl.when(kt != 0)
    def _acc():
        out_ref[0] = out_ref[0] + win


def _kernel_stream(vals_l_ref, vals_u_ref, col_ref, row_ref, ad_ref, x_ref,
                   out_ref, *, tm: int, w_pad: int, nrhs: int,
                   num_symmetric: bool):
    """Streaming variant (see csrc_spmv._kernel_stream): per-lane row
    gather of the (W, B) window + segment-sum scatter — no (S, W) one-hot
    operands, O(B) work per slot."""
    b = pl.program_id(0)
    kt = pl.program_id(1)
    start = (b + 1) * tm
    xw = jax.lax.dynamic_slice(x_ref[...], (start, 0), (w_pad, nrhs))

    cols = col_ref[0].astype(jnp.int32).reshape(-1)   # (S,), sentinel == W
    rows = row_ref[0].astype(jnp.int32).reshape(-1)
    vl = vals_l_ref[0].reshape(-1)
    vu = vl if num_symmetric else vals_u_ref[0].reshape(-1)

    xg = jnp.take(xw, jnp.minimum(cols, w_pad - 1), axis=0)   # (S, B)
    xi = jnp.take(xw, rows, axis=0)

    c_rows = vl[:, None] * xg      # al[p]·x[ja[p],:] -> rows
    c_cols = vu[:, None] * xi      # au[p]·x[i,:]     -> cols

    win = jax.ops.segment_sum(c_rows.astype(jnp.float32), rows,
                              num_segments=w_pad)
    win = win + jax.ops.segment_sum(c_cols.astype(jnp.float32), cols,
                                    num_segments=w_pad)

    @pl.when(kt == 0)
    def _init():
        diag = ad_ref[0][:, None] * jax.lax.dynamic_slice(
            xw, (w_pad - tm, 0), (tm, nrhs))
        base = jnp.zeros((w_pad, nrhs), jnp.float32)
        base = jax.lax.dynamic_update_slice(base, diag, (w_pad - tm, 0))
        out_ref[0] = base + win

    @pl.when(kt != 0)
    def _acc():
        out_ref[0] = out_ref[0] + win


_BODIES = {"onehot": _kernel, "stream": _kernel_stream}


def blockell_spmm(pack: BlockEll, X: jnp.ndarray,
                  k_step_sublanes: int = 8,
                  interpret: bool = True,
                  variant: str = "onehot") -> jnp.ndarray:
    """Y = A @ X for X (n, B); returns (n, B)."""
    n, nrhs = X.shape
    assert n == pack.n
    nt, s = pack.vals_l.shape
    ks = k_step_sublanes
    assert s % (ks * 128) == 0
    nk = s // (ks * 128)
    x_full = jnp.pad(X.astype(jnp.float32),
                     ((pack.w_pad, pack.n_pad - pack.n), (0, 0)))

    def reshape3(a):
        return a.reshape(nt, nk * ks, 128)

    slot_spec = pl.BlockSpec((1, ks, 128), lambda b, kt: (b, kt, 0))
    wins = pl.pallas_call(
        functools.partial(_BODIES[variant], tm=pack.tm, w_pad=pack.w_pad,
                          nrhs=nrhs, num_symmetric=pack.num_symmetric),
        grid=(nt, nk),
        in_specs=[
            slot_spec, slot_spec, slot_spec, slot_spec,
            pl.BlockSpec((1, pack.tm), lambda b, kt: (b, 0)),
            pl.BlockSpec(x_full.shape, lambda b, kt: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, pack.w_pad, nrhs),
                               lambda b, kt: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nt, pack.w_pad, nrhs),
                                       jnp.float32),
        interpret=interpret,
    )(reshape3(pack.vals_l), reshape3(pack.vals_u),
      reshape3(pack.col_local), reshape3(pack.row_in_win),
      pack.ad, x_full)

    # overlap-add per RHS column (windows are (NT, W, B))
    return overlap_add_mm(pack, wins)
