"""Pallas TPU kernel for the block-ELL CSRC sparse matrix-vector product.

TPU adaptation of the paper's parallel CSRC SpMV (docs/DESIGN.md §4):

  * a grid program = one (row-tile b, k-step kt) pair — the paper's "thread
    processing a row range" at VMEM-tile granularity;
  * the scatter `y[ja] += au·x[i]` and gather `y[i] += al·x[ja]` terms are
    both realized as **one-hot MXU matmuls** against the tile's x-window —
    TPUs have no atomics or efficient per-lane scatter, so indexing becomes
    arithmetic.  One-hot of the padding sentinel (index == W) is the zero
    vector, so ELL padding is numerically inert;
  * each program accumulates into a per-tile output *window* (the paper's
    "local buffer" restricted to its "effective range"); windows are
    combined by `core.blockell.overlap_add` — the *effective accumulation*
    step, expressed as reshape+add (scatter-free HLO);
  * for numerically symmetric matrices only `vals_l` is streamed (the
    paper's one-fewer-load optimization — here it saves 4 of ~16 streamed
    bytes/slot, directly visible in the memory roofline term).

Grid: (NT, NK); k-step block = (KS, 128) slots; x stays whole in VMEM
(the per-shard x slice after row partitioning; callers enforce the VMEM cap).
Output block (1, W) is revisited across kt (revisited-output accumulation,
standard Pallas reduction pattern).

Validated in interpret mode on CPU (tests/test_kernels_spmv.py); BlockSpecs
are MXU/VPU aligned (last dim 128) for the TPU target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.blockell import BlockEll, pad_x, overlap_add


def _kernel(vals_l_ref, vals_u_ref, col_ref, row_ref, ad_ref, x_ref,
            out_ref, *, tm: int, w_pad: int, num_symmetric: bool):
    b = pl.program_id(0)
    kt = pl.program_id(1)

    # ---- x window for this row tile: padded coords [(b+1)*tm, +W) ----
    start = (b + 1) * tm
    xw = jax.lax.dynamic_slice(x_ref[...], (start,), (w_pad,))  # (W,)

    # int32 or int16 stream (plan.index_dtype); upcast for the iota compare
    cols = col_ref[0].astype(jnp.int32)   # (KS, 128), sentinel == W
    rows = row_ref[0].astype(jnp.int32)   # (KS, 128) in [W-tm, W)
    vl = vals_l_ref[0]                    # (KS, 128) f32
    vu = vl if num_symmetric else vals_u_ref[0]

    ks = cols.shape[0]
    iota_w = jax.lax.broadcasted_iota(jnp.int32, (ks, 128, w_pad), 2)
    # one-hot over the window; sentinel (== W) produces a zero row
    oh_cols = (cols[..., None] == iota_w).astype(vl.dtype)      # (KS,128,W)
    oh_rows = (rows[..., None] == iota_w).astype(vl.dtype)

    # gather x[j] and x[i] via one-hot contraction over W
    xg = jax.lax.dot_general(
        oh_cols.reshape(ks * 128, w_pad), xw[:, None],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]               # (KS*128,)
    xi = jax.lax.dot_general(
        oh_rows.reshape(ks * 128, w_pad), xw[:, None],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]

    contrib_to_rows = vl.reshape(-1) * xg      # al[p]*x[ja[p]]  -> y[i]
    contrib_to_cols = vu.reshape(-1) * xi      # au[p]*x[i]      -> y[ja[p]]

    # scatter via the transposed one-hots: (W, S) @ (S,)
    win = jax.lax.dot_general(
        oh_rows.reshape(ks * 128, w_pad), contrib_to_rows[:, None],
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]               # (W,)
    win = win + jax.lax.dot_general(
        oh_cols.reshape(ks * 128, w_pad), contrib_to_cols[:, None],
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]

    @pl.when(kt == 0)
    def _init():
        # diagonal: tile rows are the last TM entries of the window
        diag = ad_ref[0] * jax.lax.dynamic_slice(xw, (w_pad - tm,), (tm,))
        base = jnp.zeros((w_pad,), jnp.float32)
        base = jax.lax.dynamic_update_slice(
            base, diag, (w_pad - tm,))
        out_ref[0] = base + win

    @pl.when(kt != 0)
    def _acc():
        out_ref[0] = out_ref[0] + win


def _kernel_stream(vals_l_ref, vals_u_ref, col_ref, row_ref, ad_ref, x_ref,
                   out_ref, *, tm: int, w_pad: int, num_symmetric: bool):
    """Streaming variant: per-lane gather + segment-sum scatter.

    Avoids the (KS, 128, W) one-hot tensors entirely — O(1) work per slot
    instead of O(W), so streamed bytes/slot sit at the format's 12-16 B
    floor and the kernel is bandwidth-bound (the regime the paper requires
    for CSRC SpMV).  The padding sentinel (col == W) is clamped into range
    for the gather — inert because padded slot values are zero — and
    dropped by the segment-sum scatter (id out of range).  Selected by
    ``ExecutionPlan.variant == 'stream'``; the one-hot body stays the
    Mosaic-safe fallback for compiled TPU, which has no native scatter.
    """
    b = pl.program_id(0)
    kt = pl.program_id(1)
    start = (b + 1) * tm
    xw = jax.lax.dynamic_slice(x_ref[...], (start,), (w_pad,))  # (W,)

    cols = col_ref[0].astype(jnp.int32).reshape(-1)   # (S,), sentinel == W
    rows = row_ref[0].astype(jnp.int32).reshape(-1)   # (S,) in [W-tm, W)
    vl = vals_l_ref[0].reshape(-1)
    vu = vl if num_symmetric else vals_u_ref[0].reshape(-1)

    xg = jnp.take(xw, jnp.minimum(cols, w_pad - 1))   # x[ja[p]]
    xi = jnp.take(xw, rows)                           # x[i]

    contrib_to_rows = vl * xg      # al[p]*x[ja[p]]  -> y[i]
    contrib_to_cols = vu * xi      # au[p]*x[i]      -> y[ja[p]]

    win = jax.ops.segment_sum(contrib_to_rows.astype(jnp.float32), rows,
                              num_segments=w_pad)
    win = win + jax.ops.segment_sum(contrib_to_cols.astype(jnp.float32),
                                    cols, num_segments=w_pad)

    @pl.when(kt == 0)
    def _init():
        diag = ad_ref[0] * jax.lax.dynamic_slice(xw, (w_pad - tm,), (tm,))
        base = jnp.zeros((w_pad,), jnp.float32)
        base = jax.lax.dynamic_update_slice(
            base, diag, (w_pad - tm,))
        out_ref[0] = base + win

    @pl.when(kt != 0)
    def _acc():
        out_ref[0] = out_ref[0] + win


_BODIES = {"onehot": _kernel, "stream": _kernel_stream}


def blockell_spmv_windows(pack: BlockEll, x: jnp.ndarray,
                          k_step_sublanes: int = 8,
                          interpret: bool = True,
                          variant: str = "onehot") -> jnp.ndarray:
    """Run the kernel; returns per-tile windows (NT, W) before accumulation."""
    nt, s = pack.vals_l.shape
    assert s % (k_step_sublanes * 128) == 0, (
        "slot count must divide the k-step")
    nk = s // (k_step_sublanes * 128)
    ks = k_step_sublanes
    x_full = pad_x(pack, x.astype(jnp.float32))

    def reshape3(a):
        return a.reshape(nt, nk * ks, 128)

    grid = (nt, nk)
    slot_spec = pl.BlockSpec((1, ks, 128), lambda b, kt: (b, kt, 0))
    out = pl.pallas_call(
        functools.partial(_BODIES[variant], tm=pack.tm, w_pad=pack.w_pad,
                          num_symmetric=pack.num_symmetric),
        grid=grid,
        in_specs=[
            slot_spec,                                      # vals_l
            slot_spec,                                      # vals_u
            slot_spec,                                      # col_local
            slot_spec,                                      # row_in_win
            pl.BlockSpec((1, pack.tm), lambda b, kt: (b, 0)),   # ad
            pl.BlockSpec(x_full.shape, lambda b, kt: (0,)),     # x (whole)
        ],
        out_specs=pl.BlockSpec((1, pack.w_pad), lambda b, kt: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((nt, pack.w_pad), jnp.float32),
        interpret=interpret,
    )(reshape3(pack.vals_l), reshape3(pack.vals_u),
      reshape3(pack.col_local), reshape3(pack.row_in_win),
      pack.ad, x_full)
    return out


def blockell_spmv(pack: BlockEll, x: jnp.ndarray,
                  interpret: bool = True,
                  k_step_sublanes: int = 8,
                  variant: str = "onehot") -> jnp.ndarray:
    """Full product: kernel windows + effective accumulation."""
    wins = blockell_spmv_windows(pack, x, k_step_sublanes=k_step_sublanes,
                                 interpret=interpret, variant=variant)
    return overlap_add(pack, wins)
