"""Pure-jnp oracles for every kernel in this package.

These are the correctness references (tests assert_allclose the Pallas
kernels against them) and the fallback path on backends without Pallas.

The CSRC product (paper Fig. 2a):

    y[i]      = ad[i] * x[i]
    y[i]     += al[p] * x[ja[p]]     (gather term,   p in row i's slots)
    y[ja[p]] += au[p] * x[i]         (scatter term,  transpose contribution)
    y[i]     += ar[q] * x[n + jar[q]]  (rectangular tail, paper Fig. 2b)

The scatter term is realized with ``segment_sum`` — the jnp-native
"local buffer + accumulate" (every slot's contribution is materialized, then
summed by destination row), which is exactly the paper's local-buffers
strategy expressed functionally.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.csrc import CSRC, row_of_slot


def csrc_spmv_arrays(ad, row_idx, ja, al, au, x, n: int,
                     num_symmetric: bool = False):
    """CSRC product on raw arrays.

    Args:
      ad: (n,) diagonal. row_idx: (k,) row of each lower slot (expanded ia).
      ja: (k,) col of each lower slot. al/au: (k,) values. x: (n,) source.
      num_symmetric: if True, au is ignored and al is used for the upper
        half (the paper's one-fewer-load optimization for numerically
        symmetric matrices).
    Returns: (n,) y.
    """
    upper = al if num_symmetric else au
    y = ad * x[:n]
    y = y + jax.ops.segment_sum(al * x[ja], row_idx, num_segments=n)
    y = y + jax.ops.segment_sum(upper * x[row_idx], ja, num_segments=n)
    return y


def csrc_spmv(M: CSRC, x, use_numeric_symmetry: bool = True):
    """CSRC product from the container (handles the rectangular tail)."""
    row_idx = jnp.asarray(row_of_slot(M))
    num_sym = bool(M.numerically_symmetric and use_numeric_symmetry)
    y = csrc_spmv_arrays(M.ad, row_idx, M.ja, M.al, M.au, x, M.n, num_sym)
    if M.jar.shape[0]:
        ia_r = np.asarray(M.iar)
        row_r = jnp.asarray(np.repeat(np.arange(M.n, dtype=np.int32),
                                      np.diff(ia_r)))
        y = y + jax.ops.segment_sum(M.ar * x[M.n + M.jar], row_r,
                                    num_segments=M.n)
    return y


def csrc_spmv_transpose(M: CSRC, x):
    """A^T x — paper §5: swap al and au, same cost."""
    row_idx = jnp.asarray(row_of_slot(M))
    return csrc_spmv_arrays(M.ad, row_idx, M.ja, M.au, M.al, x, M.n, False)


def csr_spmv_arrays(row_idx, ja, a, x, n: int):
    """Plain CSR product (the paper's baseline): y[i] += a[p] * x[ja[p]]."""
    return jax.ops.segment_sum(a * x[ja], row_idx, num_segments=n)


def csr_from_csrc(M: CSRC):
    """Expand a CSRC container to full CSR arrays (baseline construction).

    Returns (row_idx, col_idx, vals) covering diag + both halves + tail,
    sorted by row — what a standard CSR of the same matrix would store."""
    ros = row_of_slot(M)
    ja = np.asarray(M.ja)
    rows = [np.arange(M.n, dtype=np.int32), ros, ja]
    cols = [np.arange(M.n, dtype=np.int32), ja, ros]
    vals = [np.asarray(M.ad), np.asarray(M.al), np.asarray(M.au)]
    if M.jar.shape[0]:
        row_r = np.repeat(np.arange(M.n, dtype=np.int32),
                          np.diff(np.asarray(M.iar)))
        rows.append(row_r)
        cols.append(np.asarray(M.jar) + M.n)
        vals.append(np.asarray(M.ar))
    rows = np.concatenate(rows)
    cols = np.concatenate(cols)
    vals = np.concatenate(vals)
    order = np.lexsort((cols, rows))
    return rows[order], cols[order], vals[order]


def csrc_spmm(M: CSRC, X, use_numeric_symmetry: bool = True):
    """Multi-RHS product: X is (m, B), returns (n, B)."""
    row_idx = jnp.asarray(row_of_slot(M))
    num_sym = bool(M.numerically_symmetric and use_numeric_symmetry)
    upper = M.al if num_sym else M.au
    y = M.ad[:, None] * X[:M.n]
    y = y + jax.ops.segment_sum(M.al[:, None] * X[M.ja], row_idx,
                                num_segments=M.n)
    y = y + jax.ops.segment_sum(upper[:, None] * X[row_idx], M.ja,
                                num_segments=M.n)
    if M.jar.shape[0]:
        row_r = jnp.asarray(np.repeat(np.arange(M.n, dtype=np.int32),
                                      np.diff(np.asarray(M.iar))))
        y = y + jax.ops.segment_sum(M.ar[:, None] * X[M.n + M.jar], row_r,
                                    num_segments=M.n)
    return y


def colorful_spmv(M: CSRC, x, coloring):
    """The paper's colorful method, expressed in jnp: colors are processed
    serially; within a color all write targets are pairwise disjoint, so the
    scatter is a permutation write (`.at[].add` with unique indices — no
    accumulation ordering needed).

    This mirrors the *algorithmic* structure (serial colors × parallel rows)
    and handles x of shape (n,) or (n, r).  The per-color slot batches are
    normally precomputed once in the schedule artifact (core/schedule.py);
    this wrapper derives them from ``coloring`` for ad-hoc use.
    """
    from repro.core.schedule import color_slot_batches, colorful_apply
    slots, ptr = color_slot_batches(M, coloring)
    return colorful_apply(M, x, slots, ptr)


def blockell_spmv(pack, x):
    """Oracle for the block-ELL packed layout (core/blockell.py): the same
    math as the Pallas kernel without tiling — used to debug pack vs kernel
    separately.  The independent end-to-end oracle is ``csrc_spmv``."""
    from repro.core.blockell import pad_x, overlap_add
    x_full = pad_x(pack, x)
    starts = (jnp.arange(pack.nt) + 1) * pack.tm
    idx = starts[:, None] + jnp.arange(pack.w_pad)[None, :]
    xw = x_full[idx]                                    # (NT, W)
    col_ok = pack.col_local < pack.w_pad
    gather_x = jnp.where(
        col_ok,
        jnp.take_along_axis(xw, jnp.minimum(pack.col_local, pack.w_pad - 1),
                            axis=1),
        0.0)
    xi = jnp.take_along_axis(xw, pack.row_in_win, axis=1)
    contrib_rows = pack.vals_l * gather_x               # -> row_in_win
    contrib_cols = pack.vals_u * xi                     # -> col_local

    def tile_acc(cr, cc, roww, colw):
        w = jnp.zeros((pack.w_pad,), x_full.dtype)
        w = w.at[roww].add(cr)
        w = w.at[jnp.minimum(colw, pack.w_pad - 1)].add(
            jnp.where(colw < pack.w_pad, cc, 0.0))
        return w

    wins = jax.vmap(tile_acc)(contrib_rows, contrib_cols,
                              pack.row_in_win, pack.col_local)
    wins = wins.at[:, pack.w_pad - pack.tm:].add(
        pack.ad * xw[:, pack.w_pad - pack.tm:])
    return overlap_add(pack, wins)
