"""Streaming executors for the block-ELL / flat / nnz-split CSRC products.

The one-hot Pallas kernels realize gather/scatter as (S, W) one-hot MXU
contractions — O(W) work per slot, which is why the tuned local path sat
~40000x above the mesh segment path (BENCH_serving, PR 5).  The paper's
whole premise is that CSRC SpMV is *memory-bound*: per slot the kernel
must stream 12-16 bytes (value + local index [+ transpose value]) and do
O(1) arithmetic.  This module is that streaming formulation, selected by
``ExecutionPlan.variant == 'stream'``:

  * on the compiled TPU target (``interpret=False``) it dispatches to the
    in-kernel streaming bodies of csrc_spmv/csrc_spmm/csrc_spmv_flat/
    csrc_spmv_nnzsplit (`variant='stream'`): per-lane ``jnp.take`` over
    the VMEM x window + segment-sum over the precomputed lane offsets,
    inside the same grid/BlockSpec structure as the one-hot bodies;
  * in interpret mode (the CPU backend of this repo's tests and benches)
    the Pallas grid is *emulated* step by step — per-step slicing installs
    a fixed cost that dwarfs the O(S) kernel math (measured ~1 ms/step
    against ~30 µs of useful work).  There the same per-tile-window
    computation is evaluated as one fused XLA expression over all (tile,
    slot) pairs: one gather + one segment-sum per product term, then the
    unchanged ``overlap_add`` accumulation.  No grid, no emulation floor.

Both routes compute the per-tile windows defined by the one-hot oracle —
the same slots summed into the same window positions — so for dyadic
values the results are bit-identical to the one-hot kernels (the order of
float additions is the only difference; tests/test_stream_variant.py
asserts equality).

Sentinel discipline (shared with the packers): padded slots carry value 0
and column sentinel ``w_pad``; the fused gather clamps the sentinel into
range (0 · x = 0) and the fused scatter maps it to segment id NT·W, one
past the last real segment, so ``segment_sum`` drops it — never an add
into a neighboring tile's window.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.blockell import BlockEll, pad_x, overlap_add, overlap_add_mm
from repro.kernels import csrc_spmv as rect_mod
from repro.kernels import csrc_spmm as rect_mm_mod
from repro.kernels import csrc_spmv_flat as flat_mod
from repro.kernels import csrc_spmv_nnzsplit as nz_mod
from repro.kernels.csrc_spmv_flat import FlatBlockEll
from repro.kernels.csrc_spmv_nnzsplit import NnzSplitPack


# ---------------------------------------------------------------------------
# Windowed packs (rect + flat share the window geometry)
# ---------------------------------------------------------------------------

def _windowed_indices(tile, cols, rows, *, tm: int, w_pad: int, nt: int):
    """Global padded-x gather indices and per-tile segment ids.

    ``tile`` is the row tile of each slot row ((G, 1) int32 — a trivial
    iota for the rectangular grid, ``tile_of_step`` for the flat grid);
    ``cols``/``rows`` are the (G, S) window-local index streams.  The x
    window of tile b starts at padded coordinate (b+1)·tm, so global
    gather index = (b+1)·tm + local; window segment id = b·W + local with
    the column sentinel (== W) routed to the drop segment NT·W.
    """
    xbase = (tile + 1) * tm
    segbase = tile * w_pad
    gcols = (xbase + cols).reshape(-1)
    grows = (xbase + rows).reshape(-1)
    seg_rows = (segbase + rows).reshape(-1)
    seg_cols = jnp.where(cols >= w_pad, nt * w_pad,
                         segbase + cols).reshape(-1)
    return gcols, grows, seg_rows, seg_cols


def _windowed_product(x_full, vl, vu, gcols, grows, seg_rows, seg_cols,
                      *, nt: int, w_pad: int):
    """The fused streaming core: two gathers, two segment-sums, (NT, W)."""
    limit = x_full.shape[0] - 1
    if x_full.ndim == 2:
        xg = jnp.take(x_full, jnp.minimum(gcols, limit), axis=0)
        xi = jnp.take(x_full, grows, axis=0)
        c_rows = (vl[:, None] * xg).astype(jnp.float32)
        c_cols = (vu[:, None] * xi).astype(jnp.float32)
    else:
        xg = jnp.take(x_full, jnp.minimum(gcols, limit))
        xi = jnp.take(x_full, grows)
        c_rows = (vl * xg).astype(jnp.float32)
        c_cols = (vu * xi).astype(jnp.float32)
    wins = jax.ops.segment_sum(c_rows, seg_rows, num_segments=nt * w_pad)
    wins = wins + jax.ops.segment_sum(c_cols, seg_cols,
                                      num_segments=nt * w_pad)
    return wins.reshape((nt, w_pad) + x_full.shape[1:])


def _diag_windows(ad, x_full, *, nt: int, tm: int, w_pad: int):
    xt = x_full[w_pad:w_pad + nt * tm]
    if x_full.ndim == 2:
        diag = ad.astype(jnp.float32).reshape(nt, tm)[..., None] * \
            xt.reshape(nt, tm, -1)
        return jnp.pad(diag, ((0, 0), (w_pad - tm, 0), (0, 0)))
    diag = ad.astype(jnp.float32).reshape(nt, tm) * xt.reshape(nt, tm)
    return jnp.pad(diag, ((0, 0), (w_pad - tm, 0)))


def _rect_streams(pack: BlockEll):
    nt, s = pack.vals_l.shape
    tile = jnp.arange(nt, dtype=jnp.int32)[:, None]
    cols = pack.col_local.astype(jnp.int32)
    rows = pack.row_in_win.astype(jnp.int32)
    vl = pack.vals_l.reshape(-1)
    vu = vl if pack.num_symmetric else pack.vals_u.reshape(-1)
    idx = _windowed_indices(tile, cols, rows, tm=pack.tm,
                            w_pad=pack.w_pad, nt=nt)
    return nt, vl, vu, idx


def blockell_spmv_stream(pack: BlockEll, x: jnp.ndarray,
                         k_step_sublanes: int = 8,
                         interpret: bool = True) -> jnp.ndarray:
    if not interpret:
        return rect_mod.blockell_spmv(pack, x, interpret=False,
                                      k_step_sublanes=k_step_sublanes,
                                      variant="stream")
    nt, vl, vu, idx = _rect_streams(pack)
    x_full = pad_x(pack, x.astype(jnp.float32))
    wins = _windowed_product(x_full, vl, vu, *idx, nt=nt, w_pad=pack.w_pad)
    wins = wins + _diag_windows(pack.ad, x_full, nt=nt, tm=pack.tm,
                                w_pad=pack.w_pad)
    return overlap_add(pack, wins)


def blockell_spmm_stream(pack: BlockEll, X: jnp.ndarray,
                         k_step_sublanes: int = 8,
                         interpret: bool = True) -> jnp.ndarray:
    if not interpret:
        return rect_mm_mod.blockell_spmm(pack, X, interpret=False,
                                         k_step_sublanes=k_step_sublanes,
                                         variant="stream")
    assert X.shape[0] == pack.n
    nt, vl, vu, idx = _rect_streams(pack)
    x_full = jnp.pad(X.astype(jnp.float32),
                     ((pack.w_pad, pack.n_pad - pack.n), (0, 0)))
    wins = _windowed_product(x_full, vl, vu, *idx, nt=nt, w_pad=pack.w_pad)
    wins = wins + _diag_windows(pack.ad, x_full, nt=nt, tm=pack.tm,
                                w_pad=pack.w_pad)
    return overlap_add_mm(pack, wins)


def _flat_streams(pack: FlatBlockEll):
    total = pack.total_steps
    s0 = pack.ks * 128
    tile = pack.tile_of_step.astype(jnp.int32)[:, None]
    cols = pack.col_local.reshape(total, s0).astype(jnp.int32)
    rows = pack.row_in_win.reshape(total, s0).astype(jnp.int32)
    vl = pack.vals_l.reshape(-1)
    vu = vl if pack.num_symmetric else pack.vals_u.reshape(-1)
    idx = _windowed_indices(tile, cols, rows, tm=pack.tm,
                            w_pad=pack.w_pad, nt=pack.nt)
    return vl, vu, idx


def flat_spmv_stream(pack: FlatBlockEll, x: jnp.ndarray,
                     interpret: bool = True) -> jnp.ndarray:
    if not interpret:
        return flat_mod.flat_spmv(pack, x, interpret=False,
                                  variant="stream")
    vl, vu, idx = _flat_streams(pack)
    x_full = jnp.pad(x.astype(jnp.float32),
                     (pack.w_pad, pack.n_pad - pack.n))
    wins = _windowed_product(x_full, vl, vu, *idx, nt=pack.nt,
                             w_pad=pack.w_pad)
    wins = wins + _diag_windows(pack.ad, x_full, nt=pack.nt, tm=pack.tm,
                                w_pad=pack.w_pad)
    return overlap_add(pack, wins)


def flat_spmm_stream(pack: FlatBlockEll, X: jnp.ndarray,
                     interpret: bool = True) -> jnp.ndarray:
    if not interpret:
        return flat_mod.flat_spmm(pack, X, interpret=False,
                                  variant="stream")
    assert X.shape[0] == pack.n
    vl, vu, idx = _flat_streams(pack)
    x_full = jnp.pad(X.astype(jnp.float32),
                     ((pack.w_pad, pack.n_pad - pack.n), (0, 0)))
    wins = _windowed_product(x_full, vl, vu, *idx, nt=pack.nt,
                             w_pad=pack.w_pad)
    wins = wins + _diag_windows(pack.ad, x_full, nt=pack.nt, tm=pack.tm,
                                w_pad=pack.w_pad)
    return overlap_add_mm(pack, wins)


# ---------------------------------------------------------------------------
# Nnz-split chunks
# ---------------------------------------------------------------------------

def _chunk_segments(pack: NnzSplitPack):
    nc = pack.num_chunks
    seg = (jnp.arange(nc, dtype=jnp.int32)[:, None] * pack.r_pad
           + pack.lrow.reshape(nc, pack.s).astype(jnp.int32)).reshape(-1)
    return seg


def nnzsplit_spmv_stream(pack: NnzSplitPack, x: jnp.ndarray,
                         interpret: bool = True) -> jnp.ndarray:
    if not interpret:
        return nz_mod.nnzsplit_spmv(pack, x, interpret=False,
                                    variant="stream")
    x = x.astype(jnp.float32)
    xg = x[pack.src.astype(jnp.int32)]
    c = (pack.vals.reshape(-1).astype(jnp.float32) * xg)
    partial = jax.ops.segment_sum(
        c, _chunk_segments(pack),
        num_segments=pack.num_chunks * pack.r_pad)
    y_pad = jnp.zeros(pack.n + pack.r_pad, jnp.float32
                      ).at[pack.fixup_idx].add(partial)
    return y_pad[:pack.n] + pack.ad.astype(jnp.float32) * x


def nnzsplit_spmm_stream(pack: NnzSplitPack, X: jnp.ndarray,
                         interpret: bool = True) -> jnp.ndarray:
    if not interpret:
        return nz_mod.nnzsplit_spmm(pack, X, interpret=False,
                                    variant="stream")
    n, nrhs = X.shape
    assert n == pack.n
    X = X.astype(jnp.float32)
    xg = X[pack.src.astype(jnp.int32), :]
    c = pack.vals.reshape(-1, 1).astype(jnp.float32) * xg
    partial = jax.ops.segment_sum(
        c, _chunk_segments(pack),
        num_segments=pack.num_chunks * pack.r_pad)
    y_pad = jnp.zeros((pack.n + pack.r_pad, nrhs), jnp.float32
                      ).at[pack.fixup_idx].add(partial)
    return y_pad[:pack.n] + pack.ad.astype(jnp.float32)[:, None] * X
