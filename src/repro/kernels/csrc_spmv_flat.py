"""Flattened-grid variant of the block-ELL CSRC SpMV kernel.

The rectangular (NT, NK) grid of csrc_spmv.py pads every row tile to the
slot count of the densest tile — skewed matrices waste bandwidth on ELL
padding (pad_ratio).  Here each row tile gets only the k-steps it needs:

  * slots are packed flat as (total_ksteps, KS, 128);
  * the grid is 1-D over k-steps; each program learns its row tile from a
    scalar-prefetched ``tile_of_step`` array (pltpu.PrefetchScalarGridSpec
    — the index maps consume the prefetch ref);
  * programs of one row tile are consecutive, so the revisited-output
    window accumulation works exactly as in the rectangular kernel, with
    "first step of my tile" read from a second prefetched flag array.

Cross-tile padding drops from (max_b nk_b)·NT to Σ_b nk_b k-steps — on a
skewed FEM matrix this is the difference between pad_ratio ~3 and ~1.1
(see tests and EXPERIMENTS.md §Perf kernel table).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.csrc import CSRC, bandwidth, row_of_slot
from repro.core.blockell import _round_up, pad_x, overlap_add


@dataclasses.dataclass(frozen=True)
class FlatBlockEll:
    n: int
    tm: int
    nt: int
    w_pad: int
    total_steps: int            # Σ_b nk_b  (k-steps overall)
    ks: int                     # sublanes per k-step
    vals_l: jnp.ndarray         # (total, KS, 128)
    vals_u: jnp.ndarray
    col_local: jnp.ndarray      # (total, KS, 128)
    row_in_win: jnp.ndarray
    ad: jnp.ndarray             # (NT, TM)
    tile_of_step: jnp.ndarray   # (total,) int32 — row tile of each k-step
    first_of_tile: jnp.ndarray  # (total,) int32 — 1 on a tile's first step
    num_symmetric: bool
    pad_ratio: float

    @property
    def n_pad(self) -> int:
        return self.nt * self.tm

    def streamed_bytes(self) -> int:
        b = self.vals_l.size * self.vals_l.dtype.itemsize
        if not self.num_symmetric:
            b += self.vals_u.size * self.vals_u.dtype.itemsize
        b += self.col_local.size * self.col_local.dtype.itemsize
        b += self.row_in_win.size * self.row_in_win.dtype.itemsize
        b += self.ad.size * self.ad.dtype.itemsize
        b += (self.n_pad + self.w_pad) * 4
        b += self.nt * self.w_pad * 4
        return b


def pack_flat(M: CSRC, tm: int = 128, ks: int = 8, w_cap: int = 4096,
              index_dtype=jnp.int32) -> FlatBlockEll:
    """Per-tile-exact packing (no cross-tile ELL padding)."""
    assert M.is_square
    n = M.n
    band = bandwidth(M)
    w_pad = _round_up(tm + band, max(128, tm))
    if w_pad > w_cap:
        raise ValueError(f"window {w_pad} > cap {w_cap}")
    nt = max(1, -(-n // tm))
    step = ks * 128
    ros = row_of_slot(M)
    ja = np.asarray(M.ja)
    al = np.asarray(M.al)
    au = np.asarray(M.au)
    tile_of_slot = ros // tm
    counts = np.bincount(tile_of_slot, minlength=nt)
    nk = np.maximum(1, -(-counts // step))          # k-steps per tile
    total = int(nk.sum())

    vals_l = np.zeros((total, step), np.float32)
    vals_u = np.zeros((total, step), np.float32)
    col_local = np.full((total, step), w_pad, np.int32)
    row_in_win = np.full((total, step), w_pad - 1, np.int32)
    tile_of_step = np.repeat(np.arange(nt, dtype=np.int32), nk)
    first = np.zeros(total, np.int32)
    starts = np.concatenate([[0], np.cumsum(nk)])[:-1]
    first[starts] = 1

    win_lo = (np.arange(nt) + 1) * tm - w_pad
    fill = np.zeros(nt, np.int64)
    for idx in np.argsort(tile_of_slot, kind="stable"):
        t = int(tile_of_slot[idx])
        q = int(fill[t]); fill[t] += 1
        j = int(starts[t]) + q // step
        pos = q % step
        vals_l[j, pos] = al[idx]
        vals_u[j, pos] = au[idx]
        col_local[j, pos] = int(ja[idx]) - int(win_lo[t])
        row_in_win[j, pos] = int(ros[idx]) - int(win_lo[t])

    ad = np.zeros((nt, tm), np.float32)
    ad.reshape(-1)[:n] = np.asarray(M.ad)
    k = max(1, int(ja.shape[0]))
    return FlatBlockEll(
        n=n, tm=tm, nt=nt, w_pad=w_pad, total_steps=total, ks=ks,
        vals_l=jnp.asarray(vals_l.reshape(total, ks, 128)),
        vals_u=jnp.asarray((vals_l if M.numerically_symmetric else vals_u
                            ).reshape(total, ks, 128)),
        col_local=jnp.asarray(col_local.reshape(total, ks, 128),
                              dtype=index_dtype),
        row_in_win=jnp.asarray(row_in_win.reshape(total, ks, 128),
                               dtype=index_dtype),
        ad=jnp.asarray(ad),
        tile_of_step=jnp.asarray(tile_of_step),
        first_of_tile=jnp.asarray(first),
        num_symmetric=bool(M.numerically_symmetric),
        pad_ratio=float(total * step) / k,
    )


def _kernel(tile_ref, first_ref, vals_l_ref, vals_u_ref, col_ref, row_ref,
            ad_ref, x_ref, out_ref, *, tm: int, w_pad: int,
            num_symmetric: bool):
    j = pl.program_id(0)
    b = tile_ref[j]
    start = (b + 1) * tm
    xw = jax.lax.dynamic_slice(x_ref[...], (start,), (w_pad,))

    cols = col_ref[0].astype(jnp.int32)
    rows = row_ref[0].astype(jnp.int32)
    vl = vals_l_ref[0]
    vu = vl if num_symmetric else vals_u_ref[0]
    ks = cols.shape[0]
    s = ks * 128
    iota_w = jax.lax.broadcasted_iota(jnp.int32, (ks, 128, w_pad), 2)
    oh_cols = (cols[..., None] == iota_w).astype(vl.dtype).reshape(s, w_pad)
    oh_rows = (rows[..., None] == iota_w).astype(vl.dtype).reshape(s, w_pad)
    xg = jax.lax.dot_general(oh_cols, xw[:, None], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)[:, 0]
    xi = jax.lax.dot_general(oh_rows, xw[:, None], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)[:, 0]
    c_rows = vl.reshape(-1) * xg
    c_cols = vu.reshape(-1) * xi
    win = jax.lax.dot_general(oh_rows, c_rows[:, None],
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)[:, 0]
    win = win + jax.lax.dot_general(oh_cols, c_cols[:, None],
                                    (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)[:, 0]

    @pl.when(first_ref[j] == 1)
    def _init():
        diag = ad_ref[0] * jax.lax.dynamic_slice(xw, (w_pad - tm,), (tm,))
        base = jnp.zeros((w_pad,), jnp.float32)
        base = jax.lax.dynamic_update_slice(base, diag, (w_pad - tm,))
        out_ref[0] = base + win

    @pl.when(first_ref[j] != 1)
    def _acc():
        out_ref[0] = out_ref[0] + win


def flat_spmv(pack: FlatBlockEll, x: jnp.ndarray,
              interpret: bool = True) -> jnp.ndarray:
    x_full = jnp.pad(x.astype(jnp.float32),
                     (pack.w_pad, pack.n_pad - pack.n))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(pack.total_steps,),
        in_specs=[
            pl.BlockSpec((1, pack.ks, 128), lambda j, tile, first: (j, 0, 0)),
            pl.BlockSpec((1, pack.ks, 128), lambda j, tile, first: (j, 0, 0)),
            pl.BlockSpec((1, pack.ks, 128), lambda j, tile, first: (j, 0, 0)),
            pl.BlockSpec((1, pack.ks, 128), lambda j, tile, first: (j, 0, 0)),
            pl.BlockSpec((1, pack.tm), lambda j, tile, first: (tile[j], 0)),
            pl.BlockSpec(x_full.shape, lambda j, tile, first: (0,)),
        ],
        out_specs=pl.BlockSpec((1, pack.w_pad),
                               lambda j, tile, first: (tile[j], 0)),
    )
    wins = pl.pallas_call(
        functools.partial(_kernel, tm=pack.tm, w_pad=pack.w_pad,
                          num_symmetric=pack.num_symmetric),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((pack.nt, pack.w_pad), jnp.float32),
        interpret=interpret,
    )(pack.tile_of_step, pack.first_of_tile,
      pack.vals_l, pack.vals_u, pack.col_local, pack.row_in_win,
      pack.ad, x_full)
    return overlap_add(pack, wins)
