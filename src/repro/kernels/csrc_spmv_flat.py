"""Flattened-grid variant of the block-ELL CSRC SpMV/SpMM kernels.

The rectangular (NT, NK) grid of csrc_spmv.py pads every row tile to the
slot count of the densest tile — skewed matrices waste bandwidth on ELL
padding (pad_ratio).  Here each row tile gets only the k-steps it needs:

  * slots are packed flat as (total_ksteps, KS, 128);
  * the grid is 1-D over k-steps; each program learns its row tile from a
    scalar-prefetched ``tile_of_step`` array (pltpu.PrefetchScalarGridSpec
    — the index maps consume the prefetch ref);
  * programs of one row tile are consecutive, so the revisited-output
    window accumulation works exactly as in the rectangular kernel, with
    "first step of my tile" read from a second prefetched flag array.

Cross-tile padding drops from (max_b nk_b)·NT to Σ_b nk_b k-steps — on a
skewed FEM matrix this is the difference between pad_ratio ~3 and ~1.1
(see tests/test_flat_path.py and docs/DESIGN.md §4; `benchmarks.run
--only flat` records the rect-vs-flat gap in results/BENCH_flat.json).

The flat path is a first-class registered KernelPath (core/paths.py):
tuner-enumerable on skewed matrices, schedule-cached (`FlatBlockEll` is
the npz-serialized artifact), and executable shard-locally inside every
distributed accumulation strategy via the stacked per-shard layouts at
the bottom of this module (``FlatShards`` for allreduce/reduce_scatter,
``FlatHalo`` for the effective/halo strategy).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.csrc import CSRC, bandwidth, row_of_slot
from repro.core.blockell import _round_up, overlap_add, overlap_add_mm


@dataclasses.dataclass(frozen=True)
class FlatBlockEll:
    n: int
    tm: int
    nt: int
    w_pad: int
    total_steps: int            # Σ_b nk_b  (k-steps overall)
    ks: int                     # sublanes per k-step
    vals_l: jnp.ndarray         # (total, KS, 128)
    vals_u: jnp.ndarray
    col_local: jnp.ndarray      # (total, KS, 128)
    row_in_win: jnp.ndarray
    ad: jnp.ndarray             # (NT, TM)
    tile_of_step: jnp.ndarray   # (total,) int32 — row tile of each k-step
    first_of_tile: jnp.ndarray  # (total,) int32 — 1 on a tile's first step
    num_symmetric: bool
    pad_ratio: float

    @property
    def n_pad(self) -> int:
        return self.nt * self.tm

    def streamed_bytes(self) -> int:
        b = self.vals_l.size * self.vals_l.dtype.itemsize
        if not self.num_symmetric:
            b += self.vals_u.size * self.vals_u.dtype.itemsize
        b += self.col_local.size * self.col_local.dtype.itemsize
        b += self.row_in_win.size * self.row_in_win.dtype.itemsize
        b += self.ad.size * self.ad.dtype.itemsize
        b += (self.n_pad + self.w_pad) * 4
        b += self.nt * self.w_pad * 4
        return b


def _flat_arrays(ros, ja, al, au, *, nt: int, tm: int, w_pad: int,
                 step: int, pad_steps_to: Optional[int] = None):
    """Fill the flat step arrays for one slot set (rows/cols may be global
    or shard-local coordinates — the packer only assumes every slot's
    column lies inside its tile's window).

    Every tile gets at least one k-step so its output window is always
    initialized (the kernel's first-of-tile write).  ``pad_steps_to``
    appends inert trailing steps (zero values, sentinel indices, assigned
    to the last tile so tile programs stay consecutive) — used to equalize
    per-shard step counts for the stacked distributed layouts.
    """
    tile_of_slot = ros // tm
    counts = np.bincount(tile_of_slot, minlength=nt)
    nk = np.maximum(1, -(-counts // step))          # k-steps per tile
    total = int(nk.sum())
    steps = total if pad_steps_to is None else int(pad_steps_to)
    if steps < total:
        raise ValueError(f"pad_steps_to {steps} < required steps {total}")

    vals_l = np.zeros((steps, step), np.float32)
    vals_u = np.zeros((steps, step), np.float32)
    col_local = np.full((steps, step), w_pad, np.int32)
    row_in_win = np.full((steps, step), w_pad - 1, np.int32)
    tile_of_step = np.full(steps, nt - 1, np.int32)
    tile_of_step[:total] = np.repeat(np.arange(nt, dtype=np.int32), nk)
    first = np.zeros(steps, np.int32)
    starts = np.concatenate([[0], np.cumsum(nk)])[:-1]
    first[starts] = 1

    win_lo = (np.arange(nt) + 1) * tm - w_pad
    fill = np.zeros(nt, np.int64)
    for idx in np.argsort(tile_of_slot, kind="stable"):
        t = int(tile_of_slot[idx])
        q = int(fill[t]); fill[t] += 1
        j = int(starts[t]) + q // step
        pos = q % step
        vals_l[j, pos] = al[idx]
        vals_u[j, pos] = au[idx]
        col_local[j, pos] = int(ja[idx]) - int(win_lo[t])
        row_in_win[j, pos] = int(ros[idx]) - int(win_lo[t])
    return vals_l, vals_u, col_local, row_in_win, tile_of_step, first, total


def pack_flat(M: CSRC, tm: int = 128, ks: int = 8, w_cap: int = 4096,
              dtype=jnp.float32, index_dtype=jnp.int32) -> FlatBlockEll:
    """Per-tile-exact packing (no cross-tile ELL padding).

    ``dtype=jnp.bfloat16`` halves the value streams (plan.value_dtype);
    ``index_dtype=jnp.int16`` halves the index streams (plan.index_dtype).
    """
    assert M.is_square
    n = M.n
    band = bandwidth(M)
    w_pad = _round_up(tm + band, max(128, tm))
    if index_dtype == jnp.int16 and w_pad + 1 > 32767:
        raise ValueError(f"window {w_pad} overflows int16 indices")
    if w_pad > w_cap:
        raise ValueError(f"window {w_pad} > cap {w_cap}")
    nt = max(1, -(-n // tm))
    step = ks * 128
    (vals_l, vals_u, col_local, row_in_win, tile_of_step, first,
     total) = _flat_arrays(row_of_slot(M), np.asarray(M.ja),
                           np.asarray(M.al), np.asarray(M.au),
                           nt=nt, tm=tm, w_pad=w_pad, step=step)

    ad = np.zeros((nt, tm), np.float32)
    ad.reshape(-1)[:n] = np.asarray(M.ad)
    k = max(1, int(np.asarray(M.ja).shape[0]))
    return FlatBlockEll(
        n=n, tm=tm, nt=nt, w_pad=w_pad, total_steps=total, ks=ks,
        vals_l=jnp.asarray(vals_l.reshape(total, ks, 128), dtype=dtype),
        vals_u=jnp.asarray((vals_l if M.numerically_symmetric else vals_u
                            ).reshape(total, ks, 128), dtype=dtype),
        col_local=jnp.asarray(col_local.reshape(total, ks, 128),
                              dtype=index_dtype),
        row_in_win=jnp.asarray(row_in_win.reshape(total, ks, 128),
                               dtype=index_dtype),
        ad=jnp.asarray(ad, dtype=dtype),
        tile_of_step=jnp.asarray(tile_of_step),
        first_of_tile=jnp.asarray(first),
        num_symmetric=bool(M.numerically_symmetric),
        pad_ratio=float(total * step) / k,
    )


def refresh_flat_values(pack: FlatBlockEll, M: CSRC) -> FlatBlockEll:
    """Refill a flat pack's value streams from a same-structure matrix
    (FEM time stepping): the step/position map is re-derived vectorized
    from the row pointers — identical to the original fill order (the
    packer's stable sort over a non-decreasing tile array is the identity)
    — and no index stream or tile map is touched."""
    assert M.is_square and M.n == pack.n, "structure mismatch"
    if bool(M.numerically_symmetric) != pack.num_symmetric:
        raise ValueError(
            "numeric symmetry changed; rebuild instead of refreshing")
    step = pack.ks * 128
    vals_l, vals_u = _value_fill_steps(
        row_of_slot(M), np.asarray(M.al), np.asarray(M.au),
        nt=pack.nt, tm=pack.tm, step=step, steps=pack.total_steps,
        num_symmetric=pack.num_symmetric)
    ad = np.zeros((pack.nt, pack.tm), np.float32)
    ad.reshape(-1)[:pack.n] = np.asarray(M.ad)
    vdtype = pack.vals_l.dtype
    return dataclasses.replace(
        pack,
        vals_l=jnp.asarray(vals_l.reshape(pack.total_steps, pack.ks, 128),
                           dtype=vdtype),
        vals_u=jnp.asarray(vals_u.reshape(pack.total_steps, pack.ks, 128),
                           dtype=vdtype),
        ad=jnp.asarray(ad, dtype=pack.ad.dtype))


def _kernel(tile_ref, first_ref, vals_l_ref, vals_u_ref, col_ref, row_ref,
            ad_ref, x_ref, out_ref, *, tm: int, w_pad: int,
            num_symmetric: bool):
    j = pl.program_id(0)
    b = tile_ref[j]
    start = (b + 1) * tm
    xw = jax.lax.dynamic_slice(x_ref[...], (start,), (w_pad,))

    cols = col_ref[0].astype(jnp.int32)
    rows = row_ref[0].astype(jnp.int32)
    vl = vals_l_ref[0]
    vu = vl if num_symmetric else vals_u_ref[0]
    ks = cols.shape[0]
    s = ks * 128
    iota_w = jax.lax.broadcasted_iota(jnp.int32, (ks, 128, w_pad), 2)
    oh_cols = (cols[..., None] == iota_w).astype(vl.dtype).reshape(s, w_pad)
    oh_rows = (rows[..., None] == iota_w).astype(vl.dtype).reshape(s, w_pad)
    xg = jax.lax.dot_general(oh_cols, xw[:, None], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)[:, 0]
    xi = jax.lax.dot_general(oh_rows, xw[:, None], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)[:, 0]
    c_rows = vl.reshape(-1) * xg
    c_cols = vu.reshape(-1) * xi
    win = jax.lax.dot_general(oh_rows, c_rows[:, None],
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)[:, 0]
    win = win + jax.lax.dot_general(oh_cols, c_cols[:, None],
                                    (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)[:, 0]

    @pl.when(first_ref[j] == 1)
    def _init():
        diag = ad_ref[0] * jax.lax.dynamic_slice(xw, (w_pad - tm,), (tm,))
        base = jnp.zeros((w_pad,), jnp.float32)
        base = jax.lax.dynamic_update_slice(base, diag, (w_pad - tm,))
        out_ref[0] = base + win

    @pl.when(first_ref[j] != 1)
    def _acc():
        out_ref[0] = out_ref[0] + win


def _kernel_stream(tile_ref, first_ref, vals_l_ref, vals_u_ref, col_ref,
                   row_ref, ad_ref, x_ref, out_ref, *, tm: int, w_pad: int,
                   num_symmetric: bool):
    """Streaming variant (see csrc_spmv._kernel_stream): per-lane gather +
    segment-sum scatter instead of the (S, W) one-hot contractions."""
    j = pl.program_id(0)
    b = tile_ref[j]
    start = (b + 1) * tm
    xw = jax.lax.dynamic_slice(x_ref[...], (start,), (w_pad,))

    cols = col_ref[0].astype(jnp.int32).reshape(-1)   # (S,), sentinel == W
    rows = row_ref[0].astype(jnp.int32).reshape(-1)
    vl = vals_l_ref[0].reshape(-1)
    vu = vl if num_symmetric else vals_u_ref[0].reshape(-1)

    xg = jnp.take(xw, jnp.minimum(cols, w_pad - 1))
    xi = jnp.take(xw, rows)
    c_rows = vl * xg
    c_cols = vu * xi
    win = jax.ops.segment_sum(c_rows.astype(jnp.float32), rows,
                              num_segments=w_pad)
    win = win + jax.ops.segment_sum(c_cols.astype(jnp.float32), cols,
                                    num_segments=w_pad)

    @pl.when(first_ref[j] == 1)
    def _init():
        diag = ad_ref[0] * jax.lax.dynamic_slice(xw, (w_pad - tm,), (tm,))
        base = jnp.zeros((w_pad,), jnp.float32)
        base = jax.lax.dynamic_update_slice(base, diag, (w_pad - tm,))
        out_ref[0] = base + win

    @pl.when(first_ref[j] != 1)
    def _acc():
        out_ref[0] = out_ref[0] + win


_BODIES = {"onehot": _kernel, "stream": _kernel_stream}


def flat_spmv(pack: FlatBlockEll, x: jnp.ndarray,
              interpret: bool = True,
              variant: str = "onehot") -> jnp.ndarray:
    x_full = jnp.pad(x.astype(jnp.float32),
                     (pack.w_pad, pack.n_pad - pack.n))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(pack.total_steps,),
        in_specs=[
            pl.BlockSpec((1, pack.ks, 128), lambda j, tile, first: (j, 0, 0)),
            pl.BlockSpec((1, pack.ks, 128), lambda j, tile, first: (j, 0, 0)),
            pl.BlockSpec((1, pack.ks, 128), lambda j, tile, first: (j, 0, 0)),
            pl.BlockSpec((1, pack.ks, 128), lambda j, tile, first: (j, 0, 0)),
            pl.BlockSpec((1, pack.tm), lambda j, tile, first: (tile[j], 0)),
            pl.BlockSpec(x_full.shape, lambda j, tile, first: (0,)),
        ],
        out_specs=pl.BlockSpec((1, pack.w_pad),
                               lambda j, tile, first: (tile[j], 0)),
    )
    wins = pl.pallas_call(
        functools.partial(_BODIES[variant], tm=pack.tm, w_pad=pack.w_pad,
                          num_symmetric=pack.num_symmetric),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((pack.nt, pack.w_pad), jnp.float32),
        interpret=interpret,
    )(pack.tile_of_step, pack.first_of_tile,
      pack.vals_l, pack.vals_u, pack.col_local, pack.row_in_win,
      pack.ad, x_full)
    return overlap_add(pack, wins)


def _kernel_mm(tile_ref, first_ref, vals_l_ref, vals_u_ref, col_ref,
               row_ref, ad_ref, x_ref, out_ref, *, tm: int, w_pad: int,
               nrhs: int, num_symmetric: bool):
    j = pl.program_id(0)
    b = tile_ref[j]
    start = (b + 1) * tm
    xw = jax.lax.dynamic_slice(x_ref[...], (start, 0), (w_pad, nrhs))

    cols = col_ref[0].astype(jnp.int32)
    rows = row_ref[0].astype(jnp.int32)
    vl = vals_l_ref[0]
    vu = vl if num_symmetric else vals_u_ref[0]
    ks = cols.shape[0]
    s = ks * 128
    iota_w = jax.lax.broadcasted_iota(jnp.int32, (ks, 128, w_pad), 2)
    oh_cols = (cols[..., None] == iota_w).astype(vl.dtype).reshape(s, w_pad)
    oh_rows = (rows[..., None] == iota_w).astype(vl.dtype).reshape(s, w_pad)

    xg = jax.lax.dot_general(oh_cols, xw, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (S, B)
    xi = jax.lax.dot_general(oh_rows, xw, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    c_rows = vl.reshape(s, 1) * xg
    c_cols = vu.reshape(s, 1) * xi
    win = jax.lax.dot_general(oh_rows, c_rows, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    win = win + jax.lax.dot_general(oh_cols, c_cols,
                                    (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)

    @pl.when(first_ref[j] == 1)
    def _init():
        diag = ad_ref[0][:, None] * jax.lax.dynamic_slice(
            xw, (w_pad - tm, 0), (tm, nrhs))
        base = jnp.zeros((w_pad, nrhs), jnp.float32)
        base = jax.lax.dynamic_update_slice(base, diag, (w_pad - tm, 0))
        out_ref[0] = base + win

    @pl.when(first_ref[j] != 1)
    def _acc():
        out_ref[0] = out_ref[0] + win


def _kernel_mm_stream(tile_ref, first_ref, vals_l_ref, vals_u_ref, col_ref,
                      row_ref, ad_ref, x_ref, out_ref, *, tm: int,
                      w_pad: int, nrhs: int, num_symmetric: bool):
    """Streaming multi-RHS variant: per-lane row gather of the (W, B)
    window + segment-sum scatter — O(B) work per slot."""
    j = pl.program_id(0)
    b = tile_ref[j]
    start = (b + 1) * tm
    xw = jax.lax.dynamic_slice(x_ref[...], (start, 0), (w_pad, nrhs))

    cols = col_ref[0].astype(jnp.int32).reshape(-1)
    rows = row_ref[0].astype(jnp.int32).reshape(-1)
    vl = vals_l_ref[0].reshape(-1)
    vu = vl if num_symmetric else vals_u_ref[0].reshape(-1)

    xg = jnp.take(xw, jnp.minimum(cols, w_pad - 1), axis=0)   # (S, B)
    xi = jnp.take(xw, rows, axis=0)
    c_rows = vl[:, None] * xg
    c_cols = vu[:, None] * xi
    win = jax.ops.segment_sum(c_rows.astype(jnp.float32), rows,
                              num_segments=w_pad)
    win = win + jax.ops.segment_sum(c_cols.astype(jnp.float32), cols,
                                    num_segments=w_pad)

    @pl.when(first_ref[j] == 1)
    def _init():
        diag = ad_ref[0][:, None] * jax.lax.dynamic_slice(
            xw, (w_pad - tm, 0), (tm, nrhs))
        base = jnp.zeros((w_pad, nrhs), jnp.float32)
        base = jax.lax.dynamic_update_slice(base, diag, (w_pad - tm, 0))
        out_ref[0] = base + win

    @pl.when(first_ref[j] != 1)
    def _acc():
        out_ref[0] = out_ref[0] + win


_BODIES_MM = {"onehot": _kernel_mm, "stream": _kernel_mm_stream}


def flat_spmm(pack: FlatBlockEll, X: jnp.ndarray,
              interpret: bool = True,
              variant: str = "onehot") -> jnp.ndarray:
    """Y = A @ X for X (n, B) — the multi-RHS flat-grid product (batched
    serving / block-Krylov shape) with the same per-tile-exact step layout
    as flat_spmv."""
    n, nrhs = X.shape
    assert n == pack.n
    x_full = jnp.pad(X.astype(jnp.float32),
                     ((pack.w_pad, pack.n_pad - pack.n), (0, 0)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(pack.total_steps,),
        in_specs=[
            pl.BlockSpec((1, pack.ks, 128), lambda j, tile, first: (j, 0, 0)),
            pl.BlockSpec((1, pack.ks, 128), lambda j, tile, first: (j, 0, 0)),
            pl.BlockSpec((1, pack.ks, 128), lambda j, tile, first: (j, 0, 0)),
            pl.BlockSpec((1, pack.ks, 128), lambda j, tile, first: (j, 0, 0)),
            pl.BlockSpec((1, pack.tm), lambda j, tile, first: (tile[j], 0)),
            pl.BlockSpec(x_full.shape, lambda j, tile, first: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, pack.w_pad, nrhs),
                               lambda j, tile, first: (tile[j], 0, 0)),
    )
    wins = pl.pallas_call(
        functools.partial(_BODIES_MM[variant], tm=pack.tm, w_pad=pack.w_pad,
                          nrhs=nrhs, num_symmetric=pack.num_symmetric),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((pack.nt, pack.w_pad, nrhs),
                                       jnp.float32),
        interpret=interpret,
    )(pack.tile_of_step, pack.first_of_tile,
      pack.vals_l, pack.vals_u, pack.col_local, pack.row_in_win,
      pack.ad, x_full)
    return overlap_add_mm(pack, wins)


# ---------------------------------------------------------------------------
# Shard-local flat layouts for the distributed strategies
# (consumed through core/schedule.py's memoized builders)
# ---------------------------------------------------------------------------

def _stack_shard_packs(slot_sets, *, nt, tm, w_pad, step, num_symmetric,
                       dtype=jnp.float32, index_dtype=jnp.int32):
    """Build one flat pack per shard and stack on a leading shard axis.

    ``slot_sets`` yields (ros, ja, al, au) per shard.  Step counts are
    equalized across shards (shard_map needs uniform shapes) by padding to
    the widest shard with inert steps.
    """
    per_tile = []
    for ros, ja, al, au in slot_sets:
        counts = np.bincount(ros // tm, minlength=nt)
        per_tile.append(int(np.maximum(1, -(-counts // step)).sum()))
    steps = max(per_tile)
    ks = step // 128
    out = {k: [] for k in ("vals_l", "vals_u", "col_local", "row_in_win",
                           "tile_of_step", "first_of_tile")}
    for ros, ja, al, au in slot_sets:
        (vl, vu, cl, rw, tos, first, _total) = _flat_arrays(
            ros, ja, al, au, nt=nt, tm=tm, w_pad=w_pad, step=step,
            pad_steps_to=steps)
        out["vals_l"].append(vl.reshape(steps, ks, 128))
        out["vals_u"].append((vl if num_symmetric else vu
                              ).reshape(steps, ks, 128))
        out["col_local"].append(cl.reshape(steps, ks, 128))
        out["row_in_win"].append(rw.reshape(steps, ks, 128))
        out["tile_of_step"].append(tos)
        out["first_of_tile"].append(first)
    arrays = {}
    for k, v in out.items():
        dt = (index_dtype if k in ("col_local", "row_in_win")
              else dtype if k in ("vals_l", "vals_u") else None)
        arrays[k] = jnp.asarray(np.stack(v), dtype=dt)
    return steps, arrays


@dataclasses.dataclass(frozen=True)
class FlatShards:
    """Per-shard flat sub-packs of one matrix in *global* coordinates
    (allreduce / reduce_scatter strategies): shard t's pack holds only the
    slots of its partition rows, plus its slice of the diagonal; running
    the flat kernel over it yields the shard's full-length partial y."""
    p: int
    n: int
    tm: int
    nt: int
    w_pad: int
    steps: int                  # uniform k-steps per shard (padded)
    ks: int
    vals_l: jnp.ndarray         # (p, steps, KS, 128)
    vals_u: jnp.ndarray
    col_local: jnp.ndarray
    row_in_win: jnp.ndarray
    ad: jnp.ndarray             # (p, NT, TM) — shard-owned diagonal
    tile_of_step: jnp.ndarray   # (p, steps)
    first_of_tile: jnp.ndarray  # (p, steps)
    num_symmetric: bool

    def shard_pack(self, t: int) -> FlatBlockEll:
        """Shard t's pack as a standalone FlatBlockEll (also the shape the
        shard_map local function rebuilds from its slices)."""
        return FlatBlockEll(
            n=self.n, tm=self.tm, nt=self.nt, w_pad=self.w_pad,
            total_steps=self.steps, ks=self.ks,
            vals_l=self.vals_l[t], vals_u=self.vals_u[t],
            col_local=self.col_local[t], row_in_win=self.row_in_win[t],
            ad=self.ad[t], tile_of_step=self.tile_of_step[t],
            first_of_tile=self.first_of_tile[t],
            num_symmetric=self.num_symmetric, pad_ratio=1.0)


def pack_flat_shards(M: CSRC, starts, tm: int = 128, ks: int = 8,
                     w_cap: int = 4096, dtype=jnp.float32,
                     index_dtype=jnp.int32) -> FlatShards:
    """Split a square CSRC matrix into per-shard flat packs along the row
    partition ``starts`` ((p+1,) boundaries from the schedule layer)."""
    assert M.is_square
    n = M.n
    band = bandwidth(M)
    w_pad = _round_up(tm + band, max(128, tm))
    if index_dtype == jnp.int16 and w_pad + 1 > 32767:
        raise ValueError(f"window {w_pad} overflows int16 indices")
    if w_pad > w_cap:
        raise ValueError(f"window {w_pad} > cap {w_cap}")
    nt = max(1, -(-n // tm))
    step = ks * 128
    starts = np.asarray(starts, dtype=np.int64)
    p = starts.shape[0] - 1
    ros = row_of_slot(M)
    ja = np.asarray(M.ja)
    al = np.asarray(M.al)
    au = np.asarray(M.au)

    def slot_sets():
        for t in range(p):
            sel = (ros >= starts[t]) & (ros < starts[t + 1])
            yield ros[sel], ja[sel], al[sel], au[sel]

    steps, arrays = _stack_shard_packs(
        list(slot_sets()), nt=nt, tm=tm, w_pad=w_pad, step=step,
        num_symmetric=M.numerically_symmetric, dtype=dtype,
        index_dtype=index_dtype)

    ad = np.zeros((p, nt * tm), np.float32)
    ad_full = np.asarray(M.ad)
    for t in range(p):
        r0, r1 = int(starts[t]), int(starts[t + 1])
        ad[t, r0:r1] = ad_full[r0:r1]
    return FlatShards(
        p=p, n=n, tm=tm, nt=nt, w_pad=w_pad, steps=steps, ks=ks,
        ad=jnp.asarray(ad.reshape(p, nt, tm), dtype=dtype),
        num_symmetric=bool(M.numerically_symmetric), **arrays)


@dataclasses.dataclass(frozen=True)
class FlatHalo:
    """Per-shard flat packs in *local* halo coordinates (the paper's
    effective-accumulation strategy): shard t owns ns rows; its local
    matrix covers rows [r0-h, r1) of y, i.e. n_local = ns + h rows with
    the halo rows first — exactly the y_ext/x_ext layout of
    schedule.build_halo_layout, but executed by the flat kernel."""
    p: int
    ns: int                     # rows per shard (8-aligned)
    h: int                      # halo width (8-aligned bandwidth)
    n_local: int                # ns + h
    tm: int
    nt: int                     # local row tiles: ceil(n_local / tm)
    w_pad: int
    steps: int
    ks: int
    vals_l: jnp.ndarray         # (p, steps, KS, 128)
    vals_u: jnp.ndarray
    col_local: jnp.ndarray
    row_in_win: jnp.ndarray
    ad: jnp.ndarray             # (p, NT, TM) local-coordinate diagonal
    tile_of_step: jnp.ndarray
    first_of_tile: jnp.ndarray
    num_symmetric: bool

    def shard_pack(self, t: int) -> FlatBlockEll:
        return FlatBlockEll(
            n=self.n_local, tm=self.tm, nt=self.nt, w_pad=self.w_pad,
            total_steps=self.steps, ks=self.ks,
            vals_l=self.vals_l[t], vals_u=self.vals_u[t],
            col_local=self.col_local[t], row_in_win=self.row_in_win[t],
            ad=self.ad[t], tile_of_step=self.tile_of_step[t],
            first_of_tile=self.first_of_tile[t],
            num_symmetric=self.num_symmetric, pad_ratio=1.0)


def pack_flat_halo(M: CSRC, p: int, tm: int = 128, ks: int = 8,
                   w_cap: int = 4096, dtype=jnp.float32,
                   index_dtype=jnp.int32) -> FlatHalo:
    """Per-shard local flat packs for the halo strategy.  Raises ValueError
    when the band does not fit inside one shard (same feasibility gate as
    schedule.build_halo_layout) or the local window exceeds ``w_cap``."""
    assert M.is_square
    n = M.n
    ns = _round_up(-(-n // p), 8)
    band = bandwidth(M)
    h = max(8, _round_up(band, 8))
    if h > ns:
        raise ValueError(
            f"band {band} exceeds shard rows {ns}; halo strategy needs "
            "band <= n/p (fall back to allreduce/reduce_scatter)")
    n_local = ns + h
    # every local row i stores columns in [i-h, i]: bandwidth_local <= h
    w_pad = _round_up(tm + h, max(128, tm))
    if index_dtype == jnp.int16 and w_pad + 1 > 32767:
        raise ValueError(f"window {w_pad} overflows int16 indices")
    if w_pad > w_cap:
        raise ValueError(f"window {w_pad} > cap {w_cap}")
    nt = max(1, -(-n_local // tm))
    step = ks * 128

    ros = row_of_slot(M)
    ja = np.asarray(M.ja)
    al = np.asarray(M.al)
    au = np.asarray(M.au)
    shard_of_slot = ros // ns

    def slot_sets():
        for t in range(p):
            sel = shard_of_slot == t
            # local row r0+i -> h+i; column j -> j - (r0 - h)
            yield (ros[sel] - t * ns + h, ja[sel] - (t * ns - h),
                   al[sel], au[sel])

    steps, arrays = _stack_shard_packs(
        list(slot_sets()), nt=nt, tm=tm, w_pad=w_pad, step=step,
        num_symmetric=M.numerically_symmetric, dtype=dtype,
        index_dtype=index_dtype)

    ad = np.zeros((p, nt * tm), np.float32)
    ad_full = np.asarray(M.ad)
    for t in range(p):
        r0 = t * ns
        r1 = min(n, r0 + ns)
        if r1 > r0:
            ad[t, h:h + (r1 - r0)] = ad_full[r0:r1]
    return FlatHalo(
        p=p, ns=ns, h=h, n_local=n_local, tm=tm, nt=nt, w_pad=w_pad,
        steps=steps, ks=ks,
        ad=jnp.asarray(ad.reshape(p, nt, tm), dtype=dtype),
        num_symmetric=bool(M.numerically_symmetric), **arrays)


# ---------------------------------------------------------------------------
# Same-structure value refresh of the stacked shard layouts (the mesh-path
# analog of refresh_flat_values: FEM time stepping / serving update_values
# must not re-pack or re-partition on the mesh)
# ---------------------------------------------------------------------------

def _value_fill_steps(ros, al, au, *, nt, tm, step, steps, num_symmetric):
    """Vectorized value-only refill of one shard's flat step arrays.

    ``ros`` is the shard's slot rows (global or local coordinates),
    non-decreasing — exactly the order `_flat_arrays` filled with (its
    stable sort over a non-decreasing tile array is the identity), so the
    (step, position) map is re-derived without touching index streams.
    """
    k = ros.shape[0]
    vals_l = np.zeros((steps, step), np.float32)
    vals_u = vals_l if num_symmetric else np.zeros((steps, step), np.float32)
    if k:
        tile = ros // tm
        counts = np.bincount(tile, minlength=nt)
        nk = np.maximum(1, -(-counts // step))
        starts = np.concatenate([[0], np.cumsum(nk)])[:-1]
        first_slot = np.searchsorted(tile, np.arange(nt))
        q = np.arange(k) - first_slot[tile]
        j = starts[tile] + q // step
        pos = q % step
        vals_l[j, pos] = al
        if not num_symmetric:
            vals_u[j, pos] = au
    return vals_l, vals_u


def refresh_flat_shards(fs: FlatShards, M: CSRC, starts) -> FlatShards:
    """Refill a FlatShards stack's value streams from a same-structure
    matrix over the same partition ``starts`` — no index stream, tile map,
    or step-count work."""
    assert M.is_square and M.n == fs.n, "structure mismatch"
    if bool(M.numerically_symmetric) != fs.num_symmetric:
        raise ValueError(
            "numeric symmetry changed; rebuild instead of refreshing")
    starts = np.asarray(starts, dtype=np.int64)
    ros = row_of_slot(M)
    al = np.asarray(M.al)
    au = np.asarray(M.au)
    step = fs.ks * 128
    vls, vus = [], []
    for t in range(fs.p):
        sel = (ros >= starts[t]) & (ros < starts[t + 1])
        vl, vu = _value_fill_steps(
            ros[sel], al[sel], au[sel], nt=fs.nt, tm=fs.tm, step=step,
            steps=fs.steps, num_symmetric=fs.num_symmetric)
        vls.append(vl.reshape(fs.steps, fs.ks, 128))
        vus.append(vu.reshape(fs.steps, fs.ks, 128))
    ad = np.zeros((fs.p, fs.nt * fs.tm), np.float32)
    ad_full = np.asarray(M.ad)
    for t in range(fs.p):
        r0, r1 = int(starts[t]), int(starts[t + 1])
        ad[t, r0:r1] = ad_full[r0:r1]
    vdtype = fs.vals_l.dtype
    return dataclasses.replace(
        fs,
        vals_l=jnp.asarray(np.stack(vls), dtype=vdtype),
        vals_u=jnp.asarray(np.stack(vus), dtype=vdtype),
        ad=jnp.asarray(ad.reshape(fs.p, fs.nt, fs.tm),
                       dtype=fs.ad.dtype))


def refresh_flat_halo(lay: FlatHalo, M: CSRC) -> FlatHalo:
    """Refill a FlatHalo stack's value streams from a same-structure
    matrix (local halo coordinates re-derived from the layout geometry)."""
    assert M.is_square, "structure mismatch"
    if bool(M.numerically_symmetric) != lay.num_symmetric:
        raise ValueError(
            "numeric symmetry changed; rebuild instead of refreshing")
    n = M.n
    ros = row_of_slot(M)
    al = np.asarray(M.al)
    au = np.asarray(M.au)
    shard_of_slot = ros // lay.ns
    step = lay.ks * 128
    vls, vus = [], []
    for t in range(lay.p):
        sel = shard_of_slot == t
        vl, vu = _value_fill_steps(
            ros[sel] - t * lay.ns + lay.h, al[sel], au[sel],
            nt=lay.nt, tm=lay.tm, step=step, steps=lay.steps,
            num_symmetric=lay.num_symmetric)
        vls.append(vl.reshape(lay.steps, lay.ks, 128))
        vus.append(vu.reshape(lay.steps, lay.ks, 128))
    ad = np.zeros((lay.p, lay.nt * lay.tm), np.float32)
    ad_full = np.asarray(M.ad)
    for t in range(lay.p):
        r0 = t * lay.ns
        r1 = min(n, r0 + lay.ns)
        if r1 > r0:
            ad[t, lay.h:lay.h + (r1 - r0)] = ad_full[r0:r1]
    vdtype = lay.vals_l.dtype
    return dataclasses.replace(
        lay,
        vals_l=jnp.asarray(np.stack(vls), dtype=vdtype),
        vals_u=jnp.asarray(np.stack(vus), dtype=vdtype),
        ad=jnp.asarray(ad.reshape(lay.p, lay.nt, lay.tm),
                       dtype=lay.ad.dtype))


# --- shard_map plumbing (ShardSupport hooks) -------------------------------

def flat_shard_arrays(fs):
    """Leading-axis-p arrays a shard_map local function consumes."""
    return (fs.tile_of_step, fs.first_of_tile, fs.vals_l, fs.vals_u,
            fs.col_local, fs.row_in_win, fs.ad)


def flat_shard_specs(axis: str):
    from jax.sharding import PartitionSpec as P
    return (P(axis, None), P(axis, None),
            P(axis, None, None, None), P(axis, None, None, None),
            P(axis, None, None, None), P(axis, None, None, None),
            P(axis, None, None))


def flat_local_fn(fs, n_local: int, interpret: bool,
                  variant: str = "onehot"):
    """Shard-local flat-grid product: rebuild the shard's FlatBlockEll from
    the shard_map-sliced stacked arrays and run the Pallas kernel (SpMV or
    SpMM by x rank).  ``fs`` is a FlatShards or FlatHalo layout."""
    def local_y(tile, first, vals_l, vals_u, col, row, ad, x):
        pk = FlatBlockEll(
            n=n_local, tm=fs.tm, nt=fs.nt, w_pad=fs.w_pad,
            total_steps=fs.steps, ks=fs.ks,
            vals_l=vals_l[0], vals_u=vals_u[0], col_local=col[0],
            row_in_win=row[0], ad=ad[0], tile_of_step=tile[0],
            first_of_tile=first[0],
            num_symmetric=fs.num_symmetric, pad_ratio=1.0)
        if x.ndim == 2:
            return flat_spmm(pk, x, interpret=interpret, variant=variant)
        return flat_spmv(pk, x, interpret=interpret, variant=variant)

    return local_y


def flat_halo_dims(lay: FlatHalo):
    return lay.ns, lay.h, lay.n_local
