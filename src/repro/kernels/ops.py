"""Public jit'd entry points for the CSRC SpMV/SpMM kernels.

``SpmvOperator`` executes an :class:`repro.core.plan.ExecutionPlan` through
an :class:`repro.core.schedule.SpmvSchedule` — the precomputed artifact
bundling the pack, row partition/halo ranges, and coloring the plan needs
(core/schedule.py).  The operator never packs, partitions, or colors
inline: it asks the schedule layer (and, given ``cache=``, reuses the
artifact stored next to the plan in the tuner's PlanCache).

Dispatch is registry-driven: the plan's path resolves to its
:class:`~repro.core.paths.KernelPath` entry, whose executor factories
produce the SpMV and SpMM callables — this module contains no per-path
``if`` chain, so a newly registered path executes here with zero edits.

Registered paths (core/paths.py):

  * 'kernel'   rectangular-grid block-ELL Pallas kernel when the matrix is
    banded enough to window (interpret-mode on CPU, compiled on TPU);
  * 'flat'     flat-grid block-ELL Pallas kernel — per-tile-exact k-steps,
    no cross-tile ELL padding (skewed row-length matrices);
  * 'segment'  segment-sum jnp path (any matrix, incl. the rectangular tail);
  * 'colorful' the paper's §3.2 color-by-color permutation writes, over the
    schedule's precomputed per-color slot batches.

Every path accepts ``x`` of shape (m,) — classic SpMV — or (m, r) —
multi-RHS SpMM (batched serving, block-Krylov solvers).  Construction
accepts either a fully-resolved plan (``from_plan``, the tuner path) or the
legacy keyword form where ``path='auto'`` resolves to
kernel-if-packable-else-segment (the paper's static fallback).  Either way
the operator *emits* the concrete plan it runs as ``op.plan`` and the
artifact as ``op.schedule``, so callers can cache, log, or replay both.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.csrc import CSRC
from repro.core import paths as paths_mod
from repro.core import schedule as schedule_mod
from repro.core.plan import ExecutionPlan
from . import ref


class SpmvOperator:
    """A prepared y = A·x / Y = A·X for repeated application.

    Builds (or fetches from ``cache``) the schedule once, jits once per RHS
    rank; call like a function with x of shape (m,) or (m, r).  ``path`` is
    one of 'auto' | 'kernel' | 'segment' | 'colorful'; or pass ``plan=`` /
    use :meth:`from_plan` to pin every degree of freedom.
    """

    def __init__(self, M: CSRC, path: str = "auto", tm: int = 128,
                 w_cap: int = 4096, interpret: bool = True,
                 coloring=None, k_step: int = 1024,
                 plan: Optional[ExecutionPlan] = None,
                 schedule: Optional["schedule_mod.SpmvSchedule"] = None,
                 cache=None):
        self.M = M
        self.n, self.m = M.n, M.m
        ks_sub = max(1, k_step // 128)

        if plan is None and schedule is not None:
            plan = schedule.plan
        if plan is None:
            if path == "auto":
                base = ExecutionPlan(path="kernel", tm=tm, w_cap=w_cap,
                                     k_step_sublanes=ks_sub)
                if M.is_square:
                    try:
                        schedule = schedule_mod.schedule_for(
                            M, base, cache=cache)
                        plan = base
                    except ValueError:      # bandwidth gate: static fallback
                        plan = dataclasses.replace(base, path="segment")
                else:
                    plan = dataclasses.replace(base, path="segment")
            else:
                plan = ExecutionPlan(path=path, tm=tm, w_cap=w_cap,
                                     k_step_sublanes=ks_sub)

        if schedule is None:
            # strict: an infeasible kernel plan or a square-only plan on a
            # rectangular matrix raises here (no silent fallback)
            schedule = schedule_mod.schedule_for(M, plan, cache=cache,
                                                 coloring=coloring)
        elif (schedule_mod.plan_artifact_fields(schedule.plan)
              != schedule_mod.plan_artifact_fields(plan)):
            raise ValueError(
                f"schedule was built for {schedule.plan.key()} and cannot "
                f"execute plan {plan.key()}")
        self.plan = plan
        self.path = plan.path
        self.interpret = interpret
        self._bind(M, schedule, coloring=coloring)

    def _bind(self, M: CSRC, schedule, coloring=None):
        """Install the schedule and (re)build both jit'd executors through
        the registry — shared by construction and ``update_values``."""
        self.M = M
        self.schedule = schedule
        self.pack = next(
            (pk for pk in (schedule.pack, schedule.flat_pack,
                           schedule.nnzsplit_pack) if pk is not None),
            None)
        self.coloring = schedule.coloring if coloring is None else coloring

        # registry dispatch: the path's KernelPath entry builds both
        # executors from the schedule artifact (no per-path if chain here)
        try:
            entry = paths_mod.get_path(self.path)
        except KeyError as e:
            raise ValueError(str(e)) from None
        spmv_fn = entry.make_spmv(
            M, schedule, self.plan, interpret=self.interpret,
            coloring=coloring)
        if entry.make_spmm is entry.make_spmv:
            # one factory registered for both shapes (e.g. colorful):
            # construct once, share the executor
            spmm_fn = spmv_fn
        else:
            spmm_fn = entry.make_spmm(
                M, schedule, self.plan, interpret=self.interpret,
                coloring=coloring)
        self._fn = jax.jit(spmv_fn)
        self._fn_mm = jax.jit(spmm_fn)

    def update_values(self, M: CSRC) -> "SpmvOperator":
        """Value-refresh fast path: swap in a matrix with **identical
        structure** (FEM time stepping — re-assembled values on a fixed
        connectivity).  Only the schedule's value streams are refreshed
        (``schedule.refresh_schedule``); no re-pack, no re-partition, no
        re-coloring — ``BUILD_COUNTS`` records a single ``value_refresh``.
        Raises ValueError when the structure actually differs."""
        refreshed = schedule_mod.refresh_schedule(self.schedule, M)
        self._bind(M, refreshed)
        return self

    @classmethod
    def from_plan(cls, M: CSRC, plan: ExecutionPlan,
                  interpret: bool = True, coloring=None, cache=None,
                  schedule=None) -> "SpmvOperator":
        """Strict construction: the plan's path is executed as given (a
        'kernel' plan whose window does not fit raises ValueError).  Pass
        ``cache=`` (a PlanCache) to reuse the stored schedule artifact."""
        return cls(M, interpret=interpret, coloring=coloring, plan=plan,
                   cache=cache, schedule=schedule)

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        if x.ndim == 2:
            return self._fn_mm(x)
        return self._fn(x)

    @property
    def flops_per_call(self) -> int:
        """Useful flops (paper §4.1): n mul + (nnz-n) fma = 2·nnz - n."""
        return 2 * self.M.nnz - self.M.n

    @property
    def bytes_per_call(self) -> int:
        if self.pack is not None:
            return self.pack.streamed_bytes()
        return self.M.working_set_bytes()


def spmv(M: CSRC, x: jnp.ndarray, path: str = "auto",
         interpret: bool = True,
         plan: Optional[ExecutionPlan] = None) -> jnp.ndarray:
    """One-shot convenience wrapper."""
    return SpmvOperator(M, path=path, interpret=interpret, plan=plan)(x)


def spmv_transpose(M: CSRC, x: jnp.ndarray) -> jnp.ndarray:
    """A^T·x — the paper's O(1) transpose (swap al/au)."""
    return ref.csrc_spmv_transpose(M, x)


def spmm(M: CSRC, X: jnp.ndarray) -> jnp.ndarray:
    """Multi-RHS product (batched serving path)."""
    return ref.csrc_spmm(M, X)
