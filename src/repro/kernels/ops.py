"""Public jit'd entry points for the CSRC SpMV kernels.

``spmv(M, x)`` picks the best available path:

  * block-ELL Pallas kernel when the matrix is banded enough to window
    (interpret-mode on CPU, compiled on TPU);
  * segment-sum jnp path otherwise (the paper's finding: unbanded matrices
    defeat locality strategies — cage15/F1 analogue).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.csrc import CSRC
from repro.core import blockell
from . import ref
from . import csrc_spmv as kernel_mod


class SpmvOperator:
    """A prepared SpMV y = A·x for repeated application (iterative solvers).

    Packs once, jits once; call like a function.  ``path`` is one of
    'auto' | 'kernel' | 'segment' | 'colorful'.
    """

    def __init__(self, M: CSRC, path: str = "auto", tm: int = 128,
                 w_cap: int = 4096, interpret: bool = True,
                 coloring=None):
        self.M = M
        self.n, self.m = M.n, M.m
        self.pack = None
        self.path = path
        if path in ("auto", "kernel") and M.is_square:
            try:
                self.pack = blockell.pack(M, tm=tm, w_cap=w_cap)
                self.path = "kernel"
            except ValueError:
                if path == "kernel":
                    raise
                self.path = "segment"
        elif path == "colorful":
            from repro.core.coloring import color_rows
            self.coloring = coloring or color_rows(M)
        else:
            self.path = "segment" if path == "auto" else path

        if self.path == "kernel":
            p = self.pack
            self._fn = jax.jit(functools.partial(
                kernel_mod.blockell_spmv, p, interpret=interpret))
        elif self.path == "segment":
            self._fn = jax.jit(lambda x: ref.csrc_spmv(M, x))
        elif self.path == "colorful":
            col = self.coloring
            self._fn = jax.jit(lambda x: ref.colorful_spmv(M, x, col))
        else:
            raise ValueError(f"unknown path {path}")

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        return self._fn(x)

    @property
    def flops_per_call(self) -> int:
        """Useful flops (paper §4.1): n mul + (nnz-n) fma = 2·nnz - n."""
        return 2 * self.M.nnz - self.M.n

    @property
    def bytes_per_call(self) -> int:
        if self.pack is not None:
            return self.pack.streamed_bytes()
        return self.M.working_set_bytes()


def spmv(M: CSRC, x: jnp.ndarray, path: str = "auto",
         interpret: bool = True) -> jnp.ndarray:
    """One-shot convenience wrapper."""
    return SpmvOperator(M, path=path, interpret=interpret)(x)


def spmv_transpose(M: CSRC, x: jnp.ndarray) -> jnp.ndarray:
    """A^T·x — the paper's O(1) transpose (swap al/au)."""
    return ref.csrc_spmv_transpose(M, x)


def spmm(M: CSRC, X: jnp.ndarray) -> jnp.ndarray:
    """Multi-RHS product (batched serving path)."""
    return ref.csrc_spmm(M, X)
