"""Public jit'd entry points for the CSRC SpMV kernels.

``SpmvOperator`` executes an :class:`repro.core.plan.ExecutionPlan`:

  * 'kernel'   block-ELL Pallas kernel when the matrix is banded enough to
    window (interpret-mode on CPU, compiled on TPU);
  * 'segment'  segment-sum jnp path (any matrix, incl. the rectangular tail);
  * 'colorful' the paper's §3.2 color-by-color permutation writes.

Construction accepts either a fully-resolved plan (``from_plan``, the
tuner path) or the legacy keyword form where ``path='auto'`` resolves to
kernel-if-packable-else-segment (the paper's static fallback).  Either
way the operator *emits* the concrete plan it runs as ``op.plan``, so
callers can cache, log, or replay the decision.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.csrc import CSRC
from repro.core import blockell
from repro.core.plan import ExecutionPlan
from . import ref
from . import csrc_spmv as kernel_mod


class SpmvOperator:
    """A prepared SpMV y = A·x for repeated application (iterative solvers).

    Packs once, jits once; call like a function.  ``path`` is one of
    'auto' | 'kernel' | 'segment' | 'colorful'; or pass ``plan=`` /
    use :meth:`from_plan` to pin every degree of freedom.
    """

    def __init__(self, M: CSRC, path: str = "auto", tm: int = 128,
                 w_cap: int = 4096, interpret: bool = True,
                 coloring=None, k_step: int = 1024,
                 plan: Optional[ExecutionPlan] = None):
        if plan is not None:
            path, tm, w_cap = plan.path, plan.tm, plan.w_cap
            k_step = plan.k_step
        self.M = M
        self.n, self.m = M.n, M.m
        self.pack = None
        self.coloring = coloring
        self.path = path
        if path in ("auto", "kernel") and M.is_square:
            try:
                self.pack = blockell.pack(M, tm=tm, k_step=k_step,
                                          w_cap=w_cap)
                self.path = "kernel"
            except ValueError:
                if path == "kernel":
                    raise
                self.path = "segment"
        elif path == "kernel":
            raise ValueError(
                "kernel path packs the square CSRC part only; "
                "use 'segment' for rectangular matrices")
        elif path == "colorful":
            if not M.is_square:
                raise ValueError(
                    "colorful path covers the square CSRC part only; "
                    "use 'segment' for rectangular matrices")
            from repro.core.coloring import color_rows
            self.coloring = coloring or color_rows(M)
        else:
            self.path = "segment" if path == "auto" else path

        if self.path == "kernel":
            p = self.pack
            self._fn = jax.jit(functools.partial(
                kernel_mod.blockell_spmv, p, interpret=interpret))
        elif self.path == "segment":
            self._fn = jax.jit(lambda x: ref.csrc_spmv(M, x))
        elif self.path == "colorful":
            col = self.coloring
            self._fn = jax.jit(lambda x: ref.colorful_spmv(M, x, col))
        else:
            raise ValueError(f"unknown path {path}")

        # the concrete plan this operator executes (legacy 'auto' resolved)
        if plan is not None and plan.path == self.path:
            self.plan = plan
        else:
            self.plan = ExecutionPlan(
                path=self.path, tm=tm, w_cap=w_cap,
                k_step_sublanes=max(1, k_step // 128))

    @classmethod
    def from_plan(cls, M: CSRC, plan: ExecutionPlan,
                  interpret: bool = True, coloring=None) -> "SpmvOperator":
        """Strict construction: the plan's path is executed as given (a
        'kernel' plan whose window does not fit raises ValueError)."""
        return cls(M, interpret=interpret, coloring=coloring, plan=plan)

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        return self._fn(x)

    @property
    def flops_per_call(self) -> int:
        """Useful flops (paper §4.1): n mul + (nnz-n) fma = 2·nnz - n."""
        return 2 * self.M.nnz - self.M.n

    @property
    def bytes_per_call(self) -> int:
        if self.pack is not None:
            return self.pack.streamed_bytes()
        return self.M.working_set_bytes()


def spmv(M: CSRC, x: jnp.ndarray, path: str = "auto",
         interpret: bool = True,
         plan: Optional[ExecutionPlan] = None) -> jnp.ndarray:
    """One-shot convenience wrapper."""
    return SpmvOperator(M, path=path, interpret=interpret, plan=plan)(x)


def spmv_transpose(M: CSRC, x: jnp.ndarray) -> jnp.ndarray:
    """A^T·x — the paper's O(1) transpose (swap al/au)."""
    return ref.csrc_spmv_transpose(M, x)


def spmm(M: CSRC, X: jnp.ndarray) -> jnp.ndarray:
    """Multi-RHS product (batched serving path)."""
    return ref.csrc_spmm(M, X)
