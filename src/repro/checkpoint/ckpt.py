"""Sharded, atomic, resumable checkpoints (no orbax in the container).

Layout:
  <dir>/step_000123.tmp-<nonce>/     (written, then atomically renamed)
      manifest.json                  (treedef, shapes, dtypes, step)
      arrays.npz                     (one entry per leaf, keyed by path)
  <dir>/step_000123/

Properties required at fleet scale and tested here:
  * atomicity — a crash mid-write never corrupts the latest checkpoint
    (tmp dir + rename; readers only see complete renames);
  * keep-k garbage collection;
  * restore-to-template resharding — arrays are device_put against the
    target sharding at load, so restarts may use a different mesh/device
    count (elastic restart);
  * async save — the host gather + write runs on a worker thread while
    training continues (fault tolerance without step-time hiccups).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import uuid
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    keys = []
    for path, _leaf in flat:
        parts = []
        for e in path:
            if hasattr(e, "key"):
                parts.append(str(e.key))
            elif hasattr(e, "idx"):
                parts.append(str(e.idx))
            elif hasattr(e, "name"):
                parts.append(str(e.name))
            else:
                parts.append(str(e))
        keys.append("/".join(parts))
    return keys, [l for _, l in flat]


def save(directory: str, step: int, tree, keep: int = 3,
         blocking: bool = True) -> str:
    os.makedirs(directory, exist_ok=True)
    keys, leaves = _paths(tree)
    # gather to host (works for sharded arrays: device_get assembles)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]

    def write():
        tmp = os.path.join(directory, f"step_{step:09d}.tmp-{uuid.uuid4().hex[:8]}")
        os.makedirs(tmp, exist_ok=True)
        arrays = {f"a{i}": a for i, a in enumerate(host_leaves)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "keys": keys,
            "dtypes": [str(a.dtype) for a in host_leaves],
            "shapes": [list(a.shape) for a in host_leaves],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(directory, f"step_{step:09d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(directory, keep)

    if blocking:
        write()
    else:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    return os.path.join(directory, f"step_{step:09d}")


def _gc(directory: str, keep: int):
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"),
                      ignore_errors=True)
    # drop orphaned tmp dirs (crashed writers)
    for d in os.listdir(directory):
        if ".tmp-" in d:
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        m = _STEP_RE.match(d)
        if m and os.path.exists(os.path.join(directory, d, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, template) -> Any:
    """Restore into the structure (and shardings) of ``template``.

    Template leaves may be jax.Arrays (their sharding is reused — elastic
    resharding) or ShapeDtypeStructs.
    """
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    keys, leaves = _paths(template)

    def fix_dtype(a, name):
        # npz round-trips ml_dtypes (bfloat16 etc.) as void — view back
        if a.dtype.kind == "V":
            import ml_dtypes
            a = a.view(np.dtype(getattr(ml_dtypes, name)))
        return a

    by_key = {k: fix_dtype(data[f"a{i}"], manifest["dtypes"][i])
              for i, k in enumerate(manifest["keys"])}
    out = []
    for k, tmpl in zip(keys, leaves):
        if k not in by_key:
            raise KeyError(f"checkpoint missing leaf {k!r}")
        a = by_key[k]
        if tuple(a.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"shape mismatch for {k}: ckpt {a.shape} vs {tmpl.shape}")
        sharding = getattr(tmpl, "sharding", None)
        arr = jax.device_put(a.astype(tmpl.dtype), sharding) \
            if sharding is not None else jax.device_put(a.astype(tmpl.dtype))
        out.append(arr)
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, out)
