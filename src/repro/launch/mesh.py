"""Production mesh construction.

A function, not a module-level constant — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).

Single pod: (data=16, model=16) = 256 chips (one v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis carries
cross-pod data parallelism (its collectives cross DCI, which is why it is a
separate axis — the roofline charges them separately).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(devices: int = 8, model: int = 2):
    """Small mesh over fake devices for subprocess tests."""
    data = devices // model
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
