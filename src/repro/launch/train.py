"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Full configs target the production mesh (--mesh single|multi requires the
matching device fleet or the dry-run's placeholder devices); --reduced runs
the family-preserving small config on whatever devices exist (CPU ok).
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs.base import get_config
from repro.data.pipeline import pipeline_for_model
from repro.models.transformer import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.step import make_train_step, init_train_state
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="full",
                    choices=["none", "full", "dots"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr_peak=args.lr, warmup_steps=args.steps // 10,
                          total_steps=args.steps)
    state = init_train_state(model, opt_cfg, jax.random.PRNGKey(args.seed))
    n_params = sum(p.size for p in jax.tree.leaves(state.params))
    print(f"[train] {cfg.name} ({'reduced' if args.reduced else 'full'}): "
          f"{n_params:,} params")

    pipe = pipeline_for_model(cfg, global_batch=args.batch,
                              seq_len=args.seq, seed=args.seed)
    step_fn = jax.jit(make_train_step(
        model, opt_cfg, microbatches=args.microbatches, remat=args.remat),
        donate_argnums=(0,))
    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every, log_every=10),
        step_fn, pipe, state)
    trainer.run()
    for h in trainer.history:
        print(json.dumps(h))
    if trainer.monitor.flagged:
        print(f"[train] stragglers flagged: {trainer.monitor.flagged}")


if __name__ == "__main__":
    main()
