"""Serving launcher: batched decode with the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --reduced \
      --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models.transformer import build_model
from repro.serve.engine import ServingEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    if cfg.input_mode != "tokens":
        raise SystemExit(f"{cfg.name} has a stub frontend (embeds input); "
                         "serve the token-mode archs")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServingEngine(model, params, max_slots=args.slots,
                           max_len=args.max_len, eos_id=1, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    for i in range(args.requests):
        plen = int(rng.integers(4, 12))
        engine.submit(Request(
            uid=i, prompt=rng.integers(2, cfg.vocab, plen),
            max_new_tokens=args.max_new, temperature=args.temperature))
    done = engine.run_until_drained()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {len(done)} requests, {tokens} tokens in {dt:.2f}s "
          f"({tokens / dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  uid={r.uid} -> {r.out_tokens}")


if __name__ == "__main__":
    main()
