import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) cell on 512 placeholder devices, record memory/cost/collective data.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out results/dryrun

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count at first init) — do not move it.
"""

import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import registry, get_config
from repro.configs.shapes import SHAPES, cell_supported, input_specs
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import build_model
from repro.models.sharding import infer_param_specs, materialize, guard_spec
from repro.optim.adamw import AdamWConfig
from repro.train.step import make_train_step, init_train_state


def _batch_sharding(sds, mesh):
    """inputs/targets: batch over (pod, data)."""
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    spec = P(ba) if ba else P()
    return NamedSharding(mesh, guard_spec(spec, sds.shape, mesh))


def _microbatches_for(arch: str, shape) -> int:
    # grad-accum keeps per-microbatch activations bounded at 4k train
    return 8 if shape.kind == "train" else 1


def _strip_axis(spec_tree, axis: str):
    """Remove one mesh axis from every PartitionSpec (serving placement:
    weights replicated over `data`, sharded over `model` only)."""
    from jax.sharding import PartitionSpec as P

    def strip(spec):
        out = []
        for e in spec:
            if e == axis:
                out.append(None)
            elif isinstance(e, tuple):
                kept = tuple(a for a in e if a != axis)
                out.append(kept if kept else None)
            else:
                out.append(e)
        return P(*out)

    return jax.tree.map(strip, spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str,
               verbose: bool = True, microbatches: int = None,
               serve_tp_only: bool = False, moe_constrained: bool = False,
               remat: str = "full", kv_quant: bool = False,
               bf16_moments: bool = False, ssd_chunked: bool = False):
    """Lower + compile one cell; returns result record dict.

    The keyword levers are the §Perf hillclimb variants:
      microbatches    — grad-accum count for train cells (weight re-gather
                        multiplier under FSDP);
      serve_tp_only   — decode params replicated over `data`, sharded over
                        `model` only (weight-stationary serving placement);
      moe_constrained — explicit EP dispatch shardings (models/moe.py);
      remat           — activation checkpoint policy for train cells.
    """
    import dataclasses
    from repro.models import moe as moe_mod
    from repro.models import mamba2 as mamba_mod
    from repro.models import rwkv6 as rwkv_mod
    moe_mod.CONSTRAIN_DISPATCH = moe_constrained
    mamba_mod.CHUNKED_SSD = bool(ssd_chunked)
    rwkv_mod.CHUNKED_WKV = bool(ssd_chunked)   # one flag: chunked scans
    cfg = get_config(arch)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": why}

    model = build_model(cfg)
    specs = input_specs(cfg, shape)
    t0 = time.time()

    with mesh:
        # parameter ShapeDtypeStructs + shardings (no allocation)
        params_sds = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0)))
        param_specs = infer_param_specs(params_sds, mesh)
        if serve_tp_only and shape.kind != "train":
            param_specs = _strip_axis(param_specs, "data")
        param_sh = jax.tree.map(
            lambda spec, sds: NamedSharding(
                mesh, guard_spec(spec, sds.shape, mesh)),
            param_specs, params_sds)

        if shape.kind == "train":
            opt_cfg = AdamWConfig(
                moment_dtype="bfloat16" if bf16_moments else "float32")
            mb = microbatches or _microbatches_for(arch, shape)
            step_fn = make_train_step(model, opt_cfg, microbatches=mb,
                                      remat=remat)
            state_sds = jax.eval_shape(
                lambda: init_train_state(model, opt_cfg,
                                         jax.random.PRNGKey(0)))
            state_sh = type(state_sds)(
                params=param_sh,
                opt=type(state_sds.opt)(
                    step=NamedSharding(mesh, P()),
                    m=param_sh, v=param_sh),
                step=NamedSharding(mesh, P()))
            batch_sds = {"inputs": specs["inputs"],
                         "targets": specs["targets"]}
            batch_sh = jax.tree.map(
                lambda s: _batch_sharding(s, mesh), batch_sds)
            jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_sds, batch_sds)
        elif shape.kind == "prefill":
            def prefill_fn(params, inputs):
                return model.prefill(params, inputs)
            in_sh = (param_sh, _batch_sharding(specs["inputs"], mesh))
            jitted = jax.jit(prefill_fn, in_shardings=in_sh)
            lowered = jitted.lower(params_sds, specs["inputs"])
        else:  # decode
            def serve_step(params, state, inputs):
                return model.decode_step(params, state, inputs)
            state_sds = specs["state"]
            state_specs = model.decode_state_specs(
                batch_axes=tuple(a for a in ("pod", "data")
                                 if a in mesh.axis_names),
                model_size=dict(mesh.shape).get("model", 1))
            state_sh = materialize(state_specs, state_sds, mesh)
            in_sh = (param_sh, state_sh,
                     _batch_sharding(specs["inputs"], mesh))
            jitted = jax.jit(serve_step, in_shardings=in_sh,
                             donate_argnums=(1,))
            lowered = jitted.lower(params_sds, state_sds, specs["inputs"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    mem_rec = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        if hasattr(mem, attr):
            mem_rec[attr] = int(getattr(mem, attr))

    from repro.roofline.analysis import analyze
    hlo = compiled.as_text()
    chips = mesh.devices.size
    roof = analyze(arch, SHAPES[shape_name], mesh_name, chips, cost, hlo,
                   cfg)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "cost": {k: v for k, v in cost.items()
                 if k in ("flops", "bytes accessed")},
        "memory": mem_rec,
        "roofline": roof.to_dict(),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: OK "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s, "
              f"bottleneck={roof.bottleneck})", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    # §Perf hillclimb variant levers
    ap.add_argument("--variant", default=None,
                    help="suffix for result files (perf experiments)")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--serve-tp-only", action="store_true")
    ap.add_argument("--moe-constrained", default=False, nargs="?",
                    const="constrain",
                    help="'constrain' or 'hierarchical' dispatch mode")
    ap.add_argument("--remat", default="full",
                    choices=["none", "full", "dots"])
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--bf16-moments", action="store_true")
    ap.add_argument("--ssd-chunked", action="store_true")
    args = ap.parse_args()

    archs = sorted(registry()) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                suffix = f"__{args.variant}" if args.variant else ""
                path = os.path.join(
                    args.out,
                    f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
                if args.skip_existing and os.path.exists(path):
                    continue
                try:
                    mesh = make_production_mesh(multi_pod=multi)
                    rec = lower_cell(
                        arch, shape_name, mesh, mesh_name,
                        microbatches=args.microbatches,
                        serve_tp_only=args.serve_tp_only,
                        moe_constrained=args.moe_constrained,
                        remat=args.remat, kv_quant=args.kv_quant,
                        bf16_moments=args.bf16_moments,
                        ssd_chunked=args.ssd_chunked)
                    if args.variant:
                        rec["variant"] = args.variant
                except Exception as e:  # record the failure, keep going
                    failures += 1
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "failed",
                           "error": repr(e),
                           "traceback": traceback.format_exc()[-4000:]}
                    print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: "
                          f"FAILED {e!r}", flush=True)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
    print(f"[dryrun] done, {failures} failures", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
