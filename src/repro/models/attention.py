"""Attention: GQA/MQA/MHA with RoPE, qk-norm, biases, sliding window, and a
chunked (flash-style) softmax for long sequences.

The chunked path scans over KV blocks with running (max, denom, acc) in fp32
— O(S·chunk) live memory instead of O(S²), required for the 32k prefill
shapes.  Heads are the TP axis; the per-(B,S) layout keeps batch on the data
axis.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import apply_rope, init_dense, rms_norm

NEG_INF = -1e30


def init_attn(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
              qkv_bias: bool = False, qk_norm: bool = False,
              dtype=jnp.bfloat16):
    ks = jax.random.split(key, 6)
    p = {
        "wq": init_dense(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": init_dense(ks[1], d_model, n_kv * head_dim, dtype),
        "wv": init_dense(ks[2], d_model, n_kv * head_dim, dtype),
        "wo": init_dense(ks[3], n_heads * head_dim, d_model, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), jnp.float32)
        p["k_norm"] = jnp.ones((head_dim,), jnp.float32)
    return p


def qkv(params, x, n_heads: int, n_kv: int, head_dim: int,
        positions, rope_theta: float = 10000.0, qk_norm: bool = False):
    b, s, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(b, s, n_heads, head_dim)
    k = k.reshape(b, s, n_kv, head_dim)
    v = v.reshape(b, s, n_kv, head_dim)
    if qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def _mask(pos_q, pos_k, window: Optional[int]):
    """(Sq, Sk) bool mask: causal, optionally sliding-window."""
    m = pos_q[:, None] >= pos_k[None, :]
    if window is not None:
        m &= (pos_q[:, None] - pos_k[None, :]) < window
    return m


def attention(q, k, v, pos_q, pos_k, window: Optional[int] = None,
              kv_chunk: Optional[int] = None):
    """Causal grouped attention.

    q: (B, Sq, H, hd); k/v: (B, Sk, KH, hd); H = KH * G.
    pos_q: (Sq,), pos_k: (Sk,) absolute positions (drive masking).
    Returns (B, Sq, H, hd).
    """
    b, sq, h, hd = q.shape
    _, sk, kh, _ = k.shape
    hd_v = v.shape[-1]          # may differ from hd (MLA absorbed decode)
    g = h // kh
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(b, sq, kh, g, hd) * scale

    if kv_chunk is None or sk <= kv_chunk:
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                            preferred_element_type=jnp.float32)
        scores = jnp.where(_mask(pos_q, pos_k, window)[None, None, None],
                           scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
        return out.reshape(b, sq, h, hd_v)

    # ---- chunked online-softmax over KV blocks ----
    assert sk % kv_chunk == 0, "cache length must divide kv_chunk"
    nchunks = sk // kv_chunk
    kc = k.reshape(b, nchunks, kv_chunk, kh, hd)
    vc = v.reshape(b, nchunks, kv_chunk, kh, hd_v)
    pkc = pos_k.reshape(nchunks, kv_chunk)

    def step(carry, inp):
        m_run, l_run, acc = carry
        kb, vb, pb = inp                     # (B,C,KH,hd), (C,)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, kb,
                       preferred_element_type=jnp.float32)
        s = jnp.where(_mask(pos_q, pb, window)[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_run = l_run * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(vb.dtype), vb
                        ).astype(jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (m_new, l_run, acc), None

    m0 = jnp.full((b, kh, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kh, g, sq, hd_v), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), pkc))
    # (b, kh, g, sq, hd_v) -> (b, sq, kh, g, hd_v)
    out = jnp.transpose(acc / jnp.maximum(l_f, 1e-30)[..., None],
                        (0, 3, 1, 2, 4))
    return out.reshape(b, sq, h, hd_v).astype(q.dtype)


class KVCache(NamedTuple):
    k: jnp.ndarray        # (B, T, KH, hd)
    v: jnp.ndarray
    length: jnp.ndarray   # () int32 — tokens filled


def init_cache(batch: int, max_len: int, n_kv: int, head_dim: int,
               dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        v=jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        length=jnp.zeros((), jnp.int32))


def attn_forward(params, x, positions, *, n_heads, n_kv, head_dim,
                 rope_theta=10000.0, qk_norm=False, window=None,
                 kv_chunk=2048):
    """Training / prefill self-attention over a full sequence."""
    q, k, v = qkv(params, x, n_heads, n_kv, head_dim, positions,
                  rope_theta, qk_norm)
    out = attention(q, k, v, positions, positions, window=window,
                    kv_chunk=kv_chunk)
    b, s = x.shape[:2]
    y = out.reshape(b, s, n_heads * head_dim) @ params["wo"]
    return y, (k, v)


def ring_positions(length, t: int):
    """Absolute position held by each slot of a ring buffer of size t after
    writing token ``length`` at slot ``length % t``: the largest p <= length
    with p ≡ slot (mod t); unwritten slots get +inf so the causal mask
    removes them."""
    i = jnp.arange(t)
    p = length - (length - i) % t
    return jnp.where(p < 0, jnp.iinfo(jnp.int32).max, p)


def attn_decode_ring(params, x, k_cache, v_cache, length, *, n_heads, n_kv,
                     head_dim, rope_theta=10000.0, qk_norm=False,
                     window: Optional[int] = None):
    """Single-token decode against a bounded ring-buffer cache (sliding-
    window attention; caches stay O(window) for 500k-token decode).

    k_cache/v_cache: (B, t, KH, hd) with t = min(max_len, window).
    Degenerates to the linear cache when length < t.
    """
    b = x.shape[0]
    pos = length[None]
    q, k, v = qkv(params, x, n_heads, n_kv, head_dim, pos,
                  rope_theta, qk_norm)
    t = k_cache.shape[1]
    slot = length % t
    k_new = jax.lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype), (0, slot, 0, 0))
    v_new = jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, slot, 0, 0))
    pos_k = ring_positions(length, t)
    out = attention(q, k_new, v_new, pos, pos_k, window=window,
                    kv_chunk=None)
    y = out.reshape(b, 1, n_heads * head_dim) @ params["wo"]
    return y, k_new, v_new


def quantize_kv(k):
    """Per-(token,head) max-abs int8 quantization of a KV tensor
    (..., head_dim).  Returns (int8 values, bf16 scales)."""
    amax = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(k.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_kv(q, scale, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32)
            * scale[..., None].astype(jnp.float32)).astype(dtype)


def attn_decode_quant(params, x, k_q, v_q, k_s, v_s, length, *, n_heads,
                      n_kv, head_dim, rope_theta=10000.0, qk_norm=False,
                      window=None):
    """Single-token decode against an int8-quantized KV cache (§Perf cell C:
    halves the dominant decode HBM stream; dequant fuses into the score
    matmul on TPU).

    k_q/v_q: (B, T, KH, hd) int8; k_s/v_s: (B, T, KH) bf16 scales.
    """
    b = x.shape[0]
    pos = length[None]
    q, k, v = qkv(params, x, n_heads, n_kv, head_dim, pos,
                  rope_theta, qk_norm)
    k_i8, k_sc = quantize_kv(k)
    v_i8, v_sc = quantize_kv(v)
    k_q = jax.lax.dynamic_update_slice(k_q, k_i8, (0, length, 0, 0))
    v_q = jax.lax.dynamic_update_slice(v_q, v_i8, (0, length, 0, 0))
    k_s = jax.lax.dynamic_update_slice(k_s, k_sc, (0, length, 0))
    v_s = jax.lax.dynamic_update_slice(v_s, v_sc, (0, length, 0))
    t = k_q.shape[1]
    out = attention(q, dequantize_kv(k_q, k_s), dequantize_kv(v_q, v_s),
                    pos, jnp.arange(t), window=window, kv_chunk=None)
    y = out.reshape(b, 1, n_heads * head_dim) @ params["wo"]
    return y, (k_q, v_q, k_s, v_s)


def attn_decode(params, x, cache: KVCache, *, n_heads, n_kv, head_dim,
                rope_theta=10000.0, qk_norm=False, window=None):
    """Single-token decode against a KV cache.

    x: (B, 1, D); cache holds max_len positions, cache.length are filled.
    """
    b = x.shape[0]
    pos = cache.length[None]                       # (1,) current position
    q, k, v = qkv(params, x, n_heads, n_kv, head_dim, pos,
                  rope_theta, qk_norm)
    k_new = jax.lax.dynamic_update_slice(
        cache.k, k.astype(cache.k.dtype), (0, cache.length, 0, 0))
    v_new = jax.lax.dynamic_update_slice(
        cache.v, v.astype(cache.v.dtype), (0, cache.length, 0, 0))
    t = cache.k.shape[1]
    pos_k = jnp.arange(t)
    # mask positions beyond current length
    valid_window = window
    out = attention(q, k_new, v_new, pos, pos_k, window=valid_window,
                    kv_chunk=None)
    y = out.reshape(b, 1, n_heads * head_dim) @ params["wo"]
    new_cache = KVCache(k=k_new, v=v_new, length=cache.length + 1)
    return y, new_cache
