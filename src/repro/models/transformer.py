"""Unified LM assembly for all assigned architecture families.

Every model exposes the same interface (used by train/serve/launch):

  model = build_model(cfg)
  params = model.init(key)
  logits = model.forward(params, inputs)                 # (B, S, V)
  loss, metrics = model.loss(params, batch)
  state = model.init_decode_state(batch, max_len)
  state, logits = model.decode_step(params, state, inputs_1)   # one token
  state, logits = model.prefill(params, inputs)

``inputs`` is token ids (B, S) int32 for input_mode='tokens', or precomputed
frontend embeddings (B, S, D) for 'embeds' (audio/vlm stubs).

Layers are stacked on a leading axis and iterated with lax.scan — O(1)
compile in depth, which the 512-device dry-run requires.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import attention as A
from . import mla as MLA
from . import moe as MOE
from . import rwkv6 as R6
from . import mamba2 as M2
from .layers import (cross_entropy_loss, init_dense, init_embed, init_mlp,
                     layer_norm, mlp, rms_norm)
from .sharding import constrain_tokens


def _norm(cfg, x, scale):
    return rms_norm(x, scale, offset=cfg.norm_offset)


def _maybe_remat(fn, policy: str):
    """Wrap a layer-scan body with activation checkpointing.

    'none'  — save everything (fastest, highest memory);
    'full'  — recompute the whole layer in backward (lowest memory);
    'dots'  — save matmul outputs only (balanced; the usual prod default).
    """
    if policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(f"unknown remat policy {policy!r}")


# ===========================================================================
# Embedding / head (common)
# ===========================================================================

def _init_embed_head(cfg: ModelConfig, key):
    k1, k2 = jax.random.split(key)
    p = {}
    if cfg.input_mode == "tokens":
        p["embed"] = init_embed(k1, cfg.vocab, cfg.d_model, cfg.compute_dtype)
        if not cfg.tie_embeddings:
            p["head"] = init_dense(k2, cfg.d_model, cfg.vocab,
                                   cfg.compute_dtype)
    else:
        p["head"] = init_dense(k2, cfg.d_model, cfg.vocab, cfg.compute_dtype)
    p["final_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    return p


def _embed_in(cfg, params, inputs):
    if cfg.input_mode == "tokens":
        x = params["embed"][inputs]
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    else:
        x = inputs.astype(cfg.compute_dtype)
    return constrain_tokens(x)


def _head_out(cfg, params, x):
    x = _norm(cfg, x, params["final_norm"])
    if cfg.input_mode == "tokens" and cfg.tie_embeddings:
        return x @ params["embed"].T
    return x @ params["head"]


# ===========================================================================
# Dense-family block (dense / audio / vlm / moe; attention = GQA or MLA)
# ===========================================================================

def _init_block(cfg: ModelConfig, key, use_moe: bool):
    k1, k2 = jax.random.split(key)
    p = {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
         "ln2": jnp.ones((cfg.d_model,), jnp.float32)}
    if cfg.mla is not None:
        c = cfg.mla
        p["mla"] = MLA.init_mla(k1, cfg.d_model, cfg.n_heads, c.kv_lora,
                                c.nope_dim, c.rope_dim, c.v_dim,
                                cfg.compute_dtype)
    else:
        p["attn"] = A.init_attn(k1, cfg.d_model, cfg.n_heads, cfg.n_kv,
                                cfg.head_dim, cfg.qkv_bias, cfg.qk_norm,
                                cfg.compute_dtype)
    if use_moe:
        m = cfg.moe
        p["moe"] = MOE.init_moe(k2, cfg.d_model, m.d_ff_expert,
                                m.num_experts, m.num_shared, m.d_ff_shared,
                                cfg.compute_dtype)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.compute_dtype)
    return p


def _block_attn_forward(cfg, p, x, positions, kv_chunk, window):
    h = _norm(cfg, x, p["ln1"])
    if cfg.mla is not None:
        c = cfg.mla
        y, kv = MLA.mla_forward(
            p["mla"], h, positions, n_heads=cfg.n_heads, kv_lora=c.kv_lora,
            nope_dim=c.nope_dim, rope_dim=c.rope_dim, v_dim=c.v_dim,
            rope_theta=cfg.rope_theta, kv_chunk=kv_chunk)
    else:
        y, kv = A.attn_forward(
            p["attn"], h, positions, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
            qk_norm=cfg.qk_norm, window=window, kv_chunk=kv_chunk)
    return x + y, kv


def _block_ffn_forward(cfg, p, x, use_moe: bool):
    h = _norm(cfg, x, p["ln2"])
    if use_moe:
        m = cfg.moe
        y, aux = MOE.moe_forward(
            p["moe"], h, num_experts=m.num_experts, top_k=m.top_k,
            capacity_factor=m.capacity_factor)
    else:
        y = mlp(p["mlp"], h, cfg.activation)
        aux = {"load_balance_loss": jnp.zeros((), jnp.float32),
               "dropped_fraction": jnp.zeros((), jnp.float32)}
    return x + y, aux


def _block_attn_decode(cfg, p, x, kcache, vcache, length, window):
    """Single-token decode; returns (x, new_k, new_v)."""
    h = _norm(cfg, x, p["ln1"])
    if cfg.mla is not None:
        c = cfg.mla
        cache = MLA.MLACache(c_kv=kcache, k_rope=vcache, length=length)
        y, new = MLA.mla_decode(
            p["mla"], h, cache, n_heads=cfg.n_heads, kv_lora=c.kv_lora,
            nope_dim=c.nope_dim, rope_dim=c.rope_dim, v_dim=c.v_dim,
            rope_theta=cfg.rope_theta)
        return x + y, new.c_kv, new.k_rope
    cache = A.KVCache(k=kcache, v=vcache, length=length)
    y, new = A.attn_decode(
        p["attn"], h, cache, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
        qk_norm=cfg.qk_norm, window=window)
    return x + y, new.k, new.v


class DenseLM:
    """dense / audio / vlm / moe families (GQA or MLA attention)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        m = cfg.moe
        self.n_first_dense = m.first_dense if m else 0
        self.n_scanned = cfg.n_layers - self.n_first_dense
        self.use_moe = m is not None
        self.remat = "none"          # set by train/step.make_train_step

    # ---- params ----
    def init(self, key):
        cfg = self.cfg
        k_eh, k_first, k_rest = jax.random.split(key, 3)
        p = _init_embed_head(cfg, k_eh)
        if self.n_first_dense:
            firsts = [
                _init_block(cfg, k, use_moe=False)
                for k in jax.random.split(k_first, self.n_first_dense)]
            p["first_layers"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *firsts) \
                if len(firsts) > 1 else jax.tree.map(
                    lambda x: x[None], firsts[0])
        keys = jax.random.split(k_rest, self.n_scanned)
        p["layers"] = jax.vmap(
            functools.partial(_init_block, cfg, use_moe=self.use_moe))(keys)
        return p

    # ---- full-sequence forward (train / prefill math) ----
    def forward(self, params, inputs, return_kv: bool = False,
                return_aux: bool = False, window: Optional[int] = None,
                logits_mode: str = "all"):
        cfg = self.cfg
        x = _embed_in(cfg, params, inputs)
        s = x.shape[1]
        positions = jnp.arange(s)
        kv_chunk = 2048 if s > 2048 else None
        window = window if window is not None else cfg.sliding_window

        first_kv = []
        for i in range(self.n_first_dense):
            lp = jax.tree.map(lambda a, i=i: a[i], params["first_layers"])
            x, kv = _block_attn_forward(cfg, lp, x, positions, kv_chunk,
                                        window)
            x, _ = _block_ffn_forward(cfg, lp, x, use_moe=False)
            first_kv.append(kv)

        def body(x, lp):
            x, kv = _block_attn_forward(cfg, lp, x, positions, kv_chunk,
                                        window)
            x, aux = _block_ffn_forward(cfg, lp, x, use_moe=self.use_moe)
            x = constrain_tokens(x)
            return x, (kv, aux)

        x, (kvs, auxs) = jax.lax.scan(_maybe_remat(body, self.remat), x,
                                      params["layers"])
        aux_mean = jax.tree.map(jnp.mean, auxs)
        if logits_mode == "last":
            x = x[:, -1:]        # serving prefill: last-token logits only
        logits = _head_out(cfg, params, x)
        out = (logits,)
        if return_kv:
            out += ((first_kv, kvs),)
        if return_aux:
            out += (aux_mean,)
        return out if len(out) > 1 else logits

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch["inputs"], return_aux=True)
        ce = cross_entropy_loss(logits, batch["targets"])
        total = ce
        metrics = {"ce": ce}
        if self.use_moe:
            total = total + 0.01 * aux["load_balance_loss"]
            metrics.update(aux)
        return total, metrics

    # ---- decode ----
    @property
    def _kv_int8(self):
        return (self.cfg.kv_cache_dtype == "int8"
                and self.cfg.mla is None)

    def init_decode_state(self, batch: int, max_len: int):
        cfg = self.cfg
        L, Ld = self.n_scanned, self.n_first_dense
        dt = cfg.compute_dtype
        if cfg.mla is not None:
            c = cfg.mla
            mk = lambda n: {
                "k": jnp.zeros((n, batch, max_len, c.kv_lora), dt),
                "v": jnp.zeros((n, batch, max_len, c.rope_dim), dt)}
        elif self._kv_int8:
            mk = lambda n: {
                "k": jnp.zeros((n, batch, max_len, cfg.n_kv, cfg.head_dim),
                               jnp.int8),
                "v": jnp.zeros((n, batch, max_len, cfg.n_kv, cfg.head_dim),
                               jnp.int8),
                "ks": jnp.zeros((n, batch, max_len, cfg.n_kv),
                                jnp.bfloat16),
                "vs": jnp.zeros((n, batch, max_len, cfg.n_kv),
                                jnp.bfloat16)}
        else:
            mk = lambda n: {
                "k": jnp.zeros((n, batch, max_len, cfg.n_kv, cfg.head_dim),
                               dt),
                "v": jnp.zeros((n, batch, max_len, cfg.n_kv, cfg.head_dim),
                               dt)}
        state = {"scan": mk(L), "length": jnp.zeros((), jnp.int32)}
        if Ld:
            state["first"] = mk(Ld)
        return state

    def decode_state_specs(self, batch_axes=("pod", "data"),
                           model_size: int = 16):
        """Logical PartitionSpecs matching init_decode_state's structure
        (guarded against the concrete mesh by launch/dryrun).

        KV caches shard their head dim over `model` when divisible;
        otherwise the *sequence* dim is sharded (flash-decoding-style
        sequence parallelism — GSPMD inserts the softmax-stat reductions).
        Without this, GQA caches with n_kv < model replicate across the
        model axis and blow the per-chip HBM budget (e.g. granite decode:
        21 GB/chip replicated vs 1.3 GB sequence-sharded).
        """
        from jax.sharding import PartitionSpec as P
        cfg = self.cfg
        if cfg.mla is not None:
            # latent cache sharded over `model` on the TIME dim (flash-
            # decoding layout): scores/ctx contract T with tiny psum'd
            # softmax stats.  Latent-dim sharding forces per-layer cache
            # all-gathers; full replication blows HBM (§Perf cell B log).
            kv = {"k": P(None, batch_axes, "model", None),
                  "v": P(None, batch_axes, "model", None)}
        else:
            if cfg.n_kv % model_size == 0:
                kv = {"k": P(None, batch_axes, None, "model", None),
                      "v": P(None, batch_axes, None, "model", None)}
                if self._kv_int8:
                    kv["ks"] = P(None, batch_axes, None, "model")
                    kv["vs"] = P(None, batch_axes, None, "model")
            else:
                kv = {"k": P(None, batch_axes, "model", None, None),
                      "v": P(None, batch_axes, "model", None, None)}
                if self._kv_int8:
                    kv["ks"] = P(None, batch_axes, "model", None)
                    kv["vs"] = P(None, batch_axes, "model", None)
        state = {"scan": dict(kv), "length": P()}
        if self.n_first_dense:
            state["first"] = dict(kv)
        return state

    def decode_step(self, params, state, inputs):
        cfg = self.cfg
        x = _embed_in(cfg, params, inputs)           # (B, 1, D)
        length = state["length"]
        window = cfg.sliding_window

        new_first = None
        if self.n_first_dense:
            ks, vs = [], []
            for i in range(self.n_first_dense):
                lp = jax.tree.map(lambda a, i=i: a[i],
                                  params["first_layers"])
                x, k, v = _block_attn_decode(
                    cfg, lp, x, state["first"]["k"][i],
                    state["first"]["v"][i], length, window)
                x, _ = _block_ffn_forward(cfg, lp, x, use_moe=False)
                ks.append(k)
                vs.append(v)
            new_first = {"k": jnp.stack(ks), "v": jnp.stack(vs)}

        if self._kv_int8:
            x, caches = self._decode_scan_quant(params, state, x, length,
                                                window)
            logits = _head_out(cfg, params, x)
            new_state = {"scan": caches, "length": length + 1}
            if new_first is not None:
                new_state["first"] = new_first
            return new_state, logits

        def body(x, inp):
            lp, k, v = inp
            x, k, v = _block_attn_decode(cfg, lp, x, k, v, length, window)
            x, _ = _block_ffn_forward(cfg, lp, x, use_moe=self.use_moe)
            return x, (k, v)

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["layers"], state["scan"]["k"],
                      state["scan"]["v"]))
        logits = _head_out(cfg, params, x)
        new_state = {"scan": {"k": ks, "v": vs}, "length": length + 1}
        if new_first is not None:
            new_state["first"] = new_first
        return new_state, logits

    def _decode_scan_quant(self, params, state, x, length, window):
        cfg = self.cfg

        def body(x, inp):
            lp, k, v, ks_, vs_ = inp
            h = _norm(cfg, x, lp["ln1"])
            y, (k, v, ks_, vs_) = A.attn_decode_quant(
                lp["attn"], h, k, v, ks_, vs_, length,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
                rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
                window=window)
            x = x + y
            x, _ = _block_ffn_forward(cfg, lp, x, use_moe=self.use_moe)
            return x, (k, v, ks_, vs_)

        sc = state["scan"]
        x, (k, v, ks_, vs_) = jax.lax.scan(
            body, x, (params["layers"], sc["k"], sc["v"], sc["ks"],
                      sc["vs"]))
        return x, {"k": k, "v": v, "ks": ks_, "vs": vs_}

    def prefill(self, params, inputs, max_len: Optional[int] = None):
        """Full-sequence pass that also fills the decode caches."""
        cfg = self.cfg
        b, s = inputs.shape[:2]
        max_len = max_len or s
        logits, (first_kv, kvs) = self.forward(params, inputs,
                                               return_kv=True,
                                               logits_mode="last")
        state = self.init_decode_state(b, max_len)

        def fill(cache, kv):
            # kv: (L, B, S, ...) from scan; cache: (L, B, T, ...)
            return jax.lax.dynamic_update_slice(
                cache, kv.astype(cache.dtype), (0,) * cache.ndim)

        def fill_group(group, k_new, v_new):
            if self._kv_int8:
                k_i8, k_sc = A.quantize_kv(k_new)
                v_i8, v_sc = A.quantize_kv(v_new)
                group["k"] = fill(group["k"], k_i8)
                group["v"] = fill(group["v"], v_i8)
                group["ks"] = fill(group["ks"], k_sc)
                group["vs"] = fill(group["vs"], v_sc)
            else:
                group["k"] = fill(group["k"], k_new)
                group["v"] = fill(group["v"], v_new)

        fill_group(state["scan"], kvs[0], kvs[1])
        if self.n_first_dense:
            fill_group(state["first"],
                       jnp.stack([kv[0] for kv in first_kv]),
                       jnp.stack([kv[1] for kv in first_kv]))
        state["length"] = jnp.asarray(s, jnp.int32)
        return state, logits


# ===========================================================================
# RWKV6 (ssm family)
# ===========================================================================

def _init_rwkv_layer(cfg: ModelConfig, key):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "ln1_b": jnp.zeros((cfg.d_model,), jnp.float32),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "ln2_b": jnp.zeros((cfg.d_model,), jnp.float32),
        "tm": R6.init_time_mix(k1, cfg.d_model, cfg.n_heads,
                               cfg.compute_dtype),
        "cm": R6.init_channel_mix(k2, cfg.d_model, cfg.d_ff,
                                  cfg.compute_dtype),
    }


class RWKVLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.remat = "none"

    def init(self, key):
        cfg = self.cfg
        k_eh, k_l, k0 = jax.random.split(key, 3)
        p = _init_embed_head(cfg, k_eh)
        p["ln0"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["ln0_b"] = jnp.zeros((cfg.d_model,), jnp.float32)
        keys = jax.random.split(k_l, cfg.n_layers)
        p["layers"] = jax.vmap(
            functools.partial(_init_rwkv_layer, cfg))(keys)
        return p

    def _zero_states(self, batch):
        cfg = self.cfg
        L = cfg.n_layers
        st = R6.init_state(batch, cfg.d_model, cfg.n_heads,
                           cfg.compute_dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (L,) + a.shape), st)

    def _run(self, params, x, states):
        cfg = self.cfg

        def body(x, inp):
            lp, st = inp
            h = layer_norm(x, lp["ln1"], lp["ln1_b"])
            y, tm_x, S = R6.time_mix(lp["tm"], h, st.tm_x, st.S,
                                     cfg.n_heads)
            x = x + y
            h = layer_norm(x, lp["ln2"], lp["ln2_b"])
            y, cm_x = R6.channel_mix(lp["cm"], h, st.cm_x)
            x = x + y
            x = constrain_tokens(x)
            return x, R6.RWKVLayerState(tm_x=tm_x, cm_x=cm_x, S=S)

        x, new_states = jax.lax.scan(_maybe_remat(body, self.remat), x,
                                     (params["layers"], states))
        return x, new_states

    def forward(self, params, inputs):
        cfg = self.cfg
        x = _embed_in(cfg, params, inputs)
        x = layer_norm(x, params["ln0"], params["ln0_b"])
        states = self._zero_states(x.shape[0])
        x, _ = self._run(params, x, states)
        return _head_out(cfg, params, x)

    def loss(self, params, batch):
        logits = self.forward(params, batch["inputs"])
        ce = cross_entropy_loss(logits, batch["targets"])
        return ce, {"ce": ce}

    def init_decode_state(self, batch: int, max_len: int = 0):
        return {"states": self._zero_states(batch),
                "length": jnp.zeros((), jnp.int32)}

    def decode_state_specs(self, batch_axes=("pod", "data"),
                           model_size: int = 16):
        from jax.sharding import PartitionSpec as P
        return {"states": R6.RWKVLayerState(
            tm_x=P(None, batch_axes, "model"),
            cm_x=P(None, batch_axes, "model"),
            S=P(None, batch_axes, "model", None, None)),
            "length": P()}

    def decode_step(self, params, state, inputs):
        cfg = self.cfg
        x = _embed_in(cfg, params, inputs)            # (B, 1, D)
        x = layer_norm(x, params["ln0"], params["ln0_b"])
        x, new_states = self._run(params, x, state["states"])
        logits = _head_out(cfg, params, x)
        return ({"states": new_states, "length": state["length"] + 1},
                logits)

    def prefill(self, params, inputs, max_len: Optional[int] = None):
        cfg = self.cfg
        x = _embed_in(cfg, params, inputs)
        x = layer_norm(x, params["ln0"], params["ln0_b"])
        states = self._zero_states(x.shape[0])
        x, new_states = self._run(params, x, states)
        logits = _head_out(cfg, params, x[:, -1:])
        return ({"states": new_states,
                 "length": jnp.asarray(inputs.shape[1], jnp.int32)}, logits)


# ===========================================================================
# Zamba2-style hybrid: Mamba2 stack + weight-shared attention block
# ===========================================================================

def _init_mamba_layer(cfg: ModelConfig, key):
    s = cfg.ssm
    d_inner = s.d_inner or 2 * cfg.d_model
    return {
        "ln": jnp.ones((cfg.d_model,), jnp.float32),
        "m": M2.init_mamba2(key, cfg.d_model, d_inner, s.d_state,
                            s.head_dim, cfg.compute_dtype),
    }


def _init_lora(cfg, key):
    """Per-application LoRA on the shared block's qkv input proj."""
    r = cfg.shared_lora_rank
    k1, k2 = jax.random.split(key)
    return {
        "lora_a": init_dense(k1, cfg.d_model, r, cfg.compute_dtype,
                             scale=1e-4),
        "lora_b": init_dense(k2, r, cfg.d_model, cfg.compute_dtype),
    }


class HybridLM:
    """n_layers Mamba2 blocks; after every `hybrid_period` of them the
    weight-shared attention+MLP block runs with a per-application LoRA
    delta on its input (Zamba2 mechanism, simplified per DESIGN.md §7)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.period = cfg.hybrid_period
        self.n_groups = cfg.n_layers // self.period
        self.n_tail = cfg.n_layers - self.n_groups * self.period
        s = cfg.ssm
        self.d_inner = s.d_inner or 2 * cfg.d_model
        self.remat = "none"

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 6)
        p = _init_embed_head(cfg, ks[0])
        # grouped mamba layers (G, period, ...)
        gkeys = jax.random.split(ks[1], self.n_groups * self.period)
        stacked = jax.vmap(functools.partial(_init_mamba_layer, cfg))(gkeys)
        p["mamba_groups"] = jax.tree.map(
            lambda a: a.reshape((self.n_groups, self.period) + a.shape[1:]),
            stacked)
        if self.n_tail:
            tkeys = jax.random.split(ks[2], self.n_tail)
            p["mamba_tail"] = jax.vmap(
                functools.partial(_init_mamba_layer, cfg))(tkeys)
        p["shared"] = {
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": A.init_attn(ks[3], cfg.d_model, cfg.n_heads, cfg.n_kv,
                                cfg.head_dim, dtype=cfg.compute_dtype),
            "mlp": init_mlp(ks[4], cfg.d_model, cfg.d_ff, cfg.compute_dtype),
        }
        lkeys = jax.random.split(ks[5], self.n_groups)
        p["lora"] = jax.vmap(functools.partial(_init_lora, cfg))(lkeys)
        return p

    def _mamba_block(self, lp, x, st):
        cfg = self.cfg
        h = _norm(cfg, x, lp["ln"])
        y, new_st = M2.mamba2_forward(
            lp["m"], h, st, d_inner=self.d_inner,
            d_state=cfg.ssm.d_state, head_dim=cfg.ssm.head_dim)
        return x + y, new_st

    def _shared_block_forward(self, params, lora, x, positions, window,
                              kv_chunk):
        cfg = self.cfg
        sp = params["shared"]
        h = _norm(cfg, x, sp["ln1"])
        h = h + (h @ lora["lora_a"]) @ lora["lora_b"]     # per-app LoRA
        y, kv = A.attn_forward(
            sp["attn"], h, positions, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
            window=window, kv_chunk=kv_chunk)
        x = x + y
        x = x + mlp(sp["mlp"], _norm(cfg, x, sp["ln2"]), cfg.activation)
        return x, kv

    def _zero_mamba_state(self, batch, n):
        cfg = self.cfg
        st = M2.init_state(batch, self.d_inner, cfg.ssm.d_state,
                           cfg.ssm.head_dim, cfg.compute_dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), st)

    def forward(self, params, inputs, window: Optional[int] = None,
                return_state: bool = False, max_len: Optional[int] = None,
                logits_mode: str = "all"):
        cfg = self.cfg
        x = _embed_in(cfg, params, inputs)
        b, s = x.shape[:2]
        positions = jnp.arange(s)
        kv_chunk = 2048 if s > 2048 else None
        window = window if window is not None else cfg.sliding_window

        def inner(x, inp):
            lp, st = inp
            x, new_st = self._mamba_block(lp, x, st)
            return x, new_st

        def group(x, inp):
            glp, lora, gst = inp
            x, new_gst = jax.lax.scan(inner, x, (glp, gst))
            x, kv = self._shared_block_forward(params, lora, x, positions,
                                               window, kv_chunk)
            x = constrain_tokens(x)
            return x, (new_gst, kv)

        gstates = jax.tree.map(
            lambda a: a.reshape((self.n_groups, self.period) + a.shape[1:]),
            self._zero_mamba_state(b, self.n_groups * self.period))
        x, (new_gstates, kvs) = jax.lax.scan(
            _maybe_remat(group, self.remat), x,
            (params["mamba_groups"], params["lora"], gstates))
        new_tail = None
        if self.n_tail:
            tstates = self._zero_mamba_state(b, self.n_tail)
            x, new_tail = jax.lax.scan(inner, x,
                                       (params["mamba_tail"], tstates))
        if logits_mode == "last":
            x = x[:, -1:]
        logits = _head_out(cfg, params, x)
        if return_state:
            return logits, (new_gstates, new_tail, kvs)
        return logits

    def loss(self, params, batch):
        logits = self.forward(params, batch["inputs"])
        ce = cross_entropy_loss(logits, batch["targets"])
        return ce, {"ce": ce}

    def init_decode_state(self, batch: int, max_len: int):
        cfg = self.cfg
        # long-context mode: attention cache bounded by the sliding window
        window = cfg.long_context_window
        t = min(max_len, window) if cfg.supports_long_context else max_len
        dt = cfg.compute_dtype
        return {
            "groups": jax.tree.map(
                lambda a: a.reshape((self.n_groups, self.period)
                                    + a.shape[1:]),
                self._zero_mamba_state(batch, self.n_groups * self.period)),
            "tail": (self._zero_mamba_state(batch, self.n_tail)
                     if self.n_tail else None),
            "k": jnp.zeros((self.n_groups, batch, t, cfg.n_kv,
                            cfg.head_dim), dt),
            "v": jnp.zeros((self.n_groups, batch, t, cfg.n_kv,
                            cfg.head_dim), dt),
            "length": jnp.zeros((), jnp.int32),
        }

    def decode_state_specs(self, batch_axes=("pod", "data"),
                           model_size: int = 16):
        from jax.sharding import PartitionSpec as P
        kv_ax = "model" if self.cfg.n_kv % model_size == 0 else None
        seq_ax = None if kv_ax else "model"
        mamba = M2.Mamba2State(
            h=P(None, None, batch_axes, "model", None, None),
            conv=P(None, None, batch_axes, None, "model"))
        out = {
            "groups": mamba,
            "tail": (M2.Mamba2State(
                h=P(None, batch_axes, "model", None, None),
                conv=P(None, batch_axes, None, "model"))
                if self.n_tail else None),
            "k": P(None, batch_axes, seq_ax, kv_ax, None),
            "v": P(None, batch_axes, seq_ax, kv_ax, None),
            "length": P(),
        }
        return out

    def decode_step(self, params, state, inputs):
        cfg = self.cfg
        x = _embed_in(cfg, params, inputs)
        length = state["length"]
        t_cache = state["k"].shape[2]

        def inner(x, inp):
            lp, st = inp
            x, new_st = self._mamba_block(lp, x, st)
            return x, new_st

        def group(x, inp):
            glp, lora, gst, k, v = inp
            x, new_gst = jax.lax.scan(inner, x, (glp, gst))
            sp = params["shared"]
            h = _norm(cfg, x, sp["ln1"])
            h = h + (h @ lora["lora_a"]) @ lora["lora_b"]
            y, k_new, v_new = A.attn_decode_ring(
                sp["attn"], h, k, v, length, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv, head_dim=cfg.head_dim,
                rope_theta=cfg.rope_theta,
                window=cfg.long_context_window
                if cfg.supports_long_context else None)
            x = x + y
            x = x + mlp(sp["mlp"], _norm(cfg, x, sp["ln2"]), cfg.activation)
            return x, (new_gst, k_new, v_new)

        x, (new_g, ks, vs) = jax.lax.scan(
            group, x, (params["mamba_groups"], params["lora"],
                       state["groups"], state["k"], state["v"]))
        new_tail = None
        if self.n_tail:
            x, new_tail = jax.lax.scan(
                inner, x, (params["mamba_tail"], state["tail"]))
        logits = _head_out(cfg, params, x)
        return ({"groups": new_g, "tail": new_tail, "k": ks, "v": vs,
                 "length": length + 1}, logits)

    def prefill(self, params, inputs, max_len: Optional[int] = None):
        cfg = self.cfg
        b, s = inputs.shape[:2]
        max_len = max_len or s
        logits, (gstates, tail, kvs) = self.forward(params, inputs,
                                                    return_state=True,
                                                    logits_mode="last")
        state = self.init_decode_state(b, max_len)
        state["groups"] = gstates
        state["tail"] = tail
        t = state["k"].shape[2]
        k_new, v_new = kvs
        if s >= t:
            # ring order: slot i must hold the largest position p < s with
            # p ≡ i (mod t) — static gather (s, t are trace-time constants)
            import numpy as np
            i = np.arange(t)
            pos_idx = (s - 1) - ((s - 1 - i) % t)
            state["k"] = k_new[:, :, pos_idx].astype(state["k"].dtype)
            state["v"] = v_new[:, :, pos_idx].astype(state["v"].dtype)
        else:
            state["k"] = jax.lax.dynamic_update_slice(
                state["k"], k_new.astype(state["k"].dtype), (0,) * 5)
            state["v"] = jax.lax.dynamic_update_slice(
                state["v"], v_new.astype(state["v"].dtype), (0,) * 5)
        state["length"] = jnp.asarray(s, jnp.int32)
        return state, logits


# ===========================================================================

def build_model(cfg: ModelConfig):
    if cfg.family in ("dense", "audio", "vlm", "moe"):
        return DenseLM(cfg)
    if cfg.family == "ssm":
        return RWKVLM(cfg)
    if cfg.family == "hybrid":
        return HybridLM(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
