"""Mixture-of-Experts with sort-based capacity dispatch (TPU/GSPMD-native).

Dispatch is the sparse step: the (tokens × experts) routing matrix is
exactly a structurally *asymmetric* sparse matrix, and dispatch/combine are
SpMM with it — the MoE analogue of the paper's scatter problem (DESIGN.md
§4).  Like the CSRC kernel, we avoid data-dependent scatter ordering by
sorting: tokens are argsorted by expert id, positions-within-expert come
from a running count, overflow beyond capacity is dropped (standard
Switch-style capacity bound keeps every shape static).

Experts are sharded over the `model` axis (EP); tokens live on the `data`
axis.  GSPMD turns the token→expert buffer scatter into the EP all-to-all.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import init_dense, init_mlp, mlp
from .sharding import constrain

# §Perf lever (EXPERIMENTS.md §Perf cell A).  Modes:
#   None / False    — baseline: global sort-based dispatch, placement left
#                     to GSPMD propagation (paper-faithful starting point);
#   "constrain"     — same computation with explicit sharding constraints
#                     (token-major on batch axes, expert-major on `model`);
#   "hierarchical"  — two-stage production dispatch: tokens are grouped so
#                     each data shard sorts only its own tokens (no global
#                     argsort), then ONE buffer reshard (batch-major →
#                     expert-major) moves data — GSPMD emits it as the EP
#                     all-to-all instead of all-reducing the whole buffer.
# Toggled by launch/dryrun --moe-constrained / --moe-hierarchical.
CONSTRAIN_DISPATCH = False
DISPATCH_GROUPS = 16        # = data-axis size; groups sort locally


def init_moe(key, d_model: int, d_ff_expert: int, num_experts: int,
             num_shared: int = 0, d_ff_shared: int = 0,
             dtype=jnp.bfloat16):
    ks = jax.random.split(key, 5)

    def expert_weights(k, fan_in, shape):
        return (jax.random.normal(k, shape, jnp.float32) * fan_in ** -0.5
                ).astype(dtype)

    p = {
        "router": init_dense(ks[0], d_model, num_experts, jnp.float32),
        "w_gate": expert_weights(ks[1], d_model,
                                 (num_experts, d_model, d_ff_expert)),
        "w_up": expert_weights(ks[2], d_model,
                               (num_experts, d_model, d_ff_expert)),
        "w_down": expert_weights(ks[3], d_ff_expert,
                                 (num_experts, d_ff_expert, d_model)),
    }
    if num_shared:
        p["shared"] = init_mlp(ks[4], d_model, d_ff_shared, dtype)
    return p


def moe_forward_hierarchical(params, x, *, num_experts: int, top_k: int,
                             capacity_factor: float = 1.25,
                             router_normalize: bool = True
                             ) -> Tuple[jnp.ndarray, dict]:
    """Two-stage EP dispatch (§Perf cell A optimized path).

    Tokens are split into G groups aligned with the data axis; each group
    sorts and capacity-packs locally (vmap over G — shard-local compute),
    producing buf (G, E, C, D) batch-major.  The single transpose to
    expert-major (E, G·C, D) sharded on `model` is the EP all-to-all.
    Numerically equivalent to `moe_forward` up to which tokens are dropped
    at tight capacity (capacity is per-group here, as in real EP systems).
    """
    b, s, d = x.shape
    t = b * s
    g = min(DISPATCH_GROUPS, b)
    while b % g:                 # groups must tile the batch exactly
        g -= 1
    tg = t // g
    xf = x.reshape(g, tg, d)
    logits = (xf.astype(jnp.float32) @ params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                 # (G, Tg, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)     # (G, Tg, k)
    if router_normalize:
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)
    capacity = max(1, int(tg * top_k / num_experts * capacity_factor))

    def dispatch_group(xg, eidx, gv):
        flat_e = eidx.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        sorted_tok = jnp.repeat(jnp.arange(tg), top_k)[order]
        sorted_g = gv.reshape(-1)[order]
        counts = jnp.bincount(sorted_e, length=num_experts)
        starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                                  jnp.cumsum(counts)[:-1]])
        pos = jnp.arange(tg * top_k) - starts[sorted_e]
        keep = pos < capacity
        pos_c = jnp.minimum(pos, capacity - 1)
        buf = jnp.zeros((num_experts, capacity, d), x.dtype)
        src = jnp.where(keep[:, None], xg[sorted_tok], 0).astype(x.dtype)
        buf = buf.at[sorted_e, pos_c].add(src)
        return buf, (sorted_e, sorted_tok, sorted_g, keep, pos_c)

    buf, meta = jax.vmap(dispatch_group)(xf, expert_idx, gate_vals)
    buf = constrain(buf, ("pod", "data"), None, None, None)  # batch-major
    # --- the EP all-to-all: batch-major -> expert-major ---
    buf_e = jnp.swapaxes(buf, 0, 1)                  # (E, G, C, D)
    buf_e = constrain(buf_e, "model", None, None, None)
    h_gate = jnp.einsum("egcd,edf->egcf", buf_e, params["w_gate"])
    h_up = jnp.einsum("egcd,edf->egcf", buf_e, params["w_up"])
    h = (jax.nn.silu(h_gate.astype(jnp.float32)).astype(x.dtype) * h_up)
    out_e = jnp.einsum("egcf,efd->egcd", h, params["w_down"])
    out_e = constrain(out_e, "model", None, None, None)
    out_buf = jnp.swapaxes(out_e, 0, 1)              # (G, E, C, D)
    out_buf = constrain(out_buf, ("pod", "data"), None, None, None)

    def combine_group(out_g, meta_g):
        sorted_e, sorted_tok, sorted_g, keep, pos_c = meta_g
        gathered = out_g[sorted_e, pos_c]
        contrib = jnp.where(keep[:, None], gathered, 0) * \
            sorted_g[:, None].astype(x.dtype)
        return jax.ops.segment_sum(contrib, sorted_tok, num_segments=tg)

    y = jax.vmap(combine_group)(out_buf, meta)       # (G, Tg, D)
    y = y.reshape(t, d)
    if "shared" in params:
        y = y + mlp(params["shared"], x.reshape(t, d)).reshape(t, d)
    me = probs.reshape(t, num_experts).mean(axis=0)
    fe = jnp.bincount(expert_idx.reshape(-1), length=num_experts) / (
        t * top_k)
    keep_frac = meta[3].astype(jnp.float32).mean()
    aux = {
        "load_balance_loss": num_experts * jnp.sum(fe * me),
        "dropped_fraction": 1.0 - keep_frac,
    }
    return y.reshape(b, s, d), aux


def moe_forward(params, x, *, num_experts: int, top_k: int,
                capacity_factor: float = 1.25,
                router_normalize: bool = True) -> Tuple[jnp.ndarray, dict]:
    """x: (B, S, D) -> (B, S, D), aux metrics (load-balance loss etc.)."""
    if CONSTRAIN_DISPATCH == "hierarchical":
        return moe_forward_hierarchical(
            params, x, num_experts=num_experts, top_k=top_k,
            capacity_factor=capacity_factor,
            router_normalize=router_normalize)
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    logits = (xf.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                 # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)     # (T, k)
    if router_normalize:
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = max(1, int(t * top_k / num_experts * capacity_factor))

    flat_e = expert_idx.reshape(-1)                         # (T*k,)
    flat_g = gate_vals.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), top_k)

    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_tok = flat_tok[order]
    sorted_g = flat_g[order]
    # position within expert: index - start offset of that expert
    counts = jnp.bincount(sorted_e, length=num_experts)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(t * top_k) - starts[sorted_e]
    keep = pos < capacity
    pos_c = jnp.minimum(pos, capacity - 1)

    # ---- dispatch: scatter kept tokens into (E, C, D) expert buffers ----
    buf = jnp.zeros((num_experts, capacity, d), x.dtype)
    src = jnp.where(keep[:, None], xf[sorted_tok], 0).astype(x.dtype)
    if CONSTRAIN_DISPATCH:
        src = constrain(src, ("pod", "data"), None)   # token-major: batch
    buf = buf.at[sorted_e, pos_c].add(src)   # unique (e,pos) among kept
    if CONSTRAIN_DISPATCH:
        buf = constrain(buf, "model", None, None)     # expert-major: EP

    # ---- expert computation: batched GLU MLP over the expert axis ----
    h_gate = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    h_up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = (jax.nn.silu(h_gate.astype(jnp.float32)).astype(x.dtype) * h_up)
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    if CONSTRAIN_DISPATCH:
        out_buf = constrain(out_buf, "model", None, None)

    # ---- combine: gather back and weight by gates ----
    gathered = out_buf[sorted_e, pos_c]                     # (T*k, D)
    if CONSTRAIN_DISPATCH:
        gathered = constrain(gathered, ("pod", "data"), None)
    contrib = jnp.where(keep[:, None], gathered, 0) * sorted_g[:, None
                                                               ].astype(x.dtype)
    y = jax.ops.segment_sum(contrib, sorted_tok, num_segments=t)

    if "shared" in params:
        y = y + mlp(params["shared"], xf).reshape(t, d)

    # Switch aux load-balance loss: E * Σ_e f_e · p_e
    me = probs.mean(axis=0)
    fe = jnp.bincount(flat_e, length=num_experts) / (t * top_k)
    aux = {
        "load_balance_loss": num_experts * jnp.sum(fe * me),
        "dropped_fraction": 1.0 - keep.mean(),
    }
    return y.reshape(b, s, d), aux
