"""Mamba2 (SSD) block — the state-space component of Zamba2 hybrids.

h_t = exp(A·dt_t)·h_{t-1} + dt_t·(x_t ⊗ B_t);   y_t = h_t·C_t + D·x_t

with per-head scalar A (negative), data-dependent dt (softplus), a width-4
causal conv on the (x,B,C) stream, and gated output.  State per layer:
(B, heads, head_dim, d_state) fp32 + conv tail (B, conv-1, conv_dim) —
O(1) in sequence length, enabling the 500k decode shape.

Recurrent lax.scan formulation (faithful); the chunked block-parallel SSD
is a §Perf candidate.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import init_dense, rms_norm

CONV_W = 4

# §Perf lever (EXPERIMENTS.md, zamba2 cells): the block-parallel SSD form
# (Mamba2's own chunked algorithm).  The recurrent scan streams the
# (B,H,P,N) state every timestep — the dominant memory-roofline term for
# hybrid/ssm train/prefill.  Chunking crosses the scan boundary once per
# SSD_CHUNK steps and turns intra-chunk work into masked matmuls (MXU).
# Default off: the recurrent form is the paper-faithful baseline.
CHUNKED_SSD = False
SSD_CHUNK = 16


def _ssd_chunked(xs, B, C, dt, a, h0):
    """Block-parallel SSD (Mamba2 Alg. 1, single B/C group).

    xs: (Bt, T, H, P); B/C: (Bt, T, N); dt: (Bt, T, H) softplus'd;
    a: (H,) negative; h0: (Bt, H, P, N) fp32.
    Returns y (Bt, T, H, P) fp32, h_final.

    Within a chunk:  log-decay L_t = Σ_{s<=t} a·dt_s;
      y_t = C_t·(e^{L_t} h0) + Σ_{s<=t} e^{L_t - L_s} dt_s (C_t·B_s) x_s
      h_end = e^{L_K} h0 + Σ_s e^{L_K - L_s} dt_s (x_s ⊗ B_s)
    The inner sum is a causal-masked (K×K) matmul per head — MXU work
    instead of K sequential state updates.
    """
    bt, t, h, p = xs.shape
    n = B.shape[-1]
    k = SSD_CHUNK
    nc = t // k

    xs = xs.astype(jnp.float32).reshape(bt, nc, k, h, p)
    Bc = B.astype(jnp.float32).reshape(bt, nc, k, n)
    Cc = C.astype(jnp.float32).reshape(bt, nc, k, n)
    dtc = dt.astype(jnp.float32).reshape(bt, nc, k, h)

    # per-chunk log-decays
    la = a[None, None, None, :] * dtc                   # (Bt,nc,K,H)
    L = jnp.cumsum(la, axis=2)                          # L_t inclusive
    cb = jnp.einsum("bctn,bcsn->bcts", Cc, Bc)          # (Bt,nc,K,K)
    # G[t,s] = e^{L_t - L_s} dt_s (C_t·B_s) for s<=t
    diff = L[:, :, :, None, :] - L[:, :, None, :, :]    # (Bt,nc,K,K,H)
    mask = jnp.tril(jnp.ones((k, k), bool))
    G = jnp.where(mask[None, None, :, :, None],
                  jnp.exp(diff), 0.0) * dtc[:, :, None, :, :] \
        * cb[..., None]                                 # (Bt,nc,K,K,H)
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", G, xs)

    # inter-chunk: carry h through chunk ends (scan over nc chunks)
    ed = jnp.exp(L)                                     # e^{L_t}
    # contribution of h0_c to each step: C_t · (e^{L_t} h0)
    # state update over the chunk:
    #   h_end = e^{L_K} h0 + Σ_s e^{L_K - L_s} dt_s (x_s ⊗ B_s)
    w_end = jnp.exp(L[:, :, -1:, :] - L) * dtc          # (Bt,nc,K,H)
    dxb = jnp.einsum("bcsh,bcshp,bcsn->bchpn", w_end, xs, Bc)

    def chunk_step(h, inp):
        ed_c, Cc_c, dxb_c, laK = inp
        y_h0 = jnp.einsum("bth,btn,bhpn->bthp", ed_c, Cc_c, h)
        h = jnp.exp(laK)[..., None, None] * h + dxb_c
        return h, y_h0

    la_sum = L[:, :, -1, :]                             # (Bt,nc,H)
    h_fin, y_h0 = jax.lax.scan(
        chunk_step, h0,
        (jnp.moveaxis(ed, 1, 0), jnp.moveaxis(Cc, 1, 0),
         jnp.moveaxis(dxb, 1, 0), jnp.moveaxis(la_sum, 1, 0)))
    y = y_intra + jnp.moveaxis(y_h0, 0, 1)
    return y.reshape(bt, t, h, p), h_fin


def init_mamba2(key, d_model: int, d_inner: int, d_state: int,
                head_dim: int = 64, dtype=jnp.bfloat16):
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * d_state
    ks = jax.random.split(key, 5)
    return {
        # z (gate), xBC (conv stream), dt (heads)
        "w_in": init_dense(ks[0], d_model,
                           d_inner + conv_dim + n_heads, dtype),
        "conv_w": (jax.random.normal(ks[1], (CONV_W, conv_dim), jnp.float32)
                   * (CONV_W ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.arange(1, n_heads + 1, dtype=jnp.float32)),
        "dt_bias": jnp.full((n_heads,), math.log(math.e - 1), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "out_norm": jnp.ones((d_inner,), jnp.float32),
        "w_out": init_dense(ks[2], d_inner, d_model, dtype),
    }


class Mamba2State(NamedTuple):
    h: jnp.ndarray          # (B, H, P, N) fp32 SSM state
    conv: jnp.ndarray       # (B, CONV_W-1, conv_dim) conv tail


def init_state(batch: int, d_inner: int, d_state: int, head_dim: int = 64,
               dtype=jnp.bfloat16) -> Mamba2State:
    n_heads = d_inner // head_dim
    return Mamba2State(
        h=jnp.zeros((batch, n_heads, head_dim, d_state), jnp.float32),
        conv=jnp.zeros((batch, CONV_W - 1, d_inner + 2 * d_state), dtype))


def _split(p, x, d_inner: int, d_state: int, n_heads: int):
    zxbcdt = x @ p["w_in"]
    conv_dim = d_inner + 2 * d_state
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + conv_dim]
    dt = zxbcdt[..., d_inner + conv_dim:]
    return z, xbc, dt


def _conv(p, xbc, conv_state):
    """Causal depthwise conv width 4; conv_state holds the previous CONV_W-1
    inputs.  Returns (activated stream, new tail)."""
    full = jnp.concatenate([conv_state, xbc], axis=1)   # (B, T+3, C)
    t = xbc.shape[1]
    acc = jnp.zeros_like(xbc, dtype=jnp.float32)
    for w in range(CONV_W):
        acc = acc + (full[:, w:w + t] * p["conv_w"][w]).astype(jnp.float32)
    acc = acc + p["conv_b"].astype(jnp.float32)
    return jax.nn.silu(acc).astype(xbc.dtype), full[:, -(CONV_W - 1):]


def mamba2_forward(p, x, state: Mamba2State, *, d_inner: int, d_state: int,
                   head_dim: int = 64):
    """x: (B, T, D) -> (y, new_state)."""
    b, t, _ = x.shape
    n_heads = d_inner // head_dim
    z, xbc, dt = _split(p, x, d_inner, d_state, n_heads)
    xbc, conv_tail = _conv(p, xbc, state.conv)
    xs = xbc[..., :d_inner].reshape(b, t, n_heads, head_dim)
    B = xbc[..., d_inner:d_inner + d_state]              # (B,T,N) group=1
    C = xbc[..., d_inner + d_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    a = -jnp.exp(p["a_log"])                             # (H,) negative

    if CHUNKED_SSD and t % SSD_CHUNK == 0 and t > 1:
        y, h_new = _ssd_chunked(xs, B, C, dt, a, state.h)
    else:
        def step(h, inp):
            x_t, b_t, c_t, dt_t = inp   # (B,H,P), (B,N), (B,N), (B,H)
            decay = jnp.exp(a * dt_t)   # (B,H)
            dbx = (dt_t[..., None] * x_t)[..., None] * b_t[:, None, None, :]
            h = decay[..., None, None] * h + dbx
            y = jnp.einsum("bhpn,bn->bhp", h, c_t)
            return h, y

        h_new, ys = jax.lax.scan(
            step, state.h,
            (jnp.moveaxis(xs, 1, 0).astype(jnp.float32),
             jnp.moveaxis(B, 1, 0).astype(jnp.float32),
             jnp.moveaxis(C, 1, 0).astype(jnp.float32),
             jnp.moveaxis(dt, 1, 0)))
        y = jnp.moveaxis(ys, 0, 1)                        # (B,T,H,P)
    y = y + p["d_skip"][:, None] * xs.astype(jnp.float32)
    y = y.reshape(b, t, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, p["out_norm"])
    return y @ p["w_out"], Mamba2State(h=h_new, conv=conv_tail)
