"""Shared building blocks for the model zoo.

Conventions:
  * params are plain dict pytrees; init functions take an explicit PRNG key;
  * compute dtype is configurable (bf16 default), accumulation/normalization
    in fp32;
  * every weight has a logical axis annotation (see sharding.py) used to
    derive PartitionSpecs for the production mesh.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rms_norm(x, scale, eps: float = 1e-6, offset: float = 0.0):
    """RMSNorm in fp32 (gemma uses (1+scale) — pass offset=1.0)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (offset + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias=None, eps: float = 1e-5):
    """LayerNorm in fp32 (RWKV blocks use LN, not RMSNorm)."""
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = xf.var(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def init_dense(key, in_dim: int, out_dim: int, dtype=jnp.bfloat16,
               scale: Optional[float] = None):
    s = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * s
            ).astype(dtype)


def init_embed(key, vocab: int, dim: int, dtype=jnp.bfloat16):
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02
            ).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., seq, hd/2)
    cos = jnp.cos(ang)[..., None, :]                     # (..., seq, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": init_dense(k1, d_model, d_ff, dtype),
        "wi_up": init_dense(k2, d_model, d_ff, dtype),
        "wo": init_dense(k3, d_ff, d_model, dtype),
    }


def mlp(params, x, activation: str = "silu"):
    """SwiGLU (llama-family) or GeGLU (gemma)."""
    gate = x @ params["wi_gate"]
    up = x @ params["wi_up"]
    if activation == "silu":
        act = jax.nn.silu(gate.astype(jnp.float32))
    elif activation == "gelu":
        act = jax.nn.gelu(gate.astype(jnp.float32), approximate=True)
    else:
        raise ValueError(activation)
    return (act.astype(x.dtype) * up) @ params["wo"]


def cross_entropy_loss(logits, labels, z_loss: float = 1e-4):
    """Token-mean cross entropy with z-loss regularization; fp32 reduction.

    logits: (..., V) — may be sharded on V (logsumexp reduces across the
    shard axis via GSPMD); labels: (...), -100 entries are masked.
    """
    logits = logits.astype(jnp.float32)
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    denom = jnp.maximum(mask.sum(), 1)
    return jnp.where(mask, nll, 0.0).sum() / denom
