"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free time-mix with
data-dependent decay, plus the squared-ReLU channel-mix.

State per layer: the WKV matrix S (B, H, N, N) in fp32, the previous token
for the time-mix shift, and the previous token for the channel-mix shift —
O(1) in sequence length, which is why this arch (not full attention) runs
the 500k-token decode shape.

Training runs a lax.scan over time (recurrent form — the paper-faithful
formulation); the chunked-parallel form is a §Perf candidate.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .layers import init_dense

LORA_MIX = 32     # low-rank size of the data-dependent mixing MLP
LORA_DECAY = 64   # low-rank size of the data-dependent decay MLP

# §Perf lever (EXPERIMENTS.md cell F): chunked block-parallel WKV6 — the
# same transform as Mamba2's SSD (models/mamba2.py).  The recurrent scan
# streams the (B,H,N,N) state every token; chunking crosses the scan
# boundary once per WKV_CHUNK steps and computes intra-chunk interactions
# as masked matmuls in log-decay space (per-channel decays, so the decay
# kernel is materialized per (t,s,channel) — (K,K,N) per head-chunk).
CHUNKED_WKV = False
WKV_CHUNK = 16


def init_time_mix(key, d: int, n_heads: int, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 12)
    hd = d // n_heads
    return {
        "maa_x": jnp.zeros((d,), dtype),
        "maa_rkvwg": jnp.zeros((5, d), dtype),
        "maa_w1": init_dense(ks[0], d, 5 * LORA_MIX, dtype, scale=1e-4),
        "maa_w2": (jax.random.normal(ks[1], (5, LORA_MIX, d), jnp.float32)
                   * LORA_MIX ** -0.5).astype(dtype),
        "decay_base": jnp.asarray(
            jnp.tile(-6.0 + 5.0 * (jnp.arange(d) / max(1, d - 1)) ** 0.9,
                     1), jnp.float32),
        "decay_w1": init_dense(ks[2], d, LORA_DECAY, dtype, scale=1e-4),
        "decay_w2": init_dense(ks[3], LORA_DECAY, d, dtype, scale=1e-4),
        "bonus": jnp.zeros((n_heads, hd), jnp.float32),        # u
        "wr": init_dense(ks[4], d, d, dtype),
        "wk": init_dense(ks[5], d, d, dtype),
        "wv": init_dense(ks[6], d, d, dtype),
        "wg": init_dense(ks[7], d, d, dtype),
        "wo": init_dense(ks[8], d, d, dtype),
        "ln_x": jnp.ones((d,), jnp.float32),                   # group norm
    }


def init_channel_mix(key, d: int, d_ff: int, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    return {
        "maa_k": jnp.zeros((d,), dtype),
        "maa_r": jnp.zeros((d,), dtype),
        "wk": init_dense(ks[0], d, d_ff, dtype),
        "wv": init_dense(ks[1], d_ff, d, dtype),
        "wr": init_dense(ks[2], d, d, dtype),
    }


def _mix_inputs(p, x, x_prev):
    """Data-dependent token-shift interpolation (the Finch novelty).

    x: (B, T, D); x_prev: (B, D) token before the window.
    Returns 5 mixed streams (r, k, v, w, g) each (B, T, D).
    """
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    xx = shifted - x
    xxx = x + xx * p["maa_x"]
    mixed = jnp.tanh(xxx @ p["maa_w1"])                     # (B,T,5*L)
    b, t, _ = mixed.shape
    mixed = mixed.reshape(b, t, 5, LORA_MIX)
    deltas = jnp.einsum("btfl,fld->fbtd", mixed, p["maa_w2"])
    outs = []
    for f in range(5):
        m = p["maa_rkvwg"][f] + deltas[f]
        outs.append(x + xx * m)
    return outs  # xr, xk, xv, xw, xg


def _decay(p, xw):
    """Per-channel data-dependent decay w in (0,1): exp(-exp(base+lora))."""
    lora = jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]
    return jnp.exp(-jnp.exp(p["decay_base"] + lora.astype(jnp.float32)))


def _group_norm(x, scale, n_heads, eps=1e-5):
    b, t, d = x.shape
    xg = x.reshape(b, t, n_heads, d // n_heads).astype(jnp.float32)
    mu = xg.mean(-1, keepdims=True)
    var = xg.var(-1, keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return (xg.reshape(b, t, d) * scale).astype(x.dtype)


def _wkv_chunked(r, k, v, w, u, S0):
    """Block-parallel WKV6 (cell F): exact chunked form of the recurrence

        S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t
        y_t = r_t · (S_{t-1} + diag(u) k_t ⊗ v_t)

    Per chunk, in log-decay space (decays are per key-channel, so the
    intra-chunk kernel sums over channels with the exp inside):
        G[t,s] = Σ_i r_ti k_si e^{L_{t-1,i} - L_{s,i}}   (s < t)
        G[t,t] = (r_t ⊙ u) · k_t                         (bonus)
        y = G @ v + (r_t ⊙ e^{L_{t-1}}) · S_carry
    All exponents are ≤ 0 (decays < 1), so no overflow.
    r/k/v/w: (B,T,H,N) fp32; u: (H,N); S0: (B,H,N,N).
    """
    b, t, h, n = r.shape
    kk = WKV_CHUNK
    nc = t // kk

    def resh(a):
        return jnp.moveaxis(a.reshape(b, nc, kk, h, n), 1, 0)

    r_, k_, v_, w_ = map(resh, (r, k, v, w))      # (nc,B,K,H,N)
    mask_lt = jnp.tril(jnp.ones((kk, kk), jnp.bool_), -1)

    def chunk(S, inp):
        rc, kc, vc, wc = inp                      # (B,K,H,N)
        logw = jnp.log(jnp.maximum(wc, 1e-38))
        L = jnp.cumsum(logw, axis=1)
        Lp = L - logw                             # L_{t-1}
        diff = Lp[:, :, None] - L[:, None]        # (B,K,K,H,N) [t,s]
        dk = jnp.where(mask_lt[None, :, :, None, None],
                       jnp.exp(diff), 0.0)
        G = jnp.einsum("bthn,bshn,btshn->btsh", rc, kc, dk)
        Gdiag = jnp.einsum("bthn,hn,bthn->bth", rc, u, kc)
        y = jnp.einsum("btsh,bshn->bthn", G, vc) + Gdiag[..., None] * vc
        y = y + jnp.einsum("bthi,bhij->bthj", rc * jnp.exp(Lp), S)
        wend = jnp.exp(L[:, -1][:, None] - L)     # e^{L_K - L_s}
        S = jnp.exp(L[:, -1])[..., None] * S + jnp.einsum(
            "bshn,bshm->bhnm", kc * wend, vc)
        return S, y

    S_fin, ys = jax.lax.scan(chunk, S0, (r_, k_, v_, w_))
    return jnp.moveaxis(ys, 0, 1).reshape(b, t, h, n), S_fin


def time_mix(p, x, x_prev, S, n_heads: int):
    """WKV6 over a window.  x: (B,T,D); S: (B,H,N,N) fp32 state.
    Returns (y, new_x_prev, new_S)."""
    b, t, d = x.shape
    n = d // n_heads
    xr, xk, xv, xw, xg = _mix_inputs(p, x, x_prev)
    r = (xr @ p["wr"]).reshape(b, t, n_heads, n).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(b, t, n_heads, n).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(b, t, n_heads, n).astype(jnp.float32)
    g = jax.nn.silu((xg @ p["wg"]).astype(jnp.float32))
    w = _decay(p, xw).reshape(b, t, n_heads, n)             # (B,T,H,N)
    u = p["bonus"]                                          # (H,N)

    if CHUNKED_WKV and t % WKV_CHUNK == 0 and t > 1:
        ys_btd, S_new = _wkv_chunked(r, k, v, w, u, S)
        wkv = ys_btd.reshape(b, t, d).astype(x.dtype)
    else:
        def step(S, inp):
            r_t, k_t, v_t, w_t = inp                        # (B,H,N)
            kv = k_t[..., :, None] * v_t[..., None, :]      # (B,H,N,N)
            y = jnp.einsum("bhi,bhij->bhj", r_t, S + u[..., None] * kv)
            S = w_t[..., None] * S + kv
            return S, y

        S_new, ys = jax.lax.scan(
            step, S,
            (jnp.moveaxis(r, 1, 0), jnp.moveaxis(k, 1, 0),
             jnp.moveaxis(v, 1, 0), jnp.moveaxis(w, 1, 0)))
        wkv = jnp.moveaxis(ys, 0, 1).reshape(b, t, d).astype(x.dtype)
    out = _group_norm(wkv, p["ln_x"], n_heads)
    y = (out * g.astype(out.dtype)) @ p["wo"]
    return y.astype(x.dtype), x[:, -1], S_new


def channel_mix(p, x, x_prev):
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    xx = shifted - x
    xk = x + xx * p["maa_k"]
    xr = x + xx * p["maa_r"]
    k = jnp.square(jax.nn.relu((xk @ p["wk"]).astype(jnp.float32)))
    kv = k.astype(x.dtype) @ p["wv"]
    return jax.nn.sigmoid((xr @ p["wr"]).astype(jnp.float32)
                          ).astype(x.dtype) * kv, x[:, -1]


class RWKVLayerState(NamedTuple):
    tm_x: jnp.ndarray     # (B, D) last token seen by time-mix
    cm_x: jnp.ndarray     # (B, D) last token seen by channel-mix
    S: jnp.ndarray        # (B, H, N, N) fp32 WKV state


def init_state(batch: int, d: int, n_heads: int, dtype=jnp.bfloat16):
    n = d // n_heads
    return RWKVLayerState(
        tm_x=jnp.zeros((batch, d), dtype),
        cm_x=jnp.zeros((batch, d), dtype),
        S=jnp.zeros((batch, n_heads, n, n), jnp.float32))
