"""Sharding rules: map model parameters and activations onto the mesh.

Mesh axes (launch/mesh.py):
  pod    — data parallelism across pods (crosses DCI);
  data   — in-pod data parallelism; parameters are FSDP-sharded here
           (ZeRO-style — GSPMD inserts the use-site all-gathers);
  model  — tensor/expert parallelism (heads, d_ff, vocab, experts).

Rules are name-based over the trailing dims of each leaf (stacked layer
axes are padded with None on the left) with per-dim divisibility guards —
a dim that does not divide its mesh axis falls back to replication (e.g.
granite's vocab 49155 on 16-way model).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# rules: leaf-name -> spec for the trailing dims (None-padded on the left).
# "col" = (in, out) -> (data, model); "row" = (in, out) -> (model, data).
_COL2 = ("data", "model")
_ROW2 = ("model", "data")
_RULES = {
    # embeddings / head
    "embed": ("model", "data"),
    "head": _COL2,
    # attention
    "wq": _COL2, "wk": _COL2, "wv": _COL2, "wo": _ROW2,
    # mlp
    "wi_gate": _COL2, "wi_up": _COL2, "w_down": _ROW2,
    # moe (E, D, F) / (E, F, D); experts over model (EP)
    "w_gate": ("model", "data", None),
    "w_up": ("model", "data", None),
    "router": ("data", None),
    # mla
    "w_dkv": _COL2,
    "w_uk": (None, "model", None), "w_uv": (None, "model", None),
    "w_q": ("data", "model", None), "w_o": ("model", None, "data"),
    # rwkv
    "wr": _COL2, "wg": _COL2,
    "maa_w1": _COL2, "decay_w1": _COL2, "decay_w2": _ROW2,
    # mamba2
    "w_in": _COL2, "w_out": _ROW2, "conv_w": (None, "model"),
    "conv_b": ("model",),
    # lora adapters (hybrid shared block)
    "lora_a": _COL2, "lora_b": _ROW2,
}
# name collisions resolved by parent path fragment
_CONTEXT_RULES = {
    ("cm", "wv"): _ROW2,        # rwkv channel-mix down-proj (F, D)
    ("moe", "w_down"): ("model", None, "data"),
}


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for e in path:
        if hasattr(e, "key"):
            names.append(str(e.key))
        elif hasattr(e, "name"):
            names.append(str(e.name))
    return tuple(names)


def _guard(spec_tail, shape, axis_sizes):
    """Drop axes that don't divide their dim; pad front with None."""
    tail = list(spec_tail)
    k = len(tail)
    full = [None] * (len(shape) - k) + tail
    out = []
    for dim, ax in zip(shape, full):
        if ax is None:
            out.append(None)
        elif isinstance(ax, str):
            size = axis_sizes.get(ax, 1)
            out.append(ax if size > 1 and dim % size == 0 else None)
        else:
            out.append(None)
    return P(*out)


def _axis_sizes(mesh):
    return dict(mesh.shape)      # works for Mesh and AbstractMesh


def infer_param_specs(params, mesh) -> "jax.tree_util.PyTreeDef":
    """PartitionSpec pytree matching ``params`` for the given mesh."""
    axis_sizes = _axis_sizes(mesh)

    def leaf_spec(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        if leaf.ndim <= 1:
            return P()
        for (ctx, n), spec in _CONTEXT_RULES.items():
            if n == name and ctx in names:
                return _guard(spec, leaf.shape, axis_sizes)
        if name in _RULES:
            return _guard(_RULES[name], leaf.shape, axis_sizes)
        if leaf.ndim >= 2:
            return _guard(_COL2, leaf.shape, axis_sizes)   # generic matmul
        return P()

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def guard_spec(spec: P, shape, mesh) -> P:
    """Drop spec entries that don't divide their dim on this mesh; flatten
    axis tuples whose axes are absent."""
    axis_sizes = _axis_sizes(mesh)
    out = []
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for dim, e in zip(shape, entries):
        if e is None:
            out.append(None)
            continue
        axes = (e,) if isinstance(e, str) else tuple(e)
        axes = tuple(a for a in axes if axis_sizes.get(a, 1) > 1)
        total = 1
        for a in axes:
            total *= axis_sizes[a]
        if axes and dim % total == 0:
            out.append(axes[0] if len(axes) == 1 else axes)
        else:
            out.append(None)
    return P(*out)


def materialize(spec_tree, sds_tree, mesh):
    """Logical spec pytree + ShapeDtypeStruct pytree -> NamedSharding pytree
    (guarded per-leaf)."""
    from jax.sharding import NamedSharding

    def one(spec, sds):
        if not isinstance(spec, P):
            spec = P() if spec is None else spec
        return NamedSharding(mesh, guard_spec(spec, sds.shape, mesh))

    return jax.tree.map(one, spec_tree, sds_tree,
                        is_leaf=lambda x: isinstance(x, P) or x is None)


# ---------------------------------------------------------------------------
# Activation constraints (no-ops outside a mesh context)
# ---------------------------------------------------------------------------

def _current_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    try:
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            m = jax.interpreters.pxla.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def batch_axes(mesh=None) -> Tuple[str, ...]:
    mesh = mesh or _current_mesh()
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def constrain(x, *spec):
    """with_sharding_constraint with divisibility guards; identity when no
    mesh is active (CPU smoke tests)."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    axis_sizes = _axis_sizes(mesh)
    out = []
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        axes = tuple(a for a in axes if axis_sizes.get(a, 1) > 1)
        total = 1
        for a in axes:
            total *= axis_sizes[a]
        if axes and total > 1 and dim % total == 0:
            out.append(axes[0] if len(axes) == 1 else axes)
        else:
            out.append(None)
    return jax.lax.with_sharding_constraint(x, P(*out))


def constrain_tokens(x):
    """(B, S[, D]) activations: batch over (pod, data)."""
    ba = batch_axes()
    if not ba:
        return x
    spec = [ba] + [None] * (x.ndim - 1)
    return constrain(x, *spec)
