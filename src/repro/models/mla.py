"""Multi-head Latent Attention (DeepSeek-V2) — compressed-KV attention.

Train/prefill materializes per-head K/V from the latent; decode uses the
*absorbed* formulation: the query is projected into the latent space
(q_abs = q_nope @ W_uk) so the cache stores only (c_kv: r, k_rope: dr) per
token — 576 values/token for V2-Lite vs n_heads*(dk+dv) = 4096 for vanilla
MHA.  Absorbed decode is algebraically MQA with head dim r+dr, so it reuses
the generic ``attention`` kernel with kh=1.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .attention import attention
from .layers import apply_rope, init_dense, rms_norm


def init_mla(key, d_model: int, n_heads: int, kv_lora: int,
             nope_dim: int, rope_dim: int, v_dim: int, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 5)
    return {
        "w_dkv": init_dense(ks[0], d_model, kv_lora + rope_dim, dtype),
        "ckv_norm": jnp.ones((kv_lora,), jnp.float32),
        "w_uk": (jax.random.normal(ks[1], (kv_lora, n_heads, nope_dim),
                                   jnp.float32) * (kv_lora ** -0.5)
                 ).astype(dtype),
        "w_uv": (jax.random.normal(ks[2], (kv_lora, n_heads, v_dim),
                                   jnp.float32) * (kv_lora ** -0.5)
                 ).astype(dtype),
        "w_q": (jax.random.normal(
            ks[3], (d_model, n_heads, nope_dim + rope_dim), jnp.float32)
            * (d_model ** -0.5)).astype(dtype),
        "w_o": (jax.random.normal(ks[4], (n_heads, v_dim, d_model),
                                  jnp.float32) * ((n_heads * v_dim) ** -0.5)
                ).astype(dtype),
    }


class MLACache(NamedTuple):
    c_kv: jnp.ndarray       # (B, T, r)
    k_rope: jnp.ndarray     # (B, T, dr)
    length: jnp.ndarray


def init_mla_cache(batch: int, max_len: int, kv_lora: int, rope_dim: int,
                   dtype=jnp.bfloat16) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((batch, max_len, kv_lora), dtype),
        k_rope=jnp.zeros((batch, max_len, rope_dim), dtype),
        length=jnp.zeros((), jnp.int32))


def _latents(params, x, positions, kv_lora, rope_theta):
    c = x @ params["w_dkv"]
    c_kv, k_rope_raw = c[..., :kv_lora], c[..., kv_lora:]
    c_kv = rms_norm(c_kv, params["ckv_norm"])
    k_rope = apply_rope(k_rope_raw[:, :, None, :], positions, rope_theta)
    return c_kv, k_rope[:, :, 0, :]


def mla_forward(params, x, positions, *, n_heads, kv_lora, nope_dim,
                rope_dim, v_dim, rope_theta=10000.0, kv_chunk=2048):
    """Materialized train/prefill path."""
    b, s, _ = x.shape
    c_kv, k_rope = _latents(params, x, positions, kv_lora, rope_theta)
    q = jnp.einsum("bsd,dhe->bshe", x, params["w_q"])
    q_nope, q_rope = q[..., :nope_dim], q[..., nope_dim:]
    q_rope = apply_rope(q_rope, positions, rope_theta)
    k_nope = jnp.einsum("bsr,rhe->bshe", c_kv, params["w_uk"])
    v = jnp.einsum("bsr,rhe->bshe", c_kv, params["w_uv"])
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, n_heads, rope_dim))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = attention(q_full, k_full, v, positions, positions,
                    kv_chunk=kv_chunk)
    y = jnp.einsum("bshe,hed->bsd", out.reshape(b, s, n_heads, v_dim),
                   params["w_o"])
    return y, (c_kv, k_rope)


def mla_decode(params, x, cache: MLACache, *, n_heads, kv_lora, nope_dim,
               rope_dim, v_dim, rope_theta=10000.0):
    """Absorbed single-token decode (MQA over the latent cache).

    Scores are computed as TWO contractions (latent + rope) instead of
    concatenating the caches: a concat across the latent dim forces GSPMD
    to all-gather the whole cache every layer (§Perf cell B — 15.6 GB/step
    before this change).  With separate contractions the cache stays
    resident (replicated over `model`; heads carry the TP sharding) and
    the only cross-chip traffic is the final output reduction.
    """
    import math
    b = x.shape[0]
    pos = cache.length[None]
    c_kv, k_rope = _latents(params, x, pos, kv_lora, rope_theta)
    q = jnp.einsum("bsd,dhe->bshe", x, params["w_q"])
    q_nope, q_rope = q[..., :nope_dim], q[..., nope_dim:]
    q_rope = apply_rope(q_rope, pos, rope_theta)
    # absorb: q_abs[h, r] = q_nope[h, e] @ w_uk[r, h, e]
    q_abs = jnp.einsum("bshe,rhe->bshr", q_nope, params["w_uk"])

    # Replicate the (B,1,576) new-token latents BEFORE the cache update:
    # w_dkv's output is model-sharded, and without this the whole updated
    # cache inherits that sharding and is re-gathered every layer
    # (§Perf cell B — 15.3 GB/step of all-gather for 576 useful values).
    from .sharding import constrain
    c_kv = constrain(c_kv, None, None, None)
    k_rope = constrain(k_rope, None, None, None)

    ckv_new = jax.lax.dynamic_update_slice(
        cache.c_kv, c_kv.astype(cache.c_kv.dtype), (0, cache.length, 0))
    kr_new = jax.lax.dynamic_update_slice(
        cache.k_rope, k_rope.astype(cache.k_rope.dtype), (0, cache.length, 0))

    t = ckv_new.shape[1]
    scale = 1.0 / math.sqrt(nope_dim + rope_dim)
    s = (jnp.einsum("bqhr,btr->bhqt", q_abs, ckv_new,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bqhd,btd->bhqt", q_rope, kr_new,
                      preferred_element_type=jnp.float32)) * scale
    mask = jnp.arange(t)[None, None, None, :] <= cache.length
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhqt,btr->bqhr", w, ckv_new)       # (B,1,H,r)
    out = jnp.einsum("bshr,rhe->bshe", ctx, params["w_uv"])
    y = jnp.einsum("bshe,hed->bsd", out, params["w_o"])
    return y, MLACache(c_kv=ckv_new, k_rope=kr_new, length=cache.length + 1)
