"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-235B-A22B family] — 128 experts
top-8, GQA kv=4, qk-norm; every layer MoE, no shared experts."""
from .base import ModelConfig, MoEConfig, register

FULL = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv=4, head_dim=128,
    d_ff=1536, vocab=151936,
    qk_norm=True, rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1536),
)

REDUCED = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=2, d_model=64, n_heads=8, n_kv=2, head_dim=8,
    d_ff=96, vocab=512,
    qk_norm=True, rope_theta=1_000_000.0,
    # capacity E/k => no token drops (keeps reduced-config decode exactly
    # consistent with prefill; the full config uses the production 1.25)
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=96,
                  capacity_factor=4.0),
)

register(FULL, REDUCED)
