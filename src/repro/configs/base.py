"""Model configuration dataclasses + the architecture registry.

Every assigned architecture registers a ``ModelConfig`` here via its own
module (one file per arch, imported by ``registry()``).  ``reduced()``
returns the family-preserving small config used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0          # shared (always-on) experts
    d_ff_shared: int = 0
    first_dense: int = 0         # leading dense layers (deepseek: 1)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    nope_dim: int = 128
    rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_inner: int = 0             # 0 -> 2 * d_model
    d_state: int = 64
    head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | ssm | hybrid | moe | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    # options
    qkv_bias: bool = False
    qk_norm: bool = False
    activation: str = "silu"     # silu (SwiGLU) | gelu (GeGLU)
    rope_theta: float = 10000.0
    norm_offset: float = 0.0     # gemma: 1.0 ((1+g) RMSNorm)
    embed_scale: bool = False    # gemma: x *= sqrt(d_model)
    tie_embeddings: bool = False
    sliding_window: Optional[int] = None
    # long-context mode (hybrids): window used by attention blocks when the
    # cache would otherwise be unbounded
    long_context_window: int = 4096
    input_mode: str = "tokens"   # tokens | embeds (audio/vlm stub frontend)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_period: int = 6       # zamba2: shared attn every k ssm layers
    shared_lora_rank: int = 64
    dtype: str = "bfloat16"
    # decode KV cache dtype: bfloat16 | int8 (per-(token,head) max-abs
    # scales; §Perf cell C bandwidth-compression lever)
    kv_cache_dtype: str = "bfloat16"
    # which input shapes this arch supports (decode needs a bounded state)
    supports_long_context: bool = False

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def param_count(self) -> int:
        """Analytic parameter count (drives MODEL_FLOPS in §Roofline)."""
        d, v, L = self.d_model, self.vocab, self.n_layers
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.input_mode == "embeds":
            emb = v * d  # head only (frontend stubbed)
        if self.family == "ssm":
            # rwkv6: time-mix 5 square mats + channel-mix
            tm = 5 * d * d + d * (5 * 32) + 5 * 32 * d + d * 64 + 64 * d
            cm = 2 * d * self.d_ff + d * d
            return emb + L * (tm + cm)
        per_layer = 0
        if self.family == "hybrid":
            s = self.ssm or SSMConfig()
            d_inner = s.d_inner or 2 * d
            conv_dim = d_inner + 2 * s.d_state
            nh = d_inner // s.head_dim
            m = d * (d_inner + conv_dim + nh) + d_inner * d
            per_layer = m
            shared = (d * 3 * self.n_heads * self.head_dim
                      + self.n_heads * self.head_dim * d
                      + 3 * d * self.d_ff)
            n_shared_apps = self.n_layers // self.hybrid_period
            return emb + L * per_layer + shared + n_shared_apps * (
                4 * d * self.shared_lora_rank * 2)
        # attention
        if self.mla is not None:
            c = self.mla
            attn = (d * (c.kv_lora + c.rope_dim)
                    + c.kv_lora * self.n_heads * (c.nope_dim + c.v_dim)
                    + d * self.n_heads * (c.nope_dim + c.rope_dim)
                    + self.n_heads * c.v_dim * d)
        else:
            attn = d * self.head_dim * (self.n_heads * 2 + self.n_kv * 2)
        # mlp / moe
        if self.moe is not None:
            m = self.moe
            moe_l = (d * m.num_experts
                     + 3 * d * m.d_ff_expert * m.num_experts
                     + (3 * d * m.d_ff_shared if m.num_shared else 0))
            dense_l = 3 * d * self.d_ff
            n_moe = L - m.first_dense
            return emb + L * attn + n_moe * moe_l + m.first_dense * dense_l
        return emb + L * (attn + 3 * d * self.d_ff)

    def active_param_count(self) -> int:
        """Active (per-token) params — MoE counts top_k + shared experts."""
        if self.moe is None:
            return self.param_count()
        d, L, m = self.d_model, self.n_layers, self.moe
        total = self.param_count()
        n_moe = L - m.first_dense
        all_experts = 3 * d * m.d_ff_expert * m.num_experts
        active = 3 * d * m.d_ff_expert * m.top_k
        return total - n_moe * (all_experts - active)


_REGISTRY = {}


def register(cfg: ModelConfig, reduced: ModelConfig):
    _REGISTRY[cfg.name] = (cfg, reduced)
    return cfg


def registry():
    """Import all arch modules and return {name: (full, reduced)}."""
    from . import (qwen1_5_0_5b, gemma_2b, granite_3_2b, qwen3_8b,  # noqa
                   rwkv6_1_6b, musicgen_large, zamba2_7b,
                   qwen3_moe_235b_a22b, deepseek_v2_lite_16b,
                   llava_next_34b)
    return dict(_REGISTRY)


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    reg = registry()
    if name not in reg:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(reg)}")
    return reg[name][1 if reduced else 0]
