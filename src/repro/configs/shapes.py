"""Assigned input shapes × architecture support matrix.

Four shapes per arch (40 cells).  ``train_*`` lowers train_step;
``prefill_*`` lowers a full-sequence forward; ``decode_*``/``long_*`` lower
serve_step (one new token against a KV cache of seq_len).  long_500k needs
sub-quadratic attention: only the SSM/hybrid archs run it (the 8 pure
full-attention archs record a documented skip — DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .base import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1),
}


def cell_supported(cfg: ModelConfig, shape: InputShape
                   ) -> Tuple[bool, str]:
    """Whether (arch × shape) is a runnable cell, with the reason if not."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: unbounded KV/state at "
                       "524k — sub-quadratic attention required (skip per "
                       "assignment; see DESIGN.md §4)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, object]:
    """ShapeDtypeStruct stand-ins for every input of the lowered step —
    weak-type-correct, shardable, no device allocation.

    train  -> {"inputs", "targets"}
    prefill-> {"inputs"}
    decode -> {"inputs", "state": <decode-state pytree>}
    """
    b, s = shape.batch, shape.seq
    tok = jnp.int32
    if cfg.input_mode == "tokens":
        def inp(batch, seq):
            return jax.ShapeDtypeStruct((batch, seq), tok)
    else:
        def inp(batch, seq):
            return jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                        cfg.compute_dtype)

    if shape.kind == "train":
        return {"inputs": inp(b, s),
                "targets": jax.ShapeDtypeStruct((b, s), tok)}
    if shape.kind == "prefill":
        return {"inputs": inp(b, s)}
    if shape.kind == "decode":
        from repro.models.transformer import build_model
        model = build_model(cfg)
        state = jax.eval_shape(
            lambda: model.init_decode_state(b, s))
        # a cache of seq_len tokens already filled, decoding token seq_len+1
        return {"inputs": inp(b, 1), "state": state}
    raise ValueError(shape.kind)
