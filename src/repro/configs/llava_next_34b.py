"""LLaVA-NeXT-34B [llava-v1.6 family] — dense 34B-class backbone (Yi-34B
shape), GQA kv=8.  The anyres vision tiling is a stub frontend;
input_specs() provides precomputed patch embeddings (input_mode='embeds')."""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv=8, head_dim=128,
    d_ff=20480, vocab=64000,
    input_mode="embeds", rope_theta=5_000_000.0,
)

REDUCED = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=2, d_model=64, n_heads=8, n_kv=2, head_dim=8,
    d_ff=128, vocab=512,
    input_mode="embeds", rope_theta=5_000_000.0,
)

register(FULL, REDUCED)
