"""Qwen3-8B [hf:Qwen/Qwen3-8B] — dense, GQA kv=8, qk-norm."""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
    d_ff=12288, vocab=151936,
    qk_norm=True, rope_theta=1_000_000.0,
)

REDUCED = ModelConfig(
    name="qwen3-8b", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
    d_ff=128, vocab=512,
    qk_norm=True, rope_theta=1_000_000.0,
)

register(FULL, REDUCED)
