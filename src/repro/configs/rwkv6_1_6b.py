"""RWKV6-1.6B "Finch" [arXiv:2404.05892] — attention-free, data-dependent
decay; O(1) state => runs the 500k long-context decode shape."""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv=32, head_dim=64,
    d_ff=7168, vocab=65536,
    supports_long_context=True,
)

REDUCED = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv=4, head_dim=16,
    d_ff=128, vocab=512,
    supports_long_context=True,
)

register(FULL, REDUCED)
