"""Granite-3.0-2B [hf:ibm-granite/granite-3.0-2b-base] — dense, GQA kv=8."""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv=8, head_dim=64,
    d_ff=8192, vocab=49155,
    tie_embeddings=True, rope_theta=10000.0,
)

REDUCED = ModelConfig(
    name="granite-3-2b", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv=2, head_dim=8,
    d_ff=128, vocab=512,
    tie_embeddings=True, rope_theta=10000.0,
)

register(FULL, REDUCED)
