"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B] — dense, MHA (GQA kv=16), QKV bias."""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv=16, head_dim=64,
    d_ff=2816, vocab=151936,
    qkv_bias=True, tie_embeddings=True, rope_theta=1_000_000.0,
)

REDUCED = ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv=4, head_dim=16,
    d_ff=128, vocab=512,
    qkv_bias=True, tie_embeddings=True, rope_theta=1_000_000.0,
)

register(FULL, REDUCED)
