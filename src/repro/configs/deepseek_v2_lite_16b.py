"""DeepSeek-V2-Lite (16B) [arXiv:2405.04434] — MLA (kv_lora=512) + MoE
64 routed experts top-6 + 2 shared experts; first layer dense.
(The assignment line's "160 routed" is the V2-236B config; we follow the
primary "MoE 64e top-6" spec — see DESIGN.md §7.)"""
from .base import ModelConfig, MoEConfig, MLAConfig, register

FULL = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv=16, head_dim=192,
    d_ff=10944, vocab=102400,
    rope_theta=10000.0,
    mla=MLAConfig(kv_lora=512, nope_dim=128, rope_dim=64, v_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                  num_shared=2, d_ff_shared=2816, first_dense=1),
)

REDUCED = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv=4, head_dim=24,
    d_ff=160, vocab=512,
    rope_theta=10000.0,
    mla=MLAConfig(kv_lora=32, nope_dim=16, rope_dim=8, v_dim=16),
    # capacity E/k => no token drops in the reduced config (see qwen3-moe)
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64,
                  num_shared=2, d_ff_shared=128, first_dense=1,
                  capacity_factor=4.0),
)

register(FULL, REDUCED)
