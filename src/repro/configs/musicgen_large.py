"""MusicGen-Large [arXiv:2306.05284] — decoder-only over EnCodec tokens.
Backbone only: the EnCodec frontend is a stub; input_specs() provides
precomputed frame embeddings (input_mode='embeds')."""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv=32, head_dim=64,
    d_ff=8192, vocab=2048,
    input_mode="embeds", rope_theta=10000.0,
)

REDUCED = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv=4, head_dim=16,
    d_ff=128, vocab=128,
    input_mode="embeds", rope_theta=10000.0,
)

register(FULL, REDUCED)
