"""Zamba2-7B [arXiv:2411.15242] — Mamba2 backbone + weight-shared attention
block applied every `hybrid_period` layers with per-application LoRA.
Attention uses a sliding window in long-context mode => bounded decode state
=> runs the 500k shape."""
from .base import ModelConfig, SSMConfig, register

FULL = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv=32, head_dim=112,
    d_ff=14336, vocab=32000,
    ssm=SSMConfig(d_inner=7168, d_state=64, head_dim=64),
    hybrid_period=6, shared_lora_rank=128,
    long_context_window=4096, supports_long_context=True,
)

REDUCED = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=7, d_model=64, n_heads=4, n_kv=4, head_dim=16,
    d_ff=128, vocab=512,
    ssm=SSMConfig(d_inner=128, d_state=16, head_dim=32),
    hybrid_period=3, shared_lora_rank=8,
    long_context_window=64, supports_long_context=True,
)

register(FULL, REDUCED)
