"""Gemma-2B [arXiv:2403.08295] — GeGLU, head_dim=256, MQA (kv=1),
(1+g) RMSNorm, sqrt(d) embedding scaling, tied embeddings."""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv=1, head_dim=256,
    d_ff=16384, vocab=256000,
    activation="gelu", norm_offset=1.0, embed_scale=True,
    tie_embeddings=True, rope_theta=10000.0,
)

REDUCED = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv=1, head_dim=32,
    d_ff=256, vocab=512,
    activation="gelu", norm_offset=1.0, embed_scale=True,
    tie_embeddings=True, rope_theta=10000.0,
)

register(FULL, REDUCED)
