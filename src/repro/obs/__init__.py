"""repro.obs — dependency-free metrics + tracing spine.

One process-global :data:`REGISTRY` of labeled Counter/Gauge/Histogram
families, nested wall-clock :func:`span`\\ s with a ring-buffer trace log,
JSON + Prometheus exporters, and a ``snapshot()/diff`` API so tests and
benchmarks assert on deltas.  See docs/DESIGN.md §9 for the metric-name
table and label conventions.

Quickstart::

    from repro import obs
    obs.counter("requests_total", matrix_id=mid).inc()
    with obs.span("serve.tick"):
        ...
    obs.histogram("serve_execute_seconds", path="kernel").observe(dt)
    print(obs.to_prometheus())

``REPRO_METRICS=1`` in the environment installs an atexit hook that
prints the full Prometheus-text snapshot on process exit — the zero-code
way to see what a run did (used by the examples and the acceptance
check).  ``set_enabled(False)`` turns every mutation and span into a
near-free no-op (the <2% serving hot-path budget).
"""
from __future__ import annotations

import atexit
import os
import sys

from .metrics import (DEFAULT_BUCKETS, MAX_CARDINALITY, OVERFLOW_LABEL,
                      STATE, Counter, Family, Gauge, Histogram,
                      MetricsRegistry, REGISTRY, Snapshot, disabled,
                      enabled, log_buckets, merge_histogram_samples,
                      quantile_from_counts, set_enabled)
from .provenance import (MISMATCH_FIELDS, env_mismatches,
                         environment_provenance, git_sha)
from .tracing import (Span, clear_trace, set_trace_capacity, span, trace)


def counter(name: str, _help: str = "", **labels) -> Counter:
    """Counter child of the global registry for these label values."""
    return REGISTRY.counter(name, _help=_help, **labels)


def gauge(name: str, _help: str = "", **labels) -> Gauge:
    return REGISTRY.gauge(name, _help=_help, **labels)


def histogram(name: str, _help: str = "", _buckets=None,
              **labels) -> Histogram:
    return REGISTRY.histogram(name, _help=_help, _buckets=_buckets,
                              **labels)


def snapshot() -> Snapshot:
    return REGISTRY.snapshot()


def to_json() -> str:
    return REGISTRY.to_json()


def to_prometheus() -> str:
    return REGISTRY.to_prometheus()


def _truthy(v: str) -> bool:
    return v.strip().lower() not in ("", "0", "false", "no", "off")


def _dump_at_exit():
    sys.stdout.write(to_prometheus())
    sys.stdout.flush()


if _truthy(os.environ.get("REPRO_METRICS", "")):
    atexit.register(_dump_at_exit)
