"""Nested wall-clock spans with a ring-buffer trace log.

``span(name, **labels)`` is a context manager:

    with obs.span("tune.measure", plan=plan.key()):
        t = measure(op, x)

On exit it appends a record to a bounded ring buffer (``trace()`` reads
it) carrying the duration, the nesting depth, the enclosing span's name,
and whether the block raised — exception-safe: the record is written and
the per-thread stack restored on the error path too, and the exception
propagates untouched.

Spans honor the global enable flag: disabled spans skip the clock, the
stack, and the ring entirely (one attribute read), which is what keeps
the serving hot path inside the <2% overhead budget.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Dict, List, Optional

from .metrics import STATE

DEFAULT_TRACE_CAPACITY = 4096

_trace = collections.deque(maxlen=DEFAULT_TRACE_CAPACITY)
_tls = threading.local()


def _stack() -> List["Span"]:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


class Span:
    """One timed block.  Created via :func:`span`; re-entrant use of a
    single instance is not supported (make a new one per block)."""

    __slots__ = ("name", "labels", "t0", "start", "depth", "parent",
                 "duration_s", "_live")

    def __init__(self, name: str, labels: Dict[str, str]):
        self.name = name
        self.labels = labels
        self.duration_s = None
        self._live = False

    def __enter__(self) -> "Span":
        if not STATE.enabled:
            return self
        st = _stack()
        self.depth = len(st)
        self.parent = st[-1].name if st else None
        st.append(self)
        self.start = time.time()
        self.t0 = time.perf_counter()
        self._live = True
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if not self._live:
            return False
        self.duration_s = time.perf_counter() - self.t0
        self._live = False
        st = _stack()
        if st and st[-1] is self:
            st.pop()
        else:                         # unbalanced exit (enable flag moved
            while st and st[-1] is not self:   # mid-span): resync stack
                st.pop()
            if st:
                st.pop()
        _trace.append({
            "name": self.name, "labels": self.labels,
            "start": self.start, "duration_s": self.duration_s,
            "depth": self.depth, "parent": self.parent,
            "ok": exc_type is None,
            "error": (None if exc_type is None
                      else f"{exc_type.__name__}: {exc}"),
        })
        return False                  # never swallow the exception


def span(name: str, **labels) -> Span:
    """A new span context manager (see module docstring).  Label values
    are stringified into the trace record."""
    return Span(name, {k: str(v) for k, v in labels.items()})


def trace(name: Optional[str] = None) -> List[Dict]:
    """Snapshot of the ring buffer, oldest first; ``name`` filters."""
    recs = list(_trace)
    if name is not None:
        recs = [r for r in recs if r["name"] == name]
    return recs


def clear_trace():
    _trace.clear()


def set_trace_capacity(n: int):
    """Resize the ring buffer (keeps the newest records)."""
    global _trace
    _trace = collections.deque(_trace, maxlen=int(n))
