"""Dependency-free labeled metrics: Counter / Gauge / Histogram families.

The process-global :data:`REGISTRY` (re-exported as ``repro.obs.REGISTRY``)
holds metric *families* — a name plus a fixed set of label names — whose
children are addressed by label values:

    obs.counter("plan_cache_lookups_total", kind="plan", outcome="hit").inc()
    obs.histogram("serve_execute_seconds", matrix_id=m, path=p).observe(dt)
    obs.gauge("tuner_winner_roofline_fraction", path="kernel").set(0.31)

Design constraints (docs/DESIGN.md §9):

* stdlib only — the serving hot path must not grow a dependency;
* near-zero cost when disabled (``set_enabled(False)``): every mutation
  checks one attribute and returns — the <2% serving-overhead budget is
  asserted in tests/test_obs.py;
* histograms use **fixed log-spaced buckets** (``DEFAULT_BUCKETS``: four
  per decade, 1 µs .. 100 s) so p50/p95/p99 estimates are mergeable
  across label sets and across processes without storing samples;
* bounded label cardinality: past ``MAX_CARDINALITY`` children per
  family, new label sets collapse into one ``_overflow`` child instead
  of growing without bound (a counter records the drops);
* ``snapshot()`` / ``Snapshot.diff`` let tests and benchmarks assert on
  deltas instead of absolute values, so suites compose;
* exporters to structured JSON (``to_json``) and Prometheus text format
  (``to_prometheus``) — the scrape surface the serving-fleet router's
  heartbeats will read.
"""
from __future__ import annotations

import bisect
import json
import math
import threading
from typing import Dict, List, Optional, Tuple


class _State:
    """Process-global enable flag; one attribute read on every hot-path
    mutation (cheaper than a function call or an env probe)."""
    __slots__ = ("enabled",)

    def __init__(self):
        self.enabled = True


STATE = _State()


def set_enabled(flag: bool) -> bool:
    """Enable/disable every metric mutation and span; returns the previous
    state so callers can restore it (see :func:`disabled`)."""
    prev = STATE.enabled
    STATE.enabled = bool(flag)
    return prev


def enabled() -> bool:
    return STATE.enabled


class disabled:
    """``with obs.disabled(): ...`` — metrics off inside the block."""

    def __enter__(self):
        self._prev = set_enabled(False)
        return self

    def __exit__(self, *exc):
        set_enabled(self._prev)
        return False


def log_buckets(lo: float = 1e-6, hi: float = 100.0,
                per_decade: int = 4) -> Tuple[float, ...]:
    """Log-spaced histogram bucket upper bounds, ``per_decade`` per decade
    from ``lo`` to ``hi`` inclusive.  Fixed and shared (DEFAULT_BUCKETS)
    so histograms merge across label sets and processes."""
    n = int(round(math.log10(hi / lo) * per_decade))
    return tuple(lo * 10.0 ** (i / per_decade) for i in range(n + 1))


DEFAULT_BUCKETS = log_buckets()

# children per family before new label sets collapse into one overflow
# child — metric memory must stay bounded under per-request labels
MAX_CARDINALITY = 512
OVERFLOW_LABEL = "_overflow"


class Counter:
    """Monotonic counter.  ``inc`` honors the global enable flag;
    ``inc_always`` bypasses it (correctness probes like BUILD_COUNTS must
    count even when telemetry is off)."""

    kind = "counter"
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0):
        if STATE.enabled:
            self.value += v

    def inc_always(self, v: float = 1.0):
        self.value += v

    def set_always(self, v: float):
        self.value = float(v)

    def sample(self) -> Dict:
        return {"value": self.value}


class Gauge:
    """Last-write-wins scalar."""

    kind = "gauge"
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float):
        if STATE.enabled:
            self.value = float(v)

    def inc(self, v: float = 1.0):
        if STATE.enabled:
            self.value += v

    def add(self, v: float):
        self.inc(v)

    def dec(self, v: float = 1.0):
        self.inc(-v)

    def sample(self) -> Dict:
        return {"value": self.value}


class Histogram:
    """Fixed-bucket histogram with quantile estimates.

    ``bounds`` are upper bucket edges; observations above the last bound
    land in an implicit +Inf bucket.  Quantiles interpolate geometrically
    inside the winning bucket (the buckets are log-spaced), so the
    estimate error is bounded by one bucket ratio (~1.78x for the default
    four-per-decade spacing) — plenty for latency SLO gating."""

    kind = "histogram"
    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: Tuple[float, ...] = DEFAULT_BUCKETS):
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float):
        if not STATE.enabled:
            return
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1

    def quantile(self, q: float) -> float:
        return quantile_from_counts(self.bounds, self.counts, self.count, q)

    def sample(self) -> Dict:
        return {"count": self.count, "sum": self.sum,
                "counts": list(self.counts),
                "p50": self.quantile(0.50), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}


def quantile_from_counts(bounds, counts, total, q: float) -> float:
    """Quantile estimate from (bounds, per-bucket counts): geometric
    interpolation inside the winning bucket.  Shared by live histograms
    and merged/snapshotted samples (benchmarks/trajectory.py)."""
    if total <= 0:
        return 0.0
    target = q * total
    cum = 0
    for i, c in enumerate(counts):
        cum += c
        if cum >= target and c > 0:
            if i >= len(bounds):          # +Inf bucket: report last edge
                return bounds[-1]
            hi = bounds[i]
            lo = bounds[i - 1] if i > 0 else hi / 10.0
            frac = (target - (cum - c)) / c
            return lo * (hi / lo) ** frac
    return bounds[-1]


def merge_histogram_samples(samples: List[Dict],
                            bounds=DEFAULT_BUCKETS) -> Dict:
    """Fold histogram samples (same fixed buckets) into one: counts add,
    quantiles recomputed — how per-label latency series roll up into one
    service-level p50/p95/p99."""
    counts = [0] * (len(bounds) + 1)
    total, s = 0, 0.0
    for smp in samples:
        for i, c in enumerate(smp.get("counts", [])):
            if i < len(counts):
                counts[i] += c
        total += smp.get("count", 0)
        s += smp.get("sum", 0.0)
    return {"count": total, "sum": s, "counts": counts,
            "p50": quantile_from_counts(bounds, counts, total, 0.50),
            "p95": quantile_from_counts(bounds, counts, total, 0.95),
            "p99": quantile_from_counts(bounds, counts, total, 0.99)}


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Family:
    """One metric name with a fixed label-name tuple and one child metric
    per observed label-value combination."""

    __slots__ = ("name", "kind", "help", "labelnames", "children",
                 "_buckets", "_lock", "dropped")

    def __init__(self, name: str, kind: str, labelnames: Tuple[str, ...],
                 help: str = "", buckets=None):
        self.name = name
        self.kind = kind
        self.help = help
        self.labelnames = tuple(labelnames)
        self.children: Dict[Tuple[str, ...], object] = {}
        self._buckets = tuple(buckets) if buckets else DEFAULT_BUCKETS
        self._lock = threading.Lock()
        self.dropped = 0              # label sets collapsed into overflow

    def _make(self):
        if self.kind == "histogram":
            return Histogram(self._buckets)
        return _KINDS[self.kind]()

    def labels(self, **labels):
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.labelnames}, "
                f"got {tuple(sorted(labels))}")
        key = tuple(str(labels[k]) for k in self.labelnames)
        child = self.children.get(key)
        if child is None:
            with self._lock:
                child = self.children.get(key)
                if child is None:
                    if len(self.children) >= MAX_CARDINALITY:
                        self.dropped += 1
                        key = tuple(OVERFLOW_LABEL
                                    for _ in self.labelnames)
                        child = self.children.get(key)
                        if child is None:
                            child = self._make()
                            self.children[key] = child
                    else:
                        child = self._make()
                        self.children[key] = child
        return child


class Snapshot:
    """Immutable-by-convention point-in-time copy of every family.

    ``data`` maps family name -> {kind, labelnames, series} where series
    maps a JSON-encoded label-value tuple -> metric sample.  Built either
    from a live registry (``MetricsRegistry.snapshot``) or from exported
    JSON (``Snapshot.from_json`` — the round-trip tests ride this)."""

    def __init__(self, data: Dict):
        self.data = data

    @staticmethod
    def key_of(labelnames, labels) -> str:
        return json.dumps([str(labels[k]) for k in labelnames])

    def value(self, name: str, **labels) -> float:
        """Counter/gauge value for an exact label set (0.0 if absent)."""
        fam = self.data.get(name)
        if fam is None:
            return 0.0
        smp = fam["series"].get(self.key_of(fam["labelnames"], labels))
        return 0.0 if smp is None else smp.get("value", 0.0)

    def hist(self, name: str, **labels) -> Optional[Dict]:
        fam = self.data.get(name)
        if fam is None:
            return None
        return fam["series"].get(self.key_of(fam["labelnames"], labels))

    def find(self, name: str, **subset) -> List[Tuple[Dict, Dict]]:
        """Every (labels, sample) of a family whose labels contain
        ``subset`` — the lookup tests and trajectory folding use when the
        full label set is not known in advance."""
        fam = self.data.get(name)
        if fam is None:
            return []
        names = fam["labelnames"]
        out = []
        for key, smp in fam["series"].items():
            labels = dict(zip(names, json.loads(key)))
            if all(labels.get(k) == str(v) for k, v in subset.items()):
                out.append((labels, smp))
        return out

    def total(self, name: str, **subset) -> float:
        """Sum of counter/gauge values across label sets matching
        ``subset``."""
        return sum(smp.get("value", 0.0)
                   for _, smp in self.find(name, **subset))

    def merged_hist(self, name: str, **subset) -> Dict:
        """All matching histogram series folded into one sample."""
        fam = self.data.get(name, {})
        bounds = fam.get("bounds", DEFAULT_BUCKETS)
        return merge_histogram_samples(
            [smp for _, smp in self.find(name, **subset)], bounds=bounds)

    def diff(self, old: "Snapshot") -> "Snapshot":
        """Delta snapshot: counters and histogram counts/sums subtract
        (absent-in-old means zero), gauges keep the new value (a gauge is
        a level, not a flow).  Series that did not move are kept with
        zero deltas so lookups stay total."""
        out: Dict = {}
        for name, fam in self.data.items():
            ofam = old.data.get(name, {"series": {}})
            series = {}
            for key, smp in fam["series"].items():
                osmp = ofam["series"].get(key)
                series[key] = _diff_sample(fam["kind"], smp, osmp)
            nf = {k: v for k, v in fam.items() if k != "series"}
            nf["series"] = series
            out[name] = nf
        return Snapshot(out)

    def to_json(self) -> str:
        return json.dumps(self.data, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "Snapshot":
        return cls(json.loads(text))


def _diff_sample(kind: str, new: Dict, old: Optional[Dict]) -> Dict:
    if kind == "gauge" or old is None:
        return dict(new)
    if kind == "counter":
        return {"value": new["value"] - old["value"]}
    counts = [a - b for a, b in zip(new["counts"], old["counts"])]
    total = new["count"] - old["count"]
    return {"count": total, "sum": new["sum"] - old["sum"],
            "counts": counts,
            "p50": new["p50"], "p95": new["p95"], "p99": new["p99"]}


class MetricsRegistry:
    """Process-global family registry.  ``family`` is get-or-create and
    validates that a name is never reused with a different kind or label
    set; the ``counter``/``gauge``/``histogram`` conveniences return the
    child for the given label values directly."""

    def __init__(self):
        self._families: Dict[str, Family] = {}
        self._lock = threading.Lock()

    def family(self, name: str, kind: str, labelnames=(), help: str = "",
               buckets=None) -> Family:
        fam = self._families.get(name)
        if fam is None:
            with self._lock:
                fam = self._families.get(name)
                if fam is None:
                    fam = Family(name, kind, tuple(labelnames), help=help,
                                 buckets=buckets)
                    self._families[name] = fam
        if fam.kind != kind or fam.labelnames != tuple(labelnames):
            raise ValueError(
                f"metric {name!r} already registered as {fam.kind} with "
                f"labels {fam.labelnames}; requested {kind} with "
                f"{tuple(labelnames)}")
        return fam

    def counter(self, name: str, _help: str = "", **labels) -> Counter:
        return self.family(name, "counter", tuple(sorted(labels)),
                           help=_help).labels(**labels)

    def gauge(self, name: str, _help: str = "", **labels) -> Gauge:
        return self.family(name, "gauge", tuple(sorted(labels)),
                           help=_help).labels(**labels)

    def histogram(self, name: str, _help: str = "", _buckets=None,
                  **labels) -> Histogram:
        return self.family(name, "histogram", tuple(sorted(labels)),
                           help=_help, buckets=_buckets).labels(**labels)

    def families(self) -> Dict[str, Family]:
        return dict(self._families)

    def reset(self):
        """Drop every family (tests only — live handles into old families
        keep counting into detached objects)."""
        with self._lock:
            self._families = {}

    def snapshot(self) -> Snapshot:
        data: Dict = {}
        for name, fam in sorted(self._families.items()):
            series = {json.dumps(list(key)): child.sample()
                      for key, child in sorted(fam.children.items())}
            entry = {"kind": fam.kind, "labelnames": list(fam.labelnames),
                     "help": fam.help, "series": series}
            if fam.kind == "histogram":
                entry["bounds"] = list(fam._buckets)
            data[name] = entry
        return Snapshot(data)

    def to_json(self) -> str:
        """Structured JSON export (the snapshot's wire format)."""
        return self.snapshot().to_json()

    def to_prometheus(self) -> str:
        """Prometheus text exposition format: counters and gauges as one
        sample per label set, histograms as cumulative ``_bucket`` series
        plus ``_sum``/``_count``."""
        lines: List[str] = []
        for name, fam in sorted(self._families.items()):
            if fam.help:
                lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {fam.kind}")
            for key, child in sorted(fam.children.items()):
                base = _prom_labels(fam.labelnames, key)
                if fam.kind in ("counter", "gauge"):
                    lines.append(f"{name}{_brace(base)} "
                                 f"{_prom_num(child.value)}")
                    continue
                cum = 0
                for i, b in enumerate(child.bounds):
                    cum += child.counts[i]
                    le = base + [f'le="{_prom_num(b)}"']
                    lines.append(f"{name}_bucket{_brace(le)} {cum}")
                le = base + ['le="+Inf"']
                lines.append(f"{name}_bucket{_brace(le)} {child.count}")
                lines.append(f"{name}_sum{_brace(base)} "
                             f"{_prom_num(child.sum)}")
                lines.append(f"{name}_count{_brace(base)} {child.count}")
        return "\n".join(lines) + ("\n" if lines else "")


def _prom_escape(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def _prom_labels(names, values) -> List[str]:
    return [f'{n}="{_prom_escape(v)}"' for n, v in zip(names, values)]


def _brace(parts: List[str]) -> str:
    return "{" + ",".join(parts) + "}" if parts else ""


def _prom_num(v: float) -> str:
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


REGISTRY = MetricsRegistry()
