"""Environment provenance: which toolchain/devices produced a cached
tuning decision.

``environment_provenance()`` returns a small JSON-safe dict (jax version,
backend, device kind/count, git SHA, python version) that
:class:`~repro.core.tuner.PlanCache` stores next to every entry's
``predicted_ms``/``measured_ms`` — a cached winner measured on different
hardware is identifiable, and loading one increments the
``plan_cache_env_mismatch_total{field=...}`` warning counter (the git SHA
is recorded for identification but not treated as a mismatch: winners
stay valid across commits, not across device kinds).

jax is imported lazily and failure-tolerated so the obs package itself
stays dependency-free.
"""
from __future__ import annotations

import functools
import os
import platform
import subprocess
from typing import Dict, Optional

# env fields whose disagreement means the measurement environment changed
# (the git SHA deliberately excluded — see module docstring)
MISMATCH_FIELDS = ("jax", "backend", "device_kind", "device_count")


def _repo_root() -> Optional[str]:
    d = os.path.dirname(os.path.abspath(__file__))
    for _ in range(8):
        if os.path.isdir(os.path.join(d, ".git")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            return None
        d = parent
    return None


@functools.lru_cache(maxsize=1)
def git_sha() -> str:
    """Current commit SHA: ``REPRO_GIT_SHA`` env override (CI images
    without a .git dir), else ``git rev-parse HEAD``, else 'unknown'."""
    sha = os.environ.get("REPRO_GIT_SHA")
    if sha:
        return sha
    root = _repo_root()
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=root or os.getcwd(),
            capture_output=True, text=True, timeout=10)
        if out.returncode == 0 and out.stdout.strip():
            return out.stdout.strip()
    except Exception:
        pass
    return "unknown"


@functools.lru_cache(maxsize=1)
def environment_provenance() -> Dict[str, object]:
    info: Dict[str, object] = {
        "python": platform.python_version(),
        "git_sha": git_sha(),
    }
    try:
        import jax
        info["jax"] = jax.__version__
        devs = jax.devices()
        info["backend"] = devs[0].platform
        info["device_kind"] = str(getattr(devs[0], "device_kind",
                                          devs[0].platform))
        info["device_count"] = len(devs)
    except Exception:                 # jax missing or backend init failed
        info.update({"jax": None, "backend": None,
                     "device_kind": None, "device_count": None})
    return info


def env_mismatches(recorded: Dict[str, object]) -> Dict[str, object]:
    """Fields of a recorded provenance dict that disagree with the
    current environment: ``{field: (recorded, current)}``."""
    cur = environment_provenance()
    out = {}
    for k in MISMATCH_FIELDS:
        if k in recorded and str(recorded[k]) != str(cur.get(k)):
            out[k] = (recorded[k], cur.get(k))
    return out
