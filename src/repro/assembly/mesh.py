"""Structured meshes and deterministic element stiffness synthesis.

The paper's CSRC format exists to hold *global finite-element matrices*;
this module supplies the FEM-shaped inputs the assembly subsystem
(docs/DESIGN.md §5) consumes: small structured 2D/3D meshes with
tri/quad/tet connectivity and per-element dense stiffness matrices.

Element stiffness entries are **quantized to multiples of 1/64** (dyadic
rationals).  Dyadic values of moderate magnitude are exact in float32 and
their sums are exact *regardless of accumulation order*, so the colored,
private-buffer, and serial assembly strategies (assembly/scatter.py) are
required to agree **bit-for-bit** — the strongest possible race detector:
any write conflict or dropped contribution changes the result exactly,
never "within tolerance".

Generators:

  grid_tri   2D triangle mesh (each grid cell split along its diagonal)
  grid_quad  2D bilinear quad mesh
  grid_tet   3D tetrahedral mesh (Kuhn triangulation: 6 tets per cube)

Stiffness synthesis:

  poisson_stiffness    exact P1/Q1 Laplacian element matrices (+ optional
                       lumped-mass shift so the global matrix is SPD and
                       CG converges — the assemble→tune→solve demo)
  synthetic_stiffness  seeded random symmetric element blocks, optionally
                       vector-valued (ndof_per_node=2/3 — the elasticity
                       shape: dofs interleave per node)
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

QUANTUM = 64                    # stiffness entries are multiples of 1/QUANTUM


@dataclasses.dataclass(frozen=True)
class Mesh:
    """A conforming mesh: node coordinates + element connectivity."""

    name: str
    dim: int
    coords: np.ndarray          # (num_nodes, dim) float64
    conn: np.ndarray            # (ne, nen) int32 node ids per element

    @property
    def num_nodes(self) -> int:
        return int(self.coords.shape[0])

    @property
    def ne(self) -> int:
        return int(self.conn.shape[0])

    @property
    def nen(self) -> int:
        return int(self.conn.shape[1])


def _grid_nodes_2d(nx: int, ny: int) -> np.ndarray:
    xs, ys = np.meshgrid(np.arange(nx + 1), np.arange(ny + 1))
    return np.stack([xs.reshape(-1), ys.reshape(-1)], axis=1).astype(
        np.float64)


def _cell_corners_2d(nx: int, ny: int):
    """Node ids of each cell's (v00, v10, v11, v01) corners."""
    x, y = np.meshgrid(np.arange(nx), np.arange(ny))
    x, y = x.reshape(-1), y.reshape(-1)
    stride = nx + 1
    v00 = y * stride + x
    return v00, v00 + 1, v00 + stride + 1, v00 + stride


def grid_quad(nx: int, ny: int = 0) -> Mesh:
    """Bilinear quads on an nx×ny unit grid."""
    ny = nx if ny == 0 else ny
    v00, v10, v11, v01 = _cell_corners_2d(nx, ny)
    conn = np.stack([v00, v10, v11, v01], axis=1).astype(np.int32)
    return Mesh(name=f"quad{nx}x{ny}", dim=2,
                coords=_grid_nodes_2d(nx, ny), conn=conn)


def grid_tri(nx: int, ny: int = 0) -> Mesh:
    """P1 triangles: each unit cell split along the (v00, v11) diagonal."""
    ny = nx if ny == 0 else ny
    v00, v10, v11, v01 = _cell_corners_2d(nx, ny)
    lower = np.stack([v00, v10, v11], axis=1)
    upper = np.stack([v00, v11, v01], axis=1)
    conn = np.concatenate([lower, upper]).astype(np.int32)
    return Mesh(name=f"tri{nx}x{ny}", dim=2,
                coords=_grid_nodes_2d(nx, ny), conn=conn)


def grid_tet(nx: int, ny: int = 0, nz: int = 0) -> Mesh:
    """P1 tetrahedra: Kuhn triangulation, 6 tets per unit cube (one per
    monotone lattice path from corner 000 to corner 111)."""
    ny = nx if ny == 0 else ny
    nz = nx if nz == 0 else nz
    xs, ys, zs = np.meshgrid(np.arange(nx + 1), np.arange(ny + 1),
                             np.arange(nz + 1), indexing="ij")
    coords = np.stack([xs.reshape(-1), ys.reshape(-1), zs.reshape(-1)],
                      axis=1).astype(np.float64)

    def node(ix, iy, iz):
        return (ix * (ny + 1) + iy) * (nz + 1) + iz

    cx, cy, cz = np.meshgrid(np.arange(nx), np.arange(ny), np.arange(nz),
                             indexing="ij")
    cx, cy, cz = cx.reshape(-1), cy.reshape(-1), cz.reshape(-1)
    origin = node(cx, cy, cz)
    steps = {0: node(cx + 1, cy, cz) - origin,
             1: node(cx, cy + 1, cz) - origin,
             2: node(cx, cy, cz + 1) - origin}
    tets = []
    for perm in itertools.permutations((0, 1, 2)):
        v0 = origin
        v1 = v0 + steps[perm[0]]
        v2 = v1 + steps[perm[1]]
        v3 = v2 + steps[perm[2]]
        # odd permutations yield negatively-oriented tets; swap the last
        # two vertices so every element volume is positive
        parity = sum(1 for a in range(3) for b in range(a + 1, 3)
                     if perm[a] > perm[b]) % 2
        order = (v0, v1, v3, v2) if parity else (v0, v1, v2, v3)
        tets.append(np.stack(order, axis=1))
    conn = np.concatenate(tets).astype(np.int32)
    return Mesh(name=f"tet{nx}x{ny}x{nz}", dim=3, coords=coords, conn=conn)


# The benchmark/CI mesh suite: one entry per generator, parameterized by a
# common size knob (tet scales down — 6 elements per cube).  The assembly
# benchmark iterates this table, so a new generator added here is
# benchmarked and oracle-checked with no benchmark edits.
MESH_GENERATORS = (
    ("tri", lambda s: grid_tri(s)),
    ("quad", lambda s: grid_quad(s)),
    ("tet", lambda s: grid_tet(max(2, s // 3))),
)


# ---------------------------------------------------------------------------
# Element stiffness synthesis
# ---------------------------------------------------------------------------

def quantize(ke: np.ndarray, quantum: int = QUANTUM) -> np.ndarray:
    """Round to multiples of 1/quantum: every entry (and every partial sum
    of the assembly scatter) is exact in float32, making strategy-vs-oracle
    comparisons bit-for-bit instead of tolerance-based."""
    return (np.round(np.asarray(ke, np.float64) * quantum) / quantum).astype(
        np.float32)


def element_volumes(mesh: Mesh) -> np.ndarray:
    """Per-element area (2D) / volume (3D), positive for the generators
    above (a mesh-sanity invariant the tests assert)."""
    pts = mesh.coords[mesh.conn]                 # (ne, nen, dim)
    if mesh.nen == 3:                            # triangle
        e1 = pts[:, 1] - pts[:, 0]
        e2 = pts[:, 2] - pts[:, 0]
        return 0.5 * (e1[:, 0] * e2[:, 1] - e1[:, 1] * e2[:, 0])
    if mesh.nen == 4 and mesh.dim == 2:          # unit quad cells
        return np.ones(mesh.ne)
    if mesh.nen == 4 and mesh.dim == 3:          # tetrahedron
        e = pts[:, 1:] - pts[:, :1]              # (ne, 3, 3)
        return np.linalg.det(e) / 6.0
    raise ValueError(f"unsupported element ({mesh.nen} nodes, "
                     f"dim {mesh.dim})")


def _simplex_stiffness(mesh: Mesh) -> np.ndarray:
    """P1 stiffness on simplices: ke = V · (∇φ_a · ∇φ_b).  Gradients come
    from inverting the edge matrix, vectorized over elements."""
    pts = mesh.coords[mesh.conn]                 # (ne, nen, dim)
    d = mesh.dim
    edges = pts[:, 1:] - pts[:, :1]              # (ne, d, d)
    inv = np.linalg.inv(edges)                   # rows: dual basis
    grads = np.concatenate([-inv.sum(axis=2, keepdims=True).transpose(
        0, 2, 1), inv.transpose(0, 2, 1)], axis=1)       # (ne, nen, d)
    vol = np.abs(element_volumes(mesh))[:, None, None]
    return vol * np.einsum("ead,ebd->eab", grads, grads)


# Q1 Laplacian on the unit square, node order (v00, v10, v11, v01): the
# standard analytic element matrix (1/6)·[[4,-1,-2,-1],...].
_Q1_KE = np.asarray([[4, -1, -2, -1],
                     [-1, 4, -1, -2],
                     [-2, -1, 4, -1],
                     [-1, -2, -1, 4]], np.float64) / 6.0


def poisson_stiffness(mesh: Mesh, mass: float = 0.0,
                      quantum: int = QUANTUM) -> np.ndarray:
    """Laplacian element matrices (ne, nen, nen), float32 dyadic.

    ``mass`` adds a lumped-mass shift ``mass·V/nen`` to the diagonal —
    the assembled matrix becomes SPD (the pure Neumann Laplacian has the
    constant null vector), which is what the assemble→tune→solve CG demo
    needs.
    """
    if mesh.nen == 4 and mesh.dim == 2:
        ke = np.broadcast_to(_Q1_KE, (mesh.ne, 4, 4)).copy()
    else:
        ke = _simplex_stiffness(mesh)
    if mass:
        vol = np.abs(element_volumes(mesh))
        lump = mass * vol[:, None] / mesh.nen
        idx = np.arange(mesh.nen)
        ke[:, idx, idx] += lump
    return quantize(ke, quantum)


def synthetic_stiffness(mesh: Mesh, ndof_per_node: int = 1, seed: int = 0,
                        quantum: int = QUANTUM) -> np.ndarray:
    """Deterministic seeded symmetric element blocks (ne, edof, edof) with
    edof = nen·ndof_per_node.  ``ndof_per_node > 1`` gives the elasticity
    shape: vector-valued dofs interleaved per node (see
    ``conflict.element_dofs``).  Diagonally shifted so the assembled global
    matrix is positive definite."""
    rng = np.random.default_rng(seed)
    edof = mesh.nen * ndof_per_node
    B = rng.standard_normal((mesh.ne, edof, edof))
    ke = np.einsum("eab,ecb->eac", B, B) / edof
    idx = np.arange(edof)
    ke[:, idx, idx] += 2.0 * edof
    return quantize(ke, quantum)
