"""The element conflict graph and its coloring (docs/DESIGN.md §5).

FEM assembly is the same scatter race the paper's SpMV scatter term has
(Chejanovsky et al., arXiv:2012.00585): every element adds a dense
``edof × edof`` block into the global matrix, and two elements sharing a
node write the same diagonal entry (and, sharing two nodes, the same
off-diagonal slots).  So the conflict graph is simply *elements sharing a
DOF* — one level, no distance-2 closure needed: sharing any node already
collides on that node's diagonal, and every off-diagonal collision
requires sharing both endpoints.

Coloring reuses the exact ordering + RACE-style balancing pipeline of
``core/coloring.py`` (:func:`~repro.core.coloring.color_graph`): within a
color no two elements share a DOF, so the per-color scatter-add is a
permutation write — conflict-free on a machine without atomics, exactly
how the colorful SpMV path executes (§3.2).
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.coloring import Coloring, color_graph


def element_dofs(conn: np.ndarray, ndof_per_node: int = 1) -> np.ndarray:
    """(ne, nen·d) global DOF ids per element; node v owns dofs
    [v·d, (v+1)·d) — the interleaved elasticity layout."""
    conn = np.asarray(conn)
    if ndof_per_node == 1:
        return conn.astype(np.int32)
    d = ndof_per_node
    return (conn[:, :, None].astype(np.int64) * d
            + np.arange(d)[None, None, :]).reshape(conn.shape[0], -1).astype(
                np.int32)


def element_adjacency(conn: np.ndarray) -> List[np.ndarray]:
    """Adjacency lists of the element conflict graph: e ~ f when the
    elements share at least one node.  (DOF interleaving is per node, so
    sharing a node and sharing a DOF are the same relation for any
    ``ndof_per_node``.)"""
    conn = np.asarray(conn)
    ne, _ = conn.shape
    num_nodes = int(conn.max()) + 1 if conn.size else 0
    node_els: List[List[int]] = [[] for _ in range(num_nodes)]
    for e in range(ne):
        for v in conn[e]:
            node_els[int(v)].append(e)
    adj: List[List[int]] = [[] for _ in range(ne)]
    for els in node_els:
        for a in els:
            for b in els:
                if a != b:
                    adj[a].append(b)
    return [np.unique(np.asarray(a, dtype=np.int64)) for a in adj]


def color_elements(conn: np.ndarray, order: str = "degree",
                   balance: bool = True,
                   provider: str = "greedy") -> Coloring:
    """Balanced coloring of the element conflict graph — same machinery
    as the row colorer (greedy first-fit or the RACE recursive
    level-group scheme), different graph.  Tet meshes are where the
    provider choice bites: ~24 elements share one node, so any classic
    coloring needs ≥ 24 colors, while RACE's level groups (BFS wavefronts
    of the mesh) cut the palette to a handful of sweeps."""
    return color_graph(element_adjacency(conn), include_indirect=False,
                       order=order, balance=balance, provider=provider)


def verify_element_coloring(conn: np.ndarray, col: Coloring) -> bool:
    """Chunk-aware invariant: no two elements of one color in *different*
    serial chunks share a node (hence no two share any scatter target,
    diagonal or off-diagonal).  Greedy colorings have singleton chunks —
    the classic per-element disjointness; RACE colorings may share nodes
    inside one level-group chunk, which the order-free ``.at[].add``
    scatter accumulates exactly."""
    conn = np.asarray(conn)
    grp = col.group_of_row
    for c in range(col.num_colors):
        owner: dict = {}
        for e in col.rows(c).tolist():
            g = int(grp[e]) if grp is not None else e
            for v in conn[e].tolist():
                og = owner.get(v)
                if og is not None and og != g:
                    return False
                owner[v] = g
    return True
