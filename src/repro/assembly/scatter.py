"""Conflict-free global-matrix assembly: the paper's accumulation
strategies applied to the FEM scatter-add (docs/DESIGN.md §5).

Assembling ``A = Σ_e P_e^T k_e P_e`` is a scatter-add over CSRC slots:
contribution (e, a, b) lands on the diagonal (i == j), on a lower slot
``al[p]`` (i > j) or on the aligned upper slot ``au[p]`` (i < j).  All
three destinations flatten into one **unified value vector**
``[ad | al | au]`` of length n + 2k, so assembly is a single scatter into
that vector and the two race-avoidance families of the paper map exactly:

  colored   per-color batched scatter (elements of one color share no
            DOF ⇒ within a color every target is written once ⇒ a
            permutation write, like the colorful SpMV path §3.2).
            Executed by the fused colored-batch kernels of
            ``repro.kernels.assembly_scatter`` (stream/onehot variants,
            one launch total); the legacy one-XLA-scatter-per-color
            discipline survives as ``variant='percolor'`` — the
            baseline the kernels are benchmarked against
  sorted    contributions pre-sorted by destination slot at
            schedule-build time, so assembly is ONE color-free
            monotone segment-sum (the atomics-style GPU assembly
            format of arXiv:2012.00585, docs/DESIGN.md §10)
  private   per-buffer full-length partials reduced at the end (the
            local-buffers / all-in-one accumulation family §3.1)
  serial    numpy ``np.add.at`` in element order — the ground-truth
            oracle the strategies must reproduce

With the dyadic-quantized stiffness synthesis of ``assembly/mesh.py``
float32 accumulation is exact in any order, so the strategies are
required to agree with the oracle **bit-for-bit** (tests and the CI
assembly smoke assert equality, not closeness).

All structure-dependent precompute — slot layout, contribution targets,
element coloring, buffer grouping — lives in the npz-serializable
:class:`AssemblySchedule`, stored in the tuner's PlanCache next to the
SpMV schedules and keyed by a **connectivity digest**: FEM time stepping
re-assembles with unchanged connectivity and must reuse every artifact
(the ``BUILD_COUNTS['assembly_schedule']`` probe asserts zero rebuilds).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Dict, Optional, Tuple, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import csrc
from repro.core.coloring import Coloring
# the shared build probe (re-exported as schedule.BUILD_COUNTS): assembly
# builds count into the same Counter the SpMV schedule layer uses
from repro.core.paths import BUILD_COUNTS
from repro import obs
from repro.kernels import assembly_scatter as akern
from repro.kernels.assembly_scatter import COLORED_VARIANTS  # noqa: F401
from .conflict import color_elements, element_dofs
from .mesh import Mesh

# version 3: the schedule carries the kernel slot packs — per-color
# (slots, targets) streams and the destination-sorted permutation — with
# overflow-gated int16 index dtypes.  Version-2 files load as misses and
# are rebuilt transparently (version 2 added the coloring provider).
ASSEMBLY_VERSION = 3

STRATEGIES = ("colored", "sorted", "private", "serial")

# the (strategy, variant) pool tune_assembly prices and measures; variant
# labels the executor ('percolor' = the legacy one-scatter-per-color
# XLA baseline, 'vmap'/'numpy' are the single executors of their strategy)
ASSEMBLY_CANDIDATES = (
    ("colored", "stream"), ("colored", "onehot"), ("colored", "percolor"),
    ("sorted", "stream"), ("private", "vmap"))

_DEFAULT_VARIANT = {"colored": "stream", "sorted": "stream",
                    "private": "vmap", "serial": "numpy"}

# int16 index streams iff every representable value (including the
# sentinel one past the real range) fits — same overflow gate as the
# SpMV window streams (core/blockell.pack)
_INT16_MAX = np.iinfo(np.int16).max


def assembly_key(digest: str, num_buffers: int,
                 coloring: str = "greedy") -> str:
    """Cache key of one assembly schedule.  Greedy keys are byte-identical
    to pre-provider caches; other providers append their name."""
    suffix = "" if coloring == "greedy" else f".{coloring}"
    return f"asm-{digest}.b{num_buffers}{suffix}"


@dataclasses.dataclass(frozen=True)
class AssemblySchedule:
    """Every structure-dependent precomputation one connectivity needs to
    assemble CSRC matrices, for any number of value refreshes."""

    structure_digest: str       # connectivity digest (see structure_digest)
    n: int                      # global DOFs
    k: int                      # strictly-lower CSRC slots
    ne: int                     # elements
    edof: int                   # DOFs per element
    ndof_per_node: int
    num_buffers: int            # private-buffer strategy width
    ia: np.ndarray              # (n+1,) CSRC lower-triangle row pointers
    ja: np.ndarray              # (k,)
    # contribution (e, a, b) at flat index e·edof² + a·edof + b scatters to
    # targets[...] in the unified [ad | al | au] vector of length n + 2k
    targets: np.ndarray         # (ne·edof²,) int32
    coloring: Coloring          # element coloring (conflict.color_elements)
    buffer_elements: np.ndarray  # (num_buffers, epb) int32, -1 = padding
    # --- kernel slot packs (version 3) -------------------------------
    # per-color contribution streams, padded to a rectangular (C, Lmax)
    # table: slots index the flat ke (sentinel = ne·edof², gathers an
    # appended zero), targets index the unified vector (sentinel = size,
    # the segment-sum drop slot).  int16 when the overflow gate allows.
    color_slots: np.ndarray      # (C, Lmax) int16|int32
    color_targets: np.ndarray    # (C, Lmax) int16|int32
    # destination-sorted permutation of all contributions (sorted-slot
    # strategy): perm gathers ke.flat, sorted_targets is monotone
    sorted_perm: np.ndarray      # (ne·edof²,) int16|int32
    sorted_targets: np.ndarray   # (ne·edof²,) int16|int32

    @property
    def size(self) -> int:
        """Length of the unified value vector."""
        return self.n + 2 * self.k

    @property
    def index_dtypes(self) -> Dict[str, str]:
        """Gated dtypes of the kernel index streams (bench provenance)."""
        return {"slots": str(self.color_slots.dtype),
                "targets": str(self.color_targets.dtype)}

    def key(self) -> str:
        return assembly_key(self.structure_digest, self.num_buffers,
                            self.coloring.provider)

    # ------------------------------------------------------------------
    # Serialization (npz arrays + JSON meta, SpmvSchedule conventions)
    # ------------------------------------------------------------------

    def save_npz(self, path: str):
        meta = {
            "version": ASSEMBLY_VERSION,
            "structure_digest": self.structure_digest,
            "n": self.n, "k": self.k, "ne": self.ne, "edof": self.edof,
            "ndof_per_node": self.ndof_per_node,
            "num_buffers": self.num_buffers,
            "num_colors": int(self.coloring.num_colors),
            "coloring_provider": self.coloring.provider,
        }
        arrays = dict(
            ia=np.asarray(self.ia), ja=np.asarray(self.ja),
            targets=np.asarray(self.targets),
            color_of_row=np.asarray(self.coloring.color_of_row),
            rows_by_color=np.asarray(self.coloring.rows_by_color),
            color_ptr=np.asarray(self.coloring.color_ptr),
            buffer_elements=np.asarray(self.buffer_elements),
            color_slots=np.asarray(self.color_slots),
            color_targets=np.asarray(self.color_targets),
            sorted_perm=np.asarray(self.sorted_perm),
            sorted_targets=np.asarray(self.sorted_targets),
        )
        # RACE level-group metadata survives the round-trip so reloaded
        # schedules keep the chunk-aware invariant verifiable
        if self.coloring.level_of_row is not None:
            arrays["color_level_of_row"] = np.asarray(
                self.coloring.level_of_row)
        if self.coloring.group_of_row is not None:
            arrays["color_group_of_row"] = np.asarray(
                self.coloring.group_of_row)
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        tmp = path + ".tmp.npz"
        with open(tmp, "wb") as f:
            np.savez_compressed(f, __meta__=np.frombuffer(
                json.dumps(meta, sort_keys=True).encode(), dtype=np.uint8),
                **arrays)
        os.replace(tmp, path)

    @classmethod
    def load_npz(cls, path: str) -> "AssemblySchedule":
        with np.load(path) as z:
            meta = json.loads(bytes(z["__meta__"]).decode())
            if meta.get("version") != ASSEMBLY_VERSION:
                raise ValueError(
                    f"assembly schedule {path}: version "
                    f"{meta.get('version')!r} != {ASSEMBLY_VERSION}")
            coloring = Coloring(
                color_of_row=z["color_of_row"],
                num_colors=int(meta["num_colors"]),
                rows_by_color=z["rows_by_color"],
                color_ptr=z["color_ptr"],
                provider=meta.get("coloring_provider", "greedy"),
                level_of_row=(z["color_level_of_row"]
                              if "color_level_of_row" in z.files else None),
                group_of_row=(z["color_group_of_row"]
                              if "color_group_of_row" in z.files else None))
            return cls(structure_digest=meta["structure_digest"],
                       n=meta["n"], k=meta["k"], ne=meta["ne"],
                       edof=meta["edof"],
                       ndof_per_node=meta["ndof_per_node"],
                       num_buffers=meta["num_buffers"],
                       ia=z["ia"], ja=z["ja"], targets=z["targets"],
                       coloring=coloring,
                       buffer_elements=z["buffer_elements"],
                       color_slots=z["color_slots"],
                       color_targets=z["color_targets"],
                       sorted_perm=z["sorted_perm"],
                       sorted_targets=z["sorted_targets"])


def structure_digest(conn: np.ndarray, ndof_per_node: int = 1,
                     num_nodes: Optional[int] = None) -> str:
    """Digest of the element connectivity (the assembly-side analog of
    ``schedule.structure_digest``): unchanged connectivity ⇒ identical
    slot layout, targets, coloring, and buffer grouping."""
    conn = np.ascontiguousarray(np.asarray(conn, np.int64))
    num_nodes = int(conn.max()) + 1 if num_nodes is None else num_nodes
    h = hashlib.sha1()
    h.update(np.asarray([conn.shape[0], conn.shape[1], num_nodes,
                         ndof_per_node], np.int64).tobytes())
    h.update(conn.tobytes())
    return h.hexdigest()[:16]


def _index_dtype(max_value: int):
    """Narrowest stream dtype that holds every value up to ``max_value``
    (the sentinel, one past the real range) — the SpMV int16 overflow
    gate applied to assembly index streams."""
    return np.int16 if max_value <= _INT16_MAX else np.int32


def _pack_colored(targets: np.ndarray, coloring: Coloring, edof2: int,
                  size: int) -> Tuple[np.ndarray, np.ndarray]:
    """The colored-batch kernel's (C, Lmax) slot/target streams.

    Row c lists color c's contribution indices (element-major) and their
    destinations, lane-aligned to a multiple of 128 and padded with the
    sentinels the kernels drop (slot = G reads the appended zero, target
    = size lands in the drop segment)."""
    num_contribs = int(targets.size)
    counts = [len(coloring.rows(c)) * edof2
              for c in range(coloring.num_colors)]
    lmax = max(128, -(-max(counts + [1]) // 128) * 128)
    slot_dt = _index_dtype(num_contribs)
    tgt_dt = _index_dtype(size)
    color_slots = np.full((coloring.num_colors, lmax), num_contribs,
                          dtype=slot_dt)
    color_targets = np.full((coloring.num_colors, lmax), size,
                            dtype=tgt_dt)
    lane = np.arange(edof2, dtype=np.int64)
    for c in range(coloring.num_colors):
        els = np.asarray(coloring.rows(c), np.int64)
        if els.size == 0:
            continue
        sl = (els[:, None] * edof2 + lane).reshape(-1)
        color_slots[c, :sl.size] = sl.astype(slot_dt)
        color_targets[c, :sl.size] = targets[sl].astype(tgt_dt)
    return color_slots, color_targets


def _pack_sorted(targets: np.ndarray,
                 size: int) -> Tuple[np.ndarray, np.ndarray]:
    """The sorted-slot strategy's destination order: a stable argsort of
    the targets (build-time work) so the value refresh is one monotone
    segment-sum with no coloring at all."""
    num_contribs = int(targets.size)
    perm = np.argsort(targets, kind="stable")
    sorted_perm = perm.astype(_index_dtype(num_contribs))
    sorted_targets = targets[perm].astype(_index_dtype(size))
    return sorted_perm, sorted_targets


def build_assembly_schedule(mesh_or_conn: Union[Mesh, np.ndarray],
                            ndof_per_node: int = 1,
                            num_buffers: int = 8,
                            num_nodes: Optional[int] = None,
                            coloring: Optional[Coloring] = None,
                            coloring_provider: str = "greedy"
                            ) -> AssemblySchedule:
    """Build the full assembly artifact for one connectivity.

    The slot layout (ia/ja) is the union of every element's dense block,
    lower triangle only — structurally symmetric by construction, so the
    assembled matrix needs no :func:`~repro.core.csrc.symmetrize_pattern`
    pass.  Contribution targets are resolved once via searchsorted on the
    sorted lower-slot keys; the element coloring and the private-buffer
    grouping ride along.
    """
    if isinstance(mesh_or_conn, Mesh):
        conn = mesh_or_conn.conn
        num_nodes = mesh_or_conn.num_nodes
    else:
        conn = np.asarray(mesh_or_conn)
        num_nodes = (int(conn.max()) + 1 if num_nodes is None
                     else num_nodes)
    BUILD_COUNTS.inc("assembly_schedule")
    d = ndof_per_node
    n = num_nodes * d
    with obs.span("assembly.build_schedule", ndof_per_node=d):
        ed = element_dofs(conn, d)                 # (ne, edof)
        ne, edof = ed.shape

        with obs.span("assembly.slot_pack", ne=ne, edof=edof):
            ii = np.broadcast_to(ed[:, :, None],
                                 (ne, edof, edof)).reshape(-1)
            jj = np.broadcast_to(ed[:, None, :],
                                 (ne, edof, edof)).reshape(-1)
            ii = ii.astype(np.int64)
            jj = jj.astype(np.int64)

            low = ii > jj
            keys = np.unique(ii[low] * n + jj[low])  # sorted lower slots
            k = int(keys.shape[0])
            rows = (keys // n).astype(np.int64)
            ja = (keys % n).astype(np.int32)
            ia = np.zeros(n + 1, dtype=np.int32)
            np.add.at(ia, rows + 1, 1)
            ia = np.cumsum(ia, dtype=np.int32)

            targets = np.empty(ne * edof * edof, dtype=np.int32)
            diag = ii == jj
            targets[diag] = ii[diag]
            targets[low] = n + np.searchsorted(keys, ii[low] * n + jj[low])
            up = ii < jj
            targets[up] = n + k + np.searchsorted(keys,
                                                  jj[up] * n + ii[up])

        if coloring is None:
            BUILD_COUNTS.inc("element_coloring")
            with obs.span("assembly.element_coloring",
                          provider=coloring_provider):
                coloring = color_elements(conn, provider=coloring_provider)

        size = n + 2 * k
        BUILD_COUNTS.inc("assembly_color_pack")
        with obs.span("assembly.color_pack",
                      num_colors=int(coloring.num_colors)):
            color_slots, color_targets = _pack_colored(
                targets, coloring, edof * edof, size)
        BUILD_COUNTS.inc("assembly_sorted_pack")
        with obs.span("assembly.sorted_pack", contributions=targets.size):
            sorted_perm, sorted_targets = _pack_sorted(targets, size)

    # private-buffer grouping: contiguous element chunks (locality), padded
    # to a rectangular (B, epb) table with -1 sentinels
    B = max(1, min(num_buffers, ne))
    epb = -(-ne // B)
    buffer_elements = np.full((B, epb), -1, dtype=np.int32)
    flat = buffer_elements.reshape(-1)
    flat[:ne] = np.arange(ne, dtype=np.int32)

    return AssemblySchedule(
        structure_digest=structure_digest(conn, d, num_nodes),
        n=n, k=k, ne=ne, edof=edof, ndof_per_node=d, num_buffers=B,
        ia=ia, ja=ja, targets=targets, coloring=coloring,
        buffer_elements=buffer_elements,
        color_slots=color_slots, color_targets=color_targets,
        sorted_perm=sorted_perm, sorted_targets=sorted_targets)


def assembly_schedule_for(mesh_or_conn, ndof_per_node: int = 1,
                          num_buffers: int = 8, cache=None,
                          num_nodes: Optional[int] = None,
                          coloring_provider: str = "greedy"
                          ) -> AssemblySchedule:
    """The schedule to assemble this connectivity with — cache hit wins.

    ``cache`` is a :class:`~repro.core.tuner.PlanCache`; a hit (keyed by
    the connectivity digest and the element-coloring provider) performs
    zero structural work, which is the FEM time-stepping fast path:
    re-assembly with unchanged connectivity only refreshes value streams.
    """
    if cache is None:
        return build_assembly_schedule(mesh_or_conn, ndof_per_node,
                                       num_buffers, num_nodes=num_nodes,
                                       coloring_provider=coloring_provider)
    if isinstance(mesh_or_conn, Mesh):
        conn, nn = mesh_or_conn.conn, mesh_or_conn.num_nodes
    else:
        conn = np.asarray(mesh_or_conn)
        nn = int(conn.max()) + 1 if num_nodes is None else num_nodes
    digest = structure_digest(conn, ndof_per_node, nn)
    # same clamp the builder applies, so lookup and stored keys agree on
    # meshes with fewer elements than buffers
    num_buffers = max(1, min(num_buffers, int(conn.shape[0])))
    hit = cache.get_assembly_schedule(digest, num_buffers,
                                      coloring=coloring_provider)
    if hit is not None:
        return hit
    sched = build_assembly_schedule(conn, ndof_per_node, num_buffers,
                                    num_nodes=nn,
                                    coloring_provider=coloring_provider)
    cache.put_assembly_schedule(sched)
    return sched


# ---------------------------------------------------------------------------
# Accumulation strategies
# ---------------------------------------------------------------------------

def scatter_colored_percolor(sched: AssemblySchedule, ke) -> jnp.ndarray:
    """The legacy per-color discipline: one XLA ``.at[].add`` scatter per
    color class, serialized — C dispatches per refresh.  Kept as the
    baseline the fused colored-batch kernels are benchmarked against
    (CI asserts a Pallas strategy beats it on the tet suite)."""
    kflat = jnp.asarray(ke, jnp.float32).reshape(sched.ne, -1)
    t2 = np.asarray(sched.targets).reshape(sched.ne, -1)
    vals = jnp.zeros(sched.size, jnp.float32)
    col = sched.coloring
    for c in range(col.num_colors):
        els = np.asarray(col.rows(c))
        if els.size == 0:
            continue
        tg = jnp.asarray(t2[els].reshape(-1))
        vals = vals.at[tg].add(kflat[jnp.asarray(els)].reshape(-1))
    return vals


def scatter_colored(sched: AssemblySchedule, ke, variant: str = "stream",
                    interpret: bool = True) -> jnp.ndarray:
    """Per-color batched conflict-free scatter-add: inside one color every
    target index is unique (no two elements share a DOF), so each color
    batch is a permutation write — the colorful path's execution
    discipline applied to assembly.  Executed by the fused colored-batch
    kernels (``variant`` in {'stream', 'onehot'}, dispatched like the
    SpMV variants) over the schedule's precomputed (C, Lmax) packs;
    ``variant='percolor'`` selects the legacy one-scatter-per-color
    baseline.  jit-compatible (the packs are static per schedule)."""
    if variant == "percolor":
        return scatter_colored_percolor(sched, ke)
    return akern.colored_scatter(
        sched.color_slots, sched.color_targets,
        jnp.asarray(ke, jnp.float32), sched.size,
        variant=variant, interpret=interpret)


def scatter_sorted(sched: AssemblySchedule, ke) -> jnp.ndarray:
    """Sorted-slot assembly (arXiv:2012.00585 analogue): contributions
    were argsorted by destination at schedule-build time, so the refresh
    is one color-free gather + monotone segment-sum — a single fused
    launch with no palette term.  jit-compatible."""
    return akern.sorted_scatter(
        sched.sorted_perm, sched.sorted_targets,
        jnp.asarray(ke, jnp.float32), sched.size)


def scatter_private(sched: AssemblySchedule, ke) -> jnp.ndarray:
    """Private-buffer accumulation: each buffer scatter-adds its element
    chunk into its own full-length partial (duplicates within a buffer are
    fine — the buffer is private), then the partials are reduced — the
    paper's local-buffers / all-in-one strategy (§3.1) as a vmap +
    tree-sum.  Padded slots target a dump entry past the vector end."""
    kflat = jnp.asarray(ke, jnp.float32).reshape(sched.ne, -1)
    t2 = jnp.asarray(sched.targets.reshape(sched.ne, -1))
    be = jnp.asarray(sched.buffer_elements)             # (B, epb)
    valid = (be >= 0)[..., None]
    el = jnp.maximum(be, 0)
    v3 = jnp.where(valid, kflat[el], 0.0)               # (B, epb, edof²)
    t3 = jnp.where(valid, t2[el], sched.size)           # dump slot

    def one_buffer(tg, vv):
        return jnp.zeros(sched.size + 1, jnp.float32).at[
            tg.reshape(-1)].add(vv.reshape(-1))

    partials = jax.vmap(one_buffer)(t3, v3)             # (B, size+1)
    return partials.sum(axis=0)[:sched.size]


def scatter_serial(sched: AssemblySchedule, ke) -> np.ndarray:
    """Serial numpy oracle: element-order ``np.add.at`` — the ground truth
    the parallel strategies must reproduce (bit-for-bit with the dyadic
    stiffness synthesis)."""
    vals = np.zeros(sched.size, np.float32)
    np.add.at(vals, np.asarray(sched.targets),
              np.asarray(ke, np.float32).reshape(-1))
    return vals


def values_to_csrc(sched: AssemblySchedule, vals) -> csrc.CSRC:
    """Split the unified value vector back into (ad, al, au) and wrap the
    schedule's structure — the O(k) value-refresh constructor."""
    vals = np.asarray(vals, np.float32)
    n, k = sched.n, sched.k
    return csrc.from_assembly(n, sched.ia, sched.ja,
                              vals[:n], vals[n:n + k], vals[n + k:])


def assemble(sched: AssemblySchedule, ke, strategy: str = "colored",
             variant: Optional[str] = None,
             interpret: bool = True) -> csrc.CSRC:
    """Assemble the global CSRC matrix from per-element dense blocks
    ``ke`` of shape (ne, edof, edof) with the chosen accumulation
    strategy.

    This IS the value-refresh fast path: every call reuses the
    schedule's precomputed packs (zero structural work — the
    ``BUILD_COUNTS['assembly_value_refresh']`` probe counts exactly one
    refresh per call and nothing else moves), runs under an obs span,
    and lands its wall time in ``assembly_scatter_seconds{strategy,
    variant}``."""
    if strategy not in STRATEGIES:
        raise ValueError(f"strategy {strategy!r} not in {STRATEGIES}")
    variant = _DEFAULT_VARIANT[strategy] if variant is None else variant
    t0 = time.perf_counter()
    with obs.span("assembly.value_refresh", strategy=strategy,
                  variant=variant):
        if strategy == "colored":
            vals = scatter_colored(sched, ke, variant=variant,
                                   interpret=interpret)
        elif strategy == "sorted":
            vals = scatter_sorted(sched, ke)
        elif strategy == "private":
            vals = scatter_private(sched, ke)
        else:
            vals = scatter_serial(sched, ke)
        # values_to_csrc materializes the device values, so the span and
        # the histogram cover the actual scatter work
        M = values_to_csrc(sched, vals)
    BUILD_COUNTS.inc("assembly_value_refresh")
    obs.histogram("assembly_scatter_seconds", strategy=strategy,
                  variant=variant).observe(time.perf_counter() - t0)
    return M


def assemble_mesh(mesh: Mesh, ke, ndof_per_node: int = 1,
                  strategy: str = "colored", cache=None,
                  num_buffers: int = 8,
                  coloring_provider: str = "greedy"):
    """One-call mesh → CSRC assembly; returns (matrix, schedule) so
    repeated value refreshes reuse the schedule (or pass ``cache=`` and
    the connectivity digest does it for you)."""
    sched = assembly_schedule_for(mesh, ndof_per_node=ndof_per_node,
                                  num_buffers=num_buffers, cache=cache,
                                  coloring_provider=coloring_provider)
    return assemble(sched, ke, strategy=strategy), sched


# ---------------------------------------------------------------------------
# Predict-then-measure strategy selection (the assembly tuner path)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AssemblyTuneResult:
    """Winner of one assembly strategy tune (mirrors tuner.TuneResult)."""
    strategy: str
    variant: str
    timings_s: Dict[str, float]        # "strategy/variant" -> measured s
    predictions_s: Dict[str, float]    # every priced candidate
    roofline_fraction: Dict[str, float]  # predicted/measured, measured set
    cached: bool                       # True = PlanCache hit, nothing timed

    def key(self) -> str:
        return f"{self.strategy}/{self.variant}"


def _scatter_fn(sched: AssemblySchedule, strategy: str, variant: str):
    """The jitted value-refresh executor of one candidate."""
    if strategy == "colored":
        return jax.jit(lambda k: scatter_colored(sched, k,
                                                 variant=variant))
    if strategy == "sorted":
        return jax.jit(lambda k: scatter_sorted(sched, k))
    if strategy == "private":
        return jax.jit(lambda k: scatter_private(sched, k))
    raise ValueError(f"no tunable executor for strategy {strategy!r}")


def _time_scatter(fn, kej, warmup: int = 2, repeats: int = 5) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(kej))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(kej))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def tune_assembly(sched: AssemblySchedule, ke, cache=None,
                  measure=None, repeats: int = 5,
                  force: bool = False) -> AssemblyTuneResult:
    """Pick the assembly (strategy, variant) for this schedule: price the
    whole candidate pool with the roofline model, measure the cheapest
    half (plus each strategy's best-predicted variant, so no family is
    pruned unseen), argmin, and record predicted-vs-measured provenance.

    The winner persists in the PlanCache under ``asmplan-<schedule key>``
    — a later call with the same cache returns it without timing
    anything.  ``measure(fn, ke)`` is injectable for deterministic
    tests."""
    from repro.roofline import cost_model

    plan_key = "asmplan-" + sched.key()
    if cache is not None and not force:
        hit = cache.get_assembly_plan(plan_key)
        if hit is not None:
            return AssemblyTuneResult(
                strategy=hit["strategy"], variant=hit["variant"],
                timings_s=dict(hit.get("timings_s", {})),
                predictions_s=dict(hit.get("predictions_s", {})),
                roofline_fraction=dict(hit.get("roofline_fraction", {})),
                cached=True)

    priced = cost_model.rank_assembly_candidates(sched,
                                                 ASSEMBLY_CANDIDATES)
    predictions = {f"{s}/{v}": est.predicted_s for (s, v), est in priced}
    ests = {f"{s}/{v}": est for (s, v), est in priced}
    obs.counter("assembly_tuner_candidates_total",
                outcome="enumerated").inc(len(priced))

    pool = [sv for sv, _ in priced]
    chosen = list(pool[:max(2, len(pool) // 2)])
    seen_strategies = {s for s, _ in chosen}
    for s, v in pool:                  # best-predicted variant per family
        if s not in seen_strategies:
            chosen.append((s, v))
            seen_strategies.add(s)

    kej = jnp.asarray(np.asarray(ke, np.float32))
    timings: Dict[str, float] = {}
    for s, v in chosen:
        fn = _scatter_fn(sched, s, v)
        t = (measure(fn, kej) if measure is not None
             else _time_scatter(fn, kej, repeats=repeats))
        timings[f"{s}/{v}"] = float(t)
    obs.counter("assembly_tuner_candidates_total",
                outcome="measured").inc(len(timings))

    winner = min(timings, key=timings.get)
    fractions = {key: cost_model.roofline_fraction(ests[key], t)
                 for key, t in timings.items() if t > 0}
    ws, wv = winner.split("/")
    obs.gauge("assembly_roofline_fraction", strategy=ws,
              variant=wv).set(fractions.get(winner, 0.0))

    result = AssemblyTuneResult(
        strategy=ws, variant=wv, timings_s=timings,
        predictions_s=predictions, roofline_fraction=fractions,
        cached=False)
    if cache is not None:
        cache.put_assembly_plan(plan_key, {
            "strategy": ws, "variant": wv, "timings_s": timings,
            "predictions_s": predictions,
            "roofline_fraction": fractions})
    return result
