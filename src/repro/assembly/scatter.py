"""Conflict-free global-matrix assembly: the paper's accumulation
strategies applied to the FEM scatter-add (docs/DESIGN.md §5).

Assembling ``A = Σ_e P_e^T k_e P_e`` is a scatter-add over CSRC slots:
contribution (e, a, b) lands on the diagonal (i == j), on a lower slot
``al[p]`` (i > j) or on the aligned upper slot ``au[p]`` (i < j).  All
three destinations flatten into one **unified value vector**
``[ad | al | au]`` of length n + 2k, so assembly is a single scatter into
that vector and the two race-avoidance families of the paper map exactly:

  colored   per-color batched scatter (elements of one color share no
            DOF ⇒ within a color every target is written once ⇒ a
            permutation write, like the colorful SpMV path §3.2)
  private   per-buffer full-length partials reduced at the end (the
            local-buffers / all-in-one accumulation family §3.1)
  serial    numpy ``np.add.at`` in element order — the ground-truth
            oracle the strategies must reproduce

With the dyadic-quantized stiffness synthesis of ``assembly/mesh.py``
float32 accumulation is exact in any order, so the strategies are
required to agree with the oracle **bit-for-bit** (tests and the CI
assembly smoke assert equality, not closeness).

All structure-dependent precompute — slot layout, contribution targets,
element coloring, buffer grouping — lives in the npz-serializable
:class:`AssemblySchedule`, stored in the tuner's PlanCache next to the
SpMV schedules and keyed by a **connectivity digest**: FEM time stepping
re-assembles with unchanged connectivity and must reuse every artifact
(the ``BUILD_COUNTS['assembly_schedule']`` probe asserts zero rebuilds).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Optional, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import csrc
from repro.core.coloring import Coloring
# the shared build probe (re-exported as schedule.BUILD_COUNTS): assembly
# builds count into the same Counter the SpMV schedule layer uses
from repro.core.paths import BUILD_COUNTS
from repro import obs
from .conflict import color_elements, element_dofs
from .mesh import Mesh

# version 2: the element coloring records its provider ('greedy'|'race')
# plus the RACE level-group metadata; non-greedy providers join the cache
# key.  Version-1 files load as misses and are rebuilt transparently.
ASSEMBLY_VERSION = 2

STRATEGIES = ("colored", "private", "serial")


def assembly_key(digest: str, num_buffers: int,
                 coloring: str = "greedy") -> str:
    """Cache key of one assembly schedule.  Greedy keys are byte-identical
    to pre-provider caches; other providers append their name."""
    suffix = "" if coloring == "greedy" else f".{coloring}"
    return f"asm-{digest}.b{num_buffers}{suffix}"


@dataclasses.dataclass(frozen=True)
class AssemblySchedule:
    """Every structure-dependent precomputation one connectivity needs to
    assemble CSRC matrices, for any number of value refreshes."""

    structure_digest: str       # connectivity digest (see structure_digest)
    n: int                      # global DOFs
    k: int                      # strictly-lower CSRC slots
    ne: int                     # elements
    edof: int                   # DOFs per element
    ndof_per_node: int
    num_buffers: int            # private-buffer strategy width
    ia: np.ndarray              # (n+1,) CSRC lower-triangle row pointers
    ja: np.ndarray              # (k,)
    # contribution (e, a, b) at flat index e·edof² + a·edof + b scatters to
    # targets[...] in the unified [ad | al | au] vector of length n + 2k
    targets: np.ndarray         # (ne·edof²,) int32
    coloring: Coloring          # element coloring (conflict.color_elements)
    buffer_elements: np.ndarray  # (num_buffers, epb) int32, -1 = padding

    @property
    def size(self) -> int:
        """Length of the unified value vector."""
        return self.n + 2 * self.k

    def key(self) -> str:
        return assembly_key(self.structure_digest, self.num_buffers,
                            self.coloring.provider)

    # ------------------------------------------------------------------
    # Serialization (npz arrays + JSON meta, SpmvSchedule conventions)
    # ------------------------------------------------------------------

    def save_npz(self, path: str):
        meta = {
            "version": ASSEMBLY_VERSION,
            "structure_digest": self.structure_digest,
            "n": self.n, "k": self.k, "ne": self.ne, "edof": self.edof,
            "ndof_per_node": self.ndof_per_node,
            "num_buffers": self.num_buffers,
            "num_colors": int(self.coloring.num_colors),
            "coloring_provider": self.coloring.provider,
        }
        arrays = dict(
            ia=np.asarray(self.ia), ja=np.asarray(self.ja),
            targets=np.asarray(self.targets),
            color_of_row=np.asarray(self.coloring.color_of_row),
            rows_by_color=np.asarray(self.coloring.rows_by_color),
            color_ptr=np.asarray(self.coloring.color_ptr),
            buffer_elements=np.asarray(self.buffer_elements),
        )
        # RACE level-group metadata survives the round-trip so reloaded
        # schedules keep the chunk-aware invariant verifiable
        if self.coloring.level_of_row is not None:
            arrays["color_level_of_row"] = np.asarray(
                self.coloring.level_of_row)
        if self.coloring.group_of_row is not None:
            arrays["color_group_of_row"] = np.asarray(
                self.coloring.group_of_row)
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        tmp = path + ".tmp.npz"
        with open(tmp, "wb") as f:
            np.savez_compressed(f, __meta__=np.frombuffer(
                json.dumps(meta, sort_keys=True).encode(), dtype=np.uint8),
                **arrays)
        os.replace(tmp, path)

    @classmethod
    def load_npz(cls, path: str) -> "AssemblySchedule":
        with np.load(path) as z:
            meta = json.loads(bytes(z["__meta__"]).decode())
            if meta.get("version") != ASSEMBLY_VERSION:
                raise ValueError(
                    f"assembly schedule {path}: version "
                    f"{meta.get('version')!r} != {ASSEMBLY_VERSION}")
            coloring = Coloring(
                color_of_row=z["color_of_row"],
                num_colors=int(meta["num_colors"]),
                rows_by_color=z["rows_by_color"],
                color_ptr=z["color_ptr"],
                provider=meta.get("coloring_provider", "greedy"),
                level_of_row=(z["color_level_of_row"]
                              if "color_level_of_row" in z.files else None),
                group_of_row=(z["color_group_of_row"]
                              if "color_group_of_row" in z.files else None))
            return cls(structure_digest=meta["structure_digest"],
                       n=meta["n"], k=meta["k"], ne=meta["ne"],
                       edof=meta["edof"],
                       ndof_per_node=meta["ndof_per_node"],
                       num_buffers=meta["num_buffers"],
                       ia=z["ia"], ja=z["ja"], targets=z["targets"],
                       coloring=coloring,
                       buffer_elements=z["buffer_elements"])


def structure_digest(conn: np.ndarray, ndof_per_node: int = 1,
                     num_nodes: Optional[int] = None) -> str:
    """Digest of the element connectivity (the assembly-side analog of
    ``schedule.structure_digest``): unchanged connectivity ⇒ identical
    slot layout, targets, coloring, and buffer grouping."""
    conn = np.ascontiguousarray(np.asarray(conn, np.int64))
    num_nodes = int(conn.max()) + 1 if num_nodes is None else num_nodes
    h = hashlib.sha1()
    h.update(np.asarray([conn.shape[0], conn.shape[1], num_nodes,
                         ndof_per_node], np.int64).tobytes())
    h.update(conn.tobytes())
    return h.hexdigest()[:16]


def build_assembly_schedule(mesh_or_conn: Union[Mesh, np.ndarray],
                            ndof_per_node: int = 1,
                            num_buffers: int = 8,
                            num_nodes: Optional[int] = None,
                            coloring: Optional[Coloring] = None,
                            coloring_provider: str = "greedy"
                            ) -> AssemblySchedule:
    """Build the full assembly artifact for one connectivity.

    The slot layout (ia/ja) is the union of every element's dense block,
    lower triangle only — structurally symmetric by construction, so the
    assembled matrix needs no :func:`~repro.core.csrc.symmetrize_pattern`
    pass.  Contribution targets are resolved once via searchsorted on the
    sorted lower-slot keys; the element coloring and the private-buffer
    grouping ride along.
    """
    if isinstance(mesh_or_conn, Mesh):
        conn = mesh_or_conn.conn
        num_nodes = mesh_or_conn.num_nodes
    else:
        conn = np.asarray(mesh_or_conn)
        num_nodes = (int(conn.max()) + 1 if num_nodes is None
                     else num_nodes)
    BUILD_COUNTS.inc("assembly_schedule")
    d = ndof_per_node
    n = num_nodes * d
    with obs.span("assembly.build_schedule", ndof_per_node=d):
        ed = element_dofs(conn, d)                 # (ne, edof)
        ne, edof = ed.shape

        with obs.span("assembly.slot_pack", ne=ne, edof=edof):
            ii = np.broadcast_to(ed[:, :, None],
                                 (ne, edof, edof)).reshape(-1)
            jj = np.broadcast_to(ed[:, None, :],
                                 (ne, edof, edof)).reshape(-1)
            ii = ii.astype(np.int64)
            jj = jj.astype(np.int64)

            low = ii > jj
            keys = np.unique(ii[low] * n + jj[low])  # sorted lower slots
            k = int(keys.shape[0])
            rows = (keys // n).astype(np.int64)
            ja = (keys % n).astype(np.int32)
            ia = np.zeros(n + 1, dtype=np.int32)
            np.add.at(ia, rows + 1, 1)
            ia = np.cumsum(ia, dtype=np.int32)

            targets = np.empty(ne * edof * edof, dtype=np.int32)
            diag = ii == jj
            targets[diag] = ii[diag]
            targets[low] = n + np.searchsorted(keys, ii[low] * n + jj[low])
            up = ii < jj
            targets[up] = n + k + np.searchsorted(keys,
                                                  jj[up] * n + ii[up])

        if coloring is None:
            BUILD_COUNTS.inc("element_coloring")
            with obs.span("assembly.element_coloring",
                          provider=coloring_provider):
                coloring = color_elements(conn, provider=coloring_provider)

    # private-buffer grouping: contiguous element chunks (locality), padded
    # to a rectangular (B, epb) table with -1 sentinels
    B = max(1, min(num_buffers, ne))
    epb = -(-ne // B)
    buffer_elements = np.full((B, epb), -1, dtype=np.int32)
    flat = buffer_elements.reshape(-1)
    flat[:ne] = np.arange(ne, dtype=np.int32)

    return AssemblySchedule(
        structure_digest=structure_digest(conn, d, num_nodes),
        n=n, k=k, ne=ne, edof=edof, ndof_per_node=d, num_buffers=B,
        ia=ia, ja=ja, targets=targets, coloring=coloring,
        buffer_elements=buffer_elements)


def assembly_schedule_for(mesh_or_conn, ndof_per_node: int = 1,
                          num_buffers: int = 8, cache=None,
                          num_nodes: Optional[int] = None,
                          coloring_provider: str = "greedy"
                          ) -> AssemblySchedule:
    """The schedule to assemble this connectivity with — cache hit wins.

    ``cache`` is a :class:`~repro.core.tuner.PlanCache`; a hit (keyed by
    the connectivity digest and the element-coloring provider) performs
    zero structural work, which is the FEM time-stepping fast path:
    re-assembly with unchanged connectivity only refreshes value streams.
    """
    if cache is None:
        return build_assembly_schedule(mesh_or_conn, ndof_per_node,
                                       num_buffers, num_nodes=num_nodes,
                                       coloring_provider=coloring_provider)
    if isinstance(mesh_or_conn, Mesh):
        conn, nn = mesh_or_conn.conn, mesh_or_conn.num_nodes
    else:
        conn = np.asarray(mesh_or_conn)
        nn = int(conn.max()) + 1 if num_nodes is None else num_nodes
    digest = structure_digest(conn, ndof_per_node, nn)
    # same clamp the builder applies, so lookup and stored keys agree on
    # meshes with fewer elements than buffers
    num_buffers = max(1, min(num_buffers, int(conn.shape[0])))
    hit = cache.get_assembly_schedule(digest, num_buffers,
                                      coloring=coloring_provider)
    if hit is not None:
        return hit
    sched = build_assembly_schedule(conn, ndof_per_node, num_buffers,
                                    num_nodes=nn,
                                    coloring_provider=coloring_provider)
    cache.put_assembly_schedule(sched)
    return sched


# ---------------------------------------------------------------------------
# Accumulation strategies
# ---------------------------------------------------------------------------

def scatter_colored(sched: AssemblySchedule, ke) -> jnp.ndarray:
    """Per-color batched conflict-free scatter-add: inside one color every
    target index is unique (no two elements share a DOF), so each
    ``.at[].add`` is a permutation write — the colorful path's execution
    discipline applied to assembly.  jit-compatible (color batches are
    static per schedule)."""
    kflat = jnp.asarray(ke, jnp.float32).reshape(sched.ne, -1)
    t2 = np.asarray(sched.targets).reshape(sched.ne, -1)
    vals = jnp.zeros(sched.size, jnp.float32)
    col = sched.coloring
    for c in range(col.num_colors):
        els = np.asarray(col.rows(c))
        if els.size == 0:
            continue
        tg = jnp.asarray(t2[els].reshape(-1))
        vals = vals.at[tg].add(kflat[jnp.asarray(els)].reshape(-1))
    return vals


def scatter_private(sched: AssemblySchedule, ke) -> jnp.ndarray:
    """Private-buffer accumulation: each buffer scatter-adds its element
    chunk into its own full-length partial (duplicates within a buffer are
    fine — the buffer is private), then the partials are reduced — the
    paper's local-buffers / all-in-one strategy (§3.1) as a vmap +
    tree-sum.  Padded slots target a dump entry past the vector end."""
    kflat = jnp.asarray(ke, jnp.float32).reshape(sched.ne, -1)
    t2 = jnp.asarray(sched.targets.reshape(sched.ne, -1))
    be = jnp.asarray(sched.buffer_elements)             # (B, epb)
    valid = (be >= 0)[..., None]
    el = jnp.maximum(be, 0)
    v3 = jnp.where(valid, kflat[el], 0.0)               # (B, epb, edof²)
    t3 = jnp.where(valid, t2[el], sched.size)           # dump slot

    def one_buffer(tg, vv):
        return jnp.zeros(sched.size + 1, jnp.float32).at[
            tg.reshape(-1)].add(vv.reshape(-1))

    partials = jax.vmap(one_buffer)(t3, v3)             # (B, size+1)
    return partials.sum(axis=0)[:sched.size]


def scatter_serial(sched: AssemblySchedule, ke) -> np.ndarray:
    """Serial numpy oracle: element-order ``np.add.at`` — the ground truth
    the parallel strategies must reproduce (bit-for-bit with the dyadic
    stiffness synthesis)."""
    vals = np.zeros(sched.size, np.float32)
    np.add.at(vals, np.asarray(sched.targets),
              np.asarray(ke, np.float32).reshape(-1))
    return vals


def values_to_csrc(sched: AssemblySchedule, vals) -> csrc.CSRC:
    """Split the unified value vector back into (ad, al, au) and wrap the
    schedule's structure — the O(k) value-refresh constructor."""
    vals = np.asarray(vals, np.float32)
    n, k = sched.n, sched.k
    return csrc.from_assembly(n, sched.ia, sched.ja,
                              vals[:n], vals[n:n + k], vals[n + k:])


def assemble(sched: AssemblySchedule, ke,
             strategy: str = "colored") -> csrc.CSRC:
    """Assemble the global CSRC matrix from per-element dense blocks
    ``ke`` of shape (ne, edof, edof) with the chosen accumulation
    strategy."""
    if strategy not in STRATEGIES:
        raise ValueError(f"strategy {strategy!r} not in {STRATEGIES}")
    if strategy == "colored":
        vals = scatter_colored(sched, ke)
    elif strategy == "private":
        vals = scatter_private(sched, ke)
    else:
        vals = scatter_serial(sched, ke)
    return values_to_csrc(sched, vals)


def assemble_mesh(mesh: Mesh, ke, ndof_per_node: int = 1,
                  strategy: str = "colored", cache=None,
                  num_buffers: int = 8,
                  coloring_provider: str = "greedy"):
    """One-call mesh → CSRC assembly; returns (matrix, schedule) so
    repeated value refreshes reuse the schedule (or pass ``cache=`` and
    the connectivity digest does it for you)."""
    sched = assembly_schedule_for(mesh, ndof_per_node=ndof_per_node,
                                  num_buffers=num_buffers, cache=cache,
                                  coloring_provider=coloring_provider)
    return assemble(sched, ke, strategy=strategy), sched
