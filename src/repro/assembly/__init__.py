"""FEM assembly subsystem: conflict-free construction of the global CSRC
matrices the SpMV stack consumes (docs/DESIGN.md §5).

  mesh       structured tri/quad/tet meshes + deterministic (dyadic)
             element stiffness synthesis
  conflict   element conflict graph + balanced coloring (reuses
             core/coloring machinery)
  scatter    accumulation strategies (colored-batch kernels /
             sorted-slot / private-buffer / serial oracle) + the cached
             AssemblySchedule artifact + tune_assembly strategy
             selection (kernels live in repro.kernels.assembly_scatter)

End to end:  mesh → stiffness → assemble → tune → solve
(examples/assemble_tune_solve.py; benchmarks/run.py --only assembly).
"""
from .mesh import (Mesh, grid_quad, grid_tet, grid_tri,          # noqa: F401
                   poisson_stiffness, synthetic_stiffness)
from .conflict import (color_elements, element_dofs,             # noqa: F401
                       verify_element_coloring)
from .scatter import (AssemblySchedule, AssemblyTuneResult,      # noqa: F401
                      assemble, assemble_mesh,
                      assembly_schedule_for, build_assembly_schedule,
                      scatter_colored, scatter_colored_percolor,
                      scatter_private, scatter_serial, scatter_sorted,
                      structure_digest, tune_assembly, values_to_csrc)
