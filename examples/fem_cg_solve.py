"""End-to-end driver (the paper's own workload): assemble an FEM-style
system, then run ~1000 CSRC matrix-vector products inside preconditioned
CG / BiCGSTAB — "a reasonable value for iterative solvers" (paper §4).

Compares all execution paths of the engine and reports the per-product
cost + the paper's bandwidth accounting.

  PYTHONPATH=src python examples/fem_cg_solve.py [--n 128] [--products 1000]
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import csrc, solvers
from repro.kernels import ops


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=96,
                    help="grid side (n^2 unknowns)")
    ap.add_argument("--products", type=int, default=1000)
    args = ap.parse_args()

    # --- assembly (5-point Laplacian = the canonical FEM band matrix) ---
    M = csrc.poisson2d(args.n)
    print(f"[assemble] n={M.n} nnz={M.nnz} band={csrc.bandwidth(M)} "
          f"ws={M.working_set_bytes()/1024:.0f}KiB")

    rng = np.random.default_rng(0)
    x_true = jnp.asarray(rng.standard_normal(M.n), dtype=jnp.float32)

    # --- the paper's benchmark loop: 1000 products, both engine paths ---
    x = jnp.asarray(rng.standard_normal(M.n), dtype=jnp.float32)
    for path in ("segment", "kernel"):
        op = ops.SpmvOperator(M, path=path, tm=64)
        y = op(x)
        jax.block_until_ready(y)
        t0 = time.perf_counter()
        reps = args.products if path == "segment" else 25  # interpret slow
        for _ in range(reps):
            y = op(x)
        jax.block_until_ready(y)
        dt = (time.perf_counter() - t0) / reps
        mflops = op.flops_per_call / dt / 1e6
        print(f"[spmv:{path:8s}] {dt*1e6:8.1f} us/product "
              f"{mflops:8.0f} Mflop/s  "
              f"bytes/call={op.bytes_per_call/1024:.0f}KiB")

    # --- PCG solve using the engine ---
    op = ops.SpmvOperator(M, path="segment")
    b = op(x_true)
    t0 = time.perf_counter()
    res = solvers.cg(op, b, tol=1e-7, maxiter=4000, diag=M.ad)
    jax.block_until_ready(res.x)
    dt = time.perf_counter() - t0
    err = float(jnp.abs(res.x - x_true).max())
    print(f"[cg] converged={bool(res.converged)} iters={int(res.iters)} "
          f"res={float(res.residual):.1e} err={err:.1e} ({dt:.2f}s)")

    # --- non-symmetric variant via BiCGSTAB ---
    Mn = csrc.fem_band(M.n, 8, seed=3)
    opn = ops.SpmvOperator(Mn, path="segment")
    bn = opn(x_true)
    resn = solvers.bicgstab(opn, bn, tol=1e-6, maxiter=4000)
    print(f"[bicgstab] converged={bool(resn.converged)} "
          f"iters={int(resn.iters)} res={float(resn.residual):.1e}")

    # --- the paper's load/flop accounting ---
    flops = 2 * M.nnz - M.n
    print(f"[paper-math] CSR loads/flop = {3*M.nnz/flops:.2f}  "
          f"CSRC = {(2.5*M.nnz - 0.5*M.n)/flops:.2f}  "
          f"CSRC(sym) = {2*M.nnz/flops:.2f}")


if __name__ == "__main__":
    main()
