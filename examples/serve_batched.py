"""Batched serving demo: continuous batching over a small model with
per-slot KV caches, greedy + temperature sampling.

  PYTHONPATH=src python examples/serve_batched.py --requests 6
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models.transformer import build_model
from repro.serve.engine import ServingEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_slots=3, max_len=128,
                           eos_id=1)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        engine.submit(Request(
            uid=i, prompt=rng.integers(2, cfg.vocab, 4 + i % 5),
            max_new_tokens=args.max_new,
            temperature=0.0 if i % 2 == 0 else 0.8))
    done = engine.run_until_drained()
    dt = time.perf_counter() - t0
    total = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests / {total} tokens in {dt:.2f}s")
    for r in sorted(done, key=lambda r: r.uid):
        print(f"  req{r.uid}: {r.out_tokens}")


if __name__ == "__main__":
    main()
