"""Mesh-backed serving demo: two matrices served from an 8-shard mesh of
forced host devices, mixed-width traffic coalesced per tick, and one
in-place value refresh (the FEM time-stepping shape) with zero structural
rebuild.

  PYTHONPATH=src python examples/serve_mesh.py --requests 12

Runs on plain CPU: the XLA_FLAGS below force 8 host devices before jax
initializes (remove it to watch placement degrade gracefully to the
local executor).
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse           # noqa: E402  (env must precede jax import)
import dataclasses        # noqa: E402
import time               # noqa: E402

import numpy as np        # noqa: E402

from repro.core import csrc, tuner      # noqa: E402
from repro.serve import SpmvServingEngine  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--mesh-p", type=int, default=8)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    matrices = {
        "fem_band": csrc.fem_band(2048, 16, seed=2),
        "skew_band": csrc.skewed_band(1024, 32, 3, seed=6),
    }

    cache = tuner.PlanCache()
    # mesh-aware tuning: measure the distributed candidates per matrix on
    # the actual mesh; winners land in the cache under <fingerprint>@p8
    for name, M in matrices.items():
        t0 = time.perf_counter()
        res = tuner.tune_mesh(M, args.mesh_p, cache=cache, repeats=1)
        print(f"tuned {name} @p{args.mesh_p}: {res.plan.key()} "
              f"({len(res.timings_s)} candidates, "
              f"{time.perf_counter() - t0:.1f}s)")

    engine = SpmvServingEngine(cache=cache, mesh_p=args.mesh_p)
    for name, M in matrices.items():
        plan = engine.register(name, M)
        print(f"registered {name}: strategy={plan.strategy} "
              f"mesh_p={plan.mesh_p} via {engine.executor(name).kind} "
              f"executor")

    # mixed traffic: interleaved requests against both matrices, answered
    # in coalesced per-matrix SpMM ticks
    expected = {}
    for i in range(args.requests):
        name = "fem_band" if i % 3 else "skew_band"
        M = matrices[name]
        x = rng.standard_normal(M.m).astype(np.float32)
        uid = engine.submit(name, x)
        expected[uid] = np.asarray(csrc.to_dense(M), np.float64) @ x
    t0 = time.perf_counter()
    out = engine.run_until_drained()
    dt = time.perf_counter() - t0
    worst = max(float(np.abs(np.asarray(r, np.float64) - expected[u]).max())
                for u, r in out.items())
    by_exec = {}
    for r in out.values():
        by_exec.setdefault((r.matrix_id, r.executor, r.batched), 0)
        by_exec[(r.matrix_id, r.executor, r.batched)] += 1
    print(f"served {len(out)} requests in {dt:.2f}s "
          f"(max abs err {worst:.2e})")
    for (mid, ex, batched), cnt in sorted(by_exec.items()):
        print(f"  {mid}: {cnt} results via {ex} executor, "
              f"coalesced {batched}/tick")

    # value refresh: same structure, new values — no re-pack/partition
    M = matrices["fem_band"]
    M2 = dataclasses.replace(M, ad=M.ad * 1.5, al=M.al * 1.5,
                             au=M.au * 1.5)
    engine.update_values("fem_band", M2)
    x = rng.standard_normal(M2.m).astype(np.float32)
    uid = engine.submit("fem_band", x)
    y = engine.step()[uid]
    err = float(np.abs(np.asarray(y, np.float64)
                       - np.asarray(csrc.to_dense(M2), np.float64) @ x
                       ).max())
    print(f"value refresh on {y.executor} executor: max abs err {err:.2e}")


if __name__ == "__main__":
    main()
