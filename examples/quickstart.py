"""Quickstart: the CSRC sparse engine in seven steps.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import csrc, solvers, tuner
from repro.core.coloring import color_rows
from repro.kernels import ops

# 1. Build a structurally-symmetric sparse matrix (CSRC format: only the
#    lower triangle's indices are stored — half the index memory of CSR).
M = csrc.poisson2d(32)                       # 1024-dof 2-D Laplacian
print(f"n={M.n} nnz={M.nnz} lower-slots k={M.k} "
      f"numerically_symmetric={M.numerically_symmetric}")
print(f"working set: {M.working_set_bytes() / 1024:.0f} KiB")

# 2. One product — auto path selection (Pallas block-ELL kernel for banded
#    matrices, segment-sum otherwise).
x = jnp.asarray(np.random.default_rng(0).standard_normal(M.n),
                dtype=jnp.float32)
op = ops.SpmvOperator(M, path="auto")
y = op(x)
print(f"path={op.path}  y[:4]={np.asarray(y[:4]).round(3)}")

# 3. The transpose product is O(1) to set up (swap al/au — paper §5).
yt = ops.spmv_transpose(M, x)
print(f"A symmetric => Ax == A^T x: {bool(jnp.allclose(y, yt))}")

# 4. The colorful method (paper §3.2): conflict-free row groups.
col = color_rows(M)
print(f"coloring: {col.num_colors} colors for bandwidth "
      f"{csrc.bandwidth(M)}")

# 5. Solve Ax = b with preconditioned CG — every iteration runs the kernel.
b = op(jnp.ones(M.n))
res = solvers.cg(op, b, tol=1e-6, maxiter=2000, diag=M.ad)
print(f"CG: converged={bool(res.converged)} iters={int(res.iters)} "
      f"residual={float(res.residual):.2e}")

# 6. Multi-RHS (batched serving path).
X = jnp.asarray(np.random.default_rng(1).standard_normal((M.n, 8)),
                dtype=jnp.float32)
print("SpMM out:", ops.spmm(M, X).shape)

# 7. Autotune: measure every feasible ExecutionPlan, cache the argmin by
#    matrix fingerprint (README "Execution plans and autotuning").
cache = tuner.PlanCache()
result = tuner.tune(M, cache=cache)
print(f"tuned plan: {result.plan.key()}  "
      f"({len(result.timings_s)} candidates measured)")
res2, op2 = solvers.cg_solve(M, b, cache=cache, maxiter=2000)
print(f"cg_solve via cached plan: converged={bool(res2.converged)} "
      f"plan={op2.plan.key()} cache_hits={cache.hits}")
