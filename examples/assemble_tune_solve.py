"""End-to-end FEM pipeline over the assembly subsystem: mesh → element
stiffness → conflict-free CSRC assembly → autotuned SpMV plan → CG solve,
then a time-stepping loop that re-assembles values each step and refreshes
the operator without any structural rebuild.

  PYTHONPATH=src python examples/assemble_tune_solve.py [--n 24] [--steps 4]
"""
import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.assembly import (assemble, assembly_schedule_for, mesh as amesh,
                            scatter_serial, tune_assembly)
from repro.core import csrc, schedule as S, tuner
from repro.core.solvers import cg_solve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=24, help="grid side (cells)")
    ap.add_argument("--steps", type=int, default=4,
                    help="time steps (value refreshes)")
    args = ap.parse_args()

    mesh = amesh.grid_tri(args.n)
    cache = tuner.PlanCache()

    # --- one-time structural precompute: slot maps + element coloring ---
    t0 = time.perf_counter()
    sched = assembly_schedule_for(mesh, cache=cache)
    print(f"[schedule] ne={sched.ne} n={sched.n} k={sched.k} "
          f"colors={sched.coloring.num_colors} "
          f"({(time.perf_counter()-t0)*1e3:.1f} ms)")

    # --- pick the scatter executor, assemble, check against the oracle ---
    ke = amesh.poisson_stiffness(mesh, mass=1.0)
    ares = tune_assembly(sched, ke, cache=cache)
    frac = ares.roofline_fraction.get(ares.key(), 0.0)
    print(f"[tune_assembly] winner={ares.key()} "
          f"roofline_fraction={frac:.2f} "
          f"({len(ares.timings_s)} candidates measured)")
    M = assemble(sched, ke, strategy=ares.strategy, variant=ares.variant)
    oracle = scatter_serial(sched, ke)
    exact = np.array_equal(
        np.concatenate([np.asarray(M.ad), np.asarray(M.al),
                        np.asarray(M.au)]), oracle)
    print(f"[assemble] nnz={M.nnz} band={csrc.bandwidth(M)} "
          f"{ares.key()}==serial: {exact}")

    # --- tune, then solve through the shared cache ---
    res = tuner.tune(M, cache=cache)
    print(f"[tune] plan={res.plan.key()} "
          f"({len(res.timings_s)} candidates measured)")
    rng = np.random.default_rng(0)
    x_true = rng.standard_normal(M.n)
    b = jnp.asarray(csrc.to_dense(M).astype(np.float64) @ x_true,
                    dtype=jnp.float32)
    sol, op = cg_solve(M, b, cache=cache, tol=1e-7, maxiter=4000)
    err = float(np.abs(np.asarray(sol.x, np.float64) - x_true).max())
    print(f"[solve] converged={bool(sol.converged)} iters={int(sol.iters)} "
          f"res={float(sol.residual):.1e} err={err:.1e}")

    # --- time stepping: new values, same structure, zero rebuilds ---
    for step in range(1, args.steps + 1):
        before = dict(S.BUILD_COUNTS)
        ke_t = amesh.poisson_stiffness(mesh, mass=1.0 + 0.5 * step)
        M_t = assemble(sched, ke_t, strategy=ares.strategy,
                       variant=ares.variant)
        op.update_values(M_t)
        delta = {k: v - before.get(k, 0) for k, v in S.BUILD_COUNTS.items()
                 if v - before.get(k, 0)}
        sol_t, _ = cg_solve(M_t, b, plan=op.plan, cache=cache, tol=1e-6,
                            maxiter=4000)
        print(f"[step {step}] rebuilds={delta} iters={int(sol_t.iters)} "
              f"converged={bool(sol_t.converged)}")


if __name__ == "__main__":
    main()
