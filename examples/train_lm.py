"""Train a ~100M-param qwen-family model for a few hundred steps through
the full production stack (pipeline → train_step(remat, microbatch) →
AdamW → trainer with checkpoints + straggler monitor).

CPU-friendly default is a ~10M reduced model; pass --full-100m on real
hardware.

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import tempfile

import jax

from repro.configs.base import get_config
from repro.models.transformer import build_model
from repro.data.pipeline import pipeline_for_model
from repro.optim.adamw import AdamWConfig
from repro.train.step import make_train_step, init_train_state
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full-100m", action="store_true")
    args = ap.parse_args()

    cfg = get_config("qwen1.5-0.5b", reduced=True)
    if args.full_100m:
        cfg = dataclasses.replace(
            cfg, n_layers=12, d_model=768, n_heads=12, n_kv=12,
            head_dim=64, d_ff=2048, vocab=32000)     # ~100M params
    else:
        cfg = dataclasses.replace(
            cfg, n_layers=6, d_model=384, n_heads=6, n_kv=6,
            head_dim=64, d_ff=1024, vocab=8192)      # ~10M (CPU demo)

    model = build_model(cfg)
    opt = AdamWConfig(lr_peak=3e-4, warmup_steps=args.steps // 10,
                      total_steps=args.steps)
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    n = sum(p.size for p in jax.tree.leaves(state.params))
    print(f"params: {n/1e6:.1f}M")

    pipe = pipeline_for_model(cfg, global_batch=args.batch,
                              seq_len=args.seq)
    step = jax.jit(make_train_step(model, opt, microbatches=2,
                                   remat="full"), donate_argnums=(0,))
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(TrainerConfig(total_steps=args.steps, ckpt_dir=d,
                                   ckpt_every=max(50, args.steps // 4),
                                   log_every=10),
                     step, pipe, state)
        tr.run()
    for h in tr.history:
        if "loss" in h:
            print(f"step {h['step']:4d}  loss {h['loss']:.4f}  "
                  f"{h['dt']*1e3:.0f} ms")
    first = next(h["loss"] for h in tr.history if "loss" in h)
    last = [h["loss"] for h in tr.history if "loss" in h][-1]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
