"""The benchmark matrix suite — synthetic analogs of the paper's Table 1
classes (the UF collection is not available offline):

  * FEM band matrices (the paper's angical/tracer/cube2m class);
  * 2-D Poisson (narrow-band quasi-diagonal, tmt_sym class);
  * extremely narrow band (torsion1/minsurfo/dixmaanl class);
  * skewed band (wide-band boundary rows over a narrow bulk — the
    row-length-skew class where the flat-grid kernel beats the
    rectangular ELL padding, see benchmarks.run flat_vs_rect);
  * unstructured random pattern (cage15/F1 class — no band);
  * shuffled power-law graph Laplacian (social/power/circuit class —
    hub rows + bandwidth ~ n, the nnz-split path's home turf);
  * dense control (dense_1000).
"""
from repro.core import csrc


def matrices(small: bool = False):
    scale = 4 if small else 1
    out = [
        ("poisson_64x64", lambda: csrc.poisson2d(64 // scale)),
        ("narrow_band1", lambda: csrc.fem_band(20000 // scale, 1, seed=1)),
        ("fem_band_w16", lambda: csrc.fem_band(20000 // scale, 16, seed=2)),
        ("fem_band_w64", lambda: csrc.fem_band(8000 // scale, 64, seed=3)),
        ("fem_band_w64_sym", lambda: csrc.fem_band(
            8000 // scale, 64, seed=3, numeric_symmetric=True)),
        ("skew_band_w48", lambda: csrc.skewed_band(
            8000 // scale, 48, 3, seed=6)),
        ("random_nnz6", lambda: csrc.random_symmetric_pattern(
            8000 // scale, 6, seed=4)),
        ("powerlaw_graph", lambda: csrc.powerlaw_laplacian(
            8000 // scale, seed=7)),
        ("dense_1000", lambda: csrc.dense_matrix(1000 // scale, seed=5)),
    ]
    return out
